"""Serving launcher: batched greedy decoding with a sharded KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \\
      --batch 4 --prompt-len 16 --gen 8

Streaming-decode additions:

  * ``--conv-variant`` routes the SSM/RG-LRU depthwise-conv switch — at
    decode the SSM conv runs the fused single-step ring kernel
    (``core.dwconv.dwconv_decode``), so this flag selects its variant
    ("xla", "rows", "chanblock", "auto", or any model-level variant name).
  * Prefill uses the family's chunked ``prefill()`` fast path when it
    materializes a decode-ready cache (structural check against
    ``init_cache``); otherwise it falls back to the token loop.
  * ``--continuous N`` serves N requests through the ``--batch``-slot pool
    with per-request admission/eviction (continuous batching); per-step
    latencies ride the span tracer and the summary reports tokens/sec and
    p50/p99.
  * ``--json`` writes the printed summary (throughput + latency
    percentiles) as machine-readable JSON.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from collections import deque
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, list_archs
from repro.distributed import sharding as shd
from repro.distributed.stepfn import build_serve_step
from repro.launch.mesh import make_mesh
from repro.models.api import get_model, make_demo_batch
from repro.obs import trace as obs_trace


def _with_conv_variant(cfg, variant: str):
    """Rebuild ``cfg`` with the conv variant switch set on every sub-config
    that carries one (SSM, RG-LRU).  Decode-native names are legal: the
    model maps them per phase (``train_variant_for``/``decode_variant_for``)."""
    changed = False
    if getattr(cfg, "ssm", None) is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, conv_variant=variant))
        changed = True
    if getattr(cfg, "rglru", None) is not None:
        from repro.core.dwconv import train_variant_for
        cfg = dataclasses.replace(
            cfg, rglru=dataclasses.replace(cfg.rglru,
                                           conv_variant=train_variant_for(variant)))
        changed = True
    if not changed:
        print(f"[serve] --conv-variant {variant} ignored: "
              f"{cfg.name} carries no depthwise-conv operator", flush=True)
    return cfg


def build_fast_prefill(model, params, prompt, cache):
    """A jitted chunked-prefill callable, or None when unavailable.

    Available iff the family module has ``prefill`` and (checked abstractly
    via ``jax.eval_shape`` — no execution) it accepts this prompt shape and
    returns ``(logits, cache)`` whose cache tree matches ``init_cache``'s
    shapes/dtypes exactly, i.e. the prefilled state is directly decodable.
    KV families whose prefill cache is sized to the prompt (not the serving
    cache_len), and chunk-constrained prompt lengths, fall back honestly.
    """
    mod = model.module
    if not hasattr(mod, "prefill") or prompt.shape[1] < 1:
        return None

    def fn(p, t):
        return mod.prefill(p, model.cfg, t)

    try:
        out = jax.eval_shape(fn, params, prompt)
    except Exception:  # noqa: BLE001 - any rejection means "not available"
        return None
    if not (isinstance(out, (tuple, list)) and len(out) == 2):
        return None

    def sig(tree):
        return jax.tree.map(
            lambda a: (tuple(a.shape), jnp.dtype(a.dtype).name), tree)

    try:
        if sig(out[1]) != sig(cache):
            return None
    except Exception:  # noqa: BLE001 - tree-structure mismatch
        return None
    return jax.jit(fn)


def _step_percentiles(tracer, name: str):
    """(p50_s, p99_s) over the closed spans named ``name``; (None, None)
    when the tracer recorded none."""
    lat = [r["dur_s"] for r in tracer.records
           if r.get("kind") == "span" and r.get("name") == name]
    if not lat:
        return None, None
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


# ---------------------------------------------------------------------------
# continuous batching: admission/eviction against a fixed slot pool
# ---------------------------------------------------------------------------


def run_continuous(
    model,
    params,
    *,
    slots: int,
    request_tokens: Sequence[np.ndarray],
    gen_lengths: Sequence[int],
    cache_len: int,
    tracer,
    label: str = "serve/continuous",
) -> Dict[str, Any]:
    """Serve ``len(request_tokens)`` requests through a ``slots``-wide pool.

    Each request (a ``(1, P)`` token array) is admitted into a free slot:
    its prompt is prefilled at batch 1 (chunked fast path when available,
    token loop otherwise) and the per-request conv/SSM state is scattered
    into the pooled cache along the ``cache_batch`` axis.  All active slots
    then decode together — one dense step over the whole pool per token, a
    ragged active set whenever requests stagger — and a finished request is
    evicted, freeing its slot for the next pending one.  The slot's stale
    state after eviction is harmless: admission rewrites it wholesale.

    Per-step latency rides ``tracer`` spans (``{label}/step``, tagged
    ``n_active``); pass an *enabled* tracer — the returned wall time and
    percentiles are read back from it.  Returns a summary dict with
    tokens/sec, p50/p99 step latency, and per-request outputs.
    """
    if len(request_tokens) != len(gen_lengths):
        raise ValueError(
            f"{len(request_tokens)} requests but {len(gen_lengths)} gen lengths")
    axes = model.cache_axes()

    def slot_axis(key: str) -> Optional[int]:
        ax = axes.get(key, ())
        return ax.index("cache_batch") if isinstance(ax, tuple) \
            and "cache_batch" in ax else None

    step = jax.jit(build_serve_step(model))
    pool = model.init_cache(slots, cache_len)
    cache1 = model.init_cache(1, cache_len)
    fast = (build_fast_prefill(model, params, request_tokens[0][:, :-1], cache1)
            if request_tokens and request_tokens[0].shape[1] > 1 else None)

    def prefill_one(toks):
        prompt = toks[:, :-1]
        if fast is not None and prompt.shape == request_tokens[0][:, :-1].shape:
            _, c = fast(params, prompt)
            return c
        c = model.init_cache(1, cache_len)
        for i in range(prompt.shape[1]):
            _, c = step(params, c, {"tokens": prompt[:, i:i + 1]})
        return c

    pending = deque(
        (rid, jnp.asarray(toks, jnp.int32)) for rid, toks in
        enumerate(request_tokens) if gen_lengths[rid] > 0)
    done: Dict[int, List[int]] = {rid: [] for rid in range(len(request_tokens))
                                  if gen_lengths[rid] <= 0}
    active: List[Optional[Dict[str, Any]]] = [None] * slots
    cur = jnp.zeros((slots, 1), jnp.int32)
    n_steps = 0
    total_tokens = 0
    with tracer.span(label, slots=slots,
                     requests=len(request_tokens)) as sp_all:
        while pending or any(a is not None for a in active):
            # -- admission: fill free slots from the pending queue ----------
            for sidx in range(slots):
                if active[sidx] is not None or not pending:
                    continue
                rid, toks = pending.popleft()
                with tracer.span(f"{label}/admit", slot=sidx,
                                 request=rid) as sp_ad:
                    c1 = prefill_one(toks)
                    scattered = {}
                    for key, v in pool.items():
                        a = slot_axis(key)
                        if a is None:
                            scattered[key] = v
                        else:
                            idx = (slice(None),) * a + (sidx,)
                            scattered[key] = v.at[idx].set(
                                jnp.take(c1[key], 0, axis=a))
                    pool = scattered
                    sp_ad.sync(pool)
                cur = cur.at[sidx, 0].set(toks[0, -1])
                active[sidx] = {"id": rid, "left": int(gen_lengths[rid]),
                                "out": []}
            # -- one dense decode step over the whole pool ------------------
            n_active = sum(a is not None for a in active)
            with tracer.span(f"{label}/step", n_active=n_active) as sp_st:
                nxt, pool = step(params, pool, {"tokens": cur})
                sp_st.sync(nxt)
            cur = nxt[:, None]
            n_steps += 1
            total_tokens += n_active
            host = np.asarray(nxt)
            # -- eviction: completed requests free their slot ---------------
            for sidx in range(slots):
                a = active[sidx]
                if a is None:
                    continue
                a["out"].append(int(host[sidx]))
                a["left"] -= 1
                if a["left"] <= 0:
                    done[a["id"]] = a["out"]
                    active[sidx] = None
        sp_all.sync(cur)
    p50, p99 = _step_percentiles(tracer, f"{label}/step")
    return {
        "slots": slots,
        "requests": len(request_tokens),
        "steps": n_steps,
        "decode_tokens": total_tokens,
        "wall_s": sp_all.dur_s,
        "tokens_per_s": total_tokens / max(sp_all.dur_s, 1e-9),
        "p50_step_s": p50,
        "p99_step_s": p99,
        "outputs": done,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--conv-variant", default="",
                    help="depthwise-conv variant switch for conv-bearing "
                         "archs: decode runs the fused single-step ring "
                         "kernel under this name ('xla', 'rows', "
                         "'chanblock', 'auto', or a model-level variant)")
    ap.add_argument("--continuous", type=int, default=0, metavar="N",
                    help="continuous-batching mode: serve N requests through "
                         "the --batch slot pool with admission/eviction "
                         "(ragged gen lengths stagger completions)")
    ap.add_argument("--trace", default="",
                    help="write the span trace (JSONL) here; phase timings "
                         "are read from the spans either way")
    ap.add_argument("--json", default="",
                    help="write the serve summary (throughput, p50/p99 step "
                         "latency) as JSON here")
    ap.add_argument("--bundle", default="",
                    help="signed fleet tuning bundle (*.bundle.json) to "
                         "import before serving (warm start; validated + "
                         "degradation-guarded — a bad bundle logs a "
                         "BundleIntegrityError degradation and serving "
                         "proceeds with the local cache)")
    args = ap.parse_args(argv)

    if args.bundle:
        from repro.fleet import import_ as fleet_import
        from repro.tuning.cache import default_cache

        res = fleet_import.import_bundle_guarded(args.bundle,
                                                 cache=default_cache())
        print(f"[serve] bundle {args.bundle}: "
              f"{res.summary() if res else 'rejected; tuning fresh'}",
              flush=True)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.conv_variant:
        cfg = _with_conv_variant(cfg, args.conv_variant)
    # The prefill/decode numbers below are the spans' own measurements
    # (event-style: block_until_ready before the span closes, perf_counter
    # clock) — with --trace they are additionally persisted as JSONL.
    if args.trace:
        tracer = obs_trace.configure(args.trace, meta={"launcher": "serve",
                                                       "arch": cfg.name})
    else:
        tracer = obs_trace.Tracer(enabled=True)
    model = get_model(cfg)
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
    else:
        mesh = make_mesh((1, jax.device_count()), ("data", "model"))

    summary: Dict[str, Any] = {
        "arch": cfg.name, "batch": args.batch,
        "prompt_len": args.prompt_len, "gen": args.gen,
        "conv_variant": args.conv_variant or None,
    }
    with mesh, shd.use_sharding(mesh, "serve"):
        params = model.init(jax.random.PRNGKey(args.seed))

        if args.continuous > 0:
            # ragged gen lengths: completions stagger, so the active set
            # shrinks/refills and every pool width between 1 and `slots`
            # is exercised.
            rng = np.random.default_rng(args.seed)
            reqs = [rng.integers(0, cfg.vocab,
                                 size=(1, args.prompt_len)).astype(np.int32)
                    for _ in range(args.continuous)]
            gens = [max(1, args.gen - (i % 3)) for i in range(args.continuous)]
            stats = run_continuous(
                model, params, slots=args.batch, request_tokens=reqs,
                gen_lengths=gens, cache_len=args.cache_len, tracer=tracer)
            stats.pop("outputs")
            summary["continuous"] = stats
            print(f"[serve] arch={cfg.name} continuous: "
                  f"{stats['requests']} requests over {stats['slots']} slots "
                  f"in {stats['steps']} steps — "
                  f"{stats['decode_tokens']} tok in {stats['wall_s']:.2f}s "
                  f"({stats['tokens_per_s']:.1f} tok/s)")
            if stats["p50_step_s"] is not None:
                print(f"[serve] continuous step latency "
                      f"p50 {stats['p50_step_s'] * 1e3:.2f} ms  "
                      f"p99 {stats['p99_step_s'] * 1e3:.2f} ms")
            _finish(args, tracer, summary)
            return 0

        batch = make_demo_batch(cfg, args.batch, args.prompt_len)
        cache = model.init_cache(args.batch, args.cache_len)
        # enc-dec / vlm: precompute cross caches from the stub modality input
        if cfg.family == "encdec":
            from repro.models import encdec
            enc = encdec.encode(params, cfg, jnp.asarray(
                np.random.default_rng(0).normal(
                    size=(args.batch, cfg.encdec.enc_frames, cfg.d_model)), jnp.float32))
            ck, cv = encdec.precompute_cross_cache(params, cfg, enc)
            cache["cross_k"], cache["cross_v"] = ck, cv
        if cfg.family == "vlm":
            from repro.models import vlm
            ik, iv = vlm.precompute_img_cache(params, cfg, batch["img"])
            cache["img_k"], cache["img_v"] = ik, iv

        serve_step = jax.jit(build_serve_step(model), donate_argnums=(1,))
        # Warm-up on a throwaway cache: the step is shape-stable across
        # prefill and decode, so one call compiles it and neither phase's
        # timing is billed for jit compilation.  (The real cache cannot be
        # used — it is donated.)
        warm = model.init_cache(args.batch, args.cache_len)
        for key in ("cross_k", "cross_v", "img_k", "img_v"):
            if key in cache:
                # Copy, don't alias: serve_step donates its cache argument,
                # and donating a buffer the real cache still references
                # would invalidate it before prefill runs.
                warm[key] = jnp.copy(cache[key])
        jax.block_until_ready(
            serve_step(params, warm, {"tokens": batch["tokens"][:, :1]}))

        # Chunked prefill when the family materializes a decode-ready cache
        # in one call; token-by-token teacher forcing otherwise.
        prompt = batch["tokens"][:, : args.prompt_len - 1]
        fast_prefill = build_fast_prefill(model, params, prompt, cache)
        prefill_mode = "chunked" if fast_prefill is not None else "token-loop"
        with tracer.span("serve/prefill", tokens=args.prompt_len - 1,
                         mode=prefill_mode) as sp_pre:
            if fast_prefill is not None:
                _, cache = fast_prefill(params, prompt)
            else:
                for i in range(args.prompt_len - 1):
                    # unsynced: per-token prefill spans time the *enqueue*
                    # (the dispatch floor); the phase span syncs and owns
                    # execution.
                    with tracer.span("serve/prefill/token", pos=i):
                        _, cache = serve_step(
                            params, cache,
                            {"tokens": batch["tokens"][:, i: i + 1]})
            sp_pre.sync(cache)
        t_prefill = sp_pre.dur_s

        # Decode continues from the *last* prompt token (tokens 0..P-2 are
        # already in the cache; feeding token P-1 predicts position P).
        tok = batch["tokens"][:, -1:]
        generated = []
        with tracer.span("serve/decode", tokens=args.gen) as sp_dec:
            for pos in range(args.gen):
                with tracer.span("serve/decode/token", pos=pos) as sp_tok:
                    nxt, cache = serve_step(params, cache, {"tokens": tok})
                    tok = nxt[:, None]
                    # np.asarray devices-to-host copies, which blocks on the
                    # step — the per-token span time is the real step latency.
                    generated.append(np.asarray(tok))
                    sp_tok.sync(tok)
            sp_dec.sync(tok)
        t_decode = sp_dec.dur_s
    # --gen 0 is a legitimate prefill-only measurement: keep shapes valid.
    gen = (np.concatenate(generated, axis=1) if generated
           else np.zeros((args.batch, 0), np.int64))
    prefill_toks = args.batch * (args.prompt_len - 1)
    decode_toks = args.batch * gen.shape[1]
    p50, p99 = _step_percentiles(tracer, "serve/decode/token")
    summary.update({
        "prefill_mode": prefill_mode,
        "prefill_s": t_prefill,
        "prefill_tokens_per_s": prefill_toks / max(t_prefill, 1e-9),
        "decode_s": t_decode,
        "decode_tokens_per_s": decode_toks / max(t_decode, 1e-9),
        "decode_p50_step_s": p50,
        "decode_p99_step_s": p99,
    })
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill[{prefill_mode}] {args.prompt_len - 1} tok/seq in "
          f"{t_prefill:.2f}s "
          f"({prefill_toks / max(t_prefill, 1e-9):.1f} tok/s)")
    print(f"[serve] decode {gen.shape[1]} tok/seq in {t_decode:.2f}s "
          f"({decode_toks / max(t_decode, 1e-9):.1f} tok/s)")
    if p50 is not None:
        print(f"[serve] decode step latency p50 {p50 * 1e3:.2f} ms  "
              f"p99 {p99 * 1e3:.2f} ms")
    print("[serve] sample token ids:", gen[0].tolist())
    _finish(args, tracer, summary)
    return 0


def _finish(args, tracer, summary: Dict[str, Any]) -> None:
    if args.trace:
        tracer.close()
        print(f"[serve] trace written to {args.trace} "
              f"({len(tracer.records)} records)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"[serve] summary written to {args.json}")


if __name__ == "__main__":
    sys.exit(main())
