"""Quickstart: the paper's operator study in 30 lines.

Runs the depthwise convolution through every kernel variant (the paper's
naive -> coalesced -> shared-memory -> warp-tiled ladder, TPU-adapted),
validates them against the reference, and prints the counter-free traffic
model that explains their ordering.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hw import TPU_V5E
from repro.analysis.traffic import bwdk_traffic, fwd_traffic
from repro.core import dwconv as dw
from repro.core.variant import REGISTRY
from repro.kernels import ref
from repro.kernels.common import DWConvDims

B, H, L, K = 8, 128, 48, 48  # the paper's operator shape (reduced batch)
dims = DWConvDims(B=B, H=H, L=L, K=K)

rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(B, H, L)), jnp.float32)
k = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
dy = jnp.asarray(rng.normal(size=(B, H, L)), jnp.float32)

y_ref = ref.dwconv_fwd_ref(x, k)
print(f"operator: depthwise conv  (B,H,L,K)=({B},{H},{L},{K})")
print(f"{'variant':8s} {'max|err|':>10s} {'fwd bytes (modeled)':>20s} {'bwd_k bytes':>14s}")
for name, spec in REGISTRY.items():
    y = dw.run_fwd(x, k, variant=name)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    tf = fwd_traffic(dims, spec.fwd)
    tb = bwdk_traffic(dims, spec.bwd_k)
    print(f"{name:8s} {err:10.2e} {tf.bytes_moved:20.3e} {tb.bytes_moved:14.3e}"
          + ("   <- redundant-traffic proxy (paper: N/A)" if not tf.reliable else ""))

# differentiable end-to-end through the best (row / warp-tiled) variant
loss = lambda x, k: jnp.sum(jnp.tanh(dw.dwconv(x, k, variant="row")))
gx, gk = jax.grad(loss, argnums=(0, 1))(x, k)
print(f"\ncustom_vjp: grad norms |gx|={float(jnp.linalg.norm(gx)):.3f} "
      f"|gk|={float(jnp.linalg.norm(gk)):.3f}")
print(f"roofline knee on {TPU_V5E.name}: {TPU_V5E.roofline_knee():.1f} FLOP/byte "
      f"(operator AI ~{fwd_traffic(dims, 'row').arithmetic_intensity:.1f} -> memory-bound, as the paper finds)")
