"""Perf-trajectory CLI: append to, inspect, and gate on the bench ledger.

  PYTHONPATH=src python -m repro.launch.perf                  # show trajectory
  PYTHONPATH=src python -m repro.launch.perf --append BENCH_kernels.json
  PYTHONPATH=src python -m repro.launch.perf --check          # regression gate

``benchmarks/run.py --json`` appends its top-level metrics automatically;
``--append`` ingests an existing BENCH_*.json by hand.  ``--check`` gates
the newest entry against the rolling median of the last ``--window``
entries recorded on the same device fingerprint, with a noise-aware
tolerance (see ``repro.obs.ledger``): exit 1 on regression, 0 otherwise —
wire it as a CI step so "raw speed" claims are enforced, not asserted.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.ledger import (
    append_entry,
    check_regression,
    ledger_path,
    metric_direction,
    numeric_metrics,
    read_ledger,
)


def _fmt(v) -> str:
    if v is None:
        return "-"
    return f"{v:.4g}"


def _show(entries) -> None:
    if not entries:
        print(f"[perf] ledger {ledger_path()} is empty")
        return
    names = sorted({m for e in entries for m in e.metrics})
    print(f"[perf] {len(entries)} entries in {ledger_path()}")
    header = ["ts", "sha", "fingerprint", "source"] + names
    print(" | ".join(header))
    for e in entries:
        row = [e.ts[:19], e.sha, e.fingerprint, e.source]
        row += [_fmt(e.metrics.get(n)) for n in names]
        print(" | ".join(row))


def _check(entries, args) -> int:
    ok, verdicts = check_regression(
        entries, window=args.window, rel_tol=args.rel_tol,
        noise_mult=args.noise_mult,
        metrics=args.metrics.split(",") if args.metrics else None)
    if not verdicts:
        print("[perf] --check: ledger empty — nothing to gate (pass)")
        return 0
    arrow = {+1: "higher-better", -1: "lower-better", 0: "informational"}
    print("metric | status | current | baseline(median) | tolerance | n | direction")
    for v in verdicts:
        print(f"{v.metric} | {v.status} | {_fmt(v.current)} | "
              f"{_fmt(v.baseline)} | {_fmt(v.tolerance)} | {v.n_history} | "
              f"{arrow[v.direction]}")
    if ok:
        print("[perf] --check: PASS")
        return 0
    bad = ", ".join(v.metric for v in verdicts if v.gate_failed)
    print(f"[perf] --check: REGRESSED ({bad})")
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--ledger", default="",
                    help="ledger path (default $REPRO_PERF_LEDGER or "
                         "results/perf/ledger.jsonl)")
    ap.add_argument("--append", default="", metavar="BENCH_JSON",
                    help="append the top-level metrics of a benchmarks/run.py "
                         "--json payload")
    ap.add_argument("--source", default="launch.perf",
                    help="source label recorded with --append")
    ap.add_argument("--check", action="store_true",
                    help="gate the newest entry vs the rolling baseline; "
                         "exit 1 on regression")
    ap.add_argument("--show", action="store_true",
                    help="print the trajectory (default when no other action)")
    ap.add_argument("--window", type=int, default=5,
                    help="rolling-baseline window (entries)")
    ap.add_argument("--rel-tol", type=float, default=0.05,
                    help="relative tolerance floor")
    ap.add_argument("--noise-mult", type=float, default=3.0,
                    help="MAD-sigma multiplier for the noise band")
    ap.add_argument("--metrics", default="",
                    help="comma-separated metric subset to gate on")
    args = ap.parse_args(argv)

    path = args.ledger or None
    if args.append:
        with open(args.append) as f:
            payload = json.load(f)
        metrics = numeric_metrics(payload)
        if not metrics:
            print(f"[perf] {args.append} has no numeric top-level metrics",
                  file=sys.stderr)
            return 2
        entry = append_entry(metrics, source=args.source, path=path)
        gated = [m for m in metrics if metric_direction(m) != 0]
        print(f"[perf] appended {len(metrics)} metrics "
              f"({len(gated)} gate-able: {', '.join(sorted(gated)) or 'none'}) "
              f"from {args.append} @ {entry.sha}")

    if args.check:
        return _check(read_ledger(path), args)
    if args.show or not args.append:
        _show(read_ledger(path))
    return 0


if __name__ == "__main__":
    sys.exit(main())
