"""Pallas TPU kernels — streaming-decode path: fused single-step ring conv.

Decode generates one token at a time, so the per-step depthwise-conv work
is not a convolution over the cached sequence but a K-tap dot against a
ring buffer of the last K-1 pre-conv inputs (the Mamba/S4 ``conv_state``
idiom; ``models/ssm.py`` carries exactly this state).  These kernels fuse
the whole step:

    ring shift + K-tap dot + bias/act epilogue

into one launch with the ring buffer as carried state: read the (B, K-1, C)
ring and the (B, 1, C) new input, produce the (B, 1, C) activation output
*and* the shifted (B, K-1, C) new ring, touching HBM exactly once per
operand.  Per-step traffic is O(B*C*K) bytes against O(B*C*L) for re-running
the full conv over the cache — the most extreme memory-bound regime in the
repo (arithmetic intensity ~K flops per ring byte round-trip).

Layout: at L=1 the temporal axis degenerates, so **channels ride the lane
axis** — ``ops.py`` transposes to channel-last ``(B, K-1, Hp)`` / ``(B, 1,
Hp)`` with the channel axis padded to a lane-aligned tile ``Hl`` (the
``block_t`` knob, reused as the channel tile at decode).  Weights arrive as
a (K, Hp) tap-major block, bias as a (1, Hp) row.

Two variants (the ``variant="auto"`` study axis for this path):

  rows      : grid (nH,); the whole padded slot pool (Bp rows) is staged
              per channel tile — minimal grid, VMEM grows with Bp.
  chanblock : grid (nB, nH); the pool is chunked into ``batch_chunk``-row
              blocks — Bp-independent VMEM, more cells.

Both accumulate in f32 with ascending taps (ring taps 0..K-2 then the new
input as tap K-1) — the same operation order as ``ref.dwconv_decode_ref``
and the full-sequence ``ref._fwd_acc``.  The two variants are bit-identical
to *each other*; against the XLA reference they match to FMA-contraction
rounding (~1 ulp), exactly like the rest of the Pallas family vs ``ref.py``
(the reference step chain itself is bit-identical to one causal
``dwconv_act`` over the stream for f32 ``act="none"``).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANE, cdiv
from repro.kernels.epilogue import apply_act


def _epilogue_lanes(acc: jnp.ndarray, b_ref, act: str) -> jnp.ndarray:
    """In-register epilogue on the f32 accumulator, channels-on-lanes layout:
    the bias block is a (1, Hl) row, broadcast over the batch sublanes.  For
    ``b_ref=None, act='none'`` this is the identity — the trivial path stays
    bit-identical to the bias-free kernel."""
    if b_ref is not None:
        acc = acc + b_ref[0, :].astype(jnp.float32)[None, :]
    return apply_act(acc, act)


def _decode_kernel(r_ref, x_ref, k_ref, *rest, K: int, act: str):
    """Fused single-step body: K-tap dot from VMEM, epilogue, ring shift.

    r_ref: (Bb, K-1, Hl) ring (oldest tap first), x_ref: (Bb, 1, Hl) new
    input, k_ref: (K, Hl) taps; outputs y (Bb, 1, Hl) and the shifted ring
    (Bb, K-1, Hl).
    """
    b_ref, (y_ref, nr_ref) = (rest[0], rest[1:]) if len(rest) == 3 else (None, rest)
    ring = r_ref[...]
    xv = x_ref[...]
    kv = k_ref[...].astype(jnp.float32)
    acc = jnp.zeros((ring.shape[0], ring.shape[2]), jnp.float32)
    for j in range(K - 1):  # static unroll, ascending taps (matches ref.py)
        acc = acc + ring[:, j, :].astype(jnp.float32) * kv[j][None, :]
    acc = acc + xv[:, 0, :].astype(jnp.float32) * kv[K - 1][None, :]
    y_ref[...] = _epilogue_lanes(acc, b_ref, act).astype(y_ref.dtype)[:, None, :]
    nr_ref[...] = jnp.concatenate([ring[:, 1:, :], xv], axis=1)


def _decode_geometry(ringT, xT, kT, K: int, block_c: int) -> Tuple[int, int, int, int]:
    """Shared wrapper legality + tiling.  Returns (Bp, Km1, Hl, nH)."""
    Bp, Km1, Hp = ringT.shape
    if K != Km1 + 1:
        raise ValueError(
            f"ring depth K-1={Km1} does not match K={K} taps; the ring must "
            f"hold exactly the last K-1 inputs")
    if K < 2:
        raise ValueError(
            f"decode kernels need K >= 2 (K-1 >= 1 ring taps); K={K} has an "
            f"empty ring — run the XLA reference instead")
    if xT.shape != (Bp, 1, Hp):
        raise ValueError(
            f"step input shape {xT.shape} does not match ring pool "
            f"(B={Bp}, 1, Hp={Hp})")
    if kT.shape != (K, Hp):
        raise ValueError(
            f"tap block shape {kT.shape} does not match (K={K}, Hp={Hp})")
    Hl = min(block_c, Hp)
    if Hl % LANE != 0:
        raise ValueError(
            f"channel tile Hl={Hl} is not lane-aligned (Hl % {LANE} != 0); "
            f"choose KernelOptions.block_t as a multiple of {LANE}")
    if Hp % Hl != 0:
        raise ValueError(
            f"padded channels Hp={Hp} are not divisible by the channel tile "
            f"Hl={Hl}; ops.py must pad the channel axis to the tile")
    return Bp, Km1, Hl, Hp // Hl


def dwconv_decode_rows(
    ringT: jnp.ndarray,
    xT: jnp.ndarray,
    kT: jnp.ndarray,
    *,
    K: int,
    block_c: int = 512,
    interpret: bool = True,
    bias=None,
    act: str = "none",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Whole-pool staging: grid (nH,), the full Bp-slot pool per channel tile.

    ringT: (Bp, K-1, Hp), xT: (Bp, 1, Hp), kT: (K, Hp), bias: (1, Hp) or
    None -> (y (Bp, 1, Hp), new_ring (Bp, K-1, Hp)).
    """
    Bp, Km1, Hl, nH = _decode_geometry(ringT, xT, kT, K, block_c)
    grid = (nH,)
    in_specs = [
        pl.BlockSpec((Bp, Km1, Hl), lambda h: (0, 0, h)),
        pl.BlockSpec((Bp, 1, Hl), lambda h: (0, 0, h)),
        pl.BlockSpec((K, Hl), lambda h: (0, h)),
    ]
    operands = [ringT, xT, kT]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, Hl), lambda h: (0, h)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_decode_kernel, K=K, act=act),
        out_shape=(
            jax.ShapeDtypeStruct((Bp, 1, ringT.shape[2]), xT.dtype),
            jax.ShapeDtypeStruct(ringT.shape, ringT.dtype),
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((Bp, 1, Hl), lambda h: (0, 0, h)),
            pl.BlockSpec((Bp, Km1, Hl), lambda h: (0, 0, h)),
        ),
        interpret=interpret,
    )(*operands)


def dwconv_decode_chanblock(
    ringT: jnp.ndarray,
    xT: jnp.ndarray,
    kT: jnp.ndarray,
    *,
    K: int,
    block_c: int = 512,
    batch_chunk: int = 128,
    interpret: bool = True,
    bias=None,
    act: str = "none",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batch-chunked staging: grid (nB, nH), Bp-independent VMEM.

    Same operand layout as :func:`dwconv_decode_rows`; the slot pool must be
    padded to a multiple of ``batch_chunk`` rows (ops.py pads).
    """
    Bp, Km1, Hl, nH = _decode_geometry(ringT, xT, kT, K, block_c)
    Bc = min(batch_chunk, Bp)
    if Bp % Bc != 0:
        raise ValueError(
            f"slot pool Bp={Bp} is not divisible by batch_chunk={Bc}; ops.py "
            f"must pad the batch axis to the chunk")
    grid = (Bp // Bc, nH)
    in_specs = [
        pl.BlockSpec((Bc, Km1, Hl), lambda b, h: (b, 0, h)),
        pl.BlockSpec((Bc, 1, Hl), lambda b, h: (b, 0, h)),
        pl.BlockSpec((K, Hl), lambda b, h: (0, h)),
    ]
    operands = [ringT, xT, kT]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, Hl), lambda b, h: (0, h)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_decode_kernel, K=K, act=act),
        out_shape=(
            jax.ShapeDtypeStruct((Bp, 1, ringT.shape[2]), xT.dtype),
            jax.ShapeDtypeStruct(ringT.shape, ringT.dtype),
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((Bc, 1, Hl), lambda b, h: (b, 0, h)),
            pl.BlockSpec((Bc, Km1, Hl), lambda b, h: (b, 0, h)),
        ),
        interpret=interpret,
    )(*operands)
