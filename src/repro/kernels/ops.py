"""jit-ready wrappers around the Pallas depthwise-conv kernels.

These handle everything the kernels assume away: zero-padding to the
convolution window, rounding every tiled dimension up to TPU-friendly
multiples (lanes of 128, h-blocks, batch-chunks), variant dispatch, and
slicing the outputs back to logical shapes.  They are the only supported
entry points to ``dwconv_fwd.py`` / ``dwconv_bwdk.py``.

``interpret=None`` auto-selects: compiled on TPU, interpret mode elsewhere
(this container is CPU-only, so tests/benches run the kernel bodies in
interpret mode — the validation regime prescribed for this build).

The *fused backward* entry point ``dwconv_bwd_fused_op`` computes dx and dk
in one staged pass (``dwconv_bwd_fused.py``): every padded buffer here uses
the ``unified_wpad`` width, so the forward's ``xp`` doubles as the fused
VJP residual with no re-pad in backward.

``variant="auto"`` (or ``opts=None`` with it) consults the persistent tuning
cache written by ``repro.tuning`` (keyed on execution path + static shape +
padding + dtype + backend) and dispatches the cached winner — implementation variant
*and* tiling — falling back to the historical defaults (``row`` / ``accum``
with ``DEFAULT_OPTS``) when no entry exists.  Resolution happens at trace
time from static shapes, so jitted callers pay a dict lookup once per
compilation, never per step.

Every Pallas dispatch below runs through ``repro.resilience.guard`` — a
lowering/compile/resource failure degrades (at trace time) down the chain
chosen variant -> conservative default -> XLA reference, quarantining the
tuning-cache entry that picked the broken configuration.  With no failure
the guard is one ``try`` frame per compilation and the dispatched
computation is bit-identical to unguarded dispatch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dwconv_bwd_fused, dwconv_bwdk, dwconv_decode, dwconv_fwd, ref
from repro.kernels.common import (
    LANE,
    DWConvDims,
    Padding,
    adjoint_pad_widths,
    cdiv,
    pad_widths,
    round_up,
)
from repro.kernels.epilogue import act_grad, epilogue_key, is_trivial

# Tile geometry is shared with the declarative performance model
# (``repro.perfmodel``): runtime padding/tiling here and the analytical
# schedules there read the *same* functions, so they cannot drift.  The
# names are re-exported because this module is their historical home.
from repro.perfmodel.geometry import (  # noqa: F401  (re-exports)
    bwd_fused_wpad,
    bwdk_time_tile,
    decode_lane_tile,
    epilogue_time_tile,
    unified_wpad,
)
from repro.resilience import faults
from repro.resilience import guard as _guard

FWD_VARIANTS = ("naive", "lane", "block", "row", "xla")
BWDK_VARIANTS = ("naive", "twostage", "accum", "xla")
# Fused backward family ("split" = run the two independent backward ops —
# the escape hatch preserving the paper's controlled per-path study).
BWD_FUSED_VARIANTS = ("fused", "fused_partials", "split")
# Streaming-decode family (single-step ring-buffer conv, kernels/dwconv_decode.py).
DECODE_VARIANTS = ("rows", "chanblock", "xla")

# Pre-autotuner hard-coded choices, kept as the no-cache-entry fallback.
# The backward stays "split" until a tuning run selects the fused kernel,
# so untuned shapes keep the historical per-path behaviour.
AUTO_FALLBACK = {"fwd": "row", "bwd_in": "row", "bwd_k": "accum",
                 "bwd_fused": "split", "decode": "rows"}


@dataclasses.dataclass(frozen=True)
class KernelOptions:
    """Static tiling knobs (hashable: used as a custom_vjp nondiff arg)."""

    block_h: int = 8
    block_t: int = 512
    batch_chunk: int = 128
    interpret: Optional[bool] = None

    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"


DEFAULT_OPTS = KernelOptions()


def resolve_variant(
    path: str,
    variant: str,
    opts: Optional[KernelOptions],
    *,
    B: int,
    H: int,
    L: int,
    K: int,
    dtype,
    padding: Padding = "same",
    epilogue: str = "none",
) -> Tuple[str, KernelOptions]:
    """Resolve ``variant="auto"`` / ``opts=None`` through the tuning cache.

    Explicit ``opts`` always wins over cached tiling (the caller asked for
    it); a cached entry decides the variant and, absent explicit opts, the
    tiling; with no cache entry the pre-autotuner defaults apply.
    ``epilogue`` is part of the cached identity on the ``fwd`` and
    ``bwd_fused`` paths: a fused bias+activation changes both the kernel
    body and the candidate ordering, so epilogue problems tune separately.
    """
    if variant != "auto":
        return variant, (opts if opts is not None else DEFAULT_OPTS)
    from repro.tuning import cache as _tuning_cache  # deferred: tuning imports ops
    from repro.tuning import space as _tuning_space

    entry = _tuning_cache.lookup(
        path=path, B=B, H=H, L=L, K=K,
        dtype=jnp.dtype(dtype).name, backend=jax.default_backend(),
        padding=padding, epilogue=epilogue,
    )
    if entry is None:
        return AUTO_FALLBACK[path], (opts if opts is not None else DEFAULT_OPTS)
    if opts is None:
        return entry.variant, entry.options()
    # The cache tuned (variant, tiling) together; pairing its variant with
    # caller tiling can violate that variant's kernel asserts (e.g. a 'lane'
    # winner with an unaligned explicit block_t).  Keep the caller's opts —
    # they asked for them — and drop to the always-safe fallback variant
    # whenever the combination is illegal.
    cand = _tuning_space.Candidate(
        path=path, variant=entry.variant,
        block_h=opts.block_h, block_t=opts.block_t, batch_chunk=opts.batch_chunk)
    if _tuning_space.is_legal(cand, DWConvDims(B=B, H=H, L=L, K=K, padding=padding))[0]:
        return entry.variant, opts
    return AUTO_FALLBACK[path], opts


def _pad_to(a: jnp.ndarray, n: int, axis: int) -> jnp.ndarray:
    """Zero-pad one axis up to an exact length (no-op when already there)."""
    if a.shape[axis] == n:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, n - a.shape[axis])
    return jnp.pad(a, widths)


def _pad_channels(a: jnp.ndarray, H: int, Hb: int, axis: int) -> jnp.ndarray:
    return _pad_to(a, round_up(H, Hb), axis)


def _pad_kernel_lanes(k: jnp.ndarray, K: int) -> jnp.ndarray:
    Kp = round_up(K, LANE)
    return jnp.pad(k, ((0, 0), (0, Kp - K))) if Kp > K else k


def _prep_bias(bias: Optional[jnp.ndarray], Hp: int) -> Optional[jnp.ndarray]:
    """(H,) per-channel bias -> channel-padded (Hp, LANE) column block (value
    in column 0) — the layout the epilogue kernels bind per h-block."""
    if bias is None:
        return None
    if bias.ndim != 1:
        raise ValueError(f"epilogue bias must be per-channel (H,), got {bias.shape}")
    return jnp.pad(bias[:, None], ((0, Hp - bias.shape[0]), (0, LANE - 1)))


def _poison(y: jnp.ndarray) -> jnp.ndarray:
    """``kernel/nan`` fault site: bake NaN into the traced output (a silent
    numerical corruption the degradation chain *cannot* see — only the
    train-loop :class:`~repro.resilience.guard.NumericsGuard` catches it)."""
    if faults.should_fire("kernel/nan"):
        return jnp.full_like(y, jnp.nan)
    return y


def _residual_input(x: Optional[jnp.ndarray], xp: Optional[jnp.ndarray],
                    B: int, H: int, L: int, K: int,
                    padding: Padding) -> jnp.ndarray:
    """The raw input for the split backward: ``x`` when the caller still has
    it, otherwise sliced back out of the forward's unified-``Wpad`` residual
    — the guard can land on the split path mid-VJP, where only ``xp``
    survived as the saved residual."""
    if x is not None:
        return x
    if xp is None:
        raise ValueError(
            "bwd_fused variant 'split' needs the unpadded input x "
            "or the padded residual xp")
    p_left, _ = pad_widths(K, padding)
    return xp[:B, :H, p_left:p_left + L]


def _fwd_impl(
    x: jnp.ndarray,
    k: jnp.ndarray,
    p_left: int,
    variant: str,
    opts: KernelOptions,
    return_padded: bool = False,
    bias: Optional[jnp.ndarray] = None,
    act: str = "none",
):
    B, H, L = x.shape
    _, K = k.shape
    faults.fire("kernel/lower", faults.KernelLoweringError,
                f"injected lowering failure (fwd-family/{variant})")
    interpret = opts.resolved_interpret()
    Hb = min(opts.block_h, H)
    Lout = round_up(L, LANE)
    Lt = min(opts.block_t, Lout)
    Wpad = unified_wpad(L, K, opts.block_t)
    xp = jnp.pad(x, ((0, 0), (0, 0), (p_left, Wpad - L - p_left)))
    xp = _pad_channels(xp, H, Hb, axis=1)
    kp = _pad_channels(_pad_kernel_lanes(k, K), H, Hb, axis=0)
    bp = _prep_bias(bias, kp.shape[0])

    kw = dict(K=K, Lout=Lout, block_h=Hb, interpret=interpret, bias=bp, act=act)
    if variant == "row":
        y = dwconv_fwd.dwconv_fwd_row(xp, kp, **kw)
    elif variant == "block":
        y = dwconv_fwd.dwconv_fwd_block(xp, kp, block_t=Lt, **kw)
    elif variant == "naive":
        y = dwconv_fwd.dwconv_fwd_naive(xp, kp, block_t=Lt, **kw)
    elif variant == "lane":
        y = dwconv_fwd.dwconv_fwd_lane(xp, kp, block_t=Lt, **kw)
    else:
        raise ValueError(f"unknown fwd variant {variant!r}")
    y = y[:, :H, :L]
    return (y, xp) if return_padded else y


def dwconv_fwd_op(
    x: jnp.ndarray,
    k: jnp.ndarray,
    padding: Padding = "same",
    variant: str = "row",
    opts: Optional[KernelOptions] = None,
    *,
    bias: Optional[jnp.ndarray] = None,
    act: str = "none",
) -> jnp.ndarray:
    """y[b,h,t] = act(sum_j x_pad[b,h,t+j] k[h,j] + bias[h]).  The epilogue
    (``bias``/``act``) is applied in-register on the f32 accumulator before
    the single cast + write; with the default trivial epilogue this is
    bit-identical to the pre-epilogue kernels.  ``variant="auto"``
    dispatches the tuned (variant, tiling) for this (shape, epilogue);
    ``"xla"`` runs the reference."""
    B, H, L = x.shape
    K = k.shape[-1]
    requested = variant
    epi = epilogue_key(bias is not None, act)
    variant, opts = resolve_variant(
        "fwd", variant, opts, B=B, H=H, L=L, K=K, dtype=x.dtype,
        padding=padding, epilogue=epi)
    if variant == "xla":
        return _poison(ref.dwconv_act_ref(x, k, bias=bias, act=act,
                                          padding=padding))
    p_left, _ = pad_widths(K, padding)
    return _poison(_guard.run_guarded(
        "fwd", shape=(B, H, L, K), dtype=jnp.dtype(x.dtype).name,
        padding=padding, epilogue=epi, requested=requested,
        attempts=[(variant, opts), (AUTO_FALLBACK["fwd"], DEFAULT_OPTS)],
        run=lambda v, o: _fwd_impl(x, k, p_left, v, o, bias=bias, act=act),
        run_reference=lambda: ref.dwconv_act_ref(x, k, bias=bias, act=act,
                                                 padding=padding)))


def dwconv_fwd_op_res(
    x: jnp.ndarray,
    k: jnp.ndarray,
    padding: Padding = "same",
    variant: str = "row",
    opts: Optional[KernelOptions] = None,
    *,
    bias: Optional[jnp.ndarray] = None,
    act: str = "none",
):
    """Forward pass that also returns the unified-``Wpad`` padded input as
    the fused-backward VJP residual (``None`` when the reference path runs —
    there is no materialized padded buffer to reuse).  Note the residual is
    the *padded input*, never the pre-activation: the epilogue backward
    recomputes the pre-activation from this same buffer in-register."""
    B, H, L = x.shape
    K = k.shape[-1]
    requested = variant
    epi = epilogue_key(bias is not None, act)
    variant, opts = resolve_variant(
        "fwd", variant, opts, B=B, H=H, L=L, K=K, dtype=x.dtype,
        padding=padding, epilogue=epi)
    if variant == "xla":
        return _poison(ref.dwconv_act_ref(x, k, bias=bias, act=act,
                                          padding=padding)), None
    p_left, _ = pad_widths(K, padding)
    y, xp = _guard.run_guarded(
        "fwd", shape=(B, H, L, K), dtype=jnp.dtype(x.dtype).name,
        padding=padding, epilogue=epi, requested=requested,
        attempts=[(variant, opts), (AUTO_FALLBACK["fwd"], DEFAULT_OPTS)],
        run=lambda v, o: _fwd_impl(x, k, p_left, v, o, return_padded=True,
                                   bias=bias, act=act),
        run_reference=lambda: (ref.dwconv_act_ref(x, k, bias=bias, act=act,
                                                  padding=padding), None))
    return _poison(y), xp


def dwconv_bwd_input_op(
    dy: jnp.ndarray,
    k: jnp.ndarray,
    padding: Padding = "same",
    variant: str = "row",
    opts: Optional[KernelOptions] = None,
) -> jnp.ndarray:
    """dx: flipped-filter correlation under adjoint padding (same kernels as
    the forward path — the structural symmetry the paper exploits)."""
    B, H, L = dy.shape
    K = k.shape[-1]
    requested = variant
    variant, opts = resolve_variant("bwd_in", variant, opts, B=B, H=H, L=L, K=K,
                                    dtype=dy.dtype, padding=padding)
    if variant == "xla":
        return ref.dwconv_bwd_input_ref(dy, k, padding)
    p_left, _ = adjoint_pad_widths(K, padding)
    return _guard.run_guarded(
        "bwd_in", shape=(B, H, L, K), dtype=jnp.dtype(dy.dtype).name,
        padding=padding, requested=requested,
        attempts=[(variant, opts), (AUTO_FALLBACK["bwd_in"], DEFAULT_OPTS)],
        run=lambda v, o: _fwd_impl(dy, k[:, ::-1], p_left, v, o),
        run_reference=lambda: ref.dwconv_bwd_input_ref(dy, k, padding))


def _bwdk_impl(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    K: int,
    padding: Padding,
    variant: str,
    opts: KernelOptions,
) -> jnp.ndarray:
    B, H, L = x.shape
    faults.fire("kernel/lower", faults.KernelLoweringError,
                f"injected lowering failure (bwd_k/{variant})")
    interpret = opts.resolved_interpret()
    Hb = min(opts.block_h, H)
    Bc = min(opts.batch_chunk, B)
    p_left, _ = pad_widths(K, padding)
    Lout = round_up(L, LANE)
    Lt = bwdk_time_tile(L, K, opts.block_t, variant)
    if Lt is not None:
        # Time-tiled layout: dy a whole number of tiles, x one extra tile so
        # the (current + right-neighbour) halo binding never reads past the
        # end.  Both extensions are zeros and contribute nothing to dk.
        nT = cdiv(Lout, Lt)
        Ldy = nT * Lt
        Wpad = (nT + 1) * Lt
    else:
        Ldy = Lout
        Wpad = round_up(Lout + K - 1, LANE)
    Bp = round_up(B, Bc)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0), (p_left, Wpad - L - p_left)))
    dyp = jnp.pad(dy, ((0, Bp - B), (0, 0), (0, Ldy - L)))
    xp = _pad_channels(xp, H, Hb, axis=1)
    dyp = _pad_channels(dyp, H, Hb, axis=1)

    kw = dict(K=K, block_h=Hb, batch_chunk=Bc, interpret=interpret)
    if variant == "accum":
        dk = dwconv_bwdk.dwconv_bwdk_accum(xp, dyp, block_t=Lt, **kw)
    elif variant == "twostage":
        dk = dwconv_bwdk.dwconv_bwdk_twostage(xp, dyp, block_t=Lt, **kw)
    elif variant == "naive":
        dk = dwconv_bwdk.dwconv_bwdk_naive(xp, dyp, **kw)
    else:
        raise ValueError(f"unknown bwdk variant {variant!r}")
    return dk[:H]


def dwconv_bwd_kernel_op(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    K: int,
    padding: Padding = "same",
    variant: str = "accum",
    opts: Optional[KernelOptions] = None,
) -> jnp.ndarray:
    """dk[h,j] = sum_{b,t} dy[b,h,t] x_pad[b,h,t+j].  Returns f32 (H, K)
    from *every* variant including the ``"xla"`` reference, so an ``auto``
    cache winner flipping variants never changes gradient dtype under bf16
    training; callers cast to the param dtype."""
    B, H, L = x.shape
    requested = variant
    variant, opts = resolve_variant("bwd_k", variant, opts, B=B, H=H, L=L, K=K,
                                    dtype=x.dtype, padding=padding)
    if variant == "xla":
        return ref.dwconv_bwd_kernel_ref(x, dy, K, padding)
    return _guard.run_guarded(
        "bwd_k", shape=(B, H, L, K), dtype=jnp.dtype(x.dtype).name,
        padding=padding, requested=requested,
        attempts=[(variant, opts), (AUTO_FALLBACK["bwd_k"], DEFAULT_OPTS)],
        run=lambda v, o: _bwdk_impl(x, dy, K, padding, v, o),
        run_reference=lambda: ref.dwconv_bwd_kernel_ref(x, dy, K, padding))


def _bwd_fused_impl(
    x: Optional[jnp.ndarray],
    dy: jnp.ndarray,
    k: jnp.ndarray,
    padding: Padding,
    variant: str,
    opts: KernelOptions,
    xp: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    act: str = "none",
):
    B, H, L = dy.shape
    K = k.shape[-1]
    faults.fire("kernel/lower", faults.KernelLoweringError,
                f"injected lowering failure (bwd_fused/{variant})")
    trivial = is_trivial(bias, act)
    interpret = opts.resolved_interpret()
    Hb = min(opts.block_h, H)
    Bc = min(opts.batch_chunk, B)
    p_left, p_right = pad_widths(K, padding)
    Lout = round_up(L, LANE)
    tile_fn = bwdk_time_tile if trivial else epilogue_time_tile
    Lt = tile_fn(L, K, opts.block_t, variant)
    Wk = bwd_fused_wpad(L, K)
    # Tiled regime: both operands live in the (nT + 1) * Lt tile layout (one
    # trailing all-zero tile feeds the right-neighbour halo binding).
    W = (cdiv(Lout, Lt) + 1) * Lt if Lt is not None else Wk
    Bp = round_up(B, Bc)
    if xp is None:
        xp = jnp.pad(x, ((0, Bp - B), (0, 0), (p_left, W - L - p_left)))
    else:
        # The forward's unified-Wpad residual: same left padding.  Untiled,
        # its width is a superset of Wk and the kernel BlockSpecs slice the
        # Wk window out of it, so reuse costs nothing.  Tiled, the residual
        # is grown (with zeros) or trimmed (of zeros) to the exact tile
        # layout — still no re-pad of the *content*.
        if xp.shape[-1] < Wk:
            raise ValueError(f"residual width {xp.shape[-1]} < fused window {Wk}")
        if Bp > B:
            xp = jnp.pad(xp, ((0, Bp - B), (0, 0), (0, 0)))
        if Lt is not None:
            if xp.shape[-1] < W:
                xp = jnp.pad(xp, ((0, 0), (0, 0), (0, W - xp.shape[-1])))
            elif xp.shape[-1] > W:
                xp = xp[:, :, :W]
    # One dy layout serves both gradients: adjoint left padding p_right for
    # the dx taps; the dk reduction reads at static offset off_dk=p_right.
    dyp = jnp.pad(dy, ((0, Bp - B), (0, 0), (p_right, W - L - p_right)))
    Hp = round_up(xp.shape[1], Hb)
    xp = _pad_to(xp, Hp, axis=1)
    dyp = _pad_to(dyp, Hp, axis=1)
    kp = _pad_to(_pad_kernel_lanes(k, K), Hp, axis=0)

    kw = dict(K=K, Lout=Lout, off_dk=p_right, block_w=Wk, block_t=Lt,
              block_h=Hb, batch_chunk=Bc, interpret=interpret)
    if trivial:
        if variant == "fused":
            dx, dk = dwconv_bwd_fused.dwconv_bwd_fused_accum(xp, dyp, kp, **kw)
        elif variant == "fused_partials":
            dx, dk = dwconv_bwd_fused.dwconv_bwd_fused_partials(xp, dyp, kp, **kw)
        else:
            raise ValueError(f"unknown bwd_fused variant {variant!r}")
        return dx[:B, :H, :L], dk[:H, :K]
    kw.update(bias=_prep_bias(bias, Hp), act=act)
    if variant == "fused":
        dx, dk, db = dwconv_bwd_fused.dwconv_bwd_fused_accum_act(xp, dyp, kp, **kw)
    elif variant == "fused_partials":
        dx, dk, db = dwconv_bwd_fused.dwconv_bwd_fused_partials_act(xp, dyp, kp, **kw)
    else:
        raise ValueError(f"unknown bwd_fused variant {variant!r}")
    dbias = db[:H, 0] if bias is not None else None
    return dx[:B, :H, :L], dk[:H, :K], dbias


def dwconv_bwd_fused_op(
    x: Optional[jnp.ndarray],
    dy: jnp.ndarray,
    k: jnp.ndarray,
    padding: Padding = "same",
    variant: str = "fused",
    opts: Optional[KernelOptions] = None,
    *,
    xp: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One staged pass -> (dx, dk): both operands cross HBM once, one padded
    layout each (vs two dy reads and three layouts on the split path).

    ``xp`` (the forward's unified-``Wpad`` padded residual) is reused
    verbatim when given; otherwise the raw ``x`` is padded here — still a
    single layout.  ``variant="auto"`` consults the ``bwd_fused`` tuning
    path; ``"split"`` (also the untuned fallback) delegates to the two
    independent backward ops, preserving the controlled per-path study.
    dk returns f32 (H, K); callers cast to the parameter dtype.
    """
    B, H, L = dy.shape
    K = k.shape[-1]
    caller_opts = opts
    requested = variant
    variant, opts = resolve_variant("bwd_fused", variant, opts, B=B, H=H, L=L,
                                    K=K, dtype=dy.dtype, padding=padding)

    def run_split():
        xs = _residual_input(x, xp, B, H, L, K, padding)
        dx = dwconv_bwd_input_op(dy, k, padding, "auto", caller_opts)
        dk = dwconv_bwd_kernel_op(xs, dy, K, padding, "auto", caller_opts)
        return dx, dk

    if variant == "split":
        return run_split()
    return _guard.run_guarded(
        "bwd_fused", shape=(B, H, L, K), dtype=jnp.dtype(dy.dtype).name,
        padding=padding, requested=requested,
        attempts=[(variant, opts)],
        run=lambda v, o: _bwd_fused_impl(x, dy, k, padding, v, o, xp=xp),
        run_reference=run_split, reference_name="split")


def dwconv_bwd_fused_act_op(
    x: Optional[jnp.ndarray],
    dy: jnp.ndarray,
    k: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    padding: Padding = "same",
    variant: str = "fused",
    opts: Optional[KernelOptions] = None,
    *,
    act: str = "none",
    xp: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[jnp.ndarray]]:
    """Epilogue-aware whole backward -> (dx, dk (H, K) f32, dbias (H,) f32
    or ``None`` when no bias participates).

    The fused kernels recompute the pre-activation ``conv(x_pad, k) + bias``
    from the staged slab (K extra in-register MACs per element), form
    ``dy_eff = dy * act'(pre)`` in f32, and drive the existing dx/dk
    reductions with it — no activation residual is ever stored and no
    standalone elementwise pass runs.  ``variant="split"`` (also the
    untuned-``auto`` fallback) is the escape hatch: it materializes
    ``dy_eff`` once via a pre-activation *recompute* pass and delegates to
    the two independent backward ops, so even the unfused structure never
    saves a residual.
    """
    B, H, L = dy.shape
    K = k.shape[-1]
    if is_trivial(bias, act):
        dx, dk = dwconv_bwd_fused_op(x, dy, k, padding, variant, opts, xp=xp)
        return dx, dk, None
    caller_opts = opts
    requested = variant
    epi = epilogue_key(bias is not None, act)
    variant, opts = resolve_variant("bwd_fused", variant, opts, B=B, H=H, L=L,
                                    K=K, dtype=dy.dtype, padding=padding,
                                    epilogue=epi)

    def run_split():
        # Activation-recompute split path: one standalone pre-activation
        # pass (conv + bias, no act), then the ordinary split backward on
        # the effective gradient.
        xs = _residual_input(x, xp, B, H, L, K, padding)
        pre = dwconv_fwd_op(xs, k, padding, "auto", caller_opts, bias=bias)
        dy_eff32 = dy.astype(jnp.float32) * act_grad(pre.astype(jnp.float32), act)
        dy_eff = dy_eff32.astype(dy.dtype)
        dx = dwconv_bwd_input_op(dy_eff, k, padding, "auto", caller_opts)
        dk = dwconv_bwd_kernel_op(xs, dy_eff, K, padding, "auto", caller_opts)
        dbias = jnp.sum(dy_eff32, axis=(0, 2)) if bias is not None else None
        return dx, dk, dbias

    if variant == "split":
        return run_split()
    return _guard.run_guarded(
        "bwd_fused", shape=(B, H, L, K), dtype=jnp.dtype(dy.dtype).name,
        padding=padding, epilogue=epi, requested=requested,
        attempts=[(variant, opts)],
        run=lambda v, o: _bwd_fused_impl(x, dy, k, padding, v, o, xp=xp,
                                         bias=bias, act=act),
        run_reference=run_split, reference_name="split")


# ---------------------------------------------------------------------------
# streaming decode (single-step ring-buffer conv, kernels/dwconv_decode.py)
# ---------------------------------------------------------------------------


def _prep_decode_bias(bias: Optional[jnp.ndarray], Hp: int) -> Optional[jnp.ndarray]:
    """(H,) per-channel bias -> channel-padded (1, Hp) row — the decode
    kernels keep channels on the lane axis, so the bias block is a row, not
    the fwd family's (Hp, LANE) column."""
    if bias is None:
        return None
    if bias.ndim != 1:
        raise ValueError(f"epilogue bias must be per-channel (H,), got {bias.shape}")
    return jnp.pad(bias[None, :], ((0, 0), (0, Hp - bias.shape[0])))


def _decode_impl(
    ring: jnp.ndarray,
    x: jnp.ndarray,
    k: jnp.ndarray,
    variant: str,
    opts: KernelOptions,
    bias: Optional[jnp.ndarray] = None,
    act: str = "none",
):
    B, H, _ = ring.shape
    K = k.shape[-1]
    faults.fire("kernel/lower", faults.KernelLoweringError,
                f"injected lowering failure (decode/{variant})")
    interpret = opts.resolved_interpret()
    # Channels ride the lane axis at L=1: transpose to channel-last and pad
    # the channel axis to the lane tile (block_t reused as the channel tile
    # — same geometry the decode schedules model, perfmodel.geometry).
    Hl = decode_lane_tile(H, opts.block_t)
    Hp = round_up(H, Hl)
    Bc = min(opts.batch_chunk, B)
    Bp = round_up(B, Bc)
    ringT = _pad_to(_pad_to(ring.transpose(0, 2, 1), Bp, axis=0), Hp, axis=2)
    xT = _pad_to(_pad_to(x[:, None, :], Bp, axis=0), Hp, axis=2)
    kT = _pad_to(k.T, Hp, axis=1)
    bT = _prep_decode_bias(bias, Hp)

    kw = dict(K=K, block_c=Hl, interpret=interpret, bias=bT, act=act)
    if variant == "rows":
        yT, nrT = dwconv_decode.dwconv_decode_rows(ringT, xT, kT, **kw)
    elif variant == "chanblock":
        yT, nrT = dwconv_decode.dwconv_decode_chanblock(ringT, xT, kT,
                                                        batch_chunk=Bc, **kw)
    else:
        raise ValueError(f"unknown decode variant {variant!r}")
    y = yT[:B, 0, :H]
    new_ring = nrT[:B, :, :H].transpose(0, 2, 1)
    return y, new_ring


def dwconv_decode_op(
    ring: jnp.ndarray,
    x: jnp.ndarray,
    k: jnp.ndarray,
    variant: str = "auto",
    opts: Optional[KernelOptions] = None,
    *,
    bias: Optional[jnp.ndarray] = None,
    act: str = "none",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused single-step streaming-decode conv: ring shift + K-tap dot +
    bias/act epilogue in one launch.

      ring : (B, H, K-1) — last K-1 pre-conv inputs, oldest tap first
      x    : (B, H)      — the new step's input
      k    : (H, K)
      -> (y (B, H), new_ring (B, H, K-1))

    Per-step traffic is O(B*H*K) bytes vs O(B*H*L) for re-running the full
    conv over the cache.  All variants share the f32 ascending-tap
    accumulation order of the full-sequence reference: N successive
    ``"xla"`` steps from a zero ring are bit-identical to one causal
    ``dwconv_act`` over the stream for f32 ``act="none"``, and the Pallas
    variants match to FMA-contraction rounding (like the rest of the
    family vs ``ref.py``) while being bit-identical to each other.
    ``variant="auto"`` dispatches the tuned decode winner; ``"xla"`` (and
    any K<2 problem, whose ring is empty) runs the reference.
    """
    B, H = x.shape
    K = k.shape[-1]
    if ring.shape != (B, H, K - 1):
        raise ValueError(
            f"ring shape {ring.shape} does not match (B={B}, H={H}, "
            f"K-1={K - 1}); the ring must hold exactly the last K-1 inputs")
    requested = variant
    epi = epilogue_key(bias is not None, act)
    variant, opts = resolve_variant(
        "decode", variant, opts, B=B, H=H, L=1, K=K, dtype=x.dtype,
        padding="causal", epilogue=epi)
    if variant == "xla" or K < 2:
        y, new_ring = ref.dwconv_decode_ref(ring, x, k, bias=bias, act=act)
        return _poison(y), new_ring
    y, new_ring = _guard.run_guarded(
        "decode", shape=(B, H, 1, K), dtype=jnp.dtype(x.dtype).name,
        padding="causal", epilogue=epi, requested=requested,
        attempts=[(variant, opts), (AUTO_FALLBACK["decode"], DEFAULT_OPTS)],
        run=lambda v, o: _decode_impl(ring, x, k, v, o, bias=bias, act=act),
        run_reference=lambda: ref.dwconv_decode_ref(ring, x, k, bias=bias,
                                                    act=act))
    return _poison(y), new_ring


def dwconv_decode_ragged_op(
    ring: jnp.ndarray,
    x: jnp.ndarray,
    k: jnp.ndarray,
    active: jnp.ndarray,
    variant: str = "auto",
    opts: Optional[KernelOptions] = None,
    *,
    bias: Optional[jnp.ndarray] = None,
    act: str = "none",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Continuous-batching form: one dense step over the whole slot pool with
    a ragged active set.  ``active`` is a (B,) bool mask of live slots;
    inactive slots emit y=0 and keep their ring **unchanged** (the state in
    a free/evicted slot must not shift under other requests' steps).  The
    kernel runs the dense pool — the honest per-step traffic is the full
    O(B*H*K) pool, which is exactly what the decode schedules charge."""
    y, new_ring = dwconv_decode_op(ring, x, k, variant, opts, bias=bias, act=act)
    live = active.astype(bool)
    y = jnp.where(live[:, None], y, jnp.zeros_like(y))
    new_ring = jnp.where(live[:, None, None], new_ring, ring)
    return y, new_ring


@functools.partial(jax.jit, static_argnames=("padding", "variant", "opts"))
def dwconv_fwd_jit(x, k, padding="same", variant="row", opts=None):
    return dwconv_fwd_op(x, k, padding, variant, opts)


@functools.partial(jax.jit, static_argnames=("padding", "variant", "opts"))
def dwconv_bwd_input_jit(dy, k, padding="same", variant="row", opts=None):
    return dwconv_bwd_input_op(dy, k, padding, variant, opts)


@functools.partial(jax.jit, static_argnames=("K", "padding", "variant", "opts"))
def dwconv_bwd_kernel_jit(x, dy, K, padding="same", variant="accum", opts=None):
    return dwconv_bwd_kernel_op(x, dy, K, padding, variant, opts)


@functools.partial(jax.jit, static_argnames=("variant", "opts", "act"))
def dwconv_decode_jit(ring, x, k, variant="auto", opts=None, *, bias=None, act="none"):
    return dwconv_decode_op(ring, x, k, variant, opts, bias=bias, act=act)
