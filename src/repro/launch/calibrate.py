"""Hardware-calibration CLI: measure this runner's achievable roofs.

  PYTHONPATH=src python -m repro.launch.calibrate
  PYTHONPATH=src python -m repro.launch.calibrate --fast \\
      --out results/calibration/ci-calibration.json

Runs the counter-free microbenchmark suite (HBM copy/triad sweep, f32
matmul sweep, dispatch-overhead floor), fits the achievable-roof overlay
(``repro.obs.calibrate``), persists it keyed by the device fingerprint,
and prints the calibrated-vs-datasheet summary.  ``launch/report.py``
consumes the persisted JSON to put calibrated denominators under its
effective-bandwidth rows.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.hw import HARDWARE, TPU_V5E
from repro.obs.calibrate import (
    default_calibration_path,
    run_calibration,
    save_calibration,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--hw", default=TPU_V5E.name, choices=sorted(HARDWARE),
                    help="datasheet base model the overlay applies to")
    ap.add_argument("--fast", action="store_true",
                    help="smaller size ladders + fewer iterations (CI)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations per microbenchmark point")
    ap.add_argument("--out", default="",
                    help="output JSON (default: the device-fingerprint path)")
    args = ap.parse_args(argv)

    base = HARDWARE[args.hw]
    cal = run_calibration(base=base, fast=args.fast, iters=args.iters)
    path = save_calibration(cal, args.out or None)

    def pct(measured: float, peak: float) -> str:
        return f"{100.0 * measured / peak:.1f}% of datasheet" if peak else "n/a"

    print(f"[calibrate] device      : {cal.fingerprint}")
    print(f"[calibrate] base model  : {base.name}")
    print(f"[calibrate] triad BW    : {cal.hbm_bw / 1e9:.2f} GB/s "
          f"({pct(cal.hbm_bw, base.hbm_bw)}, fit r2={cal.bw_r2:.3f}, "
          f"launch overhead {cal.bw_overhead_s * 1e6:.1f}us)")
    print(f"[calibrate] copy BW     : {cal.copy_bw / 1e9:.2f} GB/s")
    print(f"[calibrate] f32 FLOP/s  : {cal.flops_f32 / 1e9:.2f} GFLOP/s "
          f"({pct(cal.flops_f32, base.peak_flops_f32)}, r2={cal.flops_r2:.3f})")
    print(f"[calibrate] dispatch    : {cal.dispatch_overhead_s * 1e6:.2f} us/call")
    print(f"[calibrate] wrote {path}")
    if cal.hbm_bw > base.hbm_bw or cal.flops_f32 > base.peak_flops_f32:
        # Measuring above the datasheet roof means the benchmark hit a cache
        # (sizes too small for this memory system) — say so rather than
        # silently persisting an impossible roof.
        print("[calibrate] warning: measured rate exceeds the datasheet peak; "
              "sweep sizes are likely cache-resident for this device",
              file=sys.stderr)
    if default_calibration_path(cal.fingerprint) != path:
        print(f"[calibrate] note: report auto-load looks at "
              f"{default_calibration_path(cal.fingerprint)}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
