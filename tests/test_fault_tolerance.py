"""Fault-tolerance tests: atomic checkpoints, async save, resume equality,
elastic re-mesh on load, supervisor crash-restart, straggler detection."""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, _flatten, _unflatten_into
from repro.launch.mesh import make_mesh
from repro.launch.supervisor import Heartbeat, Supervisor, SupervisorConfig, detect_stragglers

REPO = Path(__file__).resolve().parent.parent


def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3), "b": [jnp.ones((4,)), jnp.zeros((2, 2))],
            "c": {"d": jnp.asarray(3)}}


def test_flatten_roundtrip():
    t = _tree()
    flat = _flatten(jax.device_get(t))
    back = _unflatten_into(t, flat)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_save_restore_keepn(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for step in (1, 2, 3):
        mgr.save(step, params=jax.tree.map(lambda x: x * step, t),
                 data_state={"step": step * 10})
    assert mgr.all_steps() == [2, 3]  # keep-2 GC
    step, params, _, extra = mgr.restore(params_template=t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(params["a"]), np.arange(6.0).reshape(2, 3) * 3)
    assert extra["data_state"]["step"] == 30


def test_checkpoint_async_and_atomicity(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    t = _tree()
    mgr.save_async(5, params=t)
    mgr.wait()
    assert mgr.latest_step() == 5
    # no tmp dirs left behind
    assert not list(Path(tmp_path).glob("*.tmp"))


def test_checkpoint_elastic_remesh(tmp_path):
    """Save under one sharding, restore with explicit shardings for the
    current device set (mesh-independence)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_mesh((1,), ("data",))
    mgr = CheckpointManager(tmp_path)
    t = {"w": jnp.arange(8.0)}
    mgr.save(1, params=t)
    sh = {"w": NamedSharding(mesh, P("data"))}
    step, params, _, _ = mgr.restore(params_template=t, shardings=sh)
    assert params["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(params["w"]), np.arange(8.0))


def test_train_cli_crash_restart_resume(tmp_path):
    """End-to-end: crash at step 7 (simulated node failure), supervisor
    restarts, run resumes from the checkpoint and completes."""
    ckpt = tmp_path / "ckpt"
    hb = tmp_path / "hb.json"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-135m", "--smoke", "--steps", "10", "--batch", "4",
        "--seq", "16", "--ckpt-dir", str(ckpt), "--ckpt-every", "3",
        "--heartbeat", str(hb), "--log-every", "0",
    ]
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    # first attempt crashes at step 7 (after a step-6 checkpoint)
    rc = subprocess.run(cmd + ["--fail-at-step", "7"], env=env,
                        capture_output=True, text=True).returncode
    assert rc == 17
    assert CheckpointManager(ckpt).latest_step() == 6
    sup = Supervisor(SupervisorConfig(cmd=cmd, heartbeat_path=str(hb),
                                      max_restarts=2, backoff_s=0.1))
    rc = sup.run(extra_env={"PYTHONPATH": str(REPO / "src")})
    assert rc == 0
    assert CheckpointManager(ckpt).latest_step() == 10


def test_heartbeat_and_stragglers(tmp_path):
    hb = Heartbeat(tmp_path / "beat.json")
    hb.beat(0)
    time.sleep(0.02)
    hb.beat(1)
    d = Heartbeat.read(tmp_path / "beat.json")
    assert d["step"] == 1 and d["ewma_s"] >= 0
    beats = [{"ewma_s": 1.0}, {"ewma_s": 1.1}, {"ewma_s": 5.0}, {"ewma_s": 0.9}]
    assert detect_stragglers(beats, factor=2.0) == [2]
    assert detect_stragglers([{"ewma_s": 0.0}]) == []
