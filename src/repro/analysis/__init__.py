from repro.analysis.hw import HARDWARE, P100, TPU_V5E, HardwareModel  # noqa: F401
from repro.analysis.hlo import HLOAnalysis, analyze_hlo, shape_bytes  # noqa: F401
from repro.analysis.roofline import (  # noqa: F401
    RooflineReport,
    dense_model_flops,
    forward_model_flops,
    roofline_from_compiled,
)
from repro.analysis.traffic import (  # noqa: F401
    TrafficEstimate,
    bwd_fused_traffic,
    bwd_split_traffic,
    bwdk_traffic,
    fwd_traffic,
    path_flops,
    variant_traffic_table,
)
from repro.analysis.bandwidth import BandwidthEstimate, effective_bandwidth  # noqa: F401
from repro.analysis.timer import Timing, time_fn  # noqa: F401
