"""Roofline table generator (deliverable g): reads the dry-run records and
emits the per-(arch x shape x mesh) three-term table as markdown + CSV rows
for EXPERIMENTS.md §Roofline — plus the kernel-level roofline rows derived
from the registered kernel schedules (``repro.perfmodel``), so this module
and ``repro.launch.report`` place the conv kernels from one computation.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import List

RESULTS = Path(os.environ.get("REPRO_RESULTS_DIR", "results/dryrun"))


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    if x >= 1e-6:
        return f"{x * 1e6:.1f}us"
    return f"{x * 1e9:.1f}ns"


def load_records(mesh: str = "pod1x16x16"):
    recs = []
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def markdown_table(mesh: str = "pod1x16x16") -> str:
    recs = load_records(mesh)
    lines = [
        "| arch | shape | compute | memory | collective | dominant | bound step | "
        "MODEL/HLO | roofline frac | mem/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | {r['dominant']} | "
            f"{fmt_s(r['step_time_overlap_s'])} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['bytes_per_device_estimate'] / 2**30:.2f}GiB | "
            f"{'Y' if r['fits_16gb'] else 'N'} |"
        )
    return "\n".join(lines)


def kernel_rows() -> List[Row]:
    """Schedule-derived roofline placement of the conv kernels at the paper
    shape (the same derivation ``repro.launch.report`` renders)."""
    from repro.analysis.hw import TPU_V5E
    from repro.analysis.paper_data import PAPER_DIMS
    from repro.analysis.report import counter_free_report

    payload = counter_free_report(PAPER_DIMS, hw=TPU_V5E,
                                  include_paper=False, include_epilogue=False)
    rows: List[Row] = []
    for r in payload["roofline"]:
        ai = "N/A" if r["arithmetic_intensity"] is None \
            else f"{r['arithmetic_intensity']:.2f}"
        bw = "N/A" if r["effective_bandwidth"] is None \
            else f"{r['effective_bandwidth'] / 1e9:.1f}GB/s"
        rows.append(Row(
            f"roofline_table/kernel/{r['study']}/{r['path']}",
            r["runtime_s"] * 1e6,
            f"AI={ai}FLOP/B regime={r['regime'] or 'N/A'} eff_bw={bw} "
            f"bytes={r['bytes_moved'] / 1e9:.3f}GB (schedule-derived)",
        ))
    return rows


def run(fast: bool = False) -> List[Row]:
    rows: List[Row] = kernel_rows()
    if not RESULTS.exists():
        return rows + [Row("roofline_table/missing", 0.0,
                           "run repro.launch.dryrun first")]
    for mesh in ("pod1x16x16", "pod2x16x16"):
        for r in load_records(mesh):
            rows.append(Row(
                f"roofline/{r['arch']}/{r['shape']}/{mesh}",
                r["step_time_overlap_s"] * 1e6,
                f"dominant={r['dominant']} compute={fmt_s(r['compute_s'])} "
                f"memory={fmt_s(r['memory_s'])} collective={fmt_s(r['collective_s'])} "
                f"useful={r['useful_flops_ratio']:.3f} frac={r['roofline_fraction']:.3f} "
                f"fits16GiB={'Y' if r['fits_16gb'] else 'N'}",
            ))
    return rows


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod1x16x16"
    print(markdown_table(mesh))
