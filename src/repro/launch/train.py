"""Production training launcher.

Composes: arch config -> mesh -> sharded train_step (microbatched,
optionally compressed gradients) -> synthetic LM pipeline -> heartbeat ->
atomic/async checkpoints -> auto-resume.  Runs identically on 1 CPU device
(smoke configs) and on a real pod slice; the elastic supervisor
(``repro.launch.supervisor``) wraps this process on a cluster.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \\
      --steps 20 --batch 8 --seq 32 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.resilience import NonFiniteOutputError, NumericsGuard
from repro.resilience import guard as resilience_guard
from repro.configs.registry import get_config, list_archs
from repro.data.lm import LMStreamConfig, LMTokenStream
from repro.distributed import sharding as shd
from repro.obs import trace as obs_trace
from repro.distributed.stepfn import (
    batch_shardings,
    build_train_step,
    opt_state_shardings,
    params_shardings,
)
from repro.launch.mesh import make_mesh
from repro.launch.supervisor import Heartbeat
from repro.models.api import batch_axes, get_model
from repro.models.config import ShapeCell
from repro.train.optim import adamw, sgd_momentum


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd_momentum"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-dtype", default=None, choices=[None, "bfloat16", "float32"])
    ap.add_argument("--mesh", default="", help="e.g. '2,4' => (data,model); default all devices on data")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--heartbeat", default="")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="write a span trace (JSONL) here: per-step data/step/"
                         "checkpoint spans, with schedule-derived modeled "
                         "bytes attached to the paper-operator kernels")
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="test hook: crash the process at this step")
    ap.add_argument("--conv-variant", default="",
                    help="override the config's depthwise-conv kernel variant "
                         "(e.g. 'row', 'auto', 'xla') on every SSM/RG-LRU "
                         "block — the chaos CI uses this to drive the "
                         "Pallas/auto dispatch paths from smoke configs")
    ap.add_argument("--guard", action="store_true",
                    help="per-step finite check on loss/grad_norm: a "
                         "nonfinite step skips the update (previous params "
                         "kept); after --guard-max-skips consecutive skips "
                         "the process exits 21 for the supervisor")
    ap.add_argument("--guard-max-skips", type=int, default=3,
                    help="consecutive nonfinite steps tolerated under "
                         "--guard before aborting (default 3)")
    return ap.parse_args(argv)


# Exit code for a numerics abort under --guard: distinct from a crash so the
# supervisor's report (and the chaos CI) can tell "diverged, aborted
# gracefully" from "blew up with a traceback".
GUARD_ABORT_EXIT = 21


def _override_conv_variant(cfg, variant: str):
    """Rebuild ``cfg`` with every depthwise-conv study axis forced to
    ``variant`` (SSM and RG-LRU blocks; other families carry no conv)."""
    if cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, conv_variant=variant))
    if cfg.rglru is not None:
        cfg = dataclasses.replace(
            cfg, rglru=dataclasses.replace(cfg.rglru, conv_variant=variant))
    return cfg


def _finish_trace(tracer, args) -> None:
    """Close the trace and surface what degraded (normal exit and guard
    abort share this — an aborted run's trace must still be complete)."""
    events = resilience_guard.degradation_events()
    if events:
        by_site = {}
        for e in events:
            by_site[e["site"]] = by_site.get(e["site"], 0) + 1
        summary = ", ".join(f"{s}: {n}" for s, n in sorted(by_site.items()))
        print(f"[train] degradations absorbed: {summary}", flush=True)
    if args.trace:
        tracer.close()
        print(f"[train] trace written to {args.trace} "
              f"({len(tracer.records)} records)", flush=True)


def main(argv=None) -> int:
    args = parse_args(argv)
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.conv_variant:
        cfg = _override_conv_variant(cfg, args.conv_variant)
    model_axes = None
    nguard = NumericsGuard(args.guard_max_skips) if args.guard else None

    tracer = (obs_trace.configure(args.trace, meta={"launcher": "train",
                                                    "arch": cfg.name})
              if args.trace else obs_trace.get_tracer())
    step_attachments = ()
    attach_hw = None
    if tracer.enabled:
        # Paper-operator kernels this arch runs per step: each step span
        # carries their schedule-derived modeled bytes, so the trace reports
        # per-span effective bandwidth with no counters.  Roofs come from
        # this runner's calibration when one exists.
        from repro.analysis.hw import TPU_V5E
        from repro.obs.calibrate import load_for_device

        cal = load_for_device()
        attach_hw = cal.hardware_model(TPU_V5E) if cal is not None else TPU_V5E
        step_attachments = tuple(obs_trace.dwconv_step_schedules(
            cfg, args.batch, args.seq))

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        axes = ("data", "model")[: len(shape)] if len(shape) == 2 else ("pod", "data", "model")
        mesh = make_mesh(shape, axes)
    else:
        mesh = make_mesh((jax.device_count(), 1), ("data", "model"))

    from repro.models.api import get_model

    model = get_model(cfg)
    opt = (adamw(lr=args.lr) if args.optimizer == "adamw"
           else sgd_momentum(lr=args.lr))
    step_fn = build_train_step(model, opt, microbatches=args.microbatches,
                               grad_dtype=args.grad_dtype)

    rules = "train"
    hb = Heartbeat(args.heartbeat) if args.heartbeat else None
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    stream = LMTokenStream(LMStreamConfig(
        vocab=cfg.vocab, batch_size=args.batch, seq_len=args.seq, seed=args.seed))

    with mesh, shd.use_sharding(mesh, rules):
        p_shard = params_shardings(model, mesh, rules)
        o_shard = opt_state_shardings(model, opt, mesh, rules)
        cell = ShapeCell("cli", args.seq, args.batch, "train")
        start_step = 0
        params = opt_state = None
        if mgr is not None and mgr.latest_step() is not None:
            tmpl_p = jax.tree.map(np.zeros_like, jax.tree.map(
                lambda s: np.zeros(s.shape, s.dtype), model.init_shapes()))
            tmpl_o = jax.eval_shape(opt.init, model.init_shapes())
            tmpl_o = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), tmpl_o)
            start_step, params, opt_state, extra = mgr.restore(
                params_template=tmpl_p, opt_state_template=tmpl_o,
                shardings=p_shard, opt_shardings=o_shard)
            if "data_state" in extra:
                stream.load_state_dict(extra["data_state"])
            print(f"[train] resumed from step {start_step}", flush=True)
        if params is None:
            params = jax.jit(model.init, out_shardings=p_shard)(
                jax.random.PRNGKey(args.seed))
            opt_state = jax.jit(opt.init, out_shardings=o_shard)(params)

        ba = {"tokens": ("act_batch", None), "labels": ("act_batch", None)}
        # Under --guard a skipped step must keep the *previous* params, so
        # the inputs cannot be donated to the step function.
        jit_step = (jax.jit(step_fn) if nguard is not None
                    else jax.jit(step_fn, donate_argnums=(0, 1)))

        losses = []
        # Progress logging, not a benchmark: float(metrics["loss"]) below
        # synchronizes every step before the elapsed time is printed.
        t0 = time.perf_counter()  # repro: noqa(REP002)
        for step in range(start_step, args.steps):
            if args.fail_at_step == step:
                print(f"[train] simulated failure at step {step}", flush=True)
                sys.exit(17)
            with tracer.span("train/data", step=step):
                batch_np = stream.next_batch()
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            with tracer.span("train/step", step=step) as sp:
                new_params, new_opt_state, metrics = jit_step(params, opt_state, batch)
                sp.sync(metrics)
                for kname, sched, count in step_attachments:
                    sp.attach(kname, sched, hw=attach_hw, count=count)
            if nguard is not None:
                try:
                    ok = nguard.check(step, loss=metrics["loss"],
                                      grad_norm=metrics["grad_norm"])
                except NonFiniteOutputError as e:
                    print(f"[train] numerics guard abort: {e}", flush=True)
                    if mgr is not None:
                        try:
                            mgr.wait()  # don't orphan an in-flight checkpoint
                        except Exception as ce:
                            print(f"[train] in-flight checkpoint failed during "
                                  f"abort: {ce}", flush=True)
                    _finish_trace(tracer, args)
                    return GUARD_ABORT_EXIT
                if ok:
                    params, opt_state = new_params, new_opt_state
                else:
                    print(f"[train] step={step} skipped (nonfinite metrics; "
                          f"{nguard.consecutive} consecutive)", flush=True)
            else:
                params, opt_state = new_params, new_opt_state
            loss = float(metrics["loss"])
            losses.append(loss)
            if hb is not None:
                hb.beat(step)
            if args.log_every and step % args.log_every == 0:
                print(f"[train] step={step} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({(time.perf_counter() - t0):.1f}s)", flush=True)
            if mgr is not None and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                with tracer.span("train/checkpoint", step=step + 1, async_save=True):
                    mgr.save_async(step + 1, params=params, opt_state=opt_state,
                                   data_state=stream.state_dict())
        if mgr is not None:
            with tracer.span("train/checkpoint", step=args.steps, final=True):
                mgr.wait()
                mgr.save(args.steps, params=params, opt_state=opt_state,
                         data_state=stream.state_dict())
        print(f"[train] done: first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f}",
              flush=True)
        if nguard is not None and nguard.total_skipped:
            print(f"[train] guard: skipped {nguard.total_skipped} nonfinite "
                  f"step(s)", flush=True)
        _finish_trace(tracer, args)
        return 0


if __name__ == "__main__":
    sys.exit(main())
