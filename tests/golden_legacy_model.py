"""Frozen pre-refactor analytical model — the golden reference.

This module is a verbatim snapshot of the hand-written formulas that lived
in ``analysis/traffic.py``, ``tuning/space.py``, and ``kernels/ops.py``
*before* the declarative ``repro.perfmodel`` refactor (seed commit of PR 5).
It is imported only by ``tests/test_perfmodel_golden.py``, which pins every
schedule-derived quantity — traffic bytes, transactions, flops, VMEM
working sets, legality verdicts, tile geometry — to exact (integer-byte)
equality with these functions across a parameterized shape/tiling/epilogue
grid.

DO NOT "fix" or modernize anything here: its only value is being frozen.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.kernels.common import LANE, DWConvDims, cdiv, round_up
from repro.kernels.epilogue import parse_epilogue


@dataclasses.dataclass(frozen=True)
class TrafficEstimate:
    flops: float
    bytes_read: float
    bytes_written: float
    transactions: float
    aligned: bool
    reliable: bool

    @property
    def bytes_moved(self) -> float:
        return self.bytes_read + self.bytes_written

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_moved, 1.0)


def path_flops(d: DWConvDims) -> float:
    return 2.0 * d.B * d.H * d.L * d.K


# --------------------------------------------------------------------------
# kernels/ops.py geometry (pre-refactor)
# --------------------------------------------------------------------------


def bwd_fused_wpad(L: int, K: int) -> int:
    return round_up(round_up(L, LANE) + K - 1, LANE)


def unified_wpad(L: int, K: int, block_t: int) -> int:
    Lout = round_up(L, LANE)
    Lt = min(block_t, Lout)
    nT = cdiv(Lout, Lt)
    Wpad = max(
        bwd_fused_wpad(L, K),
        (nT + 1) * Lt,
        nT * Lt + K - 1 + LANE,
    )
    return round_up(Wpad, LANE)


def bwdk_time_tile(L: int, K: int, block_t: int, variant: str) -> Optional[int]:
    if variant not in ("accum", "twostage", "fused", "fused_partials"):
        return None
    Lout = round_up(L, LANE)
    Lt = min(block_t, Lout)
    if Lt >= Lout or Lt < K - 1:
        return None
    return Lt


def epilogue_time_tile(L: int, K: int, block_t: int, variant: str) -> Optional[int]:
    Lt = bwdk_time_tile(L, K, block_t, variant)
    if Lt is None or Lt < 2 * (K - 1):
        return None
    return Lt


# --------------------------------------------------------------------------
# analysis/traffic.py (pre-refactor)
# --------------------------------------------------------------------------


def _tile_geometry(d: DWConvDims, block_h: int, block_t: int):
    Hb = min(block_h, d.H)
    Lout = round_up(d.L, LANE)
    Lt = min(block_t, Lout)
    nT = cdiv(Lout, Lt)
    n_tiles = d.B * cdiv(d.H, Hb) * nT
    return Hb, Lout, Lt, nT, n_tiles


def fwd_traffic(d, variant, itemsize=4, block_h=8, block_t=512) -> TrafficEstimate:
    Hb, Lout, Lt, nT, n_tiles = _tile_geometry(d, block_h, block_t)
    flops = path_flops(d)
    y_bytes = d.B * d.H * d.L * itemsize
    k_bytes_once = d.H * d.K * itemsize

    if variant == "naive":
        read = n_tiles * d.K * (Hb * Lt) * itemsize + k_bytes_once
        tx = n_tiles * d.K
        return TrafficEstimate(flops, read, y_bytes, tx, aligned=False, reliable=False)
    if variant == "lane":
        read = n_tiles * d.K * (Hb * (Lt + LANE)) * itemsize + k_bytes_once
        tx = n_tiles * d.K
        return TrafficEstimate(flops, read, y_bytes, tx, aligned=True, reliable=True)
    if variant == "block":
        read = n_tiles * 2 * (Hb * Lt) * itemsize + k_bytes_once
        tx = n_tiles * 2
        return TrafficEstimate(flops, read, y_bytes, tx, aligned=True, reliable=True)
    if variant == "row":
        read = d.B * d.H * (Lout + d.K - 1) * itemsize + k_bytes_once
        tx = d.B * cdiv(d.H, Hb)
        return TrafficEstimate(flops, read, y_bytes, tx, aligned=True, reliable=True)
    if variant == "xla":
        read = d.B * d.H * (d.L + d.K - 1) * itemsize + k_bytes_once
        return TrafficEstimate(flops, read, y_bytes, 0, aligned=True, reliable=True)
    raise ValueError(variant)


def _bwd_tiles(d: DWConvDims, variant: str, block_t: int):
    Lt = bwdk_time_tile(d.L, d.K, block_t, variant)
    if Lt is None:
        return 1, 0
    nT = cdiv(round_up(d.L, LANE), Lt)
    halo = d.B * d.H * (nT - 1) * (d.K - 1)
    return nT, halo


def bwdk_traffic(d, variant, itemsize=4, block_h=8, block_t=512,
                 batch_chunk=128) -> TrafficEstimate:
    flops = path_flops(d)
    Hb = min(block_h, d.H)
    Bc = min(batch_chunk, d.B)
    nC = cdiv(d.B, Bc)
    nH = cdiv(d.H, Hb)
    Kp = round_up(d.K, LANE)
    slab = d.B * d.H * d.L * itemsize
    dk_bytes = d.H * d.K * itemsize
    nT, halo = _bwd_tiles(d, variant, block_t)
    halo_bytes = halo * itemsize
    in_blocks = 3 if nT > 1 else 2

    if variant == "naive":
        read = 2 * d.K * slab
        tx = nH * nC * d.K * 2
        return TrafficEstimate(flops, read, dk_bytes, tx, aligned=False, reliable=False)
    if variant == "twostage":
        partials = nC * nT * d.H * Kp * 4
        read = 2 * slab + halo_bytes + partials
        tx = nH * nC * nT * in_blocks + nH * nC * nT
        return TrafficEstimate(flops, read, dk_bytes + partials, tx, aligned=True, reliable=True)
    if variant == "accum":
        read = 2 * slab + halo_bytes
        tx = nH * nC * nT * in_blocks
        return TrafficEstimate(flops, read, dk_bytes, tx, aligned=True, reliable=True)
    if variant == "xla":
        read = 2 * slab
        return TrafficEstimate(flops, read, dk_bytes, 0, aligned=True, reliable=True)
    raise ValueError(variant)


def bwd_split_traffic(d, itemsize=4, bwd_in_variant="row", bwd_k_variant="accum",
                      block_h=8, block_t=512, batch_chunk=128) -> TrafficEstimate:
    est_in = fwd_traffic(d, bwd_in_variant, itemsize,
                         block_h=block_h, block_t=block_t)
    est_k = bwdk_traffic(d, bwd_k_variant, itemsize,
                         block_h=block_h, block_t=block_t,
                         batch_chunk=batch_chunk)
    slab = d.B * d.H * d.L * itemsize
    pslab = d.B * d.H * (d.L + d.K - 1) * itemsize
    pad_read = 3 * slab
    pad_written = 2 * pslab + slab
    return TrafficEstimate(
        flops=est_in.flops + est_k.flops,
        bytes_read=pad_read + est_in.bytes_read + est_k.bytes_read,
        bytes_written=pad_written + est_in.bytes_written + est_k.bytes_written,
        transactions=est_in.transactions + est_k.transactions + 3,
        aligned=est_in.aligned and est_k.aligned,
        reliable=est_in.reliable and est_k.reliable,
    )


def bwd_fused_traffic(d, variant="fused", itemsize=4, block_h=8, block_t=512,
                      batch_chunk=128) -> TrafficEstimate:
    if variant == "split":
        return bwd_split_traffic(d, itemsize, block_h=block_h,
                                 block_t=block_t, batch_chunk=batch_chunk)
    flops = 2.0 * path_flops(d)
    Hb = min(block_h, d.H)
    Bc = min(batch_chunk, d.B)
    nC = cdiv(d.B, Bc)
    nH = cdiv(d.H, Hb)
    slab = d.B * d.H * d.L * itemsize
    pslab = d.B * d.H * (d.L + d.K - 1) * itemsize
    k_bytes = d.H * d.K * itemsize
    dk_bytes = d.H * d.K * itemsize
    nT, halo = _bwd_tiles(d, variant, block_t)
    halo_bytes = 2 * halo * itemsize
    in_blocks = 5 if nT > 1 else 3
    read = slab + 2 * pslab + k_bytes + halo_bytes
    written = pslab + slab + dk_bytes
    tx = nH * nC * nT * in_blocks + 1
    if variant == "fused_partials":
        partials = nC * nT * d.H * round_up(d.K, LANE) * 4
        read += partials
        written += partials
        tx += nH * nC * nT
    elif variant != "fused":
        raise ValueError(variant)
    return TrafficEstimate(flops, read, written, tx, aligned=True, reliable=True)


ACT_FLOPS_PER_ELEM = 10.0


def _epilogue_n_ops(bias: bool, act: str) -> int:
    return (1 if bias else 0) + (1 if act != "none" else 0)


def _epilogue_flops(d: DWConvDims, bias: bool, act: str) -> float:
    elems = d.B * d.H * d.L
    return (elems if bias else 0.0) + (ACT_FLOPS_PER_ELEM * elems if act != "none" else 0.0)


def epilogue_fwd_traffic(d, variant="row", itemsize=4, *, epilogue="none",
                         fused=True, block_h=8, block_t=512) -> TrafficEstimate:
    bias, act = parse_epilogue(epilogue)
    base = fwd_traffic(d, variant, itemsize, block_h=block_h, block_t=block_t)
    bias_bytes = d.H * itemsize if bias else 0
    flops = base.flops + _epilogue_flops(d, bias, act)
    if fused:
        return dataclasses.replace(
            base, flops=flops, bytes_read=base.bytes_read + bias_bytes)
    n_ops = _epilogue_n_ops(bias, act)
    slab = d.B * d.H * d.L * itemsize
    return dataclasses.replace(
        base, flops=flops,
        bytes_read=base.bytes_read + bias_bytes + n_ops * slab,
        bytes_written=base.bytes_written + n_ops * slab)


def epilogue_bwd_traffic(d, variant="fused", itemsize=4, *, epilogue="none",
                         block_h=8, block_t=512, batch_chunk=128) -> TrafficEstimate:
    bias, act = parse_epilogue(epilogue)
    if epilogue == "none":
        return bwd_fused_traffic(d, variant, itemsize, block_h=block_h,
                                 block_t=block_t, batch_chunk=batch_chunk)
    slab = d.B * d.H * d.L * itemsize
    if variant == "split":
        base = bwd_split_traffic(d, itemsize, block_h=block_h,
                                 block_t=block_t, batch_chunk=batch_chunk)
        pre = fwd_traffic(d, "row", itemsize, block_h=block_h, block_t=block_t)
        extra_read = pre.bytes_read + 2 * slab + (slab if bias else 0)
        extra_written = pre.bytes_written + slab + (d.H * itemsize if bias else 0)
        return dataclasses.replace(
            base,
            flops=base.flops + pre.flops + _epilogue_flops(d, bias, act),
            bytes_read=base.bytes_read + extra_read,
            bytes_written=base.bytes_written + extra_written,
            transactions=base.transactions + pre.transactions + 2)
    if variant not in ("fused", "fused_partials"):
        raise ValueError(variant)
    flops = 3.0 * path_flops(d) + _epilogue_flops(d, bias, act)
    Hb = min(block_h, d.H)
    Bc = min(batch_chunk, d.B)
    nC = cdiv(d.B, Bc)
    nH = cdiv(d.H, Hb)
    pslab = d.B * d.H * (d.L + d.K - 1) * itemsize
    k_bytes = d.H * d.K * itemsize
    dk_bytes = d.H * d.K * itemsize
    bias_bytes = d.H * itemsize if bias else 0
    Lt = epilogue_time_tile(d.L, d.K, block_t, variant)
    if Lt is None:
        nT, halo = 1, 0
    else:
        nT = cdiv(round_up(d.L, LANE), Lt)
        halo = d.B * d.H * (nT - 1) * (d.K - 1)
    halo_bytes = 3 * halo * itemsize
    in_blocks = (7 if bias else 6) if nT > 1 else (4 if bias else 3)
    read = slab + 2 * pslab + k_bytes + bias_bytes + halo_bytes
    written = pslab + slab + dk_bytes + bias_bytes
    tx = nH * nC * nT * in_blocks + 1
    if variant == "fused_partials":
        partials = nC * nT * d.H * (round_up(d.K, LANE) + LANE) * 4
        read += partials
        written += partials
        tx += nH * nC * nT
    return TrafficEstimate(flops, read, written, tx, aligned=True, reliable=True)


def epilogue_unfused_bwd_traffic(d, itemsize=4, *, epilogue="none", block_h=8,
                                 block_t=512, batch_chunk=128) -> TrafficEstimate:
    bias, act = parse_epilogue(epilogue)
    base = bwd_split_traffic(d, itemsize, block_h=block_h, block_t=block_t,
                             batch_chunk=batch_chunk)
    slab = d.B * d.H * d.L * itemsize
    extra_read = (2 * slab if act != "none" else 0) + (slab if bias else 0)
    extra_written = (slab if act != "none" else 0) + (d.H * itemsize if bias else 0)
    return dataclasses.replace(
        base,
        flops=base.flops + _epilogue_flops(d, bias, act),
        bytes_read=base.bytes_read + extra_read,
        bytes_written=base.bytes_written + extra_written,
        transactions=base.transactions + _epilogue_n_ops(bias, act))


def epilogue_block_traffic(d, itemsize=4, *, epilogue="bias+silu", fused=True,
                           fwd_variant="row", bwd_variant="fused", block_h=8,
                           block_t=512, batch_chunk=128) -> TrafficEstimate:
    fwd = epilogue_fwd_traffic(d, fwd_variant, itemsize, epilogue=epilogue,
                               fused=fused, block_h=block_h, block_t=block_t)
    if fused:
        bwd = epilogue_bwd_traffic(d, bwd_variant, itemsize, epilogue=epilogue,
                                   block_h=block_h, block_t=block_t,
                                   batch_chunk=batch_chunk)
    else:
        bwd = epilogue_unfused_bwd_traffic(d, itemsize, epilogue=epilogue,
                                           block_h=block_h, block_t=block_t,
                                           batch_chunk=batch_chunk)
    return TrafficEstimate(
        flops=fwd.flops + bwd.flops,
        bytes_read=fwd.bytes_read + bwd.bytes_read,
        bytes_written=fwd.bytes_written + bwd.bytes_written,
        transactions=fwd.transactions + bwd.transactions,
        aligned=fwd.aligned and bwd.aligned,
        reliable=fwd.reliable and bwd.reliable,
    )


_WARP_SIZE = 32
_SHARED_TPB = 128


def paper_fwd_traffic(d, variant, itemsize=4) -> TrafficEstimate:
    flops = path_flops(d)
    slab = d.B * d.H * d.L * itemsize
    k_bytes = d.H * d.K * itemsize
    if variant == "naive":
        return TrafficEstimate(flops, slab + k_bytes, slab, 0, aligned=False, reliable=False)
    if variant == "gmc":
        rho = d.K / min(d.K, _WARP_SIZE)
        return TrafficEstimate(flops, rho * slab + k_bytes, slab, 0, aligned=True, reliable=True)
    if variant == "shared":
        rho = (_SHARED_TPB + d.K - 1) / _SHARED_TPB
        return TrafficEstimate(flops, rho * slab + k_bytes, slab, 0, aligned=True, reliable=True)
    if variant == "warp":
        return TrafficEstimate(flops, slab + k_bytes, slab, 0, aligned=True, reliable=True)
    raise ValueError(variant)


def paper_bwdk_traffic(d, variant, itemsize=4) -> TrafficEstimate:
    flops = path_flops(d)
    slab = d.B * d.H * d.L * itemsize
    dk = d.H * d.K * itemsize
    if variant == "naive":
        return TrafficEstimate(flops, 2 * slab, dk, 0, aligned=False, reliable=False)
    n_chunks = max(d.B // 128, 1)
    partials = n_chunks * d.H * d.K * 4 * 2
    return TrafficEstimate(flops, 2 * slab + partials / 2, dk + partials / 2, 0,
                           aligned=True, reliable=True)


# --------------------------------------------------------------------------
# tuning/space.py (pre-refactor): VMEM working set + legality
# --------------------------------------------------------------------------

_KNOBLESS = ("xla", "split")


def _effective_tiles_raw(block_h, block_t, batch_chunk,
                         d: DWConvDims) -> Tuple[int, int, int, int]:
    Hb = max(1, min(block_h, d.H))
    Lout = round_up(d.L, LANE)
    Lt = max(1, min(block_t, Lout))
    Bc = max(1, min(batch_chunk, d.B))
    return Hb, Lt, Bc, Lout


def _bwd_time_tile_raw(path, variant, block_t, d, epilogue="none"):
    if path == "bwd_fused" and epilogue != "none":
        return epilogue_time_tile(d.L, d.K, block_t, variant)
    return bwdk_time_tile(d.L, d.K, block_t, variant)


def vmem_working_set_bytes(path, variant, d, itemsize, block_h=8, block_t=512,
                           batch_chunk=128, epilogue="none") -> int:
    Hb, Lt, Bc, Lout = _effective_tiles_raw(block_h, block_t, batch_chunk, d)
    Wpad = round_up(Lout + d.K - 1, LANE)
    Kp4 = Hb * round_up(d.K, LANE) * 4
    if path in ("fwd", "bwd_in"):
        if variant == "row":
            return Hb * (Wpad + Lout) * itemsize
        if variant == "block":
            return Hb * 3 * Lt * itemsize
        return Hb * (Lt + LANE + Lt) * itemsize
    tiled_lt = _bwd_time_tile_raw(path, variant, block_t, d, epilogue)
    if path == "bwd_fused":
        epi = epilogue != "none"
        if tiled_lt is not None:
            slabs = 6 if epi else 5
            extra = 2 * Bc * Hb * (tiled_lt + d.K - 1) * 4 if epi else 0
            return Bc * Hb * slabs * tiled_lt * itemsize + extra + Kp4
        extra = 2 * Bc * Hb * Lout * 4 if epi else 0
        return Bc * Hb * (2 * Wpad + Lout) * itemsize + extra + Kp4
    if tiled_lt is not None:
        return Bc * Hb * 3 * tiled_lt * itemsize + Kp4
    return Bc * Hb * (Wpad + d.L) * itemsize


def is_legal(path, variant, d, itemsize=4, hw=None, block_h=8, block_t=512,
             batch_chunk=128, epilogue="none") -> Tuple[bool, str]:
    if min(block_h, block_t, batch_chunk) < 1:
        return False, "tiling knobs must be positive"
    if variant in _KNOBLESS:
        return True, "ok"
    Hb, Lt, Bc, Lout = _effective_tiles_raw(block_h, block_t, batch_chunk, d)
    if path in ("fwd", "bwd_in"):
        if variant in ("naive", "lane") and Lt % LANE != 0:
            return False, f"Lt={Lt} not lane-aligned (Lt % {LANE} != 0)"
        if variant == "block" and Lt < d.K - 1:
            return False, f"halo K-1={d.K - 1} does not fit tile Lt={Lt}"
    if hw is not None and hw.vmem_bytes:
        need = vmem_working_set_bytes(path, variant, d, itemsize, block_h,
                                      block_t, batch_chunk, epilogue)
        if need > hw.vmem_bytes:
            return False, f"VMEM working set {need}B > {int(hw.vmem_bytes)}B"
    return True, "ok"
