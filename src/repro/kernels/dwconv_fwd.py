"""Pallas TPU kernels — depthwise-conv *forward* path, four variants.

TPU adaptation of the paper's CUDA variants (DESIGN.md §2):

  naive : per-tap, unaligned manual DMAs HBM->VMEM.  Each of the K taps
          issues its own overlapping copy of the (Hb, Lt) window — the
          analogue of each CUDA thread re-loading its convolution window
          from global memory.  Redundant traffic ~ K x tile.
  lane  : identical per-tap redundancy, but every DMA is widened to a
          128-lane-aligned window — the analogue of warp-coalesced
          transactions (alignment without data-movement reduction).
  block : BlockSpec-pipelined VMEM staging with a neighbour-tile halo
          (the same padded input is bound twice with a shifted index_map).
          All K taps are computed from VMEM; the Pallas pipeline
          double-buffers the tile DMAs — the analogue of shared-memory
          cache blocking.  Traffic ~ 2 x tile.
  row   : one grid cell per (b, h-block); the *entire* temporal row is
          staged in VMEM once and every tap reads on-chip — the analogue
          of the warp-tiled kernel (full working set on chip).
          Traffic ~ 1 x row.

All kernels consume an input that ``ops.py`` has already zero-padded to
(B, H, Wpad) where ``Wpad >= Lout + K - 1`` and ``Lout = round_up(L, LANE)``,
and produce (B, H, Lout); the wrapper slices back to L.  Accumulation is
always f32 regardless of the input dtype.

Every variant supports a *fused epilogue* (``kernels/epilogue.py``): an
optional per-channel bias add plus a pointwise activation applied to the
f32 accumulator **in-register**, before the single cast + HBM write — the
call-site composition ``act(conv(x, k) + b)`` with zero standalone
elementwise passes (and one fewer rounding step than the unfused chain in
low-precision dtypes).  ``bias`` arrives channel-padded as an (Hp, LANE)
column block from ``ops.py``; ``bias=None, act="none"`` takes the exact
pre-epilogue code path, bit for bit.

The *input-gradient* path reuses these kernels with a flipped filter and
adjoint padding (see ``ops.dwconv_bwd_input``) — exactly the paper's
observation that FWD and BWD_in share structure and optimization behaviour.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import LANE, cdiv, round_up
from repro.kernels.epilogue import apply_act


def _epilogue(acc: jnp.ndarray, b_ref, act: str) -> jnp.ndarray:
    """In-register epilogue on the f32 accumulator: per-channel bias (column
    0 of the (Hb, LANE) bias block) then the activation.  For ``b_ref=None,
    act='none'`` this is the identity — the trivial path stays bit-identical
    to the pre-epilogue kernels."""
    if b_ref is not None:
        acc = acc + b_ref[:, 0].astype(jnp.float32)[:, None]
    return apply_act(acc, act)


# ---------------------------------------------------------------------------
# row variant (warp-tiled analogue)
# ---------------------------------------------------------------------------


def _row_kernel(x_ref, k_ref, *rest, K: int, Lout: int, act: str):
    b_ref, y_ref = rest if len(rest) == 2 else (None, rest[0])
    full = x_ref[0].astype(jnp.float32)  # (Hb, Wpad) staged once in VMEM
    kv = k_ref[...].astype(jnp.float32)  # (Hb, Kp)
    acc = jnp.zeros(y_ref.shape[1:], jnp.float32)  # (Hb, Lout)
    for j in range(K):  # static unroll: K fused multiply-adds from VMEM
        acc = acc + full[:, j : j + Lout] * kv[:, j][:, None]
    y_ref[0] = _epilogue(acc, b_ref, act).astype(y_ref.dtype)


def dwconv_fwd_row(
    xp: jnp.ndarray,
    kp: jnp.ndarray,
    *,
    K: int,
    Lout: int,
    block_h: int = 8,
    interpret: bool = True,
    bias=None,
    act: str = "none",
) -> jnp.ndarray:
    """Full-row staging.  xp: (B, H, Wpad), kp: (H, Kp) -> (B, H, Lout)."""
    B, H, Wpad = xp.shape
    _, Kp = kp.shape
    Hb = min(block_h, H)
    if H % Hb != 0:
        raise ValueError(
            f"channels H={H} are not divisible by block_h={Hb}; lower "
            f"KernelOptions.block_h or let ops.py pad the channel axis")
    grid = (B, H // Hb)
    in_specs = [
        pl.BlockSpec((1, Hb, Wpad), lambda b, h: (b, h, 0)),
        pl.BlockSpec((Hb, Kp), lambda b, h: (h, 0)),
    ]
    operands = [xp, kp]
    if bias is not None:
        in_specs.append(pl.BlockSpec((Hb, LANE), lambda b, h: (h, 0)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_row_kernel, K=K, Lout=Lout, act=act),
        out_shape=jax.ShapeDtypeStruct((B, H, Lout), xp.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hb, Lout), lambda b, h: (b, h, 0)),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# block variant (shared-memory cache-blocking analogue)
# ---------------------------------------------------------------------------


def _block_kernel(xc_ref, xn_ref, k_ref, *rest, K: int, Lt: int, act: str):
    b_ref, y_ref = rest if len(rest) == 2 else (None, rest[0])
    cur = xc_ref[0].astype(jnp.float32)  # (Hb, Lt) current tile
    nxt = xn_ref[0].astype(jnp.float32)  # (Hb, Lt) halo tile
    full = jnp.concatenate([cur, nxt], axis=-1)  # extended tile, TPB + halo
    kv = k_ref[...].astype(jnp.float32)
    acc = jnp.zeros(y_ref.shape[1:], jnp.float32)
    for j in range(K):
        acc = acc + full[:, j : j + Lt] * kv[:, j][:, None]
    y_ref[0] = _epilogue(acc, b_ref, act).astype(y_ref.dtype)


def dwconv_fwd_block(
    xp: jnp.ndarray,
    kp: jnp.ndarray,
    *,
    K: int,
    Lout: int,
    block_h: int = 8,
    block_t: int = 512,
    interpret: bool = True,
    bias=None,
    act: str = "none",
) -> jnp.ndarray:
    """Halo-tile staging.  Requires Wpad >= (nT + 1) * Lt (ops.py pads)."""
    B, H, Wpad = xp.shape
    _, Kp = kp.shape
    Hb = min(block_h, H)
    if H % Hb != 0:
        raise ValueError(
            f"channels H={H} are not divisible by block_h={Hb}; lower "
            f"KernelOptions.block_h or let ops.py pad the channel axis")
    Lt = min(block_t, Lout)
    if Lt < K - 1:
        raise ValueError(
            f"halo K-1={K - 1} does not fit a single neighbour tile Lt={Lt}; "
            f"raise KernelOptions.block_t to at least K-1")
    nT = cdiv(Lout, Lt)
    if Wpad < (nT + 1) * Lt:
        raise ValueError(
            f"padded input width {Wpad} < (nT+1)*Lt={(nT + 1) * Lt}: the "
            f"neighbour-tile halo read runs out of bounds; ops.py must pad "
            f"x to (nT+1)*block_t columns")
    grid = (B, H // Hb, nT)
    in_specs = [
        pl.BlockSpec((1, Hb, Lt), lambda b, h, i: (b, h, i)),
        pl.BlockSpec((1, Hb, Lt), lambda b, h, i: (b, h, i + 1)),
        pl.BlockSpec((Hb, Kp), lambda b, h, i: (h, 0)),
    ]
    operands = [xp, xp, kp]
    if bias is not None:
        in_specs.append(pl.BlockSpec((Hb, LANE), lambda b, h, i: (h, 0)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_block_kernel, K=K, Lt=Lt, act=act),
        out_shape=jax.ShapeDtypeStruct((B, H, nT * Lt), xp.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hb, Lt), lambda b, h, i: (b, h, i)),
        interpret=interpret,
    )(*operands)[:, :, :Lout]


# ---------------------------------------------------------------------------
# naive + lane variants (manual-DMA, redundant per-tap traffic)
# ---------------------------------------------------------------------------


def _tapdma_kernel(
    x_hbm,
    k_ref,
    *rest,
    K: int,
    Lt: int,
    Hb: int,
    aligned: bool,
    act: str,
):
    """Per-tap DMA kernel.  ``aligned=False`` -> naive (K unaligned copies of
    exactly the tap window); ``aligned=True`` -> lane (K copies widened to a
    128-lane-aligned window).  Both move ~K x the tile from HBM — the point
    is the *structure*: alignment alone does not remove redundancy.

    ``Lt`` is a multiple of LANE, so the tile base ``i * Lt`` is always
    lane-aligned and the aligned variant's in-scratch offset ``j % LANE`` is
    a static Python int.
    """
    b_ref, y_ref, scratch, sem = rest if len(rest) == 4 else (None,) + rest
    b = pl.program_id(0)
    h = pl.program_id(1)
    i = pl.program_id(2)
    kv = k_ref[...].astype(jnp.float32)
    acc = jnp.zeros(y_ref.shape[1:], jnp.float32)
    base = i * Lt
    w = scratch.shape[-1]
    for j in range(K):  # one DMA per tap — the redundant-traffic structure
        if aligned:
            start = base + (j // LANE) * LANE  # lane-aligned transaction
            off = j % LANE  # static
        else:
            start = base + j  # unaligned transaction
            off = 0
        copy = pltpu.make_async_copy(
            x_hbm.at[b, pl.ds(h * Hb, Hb), pl.ds(start, w)], scratch, sem
        )
        copy.start()
        copy.wait()
        win = scratch[:, off : off + Lt].astype(jnp.float32)
        acc = acc + win * kv[:, j][:, None]
    y_ref[0] = _epilogue(acc, b_ref, act).astype(y_ref.dtype)


def _dwconv_fwd_tapdma(
    xp: jnp.ndarray,
    kp: jnp.ndarray,
    *,
    K: int,
    Lout: int,
    block_h: int,
    block_t: int,
    aligned: bool,
    interpret: bool,
    bias=None,
    act: str = "none",
) -> jnp.ndarray:
    B, H, Wpad = xp.shape
    _, Kp = kp.shape
    Hb = min(block_h, H)
    if H % Hb != 0:
        raise ValueError(
            f"channels H={H} are not divisible by block_h={Hb}; lower "
            f"KernelOptions.block_h or let ops.py pad the channel axis")
    Lt = min(block_t, Lout)
    if Lt % LANE != 0:
        raise ValueError(
            f"temporal tile Lt={Lt} is not lane-aligned (Lt % {LANE} != 0); "
            f"choose KernelOptions.block_t as a multiple of {LANE}")
    nT = cdiv(Lout, Lt)
    scratch_w = Lt + LANE if aligned else Lt
    need_w = nT * Lt + K - 1 + (LANE if aligned else 0)
    if Wpad < need_w:
        raise ValueError(
            f"padded input width {Wpad} < {need_w} needed by the per-tap DMA "
            f"windows (nT={nT}, Lt={Lt}, K={K}, aligned={aligned}); ops.py "
            f"must pad x to the widened window")
    grid = (B, H // Hb, nT)
    in_specs = [
        pl.BlockSpec(memory_space=pl.ANY),  # stays in HBM; DMA'd per tap
        pl.BlockSpec((Hb, Kp), lambda b, h, i: (h, 0)),
    ]
    operands = [xp, kp]
    if bias is not None:
        in_specs.append(pl.BlockSpec((Hb, LANE), lambda b, h, i: (h, 0)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_tapdma_kernel, K=K, Lt=Lt, Hb=Hb, aligned=aligned, act=act),
        out_shape=jax.ShapeDtypeStruct((B, H, nT * Lt), xp.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hb, Lt), lambda b, h, i: (b, h, i)),
        scratch_shapes=[
            pltpu.VMEM((Hb, scratch_w), xp.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(*operands)[:, :, :Lout]


def dwconv_fwd_naive(xp, kp, *, K, Lout, block_h=8, block_t=512, interpret=True,
                     bias=None, act="none"):
    return _dwconv_fwd_tapdma(
        xp, kp, K=K, Lout=Lout, block_h=block_h, block_t=block_t,
        aligned=False, interpret=interpret, bias=bias, act=act,
    )


def dwconv_fwd_lane(xp, kp, *, K, Lout, block_h=8, block_t=512, interpret=True,
                    bias=None, act="none"):
    return _dwconv_fwd_tapdma(
        xp, kp, K=K, Lout=Lout, block_h=block_h, block_t=block_t,
        aligned=True, interpret=interpret, bias=bias, act=act,
    )
