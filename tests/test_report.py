"""Counter-free report: payload structure, CLI, and benchmark agreement.

Acceptance for PR 5's report half: ``python -m repro.launch.report`` runs
clean, and its roofline rows are the same computation
``benchmarks/paper_roofline.py`` renders.
"""
from __future__ import annotations

import json

import pytest

from repro import perfmodel
from repro.analysis.hw import P100, TPU_V5E
from repro.analysis.paper_data import PAPER_DIMS, TABLE2_MS
from repro.analysis.report import (
    counter_free_markdown,
    counter_free_report,
    paper_roofline_points,
)
from repro.kernels.common import DWConvDims
from repro.launch import report as report_cli

D_SMALL = DWConvDims(B=8, H=16, L=48, K=4)


def test_payload_structure_and_derivation():
    payload = counter_free_report(D_SMALL, hw=TPU_V5E)
    assert payload["hw"] == "tpu-v5e"
    assert payload["decomposition"] and payload["roofline"]
    assert len(payload["decomposition"]) == len(payload["roofline"])
    for rec in payload["decomposition"]:
        # decomposition rows are sums of their own operand breakdowns
        reads = sum(o["bytes"] for o in rec["operands"] if o["role"] == "read")
        writes = sum(o["bytes"] for o in rec["operands"] if o["role"] == "write")
        assert rec["bytes_read"] == reads
        assert rec["bytes_written"] == writes
        assert rec["bytes_moved"] == reads + writes
    # every reliable kernel point of this memory-bound operator is below the knee
    for r in payload["roofline"]:
        if r["regime"] is not None:
            assert r["regime"] == "memory-bound"
    # epilogue fusion always saves bytes
    for r in payload["epilogue"]:
        assert r["ratio"] < 1.0


def test_markdown_renders_all_sections():
    payload = counter_free_report(D_SMALL, hw=TPU_V5E)
    md = counter_free_markdown(payload)
    for section in ("Execution-path decomposition", "Roofline placement",
                    "Paper-mode rows", "Epilogue fusion"):
        assert section in md
    assert "N/A" in md  # the naive proxy rows


def test_paper_points_match_paper_roofline_benchmark():
    """The CLI's paper-mode roofline rows and the benchmark's rows are one
    computation: identical runtimes, AI, achieved GFLOP/s, and regimes."""
    paper_roofline = pytest.importorskip(
        "benchmarks.paper_roofline",
        reason="benchmarks namespace package needs repo root on sys.path")
    points = paper_roofline_points()
    rows = [r for r in paper_roofline.run()
            if not r.name.endswith("/summary")]
    assert len(points) == len(rows) == 3 * len(TABLE2_MS)
    for p, row in zip(points, rows):
        assert row.name == f"paper_roofline/{p.variant}/{p.path}"
        assert row.us_per_call == pytest.approx(p.runtime_s * 1e6)
        assert f"achieved={p.achieved_gflops:.0f}GFLOP/s" in row.derived
        if p.reliable:
            assert f"AI={p.arithmetic_intensity:.2f}FLOP/B" in row.derived
            assert p.regime in row.derived
        else:
            assert "AI=N/A" in row.derived


def test_paper_points_use_published_runtimes():
    points = paper_roofline_points()
    by_key = {(p.variant, p.path): p for p in points}
    for variant, (fwd_ms, bin_ms, bk_ms, _, _) in TABLE2_MS.items():
        assert by_key[(variant, "fwd")].runtime_s == pytest.approx(fwd_ms / 1e3)
        assert by_key[(variant, "bwd_in")].runtime_s == pytest.approx(bin_ms / 1e3)
        assert by_key[(variant, "bwd_k")].runtime_s == pytest.approx(bk_ms / 1e3)
        # Fig. 10 headline: everything memory-bound on the P100 roofline
        for path in ("fwd", "bwd_in", "bwd_k"):
            p = by_key[(variant, path)]
            if p.reliable:
                assert p.regime == "memory-bound"
                assert p.knee == pytest.approx(P100.peak_flops_f32 / P100.hbm_bw)


def test_paper_section_pins_f32_charging():
    """The paper-mode rows divide by *published float32* runtimes, so a
    bfloat16 report must not halve their bytes (which would flip gmc rows
    past the P100 knee into compute-bound)."""
    bf16 = counter_free_report(PAPER_DIMS, hw=TPU_V5E, itemsize=2)
    f32 = counter_free_report(PAPER_DIMS, hw=TPU_V5E, itemsize=4)
    assert bf16["paper"] == f32["paper"]
    for r in bf16["paper"]:
        if r["regime"] is not None:
            assert r["regime"] == "memory-bound"


def test_cli_runs_clean_and_writes_artifacts(tmp_path):
    out_md = tmp_path / "REPORT.md"
    out_json = tmp_path / "BENCH_report.json"
    rc = report_cli.main([
        "--shapes", "paper", "--out", str(out_md), "--json", str(out_json)])
    assert rc == 0
    md = out_md.read_text()
    assert "# Counter-free performance report" in md
    assert "16384" in md  # the paper shape made it in
    payload = json.loads(out_json.read_text())
    assert payload["dims"] == {"B": PAPER_DIMS.B, "H": PAPER_DIMS.H,
                               "L": PAPER_DIMS.L, "K": PAPER_DIMS.K,
                               "padding": "same"}
    assert payload["roofline"] and payload["paper"] and payload["epilogue"]


def test_cli_shape_and_hw_flags(tmp_path, capsys):
    rc = report_cli.main(["--shapes", "8x16x48x4", "--hw", "p100",
                          "--no-paper", "--no-epilogue"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "hardware=p100" in out
    assert "Paper-mode rows" not in out


def test_cli_rejects_bad_shape():
    with pytest.raises(SystemExit):
        report_cli.main(["--shapes", "not-a-shape"])


def test_dtype_itemsize_convention():
    assert perfmodel.dtype_itemsize("float32") == 4
    assert perfmodel.dtype_itemsize("bfloat16") == 2
    with pytest.raises(ValueError):
        perfmodel.dtype_itemsize("int8")
    # bf16 charging halves operand bytes but keeps f32 partials at 4
    d = DWConvDims(B=8, H=64, L=16384, K=4)
    f32 = perfmodel.derive_traffic(
        perfmodel.schedule_for("bwd_k", "twostage", d, 4, block_t=128))
    bf16 = perfmodel.derive_traffic(
        perfmodel.schedule_for("bwd_k", "twostage", d, 2, block_t=128))
    partials = next(
        o.hbm_bytes
        for o in perfmodel.schedule_for("bwd_k", "twostage", d, 2,
                                        block_t=128).operands
        if o.name == "dk_partials" and o.role == "write")
    # operand slabs halve; the partials term is identical in both charges
    assert bf16.bytes_read < f32.bytes_read
    assert partials == next(
        o.hbm_bytes
        for o in perfmodel.schedule_for("bwd_k", "twostage", d, 4,
                                        block_t=128).operands
        if o.name == "dk_partials" and o.role == "write")
