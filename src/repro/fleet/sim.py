"""Replica simulation harness: prove warm start and chaos tolerance.

``run_sim`` spawns N subprocess "replicas" that share one signed bundle,
modeling a serving fleet behind a shared artifact store:

  * a **seed** replica tunes the shape fresh and exports the signed bundle;
  * N **warm** replicas start with an *empty* local cache and
    ``REPRO_TUNE_BUNDLE`` pointing at the bundle — each must serve the
    shape with **zero** metered tuning candidates (asserted by counting
    ``tune/candidate`` spans);
  * one **chaos** replica receives a bit-flipped copy of the bundle (byte
    mutated, signature re-used) — the import must be rejected with
    ``BundleIntegrityError``, degrade (``kind="degradation"`` record, no
    crash), leave the local cache byte-identical, and the replica must
    still serve correctly via fresh tuning.

Each replica verifies its served output against the XLA reference, so
"warm" never silently means "wrong".

CLI (used by ``benchmarks/paper_fleet.py``, the CI fleet job, and tests)::

  # full parent-orchestrated simulation
  python -m repro.fleet.sim --shape 2x4x48x5 --warm 2 --budget 2

  # one replica (what the parent spawns)
  python -m repro.fleet.sim --replica --shape 2x4x48x5 --expect-warm \\
      --result out.json

  # deterministic single-byte tamper (CI's corrupted-copy step)
  python -m repro.fleet.sim --tamper good.bundle.json bad.bundle.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import subprocess
import sys
from pathlib import Path
from typing import Dict, List, Optional

SIM_KEY_FALLBACK = "repro-fleet-sim-key"


def _write_json(path: os.PathLike, obj: Dict) -> None:
    Path(path).write_text(json.dumps(obj, indent=1))


def _read_json(path: os.PathLike) -> Dict:
    return json.loads(Path(path).read_text())


def parse_shape(spec: str):
    from repro.kernels.common import DWConvDims

    b, h, l, k = (int(v) for v in spec.lower().split("x"))
    return DWConvDims(B=b, H=h, L=l, K=k)


def tamper_bundle(src: os.PathLike, dst: os.PathLike) -> None:
    """Flip one digit inside the entries region, re-using the signature.

    The mutation keeps the JSON parseable — the file still *looks* like a
    bundle — so rejection can only come from the HMAC check, which is
    exactly the property the chaos replica exercises.
    """
    text = Path(src).read_text()
    region = text.find('"entries"')
    m = re.search(r"\d", text[region:])
    if m is None:  # no digit to flip: corrupt the signature hex instead
        region, m = text.find('"signature"'), re.search(r"[0-9a-f]", text[text.find('"signature"'):])
    i = region + m.start()
    flipped = "1" if text[i] != "1" else "2"
    Path(dst).write_text(text[:i] + flipped + text[i + 1:])


# ---------------------------------------------------------------------------
# one replica (subprocess body)
# ---------------------------------------------------------------------------


def run_replica(args) -> int:
    """One serving replica: warm-start (env auto-import) -> tune if cold ->
    serve the shape through ``variant="auto"`` dispatch -> verify against
    the XLA reference -> report metered-candidate count + degradations."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref
    from repro.obs import trace as obs_trace
    from repro.resilience import guard
    from repro.tuning import cache as tuning_cache
    from repro.tuning.tuner import tune_path

    # Always install an *enabled* global tracer: the tuner's per-candidate
    # spans land on it, and counting them is how this replica proves (or
    # disproves) its warm start.  Without --trace it records in-memory only.
    tracer = obs_trace.configure(args.trace or None,
                                 meta={"launcher": "fleet-sim"})

    d = parse_shape(args.shape)
    # First default_cache() touch: REPRO_TUNE_BUNDLE (if set) auto-imports
    # here, through the full validated chain, degradation-guarded.
    cache = tuning_cache.default_cache()
    key = tuning_cache.ShapeKey(
        path="fwd", B=d.B, H=d.H, L=d.L, K=d.K, dtype="float32",
        backend=jax.default_backend(), padding=d.padding)

    entry = cache.get(key)
    warm = entry is not None and not entry.quarantined
    if not warm:
        tune_path(d, "fwd", budget=args.tune_budget, iters=1, cache=cache)

    metered = sum(1 for r in tracer.records
                  if r.get("kind") == "span" and r.get("name") == "tune/candidate")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(d.B, d.H, d.L)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(d.H, d.K)), jnp.float32)
    got = ops.dwconv_fwd_op(x, k, d.padding, "auto")
    want = ref.dwconv_fwd_ref(x, k, d.padding)
    served_ok = bool(jnp.allclose(got, want, atol=1e-4, rtol=1e-4))

    rejected = [e for e in guard.degradation_events()
                if e.get("site") == "bundle/import"]
    result = {
        "shape": args.shape,
        "warm": warm,
        "metered_candidates": metered,
        "served_ok": served_ok,
        "bundle_rejections": len(rejected),
        "cache_entries": len(cache),
    }
    if args.export:
        from repro.fleet.bundle import export_bundle

        result["bundle"] = str(export_bundle(cache, args.export))
    # Always emit the outcome as a trace record: a *warm* replica records no
    # spans at all, and the trace file must still exist (and say why) so the
    # CI grep for tune/candidate spans can never pass against a missing file.
    tracer.event("replica/result", **result)
    if args.result:
        _write_json(args.result, result)
    print(f"[fleet.replica] {result}", flush=True)
    if args.trace:
        tracer.close()
    if not served_ok:
        return 4
    if args.expect_warm and metered > 0:
        print(f"[fleet.replica] FAIL: expected warm start but metered "
              f"{metered} candidates", file=sys.stderr, flush=True)
        return 3
    return 0


# ---------------------------------------------------------------------------
# the fleet (parent orchestration)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    bundle: str
    seed: Dict
    warm: List[Dict]
    chaos: Optional[Dict]

    @property
    def warm_metered(self) -> int:
        return sum(r["metered_candidates"] for r in self.warm)

    @property
    def ok(self) -> bool:
        replicas = [self.seed, *self.warm] + ([self.chaos] if self.chaos else [])
        return (all(r["served_ok"] for r in replicas)
                and self.warm_metered == 0
                and (self.chaos is None
                     or (self.chaos["bundle_rejections"] > 0
                         and self.chaos["metered_candidates"] > 0)))


def _replica_env(workdir: Path, name: str, key: str,
                 bundle: Optional[Path]) -> Dict[str, str]:
    import repro

    # namespace package: derive the src dir from __path__, not __file__
    src = str(Path(next(iter(repro.__path__))).resolve().parent)
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    env["REPRO_TUNE_CACHE"] = str(workdir / f"{name}.cache.json")
    env["REPRO_FLEET_KEY"] = key
    if bundle is not None:
        env["REPRO_TUNE_BUNDLE"] = str(bundle)
    else:
        env.pop("REPRO_TUNE_BUNDLE", None)
    return env


def run_sim(shape: str, workdir: os.PathLike, *, warm_replicas: int = 2,
            chaos: bool = True, tune_budget: int = 2,
            key: Optional[str] = None, verbose: bool = False) -> SimResult:
    """Seed replica tunes + exports; warm replicas consume the bundle with
    empty caches; a chaos replica consumes a tampered copy.  Subprocesses
    give each replica its own process-global state (memoized caches, trace,
    degradation ledger) — the same isolation real replicas have."""
    workdir = Path(workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    key = key or os.environ.get("REPRO_FLEET_KEY") or SIM_KEY_FALLBACK
    bundle = workdir / "fleet.bundle.json"

    def spawn(name: str, extra: List[str], env: Dict[str, str]) -> Dict:
        result_file = workdir / f"{name}.result.json"
        cmd = [sys.executable, "-m", "repro.fleet.sim", "--replica",
               "--shape", shape, "--tune-budget", str(tune_budget),
               "--result", str(result_file), *extra]
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
        if verbose or proc.returncode != 0:
            sys.stderr.write(proc.stderr)
        out = _read_json(result_file) if result_file.exists() else {
            "served_ok": False, "metered_candidates": -1,
            "bundle_rejections": 0, "warm": False, "shape": shape}
        out["replica"] = name
        out["returncode"] = proc.returncode
        return out

    seed = spawn("seed", ["--export", str(bundle)],
                 _replica_env(workdir, "seed", key, None))
    warm = [spawn(f"warm{i}", ["--expect-warm"],
                  _replica_env(workdir, f"warm{i}", key, bundle))
            for i in range(warm_replicas)]
    chaos_res = None
    if chaos:
        bad = workdir / "tampered.bundle.json"
        tamper_bundle(bundle, bad)
        chaos_res = spawn("chaos", [],
                          _replica_env(workdir, "chaos", key, bad))
    return SimResult(bundle=str(bundle), seed=seed, warm=warm,
                     chaos=chaos_res)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--replica", action="store_true",
                    help="run as one replica (internal: spawned by the parent)")
    ap.add_argument("--tamper", nargs=2, metavar=("SRC", "DST"),
                    help="flip one byte of SRC's entries into DST and exit")
    ap.add_argument("--shape", default="2x4x48x5", help="BxHxLxK")
    ap.add_argument("--warm", type=int, default=2, help="warm replica count")
    ap.add_argument("--no-chaos", action="store_true")
    ap.add_argument("--tune-budget", type=int, default=2)
    ap.add_argument("--workdir", default="results/fleet-sim")
    ap.add_argument("--expect-warm", action="store_true",
                    help="replica mode: fail (exit 3) if any candidate is metered")
    ap.add_argument("--export", default="",
                    help="replica mode: export the cache as a bundle here")
    ap.add_argument("--result", default="",
                    help="replica mode: write the result JSON here")
    ap.add_argument("--trace", default="",
                    help="replica mode: write the span trace (JSONL) here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.tamper:
        tamper_bundle(args.tamper[0], args.tamper[1])
        print(f"[fleet.sim] tampered copy written to {args.tamper[1]}")
        return 0
    if args.replica:
        return run_replica(args)

    res = run_sim(args.shape, args.workdir, warm_replicas=args.warm,
                  chaos=not args.no_chaos, tune_budget=args.tune_budget,
                  verbose=args.verbose)
    print(f"[fleet.sim] seed: {res.seed}")
    for r in res.warm:
        print(f"[fleet.sim] {r['replica']}: {r}")
    if res.chaos:
        print(f"[fleet.sim] chaos: {res.chaos}")
    print(f"[fleet.sim] warm replicas metered {res.warm_metered} candidates; "
          f"{'OK' if res.ok else 'FAILED'}")
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
