"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--fast`` trims iteration
counts (used by CI); ``--only <prefix>`` filters benchmarks; ``--json
<path>`` additionally writes machine-readable results (conventionally
``BENCH_kernels.json``) so the perf trajectory is recorded per run — the
fused-vs-split backward speedup is promoted to a top-level metric.

A module may signal a soft failure by emitting a row whose ``derived``
contains ``FAILED`` (e.g. the e2e convergence check): the remaining rows
still print, but the harness exits nonzero.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import traceback

_SPEEDUP_RE = re.compile(r"fused_vs_split=([0-9.]+)x")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write machine-readable results (BENCH_kernels.json)")
    args = ap.parse_args()

    from benchmarks import paper_table2, paper_table3, paper_roofline, paper_validation
    from benchmarks import paper_autotune, paper_fused_bwd, paper_longseq
    from benchmarks import roofline_table, s4convd_e2e

    modules = [
        ("paper_table2", paper_table2),
        ("paper_table3", paper_table3),
        ("paper_roofline", paper_roofline),
        ("paper_validation", paper_validation),
        ("paper_autotune", paper_autotune),
        ("paper_fused_bwd", paper_fused_bwd),
        ("paper_longseq", paper_longseq),
        ("s4convd_e2e", s4convd_e2e),
        ("roofline_table", roofline_table),
    ]
    print("name,us_per_call,derived")
    failures = 0
    results = []
    fused_vs_split = None
    for name, mod in modules:
        if args.only and not name.startswith(args.only):
            continue
        try:
            for row in mod.run(fast=args.fast):
                print(f"{row.name},{row.us_per_call:.1f},{row.derived}")
                results.append({"name": row.name, "us_per_call": row.us_per_call,
                                "derived": row.derived})
                if "FAILED" in row.derived:
                    failures += 1
                m = _SPEEDUP_RE.search(row.derived)
                if m and row.name.startswith("paper_fused_bwd/measured"):
                    fused_vs_split = float(m.group(1))
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR", file=sys.stdout)
            results.append({"name": name, "us_per_call": 0.0, "derived": "ERROR"})
            traceback.print_exc()
    if args.json:
        payload = {
            "fused_vs_split_backward_speedup": fused_vs_split,
            "failures": failures,
            "results": results,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(results)} rows to {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
