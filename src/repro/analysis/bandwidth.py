"""Counter-free effective-bandwidth estimation (paper §V-B3, Table III).

    eff_bw  = modeled_bytes_moved / measured_runtime
    util    = eff_bw / peak_hbm_bw

The naive variant's redundant traffic cannot be modeled reliably without
counters (cache behaviour is unobservable), so — as in the paper — it
reports ``None`` ("N/A") rather than a misleading number.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.analysis.hw import HardwareModel
from repro.analysis.traffic import TrafficEstimate


@dataclasses.dataclass(frozen=True)
class BandwidthEstimate:
    variant: str
    path: str
    runtime_s: float
    bytes_moved: Optional[float]
    eff_bw: Optional[float]          # bytes/s; None == paper's "N/A"
    peak_util: Optional[float]
    gflops: float
    arithmetic_intensity: Optional[float]


def effective_bandwidth(
    variant: str,
    path: str,
    est: TrafficEstimate,
    runtime_s: float,
    hw: HardwareModel,
) -> BandwidthEstimate:
    if not est.reliable:
        return BandwidthEstimate(
            variant, path, runtime_s, None, None, None,
            gflops=est.flops / runtime_s / 1e9,
            arithmetic_intensity=None,
        )
    bw = est.bytes_moved / runtime_s
    return BandwidthEstimate(
        variant,
        path,
        runtime_s,
        est.bytes_moved,
        bw,
        bw / hw.hbm_bw,
        gflops=est.flops / runtime_s / 1e9,
        arithmetic_intensity=est.arithmetic_intensity,
    )
