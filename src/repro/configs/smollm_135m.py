"""smollm-135m [dense]: 30L, d=576, 9H (GQA kv=3), ff=1536, vocab=49152,
llama-arch small, tied embeddings.  [hf:HuggingFaceTB/SmolLM-135M]"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv=3,
    d_ff=1536,
    vocab=49152,
    head_dim=64,
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=48, n_heads=3, n_kv=1, d_ff=96, vocab=256,
    head_dim=16, compute_dtype="float32",
)
