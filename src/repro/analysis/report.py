"""Markdown/CSV emitters for the counter-free analysis workflow."""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence

from repro.analysis.roofline import RooflineReport


def fmt_si(x: Optional[float], unit: str = "") -> str:
    if x is None:
        return "N/A"
    ax = abs(x)
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if ax >= div:
            return f"{x / div:.2f}{suf}{unit}"
    return f"{x:.3g}{unit}"


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    if x >= 1e-6:
        return f"{x * 1e6:.1f}us"
    return f"{x * 1e9:.1f}ns"


def markdown_table(headers: Sequence[str], rows: Iterable[Sequence[str]]) -> str:
    lines = ["| " + " | ".join(headers) + " |", "|" + "|".join(["---"] * len(headers)) + "|"]
    for row in rows:
        lines.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(lines)


def roofline_markdown(reports: List[RooflineReport]) -> str:
    headers = [
        "cell", "chips", "compute", "memory", "collective", "dominant",
        "bound step", "MODEL/HLO flops", "roofline frac", "peak mem/dev",
    ]
    rows = []
    for r in reports:
        rows.append(
            [
                r.label,
                r.chips,
                fmt_s(r.compute_s),
                fmt_s(r.memory_s),
                fmt_s(r.collective_s),
                r.dominant,
                fmt_s(r.step_time_overlap_s),
                f"{r.useful_flops_ratio:.3f}",
                f"{r.roofline_fraction:.3f}",
                fmt_si(r.peak_memory_per_device, "B"),
            ]
        )
    return markdown_table(headers, rows)


def csv_line(fields: Sequence) -> str:
    return ",".join(str(f) for f in fields)


def dump_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=str)
