"""Distributed step-function builders.

``build_train_step`` produces a pjit-ready ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` with:

  * microbatch gradient accumulation (lax.scan) — bounds activation memory
    for the 4k x 256 training cells and gives XLA windows to overlap the
    per-microbatch gradient reduce-scatters with the next microbatch's
    compute;
  * optional gradient compression: accumulating/reducing grads in bf16
    halves cross-pod all-reduce bytes (the `pod` axis rides DCN);
  * sharding via the logical-rule system — model code carries constraints,
    in/out shardings come from the trees built here.

``build_serve_step`` wraps a model's decode_step; KV-cache sharding
(sequence over `model`, and over `data` too for single-sequence
long-context) makes GSPMD derive the flash-decoding partial-softmax
combine automatically.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.api import Model
from repro.train.optim import Optimizer, global_norm


def build_train_step(
    model: Model,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
    grad_dtype: Optional[str] = None,   # "bfloat16" -> compressed reduction
) -> Callable:
    acc_dt = {None: jnp.float32, "float32": jnp.float32, "bfloat16": jnp.bfloat16}[grad_dtype]

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(acc_dt), grads)
        else:
            split = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]),
                batch,
            )

            def acc_body(carry, mb):
                closs, cgrads = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                cgrads = jax.tree.map(lambda a, g: a + g.astype(acc_dt), cgrads, grads)
                return (closs + loss, cgrads), ()

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.zeros((), jnp.float32), zeros), split)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        gnorm = global_norm(grads)
        new_params, new_opt = optimizer.update(grads, params, opt_state)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step


def build_serve_step(model: Model) -> Callable:
    def serve_step(params, cache, batch):
        logits, new_cache = model.decode_step(params, cache, batch)
        # greedy next-token (serving returns tokens, not logits, to the host)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return serve_step


def build_prefill_step(model: Model) -> Callable:
    mod = model.module

    def prefill_step(params, batch):
        if hasattr(mod, "prefill"):
            return mod.prefill(params, model.cfg, batch["tokens"])
        raise NotImplementedError(model.cfg.family)

    return prefill_step


# ---------------------------------------------------------------------------
# sharding trees for jit in/out specs
# ---------------------------------------------------------------------------


def _axes_leaf(t):
    return isinstance(t, tuple) and all(isinstance(a, (str, type(None))) for a in t)


def params_shardings(model: Model, mesh, rules):
    axes = model.param_axes()
    shapes = model.init_shapes()
    return jax.tree.map(
        lambda a, s: shd.spec_for_axes(a, mesh, rules, s.shape),
        axes, shapes, is_leaf=_axes_leaf,
    )


def opt_state_shardings(model: Model, optimizer: Optimizer, mesh, rules):
    """Optimizer-state tree mirrors the param tree (plus scalars)."""
    p_shard = params_shardings(model, mesh, rules)
    shapes = model.init_shapes()
    state_shape = jax.eval_shape(optimizer.init, shapes)

    def build(path_tree):
        # replace every param-shaped leaf with its param sharding; scalars
        # (step counters) are replicated.
        def walk(st):
            if isinstance(st, dict):
                out = {}
                for k, v in st.items():
                    if k in ("mu", "m", "v"):
                        out[k] = p_shard
                    elif k == "step":
                        out[k] = shd.spec_for_axes((), mesh, rules, ())
                    else:
                        out[k] = walk(v)
                return out
            return st

        return walk(path_tree)

    return build(state_shape)


def batch_shardings(batch_axes: Dict[str, tuple], batch_spec, mesh, rules):
    return {
        k: shd.spec_for_axes(batch_axes[k], mesh, rules, batch_spec[k].shape)
        for k in batch_spec
    }


def cache_shardings(model: Model, mesh, rules, cache_shapes):
    axes = model.cache_axes()
    return jax.tree.map(
        lambda a, s: shd.spec_for_axes(a, mesh, rules, s.shape),
        axes, cache_shapes, is_leaf=_axes_leaf,
    )
