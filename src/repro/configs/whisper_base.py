"""whisper-base [audio]: 6L enc + 6L dec, d=512, 8H MHA, ff=2048,
vocab=51865.  Enc-dec with stub conv frontend.  [arXiv:2212.04356]"""
import dataclasses

from repro.models.config import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,                 # decoder layers
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_ff=2048,
    vocab=51865,
    head_dim=64,
    norm="layer",
    act="gelu",
    encdec=EncDecConfig(n_enc_layers=6, enc_frames=1500),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=128,
    head_dim=16, encdec=EncDecConfig(n_enc_layers=2, enc_frames=32, max_positions=128),
    compute_dtype="float32",
)
