"""End-to-end S4ConvD training benchmark (paper §V-B1 analogue).

Measures steady-state epoch time (warm-up excluded) for a reduced S4ConvD
workload under the XLA production path, and reports the kernel-level vs
end-to-end decomposition the paper highlights: kernel speedups translate
sublinearly because non-conv components (projections, optimizer, framework)
take a growing runtime share.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core import s4convd
from repro.data.gep3 import GEP3Config
from repro.train.s4_trainer import train


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def run(fast: bool = False, variant: str = "xla") -> List[Row]:
    cfg = s4convd.S4ConvDConfig(H=64, N=8, n_blocks=2, L=48, K=48)
    data = GEP3Config(n_buildings=16, n_hours=400 if fast else 800)
    res = train(
        cfg, data, batch_size=256, epochs=2 if fast else 3,
        max_steps_per_epoch=8 if fast else 20,
        conv_variant=variant,
    )
    rows = [
        Row(f"s4convd_e2e/{variant}/steady_epoch", res.steady_epoch_time_s * 1e6,
            f"loss_first={res.epoch_losses[0]:.4f} loss_last={res.epoch_losses[-1]:.4f} "
            f"dev_rmsle={res.dev_rmsle:.4f}"),
    ]
    assert res.epoch_losses[-1] < res.epoch_losses[0], "training must converge"
    rows.append(Row(f"s4convd_e2e/{variant}/convergence", 0.0, "loss decreases REPRODUCED"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="xla",
                    choices=["xla", "row", "block", "lane", "naive", "auto"],
                    help='"auto" trains on the tuning cache\'s per-shape winner')
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    for r in run(fast=args.fast, variant=args.variant):
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
