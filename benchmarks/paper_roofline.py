"""Paper Fig. 10 analogue: roofline placement of every (variant x path) point.

Counter-free construction (paper §III-G): FLOPs from eqs. (2)-(3), bytes from
the analytical traffic model, runtimes from the paper's Table II, roofs from
the P100 datasheet (732 GB/s, 10.6 TFLOP/s fp32).  The reproduction target
is the paper's qualitative result: *every* variant/path stays in the
memory-bound regime, with shared/warp shifted up and slightly right.
"""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.paper_constants import PAPER_DIMS, TABLE2_MS
from repro.analysis.hw import P100
from repro.analysis.traffic import paper_bwdk_traffic, paper_fwd_traffic, path_flops


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def run(fast: bool = False) -> List[Row]:
    rows: List[Row] = []
    knee = P100.roofline_knee()
    flops = path_flops(PAPER_DIMS)
    for variant, (fwd_ms, bin_ms, bk_ms, _, _) in TABLE2_MS.items():
        for path, ms in (("fwd", fwd_ms), ("bwd_in", bin_ms), ("bwd_k", bk_ms)):
            est = (paper_bwdk_traffic if path == "bwd_k" else paper_fwd_traffic)(PAPER_DIMS, variant)
            gflops = flops / (ms / 1e3) / 1e9
            if est.reliable:
                ai = est.arithmetic_intensity
                mem_roof_gflops = ai * P100.hbm_bw / 1e9
                regime = "memory-bound" if ai < knee else "compute-bound"
                assert regime == "memory-bound", (variant, path, ai)
                assert gflops < P100.peak_flops / 1e9, "must stay below compute roof"
                rows.append(Row(
                    f"paper_roofline/{variant}/{path}", ms * 1e3,
                    f"AI={ai:.2f}FLOP/B achieved={gflops:.0f}GFLOP/s "
                    f"roof@AI={mem_roof_gflops:.0f}GFLOP/s {regime}",
                ))
            else:
                rows.append(Row(
                    f"paper_roofline/{variant}/{path}", ms * 1e3,
                    f"achieved={gflops:.0f}GFLOP/s AI=N/A (naive proxy) memory-bound",
                ))
    rows.append(Row("paper_roofline/summary", 0.0,
                    f"knee={knee:.1f}FLOP/B all points memory-bound REPRODUCED"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
