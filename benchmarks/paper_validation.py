"""Paper Appendix A analogue: numerical validation of the best (row/warp)
kernel against the reference across problem sizes.

Reproduced behaviours: forward and input-gradient errors at the f32
precision floor across all sizes; weight-gradient error grows with
accumulation depth (B x L) but stays at ~1e-6 relative error.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import dwconv as dw
from repro.kernels import ops, ref


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


SIZES = [
    # (B, H, L, K) — small shapes with varied K, then growing accumulation depth
    (4, 16, 32, 3),
    (8, 32, 48, 9),
    (16, 64, 48, 17),
    (64, 128, 48, 48),
    (256, 128, 48, 48),
]


def run(fast: bool = False) -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    opts = ops.KernelOptions(batch_chunk=32)
    prev_dk_err = 0.0
    sizes = SIZES[:3] if fast else SIZES
    for B, H, L, K in sizes:
        x = jnp.asarray(rng.normal(size=(B, H, L)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
        dy = jnp.asarray(rng.normal(size=(B, H, L)), jnp.float32)
        fwd_err = float(jnp.max(jnp.abs(
            dw.run_fwd(x, k, "same", "row", opts) - ref.dwconv_fwd_ref(x, k))))
        bin_err = float(jnp.max(jnp.abs(
            dw.run_bwd_input(dy, k, "same", "row", opts) - ref.dwconv_bwd_input_ref(dy, k))))
        dk_got = dw.run_bwd_kernel(x, dy, K, "same", "row", opts)
        dk_ref = ref.dwconv_bwd_kernel_ref(x, dy, K)
        dk_err = float(jnp.max(jnp.abs(dk_got - dk_ref)))
        dk_rel = dk_err / float(jnp.max(jnp.abs(dk_ref)))
        assert fwd_err < 1e-4 and bin_err < 1e-4, (fwd_err, bin_err)
        assert dk_rel < 1e-4, dk_rel
        rows.append(Row(
            f"paper_validation/B{B}_H{H}_L{L}_K{K}", 0.0,
            f"fwd_err={fwd_err:.2e} bwd_in_err={bin_err:.2e} "
            f"dk_err={dk_err:.2e} dk_rel={dk_rel:.2e}",
        ))
        prev_dk_err = dk_err
    rows.append(Row("paper_validation/summary", 0.0,
                    "fwd/bwd_in at precision floor; dk rel-err ~1e-6 scale REPRODUCED"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
