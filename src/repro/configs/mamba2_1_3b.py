"""mamba2-1.3b [ssm]: 48L, d=2048, attention-free SSD, ssm_state=128,
vocab padded to 50288 (multiple of 16).  [arXiv:2405.21060]

The depthwise causal conv1d inside every block routes through the paper's
kernel (``ssm.conv_variant``)."""
import dataclasses

from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,               # SSD heads = d_inner / head_dim
    n_kv=64,
    d_ff=0,                   # attention/MLP-free
    vocab=50288,
    head_dim=64,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256,
                  conv_variant="xla"),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, vocab=256,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=8,
                  conv_variant="xla"),
    compute_dtype="float32",
)

# Same reduced config but running the paper's Pallas row-tiled kernel in the
# conv — exercised by the smoke tests to prove the integration.
SMOKE_PALLAS = dataclasses.replace(
    SMOKE,
    ssm=dataclasses.replace(SMOKE.ssm, conv_variant="row"),
)
