"""Time-tiled weight-gradient validation (the long-sequence regime).

The ``block_t`` time tiling in ``kernels/dwconv_bwdk.py`` /
``dwconv_bwd_fused.py`` bounds the per-cell VMEM working set for long
sequences.  These tests pin down:

  * correctness of every tiled bwdk / fused variant against ``jax.vjp`` of
    the reference on ragged L spanning multiple tiles with non-divisible
    tails (Lout not a multiple of block_t);
  * bitwise agreement of the tiled ``accum`` variant with the untiled one
    on integer-valued data (every partial sum is exact in f32, so any
    seam/halo indexing slip shows up as a hard mismatch, not a tolerance);
  * bitwise agreement of tiled fused dk with tiled accum dk (the fused
    kernels compute dk from identically shaped slabs);
  * tiled VMEM working sets that are bounded by block_t (independent of L)
    and legal where the untiled estimate grows with L;
  * the tiled traffic model charging exactly the per-seam halo re-read.

Shapes are kept small — the tiling logic is exercised by the tile *count*,
not the absolute length; ``benchmarks/paper_longseq.py`` runs the real
``L=16384`` shape.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import traffic
from repro.core import dwconv as dw
from repro.kernels import ops, ref
from repro.kernels.common import LANE, DWConvDims, cdiv, round_up
from repro.tuning import space
from repro.tuning.space import Candidate

# (B, H, L, K, padding, block_t): every case spans >= 2 tiles; most have a
# non-divisible tail (Lout % block_t != 0) so the zero-padded tile and the
# trailing halo tile are both exercised.
TILED_SHAPES = [
    (2, 4, 300, 5, "same", 128),     # Lout=384, 3 tiles, exact
    (1, 3, 520, 4, "causal", 256),   # Lout=640, 3 tiles, tail 128
    (2, 2, 700, 9, "same", 128),     # Lout=768, 6 tiles, tail 68 inside L
    (3, 5, 130, 48, "same", 128),    # K-1=47 close to the tile, Lout=256
    (2, 4, 300, 6, "causal", 128),   # even K causal: off_dk=0 edge
]
BWDK_TILED = ["accum", "twostage"]
FUSED_TILED = ["fused", "fused_partials"]


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


def _randint(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-4, 5, size=shape), jnp.float32)


def _vjp_ref(x, k, dy, pad):
    _, vjp = jax.vjp(lambda x, k: ref.dwconv_fwd_ref(x, k, pad), x, k)
    return vjp(dy)


def _opts(block_t):
    return ops.KernelOptions(block_h=3, block_t=block_t, batch_chunk=2)


# ---------------------------------------------------------------------------
# tiled correctness vs jax.vjp
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", BWDK_TILED)
@pytest.mark.parametrize("B,H,L,K,pad,bt", TILED_SHAPES)
def test_tiled_bwdk_matches_vjp(variant, B, H, L, K, pad, bt):
    assert ops.bwdk_time_tile(L, K, bt, variant) is not None, "case must tile"
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    dy = _rand((B, H, L), jnp.float32, 2)
    _, dk_want = _vjp_ref(x, k, dy, pad)
    dk = ops.dwconv_bwd_kernel_op(x, dy, K, pad, variant, _opts(bt))
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dk_want),
                               atol=2e-3, rtol=1e-4)


@pytest.mark.parametrize("variant", FUSED_TILED)
@pytest.mark.parametrize("B,H,L,K,pad,bt", TILED_SHAPES)
def test_tiled_fused_matches_vjp(variant, B, H, L, K, pad, bt):
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    dy = _rand((B, H, L), jnp.float32, 2)
    dx_want, dk_want = _vjp_ref(x, k, dy, pad)
    dx, dk = ops.dwconv_bwd_fused_op(x, dy, k, pad, variant, _opts(bt))
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_want),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dk_want),
                               atol=2e-3, rtol=1e-4)


@pytest.mark.parametrize("B,H,L,K,pad,bt", TILED_SHAPES[:2])
def test_tiled_custom_vjp_matches_autodiff(B, H, L, K, pad, bt):
    """The differentiable operator with a tiled fused backward."""
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)

    def loss_custom(x, k):
        return jnp.sum(jnp.sin(dw.dwconv(x, k, padding=pad, variant="fused",
                                         opts=_opts(bt))))

    def loss_ref(x, k):
        return jnp.sum(jnp.sin(ref.dwconv_fwd_ref(x, k, pad)))

    gx, gk = jax.grad(loss_custom, argnums=(0, 1))(x, k)
    rx, rk = jax.grad(loss_ref, argnums=(0, 1))(x, k)
    np.testing.assert_allclose(gx, rx, atol=1e-4)
    np.testing.assert_allclose(gk, rk, atol=1e-3)


# ---------------------------------------------------------------------------
# bitwise pins: seam/halo indexing errors must be hard failures
# ---------------------------------------------------------------------------


def test_tiled_accum_bitwise_matches_untiled_on_integers():
    """Integer-valued data keeps every f32 partial sum exact, so the tiled
    accumulation must reproduce the untiled dk bit for bit — any halo or
    seam slip changes the integers."""
    B, H, L, K = 2, 4, 300, 5
    x = _randint((B, H, L), 0)
    dy = _randint((B, H, L), 1)
    tiled = ops.dwconv_bwd_kernel_op(x, dy, K, "same", "accum", _opts(128))
    untiled = ops.dwconv_bwd_kernel_op(x, dy, K, "same", "accum", _opts(4096))
    assert ops.bwdk_time_tile(L, K, 128, "accum") is not None
    assert ops.bwdk_time_tile(L, K, 4096, "accum") is None
    assert np.array_equal(np.asarray(tiled), np.asarray(untiled))


@pytest.mark.parametrize("B,H,L,K,pad,bt", TILED_SHAPES[:3])
def test_tiled_fused_dk_bitwise_matches_tiled_accum(B, H, L, K, pad, bt):
    """Tiled fused dk is computed from identically shaped slabs as the tiled
    accum variant — bit-for-bit, like the untiled pair."""
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    dy = _rand((B, H, L), jnp.float32, 2)
    _, dk_fused = ops.dwconv_bwd_fused_op(x, dy, k, pad, "fused", _opts(bt))
    dk_accum = ops.dwconv_bwd_kernel_op(x, dy, K, pad, "accum", _opts(bt))
    assert np.asarray(dk_fused).tobytes() == np.asarray(dk_accum).tobytes()


# ---------------------------------------------------------------------------
# legality: the tiled working set is bounded by block_t, not L
# ---------------------------------------------------------------------------


LONG_DIMS = DWConvDims(B=8, H=64, L=16384, K=4)


@pytest.mark.parametrize("path,variant", [("bwd_k", "accum"),
                                          ("bwd_k", "twostage"),
                                          ("bwd_fused", "fused"),
                                          ("bwd_fused", "fused_partials")])
def test_tiled_vmem_working_set_is_L_independent(path, variant):
    d, d2 = LONG_DIMS, dataclasses.replace(LONG_DIMS, L=4 * LONG_DIMS.L)
    c = space.normalize(Candidate(path=path, variant=variant, block_h=8,
                                  block_t=512, batch_chunk=8), d)
    need = space._vmem_working_set_bytes(c, d, itemsize=4)
    c2 = space.normalize(dataclasses.replace(c), d2)
    need2 = space._vmem_working_set_bytes(c2, d2, itemsize=4)
    assert need == need2, "tiled footprint must not grow with L"
    ok, reason = space.is_legal(c, d)
    assert ok, reason


def test_long_L_search_space_contains_tiled_candidates():
    """The predicates must pass tiled candidates for long L — the space is
    not pruned to the reference/naive escape hatches."""
    for path in ("bwd_k", "bwd_fused"):
        cands = space.search_space(LONG_DIMS, path, include_xla=False)
        Lout = round_up(LONG_DIMS.L, LANE)
        tiled = [c for c in cands if c.variant not in ("naive", "split")
                 and c.block_t < Lout]
        assert tiled, f"no tiled candidates survived for {path}"


# ---------------------------------------------------------------------------
# tiled traffic model: exactly the per-seam halo re-read is charged
# ---------------------------------------------------------------------------


def test_tiled_traffic_charges_halo_only():
    d = LONG_DIMS
    bt = 512
    nT = cdiv(round_up(d.L, LANE), bt)
    tiled = traffic.bwdk_traffic(d, "accum", block_t=bt)
    untiled = traffic.bwdk_traffic(d, "accum", block_t=d.L)
    halo = d.B * d.H * (nT - 1) * (d.K - 1) * 4
    assert tiled.bytes_moved - untiled.bytes_moved == halo
    assert tiled.bytes_moved <= 1.10 * untiled.bytes_moved

    f_tiled = traffic.bwd_fused_traffic(d, "fused", block_t=bt)
    f_untiled = traffic.bwd_fused_traffic(d, "fused", block_t=d.L)
    assert f_tiled.bytes_moved - f_untiled.bytes_moved == 2 * halo
