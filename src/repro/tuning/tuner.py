"""Search drivers: analytical pre-rank + metered grid / greedy hillclimb.

``tune_path`` optimizes one execution path for one problem shape:

  1. enumerate the legal candidate space (``space.search_space``);
  2. rank every candidate with the analytical traffic/roofline model
     (``cost.rank_candidates``) — no execution;
  3. spend the measurement *budget* only on the analytical front-runners —
     with one slot always reserved for the *fallback baseline* (the
     ``AUTO_FALLBACK`` configuration ``variant="auto"`` uses on a cache
     miss), so the persisted winner is never slower than what an untuned
     dispatch would have run:
       * ``grid``      — measure the baseline + top candidates up to budget;
       * ``hillclimb`` — measure the baseline and the analytical best, then
         walk single-knob neighbour moves (``space.neighbors``), accepting
         improvements, until the budget is exhausted or a local optimum is
         reached;
  4. write the winner into the persistent tuning cache, where
     ``variant="auto"`` dispatch (``kernels/ops.py``) picks it up.

This is the TVM-style analytical-model-guided empirical search, built
entirely from the paper's counter-free measurement apparatus.
"""
from __future__ import annotations

import dataclasses
import math
import sys
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis.hw import TPU_V5E, HardwareModel
from repro.kernels.common import DWConvDims
from repro.obs import trace as obs_trace
from repro.resilience import faults, guard
from repro.tuning import cost, space
from repro.tuning.cache import ShapeKey, TuneEntry, TuningCache, default_cache
from repro.tuning.space import Candidate

MeasureFn = Callable[[Candidate, DWConvDims], float]


@dataclasses.dataclass
class TuneResult:
    key: ShapeKey
    best: TuneEntry
    candidates_considered: int
    candidates_measured: int
    # (candidate, analytical_s, measured_s) for every metered candidate
    history: List[Tuple[Candidate, float, float]]

    @property
    def best_candidate(self) -> Candidate:
        return Candidate(
            path=self.key.path,
            variant=self.best.variant,
            block_h=self.best.block_h,
            block_t=self.best.block_t,
            batch_chunk=self.best.batch_chunk,
        )


def fallback_candidate(d: DWConvDims, path: str) -> Candidate:
    """The configuration ``variant="auto"`` runs on a cache miss — always
    metered so tuning can only ever improve on untuned dispatch."""
    from repro.kernels.ops import AUTO_FALLBACK, DEFAULT_OPTS

    return space.normalize(
        Candidate(path=path, variant=AUTO_FALLBACK[path],
                  block_h=DEFAULT_OPTS.block_h, block_t=DEFAULT_OPTS.block_t,
                  batch_chunk=DEFAULT_OPTS.batch_chunk), d)


def _make_key(d: DWConvDims, path: str, dtype: str, backend: Optional[str],
              epilogue: str = "none") -> ShapeKey:
    return ShapeKey(
        path=path, B=d.B, H=d.H, L=d.L, K=d.K, dtype=dtype,
        backend=backend if backend is not None else jax.default_backend(),
        padding=d.padding, epilogue=epilogue,
    )


def tune_path(
    d: DWConvDims,
    path: str,
    *,
    dtype: str = "float32",
    backend: Optional[str] = None,
    budget: int = 20,
    search: str = "grid",
    variants: Optional[Sequence[str]] = None,
    hw: HardwareModel = TPU_V5E,
    itemsize: Optional[int] = None,
    measure_fn: Optional[MeasureFn] = None,
    warmup: int = 1,
    iters: int = 3,
    cache: Optional[TuningCache] = None,
    persist: bool = True,
    verbose: bool = False,
    epilogue: str = "none",
) -> TuneResult:
    """Tune one (shape, path) and record the winner in the cache.

    ``itemsize`` defaults to the *measured* ``dtype``'s width (the one
    charging convention, ``perfmodel.dtype_itemsize``), so the stage-1
    analytical ranking and the stage-2 measurement always price bytes in
    the same currency; pass it explicitly only to model a different one.
    """
    if budget < 1:
        raise ValueError(f"budget must be >= 1, got {budget}")
    if itemsize is None:
        from repro.perfmodel import dtype_itemsize

        itemsize = dtype_itemsize(dtype)
    if epilogue != "none" and path not in ("fwd", "bwd_fused"):
        raise ValueError(
            f"epilogue {epilogue!r} only parameterizes the 'fwd'/'bwd_fused' "
            f"paths, not {path!r}")
    if measure_fn is None:
        def measure_fn(c: Candidate, dd: DWConvDims) -> float:
            return cost.measure_candidate(
                c, dd, dtype=dtype, warmup=warmup, iters=iters,
                epilogue=epilogue)

    cands = space.search_space(d, path, variants=variants, itemsize=itemsize,
                               hw=hw, epilogue=epilogue)
    ranked = cost.rank_candidates(cands, d, itemsize=itemsize, hw=hw,
                                  epilogue=epilogue)
    analytical: Dict[Candidate, float] = dict(ranked)

    # A quarantined previous decision (guarded dispatch caught it failing to
    # execute — see repro.resilience.guard) is banned from this search: its
    # exact configuration prices as +inf, so re-tuning can never re-elect
    # the broken config, and the fresh winner overwrites the quarantine.
    key = _make_key(d, path, dtype, backend, epilogue)
    the_cache = cache if cache is not None else default_cache()
    prev = the_cache.get(key)
    banned: Optional[Candidate] = None
    if prev is not None and prev.quarantined:
        banned = space.normalize(
            Candidate(path=path, variant=prev.variant, block_h=prev.block_h,
                      block_t=prev.block_t, batch_chunk=prev.batch_chunk), d)
        guard.record_degradation(
            "tuner/banned-candidate", key=key.encode(), variant=prev.variant,
            reason=prev.quarantine_reason)

    measured: Dict[Candidate, float] = {}
    tracer = obs_trace.get_tracer()

    def meter(c: Candidate) -> float:
        if c not in measured:
            if banned is not None and c == banned:
                measured[c] = float("inf")
                return measured[c]
            with tracer.span("tune/candidate", path=c.path, variant=c.variant,
                             block_h=c.block_h, block_t=c.block_t,
                             batch_chunk=c.batch_chunk) as sp:
                try:
                    t = measure_fn(c, d)
                except guard.guardable_exceptions() as e:
                    # A candidate that cannot execute loses, it does not
                    # abort the search over every other candidate.
                    t = float("inf")
                    guard.record_degradation(
                        "tuner/measure-failed", path=c.path, variant=c.variant,
                        block_h=c.block_h, block_t=c.block_t,
                        batch_chunk=c.batch_chunk,
                        error=f"{type(e).__name__}: {e}")
                if faults.should_fire("tuner/slow-candidate") and math.isfinite(t):
                    t *= 1000.0  # injected straggler: a pathological config
                measured[c] = t
                sp.tag(measured_s=t, analytical_s=analytical.get(c))
                if tracer.enabled and math.isfinite(t):
                    # each candidate's schedule rides along, so the tuning
                    # trace shows modeled bytes / effective bandwidth per try
                    sp.attach("kernel", space._schedule(c, d, itemsize, epilogue),
                              hw=hw, runtime_s=t)
            if verbose:
                print(f"  [tune] {c.path}/{c.variant} bh={c.block_h} bt={c.block_t} "
                      f"bc={c.batch_chunk}: {measured[c] * 1e6:.1f}us "
                      f"(analytical {analytical.get(c, float('nan')) * 1e6:.1f}us)",
                      flush=True)
        return measured[c]

    # The baseline is metered first (within budget): the persisted winner
    # can then never regress what an untuned variant="auto" would run.
    meter(fallback_candidate(d, path))

    # Fleet advisory seeding: a foreign-fingerprint bundle import
    # (repro.fleet.import_) may hint a configuration for this key.  The hint
    # is metered right after the baseline — seeding the stage-2 candidate
    # order with another device's winner — but it competes on *this*
    # device's measurements like every other candidate: advisory entries
    # never bypass measurement.  The probe is a sys.modules lookup so the
    # tuner stays fleet-free unless the fleet layer actually ran.
    fleet = sys.modules.get("repro.fleet.import_")
    hint_entry = fleet.advisory_entry(key.encode()) if fleet is not None else None
    if hint_entry is not None and len(measured) < budget:
        try:
            hint = space.normalize(
                Candidate(path=path, variant=hint_entry.variant,
                          block_h=hint_entry.block_h,
                          block_t=hint_entry.block_t,
                          batch_chunk=hint_entry.batch_chunk), d)
            legal, _ = space.is_legal(hint, d, itemsize=itemsize, hw=hw,
                                      epilogue=epilogue)
        except (KeyError, ValueError):
            legal, hint = False, None  # foreign variant this build lacks
        if legal and (banned is None or hint != banned):
            if hint not in analytical:
                try:
                    analytical[hint] = cost.analytical_time_s(
                        hint, d, itemsize=itemsize, hw=hw, epilogue=epilogue)
                except (KeyError, ValueError):
                    pass
            meter(hint)

    if search == "grid":
        for c, _ in ranked:
            if len(measured) >= budget:
                break
            meter(c)
    elif search == "hillclimb":
        cur = ranked[0][0]
        if len(measured) < budget:
            meter(cur)
        if cur not in measured:  # budget=1: the baseline is the answer
            cur = next(iter(measured))
        improved = True
        while improved and len(measured) < budget:
            improved = False
            moves = space.neighbors(cur, d, itemsize=itemsize, hw=hw,
                                    epilogue=epilogue)
            # visit neighbours in analytical order: best-looking moves first
            moves.sort(key=lambda m: analytical.get(
                m, cost.analytical_time_s(m, d, itemsize=itemsize, hw=hw,
                                          epilogue=epilogue)))
            for m in moves:
                if len(measured) >= budget:
                    break
                if meter(m) < measured[cur]:
                    cur = m
                    improved = True
                    break  # greedy: restart the walk from the new optimum
    else:
        raise ValueError(f"unknown search {search!r}; use 'grid' or 'hillclimb'")

    best_c = min(measured, key=measured.get)
    entry = TuneEntry(
        variant=best_c.variant,
        block_h=best_c.block_h,
        block_t=best_c.block_t,
        batch_chunk=best_c.batch_chunk,
        time_us=measured[best_c] * 1e6,
        analytical_time_us=analytical.get(best_c, 0.0) * 1e6,
        source="measured",
    )
    # put() writes a fresh (quarantined=False) entry: re-tuning a
    # quarantined key clears the quarantine with a decision that measured.
    the_cache.put(key, entry, persist=persist)
    history = [(c, analytical.get(c, 0.0), t) for c, t in measured.items()]
    history.sort(key=lambda h: h[2])
    return TuneResult(
        key=key,
        best=entry,
        candidates_considered=len(cands),
        candidates_measured=len(measured),
        history=history,
    )


def tune_shape(
    d: DWConvDims,
    *,
    paths: Sequence[str] = space.PATHS,
    budget: int = 20,
    epilogue: str = "none",
    **kw,
) -> Dict[str, TuneResult]:
    """Tune every execution path of one shape; budget is split across paths.

    ``epilogue`` applies to the paths it parameterizes ('fwd', 'bwd_fused');
    the split reductions ('bwd_in', 'bwd_k') consume the effective gradient
    unchanged and always tune epilogue-less."""
    per_path = max(1, budget // max(len(paths), 1))
    return {p: tune_path(
        d, p, budget=per_path,
        epilogue=epilogue if p in ("fwd", "bwd_fused") else "none",
        **kw) for p in paths}
