"""Fault-tolerant checkpointing.

Design goals (assignment: checkpoint/restart, node failures, elastic):

  * **atomic AND durable**: write to ``step_<n>.tmp/`` then rename, with
    every payload file *and* the directories fsynced before the publish —
    a crash mid-save never corrupts the latest checkpoint, and a published
    checkpoint cannot be hollowed out by a post-rename power loss;
  * **validated restore**: ``restore()`` cross-checks the manifest against
    the on-disk ``.npz`` payloads; when the latest checkpoint is corrupt it
    falls back to the previous step (with a recorded degradation) instead
    of crashing the restart loop — an explicitly requested step still
    raises :class:`~repro.resilience.faults.CheckpointIOError`;
  * **retrying save**: one transient ``OSError`` per save is retried once
    (recorded as a degradation) before surfacing;
  * **mesh-independent**: arrays are saved as host numpy with their logical
    param paths; a restart may load onto a *different* mesh/device count
    (elastic re-mesh) because shardings are re-derived from the rule table
    at load time, not stored;
  * **complete**: params + optimizer state + data-iterator state + step +
    RNG key, so restarts are bit-exact continuations;
  * **async**: ``save_async`` hands the host copy to a writer thread so the
    training loop is not blocked by filesystem latency;
  * **keep-N** garbage collection.

Format: one ``.npz`` per pytree (flattened with ``/``-joined paths) + a JSON
manifest.  No external deps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zipfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.resilience import faults
from repro.resilience.guard import record_degradation


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}" if prefix else str(i)))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray], prefix: str = "") -> Any:
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}/{k}" if prefix else str(k))
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        seq = [
            _unflatten_into(v, flat, f"{prefix}/{i}" if prefix else str(i))
            for i, v in enumerate(template)
        ]
        return type(template)(seq) if isinstance(template, tuple) else seq
    return flat[prefix]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    @staticmethod
    def _fsync_dir(path: Path) -> None:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _write(self, step: int, trees: Dict[str, Any], extra: Dict[str, Any]):
        faults.fire("ckpt/write", faults.CheckpointIOError,
                    f"injected checkpoint write failure at step {step}")
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # fsync every payload before the rename publishes it: os.rename is
        # atomic in the namespace but says nothing about the *data* — on a
        # power loss a renamed-but-unsynced checkpoint can come back as the
        # latest step with hollow .npz files, which restore() would then
        # have to reject.  Durability belongs on the write side.
        for name, tree in trees.items():
            flat = _flatten(tree)
            with open(tmp / f"{name}.npz", "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
        with open(tmp / "manifest.json", "w") as f:
            json.dump({"step": step, "trees": sorted(trees), "extra": extra},
                      f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        self._fsync_dir(tmp)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._fsync_dir(self.dir)  # persist the rename itself
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    def save(self, step: int, *, params, opt_state=None, data_state=None,
             rng=None, extra: Optional[Dict] = None) -> None:
        trees = {"params": jax.device_get(params)}
        if opt_state is not None:
            trees["opt_state"] = jax.device_get(opt_state)
        meta = dict(extra or {})
        if data_state is not None:
            meta["data_state"] = data_state
        if rng is not None:
            meta["rng"] = np.asarray(jax.device_get(rng)).tolist()
        try:
            self._write(step, trees, meta)
        except OSError as e:
            # One transient I/O failure (full/flaky NFS, injected
            # ckpt/write) is retried before surfacing: losing a training
            # run to a single EIO is worse than one duplicate write.
            record_degradation("ckpt/write", step=step,
                               error=f"{type(e).__name__}: {e}",
                               action="retry once")
            self._write(step, trees, meta)

    def save_async(self, step: int, **kw) -> None:
        """Snapshot to host synchronously, write in a background thread."""
        self.wait()  # one in-flight save at a time
        kw = {k: (jax.device_get(v) if k in ("params", "opt_state", "rng") and v is not None else v)
              for k, v in kw.items()}

        def work():
            try:
                self.save(step, **kw)
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        # Non-daemon: an in-flight save must survive an orderly process exit
        # (sys.exit during the next step) — otherwise a checkpoint the loop
        # already considers taken is silently lost and restart re-does work.
        self._thread = threading.Thread(target=work, daemon=False)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _read_manifest(self, d: Path) -> Dict:
        """Manifest of checkpoint dir ``d``, cross-checked against the
        on-disk payloads; raises :class:`CheckpointIOError` on any gap."""
        try:
            manifest = json.loads((d / "manifest.json").read_text())
        except (OSError, json.JSONDecodeError) as e:
            raise faults.CheckpointIOError(
                f"{d.name}: unreadable manifest ({type(e).__name__}: {e})") from e
        for name in manifest.get("trees", []):
            npz = d / f"{name}.npz"
            if not npz.exists():
                raise faults.CheckpointIOError(
                    f"{d.name}: manifest lists {name!r} but {npz.name} is missing")
        return manifest

    def restore(
        self,
        step: Optional[int] = None,
        *,
        params_template,
        opt_state_template=None,
        shardings=None,
        opt_shardings=None,
    ) -> Tuple[int, Any, Any, Dict]:
        """Load a checkpoint.  ``shardings`` (same tree structure as params)
        re-places arrays for the *current* mesh — elastic re-mesh on load.

        With ``step=None`` a corrupt/incomplete latest checkpoint degrades
        to the previous step (recorded + warned) — the restart loop must
        never die to a half-written directory.  An explicit ``step`` is a
        statement of intent and raises :class:`CheckpointIOError` instead.
        """
        explicit = step is not None
        candidates = [step] if explicit else self.all_steps()[::-1]
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        last_exc: Optional[BaseException] = None
        for s in candidates:
            d = self.dir / f"step_{s:010d}"
            try:
                manifest = self._read_manifest(d)

                def load_tree(name, template, shard_tree):
                    with np.load(d / f"{name}.npz") as z:
                        flat = {k: z[k] for k in z.files}
                    tree = _unflatten_into(template, flat)
                    if shard_tree is not None:
                        tree = jax.tree.map(
                            lambda a, sh: jax.device_put(a, sh), tree, shard_tree)
                    return tree

                params = load_tree("params", params_template, shardings)
                opt_state = None
                if opt_state_template is not None and (d / "opt_state.npz").exists():
                    opt_state = load_tree("opt_state", opt_state_template,
                                          opt_shardings)
                return s, params, opt_state, manifest.get("extra", {})
            except (faults.CheckpointIOError, OSError, KeyError, ValueError,
                    zipfile.BadZipFile) as e:
                if explicit:
                    raise faults.CheckpointIOError(
                        f"requested checkpoint step {s} is unreadable: {e}") from e
                record_degradation("ckpt/restore", step=s,
                                   error=f"{type(e).__name__}: {e}",
                                   action="fall back to previous step")
                last_exc = e
        raise faults.CheckpointIOError(
            f"no readable checkpoint in {self.dir} "
            f"(tried steps {candidates})") from last_exc
