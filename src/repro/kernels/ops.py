"""jit-ready wrappers around the Pallas depthwise-conv kernels.

These handle everything the kernels assume away: zero-padding to the
convolution window, rounding every tiled dimension up to TPU-friendly
multiples (lanes of 128, h-blocks, batch-chunks), variant dispatch, and
slicing the outputs back to logical shapes.  They are the only supported
entry points to ``dwconv_fwd.py`` / ``dwconv_bwdk.py``.

``interpret=None`` auto-selects: compiled on TPU, interpret mode elsewhere
(this container is CPU-only, so tests/benches run the kernel bodies in
interpret mode — the validation regime prescribed for this build).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dwconv_bwdk, dwconv_fwd
from repro.kernels.common import LANE, Padding, adjoint_pad_widths, cdiv, pad_widths, round_up

FWD_VARIANTS = ("naive", "lane", "block", "row", "xla")
BWDK_VARIANTS = ("naive", "twostage", "accum", "xla")


@dataclasses.dataclass(frozen=True)
class KernelOptions:
    """Static tiling knobs (hashable: used as a custom_vjp nondiff arg)."""

    block_h: int = 8
    block_t: int = 512
    batch_chunk: int = 128
    interpret: Optional[bool] = None

    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"


DEFAULT_OPTS = KernelOptions()


def _pad_channels(a: jnp.ndarray, H: int, Hb: int, axis: int) -> jnp.ndarray:
    Hp = round_up(H, Hb)
    if Hp == H:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, Hp - H)
    return jnp.pad(a, widths)


def _pad_kernel_lanes(k: jnp.ndarray, K: int) -> jnp.ndarray:
    Kp = round_up(K, LANE)
    return jnp.pad(k, ((0, 0), (0, Kp - K))) if Kp > K else k


def _fwd_impl(
    x: jnp.ndarray,
    k: jnp.ndarray,
    p_left: int,
    variant: str,
    opts: KernelOptions,
) -> jnp.ndarray:
    B, H, L = x.shape
    _, K = k.shape
    interpret = opts.resolved_interpret()
    Hb = min(opts.block_h, H)
    Lout = round_up(L, LANE)
    Lt = min(opts.block_t, Lout)
    nT = cdiv(Lout, Lt)
    # One padded buffer wide enough for every variant's window reads.
    Wpad = max(
        round_up(Lout + K - 1, LANE),
        (nT + 1) * Lt,                       # block: neighbour halo tile
        nT * Lt + K - 1 + LANE,              # lane: widened aligned windows
    )
    Wpad = round_up(Wpad, LANE)
    xp = jnp.pad(x, ((0, 0), (0, 0), (p_left, Wpad - L - p_left)))
    xp = _pad_channels(xp, H, Hb, axis=1)
    kp = _pad_channels(_pad_kernel_lanes(k, K), H, Hb, axis=0)

    kw = dict(K=K, Lout=Lout, block_h=Hb, interpret=interpret)
    if variant == "row":
        y = dwconv_fwd.dwconv_fwd_row(xp, kp, **kw)
    elif variant == "block":
        y = dwconv_fwd.dwconv_fwd_block(xp, kp, block_t=Lt, **kw)
    elif variant == "naive":
        y = dwconv_fwd.dwconv_fwd_naive(xp, kp, block_t=Lt, **kw)
    elif variant == "lane":
        y = dwconv_fwd.dwconv_fwd_lane(xp, kp, block_t=Lt, **kw)
    else:
        raise ValueError(f"unknown fwd variant {variant!r}")
    return y[:, :H, :L]


def dwconv_fwd_op(
    x: jnp.ndarray,
    k: jnp.ndarray,
    padding: Padding = "same",
    variant: str = "row",
    opts: KernelOptions = DEFAULT_OPTS,
) -> jnp.ndarray:
    """y[b,h,t] = sum_j x_pad[b,h,t+j] k[h,j]."""
    p_left, _ = pad_widths(k.shape[-1], padding)
    return _fwd_impl(x, k, p_left, variant, opts)


def dwconv_bwd_input_op(
    dy: jnp.ndarray,
    k: jnp.ndarray,
    padding: Padding = "same",
    variant: str = "row",
    opts: KernelOptions = DEFAULT_OPTS,
) -> jnp.ndarray:
    """dx: flipped-filter correlation under adjoint padding (same kernels as
    the forward path — the structural symmetry the paper exploits)."""
    p_left, _ = adjoint_pad_widths(k.shape[-1], padding)
    return _fwd_impl(dy, k[:, ::-1], p_left, variant, opts)


def _bwdk_impl(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    K: int,
    padding: Padding,
    variant: str,
    opts: KernelOptions,
) -> jnp.ndarray:
    B, H, L = x.shape
    interpret = opts.resolved_interpret()
    Hb = min(opts.block_h, H)
    Bc = min(opts.batch_chunk, B)
    p_left, _ = pad_widths(K, padding)
    Lout = round_up(L, LANE)
    Wpad = round_up(Lout + K - 1, LANE)
    Bp = round_up(B, Bc)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0), (p_left, Wpad - L - p_left)))
    dyp = jnp.pad(dy, ((0, Bp - B), (0, 0), (0, Lout - L)))
    xp = _pad_channels(xp, H, Hb, axis=1)
    dyp = _pad_channels(dyp, H, Hb, axis=1)

    kw = dict(K=K, block_h=Hb, batch_chunk=Bc, interpret=interpret)
    if variant == "accum":
        dk = dwconv_bwdk.dwconv_bwdk_accum(xp, dyp, **kw)
    elif variant == "twostage":
        dk = dwconv_bwdk.dwconv_bwdk_twostage(xp, dyp, **kw)
    elif variant == "naive":
        dk = dwconv_bwdk.dwconv_bwdk_naive(xp, dyp, **kw)
    else:
        raise ValueError(f"unknown bwdk variant {variant!r}")
    return dk[:H]


def dwconv_bwd_kernel_op(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    K: int,
    padding: Padding = "same",
    variant: str = "accum",
    opts: KernelOptions = DEFAULT_OPTS,
) -> jnp.ndarray:
    """dk[h,j] = sum_{b,t} dy[b,h,t] x_pad[b,h,t+j].  Returns f32 (H, K)."""
    return _bwdk_impl(x, dy, K, padding, variant, opts)


@functools.partial(jax.jit, static_argnames=("padding", "variant", "opts"))
def dwconv_fwd_jit(x, k, padding="same", variant="row", opts=DEFAULT_OPTS):
    return dwconv_fwd_op(x, k, padding, variant, opts)


@functools.partial(jax.jit, static_argnames=("padding", "variant", "opts"))
def dwconv_bwd_input_jit(dy, k, padding="same", variant="row", opts=DEFAULT_OPTS):
    return dwconv_bwd_input_op(dy, k, padding, variant, opts)


@functools.partial(jax.jit, static_argnames=("K", "padding", "variant", "opts"))
def dwconv_bwd_kernel_jit(x, dy, K, padding="same", variant="accum", opts=DEFAULT_OPTS):
    return dwconv_bwd_kernel_op(x, dy, K, padding, variant, opts)
