"""Declarative search space for the depthwise-conv kernel autotuner.

A :class:`Candidate` names, for one execution path, the kernel implementation
variant plus the tiling knobs :class:`~repro.kernels.ops.KernelOptions`
understands.  Legality and the VMEM working set are *derived* from the
candidate's registered :class:`~repro.perfmodel.KernelSchedule`
(``repro.perfmodel``), whose verdicts mirror the asserts inside
``kernels/dwconv_fwd.py`` / ``kernels/dwconv_bwdk.py`` *after* the padding
``kernels/ops.py`` applies, so every candidate emitted by
:func:`search_space` is guaranteed to execute:

  * ``naive``/``lane`` fwd kernels require the effective temporal tile
    ``Lt = min(block_t, Lout)`` to be lane-aligned (``Lt % LANE == 0``);
  * the ``block`` fwd kernel requires the halo to fit one neighbour tile
    (``Lt >= K - 1``);
  * ``H % Hb == 0`` / ``B % Bc == 0`` are discharged by the channel/batch
    padding in ``ops.py``, so ``block_h`` / ``batch_chunk`` only need to be
    positive — but values above the dimension are clamped by the kernels,
    so candidates are *normalized* (clamped + irrelevant knobs pinned to
    defaults) and deduplicated to keep the space minimal;
  * staged slabs must fit on-chip memory: the VMEM working-set estimate per
    grid cell is checked against the hardware model's ``vmem_bytes``.  The
    staged bwd_k / bwd_fused variants honour ``block_t`` *time tiling*
    (``kernels/dwconv_bwdk.py``): their tiled working set is bounded by
    ``block_t`` instead of growing with L, which is what makes long-sequence
    shapes legal to tune at all — the tuner then trades tile count against
    the per-seam K-1 halo re-read via the tiled traffic models.

The same structure generalizes the paper's four-variant study axis: the
tuner explores exactly the implementations the controlled study compares.
The ``bwd_fused`` path extends the axis to the backward-pass *structure*:
its candidates are the fused single-pass kernels (staging both operand
slabs — double the bwd_k working set, checked against VMEM) plus ``split``,
which delegates to the independently tuned bwd_in + bwd_k ops, so
fused-vs-split is itself a counter-free tuning decision.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis.hw import TPU_V5E, HardwareModel
from repro.kernels.common import DWConvDims
from repro.kernels.ops import KernelOptions
from repro.perfmodel import check_legality, schedule_for, vmem_bytes
from repro.perfmodel.geometry import decode_tiles, effective_tiles, time_tile

PATHS = ("fwd", "bwd_in", "bwd_k", "bwd_fused", "decode")

# Kernel implementations selectable per path ("xla" = the jnp reference,
# which is also the SPMD production path — a legitimate tuning outcome).
FWD_SPACE_VARIANTS = ("row", "block", "lane", "naive", "xla")
BWDK_SPACE_VARIANTS = ("accum", "twostage", "naive", "xla")
# The whole-backward path: fused single-pass kernels vs "split" (run the
# independently tuned bwd_in + bwd_k ops) — fused-vs-split dispatch is a
# tuning decision like any other.
BWD_FUSED_SPACE_VARIANTS = ("fused", "fused_partials", "split")
# Streaming-decode path (single-step ring-buffer conv at L=1): whole-pool
# staging vs batch-chunked cells vs the jnp reference.
DECODE_SPACE_VARIANTS = ("rows", "chanblock", "xla")

# Variants with no tiling knobs of their own (reference / delegating paths).
_KNOBLESS = ("xla", "split")


def _space_variants(path: str) -> Tuple[str, ...]:
    if path in ("fwd", "bwd_in"):
        return FWD_SPACE_VARIANTS
    if path == "bwd_k":
        return BWDK_SPACE_VARIANTS
    if path == "decode":
        return DECODE_SPACE_VARIANTS
    return BWD_FUSED_SPACE_VARIANTS

# Tiling lattices (clamped to the problem dims during normalization).
# UNTILED_BLOCK_T is a sentinel that always clamps to the full Lout: it keeps
# the *untiled* staged execution reachable for shapes with Lout above the
# largest finite tile (normalize() collapses it with every other block_t
# that executes untiled, so short shapes gain no duplicate candidates), and
# the VMEM predicate then decides whether that single-slab config is legal.
UNTILED_BLOCK_T = 1 << 30
BLOCK_H_CHOICES = (1, 2, 4, 8, 16, 32)
BLOCK_T_CHOICES = (128, 256, 512, 1024, 2048, UNTILED_BLOCK_T)
BATCH_CHUNK_CHOICES = (8, 16, 32, 64, 128, 256)

# The paper's study shape (B, H, L, K) = (16384, 128, 48, 48) and the
# CPU-interpret reduction used by the benchmark harness (same geometry,
# batch cut so interpret-mode measurement stays tractable).
PAPER_DIMS_FULL = DWConvDims(B=16384, H=128, L=48, K=48)
PAPER_DIMS_CPU = DWConvDims(B=64, H=128, L=48, K=48)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the per-path search space (hashable, normalized)."""

    path: str            # "fwd" | "bwd_in" | "bwd_k"
    variant: str         # kernel implementation for that path
    block_h: int = 8
    block_t: int = 512
    batch_chunk: int = 128

    def options(self, interpret: Optional[bool] = None) -> KernelOptions:
        return KernelOptions(
            block_h=self.block_h,
            block_t=self.block_t,
            batch_chunk=self.batch_chunk,
            interpret=interpret,
        )


_DEFAULT = Candidate(path="fwd", variant="row")  # source of default knob values


def _effective_tiles(c: Candidate, d: DWConvDims) -> Tuple[int, int, int, int]:
    """(Hb, Lt, Bc, Lout) exactly as ops.py/kernels compute them (shared
    geometry: ``perfmodel.geometry.effective_tiles``)."""
    return effective_tiles(d, c.block_h, c.block_t, c.batch_chunk)


def _bwd_time_tile(c: Candidate, d: DWConvDims, epilogue: str = "none") -> Optional[int]:
    """Effective time tile for a staged bwd candidate, or None when the
    kernel executes untiled — the shared ``perfmodel.geometry.time_tile``
    (``bwdk_time_tile``, or its stricter epilogue sibling for
    epilogue-aware bwd_fused problems, whose recompute window needs a
    prev-tile halo)."""
    epi = epilogue if c.path == "bwd_fused" else "none"
    return time_tile(d.L, d.K, c.block_t, c.variant, epi)


def normalize(c: Candidate, d: DWConvDims, epilogue: str = "none") -> Candidate:
    """Clamp knobs to the problem dims and pin knobs the variant ignores.

    Two candidates that resolve to the same executed configuration collapse
    to the same normalized value, which keeps the measured set minimal.
    """
    Hb, Lt, Bc, Lout = _effective_tiles(c, d)
    if c.variant in _KNOBLESS:  # reference/delegating paths: no tiling knobs
        return Candidate(c.path, c.variant, _DEFAULT.block_h,
                         _DEFAULT.block_t, _DEFAULT.batch_chunk)
    if c.path in ("fwd", "bwd_in"):
        if c.variant == "row":  # row stages the whole temporal row: no Lt
            Lt = _DEFAULT.block_t
        return Candidate(c.path, c.variant, Hb, Lt, _DEFAULT.batch_chunk)
    if c.path == "decode":
        # block_t is the channel-lane tile, batch_chunk the pool chunk;
        # block_h has no decode meaning.  Every block_t that clamps to the
        # same effective tile collapses (the UNTILED sentinel becomes the
        # full padded channel extent).
        Hl, _, _, Bc_d, _, _ = decode_tiles(d, c.block_t, c.batch_chunk)
        return Candidate(c.path, c.variant, _DEFAULT.block_h, Hl,
                         Bc_d if c.variant == "chanblock" else _DEFAULT.batch_chunk)
    # bwd_k and bwd_fused: (h-block x batch-chunk [x time-tile]) grids.  The
    # staged variants honour block_t (time-tiled reduction); every block_t
    # that executes untiled (naive, single tile, or a halo-starved tile that
    # ops.py falls back from) collapses to the canonical Lt=Lout form.
    tiled_lt = _bwd_time_tile(c, d, epilogue)
    Lt = tiled_lt if tiled_lt is not None else Lout
    return Candidate(c.path, c.variant, Hb, Lt, Bc)


def _schedule(c: Candidate, d: DWConvDims, itemsize: int, epilogue: str):
    """The candidate's registered :class:`~repro.perfmodel.KernelSchedule`
    (the fwd/bwd_in structural checks ignore the epilogue, like the
    kernels themselves — only bwd_fused changes body under an epilogue)."""
    return schedule_for(
        c.path, c.variant, d, itemsize,
        block_h=c.block_h, block_t=c.block_t, batch_chunk=c.batch_chunk,
        epilogue=epilogue if c.path in ("fwd", "bwd_fused", "decode") else "none")


def _vmem_working_set_bytes(
    c: Candidate, d: DWConvDims, itemsize: int, epilogue: str = "none"
) -> int:
    """Per-grid-cell VMEM staging estimate for the candidate's kernel —
    derived from the staged block shapes its schedule declares."""
    return vmem_bytes(_schedule(c, d, itemsize, epilogue))


def is_legal(
    c: Candidate,
    d: DWConvDims,
    *,
    itemsize: int = 4,
    hw: HardwareModel = TPU_V5E,
    epilogue: str = "none",
) -> Tuple[bool, str]:
    """Check the kernel asserts (post-ops-padding) for this candidate.

    The structural verdicts (lane alignment, halo fit) and the VMEM bound
    are both derived from the candidate's registered schedule
    (``perfmodel.check_legality``); this wrapper only screens the
    search-space domain (known path/variant, positive knobs).

    Returns ``(ok, reason)`` — the reason names the violated constraint so
    tuner logs stay self-explanatory.
    """
    if c.path not in PATHS:
        return False, f"unknown path {c.path!r}"
    variants = _space_variants(c.path)
    if c.variant not in variants:
        return False, f"variant {c.variant!r} not applicable to path {c.path!r}"
    if min(c.block_h, c.block_t, c.batch_chunk) < 1:
        return False, "tiling knobs must be positive"
    if c.variant in _KNOBLESS:
        return True, "ok"
    return check_legality(_schedule(c, d, itemsize, epilogue), hw=hw)


def search_space(
    d: DWConvDims,
    path: str,
    *,
    variants: Optional[Sequence[str]] = None,
    block_h_choices: Iterable[int] = BLOCK_H_CHOICES,
    block_t_choices: Iterable[int] = BLOCK_T_CHOICES,
    batch_chunk_choices: Iterable[int] = BATCH_CHUNK_CHOICES,
    include_xla: bool = True,
    itemsize: int = 4,
    hw: HardwareModel = TPU_V5E,
    epilogue: str = "none",
) -> List[Candidate]:
    """Enumerate the legal, normalized, deduplicated candidates for a path."""
    if path not in PATHS:
        raise ValueError(f"unknown path {path!r}; known: {PATHS}")
    if variants is None:
        variants = _space_variants(path)
    if not include_xla:
        variants = tuple(v for v in variants if v != "xla")

    seen = set()
    out: List[Candidate] = []
    for v, bh, bt, bc in itertools.product(
        variants, block_h_choices, block_t_choices, batch_chunk_choices
    ):
        cand = normalize(Candidate(path, v, bh, bt, bc), d, epilogue)
        if cand in seen:
            continue
        seen.add(cand)
        ok, _ = is_legal(cand, d, itemsize=itemsize, hw=hw, epilogue=epilogue)
        if ok:
            out.append(cand)
    return out


def neighbors(c: Candidate, d: DWConvDims, *, itemsize: int = 4,
              hw: HardwareModel = TPU_V5E,
              epilogue: str = "none") -> List[Candidate]:
    """Single-knob moves on the tiling lattice plus variant switches —
    the move set of the greedy hillclimb driver."""
    moves: List[Candidate] = []
    for field, choices in (
        ("block_h", BLOCK_H_CHOICES),
        ("block_t", BLOCK_T_CHOICES),
        ("batch_chunk", BATCH_CHUNK_CHOICES),
    ):
        cur = getattr(c, field)
        ordered = sorted(choices)
        # The lattice points straddling ``cur``.  For an off-lattice value
        # (a clamped knob, e.g. block_h=12 on {...8,16...}) BOTH straddling
        # points are single moves — a nearest±1 scheme would skip one.
        lo = bisect.bisect_left(ordered, cur)
        below = ordered[lo - 1] if lo > 0 else None
        if lo < len(ordered) and ordered[lo] == cur:
            above = ordered[lo + 1] if lo + 1 < len(ordered) else None
        else:
            above = ordered[lo] if lo < len(ordered) else None
        for nv in (below, above):
            if nv is not None and nv != cur:
                moves.append(dataclasses.replace(c, **{field: nv}))
    for v in _space_variants(c.path):
        if v != c.variant:
            moves.append(dataclasses.replace(c, variant=v))
    uniq, seen = [], {c}
    for m in moves:
        m = normalize(m, d, epilogue)
        if m not in seen and is_legal(m, d, itemsize=itemsize, hw=hw,
                                      epilogue=epilogue)[0]:
            seen.add(m)
            uniq.append(m)
    return uniq
