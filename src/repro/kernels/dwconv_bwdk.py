"""Pallas TPU kernels — the reduction-dominated *weight-gradient* path.

dk[h, j] = sum_{b, t} dy[b, h, t] * x_pad[b, h, t + j]          (paper eq. 10)

This is the path the paper identifies as the persistent bottleneck: the
reduction runs over the full (B x L) domain per coefficient.  TPU grids are
*sequential* on a core, so the CUDA two-stage shuffle reduction maps to two
idiomatic structures:

  naive    : per (h-block) cell, every tap re-DMAs the full (Bc, Hb, L) slab
             from HBM — K x redundant traffic, zero on-chip reuse across
             taps (the one-thread-per-(h,j) CUDA baseline).
  twostage : stage the slab in VMEM once per batch-chunk, compute *all* K
             tap partials from it, write per-chunk partials to HBM, then a
             second jnp reduction combines chunks — the paper's explicit
             partial-sum + second-stage design (atomic-free).
  accum    : same staging, but chunks accumulate in-place into a revisited
             output block across the sequential grid — the TPU-native fusion
             of both stages (no partials round-trip through HBM).

``accum`` and ``twostage`` additionally support *time tiling*
(``block_t``): instead of staging the full padded sequence per cell —
which makes the VMEM working set grow with L and walls off long-sequence
workloads — the grid gains a third, sequential dimension over sequence
tiles.  Each cell stages a ``(Bc, Hb, Lt + K - 1)`` haloed slab (bound as
the current tile plus its right neighbour, the same halo idiom as the
``block`` forward kernel), computes all K tap partials from it, and
accumulates across the tile axis (accum: the revisited output block;
twostage: a ``(nC, nT, H, Kp)`` partials buffer plus the second-stage
reduction).  The per-cell footprint is then bounded by ``block_t``
regardless of L, at the cost of re-reading the K-1 halo columns once per
tile seam.

Inputs arrive pre-padded from ops.py: xp (B, H, Wpad), dy (B, H, Ldy)
with ``Ldy = nT * Lt`` and ``Wpad = (nT + 1) * Lt`` in the tiled regime.
Output: (H, Kp) with Kp = round_up(K, LANE); ops.py slices to (H, K).
Accumulation is f32.

``dwconv_bwd_fused.py`` extends the ``accum``/``twostage`` staging into a
*fused* backward that also emits dx from the same slab (one HBM pass over
each operand for the whole backward); this module remains the split-path
weight-gradient study the paper's per-path tables are built from.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import LANE, cdiv


def _taps_from_slabs(x32: jnp.ndarray, dy32: jnp.ndarray, K: int, Kp: int) -> jnp.ndarray:
    """(Bc, Hb, >=L+K-1) x (Bc, Hb, L) -> per-tap partials (Hb, Kp), f32."""
    L = dy32.shape[-1]
    taps = [jnp.sum(dy32 * x32[:, :, j : j + L], axis=(0, 2)) for j in range(K)]
    part = jnp.stack(taps, axis=-1)  # (Hb, K)
    if Kp > K:
        part = jnp.pad(part, ((0, 0), (0, Kp - K)))
    return part


def _check_chunking(B: int, Bc: int, H: int, Hb: int) -> None:
    if B % Bc != 0:
        raise ValueError(
            f"batch B={B} is not divisible by batch_chunk={Bc}; lower "
            f"KernelOptions.batch_chunk or let ops.py pad the batch")
    if H % Hb != 0:
        raise ValueError(
            f"channels H={H} are not divisible by block_h={Hb}; lower "
            f"KernelOptions.block_h or let ops.py pad the channel axis")


def _check_tiled_layout(Wpad: int, Ldy: int, Lt: int, K: int) -> int:
    """Validate the tiled (xp, dy) layout; returns the tile count nT."""
    if Lt < K - 1:
        raise ValueError(
            f"time tile block_t={Lt} cannot hold the K-1={K - 1} halo; "
            f"raise KernelOptions.block_t to at least K-1")
    if Ldy % Lt != 0:
        raise ValueError(
            f"dy width {Ldy} is not a whole number of block_t={Lt} tiles; "
            f"ops.py must pad dy to a tile multiple")
    nT = Ldy // Lt
    if Wpad < (nT + 1) * Lt:
        raise ValueError(
            f"padded input width {Wpad} < (nT+1)*Lt={(nT + 1) * Lt}: the "
            f"neighbour-tile halo read runs out of bounds; ops.py must pad "
            f"x to (nT+1)*block_t columns")
    return nT


def _check_untiled_layout(Wpad: int, Ldy: int, K: int) -> None:
    if Wpad < Ldy + K - 1:
        raise ValueError(
            f"padded input width {Wpad} < L+K-1={Ldy + K - 1}: the tap "
            f"windows run out of bounds; ops.py must pad x to the full "
            f"convolution window")


# ---------------------------------------------------------------------------
# accum variant: sequential-grid in-place accumulation (TPU-native two-stage)
# ---------------------------------------------------------------------------


def _accum_kernel(x_ref, dy_ref, dk_ref, *, K: int, Kp: int):
    c = pl.program_id(1)  # batch-chunk index — innermost, sequential

    @pl.when(c == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)

    x32 = x_ref[...].astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    dk_ref[...] += _taps_from_slabs(x32, dy32, K, Kp).astype(dk_ref.dtype)


def _accum_tiled_kernel(xc_ref, xn_ref, dy_ref, dk_ref, *, K: int, Kp: int):
    c = pl.program_id(1)  # batch-chunk index — sequential
    t = pl.program_id(2)  # time-tile index — innermost, sequential

    @pl.when(jnp.logical_and(c == 0, t == 0))
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)

    # Haloed slab: current tile + right neighbour covers (Bc, Hb, Lt + K - 1).
    x32 = jnp.concatenate([xc_ref[...], xn_ref[...]], axis=-1).astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    dk_ref[...] += _taps_from_slabs(x32, dy32, K, Kp).astype(dk_ref.dtype)


def dwconv_bwdk_accum(
    xp: jnp.ndarray,
    dy: jnp.ndarray,
    *,
    K: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    block_t: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Wpad = xp.shape
    L = dy.shape[-1]
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    _check_chunking(B, Bc, H, Hb)
    Kp = cdiv(K, LANE) * LANE
    if block_t is not None and block_t < L:
        Lt = block_t
        nT = _check_tiled_layout(Wpad, L, Lt, K)
        grid = (H // Hb, B // Bc, nT)
        out = pl.pallas_call(
            functools.partial(_accum_tiled_kernel, K=K, Kp=Kp),
            out_shape=jax.ShapeDtypeStruct((H, Kp), jnp.float32),
            grid=grid,
            in_specs=[
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t + 1)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
            ],
            out_specs=pl.BlockSpec((Hb, Kp), lambda h, c, t: (h, 0)),
            interpret=interpret,
        )(xp, xp, dy)
        return out[:, :K]
    _check_untiled_layout(Wpad, L, K)
    grid = (H // Hb, B // Bc)
    out = pl.pallas_call(
        functools.partial(_accum_kernel, K=K, Kp=Kp),
        out_shape=jax.ShapeDtypeStruct((H, Kp), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bc, Hb, Wpad), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Bc, Hb, L), lambda h, c: (c, h, 0)),
        ],
        out_specs=pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        interpret=interpret,
    )(xp, dy)
    return out[:, :K]


# ---------------------------------------------------------------------------
# twostage variant: explicit HBM partials + second reduction stage
# ---------------------------------------------------------------------------


def _partials_kernel(x_ref, dy_ref, part_ref, *, K: int, Kp: int):
    x32 = x_ref[...].astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    part_ref[0] = _taps_from_slabs(x32, dy32, K, Kp)


def _partials_tiled_kernel(xc_ref, xn_ref, dy_ref, part_ref, *, K: int, Kp: int):
    x32 = jnp.concatenate([xc_ref[...], xn_ref[...]], axis=-1).astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    part_ref[0, 0] = _taps_from_slabs(x32, dy32, K, Kp)


def dwconv_bwdk_twostage(
    xp: jnp.ndarray,
    dy: jnp.ndarray,
    *,
    K: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    block_t: Optional[int] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Wpad = xp.shape
    L = dy.shape[-1]
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    _check_chunking(B, Bc, H, Hb)
    Kp = cdiv(K, LANE) * LANE
    nC = B // Bc
    if block_t is not None and block_t < L:
        Lt = block_t
        nT = _check_tiled_layout(Wpad, L, Lt, K)
        grid = (H // Hb, nC, nT)
        partials = pl.pallas_call(
            functools.partial(_partials_tiled_kernel, K=K, Kp=Kp),
            out_shape=jax.ShapeDtypeStruct((nC, nT, H, Kp), jnp.float32),
            grid=grid,
            in_specs=[
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t + 1)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
            ],
            out_specs=pl.BlockSpec((1, 1, Hb, Kp), lambda h, c, t: (c, t, h, 0)),
            interpret=interpret,
        )(xp, xp, dy)
        return jnp.sum(partials, axis=(0, 1))[:, :K]  # second reduction stage
    _check_untiled_layout(Wpad, L, K)
    grid = (H // Hb, nC)
    partials = pl.pallas_call(
        functools.partial(_partials_kernel, K=K, Kp=Kp),
        out_shape=jax.ShapeDtypeStruct((nC, H, Kp), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bc, Hb, Wpad), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Bc, Hb, L), lambda h, c: (c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hb, Kp), lambda h, c: (c, h, 0)),
        interpret=interpret,
    )(xp, dy)
    return jnp.sum(partials, axis=0)[:, :K]  # second reduction stage


# ---------------------------------------------------------------------------
# naive variant: per-tap full re-read (no staging reuse across taps)
# ---------------------------------------------------------------------------


def _naive_bwdk_kernel(
    x_hbm, dy_hbm, dk_ref, xs, dys, sem_x, sem_y, *, K: int, Kp: int, Hb: int, Bc: int
):
    h = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)

    L = dys.shape[-1]
    acc = jnp.zeros((Hb, Kp), jnp.float32)
    for j in range(K):
        # The naive structure: *both* operands re-DMA'd per tap.
        cx = pltpu.make_async_copy(
            x_hbm.at[pl.ds(c * Bc, Bc), pl.ds(h * Hb, Hb), pl.ds(j, L)], xs, sem_x
        )
        cy = pltpu.make_async_copy(
            dy_hbm.at[pl.ds(c * Bc, Bc), pl.ds(h * Hb, Hb), :], dys, sem_y
        )
        cx.start()
        cy.start()
        cx.wait()
        cy.wait()
        tap = jnp.sum(xs[...].astype(jnp.float32) * dys[...].astype(jnp.float32), axis=(0, 2))
        acc = acc.at[:, j].set(tap)
    dk_ref[...] += acc.astype(dk_ref.dtype)


def dwconv_bwdk_naive(
    xp: jnp.ndarray,
    dy: jnp.ndarray,
    *,
    K: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Wpad = xp.shape
    L = dy.shape[-1]
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    _check_chunking(B, Bc, H, Hb)
    _check_untiled_layout(Wpad, L, K)
    Kp = cdiv(K, LANE) * LANE
    grid = (H // Hb, B // Bc)
    out = pl.pallas_call(
        functools.partial(_naive_bwdk_kernel, K=K, Kp=Kp, Hb=Hb, Bc=Bc),
        out_shape=jax.ShapeDtypeStruct((H, Kp), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        scratch_shapes=[
            pltpu.VMEM((Bc, Hb, L), xp.dtype),
            pltpu.VMEM((Bc, Hb, L), dy.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(xp, dy)
    return out[:, :K]
