"""olmoe-1b-7b [moe]: 16L, d=2048, 16H, ff=1024/expert, 64 experts top-8,
vocab=50304, QK-norm.  [arXiv:2409.02060]"""
import dataclasses

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1024,
    vocab=50304,
    head_dim=128,
    qk_norm=True,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=8, capacity_factor=1.25, group_size=512),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=32, vocab=256,
    head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.5, group_size=16),
    compute_dtype="float32",
)
