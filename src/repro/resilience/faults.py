"""Error taxonomy + deterministic, seeded fault-injection harness.

Running a controlled kernel study in a *restricted cloud environment* means
every layer of the stack must treat failure as an input, not an accident: a
tuning-cache entry written by another device fingerprint, a VMEM overflow on
an untested shape, a preempted host mid-checkpoint.  This module gives those
failures two first-class representations:

  1. an **error taxonomy** (:class:`ResilienceError` and friends) the
     degradation machinery (``resilience/guard.py``) can catch by type
     instead of pattern-matching messages;
  2. a **deterministic fault-injection harness**: named *sites* compiled
     into the production code paths (``kernels/ops.py``,
     ``tuning/cache.py``, ``checkpoint/manager.py``, the supervisor
     heartbeat, the tuner) ask :func:`should_fire` whether to misbehave.
     With no plan installed the check is a module-global ``None`` test —
     the harness costs nothing in production.

Plans are activated either programmatically::

    with FaultPlan.parse("kernel/lower*2,ckpt/write"):
        run_training()

or from the environment (read once, lazily)::

    REPRO_FAULTS="kernel/lower,cache/read@skip=1,kernel/nan@p=0.5@seed=7"

Spec grammar (comma-separated rules)::

    site[*count][@skip=N][@p=F][@seed=N]

``count`` firings (default 1, ``*`` alone = unlimited) after ``skip``
eligible hits are passed through; ``p`` makes each eligible hit fire with
probability ``F`` drawn from a per-rule ``random.Random(seed)`` — still
fully deterministic for a given seed.  Unknown site names are rejected at
parse time so a typo cannot silently disable a chaos run.
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading
from typing import Dict, List, Optional, Tuple

__all__ = [
    "BundleIntegrityError",
    "CheckpointIOError",
    "CorruptCacheEntryError",
    "FaultPlan",
    "FaultRule",
    "KernelLoweringError",
    "KernelResourceError",
    "NonFiniteOutputError",
    "ResilienceError",
    "SITES",
    "fire",
    "active_plan",
    "reset",
    "should_fire",
]


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------


class ResilienceError(RuntimeError):
    """Base for every failure the degradation machinery knows how to absorb."""


class KernelLoweringError(ResilienceError):
    """A Pallas kernel failed to lower/compile (Mosaic ``NotImplementedError``,
    BlockSpec mismatch, backend rejection).  Recoverable: fall down the
    degradation chain to a conservative tiling or the XLA reference."""


class KernelResourceError(ResilienceError):
    """The kernel's staged working set exceeded on-chip memory (VMEM
    overflow / XLA ``RESOURCE_EXHAUSTED``).  Recoverable the same way."""


class NonFiniteOutputError(ResilienceError):
    """A kernel or train step produced NaN/Inf.  The train-loop numerics
    guard skips the update; persistent nonfiniteness aborts nonzero so the
    supervisor's crash-restart path takes over."""


class CorruptCacheEntryError(ResilienceError):
    """A tuning-cache file or entry could not be parsed.  Recoverable: the
    file is preserved aside (never silently overwritten) and readable
    entries are salvaged."""


class CheckpointIOError(ResilienceError, OSError):
    """Checkpoint write/read failed at the filesystem layer.  Saves retry;
    restores fall back to the previous intact step."""


class BundleIntegrityError(ResilienceError):
    """A fleet tuning-cache bundle failed validation (unreadable file, bad
    or missing HMAC signature, content-id mismatch, unmigratable schema, or
    quarantined entries under strict import).  Recoverable: the replica
    drops the bundle, leaves its local cache untouched, and tunes fresh."""


# ---------------------------------------------------------------------------
# injection sites
# ---------------------------------------------------------------------------

# Every site compiled into the codebase.  Keep in sync with the README
# fault-site table.
SITES: Tuple[str, ...] = (
    "kernel/lower",          # kernels/ops.py: Pallas impl raises KernelLoweringError
    "kernel/nan",            # kernels/ops.py: forward output replaced with NaN
    "cache/read",            # tuning/cache.py: reading the DB raises OSError
    "cache/torn-write",      # tuning/cache.py: save writes a truncated file in place
    "ckpt/write",            # checkpoint/manager.py: _write raises CheckpointIOError
    "heartbeat/stall",       # launch/supervisor.py: Heartbeat.beat silently no-ops
    "tuner/slow-candidate",  # tuning/tuner.py: measured time inflated 1000x
    "bundle/tamper",             # fleet/bundle.py: parsed bundle mutated pre-verify
    "bundle/stale-fingerprint",  # fleet/import_.py: local fingerprint skewed
)


@dataclasses.dataclass
class FaultRule:
    """One armed site.  ``count`` firings (-1 = unlimited) after ``skip``
    eligible hits; each eligible hit fires with probability ``p`` drawn from
    a per-rule seeded RNG (deterministic given ``seed``)."""

    site: str
    count: int = 1
    skip: int = 0
    p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; known: {', '.join(SITES)}")
        if not (0.0 <= self.p <= 1.0):
            raise ValueError(f"fault probability must be in [0, 1], got {self.p}")


class FaultPlan:
    """A set of armed :class:`FaultRule`\\ s with per-site hit/fire counters.

    Context manager: entering installs the plan process-globally (stacking
    over any previous plan, including one parsed from ``REPRO_FAULTS``);
    exiting restores the previous plan.  All counting is thread-safe and
    deterministic: the n-th hit of a site fires iff the rule says so.
    """

    def __init__(self, rules: List[FaultRule]):
        self.rules: Dict[str, FaultRule] = {}
        for r in rules:
            if r.site in self.rules:
                raise ValueError(f"duplicate fault rule for site {r.site!r}")
            self.rules[r.site] = r
        self._hits: Dict[str, int] = {s: 0 for s in self.rules}
        self._fired: Dict[str, int] = {s: 0 for s in self.rules}
        self._rng: Dict[str, random.Random] = {
            s: random.Random(r.seed) for s, r in self.rules.items()}
        self._lock = threading.Lock()
        self._previous: Optional[Optional["FaultPlan"]] = None

    # ------------------------------------------------------------- parsing
    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the ``REPRO_FAULTS`` grammar (see module docstring)."""
        rules: List[FaultRule] = []
        for tok in spec.split(","):
            tok = tok.strip()
            if not tok:
                continue
            head, *mods = tok.split("@")
            site, star, count_s = head.partition("*")
            kw: Dict[str, object] = {"site": site.strip()}
            if star:
                kw["count"] = -1 if not count_s.strip() else int(count_s)
            for m in mods:
                k, eq, v = m.partition("=")
                k = k.strip()
                if not eq or k not in ("skip", "p", "seed"):
                    raise ValueError(
                        f"bad fault modifier {m!r} in {tok!r}: expected "
                        f"@skip=N, @p=F, or @seed=N")
                kw[k] = float(v) if k == "p" else int(v)
            rules.append(FaultRule(**kw))  # type: ignore[arg-type]
        return cls(rules)

    def spec(self) -> str:
        out = []
        for r in self.rules.values():
            s = r.site + ("" if r.count == 1 else "*" if r.count < 0 else f"*{r.count}")
            if r.skip:
                s += f"@skip={r.skip}"
            if r.p < 1.0:
                s += f"@p={r.p}@seed={r.seed}"
            out.append(s)
        return ",".join(out)

    # ------------------------------------------------------------ counting
    def should_fire(self, site: str) -> bool:
        rule = self.rules.get(site)
        if rule is None:
            return False
        with self._lock:
            hit = self._hits[site]
            self._hits[site] = hit + 1
            if hit < rule.skip:
                return False
            if rule.count >= 0 and self._fired[site] >= rule.count:
                return False
            if rule.p < 1.0 and self._rng[site].random() >= rule.p:
                return False
            self._fired[site] += 1
            return True

    def hits(self, site: str) -> int:
        with self._lock:
            return self._hits.get(site, 0)

    def fired(self, site: str) -> int:
        with self._lock:
            return self._fired.get(site, 0)

    def summary(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {s: {"hits": self._hits[s], "fired": self._fired[s]}
                    for s in self.rules}

    # ---------------------------------------------------- global installing
    def __enter__(self) -> "FaultPlan":
        global _PLAN, _ENV_LOADED
        with _GLOBAL_LOCK:
            _ENV_LOADED = True  # an explicit plan overrides the env plan
            self._previous = _PLAN
            _PLAN = self
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        global _PLAN
        with _GLOBAL_LOCK:
            _PLAN = self._previous
            self._previous = None


# ---------------------------------------------------------------------------
# process-global plan (explicit FaultPlan context > REPRO_FAULTS env > none)
# ---------------------------------------------------------------------------

FAULTS_ENV_VAR = "REPRO_FAULTS"

_PLAN: Optional[FaultPlan] = None
_ENV_LOADED = False
_GLOBAL_LOCK = threading.Lock()


def _load_env_plan() -> None:
    global _PLAN, _ENV_LOADED
    with _GLOBAL_LOCK:
        if _ENV_LOADED:
            return
        _ENV_LOADED = True
        spec = os.environ.get(FAULTS_ENV_VAR, "").strip()
        if spec:
            _PLAN = FaultPlan.parse(spec)


def active_plan() -> Optional[FaultPlan]:
    """The installed plan, if any (lazily reading ``REPRO_FAULTS`` once)."""
    if not _ENV_LOADED:
        _load_env_plan()
    return _PLAN


def should_fire(site: str) -> bool:
    """True when ``site`` must misbehave now.  The no-plan fast path is a
    single global ``None`` test — safe to leave in production code."""
    if not _ENV_LOADED:
        _load_env_plan()
    p = _PLAN
    return p is not None and p.should_fire(site)


def fire(site: str, exc_type: type, message: str) -> None:
    """Raise ``exc_type(message)`` when ``site`` fires (the raising sites'
    one-liner; value-corrupting sites call :func:`should_fire` directly)."""
    if should_fire(site):
        raise exc_type(f"[fault-injection:{site}] {message}")


def reset() -> None:
    """Drop any installed plan and forget the env read (tests)."""
    global _PLAN, _ENV_LOADED
    with _GLOBAL_LOCK:
        _PLAN = None
        _ENV_LOADED = False
