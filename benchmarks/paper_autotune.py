"""Tuned-vs-default comparison on the paper's study shape (autotuner gate).

For each execution path of the reduced paper shape (the CPU-interpret regime
``paper_table2.py`` uses), this benchmark reports:

  * the *default* hard-coded configuration (``row``/``accum`` with
    ``DEFAULT_OPTS``) — the reproduction's pre-autotuner behaviour;
  * the *tuned* configuration resolved by ``variant="auto"`` from the
    persistent tuning cache.

If the active cache (``REPRO_TUNE_CACHE`` or ``results/tuning/cache.json``)
has no entry for the shape, a small in-process tuning run (grid search over
the analytical top candidates) fills the in-memory cache first — without
persisting, so a quick benchmark run never pollutes the database a real
``python -m repro.launch.tune`` run would write — making this benchmark
self-contained in CI while still honouring a previously tuned cache.

The acceptance property asserted here: the tuned choice is never slower
than the default beyond measurement noise — the autotuner must not regress
the paper's hand-picked configuration on the paper's own shape.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax

from repro.analysis.timer import time_fn
from repro.tuning import cost, tuner
from repro.tuning.cache import TuningCache, default_cache, lookup
from repro.tuning.space import PAPER_DIMS_CPU, PATHS, Candidate
from repro.kernels.ops import AUTO_FALLBACK, DEFAULT_OPTS

# Tolerance for run-to-run wall-clock jitter on shared CPU runners.
NOISE_FACTOR = 1.25


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def _default_candidate(path: str) -> Candidate:
    return Candidate(
        path=path,
        variant=AUTO_FALLBACK[path],
        block_h=DEFAULT_OPTS.block_h,
        block_t=DEFAULT_OPTS.block_t,
        batch_chunk=DEFAULT_OPTS.batch_chunk,
    )


def run(fast: bool = False) -> List[Row]:
    d = PAPER_DIMS_CPU
    iters = 2 if fast else 3
    budget = 2 if fast else 6
    rows: List[Row] = []

    for path in PATHS:
        entry = lookup(path, d.B, d.H, d.L, d.K, "float32", jax.default_backend(),
                       d.padding)
        if entry is None:
            # A private throwaway cache: the low-budget emergency tune keeps
            # the benchmark self-contained but must never reach the
            # persistent database — not even via a later save() of the
            # process-wide default cache — where it would permanently
            # preempt a real `repro.launch.tune` run for auto dispatch.
            scratch = TuningCache(default_cache().path)
            res = tuner.tune_path(d, path, budget=budget, iters=iters,
                                  cache=scratch, persist=False)
            entry = res.best
        tuned = Candidate(path=path, variant=entry.variant, block_h=entry.block_h,
                          block_t=entry.block_t, batch_chunk=entry.batch_chunk)
        default = _default_candidate(path)

        t_default = cost.measure_candidate(default, d, warmup=1, iters=iters, timer=time_fn)
        if tuned == default:
            # The tuner kept the fallback configuration (it always meters the
            # baseline, so this is a legitimate decision): the no-regression
            # property holds by construction — re-measuring the identical
            # configuration would only gate on wall-clock noise.
            t_tuned = t_default
        else:
            t_tuned = cost.measure_candidate(tuned, d, warmup=1, iters=iters, timer=time_fn)
        speedup = t_default / max(t_tuned, 1e-12)
        verdict = "TUNED_OK" if t_tuned <= t_default * NOISE_FACTOR else "TUNED_SLOWER"
        rows.append(Row(
            f"paper_autotune/{path}/tuned", t_tuned * 1e6,
            f"variant={tuned.variant} bh={tuned.block_h} bt={tuned.block_t} "
            f"bc={tuned.batch_chunk}"))
        rows.append(Row(
            f"paper_autotune/{path}/default", t_default * 1e6,
            f"variant={default.variant}"))
        rows.append(Row(
            f"paper_autotune/{path}/speedup", 0.0,
            f"tuned_vs_default={speedup:.2f}x {verdict}"))
        assert t_tuned <= t_default * NOISE_FACTOR, (
            f"{path}: tuned config {t_tuned * 1e6:.1f}us slower than default "
            f"{t_default * 1e6:.1f}us beyond noise")
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
