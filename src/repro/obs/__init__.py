"""Counter-free observability: span tracer, hardware calibration, perf ledger.

Three legs, all built from the paper's §III-F apparatus (explicit
synchronization + wall-clock + analytical byte models — no hardware
counters):

  * :mod:`repro.obs.trace`     — hierarchical span tracer whose span close
    performs ``block_until_ready`` (the JAX analogue of CUDA-event timing);
    kernel spans attach schedule-derived modeled bytes/flops so every span
    carries measured time *plus* modeled traffic.
  * :mod:`repro.obs.calibrate` — microbenchmark suite (HBM copy/triad sweep,
    matmul FLOP/s, dispatch floor) fitting a :class:`CalibratedHardware`
    overlay on the static ``analysis/hw.py`` datasheet peaks, persisted per
    device fingerprint.
  * :mod:`repro.obs.ledger`    — append-only perf-trajectory ledger with a
    rolling-baseline, noise-aware regression gate for CI.
"""
from repro.obs.ledger import (
    LedgerEntry,
    MetricVerdict,
    append_entry,
    check_regression,
    read_ledger,
)
from repro.obs.trace import Span, Tracer, configure, get_tracer, read_trace

__all__ = [
    "LedgerEntry",
    "MetricVerdict",
    "Span",
    "Tracer",
    "append_entry",
    "check_regression",
    "configure",
    "get_tracer",
    "read_trace",
]
