"""Single-host trainer for the paper's fixed S4ConvD workload.

Implements the paper's §III-C training configuration and §III-F measurement
protocol: SGD(momentum=0.9, lr=1e-3), grad-clip 1.0, RMSLE objective,
per-epoch wall-clock with the warm-up epoch excluded, and — the study's
whole point — a selectable depthwise-conv kernel variant, everything else
fixed.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import s4convd
from repro.data.gep3 import BatchIterator, GEP3Config, make_splits
from repro.train.losses import msle, rmsle
from repro.train.optim import get_optimizer


@dataclasses.dataclass
class TrainResult:
    epoch_losses: List[float]
    epoch_times_s: List[float]
    steady_epoch_time_s: float    # mean excluding warm-up epoch (paper)
    dev_rmsle: float
    steps: int


def make_train_step(cfg: s4convd.S4ConvDConfig, optimizer):
    def loss_fn(params, x, y, rng):
        pred = s4convd.apply(params, cfg, x, rng=rng, train=True)
        return msle(pred, y)

    @jax.jit
    def step(params, opt_state, x, y, rng):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, rng)
        params, opt_state = optimizer.update(grads, params, opt_state)
        return params, opt_state, loss

    return step


def evaluate(params, cfg: s4convd.S4ConvDConfig, x: np.ndarray, y: np.ndarray, batch: int = 4096) -> float:
    apply = jax.jit(lambda p, xb: s4convd.apply(p, cfg, xb, train=False))
    preds, tgts = [], []
    for lo in range(0, x.shape[0], batch):
        preds.append(np.asarray(apply(params, jnp.asarray(x[lo : lo + batch]))))
        tgts.append(y[lo : lo + batch])
    pred = jnp.asarray(np.concatenate(preds))
    tgt = jnp.asarray(np.concatenate(tgts))
    return float(rmsle(pred, tgt))


def train(
    cfg: s4convd.S4ConvDConfig,
    data_cfg: GEP3Config,
    *,
    batch_size: int = 512,
    epochs: int = 3,
    seed: int = 0,
    optimizer_name: str = "sgd_momentum",
    max_steps_per_epoch: Optional[int] = None,
    log_every: int = 0,
    conv_variant: Optional[str] = None,
) -> TrainResult:
    """``conv_variant`` overrides ``cfg.conv_variant`` (the study axis) —
    ``"auto"`` trains on whatever the tuning cache selected for this shape."""
    if conv_variant is not None:
        cfg = dataclasses.replace(cfg, conv_variant=conv_variant)
    splits = make_splits(data_cfg)
    optimizer = get_optimizer(optimizer_name)
    rng = jax.random.PRNGKey(seed)
    params = s4convd.init(rng, cfg)
    opt_state = optimizer.init(params)
    step_fn = make_train_step(cfg, optimizer)

    it = BatchIterator(splits.train_x, splits.train_y, batch_size, seed=seed)
    epoch_losses, epoch_times = [], []
    steps = 0
    for epoch in range(epochs):
        t0 = time.perf_counter()
        losses = []
        stopped_early = False
        for bi, (xb, yb) in enumerate(it):
            if max_steps_per_epoch is not None and bi >= max_steps_per_epoch:
                stopped_early = True
                break
            rng, sub = jax.random.split(rng)
            params, opt_state, loss = step_fn(
                params, opt_state, jnp.asarray(xb), jnp.asarray(yb), sub
            )
            losses.append(loss)
            steps += 1
            if log_every and steps % log_every == 0:
                print(f"  step {steps}: loss={float(loss):.5f}")
        if stopped_early:
            it.end_epoch()
        if not losses:
            raise RuntimeError("epoch produced no batches — batch_size too large?")
        jax.block_until_ready(losses[-1])
        epoch_times.append(time.perf_counter() - t0)
        epoch_losses.append(float(jnp.mean(jnp.stack(losses))))
    steady = float(np.mean(epoch_times[1:])) if len(epoch_times) > 1 else epoch_times[0]
    dev = evaluate(params, cfg, splits.dev_x, splits.dev_y)
    return TrainResult(
        epoch_losses=epoch_losses,
        epoch_times_s=epoch_times,
        steady_epoch_time_s=steady,
        dev_rmsle=dev,
        steps=steps,
    )
