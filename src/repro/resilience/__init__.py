"""Guarded kernel execution for restricted cloud environments.

``faults``  — error taxonomy + deterministic seeded fault injection
              (:class:`FaultPlan` / ``REPRO_FAULTS``, named sites wired into
              kernels, tuning cache, checkpoints, heartbeat, tuner);
``guard``   — degradation-chain dispatch (chosen variant -> conservative
              default -> XLA reference) with failure memoization, tuning-
              cache quarantine, ``kind="degradation"`` trace records, and
              the train-loop :class:`NumericsGuard`;
``report``  — CLI collecting degradation events + quarantined cache entries
              into one JSON artifact (the chaos CI job uploads it).
"""
from repro.resilience.faults import (  # noqa: F401
    BundleIntegrityError,
    CheckpointIOError,
    CorruptCacheEntryError,
    FaultPlan,
    FaultRule,
    KernelLoweringError,
    KernelResourceError,
    NonFiniteOutputError,
    ResilienceError,
    SITES,
)
from repro.resilience.guard import (  # noqa: F401
    NumericsGuard,
    degradation_events,
    record_degradation,
    run_guarded,
)
