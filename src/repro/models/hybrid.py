"""recurrentgemma-2b — Griffin-style hybrid: RG-LRU recurrent blocks with a
temporal conv1d (the paper's operator, causal K=4), interleaved 2:1 with
local sliding-window attention (window 2048, MQA kv=1).

Scan-over-superblocks: the (rec, rec, attn) pattern is one scan body over
n_layers // 3 stacked superblocks (+ unrolled remainder), keeping the HLO
compact while preserving the heterogeneous layer pattern.

The RG-LRU linear recurrence trains via ``jax.lax.associative_scan``
(log-depth) and decodes with an O(1) carried state — hence this arch runs
the long_500k cell (bounded attention window + constant recurrent state).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.dwconv import dwconv
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.train.losses import softmax_cross_entropy

_C_RGLRU = 8.0  # Griffin's fixed recurrence-gate temperature


def attn_dims(cfg: ArchConfig) -> L.AttnDims:
    return L.AttnDims(n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_rec_block(rng, cfg: ArchConfig) -> Dict[str, Any]:
    r = cfg.rglru
    W = r.lru_width
    D = cfg.d_model
    ks = jax.random.split(rng, 6)
    # Lambda init so a = sigmoid(lam)^c lands in [0.9, 0.999]
    u = jax.random.uniform(ks[4], (W,), minval=0.9, maxval=0.999)
    lam = jnp.log(u ** (1.0 / _C_RGLRU) / (1 - u ** (1.0 / _C_RGLRU)))
    return {
        "w_xbranch": L.dense_init(ks[0], D, W),
        "w_ybranch": L.dense_init(ks[1], D, W),
        "conv_w": jax.random.normal(ks[2], (W, r.d_conv)) / math.sqrt(r.d_conv),
        "conv_b": jnp.zeros((W,)),
        # diagonal input/recurrence gates (block-diagonal in the paper)
        "w_gate_a": jnp.zeros((W,)),
        "w_gate_x": jnp.zeros((W,)),
        "lam": lam,
        "w_out": L.dense_init(ks[3], W, D),
        "ln": jnp.zeros((D,)),
    }


def _init_mlp_half(rng, cfg: ArchConfig) -> Dict[str, Any]:
    k1 = rng
    return {"mlp": L.init_mlp(k1, cfg.d_model, cfg.d_ff, gated=True),
            "ln_mlp": jnp.zeros((cfg.d_model,))}


def _init_attn_block(rng, cfg: ArchConfig) -> Dict[str, Any]:
    k1, _ = jax.random.split(rng)
    return {"attn": L.init_attention(k1, cfg.d_model, attn_dims(cfg)),
            "ln": jnp.zeros((cfg.d_model,))}


def _init_superblock(rng, cfg: ArchConfig) -> Dict[str, Any]:
    """(rec + mlp, rec + mlp, attn + mlp) — every residual block is followed
    by a gated-MLP block, per Griffin."""
    ks = jax.random.split(rng, 6)
    return {
        "rec1": _init_rec_block(ks[0], cfg), "mlp1": _init_mlp_half(ks[1], cfg),
        "rec2": _init_rec_block(ks[2], cfg), "mlp2": _init_mlp_half(ks[3], cfg),
        "attn": _init_attn_block(ks[4], cfg), "mlp3": _init_mlp_half(ks[5], cfg),
    }


def n_superblocks(cfg: ArchConfig) -> int:
    return cfg.n_layers // len(cfg.rglru.block_pattern)


def n_tail_rec(cfg: ArchConfig) -> int:
    """Remainder recurrent layers (26 = 8 x (rec,rec,attn) + 2 x rec)."""
    return cfg.n_layers % len(cfg.rglru.block_pattern)


def init(rng, cfg: ArchConfig) -> Dict[str, Any]:
    k_embed, k_layers, k_tail = jax.random.split(rng, 3)
    nb = n_superblocks(cfg)
    keys = jax.random.split(k_layers, nb)
    params = {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model),
        "blocks": jax.vmap(lambda r: _init_superblock(r, cfg))(keys),
        "ln_f": jnp.zeros((cfg.d_model,)),
    }
    nt = n_tail_rec(cfg)
    if nt:
        tks = jax.random.split(k_tail, 2 * nt)
        params["tail"] = [
            {"rec": _init_rec_block(tks[2 * i], cfg),
             "mlp": _init_mlp_half(tks[2 * i + 1], cfg)}
            for i in range(nt)
        ]
    return jax.tree.map(lambda x: x.astype(cfg.param_dt), params)


def param_axes(cfg: ArchConfig) -> Dict[str, Any]:
    rec = {
        "w_xbranch": ("embed", "mlp"), "w_ybranch": ("embed", "mlp"),
        "conv_w": ("mlp", "conv_k"), "conv_b": ("mlp",),
        "w_gate_a": ("mlp",), "w_gate_x": ("mlp",), "lam": ("mlp",),
        "w_out": ("mlp", "embed"), "ln": ("embed",),
    }
    mlp_half = {"mlp": L.mlp_param_axes(True), "ln_mlp": ("embed",)}
    attn = {"attn": L.attention_param_axes(attn_dims(cfg)), "ln": ("embed",)}
    sb = {"rec1": rec, "mlp1": mlp_half, "rec2": rec, "mlp2": mlp_half,
          "attn": attn, "mlp3": mlp_half}
    sb = jax.tree.map(lambda t: ("layers",) + t, sb,
                      is_leaf=lambda t: isinstance(t, tuple))
    axes = {"embed": ("vocab", "embed"), "blocks": sb, "ln_f": ("embed",)}
    nt = n_tail_rec(cfg)
    if nt:
        axes["tail"] = [{"rec": dict(rec), "mlp": dict(mlp_half)} for _ in range(nt)]
    return axes


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _rglru_gates(lp, xc: jnp.ndarray):
    """xc: (..., W) conv output.  Returns (a, gated_input) with
    a = sigmoid(lam)^(c*r) elementwise, input scaled by sqrt(1-a^2)*i*x."""
    r_gate = jax.nn.sigmoid(xc.astype(jnp.float32) * lp["w_gate_a"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(xc.astype(jnp.float32) * lp["w_gate_x"].astype(jnp.float32))
    log_a = -_C_RGLRU * r_gate * jax.nn.softplus(lp["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * i_gate * xc.astype(jnp.float32)
    return a, x_in


def _rglru_scan(a: jnp.ndarray, b: jnp.ndarray, h0=None) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t along axis 1 (associative, log-depth)."""

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _rec_block(lp, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    r = cfg.rglru
    h = L.rms_norm(x, lp["ln"])
    xb = jnp.einsum("bsd,dw->bsw", h, lp["w_xbranch"].astype(h.dtype))
    yb = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, lp["w_ybranch"].astype(h.dtype)))
    xc = xb.transpose(0, 2, 1)
    xc = shard(xc, "act_batch", "act_mlp", None)
    xc = dwconv(xc, lp["conv_w"].astype(xc.dtype), padding="causal",
                variant=r.conv_variant)
    xc = (xc + lp["conv_b"].astype(xc.dtype)[None, :, None]).transpose(0, 2, 1)
    a, b = _rglru_gates(lp, xc)
    hseq = _rglru_scan(a, b).astype(h.dtype)
    out = jnp.einsum("bsw,wd->bsd", hseq * yb, lp["w_out"].astype(h.dtype))
    return shard(x + out, "act_batch", "act_seq", "act_embed")


def _mlp_block(lp, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    return x + L.mlp(lp["mlp"], L.rms_norm(x, lp["ln_mlp"]), "gelu")


def _attn_block(lp, cfg: ArchConfig, x, positions, use_chunked) -> jnp.ndarray:
    h = L.rms_norm(x, lp["ln"])
    a, _ = L.attention(lp["attn"], h, attn_dims(cfg), positions=positions,
                       rope_theta=cfg.rope_theta, window=cfg.rglru.attn_window,
                       use_chunked=use_chunked)
    return x + a


def _superblock(sb, cfg: ArchConfig, x, positions, use_chunked) -> jnp.ndarray:
    x = _mlp_block(sb["mlp1"], cfg, _rec_block(sb["rec1"], cfg, x))
    x = _mlp_block(sb["mlp2"], cfg, _rec_block(sb["rec2"], cfg, x))
    x = _mlp_block(sb["mlp3"], cfg, _attn_block(sb["attn"], cfg, x, positions, use_chunked))
    return x


def forward(params, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma convention
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    use_chunked = S >= cfg.attn_chunk_threshold

    def body(x, sb):
        return _superblock(sb, cfg, x, positions, use_chunked), ()

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    for t in params.get("tail", []):
        x = _mlp_block(t["mlp"], cfg, _rec_block(t["rec"], cfg, x))
    return L.rms_norm(x, params["ln_f"])


def loss_fn(params, cfg: ArchConfig, batch) -> jnp.ndarray:
    hidden = forward(params, cfg, batch["tokens"])
    logits = L.unembed(hidden, params["embed"])  # tied embeddings
    return softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# serving: recurrent state + ring-buffer local-attention cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None):
    """Attention caches are bounded by the local window (ring buffer) — the
    property that makes long_500k feasible for this arch."""
    dtype = dtype or cfg.compute_dt
    r = cfg.rglru
    nb = n_superblocks(cfg)
    W = r.lru_width
    win = min(cache_len, r.attn_window)
    cache = {
        "conv1": jnp.zeros((nb, batch, W, r.d_conv - 1), dtype),
        "conv2": jnp.zeros((nb, batch, W, r.d_conv - 1), dtype),
        "state1": jnp.zeros((nb, batch, W), jnp.float32),
        "state2": jnp.zeros((nb, batch, W), jnp.float32),
        "k": jnp.zeros((nb, batch, win, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((nb, batch, win, cfg.n_kv, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    nt = n_tail_rec(cfg)
    if nt:
        cache["tail_conv"] = jnp.zeros((nt, batch, W, r.d_conv - 1), dtype)
        cache["tail_state"] = jnp.zeros((nt, batch, W), jnp.float32)
    return cache


def cache_axes(cfg: ArchConfig):
    kv = ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None)
    axes = {
        "conv1": ("layers", "cache_batch", "act_mlp", None),
        "conv2": ("layers", "cache_batch", "act_mlp", None),
        "state1": ("layers", "cache_batch", "act_mlp"),
        "state2": ("layers", "cache_batch", "act_mlp"),
        "k": kv, "v": kv, "pos": (),
    }
    if n_tail_rec(cfg):
        axes["tail_conv"] = ("layers", "cache_batch", "act_mlp", None)
        axes["tail_state"] = ("layers", "cache_batch", "act_mlp")
    return axes


def _rec_decode(lp, cfg, x, conv_st, state):
    """x: (B,1,D).  Returns (y, new_conv, new_state)."""
    h = L.rms_norm(x, lp["ln"])[:, 0]
    xb = h @ lp["w_xbranch"].astype(h.dtype)
    yb = jax.nn.gelu(h @ lp["w_ybranch"].astype(h.dtype))
    buf = jnp.concatenate([conv_st, xb[..., None]], axis=-1)     # (B,W,K)
    xc = jnp.einsum("bwk,wk->bw", buf, lp["conv_w"].astype(buf.dtype))
    xc = xc + lp["conv_b"].astype(xc.dtype)
    a, b = _rglru_gates(lp, xc)
    new_state = a * state + b
    out = (new_state.astype(h.dtype) * yb) @ lp["w_out"].astype(h.dtype)
    return x + out[:, None], buf[..., 1:], new_state


def _attn_decode_ring(lp, cfg, x, ck, cv, pos):
    """Ring-buffer windowed attention decode.  Slot = pos % win."""
    r = cfg.rglru
    win = ck.shape[1]
    B = x.shape[0]
    h = L.rms_norm(x, lp["ln"])
    dims = attn_dims(cfg)
    q, k, v = L._project_qkv(lp["attn"], h, h, dims)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    slot = pos % win
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
    # absolute position held by each ring slot
    s = jnp.arange(win, dtype=jnp.int32)
    kv_pos = pos - ((pos - s) % win)
    valid = (kv_pos >= 0) & (kv_pos <= pos) & (pos - kv_pos < r.attn_window)
    bias = jnp.where(valid, 0.0, -1e30)[None, :]                  # (1, win)
    out = L._sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), bias, dims)
    y = out.reshape(B, 1, -1) @ lp["attn"]["wo"].astype(x.dtype)
    return x + y, ck, cv


def decode_step(params, cfg: ArchConfig, cache, tokens: jnp.ndarray):
    B, S = tokens.shape
    assert S == 1
    pos = cache["pos"]
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    def body(x, inp):
        sb, c1, c2, s1, s2, ck, cv = inp
        x, nc1, ns1 = _rec_decode(sb["rec1"], cfg, x, c1, s1)
        x = _mlp_block(sb["mlp1"], cfg, x)
        x, nc2, ns2 = _rec_decode(sb["rec2"], cfg, x, c2, s2)
        x = _mlp_block(sb["mlp2"], cfg, x)
        x, nk, nv = _attn_decode_ring(sb["attn"], cfg, x, ck, cv, pos)
        x = _mlp_block(sb["mlp3"], cfg, x)
        return x, (nc1, nc2, ns1, ns2, nk, nv)

    x, (nc1, nc2, ns1, ns2, nk, nv) = jax.lax.scan(
        body, x,
        (params["blocks"], cache["conv1"], cache["conv2"],
         cache["state1"], cache["state2"], cache["k"], cache["v"]))
    new_cache = {"conv1": nc1, "conv2": nc2, "state1": ns1, "state2": ns2,
                 "k": nk, "v": nv, "pos": pos + 1}
    for i, t in enumerate(params.get("tail", [])):
        x, ncv, nst = _rec_decode(t["rec"], cfg, x, cache["tail_conv"][i], cache["tail_state"][i])
        x = _mlp_block(t["mlp"], cfg, x)
        if i == 0:
            new_cache["tail_conv"] = cache["tail_conv"]
            new_cache["tail_state"] = cache["tail_state"]
        new_cache["tail_conv"] = new_cache["tail_conv"].at[i].set(ncv)
        new_cache["tail_state"] = new_cache["tail_state"].at[i].set(nst)
    hidden = L.rms_norm(x, params["ln_f"])
    logits = L.unembed(hidden, params["embed"])
    return logits, new_cache


def prefill(params, cfg: ArchConfig, tokens: jnp.ndarray):
    """Logits-only prefill (forward pass); decode state handoff is done by
    replaying the last window through decode_step (DESIGN.md note) — the
    roofline-relevant compute is the forward pass lowered here."""
    hidden = forward(params, cfg, tokens)
    logits = L.unembed(hidden[:, -1:, :], params["embed"])
    return logits, init_cache(cfg, tokens.shape[0], min(tokens.shape[1], cfg.rglru.attn_window))


def n_params(cfg: ArchConfig) -> int:
    r = cfg.rglru
    W, D = r.lru_width, cfg.d_model
    rec = 2 * D * W + W * r.d_conv + 4 * W + W * D + D
    mlp_half = 3 * D * cfg.d_ff + D
    attn = D * (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim + cfg.n_heads * cfg.head_dim * D + D
    per_sb = 2 * rec + 3 * mlp_half + attn
    tail = n_tail_rec(cfg) * (rec + mlp_half)
    return n_superblocks(cfg) * per_sb + tail + cfg.vocab * D + D


def n_active_params(cfg: ArchConfig) -> int:
    return n_params(cfg)
