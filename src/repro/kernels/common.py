"""Shared helpers for the depthwise-convolution kernel family.

Conventions (paper §IV-A):
  x : (B, H, L)   input, row-major, temporal axis `L` is stride-1 (lane dim)
  k : (H, K)      one 1-D filter per channel, contiguous per channel
  y : (B, H, L)   output, same length as input ("same"-style padding)

The forward operator is a *correlation* over a zero-padded input:

    y[b, h, t] = sum_j  x_pad[b, h, t + j] * k[h, j]

where ``x_pad`` is ``x`` padded with ``p_left`` zeros on the left and
``p_right = K - 1 - p_left`` zeros on the right.  ``padding='same'`` uses
``p_left = K // 2`` (the paper's convention, eq. (7)-(8); for even K the
output is implicitly cropped to L, matching the paper's PyTorch reference).
``padding='causal'`` uses ``p_left = K - 1`` (the Mamba/RG-LRU short-conv
convention: the window for output t ends at t).

Adjoint identities used by the backward kernels (derived from eq. (8); the
paper's eq. (9) assumes odd K — we implement the exact adjoint, validated
against ``jax.vjp``):

    dx = dwconv_fwd(dy, flip(k), p_left' = K - 1 - p_left)
    dk[h, j] = sum_{b, t} dy[b, h, t] * x_pad[b, h, t + j]
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Tuple

Padding = Literal["same", "causal"]


def pad_widths(K: int, padding: Padding) -> Tuple[int, int]:
    """(left, right) zero-padding for a kernel of length K."""
    if padding == "same":
        left = K // 2
    elif padding == "causal":
        left = K - 1
    else:
        raise ValueError(f"unknown padding {padding!r}")
    return left, K - 1 - left


def adjoint_pad_widths(K: int, padding: Padding) -> Tuple[int, int]:
    """Padding for the input-gradient pass (flipped-kernel correlation)."""
    left, right = pad_widths(K, padding)
    return right, left


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


# TPU tiling constants (v5e): VPU vector registers are (8, 128) for f32,
# (16, 128) for bf16; the lane (minor) dimension is 128.
LANE = 128
SUBLANE_F32 = 8
SUBLANE_BF16 = 16


def sublane(dtype) -> int:
    import jax.numpy as jnp

    return SUBLANE_BF16 if dtype == jnp.bfloat16 else SUBLANE_F32


@dataclasses.dataclass(frozen=True)
class DWConvDims:
    """Static problem dimensions shared by every kernel variant."""

    B: int
    H: int
    L: int
    K: int
    padding: Padding = "same"

    @property
    def p_left(self) -> int:
        return pad_widths(self.K, self.padding)[0]

    @property
    def p_right(self) -> int:
        return pad_widths(self.K, self.padding)[1]

    @property
    def Lp(self) -> int:
        """Padded temporal length (valid-correlation input length)."""
        return self.L + self.K - 1
