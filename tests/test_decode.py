"""Streaming-decode validation: the fused single-step ring-buffer conv.

Four layers of proof, mirroring the repo's kernel/model/schedule split:

  * step equivalence — N successive single-step decode calls reproduce one
    full-sequence causal ``dwconv_act``: *bitwise* for the f32 ``act="none"``
    XLA chain (the reference shares ``_fwd_acc``'s ascending-tap operation
    order), to FMA-contraction tolerance for the Pallas variants — which are
    in turn bit-identical to each other;
  * ring round-trip under continuous batching — admission/eviction with
    ragged active sets never perturbs an inactive slot's carried state;
  * schedule legality/VMEM at serving shapes, plus the static
    model↔kernel cross-check (``verify_config``) for every decode variant;
  * the prefill ring handoff — decode after ``ssm.prefill`` continues the
    exact stream the full forward saw (the bug this PR's satellite fixes),
    including split-conv layouts and prompts shorter than the ring.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dwconv as dw
from repro.kernels import ops, ref
from repro.kernels.common import DWConvDims

DECODE_SHAPES = [
    # (B, H, K) — lane-aligned, ragged-channel, wide-filter, tiny.
    (2, 128, 4),
    (3, 100, 7),
    (1, 256, 48),
    (2, 3, 2),
]
SERVE_DIMS = [
    DWConvDims(B=8, H=192, L=1, K=4, padding="causal"),
    DWConvDims(B=64, H=1536, L=1, K=4, padding="causal"),
    DWConvDims(B=5, H=100, L=1, K=7, padding="causal"),
]
SMALL_OPTS = ops.KernelOptions(block_t=128, batch_chunk=2)


def _rand(shape, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


def _stream_decode(xs, k, bias=None, act="none", variant="xla", opts=None):
    """Run the (B, H, L) stream through L single-step decode calls from a
    zero ring; returns the stacked outputs (B, H, L) and the final ring."""
    B, H, L = xs.shape
    ring = jnp.zeros((B, H, k.shape[1] - 1), xs.dtype)
    outs = []
    for t in range(L):
        y, ring = dw.dwconv_decode(ring, xs[:, :, t], k, bias,
                                   act=act, variant=variant, opts=opts)
        outs.append(y)
    return jnp.stack(outs, axis=-1), ring


# ---------------------------------------------------------------------------
# step equivalence vs the full-sequence operator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,K", DECODE_SHAPES)
def test_xla_chain_bitwise_vs_full_conv(B, H, K):
    """f32, act=none: the single-step chain IS the causal conv, bit for bit."""
    L = K + 5
    xs = _rand((B, H, L), 0)
    k = _rand((H, K), 1)
    want = ref.dwconv_act_ref(xs, k, padding="causal")
    got, _ = _stream_decode(xs, k, variant="xla")
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,H,K", DECODE_SHAPES)
def test_xla_chain_epilogue_allclose(B, H, K):
    L = K + 3
    xs = _rand((B, H, L), 0)
    k = _rand((H, K), 1)
    bias = _rand((H,), 2)
    want = ref.dwconv_act_ref(xs, k, bias, act="silu", padding="causal")
    got, _ = _stream_decode(xs, k, bias, act="silu", variant="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("variant", ["rows", "chanblock"])
@pytest.mark.parametrize("B,H,K", DECODE_SHAPES)
def test_pallas_variants_match_ref(variant, B, H, K):
    if K < 2:
        pytest.skip("Pallas decode needs a non-empty ring")
    ring = _rand((B, H, K - 1), 0)
    x = _rand((B, H), 1)
    k = _rand((H, K), 2)
    bias = _rand((H,), 3)
    for b, act in ((None, "none"), (bias, "silu")):
        want_y, want_r = ref.dwconv_decode_ref(ring, x, k, b, act)
        got_y, got_r = dw.dwconv_decode(ring, x, k, b, act=act,
                                        variant=variant, opts=SMALL_OPTS)
        np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                                   atol=1e-5, rtol=1e-5)
        # the shifted ring is pure data movement: bitwise always
        assert np.array_equal(np.asarray(got_r), np.asarray(want_r))


@pytest.mark.parametrize("B,H,K", DECODE_SHAPES)
def test_pallas_variants_bitwise_identical(B, H, K):
    """rows and chanblock share one accumulation order: bit-identical."""
    if K < 2:
        pytest.skip("Pallas decode needs a non-empty ring")
    ring = _rand((B, H, K - 1), 0)
    x = _rand((B, H), 1)
    k = _rand((H, K), 2)
    bias = _rand((H,), 3)
    ya, ra = dw.dwconv_decode(ring, x, k, bias, act="silu",
                              variant="rows", opts=SMALL_OPTS)
    yb, rb = dw.dwconv_decode(ring, x, k, bias, act="silu",
                              variant="chanblock", opts=SMALL_OPTS)
    assert np.array_equal(np.asarray(ya), np.asarray(yb))
    assert np.array_equal(np.asarray(ra), np.asarray(rb))


def test_k1_empty_ring_routes_to_reference():
    """K=1 has no ring; every variant must still produce the pointwise conv
    (the op routes to the XLA reference instead of an illegal launch)."""
    x = _rand((2, 8), 0)
    k = _rand((8, 1), 1)
    ring = jnp.zeros((2, 8, 0), jnp.float32)
    want = x * k[:, 0][None, :]
    for variant in ops.DECODE_VARIANTS:
        y, new_ring = dw.dwconv_decode(ring, x, k, variant=variant)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-6)
        assert new_ring.shape == (2, 8, 0)


def test_wrapper_validates_shapes_and_variant_mapping():
    ring = _rand((2, 8, 3), 0)
    x = _rand((2, 8), 1)
    k = _rand((8, 4), 2)
    with pytest.raises(ValueError, match="bad shapes"):
        dw.dwconv_decode(ring[0], x, k)
    with pytest.raises(ValueError, match="bias must be per-channel"):
        dw.dwconv_decode(ring, x, k, _rand((3,), 3))
    with pytest.raises(ValueError, match="unknown act"):
        dw.dwconv_decode(ring, x, k, act="tanh")
    # model-level variant names resolve by their forward family
    assert dw.decode_variant_for("xla") == "xla"
    assert dw.decode_variant_for("rows") == "rows"
    assert dw.decode_variant_for("row") == "auto"      # Pallas spec -> tuned
    assert dw.train_variant_for("rows") == "auto"
    assert dw.train_variant_for("chanblock") == "auto"
    assert dw.train_variant_for("row") == "row"
    assert dw.train_variant_for("xla") == "xla"
    with pytest.raises(Exception):
        dw.decode_variant_for("not-a-variant")


# ---------------------------------------------------------------------------
# ring round-trip under admission/eviction (continuous batching)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["xla", "rows"])
def test_ragged_active_set_round_trip(variant):
    """Dense pool steps with a ragged active mask: live slots advance exactly
    like a dense batch of their own; dead slots' rings are untouched bitwise."""
    B, H, K, steps = 6, 64, 4, 5
    k = _rand((H, K), 0)
    ring = _rand((B, H, K - 1), 1)
    rng = np.random.default_rng(2)
    masks = [jnp.asarray(rng.integers(0, 2, size=B).astype(bool))
             for _ in range(steps)]
    xs = [_rand((B, H), 10 + t) for t in range(steps)]

    pool = ring
    per_slot = [ring[b] for b in range(B)]  # independent per-slot replay
    for t in range(steps):
        y, pool = ops.dwconv_decode_ragged_op(
            pool, xs[t], k, masks[t], variant=variant, opts=SMALL_OPTS)
        host_mask = np.asarray(masks[t])
        for b in range(B):
            if host_mask[b]:
                yb, rb = dw.dwconv_decode(per_slot[b][None], xs[t][b][None],
                                          k, variant=variant, opts=SMALL_OPTS)
                per_slot[b] = rb[0]
                np.testing.assert_allclose(np.asarray(y[b]), np.asarray(yb[0]),
                                           atol=1e-6, rtol=1e-6)
            else:
                assert np.array_equal(np.asarray(y[b]), np.zeros((H,)))
        # pooled rings must equal the independent replays bitwise
        for b in range(B):
            assert np.array_equal(np.asarray(pool[b]), np.asarray(per_slot[b]))


def test_eviction_then_admission_overwrites_cleanly():
    """A slot evicted mid-stream and re-admitted with fresh state behaves as
    if the pool had never seen the previous occupant."""
    B, H, K = 2, 32, 4
    k = _rand((H, K), 0)
    pool = _rand((B, H, K - 1), 1)
    stale = pool
    # slot 1 evicted: three masked steps must not move its ring
    for t in range(3):
        _, pool = ops.dwconv_decode_ragged_op(
            pool, _rand((B, H), 5 + t), k,
            jnp.asarray([True, False]), variant="xla")
    assert np.array_equal(np.asarray(pool[1]), np.asarray(stale[1]))
    # re-admission scatters a fresh ring; the next dense step matches a
    # from-scratch batch-1 run exactly
    fresh = _rand((1, H, K - 1), 9)
    pool = pool.at[1].set(fresh[0])
    x = _rand((B, H), 20)
    y, pool = ops.dwconv_decode_ragged_op(
        pool, x, k, jnp.asarray([True, True]), variant="xla")
    y1, r1 = dw.dwconv_decode(fresh, x[1][None], k, variant="xla")
    assert np.array_equal(np.asarray(y[1]), np.asarray(y1[0]))
    assert np.array_equal(np.asarray(pool[1]), np.asarray(r1[0]))


# ---------------------------------------------------------------------------
# schedules: legality, VMEM, and the static model<->kernel cross-check
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", SERVE_DIMS, ids=lambda d: f"{d.B}x{d.H}x{d.K}")
@pytest.mark.parametrize("variant", ["rows", "chanblock", "xla"])
def test_decode_schedules_legal_at_serving_shapes(d, variant):
    from repro import perfmodel

    s = perfmodel.schedule_for("decode", variant, d, 4,
                               block_t=512, batch_chunk=128,
                               epilogue="bias+silu")
    ok, reason = perfmodel.check_legality(s)
    assert ok, reason
    est = perfmodel.derive_traffic(s)
    assert est.reliable
    # per-step traffic is O(B*H*K): bounded by a few ring copies, far below
    # the full-conv-over-cache baseline at any realistic cache length
    assert est.bytes_moved <= 4 * 4 * (2 * d.B * d.H * d.K + d.H * d.K + d.H)
    # AI ~ K flops/byte scale: single-step decode is firmly memory-bound
    assert est.arithmetic_intensity < 1.0


def test_decode_k1_schedule_illegal_with_agreeing_wrapper():
    from repro import perfmodel
    from repro.verify.schedule_check import verify_config

    d = DWConvDims(B=2, H=128, L=1, K=1, padding="causal")
    s = perfmodel.schedule_for("decode", "rows", d, 4)
    ok, reason = perfmodel.check_legality(s)
    assert not ok and "K >= 2" in reason
    # the wrapper agrees by routing to the XLA reference: "illegal", no
    # findings (VER107 only fires when a Pallas kernel actually launched)
    status, findings = verify_config("decode", "rows", d)
    assert status == "illegal" and not findings


@pytest.mark.parametrize("variant", ["rows", "chanblock"])
def test_decode_verify_config_verified(variant):
    """VER101-VER108: the decode schedules describe the decode kernels."""
    from repro.verify.schedule_check import verify_config

    d = SERVE_DIMS[2]  # ragged extents exercise the padding math hardest
    for epi in ("none", "bias+silu"):
        status, findings = verify_config("decode", variant, d, epilogue=epi,
                                         block_t=128, batch_chunk=2)
        assert status == "verified", [f.render() for f in findings]


def test_decode_tuning_space_normalizes():
    from repro.tuning import space

    d = SERVE_DIMS[0]
    cands = space.search_space(d, "decode")
    assert cands, "decode tuning space is empty"
    variants = {c.variant for c in cands}
    assert {"rows", "chanblock", "xla"} <= variants
    for c in cands:
        assert c.path == "decode"
        ok, reason = space.is_legal(c, d)
        assert ok, reason


# ---------------------------------------------------------------------------
# the prefill ring handoff (satellite bugfix regression)
# ---------------------------------------------------------------------------


def _ssm_decode_after_prefill(cfg, S_prompt, S_total, seed=0):
    """(decode-after-prefill logits, full-forward logits) over the same
    stream — they must agree position by position past the prompt."""
    from repro.models import layers as L, ssm
    from repro.models.api import get_model

    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, S_total),
                              0, cfg.vocab)
    full = L.unembed(ssm.forward(params, cfg, toks), params["embed"])
    _, cache = ssm.prefill(params, cfg, toks[:, :S_prompt])
    outs = []
    for t in range(S_prompt, S_total):
        logits, cache = model.decode_step(params, cache,
                                          {"tokens": toks[:, t:t + 1]})
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), full[:, S_prompt:]


@pytest.mark.parametrize("conv_variant", ["xla", "row"])
def test_prefill_populates_conv_ring(conv_variant):
    """Decode after prefill must continue the exact stream — before the fix
    the ring stayed zeroed and the first d_conv-1 decoded positions drifted."""
    from repro.configs.mamba2_1_3b import SMOKE

    cfg = dataclasses.replace(
        SMOKE, ssm=dataclasses.replace(SMOKE.ssm, conv_variant=conv_variant))
    got, want = _ssm_decode_after_prefill(cfg, S_prompt=8, S_total=16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-4, rtol=1e-4)


def test_prefill_ring_split_conv():
    from repro.configs.mamba2_1_3b import SMOKE

    cfg = dataclasses.replace(
        SMOKE, ssm=dataclasses.replace(SMOKE.ssm, split_conv=True))
    got, want = _ssm_decode_after_prefill(cfg, S_prompt=8, S_total=16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-4, rtol=1e-4)


def test_prefill_ring_short_prompt():
    """Prompt shorter than the ring (S < d_conv-1): the tail is left-padded
    with zeros, matching the zero state a from-scratch decode starts with."""
    from repro.configs.mamba2_1_3b import SMOKE

    cfg = dataclasses.replace(
        SMOKE, ssm=dataclasses.replace(SMOKE.ssm, chunk=2))
    got, want = _ssm_decode_after_prefill(cfg, S_prompt=2, S_total=6)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-4, rtol=1e-4)
