"""Three-term roofline analysis from compiled artifacts (deliverable g).

For a compiled SPMD program, ``cost_analysis()`` reports *per-device* FLOPs
and bytes (the SPMD module is the per-device program), and the HLO parser
reports per-device collective operand bytes.  With global quantities defined
as per-device x chips, the assignment's three terms

    compute    = HLO_FLOPs_global            / (chips x peak_flops)
    memory     = HLO_bytes_global            / (chips x hbm_bw)
    collective = collective_bytes_global     / (chips x ici_bw)

reduce to per-device quantity / per-chip rate, which is how they are
computed here (exactly equivalent, no double counting).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.analysis.hlo import HLOAnalysis, analyze_hlo
from repro.analysis.hw import TPU_V5E, HardwareModel


@dataclasses.dataclass
class RooflineReport:
    label: str
    chips: int
    # per-device quantities from the compiled artifact
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_wire_bytes_per_device: float
    # the three terms, in seconds
    compute_s: float
    memory_s: float
    collective_s: float
    # usefulness accounting
    model_flops: float = 0.0            # 6 N D (dense) / 6 N_active D (MoE)
    peak_memory_per_device: float = 0.0  # from memory_analysis()
    collective_breakdown: Optional[Dict[str, float]] = None
    op_histogram: Optional[Dict[str, int]] = None

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: sum of terms (reported for context)."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def step_time_overlap_s(self) -> float:
        """Perfect-overlap lower bound: max of terms = the roofline bound."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def hlo_flops_global(self) -> float:
        return self.flops_per_device * self.chips

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        if self.hlo_flops_global <= 0:
            return 0.0
        return self.model_flops / self.hlo_flops_global

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound step time = achievable MFU at the bound."""
        if self.step_time_overlap_s <= 0:
            return 0.0
        useful_compute_s = self.compute_s * self.useful_flops_ratio
        return useful_compute_s / self.step_time_overlap_s

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(
            dominant=self.dominant,
            step_time_s=self.step_time_s,
            step_time_overlap_s=self.step_time_overlap_s,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
            hlo_flops_global=self.hlo_flops_global,
        )
        return d


def _cost_get(cost: Dict[str, float], key: str) -> float:
    v = cost.get(key, 0.0)
    return float(v) if v and v > 0 else 0.0


def roofline_from_compiled(
    compiled,
    *,
    label: str,
    chips: int,
    model_flops: float = 0.0,
    hw: HardwareModel = TPU_V5E,
    hlo_analysis: Optional[HLOAnalysis] = None,
    hlo_text: Optional[str] = None,
) -> RooflineReport:
    """Build the three-term report from a jax compiled executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # some backends return [dict]
        cost = cost[0]
    flops = _cost_get(cost, "flops")
    bytes_accessed = _cost_get(cost, "bytes accessed")
    if hlo_analysis is None:
        text = hlo_text if hlo_text is not None else compiled.as_text()
        hlo_analysis = analyze_hlo(text, num_partitions=chips)
    # XLA's cost_analysis counts while-loop bodies ONCE (verified on the CPU
    # backend) — scanned-layer programs are undercounted by ~n_layers x.  The
    # counter-free analytic reconstruction applies trip-count multipliers;
    # prefer it whenever it sees more work than XLA's number.
    flops = max(flops, hlo_analysis.analytic_flops)
    bytes_accessed = max(bytes_accessed, hlo_analysis.analytic_bytes)
    mem = compiled.memory_analysis()
    peak = 0.0
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes"):
        peak += float(getattr(mem, attr, 0.0) or 0.0)

    coll = hlo_analysis.collective_operand_bytes
    wire = hlo_analysis.collective_wire_bytes
    return RooflineReport(
        label=label,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective_bytes_per_device=coll,
        collective_wire_bytes_per_device=wire,
        compute_s=flops / hw.peak_flops,
        memory_s=bytes_accessed / hw.hbm_bw,
        collective_s=(coll / hw.ici_bw) if hw.ici_bw else 0.0,
        model_flops=model_flops,
        peak_memory_per_device=peak,
        collective_breakdown=hlo_analysis.bytes_by_kind(),
        op_histogram=hlo_analysis.op_histogram,
    )


def dense_model_flops(n_params: float, tokens: float) -> float:
    """MODEL_FLOPS = 6 N D for a training step over D tokens."""
    return 6.0 * n_params * tokens


def forward_model_flops(n_params: float, tokens: float) -> float:
    """2 N D for inference (fwd only)."""
    return 2.0 * n_params * tokens
