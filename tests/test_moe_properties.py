"""Property tests for the MoE dispatch machinery.

``hypothesis`` is optional (see README "Optional dependencies"): without it
the randomized test degrades to a single-seed deterministic check instead of
aborting collection for the whole tier-1 suite.
"""
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None
    st = None
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.olmoe_1b_7b import SMOKE
from repro.models import moe


def _params(cfg, seed=0):
    return moe._init_moe_block(jax.random.PRNGKey(seed), cfg)


def _check_moe_output_finite_and_bounded(seed, B):
    cfg = SMOKE
    p = _params(cfg, 0)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(B, 16, cfg.d_model)),
                    jnp.float32) * 0.1
    out, aux = moe.moe_mlp(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert np.isfinite(float(aux)) and float(aux) >= 0


if hypothesis is None:

    def test_moe_output_finite_and_bounded():
        _check_moe_output_finite_and_bounded(0, 2)

else:

    @hypothesis.given(st.integers(0, 2**31 - 1), st.integers(1, 3))
    @hypothesis.settings(max_examples=10, deadline=None)
    def test_moe_output_finite_and_bounded(seed, B):
        _check_moe_output_finite_and_bounded(seed, B)


def test_moe_capacity_drops_are_graceful():
    """With capacity_factor -> tiny, most tokens drop; output shrinks toward
    the shared/zero path but stays finite (no NaN from empty experts)."""
    import dataclasses

    cfg = SMOKE
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    p = _params(cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    out_full, _ = moe.moe_mlp(p, x, cfg)
    out_tight, _ = moe.moe_mlp(p, x, tight)
    assert bool(jnp.all(jnp.isfinite(out_tight)))
    assert float(jnp.linalg.norm(out_tight)) <= float(jnp.linalg.norm(out_full)) + 1e-3


def test_moe_aux_loss_bounds():
    """Switch aux loss is minimized at ~top_k for balanced routing and
    bounded by ~E x top_k/... for fully-collapsed routing.  A uniform router
    (all-ties) collapses selection onto the first k experts — the aux loss
    must detect that imbalance (> k x the balanced value is impossible;
    balanced would be ~ top_k/E x E = top_k... we assert the bracket)."""
    cfg = SMOKE
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    # random router: slightly above the floor (selection-prob correlation)
    p = _params(cfg)
    _, aux_rand = moe.moe_mlp(p, x, cfg)
    # uniform logits: probs uniform -> aux at the exact floor k
    p2 = dict(p)
    p2["router"] = jnp.zeros_like(p["router"])
    _, aux_tied = moe.moe_mlp(p2, x, cfg)
    assert abs(float(aux_tied) - k) < 1e-3  # floor = top_k
    assert k - 1e-3 <= float(aux_rand) <= E * k


def test_moe_permutation_equivariance_over_batch():
    """Group-local dispatch: permuting tokens within one dispatch group
    permutes outputs identically (capacity permitting)."""
    import dataclasses

    cfg = dataclasses.replace(
        SMOKE, moe=dataclasses.replace(SMOKE.moe, capacity_factor=8.0))
    p = _params(cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 16, cfg.d_model)),
                    jnp.float32)
    perm = np.random.default_rng(1).permutation(16)
    out1, _ = moe.moe_mlp(p, x, cfg)
    out2, _ = moe.moe_mlp(p, x[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(out1[:, perm]), np.asarray(out2),
                               atol=2e-4, rtol=1e-3)
