"""Golden equivalence: schedule-derived numbers == pre-refactor formulas.

The declarative ``repro.perfmodel`` refactor (PR 5) replaced four
hand-maintained copies of the analytical model — traffic byte formulas in
``analysis/traffic.py``, VMEM/legality predicates in ``tuning/space.py``,
the tuner's stage-1 cost in ``tuning/cost.py``, and the tile geometry in
``kernels/ops.py`` — with derivations from one registered
:class:`~repro.perfmodel.KernelSchedule` per kernel configuration.

This suite pins the refactor: every derived traffic/VMEM/legality/cost
number must agree *exactly* (integer-byte equality, no tolerances) with
the frozen pre-refactor implementations in ``tests/golden_legacy_model.py``
over a parameterized (B, H, L, K, variant, block_h, block_t, batch_chunk,
epilogue) grid that includes the paper's study shape, the long-sequence
shape (tiled halo charges + partials accounting), and every epilogue
configuration.
"""
from __future__ import annotations

import pytest

import golden_legacy_model as legacy
from repro import perfmodel
from repro.analysis import traffic
from repro.analysis.hw import P100, TPU_V5E
from repro.kernels import ops
from repro.kernels.common import DWConvDims
from repro.kernels.epilogue import EPILOGUE_KEYS
from repro.tuning import cost, space

# The grid: paper study shape, CPU-reduced paper shape, the long-sequence
# shape (PR 3's time-tiled regime), a causal non-divisible shape, and a
# deliberately ragged small shape (H % Hb != 0, L % LANE != 0).
SHAPES = [
    DWConvDims(B=16384, H=128, L=48, K=48),        # paper
    DWConvDims(B=64, H=128, L=48, K=48),           # paper, CPU batch
    DWConvDims(B=8, H=64, L=16384, K=4),           # long sequence (tiled)
    DWConvDims(B=4, H=24, L=100, K=5, padding="causal"),
    DWConvDims(B=3, H=17, L=300, K=7),             # ragged
]
TILINGS = [
    (8, 512, 128),     # defaults
    (4, 128, 16),      # small tiles: tiled bwd regime on long L
    (16, 1024, 64),
    (12, 300, 100),    # off-lattice knobs (clamping paths)
]
ITEMSIZES = [4, 2]
EPILOGUES = list(EPILOGUE_KEYS)

# The tuning paths that existed at the PR-5 golden freeze.  Paths added
# later (e.g. the streaming-decode path) have no legacy formula to agree
# with — they are covered by their own suites, not the golden pin.
LEGACY_PATHS = ("fwd", "bwd_in", "bwd_k", "bwd_fused")

FWD_VARIANTS = ("naive", "lane", "block", "row", "xla")
BWDK_VARIANTS = ("naive", "twostage", "accum", "xla")
BWD_FUSED_VARIANTS = ("fused", "fused_partials", "split")


def _assert_estimates_equal(old, new, tag):
    for f in ("flops", "bytes_read", "bytes_written", "transactions",
              "aligned", "reliable"):
        assert getattr(old, f) == getattr(new, f), (
            f"{tag}: field {f!r} diverged: "
            f"legacy={getattr(old, f)!r} derived={getattr(new, f)!r}")


@pytest.mark.parametrize("d", SHAPES, ids=str)
@pytest.mark.parametrize("tiling", TILINGS, ids=str)
@pytest.mark.parametrize("itemsize", ITEMSIZES)
def test_golden_traffic_all_paths(d, tiling, itemsize):
    """Old-vs-derived traffic agrees exactly on every (path, variant)."""
    bh, bt, bc = tiling
    for v in FWD_VARIANTS:
        _assert_estimates_equal(
            legacy.fwd_traffic(d, v, itemsize, block_h=bh, block_t=bt),
            traffic.fwd_traffic(d, v, itemsize, block_h=bh, block_t=bt),
            f"fwd/{v}")
    for v in BWDK_VARIANTS:
        _assert_estimates_equal(
            legacy.bwdk_traffic(d, v, itemsize, block_h=bh, block_t=bt,
                                batch_chunk=bc),
            traffic.bwdk_traffic(d, v, itemsize, block_h=bh, block_t=bt,
                                 batch_chunk=bc),
            f"bwd_k/{v}")
    for v in BWD_FUSED_VARIANTS:
        _assert_estimates_equal(
            legacy.bwd_fused_traffic(d, v, itemsize, block_h=bh, block_t=bt,
                                     batch_chunk=bc),
            traffic.bwd_fused_traffic(d, v, itemsize, block_h=bh, block_t=bt,
                                      batch_chunk=bc),
            f"bwd_fused/{v}")
    _assert_estimates_equal(
        legacy.bwd_split_traffic(d, itemsize, block_h=bh, block_t=bt,
                                 batch_chunk=bc),
        traffic.bwd_split_traffic(d, itemsize, block_h=bh, block_t=bt,
                                  batch_chunk=bc),
        "bwd_split")


@pytest.mark.parametrize("d", SHAPES, ids=str)
@pytest.mark.parametrize("epi", EPILOGUES)
def test_golden_traffic_epilogue(d, epi):
    """Epilogue accounting (fused, unfused-composition, recompute-split,
    whole-block) agrees exactly, itemsize 4 and 2, tiled and untiled."""
    for bh, bt, bc in ((8, 512, 128), (4, 128, 16)):
        for itemsize in ITEMSIZES:
            for fused in (True, False):
                _assert_estimates_equal(
                    legacy.epilogue_fwd_traffic(
                        d, "row", itemsize, epilogue=epi, fused=fused,
                        block_h=bh, block_t=bt),
                    traffic.epilogue_fwd_traffic(
                        d, "row", itemsize, epilogue=epi, fused=fused,
                        block_h=bh, block_t=bt),
                    f"epilogue_fwd/{epi}/fused={fused}")
                _assert_estimates_equal(
                    legacy.epilogue_block_traffic(
                        d, itemsize, epilogue=epi, fused=fused, block_h=bh,
                        block_t=bt, batch_chunk=bc),
                    traffic.epilogue_block_traffic(
                        d, itemsize, epilogue=epi, fused=fused, block_h=bh,
                        block_t=bt, batch_chunk=bc),
                    f"epilogue_block/{epi}/fused={fused}")
            for v in BWD_FUSED_VARIANTS:
                _assert_estimates_equal(
                    legacy.epilogue_bwd_traffic(
                        d, v, itemsize, epilogue=epi, block_h=bh, block_t=bt,
                        batch_chunk=bc),
                    traffic.epilogue_bwd_traffic(
                        d, v, itemsize, epilogue=epi, block_h=bh, block_t=bt,
                        batch_chunk=bc),
                    f"epilogue_bwd/{v}/{epi}")
            _assert_estimates_equal(
                legacy.epilogue_unfused_bwd_traffic(
                    d, itemsize, epilogue=epi, block_h=bh, block_t=bt,
                    batch_chunk=bc),
                traffic.epilogue_unfused_bwd_traffic(
                    d, itemsize, epilogue=epi, block_h=bh, block_t=bt,
                    batch_chunk=bc),
                f"epilogue_unfused/{epi}")


@pytest.mark.parametrize("d", SHAPES, ids=str)
@pytest.mark.parametrize("variant", ("naive", "gmc", "shared", "warp"))
def test_golden_traffic_paper_mode(d, variant):
    for itemsize in ITEMSIZES:
        _assert_estimates_equal(
            legacy.paper_fwd_traffic(d, variant, itemsize),
            traffic.paper_fwd_traffic(d, variant, itemsize),
            f"paper_fwd/{variant}")
        _assert_estimates_equal(
            legacy.paper_bwdk_traffic(d, variant, itemsize),
            traffic.paper_bwdk_traffic(d, variant, itemsize),
            f"paper_bwdk/{variant}")


@pytest.mark.parametrize("d", SHAPES, ids=str)
@pytest.mark.parametrize("tiling", TILINGS, ids=str)
@pytest.mark.parametrize("itemsize", ITEMSIZES)
def test_golden_vmem_working_set(d, tiling, itemsize):
    """Per-grid-cell VMEM footprints agree exactly for every staged
    (path, variant), trivial and epilogue, tiled and untiled."""
    bh, bt, bc = tiling
    cases = [("fwd", v) for v in ("naive", "lane", "block", "row")]
    cases += [("bwd_in", v) for v in ("naive", "lane", "block", "row")]
    cases += [("bwd_k", v) for v in ("naive", "twostage", "accum")]
    cases += [("bwd_fused", v) for v in ("fused", "fused_partials")]
    for path, v in cases:
        epis = EPILOGUES if path in ("fwd", "bwd_fused") else ("none",)
        for epi in epis:
            c = space.Candidate(path, v, bh, bt, bc)
            old = legacy.vmem_working_set_bytes(
                path, v, d, itemsize, block_h=bh, block_t=bt,
                batch_chunk=bc, epilogue=epi)
            new = space._vmem_working_set_bytes(c, d, itemsize, epi)
            assert old == new, (
                f"VMEM diverged for {path}/{v}/{epi} on {d} "
                f"bh={bh} bt={bt} bc={bc} itemsize={itemsize}: "
                f"legacy={old} derived={new}")


@pytest.mark.parametrize("d", SHAPES, ids=str)
@pytest.mark.parametrize("tiling", TILINGS + [(8, 300, 128), (8, 4, 128)],
                         ids=str)
@pytest.mark.parametrize("hw", [TPU_V5E, P100], ids=lambda h: h.name)
def test_golden_legality_verdicts(d, tiling, hw):
    """(ok, reason) verdicts agree exactly — including the lane-alignment
    and halo-fit rejections and the VMEM bound (P100's 64 KiB shared-memory
    model exercises the VMEM branch on most staged candidates)."""
    bh, bt, bc = tiling
    for path in LEGACY_PATHS:
        for v in space._space_variants(path):
            epis = EPILOGUES if path in ("fwd", "bwd_fused") else ("none",)
            for epi in epis:
                c = space.Candidate(path, v, bh, bt, bc)
                old = legacy.is_legal(path, v, d, itemsize=4, hw=hw,
                                      block_h=bh, block_t=bt, batch_chunk=bc,
                                      epilogue=epi)
                new = space.is_legal(c, d, itemsize=4, hw=hw, epilogue=epi)
                assert old == new, (
                    f"legality diverged for {path}/{v}/{epi} on {d} "
                    f"bh={bh} bt={bt} bc={bc} hw={hw.name}: "
                    f"legacy={old} derived={new}")


@pytest.mark.parametrize("d", SHAPES, ids=str)
@pytest.mark.parametrize("tiling", TILINGS, ids=str)
def test_golden_stage1_cost(d, tiling):
    """The tuner's stage-1 analytical time (roofline bound + DMA overhead)
    agrees exactly with the legacy formula on every tuning path."""
    bh, bt, bc = tiling
    for path in LEGACY_PATHS:
        for v in space._space_variants(path):
            epis = ("none", "bias+gelu") if path in ("fwd", "bwd_fused") \
                else ("none",)
            for epi in epis:
                c = space.Candidate(path, v, bh, bt, bc)
                if path == "fwd":
                    est = legacy.epilogue_fwd_traffic(
                        d, v, 4, epilogue=epi, fused=True,
                        block_h=bh, block_t=bt)
                elif path == "bwd_in":
                    est = legacy.fwd_traffic(d, v, 4, block_h=bh, block_t=bt)
                elif path == "bwd_fused":
                    est = legacy.epilogue_bwd_traffic(
                        d, v, 4, epilogue=epi, block_h=bh, block_t=bt,
                        batch_chunk=bc)
                else:
                    est = legacy.bwdk_traffic(d, v, 4, block_h=bh,
                                              block_t=bt, batch_chunk=bc)
                old = (max(est.flops / TPU_V5E.peak_flops_f32,
                           est.bytes_moved / TPU_V5E.hbm_bw)
                       + est.transactions * legacy_dma_overhead())
                new = cost.analytical_time_s(c, d, itemsize=4, hw=TPU_V5E,
                                             epilogue=epi)
                assert old == new, (
                    f"stage-1 cost diverged for {path}/{v}/{epi}: "
                    f"legacy={old!r} derived={new!r}")


def legacy_dma_overhead() -> float:
    return 1e-7  # pre-refactor cost.DMA_OVERHEAD_S


# --------------------------------------------------------------------------
# geometry dedup: ops.py re-exports are the shared perfmodel functions
# --------------------------------------------------------------------------


def test_geometry_shared_single_source():
    """``kernels/ops.py`` and the schedule model read the *same* geometry
    functions (identity, not just equality), so runtime tiling and the
    analytical model cannot drift."""
    assert ops.unified_wpad is perfmodel.unified_wpad
    assert ops.bwd_fused_wpad is perfmodel.bwd_fused_wpad
    assert ops.bwdk_time_tile is perfmodel.bwdk_time_tile
    assert ops.epilogue_time_tile is perfmodel.epilogue_time_tile


@pytest.mark.parametrize("L", [48, 100, 300, 4096, 16384])
@pytest.mark.parametrize("K", [3, 4, 5, 7, 48, 80])
@pytest.mark.parametrize("bt", [4, 128, 300, 512, 2048, 1 << 30])
def test_golden_geometry(L, K, bt):
    assert ops.unified_wpad(L, K, bt) == legacy.unified_wpad(L, K, bt)
    assert ops.bwd_fused_wpad(L, K) == legacy.bwd_fused_wpad(L, K)
    for v in ("accum", "twostage", "fused", "fused_partials", "naive", "xla"):
        assert ops.bwdk_time_tile(L, K, bt, v) == legacy.bwdk_time_tile(L, K, bt, v)
        assert ops.epilogue_time_tile(L, K, bt, v) == legacy.epilogue_time_tile(L, K, bt, v)


# --------------------------------------------------------------------------
# typed contract: the historical TrafficEstimate is the perfmodel one
# --------------------------------------------------------------------------


def test_traffic_estimate_is_shared_type():
    assert traffic.TrafficEstimate is perfmodel.TrafficEstimate
    est = traffic.fwd_traffic(DWConvDims(B=2, H=8, L=48, K=4), "row")
    assert isinstance(est, perfmodel.TrafficEstimate)
    assert est.bytes_moved == est.bytes_read + est.bytes_written


def test_schedule_operand_sums_are_the_estimate():
    """The derived estimate is literally the sum of the spec's operands —
    the decomposition the report prints is the traffic, not a restatement."""
    d = DWConvDims(B=8, H=64, L=16384, K=4)
    s = perfmodel.schedule_for("bwd_fused", "fused", d, 4, block_t=128)
    est = perfmodel.derive_traffic(s)
    assert est.bytes_read == sum(o.hbm_bytes for o in s.reads())
    assert est.bytes_written == sum(o.hbm_bytes for o in s.writes())
    assert est.transactions == sum(o.transactions for o in s.operands)
    # the tiled schedule names the haloed staged slabs
    names = {o.name for o in s.operands}
    assert {"x_pad", "dy_pad", "dx", "dk"} <= names
