"""Counter-free performance report CLI — the paper's full analysis from specs.

  PYTHONPATH=src python -m repro.launch.report
  PYTHONPATH=src python -m repro.launch.report --shapes paper --out REPORT.md \\
      --json BENCH_report.json
  PYTHONPATH=src python -m repro.launch.report --shapes 8x64x16384x4 --hw p100

One command reproduces the paper's Tables II/III / Fig. 10 analysis for
every (study variant x execution path): the execution-path traffic
decomposition, modeled HBM bytes with the per-operand breakdown, effective
bandwidth against the ``analysis/hw.py`` peaks, and the roofline table —
all *derived* from the declarative kernel schedules (``repro.perfmodel``),
with no hardware counters, no measurement, and no benchmark scripts.

The P100 paper-mode section places the paper's published Table II runtimes
on the roofline through the same derivation ``benchmarks/paper_roofline.py``
renders, so the report and the benchmark cannot diverge.
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from repro.analysis.hw import HARDWARE, TPU_V5E
from repro.analysis.report import (
    counter_free_markdown,
    counter_free_report,
    dump_json,
)
from repro.kernels.common import DWConvDims
from repro.perfmodel import dtype_itemsize


def parse_shapes(spec: str) -> List[DWConvDims]:
    from repro.tuning.space import PAPER_DIMS_CPU, PAPER_DIMS_FULL

    presets = {"paper": PAPER_DIMS_FULL, "paper-cpu": PAPER_DIMS_CPU}
    out: List[DWConvDims] = []
    for tok in spec.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if tok in presets:
            out.append(presets[tok])
            continue
        try:
            b, h, l, k = (int(v) for v in tok.lower().split("x"))
        except ValueError:
            raise SystemExit(
                f"bad shape {tok!r}: expected a preset {sorted(presets)} or BxHxLxK")
        out.append(DWConvDims(B=b, H=h, L=l, K=k))
    if not out:
        raise SystemExit("no shapes given")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--shapes", default="paper",
                    help="comma-separated presets (paper, paper-cpu) and/or BxHxLxK")
    ap.add_argument("--hw", default=TPU_V5E.name, choices=sorted(HARDWARE),
                    help="hardware model for the roofline terms")
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="operand dtype: sets the one itemsize convention "
                         "charged end to end (f32 partials always charge 4)")
    ap.add_argument("--block-h", type=int, default=8)
    ap.add_argument("--block-t", type=int, default=512)
    ap.add_argument("--batch-chunk", type=int, default=128)
    ap.add_argument("--no-paper", action="store_true",
                    help="omit the P100 paper-mode section")
    ap.add_argument("--no-epilogue", action="store_true",
                    help="omit the epilogue fused-vs-unfused section")
    ap.add_argument("--out", default="",
                    help="write the markdown report here (default: stdout)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the machine-readable payload (BENCH_report.json)")
    args = ap.parse_args(argv)

    hw = HARDWARE[args.hw]
    itemsize = dtype_itemsize(args.dtype)
    payloads = []
    chunks = []
    for d in parse_shapes(args.shapes):
        payload = counter_free_report(
            d, hw=hw, itemsize=itemsize,
            block_h=args.block_h, block_t=args.block_t,
            batch_chunk=args.batch_chunk,
            include_paper=not args.no_paper,
            include_epilogue=not args.no_epilogue,
        )
        payloads.append(payload)
        chunks.append(counter_free_markdown(payload))
    md = "\n".join(chunks)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"[report] wrote {args.out}", file=sys.stderr)
    else:
        print(md, end="")
    if args.json:
        dump_json(args.json, payloads[0] if len(payloads) == 1 else payloads)
        print(f"[report] wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
