"""AST repo lint: the repo's hard-won invariants as named, suppressible rules.

Usage::

    python -m repro.verify.lint [paths...] [--json OUT] [--fail-on LEVEL]

Rules (see README "Static verification" for the rationale table):

  REP001  bare ``assert`` in kernel/ops code — must be a ValueError naming
          the offending dims, so the check survives ``python -O``
  REP002  ``time.perf_counter``/``time.time`` timing JAX work with no
          ``block_until_ready`` sync in the same function (the async
          dispatch hazard; the paper's CUDA-event discipline)
  REP003  a ``pl.pallas_call`` wrapper with no registered schedule builder —
          every kernel must be analytically modeled before it is tuned
  REP004  geometry helpers imported from their pre-PR-5 homes
          (``repro.kernels.ops``) instead of ``repro.perfmodel.geometry``
  REP005  tuning-cache state mutated outside ``repro.tuning`` — all cache
          writes must go through the versioned-schema API
  REP006  fleet bundle / tuning-cache files read or written with direct
          ``json.load``/``json.dump`` outside ``tuning/cache.py`` and
          ``fleet/bundle.py`` — bundle I/O must pass signature validation
          and the versioned schema (REP005's read-side sibling)

Suppress a finding with a line comment ``# repro: noqa(REP002)`` (several
codes comma-separated); undocumented blanket suppression is not supported
on purpose.
"""
from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.verify.findings import (Finding, findings_payload, max_severity,
                                   should_fail)

# Kernel wrapper -> the registered (path, variant) keys it implements.
# REP003 checks both directions: every pallas_call wrapper is listed here,
# and every listed key exists in the schedule registry.
KNOWN_KERNEL_SCHEDULES: Dict[str, Tuple[Tuple[str, str], ...]] = {
    "dwconv_fwd_row": (("fwd", "row"), ("bwd_in", "row")),
    "dwconv_fwd_block": (("fwd", "block"), ("bwd_in", "block")),
    "_dwconv_fwd_tapdma": (("fwd", "naive"), ("fwd", "lane"),
                           ("bwd_in", "naive"), ("bwd_in", "lane")),
    "dwconv_bwdk_accum": (("bwd_k", "accum"),),
    "dwconv_bwdk_twostage": (("bwd_k", "twostage"),),
    "dwconv_bwdk_naive": (("bwd_k", "naive"),),
    "dwconv_bwd_fused_accum": (("bwd_fused", "fused"),),
    "dwconv_bwd_fused_partials": (("bwd_fused", "fused_partials"),),
    "dwconv_bwd_fused_accum_act": (("bwd_fused", "fused"),),
    "dwconv_bwd_fused_partials_act": (("bwd_fused", "fused_partials"),),
    "dwconv_decode_rows": (("decode", "rows"),),
    "dwconv_decode_chanblock": (("decode", "chanblock"),),
}

# Helpers that moved to perfmodel.geometry in PR 5; importing them from the
# kernel layer reintroduces the drift the refactor removed.
GEOMETRY_NAMES = {
    "bwdk_time_tile", "unified_wpad", "bwd_fused_wpad", "epilogue_time_tile",
    "time_tile", "effective_tiles", "fwd_tile_grid", "bwd_time_tiles",
    "dtype_itemsize",
}

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa\(([^)]*)\)")

# Direct JSON (de)serialization calls REP006 polices outside the two
# modules allowed to touch bundle/cache bytes.
_JSON_IO_CALLS = {"json.load", "json.loads", "json.dump", "json.dumps"}


def _noqa_codes(lines: Sequence[str], lineno: int) -> Set[str]:
    if 1 <= lineno <= len(lines):
        m = _NOQA_RE.search(lines[lineno - 1])
        if m:
            return {c.strip() for c in m.group(1).split(",") if c.strip()}
    return set()


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _attrs_in(node: ast.AST) -> Set[str]:
    return {n.attr for n in ast.walk(node) if isinstance(n, ast.Attribute)}


def _call_name(call: ast.Call) -> str:
    """Dotted name of a call target: 'time.perf_counter', 'pl.pallas_call'."""
    parts: List[str] = []
    node = call.func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _schedule_registry_keys() -> Optional[Set[Tuple[str, str]]]:
    try:
        from repro.perfmodel.schedules import SCHEDULE_BUILDERS
        return set(SCHEDULE_BUILDERS)
    except Exception:  # noqa: BLE001 - lint stays usable without the package
        return None


class _FileLint:
    def __init__(self, path: Path, rel: str, tree: ast.Module,
                 lines: Sequence[str]):
        self.path = path
        self.rel = rel
        self.tree = tree
        self.lines = lines
        self.findings: List[Finding] = []

    def emit(self, code: str, lineno: int, message: str,
             severity: str = "error") -> None:
        if code in _noqa_codes(self.lines, lineno):
            return
        self.findings.append(Finding(code=code, severity=severity,
                                     where=f"{self.rel}:{lineno}",
                                     message=message))

    # -- REP001 -------------------------------------------------------------
    def check_asserts(self) -> None:
        if not ("/kernels/" in self.rel or "/core/" in self.rel):
            return
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assert):
                self.emit("REP001", node.lineno,
                          "bare assert in kernel/ops code — raise ValueError "
                          "naming the dims so the check survives python -O")

    # -- REP002 -------------------------------------------------------------
    def check_timing(self) -> None:
        for fn in ast.walk(self.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            timing_lines = [
                c.lineno for c in ast.walk(fn)
                if isinstance(c, ast.Call)
                and _call_name(c) in ("time.perf_counter", "time.time",
                                      "perf_counter")
            ]
            if not timing_lines:
                continue
            names = _names_in(fn)
            if not ({"jax", "jnp"} & names):
                continue
            if "block_until_ready" in _attrs_in(fn):
                continue
            self.emit("REP002", min(timing_lines),
                      f"'{fn.name}' wraps JAX work in a wall-clock timer with "
                      f"no block_until_ready sync — async dispatch makes the "
                      f"reading meaningless")

    # -- REP003 -------------------------------------------------------------
    def check_kernel_registration(
            self, registry: Optional[Set[Tuple[str, str]]]) -> None:
        if "/kernels/" not in self.rel:
            return
        for fn in self.tree.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            calls = [c for c in ast.walk(fn) if isinstance(c, ast.Call)
                     and _call_name(c) == "pl.pallas_call"]
            if not calls:
                continue
            keys = KNOWN_KERNEL_SCHEDULES.get(fn.name)
            if keys is None:
                self.emit("REP003", fn.lineno,
                          f"pallas_call wrapper '{fn.name}' has no registered "
                          f"schedule builder — add a KernelSchedule in "
                          f"perfmodel/schedules.py and map it in "
                          f"verify.lint.KNOWN_KERNEL_SCHEDULES")
            elif registry is not None:
                missing = [k for k in keys if k not in registry]
                if missing:
                    self.emit("REP003", fn.lineno,
                              f"'{fn.name}' maps to unregistered schedule "
                              f"key(s) {missing}")

    # -- REP004 -------------------------------------------------------------
    def check_geometry_imports(self) -> None:
        if "/kernels/ops.py" in self.rel or "/perfmodel/" in self.rel:
            return
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ImportFrom) and node.module \
                    and node.module.endswith("kernels.ops"):
                bad = sorted(a.name for a in node.names
                             if a.name in GEOMETRY_NAMES)
                if bad:
                    self.emit("REP004", node.lineno,
                              f"geometry helper(s) {bad} imported from "
                              f"repro.kernels.ops — the post-PR-5 home is "
                              f"repro.perfmodel.geometry")
            if isinstance(node, ast.Attribute) and node.attr in GEOMETRY_NAMES \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id in ("ops", "_ops", "kernel_ops"):
                self.emit("REP004", node.lineno,
                          f"geometry helper '{node.attr}' reached through the "
                          f"kernel ops module — use repro.perfmodel.geometry")

    # -- REP005 -------------------------------------------------------------
    def check_cache_schema(self) -> None:
        if "/tuning/" in self.rel:
            return
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    if isinstance(t, ast.Attribute) and t.attr == "_entries":
                        self.emit("REP005", node.lineno,
                                  "direct write to a TuningCache._entries — "
                                  "use the versioned put()/quarantine() API")
            if isinstance(node, ast.Call):
                cname = _call_name(node)
                if cname.endswith("replace") and any(
                        kw.arg == "quarantined" for kw in node.keywords):
                    self.emit("REP005", node.lineno,
                              "entry quarantine flag rewritten outside "
                              "repro.tuning — use TuningCache.quarantine()")
        for fn in ast.walk(self.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            calls = {_call_name(c) for c in ast.walk(fn)
                     if isinstance(c, ast.Call)}
            if ("resolve_cache_path" in {c.split(".")[-1] for c in calls}
                    and {"json.dump", "json.dumps"} & calls):
                self.emit("REP005", fn.lineno,
                          f"'{fn.name}' serializes JSON to the resolved cache "
                          f"path outside repro.tuning — cache files must be "
                          f"written through TuningCache.save()")

    # -- REP006 -------------------------------------------------------------
    def check_bundle_io(self) -> None:
        """Direct json I/O on fleet bundles (or reads of the resolved tuning
        cache) outside the two modules allowed to touch those bytes.  Same
        heuristic family as REP005: per function, a json.(load|dump)[s] call
        plus evidence the function handles a bundle — a ``.bundle.json``
        string constant, or any name/argument containing 'bundle' — or a
        ``resolve_cache_path`` read (the read-side complement of REP005's
        dump check)."""
        if self.rel.endswith("/tuning/cache.py") \
                or self.rel.endswith("/fleet/bundle.py"):
            return
        for fn in ast.walk(self.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            calls = {_call_name(c) for c in ast.walk(fn)
                     if isinstance(c, ast.Call)}
            if not (_JSON_IO_CALLS & calls):
                continue
            touches_bundle = any(
                isinstance(n, ast.Constant) and isinstance(n.value, str)
                and ".bundle.json" in n.value
                for n in ast.walk(fn))
            if not touches_bundle:
                idents = {s.lower() for s in _names_in(fn) | _attrs_in(fn)}
                idents |= {a.arg.lower() for a in ast.walk(fn)
                           if isinstance(a, ast.arg)}
                touches_bundle = any("bundle" in s for s in idents)
            reads_cache = (
                "resolve_cache_path" in {c.split(".")[-1] for c in calls}
                and {"json.load", "json.loads"} & calls
                and "/tuning/" not in self.rel)
            if touches_bundle or reads_cache:
                what = ("a fleet bundle" if touches_bundle
                        else "the resolved tuning cache")
                self.emit("REP006", fn.lineno,
                          f"'{fn.name}' touches {what} with direct json I/O — "
                          f"bundle/cache bytes go through repro.fleet.bundle "
                          f"(signature-validated) or TuningCache (versioned "
                          f"schema)")


def lint_file(path: Path) -> List[Finding]:
    try:
        src = path.read_text()
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [Finding("REP000", "error", f"{path}:{e.lineno or 0}",
                        f"syntax error: {e.msg}")]
    # Rule scoping matches on the absolute posix path ("/kernels/" etc.);
    # findings display the root-relative path.
    fl = _FileLint(path, "/" + path.resolve().as_posix().lstrip("/"),
                   tree, src.splitlines())
    fl.check_asserts()
    fl.check_timing()
    fl.check_kernel_registration(_schedule_registry_keys())
    fl.check_geometry_imports()
    fl.check_cache_schema()
    fl.check_bundle_io()
    return fl.findings


def lint_paths(paths: Iterable[Path]) -> List[Finding]:
    findings: List[Finding] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_file(f))
    return findings


def _default_root() -> Path:
    here = Path(__file__).resolve()
    return here.parents[1]  # src/repro


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify.lint",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint (default: src/repro)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write findings as JSON")
    ap.add_argument("--fail-on", choices=("error", "warning", "never"),
                    default="error",
                    help="exit 1 when findings at/above this level exist")
    args = ap.parse_args(argv)
    paths = [Path(p) for p in args.paths] or [_default_root()]
    findings = lint_paths(paths)
    for f in findings:
        print(f.render())
    summary = f"{len(findings)} finding(s)"
    if findings:
        summary += f" (worst: {max_severity(findings)})"
    print(f"[lint] {summary} over {', '.join(str(p) for p in paths)}",
          file=sys.stderr)
    if args.json:
        Path(args.json).write_text(json.dumps(
            {"tool": "repro.verify.lint", "findings": findings_payload(findings)},
            indent=1))
    return 1 if should_fail(findings, args.fail_on) else 0


if __name__ == "__main__":
    sys.exit(main())
