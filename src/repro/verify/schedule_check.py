"""Schedule↔kernel cross-checker: prove model/kernel agreement statically.

For one (path × variant × epilogue × shape × knobs) configuration this module
abstractly traces the kernel wrapper (``trace.trace_config`` — no execution),
rebuilds the registered ``KernelSchedule`` at the kernel's *padded* dims, and
checks that the two descriptions of the launch agree.  Rule codes:

  VER101  grid mismatch (extents / total cell count)
  VER102  operand block/binding mismatch (a staged block the model does not
          describe, or a modeled block the kernel does not stage)
  VER103  index-map coverage (out-of-bounds block, gap in the tiling, an
          output tile never written, or an unanalyzable index map)
  VER104  revisited output block reachable from a non-innermost grid dim
          (static write-write race for the accumulating reductions)
  VER105  accumulator dtype (revisited output block or modeled accumulator
          scratch that is not f32)
  VER106  VMEM footprint disagreement beyond the explained conventions
  VER107  legality disagreement (model verdict vs the wrapper's ValueError)
  VER108  modeled read traffic outside the bounds implied by the BlockSpecs

The model and the kernels speak slightly different dialects by design; every
sanctioned difference is folded into an *explained-bytes* budget instead of
being waved through wholesale:

  * row-family kernels stage the unified ``Wpad`` row (``geometry.
    unified_wpad``) — wider than the schedule's minimal padded row;
  * the tap-DMA kernels (fwd naive/lane, bwd_k naive) bind operands as
    ``pl.ANY`` and stage manually into a VMEM scratch window;
  * the filter/bias vectors are modeled as unstaged whole-tensor reads but
    the kernels stage them as (Hb, Kp)/(Hb, LANE) blocks;
  * blockless modeled writes (dk, dbias, partials) are the kernels' f32
    accumulator / partials output blocks;
  * the epilogue recompute temporaries (``pre``, ``dy_eff``) are modeled
    VMEM charges with no operand counterpart (register/VMEM temporaries).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.kernels.common import (LANE, DWConvDims, adjoint_pad_widths, cdiv,
                                  pad_widths, round_up)
from repro.perfmodel.derive import check_legality, vmem_bytes
from repro.perfmodel.geometry import (decode_tiles, effective_tiles,
                                      unified_wpad)
from repro.perfmodel.schedules import schedule_for
from repro.verify.findings import Finding
from repro.verify.trace import (PALLAS_VARIANTS, PallasRecord, SpecInfo,
                                trace_config)

# VER108 lower bound: modeled read bytes must be at least this fraction of
# the bytes the BlockSpecs can touch (union of visited cells).  The loosest
# legitimate case is the row family on a short-L shape, where the staged
# unified row is up to ~3x the modeled minimal row (~0.34); a schedule whose
# elems are off by an order of magnitude still trips it.
READ_LOWER_FRACTION = 0.25


def _err(code: str, where: str, msg: str) -> Finding:
    return Finding(code=code, severity="error", where=where, message=msg)


def _itemsize(dtype_name: str) -> int:
    return int(np.dtype(dtype_name).itemsize)


def _prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _squeeze(shape: Sequence[int]) -> Tuple[int, ...]:
    return tuple(int(s) for s in shape if int(s) != 1)


def padded_dims(path: str, d: DWConvDims, *, block_h: int, block_t: int,
                batch_chunk: int) -> DWConvDims:
    """The dims the kernel actually launches over: ops pads channels to a
    whole number of h-blocks, time to the lane-aligned Lout, and (reduction
    paths) batch to a whole number of chunks.  The tiling knobs are
    idempotent under this padding (min/round_up fixpoints), so rebuilding
    the schedule at these dims describes the traced launch exactly."""
    if path == "decode":
        # L=1 single-step: channels are lane-padded to the channel tile and
        # the slot pool to a whole number of batch chunks; L never pads.
        _, _, Hp, Bc, _, Bp = decode_tiles(d, block_t, batch_chunk)
        return DWConvDims(B=Bp, H=Hp, L=d.L, K=d.K, padding=d.padding)
    Hb = max(1, min(block_h, d.H))
    Hp = round_up(d.H, Hb)
    Lp = round_up(d.L, LANE)
    Bp = d.B
    if path in ("bwd_k", "bwd_fused"):
        Bc = max(1, min(batch_chunk, d.B))
        Bp = round_up(d.B, Bc)
    return DWConvDims(B=Bp, H=Hp, L=Lp, K=d.K, padding=d.padding)


# ---------------------------------------------------------------------------
# index-map analysis
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MapInfo:
    """Separable description of one index map over the launch grid."""
    ncomp: int
    comp_dim: List[Optional[int]]       # grid dim driving each component
    comp_values: List[List[int]]        # visited block index per driving step
    used_dims: Set[int]
    error: Optional[str] = None


def _eval_map(index_map, args) -> Tuple[int, ...]:
    out = index_map(*args)
    if not isinstance(out, tuple):
        out = (out,)
    return tuple(int(v) for v in out)


def analyze_index_map(index_map, grid: Sequence[int]) -> MapInfo:
    """Per-dimension sweeps + sample cross-check: O(sum of extents) instead
    of enumerating the full grid (paper shapes reach ~260k cells)."""
    n = len(grid)
    try:
        base = _eval_map(index_map, (0,) * n)
    except Exception as e:  # noqa: BLE001 - any failure is a finding
        return MapInfo(0, [], [], set(), error=f"index map failed at origin: {e}")
    ncomp = len(base)
    sweeps: List[List[Tuple[int, ...]]] = []
    for dim in range(n):
        vals = [base]
        for g in range(1, int(grid[dim])):
            args = [0] * n
            args[dim] = g
            try:
                vals.append(_eval_map(index_map, tuple(args)))
            except Exception as e:  # noqa: BLE001
                return MapInfo(0, [], [], set(),
                               error=f"index map failed at grid[{dim}]={g}: {e}")
        sweeps.append(vals)
    comp_dim: List[Optional[int]] = []
    comp_values: List[List[int]] = []
    used: Set[int] = set()
    for c in range(ncomp):
        dims_c = [dim for dim in range(n)
                  if any(v[c] != base[c] for v in sweeps[dim])]
        if len(dims_c) > 1:
            return MapInfo(0, [], [], set(),
                           error=f"component {c} depends on grid dims {dims_c} "
                                 f"jointly (non-separable index map)")
        dim = dims_c[0] if dims_c else None
        comp_dim.append(dim)
        comp_values.append([v[c] for v in sweeps[dim]] if dim is not None else [base[c]])
        if dim is not None:
            used.add(dim)
    # Cross-check separability at the far corner and a mixed sample point.
    for point in ((tuple(int(g) - 1 for g in grid)),
                  tuple(min(1, int(g) - 1) for g in grid)):
        predicted = tuple(
            comp_values[c][point[comp_dim[c]]] if comp_dim[c] is not None
            else comp_values[c][0]
            for c in range(ncomp))
        try:
            actual = _eval_map(index_map, point)
        except Exception as e:  # noqa: BLE001
            return MapInfo(0, [], [], set(), error=f"index map failed at {point}: {e}")
        if actual != predicted:
            return MapInfo(0, [], [], set(),
                           error=f"index map is not separable: f{point}={actual}, "
                                 f"per-dim sweeps predict {predicted}")
    return MapInfo(ncomp, comp_dim, comp_values, used)


def _identity_map(n: int):
    return lambda *args: args if n > 1 else args[0]


def pipelined_fetches(minfo: MapInfo, grid: Sequence[int]) -> int:
    """Upper bound on block fetches under the Pallas pipeline, which skips
    the copy when the block index is unchanged between consecutive row-major
    grid steps.  A transition's outermost-changing dim d triggers a fetch
    iff d (or any wrapping inner dim) feeds the index map."""
    n = len(grid)
    total = 1
    for dim in range(n):
        inner_used = any(j in minfo.used_dims and int(grid[j]) > 1
                         for j in range(dim + 1, n))
        if dim in minfo.used_dims or inner_used:
            total += (int(grid[dim]) - 1) * _prod(grid[:dim])
    return total


def _merged_cover(intervals: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    out: List[Tuple[int, int]] = []
    for lo, hi in sorted(intervals):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


# ---------------------------------------------------------------------------
# one traced launch vs one padded schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Group:
    gid: int
    specs: List[SpecInfo]
    shape: Tuple[int, ...]
    dtype: str
    model_name: Optional[str] = None    # schedule operand this group realizes
    model_read_bytes: float = 0.0       # its modeled HBM read charge


def _bind_candidates(op, cells: int) -> List[Tuple[int, Tuple[int, ...]]]:
    """(n_binds, per-bind block) readings of a modeled block.  A multi-bind
    block is encoded as (n_binds, *per_bind) with transactions = binds/cell."""
    block = tuple(int(b) for b in op.block)
    cands = [(1, block)]
    if cells and op.transactions:
        nb = int(round(op.transactions / cells))
        if nb > 1 and len(block) >= 2 and block[0] == nb:
            cands.append((nb, block[1:]))
    return cands


def _op_block_itemsize(op) -> int:
    return int(getattr(op, "block_itemsize", None) or op.itemsize)


def _live_last(name: str, path: str, d: DWConvDims) -> Optional[int]:
    """Columns of the last axis that hold real data (the rest is layout
    padding a kernel may legitimately skip).  None: require full extent."""
    if path == "decode":
        # Decode arrays are channel-last and padded exactly to the launch
        # extents; every column is live by construction.
        return None
    pl_l, pl_r = pad_widths(d.K, d.padding)
    al_l, _ = adjoint_pad_widths(d.K, d.padding)
    if name == "x":
        return (al_l if path == "bwd_in" else pl_l) + d.L
    if name == "x_pad":
        return pl_l + d.L
    if name == "dy_pad":
        return pl_r + d.L
    if name == "dy":
        return d.L
    return None


def check_record(rec: PallasRecord, sched_p, d: DWConvDims, *, path: str,
                 variant: str, epilogue: str, block_h: int, block_t: int,
                 batch_chunk: int, where: str) -> List[Finding]:
    """Cross-check one traced pallas_call against the padded-dims schedule.

    ``sched_p`` is the registered schedule rebuilt at ``padded_dims(...)``;
    ``d`` is the *logical* shape (used for the live-data coverage targets
    and the unified-row width, which are functions of the un-padded L).
    """
    findings: List[Finding] = []
    dp = sched_p.dims
    Hb, _, _, _ = effective_tiles(dp, block_h, block_t, batch_chunk)
    Kp = round_up(d.K, LANE)
    cells = _prod([e for _, e in sched_p.grid]) if sched_p.grid else 1

    # ---- VER101: grid agreement (orders differ by convention) -------------
    model_ext = [int(e) for _, e in sched_p.grid]
    actual_ext = [int(e) for e in rec.grid]
    if (sorted(e for e in model_ext if e > 1) != sorted(e for e in actual_ext if e > 1)
            or _prod(model_ext) != _prod(actual_ext)):
        findings.append(_err("VER101", where,
                             f"grid mismatch: schedule {sched_p.grid} vs "
                             f"kernel grid {rec.grid}"))
        return findings

    if len(rec.in_specs) != len(rec.operand_shapes):
        findings.append(_err("VER102", where,
                             f"{len(rec.operand_shapes)} operands bound to "
                             f"{len(rec.in_specs)} in_specs"))
        return findings

    # ---- group the kernel's operand bindings (same array => one group) ----
    groups: Dict[int, _Group] = {}
    for i, spec in enumerate(rec.in_specs):
        gid = rec.operand_groups[i]
        g = groups.setdefault(gid, _Group(gid, [], rec.operand_shapes[i],
                                          rec.operand_dtypes[i]))
        g.specs.append(spec)

    explained = 0.0          # |model VMEM - actual VMEM| budget from conventions
    used_scratch: Set[int] = set()
    structural_ok = True     # gates VER106/VER108 on a clean VER102 pass

    def _group_binds(g: _Group) -> Optional[List[Tuple[int, ...]]]:
        if any(s.block_shape is None for s in g.specs):
            return None
        return [_squeeze(s.block_shape) for s in g.specs]

    # ---- VER102: staged modeled reads must appear as spec groups ----------
    model_ops = [op for op in sched_p.operands if not op.name.startswith("pad:")]
    staged_reads = [op for op in model_ops if op.role == "read" and op.block]
    unstaged_reads = [op for op in model_ops
                      if op.role == "read" and not op.block
                      and op.elems > 0 and op.name in ("k", "bias")]

    for op in staged_reads:
        bi = _op_block_itemsize(op)
        hit: Optional[_Group] = None
        for nb, per_bind in _bind_candidates(op, cells):
            want = _squeeze(per_bind)
            for g in groups.values():
                if g.model_name is not None:
                    continue
                binds = _group_binds(g)
                if binds is None or len(binds) != nb:
                    continue
                if all(b == want for b in binds):
                    hit = g
                    break
                # Unified-row widening: identical up to a wider last axis,
                # exactly the shared unified_wpad width.
                if (all(len(b) == len(want) and b[:-1] == want[:-1]
                        and b[-1] >= want[-1] for b in binds)
                        and binds[0][-1] == unified_wpad(d.L, d.K, block_t)
                        and all(b == binds[0] for b in binds)):
                    hit = g
                    explained += nb * (binds[0][-1] - want[-1]) * _prod(want[:-1]) * bi
                    break
            if hit is not None:
                break
        if hit is None:
            # Manual-DMA convention: a pl.ANY binding staged by the kernel
            # itself into a VMEM scratch window of the modeled width (the
            # model may charge up to K-1+LANE extra alignment columns).
            want = _squeeze(tuple(int(b) for b in op.block))
            for g in groups.values():
                if g.model_name is not None or _group_binds(g) is not None:
                    continue
                for si, sc in enumerate(rec.scratch):
                    if si in used_scratch or sc.kind != "vmem":
                        continue
                    ssh = _squeeze(sc.shape)
                    if (len(ssh) == len(want) and ssh[:-1] == want[:-1]
                            and 0 <= want[-1] - ssh[-1] <= d.K - 1 + LANE):
                        used_scratch.add(si)
                        explained += abs(_prod(want) * bi
                                         - _prod(sc.shape) * _itemsize(sc.dtype))
                        hit = g
                        break
                if hit is not None:
                    break
        if hit is None:
            structural_ok = False
            findings.append(_err("VER102", where,
                                 f"schedule read '{op.name}' block={op.block} "
                                 f"has no matching kernel binding"))
        else:
            hit.model_name = op.name
            hit.model_read_bytes = op.hbm_bytes

    # Modeled whole-tensor reads the kernels stage as fixed blocks.
    for op in unstaged_reads:
        want = {"k": _squeeze((Hb, Kp)), "bias": _squeeze((Hb, LANE))}[op.name]
        hit = None
        for g in groups.values():
            binds = _group_binds(g)
            if g.model_name is None and binds is not None and binds == [want]:
                hit = g
                break
        if hit is None:
            structural_ok = False
            findings.append(_err("VER102", where,
                                 f"schedule read '{op.name}' (unstaged) has no "
                                 f"({'x'.join(map(str, want))}) kernel binding"))
        else:
            hit.model_name = op.name
            hit.model_read_bytes = op.hbm_bytes
            explained += len(hit.specs) * _prod(want) * _itemsize(hit.dtype)

    for g in groups.values():
        if g.model_name is None:
            structural_ok = False
            binds = _group_binds(g)
            desc = "pl.ANY" if binds is None else f"blocks {binds}"
            findings.append(_err("VER102", where,
                                 f"kernel binds operand shape {g.shape} as {desc} "
                                 f"with no schedule operand to account for it"))

    # ---- VER102 (outputs) -------------------------------------------------
    out_used = [False] * len(rec.out_specs)
    staged_writes = [op for op in model_ops if op.role == "write" and op.block]
    acc_names = ["dk_partials", "partials", "dk", "dbias"]
    unstaged_writes = sorted(
        (op for op in model_ops if op.role == "write" and not op.block
         and op.elems > 0 and op.name in acc_names),
        key=lambda op: acc_names.index(op.name))
    if len(rec.out_specs) != len(rec.out_shapes):
        findings.append(_err("VER102", where, "out_specs/out_shape arity mismatch"))
        return findings

    def _claim_out(want: Tuple[int, ...]) -> Optional[int]:
        for oi, spec in enumerate(rec.out_specs):
            if out_used[oi] or spec.block_shape is None:
                continue
            if _squeeze(spec.block_shape) == want:
                out_used[oi] = True
                return oi
        return None

    matched_outs: List[Tuple[int, str]] = []
    for op in staged_writes:
        oi = _claim_out(_squeeze(tuple(int(b) for b in op.block)))
        if oi is None:
            structural_ok = False
            findings.append(_err("VER102", where,
                                 f"schedule write '{op.name}' block={op.block} "
                                 f"has no matching kernel output"))
        else:
            matched_outs.append((oi, op.name))

    acc_blocks = {"dk": [(Hb, Kp)], "dbias": [(Hb, LANE)],
                  "dk_partials": [(Hb, Kp)],
                  "partials": [(Hb, Kp), (Hb, LANE)] if epilogue != "none"
                  else [(Hb, Kp)]}
    seen_partials_read = False
    for op in unstaged_writes:
        if op.name == "partials" and seen_partials_read:
            continue
        seen_partials_read |= op.name == "partials"
        for want in acc_blocks[op.name]:
            oi = _claim_out(_squeeze(want))
            if oi is not None:
                # The kernel's f32 accumulator / partials block realizes a
                # modeled blockless write (final dk/dbias may be a post-kernel
                # jnp reduction, so a missing output here is not a finding).
                matched_outs.append((oi, op.name))
                explained += _prod(rec.out_specs[oi].block_shape) \
                    * _itemsize(rec.out_dtypes[oi])
    # An epilogue kernel always carries its dbias accumulator column even
    # when bias is off (the modeled dbias op then has elems 0).
    if epilogue != "none":
        oi = _claim_out(_squeeze((Hb, LANE)))
        if oi is not None:
            matched_outs.append((oi, "dbias"))
            explained += _prod(rec.out_specs[oi].block_shape) \
                * _itemsize(rec.out_dtypes[oi])

    for oi in range(len(rec.out_specs)):
        if not out_used[oi]:
            structural_ok = False
            findings.append(_err("VER102", where,
                                 f"kernel output block "
                                 f"{rec.out_specs[oi].block_shape} -> shape "
                                 f"{rec.out_shapes[oi]} has no schedule operand"))

    # Modeled VMEM charges with no operand counterpart: the epilogue
    # recompute temporaries, and (accum variants) the dk accumulator that is
    # realized by the f32 output block counted above.
    for op in model_ops:
        if op.role != "scratch" or not op.block:
            continue
        if op.name in ("pre", "dy_eff"):
            explained += op.vmem_bytes
        elif op.name == "dk_acc":
            pass  # cancels against the f32 accumulator output block
        else:
            explained += op.vmem_bytes

    # ---- VER103/VER104/VER105: coverage, races, accumulator dtype ---------
    spec_infos: Dict[int, MapInfo] = {}

    def _analyze(spec: SpecInfo, label: str) -> Optional[MapInfo]:
        key = id(spec)
        if key not in spec_infos:
            imap = spec.index_map or _identity_map(len(spec.block_shape))
            spec_infos[key] = analyze_index_map(imap, rec.grid)
        minfo = spec_infos[key]
        if minfo.error:
            findings.append(_err("VER103", where, f"{label}: {minfo.error}"))
            return None
        if minfo.ncomp != len(spec.block_shape):
            findings.append(_err("VER103", where,
                                 f"{label}: index map yields {minfo.ncomp} "
                                 f"components for a rank-{len(spec.block_shape)} block"))
            return None
        return minfo

    def _axis_checks(minfo: MapInfo, block: Tuple[int, ...],
                     ashape: Tuple[int, ...], label: str) -> bool:
        ok = True
        for c in range(minfo.ncomp):
            vals = minfo.comp_values[c]
            lo, hi = min(vals), max(vals)
            if lo < 0 or (hi + 1) * block[c] > ashape[c]:
                findings.append(_err("VER103", where,
                                     f"{label}: axis {c} visits blocks "
                                     f"[{lo}, {hi}] of size {block[c]} — out of "
                                     f"bounds for extent {ashape[c]}"))
                ok = False
            if sorted(set(vals)) != list(range(lo, hi + 1)):
                findings.append(_err("VER103", where,
                                     f"{label}: axis {c} visits a gapped block "
                                     f"set {sorted(set(vals))}"))
                ok = False
        return ok

    for g in groups.values():
        binds = _group_binds(g)
        if binds is None or g.model_name is None:
            continue  # manual-DMA groups have no specs to check
        per_axis: List[List[Tuple[int, int]]] = [[] for _ in g.shape]
        bad = False
        for si, spec in enumerate(g.specs):
            label = f"in '{g.model_name}' spec#{si}"
            minfo = _analyze(spec, label)
            if minfo is None or not _axis_checks(minfo, spec.block_shape,
                                                 g.shape, label):
                bad = True
                continue
            for c in range(minfo.ncomp):
                vals = minfo.comp_values[c]
                per_axis[c].append((min(vals) * spec.block_shape[c],
                                    (max(vals) + 1) * spec.block_shape[c]))
        if bad:
            structural_ok = False
            continue
        live = _live_last(g.model_name, path, d)
        for c in range(len(g.shape)):
            cover = _merged_cover(per_axis[c])
            target = g.shape[c] if (live is None or c != len(g.shape) - 1) else live
            if len(cover) != 1 or cover[0][0] != 0 or cover[0][1] < target:
                structural_ok = False
                findings.append(_err("VER103", where,
                                     f"in '{g.model_name}': axis {c} coverage "
                                     f"{cover} misses live region [0, {target})"))

    for oi, name in matched_outs:
        spec = rec.out_specs[oi]
        oshape = rec.out_shapes[oi]
        label = f"out '{name}'"
        minfo = _analyze(spec, label)
        if minfo is None:
            structural_ok = False
            continue
        block = spec.block_shape
        if not _axis_checks(minfo, block, oshape, label):
            structural_ok = False
            continue
        counts = []
        for c in range(minfo.ncomp):
            vals = set(minfo.comp_values[c])
            n_tiles_c = oshape[c] // block[c]
            if oshape[c] % block[c] != 0 or vals != set(range(n_tiles_c)):
                findings.append(_err("VER103", where,
                                     f"{label}: axis {c} tiling is not exact — "
                                     f"{len(vals)} visited blocks of {block[c]} "
                                     f"over extent {oshape[c]}"))
            counts.append(len(vals))
        # Combination completeness: distinct visited tuples must equal the
        # per-axis product (a diagonal map tiles each axis but skips cells).
        dim_joint = 1
        for dim in minfo.used_dims:
            comps = [c for c in range(minfo.ncomp) if minfo.comp_dim[c] == dim]
            dim_joint *= len({tuple(minfo.comp_values[c][g] for c in comps)
                              for g in range(int(rec.grid[dim]))})
        if dim_joint != _prod(counts):
            findings.append(_err("VER103", where,
                                 f"{label}: index map visits {dim_joint} distinct "
                                 f"tiles but the axes require {_prod(counts)}"))
        # VER104/VER105: revisits only along the innermost (sequential) grid
        # suffix, and only into an f32 accumulator block.
        ignored = {dim for dim in range(len(rec.grid))
                   if int(rec.grid[dim]) > 1 and dim not in minfo.used_dims}
        if ignored:
            if minfo.used_dims and max(minfo.used_dims) > min(ignored):
                findings.append(_err("VER104", where,
                                     f"{label}: block revisited along grid dim(s) "
                                     f"{sorted(ignored)} while outer dim "
                                     f"{max(minfo.used_dims)} varies — revisits "
                                     f"must be confined to the innermost "
                                     f"sequential dims"))
            if rec.out_dtypes[oi] != "float32":
                findings.append(_err("VER105", where,
                                     f"{label}: revisited accumulator block has "
                                     f"dtype {rec.out_dtypes[oi]}, must be float32"))

    for op in model_ops:
        if op.role == "scratch" and op.block and _op_block_itemsize(op) != 4:
            findings.append(_err("VER105", where,
                                 f"schedule scratch '{op.name}' declares "
                                 f"itemsize {_op_block_itemsize(op)}, accumulators "
                                 f"must be f32"))

    if not structural_ok:
        return findings

    # ---- VER106: VMEM footprint ------------------------------------------
    actual_vmem = 0.0
    for g in groups.values():
        binds = _group_binds(g)
        if binds is not None:
            for spec in g.specs:
                actual_vmem += _prod(spec.block_shape) * _itemsize(g.dtype)
    for oi, spec in enumerate(rec.out_specs):
        if spec.block_shape is not None:
            actual_vmem += _prod(spec.block_shape) * _itemsize(rec.out_dtypes[oi])
    for sc in rec.scratch:
        if sc.kind == "vmem":
            actual_vmem += _prod(sc.shape) * _itemsize(sc.dtype)
    model_vmem = vmem_bytes(sched_p)
    if abs(actual_vmem - model_vmem) > explained + 0.5:
        findings.append(_err("VER106", where,
                             f"VMEM footprint disagrees: BlockSpecs stage "
                             f"{actual_vmem:.0f} B, schedule derives "
                             f"{model_vmem:.0f} B, explained conventions cover "
                             f"only {explained:.0f} B"))

    # ---- VER108: modeled read traffic within BlockSpec-implied bounds -----
    if all(_group_binds(g) is not None for g in groups.values()):
        model_bytes = sum(g.model_read_bytes for g in groups.values())
        union_bytes = 0.0
        pipe_bytes = 0.0
        for g in groups.values():
            isz = _itemsize(g.dtype)
            per_axis = [[] for _ in g.shape]
            for spec in g.specs:
                minfo = spec_infos[id(spec)]
                for c in range(minfo.ncomp):
                    vals = minfo.comp_values[c]
                    per_axis[c].append((min(vals) * spec.block_shape[c],
                                        (max(vals) + 1) * spec.block_shape[c]))
                pipe_bytes += pipelined_fetches(minfo, rec.grid) \
                    * _prod(spec.block_shape) * isz
            union = 1
            for c in range(len(g.shape)):
                union *= sum(hi - lo for lo, hi in _merged_cover(per_axis[c]))
            union_bytes += union * isz
        if model_bytes < READ_LOWER_FRACTION * union_bytes - 0.5:
            findings.append(_err("VER108", where,
                                 f"schedule charges {model_bytes:.0f} read bytes "
                                 f"but the BlockSpecs touch {union_bytes:.0f} B of "
                                 f"distinct cells — elems look understated"))
        if model_bytes > pipe_bytes + 0.5:
            findings.append(_err("VER108", where,
                                 f"schedule charges {model_bytes:.0f} read bytes "
                                 f"but the pipelined fetch bound is only "
                                 f"{pipe_bytes:.0f} B — elems look overstated"))

    return findings


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def verify_config(path: str, variant: str, d: DWConvDims, *, itemsize: int = 4,
                  block_h: int = 8, block_t: int = 512, batch_chunk: int = 128,
                  epilogue: str = "none",
                  dtype: str = "float32") -> Tuple[str, List[Finding]]:
    """Cross-check one configuration.  Returns ``(status, findings)`` with
    status in {"verified", "failed", "illegal", "model-only"} — "illegal"
    means the model and the kernel *agree* the layout is not runnable.
    ``dtype`` is the traced operand dtype; keep ``itemsize`` consistent with
    it (the model charges per-element bytes, the trace reports real blocks).
    """
    where = (f"{path}/{variant}[{epilogue}] "
             f"{d.B}x{d.H}x{d.L}x{d.K}/{d.padding} "
             f"bh{block_h}.bt{block_t}.bc{batch_chunk}")
    if variant not in PALLAS_VARIANTS.get(path, ()):
        return "model-only", []
    knobs = dict(block_h=block_h, block_t=block_t, batch_chunk=batch_chunk)
    sched = schedule_for(path, variant, d, itemsize, epilogue=epilogue, **knobs)
    legal, reason = check_legality(sched)
    records, err = trace_config(path, variant, d, epilogue=epilogue,
                                dtype=dtype, **knobs)
    if err is not None:
        if legal:
            return "failed", [_err("VER107", where,
                                   f"model says legal but the kernel wrapper "
                                   f"rejected the layout: {err}")]
        return "illegal", []
    if not legal:
        if not records:
            # The wrapper agreed without raising: it routed the call away
            # from the Pallas kernel entirely (decode K<2 runs the XLA
            # reference instead of launching an empty-ring kernel).
            return "illegal", []
        return "failed", [_err("VER107", where,
                               f"model says illegal ({reason}) but the kernel "
                               f"wrapper launched a Pallas kernel anyway")]
    if len(records) != 1:
        return "failed", [_err("VER101", where,
                               f"expected one pallas_call launch, traced "
                               f"{len(records)}")]
    d_pad = padded_dims(path, d, **knobs)
    sched_p = schedule_for(path, variant, d_pad, itemsize, epilogue=epilogue,
                           **knobs)
    findings = check_record(records[0], sched_p, d, path=path, variant=variant,
                            epilogue=epilogue, where=where, **knobs)
    return ("verified" if not findings else "failed"), findings
