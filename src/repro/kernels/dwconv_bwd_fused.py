"""Pallas TPU kernels — the *fused* backward pass (dx and dk in one sweep).

The split backward runs two independent ops: the input-gradient path pads
``dy`` into an adjoint layout and re-runs the forward kernels with a flipped
filter, then the weight-gradient path pads ``dy`` *again* (into a different
layout) and re-reads the freshly re-padded ``x``.  Every operand therefore
crosses HBM twice and three distinct padded layouts are materialized.

These kernels stage ``x_pad`` and ``dy`` in VMEM **once** per
(h-block x batch-chunk) grid cell and compute *both* gradients from the
shared slab:

    dx[b,h,s] = sum_j dy_pad[b,h,s+j] * k[h,K-1-j]     (flipped-filter taps)
    dk[h,j]   = sum_{b,t} dy[b,h,t] * x_pad[b,h,t+j]   (tap partials)

A single ``dy`` layout serves both: ``dy`` is padded with ``p_right`` zeros
on the left (the adjoint layout), so the dx taps read it at offset ``j`` and
the dk reduction reads the un-shifted window at static offset
``off_dk = p_right``.  Two family members mirror the weight-gradient study:

  fused          : dk accumulates in-place into a revisited output block
                   across the sequential batch-chunk grid (the ``accum``
                   structure); dx blocks are written per cell.
  fused_partials : per-chunk dk partials round-trip HBM and a second jnp
                   reduction combines them (the ``twostage`` structure).

Both members support *time tiling* (``block_t``), mirroring
``dwconv_bwdk``: a third, sequential grid dimension walks sequence tiles,
and each cell stages haloed ``(Bc, Hb, Lt + K - 1)`` slabs of **both**
operands (bound as current tile + right neighbour).  At every tile seam the
halo covers both consumers: the flipped-filter dx taps read
``dy[t*Lt + u + j]`` (max offset ``Lt + K - 2`` into the slab) and the dk
reduction reads ``dy`` at the static offset ``off_dk <= K - 1`` (max offset
``off_dk + Lt - 1 <= Lt + K - 2``), so one ``Lt + K - 1`` window serves
both gradients and the per-cell VMEM footprint is bounded by ``block_t``
regardless of L.  dx tiles are written per cell; dk accumulates across the
sequential (chunk x tile) axes exactly as in the untiled kernels.

Inputs arrive pre-padded from ``ops.py``:
  xp  (B, H, W) with ``p_left`` forward padding — the *forward's own*
      padded residual is accepted verbatim (untiled: its unified Wpad is a
      superset of the ``Wk = round_up(round_up(L,LANE) + K - 1, LANE)``
      window the BlockSpecs slice; tiled: ops.py grows/trims it to the
      ``(nT + 1) * Lt`` tile layout);
  dyp (B, H, W)    with ``p_right`` adjoint padding (width Wk untiled,
      ``(nT + 1) * Lt`` tiled);
  kp  (H, Kp)      lane-padded filters.
Outputs: dx (B, H, Lout or nT*Lt) in dy's dtype and dk (H, Kp) in f32;
``ops.py`` slices both back to logical shapes.  Accumulation is f32; the dk
partials are computed with the *same* slab shapes as ``dwconv_bwdk``'s
staged variants, so fused dk matches the ``accum`` variant bit-for-bit in
both the untiled and the tiled regime.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANE
from repro.kernels.dwconv_bwdk import (
    _check_chunking,
    _check_tiled_layout,
    _taps_from_slabs,
)
from repro.kernels.epilogue import act_grad


def _dx_from_slab(dy32: jnp.ndarray, kv: jnp.ndarray, K: int, Lout: int) -> jnp.ndarray:
    """(Bc, Hb, >=Lout+K-1) adjoint-padded dy slab -> dx taps (Bc, Hb, Lout)."""
    acc = jnp.zeros(dy32.shape[:2] + (Lout,), jnp.float32)
    for j in range(K):  # static unroll: flipped-filter multiply-adds from VMEM
        acc = acc + dy32[:, :, j : j + Lout] * kv[:, K - 1 - j][None, :, None]
    return acc


def _check_untiled_window(
    Wx: int, Wdy: int, block_w: int, Lout: int, K: int, off_dk: int
) -> None:
    if Wx < block_w or Wdy < block_w:
        raise ValueError(
            f"operand widths (x={Wx}, dy={Wdy}) are narrower than the staged "
            f"window block_w={block_w}; ops.py must pad both to the unified "
            f"fused-backward width")
    if not (block_w >= Lout + K - 1 >= off_dk + Lout):
        raise ValueError(
            f"staged window block_w={block_w} cannot hold Lout+K-1="
            f"{Lout + K - 1} (or off_dk={off_dk} exceeds K-1={K - 1}); the "
            f"fused window math in ops.py is inconsistent")


def _tiled_geometry(xp: jnp.ndarray, dyp: jnp.ndarray, Lt: int, K: int) -> int:
    """Validate the tiled operand layout; returns the tile count nT."""
    Wx, Wdy = xp.shape[-1], dyp.shape[-1]
    if Wx != Wdy:
        raise ValueError(
            f"tiled fused backward needs equal operand widths, got x={Wx} "
            f"dy={Wdy}; ops.py must pad both to (nT+1)*block_t columns")
    return _check_tiled_layout(Wx, Wx - Lt, Lt, K)


# ---------------------------------------------------------------------------
# fused (accum-style): sequential-grid in-place dk accumulation
# ---------------------------------------------------------------------------


def _fused_accum_kernel(
    x_ref, dy_ref, k_ref, dx_ref, dk_ref, *, K: int, Kp: int, Lout: int, off_dk: int
):
    c = pl.program_id(1)  # batch-chunk index — innermost, sequential

    @pl.when(c == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)

    # Both operand slabs staged once; every tap of BOTH gradients reads VMEM.
    x32 = x_ref[...].astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    kv = k_ref[...].astype(jnp.float32)
    dx_ref[...] = _dx_from_slab(dy32, kv, K, Lout).astype(dx_ref.dtype)
    dy_win = dy32[:, :, off_dk : off_dk + Lout]  # forward-aligned window
    dk_ref[...] += _taps_from_slabs(x32, dy_win, K, Kp).astype(dk_ref.dtype)


def _fused_accum_tiled_kernel(
    xc_ref, xn_ref, dyc_ref, dyn_ref, k_ref, dx_ref, dk_ref,
    *, K: int, Kp: int, Lt: int, off_dk: int,
):
    c = pl.program_id(1)  # batch-chunk index — sequential
    t = pl.program_id(2)  # time-tile index — innermost, sequential

    @pl.when(jnp.logical_and(c == 0, t == 0))
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)

    # Haloed slabs (current + right-neighbour tile) of BOTH operands: the
    # 2*Lt width covers every read below because Lt >= K-1 >= off_dk.
    x32 = jnp.concatenate([xc_ref[...], xn_ref[...]], axis=-1).astype(jnp.float32)
    dy32 = jnp.concatenate([dyc_ref[...], dyn_ref[...]], axis=-1).astype(jnp.float32)
    kv = k_ref[...].astype(jnp.float32)
    dx_ref[...] = _dx_from_slab(dy32, kv, K, Lt).astype(dx_ref.dtype)
    dy_win = dy32[:, :, off_dk : off_dk + Lt]  # forward-aligned window
    dk_ref[...] += _taps_from_slabs(x32, dy_win, K, Kp).astype(dk_ref.dtype)


def dwconv_bwd_fused_accum(
    xp: jnp.ndarray,
    dyp: jnp.ndarray,
    kp: jnp.ndarray,
    *,
    K: int,
    Lout: int,
    off_dk: int,
    block_w: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    block_t: Optional[int] = None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One staged pass -> (dx (B, H, Lout or nT*Lt), dk (H, Kp) f32)."""
    B, H, Wx = xp.shape
    _, Kp = kp.shape
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    _check_chunking(B, Bc, H, Hb)
    if block_t is not None and block_t < Lout:
        Lt = block_t
        nT = _tiled_geometry(xp, dyp, Lt, K)
        grid = (H // Hb, B // Bc, nT)
        return pl.pallas_call(
            functools.partial(
                _fused_accum_tiled_kernel, K=K, Kp=Kp, Lt=Lt, off_dk=off_dk),
            out_shape=[
                jax.ShapeDtypeStruct((B, H, nT * Lt), dyp.dtype),
                jax.ShapeDtypeStruct((H, Kp), jnp.float32),
            ],
            grid=grid,
            in_specs=[
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t + 1)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t + 1)),
                pl.BlockSpec((Hb, Kp), lambda h, c, t: (h, 0)),
            ],
            out_specs=[
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((Hb, Kp), lambda h, c, t: (h, 0)),
            ],
            interpret=interpret,
        )(xp, xp, dyp, dyp, kp)
    _check_untiled_window(Wx, dyp.shape[-1], block_w, Lout, K, off_dk)
    grid = (H // Hb, B // Bc)
    return pl.pallas_call(
        functools.partial(_fused_accum_kernel, K=K, Kp=Kp, Lout=Lout, off_dk=off_dk),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Lout), dyp.dtype),
            jax.ShapeDtypeStruct((H, Kp), jnp.float32),
        ],
        grid=grid,
        in_specs=[
            # Width block_w slices the staged window out of a possibly wider
            # forward residual — the reuse is free, not a re-pad.
            pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Bc, Hb, Lout), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        ],
        interpret=interpret,
    )(xp, dyp, kp)


# ---------------------------------------------------------------------------
# fused_partials (twostage-style): HBM dk partials + second reduction stage
# ---------------------------------------------------------------------------


def _fused_partials_kernel(
    x_ref, dy_ref, k_ref, dx_ref, part_ref, *, K: int, Kp: int, Lout: int, off_dk: int
):
    x32 = x_ref[...].astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    kv = k_ref[...].astype(jnp.float32)
    dx_ref[...] = _dx_from_slab(dy32, kv, K, Lout).astype(dx_ref.dtype)
    dy_win = dy32[:, :, off_dk : off_dk + Lout]
    part_ref[0] = _taps_from_slabs(x32, dy_win, K, Kp)


def _fused_partials_tiled_kernel(
    xc_ref, xn_ref, dyc_ref, dyn_ref, k_ref, dx_ref, part_ref,
    *, K: int, Kp: int, Lt: int, off_dk: int,
):
    x32 = jnp.concatenate([xc_ref[...], xn_ref[...]], axis=-1).astype(jnp.float32)
    dy32 = jnp.concatenate([dyc_ref[...], dyn_ref[...]], axis=-1).astype(jnp.float32)
    kv = k_ref[...].astype(jnp.float32)
    dx_ref[...] = _dx_from_slab(dy32, kv, K, Lt).astype(dx_ref.dtype)
    dy_win = dy32[:, :, off_dk : off_dk + Lt]
    part_ref[0, 0] = _taps_from_slabs(x32, dy_win, K, Kp)


def dwconv_bwd_fused_partials(
    xp: jnp.ndarray,
    dyp: jnp.ndarray,
    kp: jnp.ndarray,
    *,
    K: int,
    Lout: int,
    off_dk: int,
    block_w: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    block_t: Optional[int] = None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Staged pass with explicit per-chunk dk partials -> (dx, dk)."""
    B, H, Wx = xp.shape
    _, Kp = kp.shape
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    _check_chunking(B, Bc, H, Hb)
    nC = B // Bc
    if block_t is not None and block_t < Lout:
        Lt = block_t
        nT = _tiled_geometry(xp, dyp, Lt, K)
        grid = (H // Hb, nC, nT)
        dx, partials = pl.pallas_call(
            functools.partial(
                _fused_partials_tiled_kernel, K=K, Kp=Kp, Lt=Lt, off_dk=off_dk),
            out_shape=[
                jax.ShapeDtypeStruct((B, H, nT * Lt), dyp.dtype),
                jax.ShapeDtypeStruct((nC, nT, H, Kp), jnp.float32),
            ],
            grid=grid,
            in_specs=[
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t + 1)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t + 1)),
                pl.BlockSpec((Hb, Kp), lambda h, c, t: (h, 0)),
            ],
            out_specs=[
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((1, 1, Hb, Kp), lambda h, c, t: (c, t, h, 0)),
            ],
            interpret=interpret,
        )(xp, xp, dyp, dyp, kp)
        return dx, jnp.sum(partials, axis=(0, 1))  # second reduction stage
    _check_untiled_window(Wx, dyp.shape[-1], block_w, Lout, K, off_dk)
    grid = (H // Hb, nC)
    dx, partials = pl.pallas_call(
        functools.partial(_fused_partials_kernel, K=K, Kp=Kp, Lout=Lout, off_dk=off_dk),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Lout), dyp.dtype),
            jax.ShapeDtypeStruct((nC, H, Kp), jnp.float32),
        ],
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Bc, Hb, Lout), lambda h, c: (c, h, 0)),
            pl.BlockSpec((1, Hb, Kp), lambda h, c: (c, h, 0)),
        ],
        interpret=interpret,
    )(xp, dyp, kp)
    return dx, jnp.sum(partials, axis=0)  # second reduction stage


# ---------------------------------------------------------------------------
# Epilogue-aware fused backward: activation-recompute, dbias emission.
#
# When the forward fused a bias + activation epilogue (y = act(conv + b)),
# the backward needs dy_eff = dy * act'(pre) where pre = conv(x_pad, k) + b.
# These kernels *recompute* pre from the already-staged x slab — K extra
# MACs per element, from VMEM — instead of reading a saved pre-activation
# residual (a full-tensor HBM round-trip in each direction).  dy_eff is
# formed in-register in f32 and fed to the exact same dx/dk reductions as
# the trivial kernels; dbias = sum_{b,t} dy_eff rides the same revisited-
# block (accum) / HBM-partials (partials) machinery as dk, as an (H, LANE)
# column block.
#
# Geometry notes vs the trivial kernels:
#   * untiled: the staged window already covers every recompute read — the
#     adjoint dy slab positions v map to forward positions v - off_dk, and
#     wherever that leaves [0, Lout) the dy padding is zero, so the
#     out-of-range derivative values are multiplied away.
#   * tiled: pre must be recomputed for the *extended* window
#     [t*Lt - off_dk, t*Lt + Lt + K - 1 - off_dk), which reaches into the
#     neighbouring tiles' outputs on both sides.  The x slab therefore
#     binds THREE consecutive tiles (prev + cur + next; prev clamped to
#     tile 0 at t=0, where the mis-read region multiplies dy's zero left
#     padding) and requires ``Lt >= 2 * (K - 1)`` — enforced by
#     ``ops.epilogue_time_tile``, which otherwise falls back untiled.
# ---------------------------------------------------------------------------


def _pre_from_slab(x32: jnp.ndarray, kv: jnp.ndarray, K: int, n: int) -> jnp.ndarray:
    """(Bc, Hb, >=n+K-1) x slab -> forward conv recompute over n positions, f32."""
    acc = jnp.zeros(x32.shape[:2] + (n,), jnp.float32)
    for j in range(K):  # static unroll: the K recompute MACs, all from VMEM
        acc = acc + x32[:, :, j : j + n] * kv[:, j][None, :, None]
    return acc


def _bias_partial(dy_win: jnp.ndarray) -> jnp.ndarray:
    """(Bc, Hb, L) effective gradient window -> (Hb, LANE) dbias partial
    (value in column 0, zero elsewhere — the dk-partials block layout)."""
    s = jnp.sum(dy_win, axis=(0, 2))[:, None]
    return jnp.pad(s, ((0, 0), (0, LANE - 1)))


def _epi_grads_untiled(x32, dy32, kv, b_ref, K, Kp, Lout, off_dk, act):
    """Shared body: recompute pre, form dy_eff, emit (dx, dk_part, db_part)."""
    pre = _pre_from_slab(x32, kv, K, Lout)
    if b_ref is not None:
        pre = pre + b_ref[:, 0].astype(jnp.float32)[None, :, None]
    dy_win = dy32[:, :, off_dk : off_dk + Lout] * act_grad(pre, act)
    lead = dy32.shape[:2]
    W = dy32.shape[-1]
    # dy_eff in the adjoint slab layout: outside the forward-aligned window
    # the true dy padding is zero, so dy_eff is exactly zero there too.
    dy_eff = jnp.concatenate(
        [jnp.zeros(lead + (off_dk,), jnp.float32), dy_win,
         jnp.zeros(lead + (W - off_dk - Lout,), jnp.float32)], axis=-1)
    dx = _dx_from_slab(dy_eff, kv, K, Lout)
    return dx, _taps_from_slabs(x32, dy_win, K, Kp), _bias_partial(dy_win)


def _epi_grads_tiled(x3, dy2, kv, b_ref, K, Kp, Lt, off_dk, act):
    """Tiled shared body.  x3: (Bc, Hb, 3*Lt) prev+cur+next slab; dy2:
    (Bc, Hb, 2*Lt) cur+next slab.  Requires Lt >= 2*(K-1)."""
    n = Lt + K - 1
    # pre over the extended window [t*Lt - off_dk, t*Lt + Lt + K - 1 - off_dk):
    # base offset Lt - off_dk into the 3-tile slab (the prev tile serves the
    # left reach, the next tile the right reach).
    pre = _pre_from_slab(x3[:, :, Lt - off_dk :], kv, K, n)
    if b_ref is not None:
        pre = pre + b_ref[:, 0].astype(jnp.float32)[None, :, None]
    dy_eff = dy2[:, :, :n] * act_grad(pre, act)
    dx = _dx_from_slab(dy_eff, kv, K, Lt)
    dy_win = dy_eff[:, :, off_dk : off_dk + Lt]
    # dk taps read x at the tile-aligned offset (one tile into the slab).
    return dx, _taps_from_slabs(x3[:, :, Lt:], dy_win, K, Kp), _bias_partial(dy_win)


def _check_epi_tile(Lt: int, K: int) -> None:
    if Lt < 2 * (K - 1):
        raise ValueError(
            f"epilogue time tile block_t={Lt} cannot hold the extended "
            f"recompute window (needs Lt >= 2*(K-1)={2 * (K - 1)}); "
            f"ops.epilogue_time_tile must fall back to the untiled kernel")


def _fused_accum_epi_kernel(*refs, K, Kp, Lout, off_dk, act, has_bias):
    if has_bias:
        x_ref, dy_ref, k_ref, b_ref = refs[:4]
        dx_ref, dk_ref, db_ref = refs[4:]
    else:
        (x_ref, dy_ref, k_ref), b_ref = refs[:3], None
        dx_ref, dk_ref, db_ref = refs[3:]
    c = pl.program_id(1)  # batch-chunk index — innermost, sequential

    @pl.when(c == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    dx, dk_part, db_part = _epi_grads_untiled(
        x_ref[...].astype(jnp.float32), dy_ref[...].astype(jnp.float32),
        k_ref[...].astype(jnp.float32), b_ref, K, Kp, Lout, off_dk, act)
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dk_ref[...] += dk_part.astype(dk_ref.dtype)
    db_ref[...] += db_part.astype(db_ref.dtype)


def _fused_accum_epi_tiled_kernel(*refs, K, Kp, Lt, off_dk, act, has_bias):
    xp_, xc_, xn_, dyc_, dyn_, k_ref = refs[:6]
    b_ref = refs[6] if has_bias else None
    dx_ref, dk_ref, db_ref = refs[6 + (1 if has_bias else 0):]
    c = pl.program_id(1)  # batch-chunk index — sequential
    t = pl.program_id(2)  # time-tile index — innermost, sequential

    @pl.when(jnp.logical_and(c == 0, t == 0))
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    x3 = jnp.concatenate([xp_[...], xc_[...], xn_[...]], axis=-1).astype(jnp.float32)
    dy2 = jnp.concatenate([dyc_[...], dyn_[...]], axis=-1).astype(jnp.float32)
    dx, dk_part, db_part = _epi_grads_tiled(
        x3, dy2, k_ref[...].astype(jnp.float32), b_ref, K, Kp, Lt, off_dk, act)
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dk_ref[...] += dk_part.astype(dk_ref.dtype)
    db_ref[...] += db_part.astype(db_ref.dtype)


def _fused_partials_epi_kernel(*refs, K, Kp, Lout, off_dk, act, has_bias):
    if has_bias:
        x_ref, dy_ref, k_ref, b_ref = refs[:4]
        dx_ref, part_ref, bpart_ref = refs[4:]
    else:
        (x_ref, dy_ref, k_ref), b_ref = refs[:3], None
        dx_ref, part_ref, bpart_ref = refs[3:]
    dx, dk_part, db_part = _epi_grads_untiled(
        x_ref[...].astype(jnp.float32), dy_ref[...].astype(jnp.float32),
        k_ref[...].astype(jnp.float32), b_ref, K, Kp, Lout, off_dk, act)
    dx_ref[...] = dx.astype(dx_ref.dtype)
    part_ref[0] = dk_part
    bpart_ref[0] = db_part


def _fused_partials_epi_tiled_kernel(*refs, K, Kp, Lt, off_dk, act, has_bias):
    xp_, xc_, xn_, dyc_, dyn_, k_ref = refs[:6]
    b_ref = refs[6] if has_bias else None
    dx_ref, part_ref, bpart_ref = refs[6 + (1 if has_bias else 0):]
    x3 = jnp.concatenate([xp_[...], xc_[...], xn_[...]], axis=-1).astype(jnp.float32)
    dy2 = jnp.concatenate([dyc_[...], dyn_[...]], axis=-1).astype(jnp.float32)
    dx, dk_part, db_part = _epi_grads_tiled(
        x3, dy2, k_ref[...].astype(jnp.float32), b_ref, K, Kp, Lt, off_dk, act)
    dx_ref[...] = dx.astype(dx_ref.dtype)
    part_ref[0, 0] = dk_part
    bpart_ref[0, 0] = db_part


def _epi_tiled_in_specs(Bc: int, Hb: int, Lt: int, Kp: int, has_bias: bool):
    """x prev+cur+next (prev clamped at t=0), dy cur+next, filters, bias."""
    specs = [
        pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, jnp.maximum(t - 1, 0))),
        pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
        pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t + 1)),
        pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
        pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t + 1)),
        pl.BlockSpec((Hb, Kp), lambda h, c, t: (h, 0)),
    ]
    if has_bias:
        specs.append(pl.BlockSpec((Hb, LANE), lambda h, c, t: (h, 0)))
    return specs


def dwconv_bwd_fused_accum_act(
    xp: jnp.ndarray,
    dyp: jnp.ndarray,
    kp: jnp.ndarray,
    *,
    K: int,
    Lout: int,
    off_dk: int,
    block_w: int,
    bias=None,
    act: str = "none",
    block_h: int = 8,
    batch_chunk: int = 128,
    block_t: Optional[int] = None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Epilogue-aware single pass -> (dx, dk (H, Kp) f32, dbias (H, LANE) f32)."""
    B, H, Wx = xp.shape
    _, Kp = kp.shape
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    _check_chunking(B, Bc, H, Hb)
    has_bias = bias is not None
    if block_t is not None and block_t < Lout:
        Lt = block_t
        _check_epi_tile(Lt, K)
        nT = _tiled_geometry(xp, dyp, Lt, K)
        grid = (H // Hb, B // Bc, nT)
        operands = [xp, xp, xp, dyp, dyp, kp] + ([bias] if has_bias else [])
        return pl.pallas_call(
            functools.partial(
                _fused_accum_epi_tiled_kernel, K=K, Kp=Kp, Lt=Lt,
                off_dk=off_dk, act=act, has_bias=has_bias),
            out_shape=[
                jax.ShapeDtypeStruct((B, H, nT * Lt), dyp.dtype),
                jax.ShapeDtypeStruct((H, Kp), jnp.float32),
                jax.ShapeDtypeStruct((H, LANE), jnp.float32),
            ],
            grid=grid,
            in_specs=_epi_tiled_in_specs(Bc, Hb, Lt, Kp, has_bias),
            out_specs=[
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((Hb, Kp), lambda h, c, t: (h, 0)),
                pl.BlockSpec((Hb, LANE), lambda h, c, t: (h, 0)),
            ],
            interpret=interpret,
        )(*operands)
    _check_untiled_window(Wx, dyp.shape[-1], block_w, Lout, K, off_dk)
    grid = (H // Hb, B // Bc)
    in_specs = [
        pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
        pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
        pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
    ]
    operands = [xp, dyp, kp]
    if has_bias:
        in_specs.append(pl.BlockSpec((Hb, LANE), lambda h, c: (h, 0)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_fused_accum_epi_kernel, K=K, Kp=Kp, Lout=Lout,
                          off_dk=off_dk, act=act, has_bias=has_bias),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Lout), dyp.dtype),
            jax.ShapeDtypeStruct((H, Kp), jnp.float32),
            jax.ShapeDtypeStruct((H, LANE), jnp.float32),
        ],
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((Bc, Hb, Lout), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
            pl.BlockSpec((Hb, LANE), lambda h, c: (h, 0)),
        ],
        interpret=interpret,
    )(*operands)


def dwconv_bwd_fused_partials_act(
    xp: jnp.ndarray,
    dyp: jnp.ndarray,
    kp: jnp.ndarray,
    *,
    K: int,
    Lout: int,
    off_dk: int,
    block_w: int,
    bias=None,
    act: str = "none",
    block_h: int = 8,
    batch_chunk: int = 128,
    block_t: Optional[int] = None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Epilogue-aware staged pass with HBM dk *and* dbias partials."""
    B, H, Wx = xp.shape
    _, Kp = kp.shape
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    _check_chunking(B, Bc, H, Hb)
    nC = B // Bc
    has_bias = bias is not None
    if block_t is not None and block_t < Lout:
        Lt = block_t
        _check_epi_tile(Lt, K)
        nT = _tiled_geometry(xp, dyp, Lt, K)
        grid = (H // Hb, nC, nT)
        operands = [xp, xp, xp, dyp, dyp, kp] + ([bias] if has_bias else [])
        dx, partials, bpartials = pl.pallas_call(
            functools.partial(
                _fused_partials_epi_tiled_kernel, K=K, Kp=Kp, Lt=Lt,
                off_dk=off_dk, act=act, has_bias=has_bias),
            out_shape=[
                jax.ShapeDtypeStruct((B, H, nT * Lt), dyp.dtype),
                jax.ShapeDtypeStruct((nC, nT, H, Kp), jnp.float32),
                jax.ShapeDtypeStruct((nC, nT, H, LANE), jnp.float32),
            ],
            grid=grid,
            in_specs=_epi_tiled_in_specs(Bc, Hb, Lt, Kp, has_bias),
            out_specs=[
                pl.BlockSpec((Bc, Hb, Lt), lambda h, c, t: (c, h, t)),
                pl.BlockSpec((1, 1, Hb, Kp), lambda h, c, t: (c, t, h, 0)),
                pl.BlockSpec((1, 1, Hb, LANE), lambda h, c, t: (c, t, h, 0)),
            ],
            interpret=interpret,
        )(*operands)
        return dx, jnp.sum(partials, axis=(0, 1)), jnp.sum(bpartials, axis=(0, 1))
    _check_untiled_window(Wx, dyp.shape[-1], block_w, Lout, K, off_dk)
    grid = (H // Hb, nC)
    in_specs = [
        pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
        pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
        pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
    ]
    operands = [xp, dyp, kp]
    if has_bias:
        in_specs.append(pl.BlockSpec((Hb, LANE), lambda h, c: (h, 0)))
        operands.append(bias)
    dx, partials, bpartials = pl.pallas_call(
        functools.partial(_fused_partials_epi_kernel, K=K, Kp=Kp, Lout=Lout,
                          off_dk=off_dk, act=act, has_bias=has_bias),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Lout), dyp.dtype),
            jax.ShapeDtypeStruct((nC, H, Kp), jnp.float32),
            jax.ShapeDtypeStruct((nC, H, LANE), jnp.float32),
        ],
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((Bc, Hb, Lout), lambda h, c: (c, h, 0)),
            pl.BlockSpec((1, Hb, Kp), lambda h, c: (c, h, 0)),
            pl.BlockSpec((1, Hb, LANE), lambda h, c: (c, h, 0)),
        ],
        interpret=interpret,
    )(*operands)
    return dx, jnp.sum(partials, axis=0), jnp.sum(bpartials, axis=0)
