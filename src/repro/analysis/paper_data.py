"""Published measurements from the paper, used to validate the counter-free
analysis pipeline against the paper's own numbers (Tables II/III, Fig. 10).

All runtimes in milliseconds, steady-state (epochs 2-5, warm-up excluded),
NVIDIA P100, (B, H, L, K) = (16384, 128, 48, 48), float32.

This is the canonical home (importable from ``repro.launch.report`` without
a dependency on the ``benchmarks/`` tree); ``benchmarks/paper_constants.py``
re-exports everything for the benchmark harness.
"""
from repro.kernels.common import DWConvDims

PAPER_DIMS = DWConvDims(B=16384, H=128, L=48, K=48)

# Table II — per-path kernel runtimes (ms) + epoch time (s).
TABLE2_MS = {
    #            FWD    BWD_in  BWD_k   conv_total  epoch_s
    "naive":  (29.97, 30.25, 73.26, 133.47, 44.82),
    "gmc":    (28.23, 28.78, 49.64, 106.65, 40.31),
    "shared": (16.36, 16.03, 34.17, 66.57, 36.91),
    "warp":   (10.46, 10.61, 19.91, 40.99, 34.74),
}

# Appendix A — PyTorch grouped-conv1d reference runtimes (ms).
PYTORCH_MS = {"fwd": 28.44, "bwd_in": 25.62, "bwd_k": 141.73, "total": 195.79}

# Table III — the paper's counter-free effective-bandwidth estimates (GB/s).
TABLE3_GBPS = {"naive": None, "gmc": 42.0, "shared": 75.0, "warp": 115.0}

# Headline claims to reproduce.
CLAIM_KERNEL_SPEEDUP = 3.26   # warp vs naive, conv total
CLAIM_EPOCH_SPEEDUP = 1.29    # warp vs naive, end-to-end
CLAIM_BWDK_SPEEDUP = 3.68     # weight-gradient path speedup
CLAIM_FWD_SPEEDUP = 2.9       # forward ~2.9x

# Map paper variant names -> this framework's TPU kernel variants.
PAPER_TO_TPU = {"naive": "naive", "gmc": "lane", "shared": "block", "warp": "row"}
