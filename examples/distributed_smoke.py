"""Distributed-stack example: train a reduced LM with the full production
machinery (sharding rules, microbatched train step, checkpointing,
heartbeat), then serve it with a sharded KV cache.

Runs on however many devices are present (1 on this container; the same
code path drives the 512-chip dry-run).

  PYTHONPATH=src python examples/distributed_smoke.py
"""
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
ENV = {"PYTHONPATH": str(REPO / "src")}


def run(cmd):
    import os

    env = dict(os.environ, **ENV)
    print("$", " ".join(cmd))
    r = subprocess.run(cmd, env=env)
    if r.returncode != 0:
        sys.exit(r.returncode)


with tempfile.TemporaryDirectory() as td:
    run([sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-0.5b",
         "--smoke", "--steps", "10", "--batch", "8", "--seq", "32",
         "--microbatches", "2", "--grad-dtype", "bfloat16",
         "--ckpt-dir", f"{td}/ckpt", "--ckpt-every", "5",
         "--heartbeat", f"{td}/hb.json", "--log-every", "2"])
    # resume from the checkpoint for 5 more steps (restart path)
    run([sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-0.5b",
         "--smoke", "--steps", "15", "--batch", "8", "--seq", "32",
         "--microbatches", "2",
         "--ckpt-dir", f"{td}/ckpt", "--ckpt-every", "5", "--log-every", "2"])
    # serve the same family with a sharded-cache decode loop
    run([sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2-0.5b",
         "--smoke", "--batch", "2", "--prompt-len", "8", "--gen", "8"])
print("distributed smoke OK")
