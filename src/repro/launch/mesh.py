"""Production mesh construction (assignment §Multi-pod dry-run).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # ``axis_types`` only exists on newer jax; Auto is the default there, so
    # passing nothing on older versions (0.4.x has no jax.sharding.AxisType)
    # is semantically identical.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading pod axis (2 pods =
    512 chips).  The ``pod`` axis carries only gradient all-reduces (DCN);
    ``data``/``model`` collectives stay on ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/elastic restarts (e.g. (2,4) on 8 devices)."""
    return _make_mesh(tuple(shape), tuple(axes))


def describe(mesh) -> str:
    return f"mesh{dict(mesh.shape)} on {mesh.devices.size} devices"
