"""Static verification: schedule↔kernel cross-checker + repo lint.

Extends the paper's counter-free methodology one level down — the analytical
``KernelSchedule``s are proven against the kernels' actual launch geometry
(grids, BlockSpecs, index maps, accumulators, VMEM) by abstract tracing, so
model↔kernel agreement is a reviewed invariant rather than a runtime hope.

  * ``repro.verify.schedule_check.verify_config`` — one configuration
  * ``python -m repro.launch.verify`` — registry × shape-grid sweep
  * ``python -m repro.verify.lint`` — AST repo lint (REP001-REP005)
"""
from repro.verify.findings import (Finding, findings_payload, max_severity,
                                   should_fail)

__all__ = ["Finding", "findings_payload", "max_severity", "should_fail"]
