import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import (jax locks the device
# count at first init).  Override for tests via REPRO_DRYRUN_DEVICES.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_DRYRUN_DEVICES']}"
    )

"""Multi-pod dry-run (deliverable e).

For every assigned (architecture x input-shape) cell, on the single-pod
16x16 mesh and the 2x16x16 multi-pod mesh: lower + compile the real step
function (train_step for train cells, prefill/serve_step for inference
cells) with ShapeDtypeStruct inputs (zero allocation), print
``memory_analysis()`` (proves fit) and ``cost_analysis()`` (FLOPs/bytes for
§Roofline), parse the post-SPMD HLO for collective bytes, and append the
roofline record to ``results/dryrun/<cell>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import roofline_from_compiled
from repro.configs.registry import get_config, list_archs, shape_cells_for
from repro.distributed import sharding as shd
from repro.distributed.stepfn import (
    batch_shardings,
    build_prefill_step,
    build_serve_step,
    build_train_step,
    cache_shardings,
    opt_state_shardings,
    params_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models.api import batch_axes, batch_spec, decode_batch_spec, get_model
from repro.models.config import SHAPES
from repro.train.optim import adamw

RESULTS_DIR = Path(os.environ.get("REPRO_RESULTS_DIR", "results/dryrun"))

# Microbatch counts chosen so the train_4k cells fit 16 GiB/chip (DESIGN §4).
# Small-d archs with head counts that do not divide |model| (qwen2 14H,
# smollm 9H, whisper 8H) leave attention scores replicated across `model`,
# so they need deeper microbatching than their size suggests (see
# EXPERIMENTS.md §Perf for the sequence-parallel alternative).
MICROBATCHES = {
    "llama3-8b": 4, "gemma3-27b": 16, "llama-3.2-vision-11b": 8,
    "deepseek-moe-16b": 4, "olmoe-1b-7b": 2, "mamba2-1.3b": 4,
    "recurrentgemma-2b": 4, "whisper-base": 8, "qwen2-0.5b": 4,
    "smollm-135m": 4,
}


def model_flops_for(cfg, cell, model) -> float:
    n, n_act = model.n_params(), model.n_active_params()
    if cell.kind == "train":
        return 6.0 * n_act * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_act * cell.global_batch * cell.seq_len
    return 2.0 * n_act * cell.global_batch  # one decoded token per sequence


def lower_cell(arch: str, shape_name: str, mesh, mesh_label: str,
               cfg=None, microbatches=None, rules=None):
    """Lower + compile one cell.  ``cfg``/``microbatches``/``rules`` overrides
    support the §Perf hillclimbing loop (patched configs, same harness)."""
    cfg = cfg or get_config(arch)
    cell = SHAPES[shape_name]
    model = get_model(cfg)
    # Times lowering + AOT compile (synchronous host work), not dispatched
    # device values, so no block_until_ready is involved.
    t0 = time.time()  # repro: noqa(REP002)

    if rules is None:
        if cell.kind == "train":
            rules = "train"
        elif cell.kind == "long_decode":
            rules = "long_serve"
        else:
            rules = "serve"

    with mesh, shd.use_sharding(mesh, rules):
        params_shapes = model.init_shapes()
        if cell.kind != "train":
            # inference serves bf16 weights (checkpoint cast at load)
            params_shapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
                params_shapes)
        p_shard = params_shardings(model, mesh, rules)

        if cell.kind == "train":
            mb = microbatches or MICROBATCHES.get(arch, 1)
            # microbatch must still cover every DP shard (pod x data)
            dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
            mb = max(1, min(mb, cell.global_batch // dp))
            opt = adamw(lr=3e-4)
            step = build_train_step(model, opt, microbatches=mb)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            o_shard = opt_state_shardings(model, opt, mesh, rules)
            b_spec = batch_spec(cfg, cell)
            b_shard = batch_shardings(batch_axes(cfg, cell), b_spec, mesh, rules)
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shapes, opt_shapes, b_spec)
        elif cell.kind == "prefill":
            step = build_prefill_step(model)
            b_spec = batch_spec(cfg, cell)
            b_spec.pop("labels", None)
            ba = {k: v for k, v in batch_axes(cfg, cell).items() if k in b_spec}
            b_shard = batch_shardings(ba, b_spec, mesh, rules)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_shapes, b_spec)
        else:  # decode / long_decode
            step = build_serve_step(model)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(cell.global_batch, cell.seq_len))
            c_shard = cache_shardings(model, mesh, rules, cache_shapes)
            b_spec = decode_batch_spec(cfg, cell)
            b_shard = batch_shardings({"tokens": ("act_batch", None)}, b_spec, rules=rules, mesh=mesh)
            jitted = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, cache_shapes, b_spec)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(f"[{arch} x {shape_name} x {mesh_label}] memory_analysis:", mem)
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    print(f"[{arch} x {shape_name} x {mesh_label}] cost_analysis: "
          f"flops={cost.get('flops', 0):.3e} bytes={cost.get('bytes accessed', 0):.3e}")

    chips = mesh.devices.size
    hlo_text = compiled.as_text()
    hlo = analyze_hlo(hlo_text, num_partitions=chips)
    rep = roofline_from_compiled(
        compiled,
        label=f"{arch}|{shape_name}|{mesh_label}",
        chips=chips,
        model_flops=model_flops_for(cfg, cell, model),
        hlo_analysis=hlo,
    )
    record = rep.to_dict()
    record.update(
        arch=arch, shape=shape_name, mesh=mesh_label,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        argument_bytes=int(mem.argument_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
        output_bytes=int(mem.output_size_in_bytes),
        collective_counts=hlo.counts_by_kind(),
        generated_code_bytes=int(mem.generated_code_size_in_bytes),
        microbatches=(microbatches or MICROBATCHES.get(arch, 1)) if cell.kind == "train" else 1,
        hlo_bytes_len=len(hlo_text),
    )
    # memory_analysis sizes are per-device for an SPMD executable:
    # arguments (donated params+opt+cache) + temp working set.
    per_dev_total = record["argument_bytes"] + record["temp_bytes"]
    record["bytes_per_device_estimate"] = per_dev_total
    record["fits_16gb"] = bool(per_dev_total < 16 * 2 ** 30)
    print(f"[{arch} x {shape_name} x {mesh_label}] roofline: "
          f"compute={rep.compute_s:.3e}s memory={rep.memory_s:.3e}s "
          f"collective={rep.collective_s:.3e}s dominant={rep.dominant} "
          f"useful={rep.useful_flops_ratio:.3f} per_dev={per_dev_total/2**30:.2f}GiB")
    return record


def result_path(arch, shape_name, mesh_label) -> Path:
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh_label}.json"


def make_dryrun_mesh(multi_pod: bool):
    """Production mesh, or a scaled-down stand-in when the test harness caps
    the fake-device count (REPRO_DRYRUN_DEVICES)."""
    if jax.device_count() >= 512:
        return make_production_mesh(multi_pod=multi_pod)
    from repro.launch.mesh import make_mesh

    n = jax.device_count()
    if multi_pod:
        return make_mesh((2, n // 4, 2), ("pod", "data", "model"))
    return make_mesh((n // 4, 4), ("data", "model"))


def run_one(arch, shape_name, mesh_label, force=False) -> dict:
    out = result_path(arch, shape_name, mesh_label)
    if out.exists() and not force:
        print(f"skip (cached): {out}")
        return json.loads(out.read_text())
    multi = mesh_label == "pod2x16x16"
    mesh = make_dryrun_mesh(multi_pod=multi)
    rec = lower_cell(arch, shape_name, mesh, mesh_label)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", default="", choices=["", "pod1x16x16", "pod2x16x16"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    jobs = []
    archs = [args.arch] if args.arch else list_archs()
    for arch in archs:
        cfg = get_config(arch)
        cells = [args.shape] if args.shape else shape_cells_for(cfg)
        for cell in cells:
            meshes = [args.mesh] if args.mesh else ["pod1x16x16", "pod2x16x16"]
            for m in meshes:
                jobs.append((arch, cell, m))

    failures = []
    for arch, cell, m in jobs:
        try:
            run_one(arch, cell, m, force=args.force)
        except Exception as e:
            failures.append((arch, cell, m, repr(e)))
            traceback.print_exc()
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print(f"dry-run complete: {len(jobs)} cells OK")


if __name__ == "__main__":
    main()
