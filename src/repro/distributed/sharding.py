"""Logical-axis sharding rules (MaxText-style), mesh-agnostic model code.

Model code annotates tensors with *logical* axis names
(``shard(x, "act_batch", "act_seq", "act_embed")``); the launcher activates a
rule table mapping logical names to mesh axes for the current use case
(train / serve / long-context serve).  With no active context the calls are
identity, so single-device tests and benchmarks are untouched.

Parameters get their sharding from per-leaf logical axes declared by each
model's ``param_axes(cfg)`` tree, converted here to NamedShardings.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxis = Union[str, Tuple[str, ...], None]
Rules = Dict[str, MeshAxis]

# ---------------------------------------------------------------------------
# rule tables
# ---------------------------------------------------------------------------

# Training: DP over (pod, data); FSDP shards params' embed axis over data;
# TP over model for heads / mlp / vocab / experts.
TRAIN_RULES: Rules = {
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_heads": "model",
    # fallback for attention scores when n_heads does not divide |model|
    # (qwen2 14H, smollm 9H, whisper 8H): shard the query-sequence dim
    # instead — sequence-parallel attention (§Perf iteration D).
    "act_attn_q": "model",
    "act_kv_heads": None,
    "act_vocab": "model",
    "act_mlp": "model",
    "act_experts": "model",
    "act_groups": ("pod", "data"),
    "act_capacity": None,
    "act_state": None,
    # params
    "embed": "data",          # FSDP/ZeRO-3 shard of the residual axis
    "heads": "model",
    "kv_heads": None,          # replicated: n_kv may be < |model|
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "layers": None,
    "state": None,
    "conv_k": None,
    "scale": None,
}

# Serving (decode): KV cache sequence-sharded over model — GSPMD derives the
# flash-decoding partial-softmax combine automatically.
SERVE_RULES: Rules = dict(
    TRAIN_RULES,
    act_batch=("pod", "data"),
    cache_batch=("pod", "data"),
    cache_seq="model",
    cache_kv_heads=None,
    embed="data",             # 2D weight sharding (gathered just-in-time) —
                              # required to fit 27B-class params next to a
                              # 32k KV cache on 16 GiB chips
)

# Long-context single-sequence serving: batch too small to fill `data`,
# so the cache sequence shards over BOTH data and model.
LONG_SERVE_RULES: Rules = dict(
    SERVE_RULES,
    cache_seq=("data", "model"),
    act_batch=None,
)

RULESETS = {"train": TRAIN_RULES, "serve": SERVE_RULES, "long_serve": LONG_SERVE_RULES}


# ---------------------------------------------------------------------------
# active context
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Active:
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_STATE = threading.local()


def _active() -> _Active:
    if not hasattr(_STATE, "v"):
        _STATE.v = _Active()
    return _STATE.v


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Union[str, Rules]):
    """Activate a rule table for model code executed in this context."""
    if isinstance(rules, str):
        rules = RULESETS[rules]
    prev = _active().mesh, _active().rules
    _active().mesh, _active().rules = mesh, rules
    try:
        yield
    finally:
        _active().mesh, _active().rules = prev


def logical_to_spec(axes: Sequence[Optional[str]], rules: Rules,
                    mesh: Optional[Mesh] = None,
                    shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to a PartitionSpec under the rule table.

    With ``mesh`` + ``shape``, allocation is divisibility-aware: a mesh axis
    that cannot divide its dimension is *not* consumed, so a later logical
    axis may claim it (e.g. attention scores fall back from head sharding to
    query-sequence sharding when n_heads does not divide |model|)."""
    parts = []
    used = set()
    dims = list(shape) if shape is not None else [None] * len(axes)
    for ax, dim in zip(axes, dims):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            parts.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a not in used
                   and (mesh is None or a in mesh.shape))
        if mesh is not None and dim is not None and ms:
            size = 1
            for a in ms:
                size *= mesh.shape[a]
            if dim % size != 0:
                ms = ()  # would not divide: leave free for later axes
        used.update(ms)
        parts.append(None if not ms else (ms[0] if len(ms) == 1 else ms))
    return P(*parts)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate ``x`` with a sharding constraint under the active rules."""
    st = _active()
    if st.mesh is None or st.rules is None:
        return x
    # Trim/pad logical axes to the array rank (defensive for rank changes).
    ax = tuple(axes)[: x.ndim]
    ax = ax + (None,) * (x.ndim - len(ax))
    spec = logical_to_spec(ax, st.rules, st.mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(st.mesh, spec))


def spec_for_axes(axes: Sequence[Optional[str]], mesh: Mesh, rules: Union[str, Rules],
                  shape: Optional[Sequence[int]] = None) -> NamedSharding:
    """NamedSharding for a parameter/input with the given logical axes."""
    if isinstance(rules, str):
        rules = RULESETS[rules]
    spec = logical_to_spec(axes, rules, mesh, shape)
    return NamedSharding(mesh, spec)


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh, rules: Union[str, Rules]):
    """Map a tree of logical-axis tuples + matching ShapeDtypeStructs to
    NamedShardings (used for in_shardings of the dry-run train_step)."""
    return jax.tree.map(
        lambda axes, sds: spec_for_axes(axes, mesh, rules, sds.shape),
        axes_tree,
        shapes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(isinstance(a, (str, type(None))) for a in t),
    )
