"""Two-stage cost model: analytical pre-rank, counter-free measurement.

Stage 1 (analytical, free): every candidate is scored with the paper's
§III-G traffic model (``analysis/traffic.py``) pushed through a roofline
bound (``analysis/hw.py``) plus a per-DMA issue-overhead term — the same
counter-free machinery the paper uses to *explain* variant ordering, used
here to *predict* it.  This prunes the space without running anything.

Stage 2 (empirical, metered): only the top-N survivors are executed and
timed with ``analysis/timer.time_fn`` — explicit synchronization, warm-up
excluded, steady-state statistics (the paper's CUDA-event protocol, §III-F).
No hardware counters are consulted anywhere, so the tuner runs in exactly
the restricted cloud environments the paper targets.

The measurement hook is injectable (``measure_fn``) so tuning is
deterministic under test and so alternative objectives (e.g. energy proxies)
can be swapped in.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import perfmodel
from repro.analysis.hw import TPU_V5E, HardwareModel
from repro.analysis.timer import Timing, time_fn
from repro.kernels import ops, ref
from repro.kernels.common import DWConvDims
from repro.kernels.epilogue import parse_epilogue
from repro.perfmodel import DMA_OVERHEAD_S  # noqa: F401  (historical home)
from repro.perfmodel.schedule import KernelSchedule, TrafficEstimate
from repro.tuning.space import Candidate


def _schedule_for(c: Candidate, d: DWConvDims, itemsize: int,
                  epilogue: str = "none") -> KernelSchedule:
    """The candidate's registered schedule on the path the tuner scores.

    ``fwd`` scores the fused-epilogue kernel; ``bwd_in``/``bwd_k`` are
    epilogue-less (the split reductions consume dy_eff unchanged);
    ``bwd_fused`` is the whole-backward accounting (pad materialization
    charged) so fused candidates rank against the "split" two-op baseline
    like for like — the epilogue-aware schedule charges the recompute MACs
    on the fused side and the standalone pre-activation pass on the split
    side.
    """
    return perfmodel.schedule_for(
        c.path, c.variant, d, itemsize,
        block_h=c.block_h, block_t=c.block_t, batch_chunk=c.batch_chunk,
        epilogue=epilogue if c.path in ("fwd", "bwd_fused", "decode") else "none")


def _traffic_for(c: Candidate, d: DWConvDims, itemsize: int,
                 epilogue: str = "none") -> TrafficEstimate:
    return perfmodel.derive_traffic(_schedule_for(c, d, itemsize, epilogue))


def analytical_time_s(
    c: Candidate,
    d: DWConvDims,
    *,
    itemsize: int = 4,
    hw: HardwareModel = TPU_V5E,
    epilogue: str = "none",
) -> float:
    """Roofline-bounded execution-time estimate for one candidate (seconds).

    ``max(compute, memory)`` is the perfect-overlap roofline bound; the DMA
    term models serialization of transaction issue, which is what actually
    separates the per-tap-DMA variants from the staged ones on equal-FLOP
    problems.  ``reliable=False`` traffic (the naive baseline's
    cache-dependent redundancy) is still ranked by its logical traffic —
    pessimistic, exactly like the paper's Table III treatment.
    """
    return perfmodel.analytical_time_s(
        _schedule_for(c, d, itemsize, epilogue), hw)


def rank_candidates(
    candidates: Sequence[Candidate],
    d: DWConvDims,
    *,
    itemsize: int = 4,
    hw: HardwareModel = TPU_V5E,
    top_n: Optional[int] = None,
    epilogue: str = "none",
) -> List[Tuple[Candidate, float]]:
    """Sort candidates by analytical cost; keep the best ``top_n`` if set."""
    scored = [(c, analytical_time_s(c, d, itemsize=itemsize, hw=hw,
                                    epilogue=epilogue))
              for c in candidates]
    scored.sort(key=lambda cs: cs[1])
    return scored[:top_n] if top_n else scored


# ---------------------------------------------------------------------------
# stage 2: counter-free measurement
# ---------------------------------------------------------------------------


def _dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}.get(name, jnp.float32)


def build_measurable(
    c: Candidate,
    d: DWConvDims,
    *,
    dtype: str = "float32",
    interpret: Optional[bool] = None,
    seed: int = 0,
    epilogue: str = "none",
) -> Tuple[Callable, tuple]:
    """A jitted zero-arg-ready ``(fn, args)`` executing the candidate's path."""
    dt = _dtype_of(dtype)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(d.B, d.H, d.L)), dt)
    k = jnp.asarray(rng.normal(size=(d.H, d.K)), dt)
    opts = c.options(interpret=interpret)
    has_bias, act = parse_epilogue(epilogue)
    bias = jnp.asarray(rng.normal(size=(d.H,)), dt) if has_bias else None
    if epilogue != "none" and c.path not in ("fwd", "bwd_fused", "decode"):
        raise ValueError(
            f"epilogue {epilogue!r} applies to the 'fwd'/'bwd_fused'/'decode' "
            f"paths, not {c.path!r} (the split reductions consume dy_eff "
            f"unchanged)")

    if c.path == "fwd":
        if c.variant == "xla":
            fn = jax.jit(lambda x, k: ref.dwconv_act_ref(
                x, k, bias=bias, act=act, padding=d.padding))
        else:
            fn = jax.jit(lambda x, k: ops.dwconv_fwd_op(
                x, k, d.padding, c.variant, opts, bias=bias, act=act))
        return fn, (x, k)
    if c.path == "bwd_in":
        dy = x
        if c.variant == "xla":
            fn = jax.jit(lambda dy, k: ref.dwconv_bwd_input_ref(dy, k, d.padding))
        else:
            fn = jax.jit(lambda dy, k: ops.dwconv_bwd_input_op(dy, k, d.padding, c.variant, opts))
        return fn, (dy, k)
    if c.path == "bwd_k":
        dy = jnp.asarray(rng.normal(size=(d.B, d.H, d.L)), dt)
        if c.variant == "xla":
            fn = jax.jit(lambda x, dy: ref.dwconv_bwd_kernel_ref(x, dy, d.K, d.padding))
        else:
            fn = jax.jit(
                lambda x, dy: ops.dwconv_bwd_kernel_op(x, dy, d.K, d.padding, c.variant, opts))
        return fn, (x, dy)
    if c.path == "bwd_fused":
        # Whole backward in one measurable: the fused kernels, or — for the
        # "split" baseline — the two independent ops resolved through their
        # own tuned (or fallback) configurations.  With an epilogue, the
        # epilogue-aware entry point runs (recompute kernels vs the
        # standalone-recompute split composition).
        dy = jnp.asarray(rng.normal(size=(d.B, d.H, d.L)), dt)
        if epilogue == "none":
            fn = jax.jit(
                lambda x, dy, k: ops.dwconv_bwd_fused_op(
                    x, dy, k, d.padding, c.variant,
                    None if c.variant == "split" else opts))
        else:
            fn = jax.jit(
                lambda x, dy, k: ops.dwconv_bwd_fused_act_op(
                    x, dy, k, bias, d.padding, c.variant,
                    None if c.variant == "split" else opts, act=act))
        return fn, (x, dy, k)
    if c.path == "decode":
        # One fused single-step over a (B, H, K-1) ring — the serving hot
        # path's per-token conv work.  L is not part of the problem (the
        # whole point); d.L is ignored beyond the shape key.
        ring = jnp.asarray(rng.normal(size=(d.B, d.H, max(d.K - 1, 0))), dt)
        xs = jnp.asarray(rng.normal(size=(d.B, d.H)), dt)
        if c.variant == "xla":
            fn = jax.jit(lambda ring, xs: ref.dwconv_decode_ref(
                ring, xs, k, bias=bias, act=act))
        else:
            fn = jax.jit(lambda ring, xs: ops.dwconv_decode_op(
                ring, xs, k, c.variant, opts, bias=bias, act=act))
        return fn, (ring, xs)
    raise ValueError(f"unknown path {c.path!r}")


def measure_candidate(
    c: Candidate,
    d: DWConvDims,
    *,
    dtype: str = "float32",
    warmup: int = 1,
    iters: int = 3,
    interpret: Optional[bool] = None,
    timer: Callable[..., Timing] = time_fn,
    seed: int = 0,
    epilogue: str = "none",
) -> float:
    """Steady-state seconds-per-call for one candidate (paper §III-F)."""
    fn, args = build_measurable(c, d, dtype=dtype, interpret=interpret,
                                seed=seed, epilogue=epilogue)
    t = timer(fn, *args, warmup=warmup, iters=iters)
    return float(t.mean_s)
