"""Paper Table II analogue: per-execution-path runtime across kernel variants.

Two regimes:
  (a) *paper validation*: the paper's published P100 runtimes are checked
      against the paper's claimed speedups (3.26x kernel, 3.68x BWD_k) —
      this pins the reproduction target.
  (b) *this framework*: wall-clock of the TPU-analogue Pallas variants in
      interpret mode on CPU at reduced batch (interpret mode executes kernel
      bodies in Python; absolute times are not architecture predictions —
      the per-variant DMA/traffic *structure* plus §Roofline carry the
      architectural content, exactly the counter-free thesis).
      The XLA reference path runs at the paper's full dims.
      Single-number timings are medians: on shared cloud runners the
      counter-free protocol has no counters to disqualify a descheduled
      iteration, so the median is the robust steady-state summary.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_constants import (
    CLAIM_BWDK_SPEEDUP,
    CLAIM_KERNEL_SPEEDUP,
    PAPER_DIMS,
    PAPER_TO_TPU,
    TABLE2_MS,
)
from repro.analysis.timer import time_fn
from repro.core import dwconv as dw
from repro.kernels import ops
from repro.kernels.common import DWConvDims


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


REDUCED = DWConvDims(B=64, H=128, L=48, K=48)


def paper_validation_rows() -> List[Row]:
    rows = []
    naive_total = TABLE2_MS["naive"][3]
    naive_bwdk = TABLE2_MS["naive"][2]
    naive_epoch = TABLE2_MS["naive"][4]
    for v, (fwd, bwd_in, bwd_k, total, epoch) in TABLE2_MS.items():
        rows.append(Row(f"paper_table2/{v}/conv_total", total * 1e3,
                        f"speedup_vs_naive={naive_total / total:.2f}x"))
    warp = TABLE2_MS["warp"]
    k_speed = naive_total / warp[3]
    e_speed = naive_epoch / warp[4]
    bk_speed = naive_bwdk / warp[2]
    assert abs(k_speed - CLAIM_KERNEL_SPEEDUP) < 0.02, k_speed
    assert abs(bk_speed - CLAIM_BWDK_SPEEDUP) < 0.02, bk_speed
    rows.append(Row("paper_table2/claims", 0.0,
                    f"kernel={k_speed:.2f}x(claim 3.26) epoch={e_speed:.2f}x(claim 1.29) "
                    f"bwdk={bk_speed:.2f}x(claim 3.68) REPRODUCED"))
    return rows


def framework_rows(iters: int = 3) -> List[Row]:
    d = REDUCED
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(d.B, d.H, d.L)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(d.H, d.K)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(d.B, d.H, d.L)), jnp.float32)
    opts = ops.KernelOptions(batch_chunk=16)
    rows: List[Row] = []
    for paper_name, tpu_name in PAPER_TO_TPU.items():
        f_fwd = jax.jit(lambda x, k, v=tpu_name: dw.run_fwd(x, k, "same", v, opts))
        f_bin = jax.jit(lambda dy, k, v=tpu_name: dw.run_bwd_input(dy, k, "same", v, opts))
        f_bk = jax.jit(lambda x, dy, v=tpu_name: dw.run_bwd_kernel(x, dy, d.K, "same", v, opts))
        t_fwd = time_fn(f_fwd, x, k, warmup=1, iters=iters)
        t_bin = time_fn(f_bin, dy, k, warmup=1, iters=iters)
        t_bk = time_fn(f_bk, x, dy, warmup=1, iters=iters)
        rows.append(Row(f"tpu_analogue/{tpu_name}/fwd", t_fwd.median_us, f"paper_variant={paper_name}"))
        rows.append(Row(f"tpu_analogue/{tpu_name}/bwd_in", t_bin.median_us, f"paper_variant={paper_name}"))
        rows.append(Row(f"tpu_analogue/{tpu_name}/bwd_k", t_bk.median_us, f"paper_variant={paper_name}"))
    # XLA reference at the paper's full dims (the production path).
    dfull = PAPER_DIMS
    xf = jnp.asarray(rng.normal(size=(256, dfull.H, dfull.L)), jnp.float32)  # per-step shard
    kf = jnp.asarray(rng.normal(size=(dfull.H, dfull.K)), jnp.float32)
    f_xla = jax.jit(lambda x, k: dw.run_fwd(x, k, "same", "xla"))
    t_xla = time_fn(f_xla, xf, kf, warmup=1, iters=iters)
    rows.append(Row("tpu_analogue/xla/fwd_256batch", t_xla.median_us, "production reference"))
    return rows


def run(fast: bool = False) -> List[Row]:
    rows = paper_validation_rows()
    rows += framework_rows(iters=2 if fast else 3)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
