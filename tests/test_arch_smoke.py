"""Per-architecture smoke tests: reduced same-family configs, one forward +
train-grad step + one decode step on CPU; output shapes + finiteness.

These are the assignment's required smoke tests for all 10 architectures.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.models.api import get_model, make_demo_batch
from repro.train.optim import sgd_momentum

ARCHS = list_archs()
B, S = 2, 16


def _smoke(name):
    cfg = get_config(name, smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_demo_batch(cfg, B, S)
    return cfg, model, params, batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_loss_finite(name):
    cfg, model, params, batch = _smoke(name)
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss)), (name, float(loss))
    # an untrained model should start near ln(vocab)
    assert float(loss) < np.log(cfg.vocab) * 3


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_updates_params(name):
    cfg, model, params, batch = _smoke(name)
    opt = sgd_momentum(lr=1e-2)
    state = opt.init(params)
    loss0, grads = jax.value_and_grad(model.loss)(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, name
    new_params, _ = opt.update(grads, params, state)
    changed = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert changed, name


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_shapes(name):
    cfg, model, params, batch = _smoke(name)
    cache = model.init_cache(B, 32)
    if cfg.family == "encdec":
        from repro.models import encdec

        enc_states = encdec.encode(params, cfg, batch["frames"])
        ck, cv = encdec.precompute_cross_cache(params, cfg, enc_states)
        cache["cross_k"], cache["cross_v"] = ck, cv
    if cfg.family == "vlm":
        from repro.models import vlm

        ik, iv = vlm.precompute_img_cache(params, cfg, batch["img"])
        cache["img_k"], cache["img_v"] = ik, iv
    tok = batch["tokens"][:, :1]
    logits, cache2 = model.decode_step(params, cache, {"tokens": tok})
    assert logits.shape == (B, 1, cfg.vocab), (name, logits.shape)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), name
    assert int(cache2["pos"]) == 1
    # a second step advances the position
    logits3, cache3 = model.decode_step(params, cache2, {"tokens": tok})
    assert int(cache3["pos"]) == 2


@pytest.mark.parametrize("name", ARCHS)
def test_param_axes_cover_params(name):
    cfg, model, params, _ = _smoke(name)
    axes = model.param_axes()
    pl = jax.tree.leaves(params)
    al = jax.tree.leaves(axes, is_leaf=lambda t: isinstance(t, tuple))
    assert len(pl) == len(al), (name, len(pl), len(al))
    for p, a in zip(pl, al):
        assert len(a) == p.ndim, (name, p.shape, a)


@pytest.mark.parametrize("name", ["llama3-8b", "mamba2-1.3b", "olmoe-1b-7b",
                                  "deepseek-moe-16b", "recurrentgemma-2b",
                                  "gemma3-27b", "llama-3.2-vision-11b"])
def test_full_config_param_count(name):
    """The FULL configs are never allocated — eval_shape only — and their
    analytic param counts must match the abstract tree within 1%."""
    cfg = get_config(name)
    model = get_model(cfg)
    shapes = model.init_shapes()
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    est = model.n_params()
    assert abs(total - est) / est < 0.01, (name, total, est)


PUBLISHED = {  # headline parameter counts from the papers / model cards
    "llama3-8b": 8.0e9,
    "mamba2-1.3b": 1.3e9,
    "olmoe-1b-7b": 6.9e9,
    "deepseek-moe-16b": 16.4e9,
    "gemma3-27b": 27e9,
    "smollm-135m": 135e6,
    "qwen2-0.5b": 0.49e9,
    "recurrentgemma-2b": 2.7e9,
}


@pytest.mark.parametrize("name,published", sorted(PUBLISHED.items()))
def test_param_count_matches_published(name, published):
    cfg = get_config(name)
    model = get_model(cfg)
    got = model.n_params()
    assert abs(got - published) / published < 0.18, (name, got, published)


def test_mamba2_conv_uses_paper_kernel():
    """Variant equivalence inside mamba2: xla vs Pallas row conv."""
    from repro.configs.mamba2_1_3b import SMOKE, SMOKE_PALLAS

    model_x = get_model(SMOKE)
    model_p = get_model(SMOKE_PALLAS)
    params = model_x.init(jax.random.PRNGKey(0))
    batch = make_demo_batch(SMOKE, 2, 16)
    lx = model_x.loss(params, batch)
    lp = model_p.loss(params, batch)
    np.testing.assert_allclose(float(lx), float(lp), rtol=1e-4)


def test_ssm_train_decode_consistency():
    """Chunked SSD (train path) must match the recurrent decode path."""
    from repro.configs.mamba2_1_3b import SMOKE
    from repro.models import ssm

    cfg = SMOKE
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    hidden = ssm.forward(params, cfg, toks)
    from repro.models import layers as L

    logits_train = L.unembed(hidden, params["embed"])
    cache = model.init_cache(2, 8)
    outs = []
    for t in range(8):
        logits, cache = model.decode_step(params, cache, {"tokens": toks[:, t : t + 1]})
        outs.append(logits[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32), np.asarray(logits_train, np.float32),
        atol=2e-2, rtol=1e-2,
    )


def test_gemma3_windowed_cache():
    """The 5:1 local:global serving path (1024-slot ring caches on local
    layers) must match the full forward bit-for-bit across ring wraps."""
    import dataclasses

    from repro.configs.gemma3_27b import SMOKE
    from repro.models import transformer as T

    cfg = dataclasses.replace(SMOKE, attn_chunk_threshold=10**9)
    model = get_model(cfg)
    p = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 30), 0, cfg.vocab)
    ref = T.logits_fn(p, cfg, T.forward(p, cfg, toks))
    lg_p, cache = T.prefill(p, cfg, toks[:, :16])
    np.testing.assert_allclose(np.asarray(lg_p[:, 0]), np.asarray(ref[:, 15]),
                               atol=2e-3, rtol=1e-3)
    # grow the global cache for decoding, keep ring caches as-is
    big = model.init_cache(2, 32)
    for key in ("global_k", "global_v"):
        big[key] = big[key].at[:, :, :16].set(cache[key])
    for key in ("local_k", "local_v"):
        big[key] = cache[key]
    big["pos"] = cache["pos"]
    cache = big
    errs = []
    for t in range(16, 30):  # crosses the W=8 ring boundary repeatedly
        lg, cache = T.decode_step(p, cfg, cache, toks[:, t : t + 1])
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - ref[:, t]))))
    assert max(errs) < 2e-3, errs


def test_hybrid_train_decode_consistency():
    from repro.configs.recurrentgemma_2b import SMOKE
    from repro.models import hybrid, layers as L

    cfg = SMOKE
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    hidden = hybrid.forward(params, cfg, toks)
    logits_train = L.unembed(hidden, params["embed"])
    cache = model.init_cache(2, 8)
    outs = []
    for t in range(8):
        logits, cache = model.decode_step(params, cache, {"tokens": toks[:, t : t + 1]})
        outs.append(logits[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32), np.asarray(logits_train, np.float32),
        atol=2e-2, rtol=1e-2,
    )
