"""Kernel-variant registry — the paper's controlled study axis.

Exactly one thing varies across a study run: which kernel implementation
executes each of the three execution paths (FWD / BWD_in / BWD_k).  A
``VariantSpec`` names the implementation for each path; the registry maps the
paper's four CUDA variants (plus the XLA reference) to their TPU analogues.

``bwd`` selects the backward-pass *structure*: ``"split"`` runs BWD_in and
BWD_k as two independent ops (the paper's controlled per-path study);
``"fused"`` computes both gradients in one staged pass
(``kernels/dwconv_bwd_fused.py``), reusing the forward's padded residual;
``"auto"`` lets the tuning cache decide per shape.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    name: str
    fwd: str        # one of ops.FWD_VARIANTS
    bwd_in: str     # same kernel family as fwd (flipped filter)
    bwd_k: str      # one of ops.BWDK_VARIANTS
    description: str = ""
    bwd: str = "split"        # backward structure: "split" | "fused" | "auto"
    bwd_fused: str = "fused"  # kernel when bwd == "fused" (ops.BWD_FUSED_VARIANTS)


REGISTRY: Dict[str, VariantSpec] = {
    s.name: s
    for s in [
        VariantSpec(
            "naive", "naive", "naive", "naive",
            "per-tap unaligned DMAs, no on-chip reuse (CUDA naive baseline)",
        ),
        VariantSpec(
            "lane", "lane", "lane", "naive",
            "per-tap 128-lane-aligned DMAs (global-memory-coalescing analogue); "
            "BWD_k keeps the naive reduction, as in the paper's GMC stage the "
            "reduction is restructured separately",
        ),
        VariantSpec(
            "block", "block", "block", "twostage",
            "BlockSpec halo-tile VMEM staging + two-stage HBM-partials "
            "reduction (shared-memory cache-blocking analogue)",
        ),
        VariantSpec(
            "row", "row", "row", "accum",
            "full-row VMEM staging + sequential-grid accumulation "
            "(warp-tiled analogue)",
        ),
        VariantSpec(
            "fused", "row", "row", "accum",
            "full-row forward + single-pass fused backward: x_pad and dy "
            "are staged in VMEM once per (h-block x batch-chunk) cell and "
            "both dx and dk are computed from the shared slab, with the "
            "forward's padded x reused as the VJP residual (bwd_in/bwd_k "
            "here are the bwd='split' escape hatch configuration)",
            bwd="fused", bwd_fused="fused",
        ),
        VariantSpec(
            "xla", "xla", "xla", "xla",
            "pure-jnp reference lowered by XLA (the PyTorch-reference role: "
            "numerical oracle + SPMD-friendly production path)",
        ),
        VariantSpec(
            "auto", "auto", "auto", "auto",
            "per-shape dispatch through the persistent tuning cache "
            "(repro.tuning): each execution path runs the counter-free "
            "autotuner's winner for the current (B, H, L, K, dtype, "
            "backend), falling back to the 'row'/'accum' defaults when the "
            "shape has not been tuned; the backward structure (fused vs "
            "split) is likewise resolved through the 'bwd_fused' path",
            bwd="auto",
        ),
    ]
}


def get_variant(name: str) -> VariantSpec:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown variant {name!r}; known: {sorted(REGISTRY)}") from None
