"""Pallas TPU kernels — the reduction-dominated *weight-gradient* path.

dk[h, j] = sum_{b, t} dy[b, h, t] * x_pad[b, h, t + j]          (paper eq. 10)

This is the path the paper identifies as the persistent bottleneck: the
reduction runs over the full (B x L) domain per coefficient.  TPU grids are
*sequential* on a core, so the CUDA two-stage shuffle reduction maps to two
idiomatic structures:

  naive    : per (h-block) cell, every tap re-DMAs the full (Bc, Hb, L) slab
             from HBM — K x redundant traffic, zero on-chip reuse across
             taps (the one-thread-per-(h,j) CUDA baseline).
  twostage : stage the slab in VMEM once per batch-chunk, compute *all* K
             tap partials from it, write per-chunk partials to HBM, then a
             second jnp reduction combines chunks — the paper's explicit
             partial-sum + second-stage design (atomic-free).
  accum    : same staging, but chunks accumulate in-place into a revisited
             output block across the sequential grid — the TPU-native fusion
             of both stages (no partials round-trip through HBM).

Inputs arrive pre-padded from ops.py: xp (B, H, Wpad), dy (B, H, L).
Output: (H, Kp) with Kp = round_up(K, LANE); ops.py slices to (H, K).
Accumulation is f32.

``dwconv_bwd_fused.py`` extends the ``accum``/``twostage`` staging into a
*fused* backward that also emits dx from the same slab (one HBM pass over
each operand for the whole backward); this module remains the split-path
weight-gradient study the paper's per-path tables are built from.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import LANE, cdiv


def _taps_from_slabs(x32: jnp.ndarray, dy32: jnp.ndarray, K: int, Kp: int) -> jnp.ndarray:
    """(Bc, Hb, Wpad) x (Bc, Hb, L) -> per-tap partials (Hb, Kp), f32."""
    L = dy32.shape[-1]
    taps = [jnp.sum(dy32 * x32[:, :, j : j + L], axis=(0, 2)) for j in range(K)]
    part = jnp.stack(taps, axis=-1)  # (Hb, K)
    if Kp > K:
        part = jnp.pad(part, ((0, 0), (0, Kp - K)))
    return part


# ---------------------------------------------------------------------------
# accum variant: sequential-grid in-place accumulation (TPU-native two-stage)
# ---------------------------------------------------------------------------


def _accum_kernel(x_ref, dy_ref, dk_ref, *, K: int, Kp: int):
    c = pl.program_id(1)  # batch-chunk index — innermost, sequential

    @pl.when(c == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)

    x32 = x_ref[...].astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    dk_ref[...] += _taps_from_slabs(x32, dy32, K, Kp).astype(dk_ref.dtype)


def dwconv_bwdk_accum(
    xp: jnp.ndarray,
    dy: jnp.ndarray,
    *,
    K: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Wpad = xp.shape
    L = dy.shape[-1]
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    assert B % Bc == 0 and H % Hb == 0, (B, Bc, H, Hb)
    Kp = cdiv(K, LANE) * LANE
    grid = (H // Hb, B // Bc)
    out = pl.pallas_call(
        functools.partial(_accum_kernel, K=K, Kp=Kp),
        out_shape=jax.ShapeDtypeStruct((H, Kp), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bc, Hb, Wpad), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Bc, Hb, L), lambda h, c: (c, h, 0)),
        ],
        out_specs=pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        interpret=interpret,
    )(xp, dy)
    return out[:, :K]


# ---------------------------------------------------------------------------
# twostage variant: explicit HBM partials + second reduction stage
# ---------------------------------------------------------------------------


def _partials_kernel(x_ref, dy_ref, part_ref, *, K: int, Kp: int):
    x32 = x_ref[...].astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    part_ref[0] = _taps_from_slabs(x32, dy32, K, Kp)


def dwconv_bwdk_twostage(
    xp: jnp.ndarray,
    dy: jnp.ndarray,
    *,
    K: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Wpad = xp.shape
    L = dy.shape[-1]
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    assert B % Bc == 0 and H % Hb == 0, (B, Bc, H, Hb)
    Kp = cdiv(K, LANE) * LANE
    nC = B // Bc
    grid = (H // Hb, nC)
    partials = pl.pallas_call(
        functools.partial(_partials_kernel, K=K, Kp=Kp),
        out_shape=jax.ShapeDtypeStruct((nC, H, Kp), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bc, Hb, Wpad), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Bc, Hb, L), lambda h, c: (c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hb, Kp), lambda h, c: (c, h, 0)),
        interpret=interpret,
    )(xp, dy)
    return jnp.sum(partials, axis=0)[:, :K]  # second reduction stage


# ---------------------------------------------------------------------------
# naive variant: per-tap full re-read (no staging reuse across taps)
# ---------------------------------------------------------------------------


def _naive_bwdk_kernel(
    x_hbm, dy_hbm, dk_ref, xs, dys, sem_x, sem_y, *, K: int, Kp: int, Hb: int, Bc: int
):
    h = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)

    L = dys.shape[-1]
    acc = jnp.zeros((Hb, Kp), jnp.float32)
    for j in range(K):
        # The naive structure: *both* operands re-DMA'd per tap.
        cx = pltpu.make_async_copy(
            x_hbm.at[pl.ds(c * Bc, Bc), pl.ds(h * Hb, Hb), pl.ds(j, L)], xs, sem_x
        )
        cy = pltpu.make_async_copy(
            dy_hbm.at[pl.ds(c * Bc, Bc), pl.ds(h * Hb, Hb), :], dys, sem_y
        )
        cx.start()
        cy.start()
        cx.wait()
        cy.wait()
        tap = jnp.sum(xs[...].astype(jnp.float32) * dys[...].astype(jnp.float32), axis=(0, 2))
        acc = acc.at[:, j].set(tap)
    dk_ref[...] += acc.astype(dk_ref.dtype)


def dwconv_bwdk_naive(
    xp: jnp.ndarray,
    dy: jnp.ndarray,
    *,
    K: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Wpad = xp.shape
    L = dy.shape[-1]
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    assert B % Bc == 0 and H % Hb == 0, (B, Bc, H, Hb)
    Kp = cdiv(K, LANE) * LANE
    grid = (H // Hb, B // Bc)
    out = pl.pallas_call(
        functools.partial(_naive_bwdk_kernel, K=K, Kp=Kp, Hb=Hb, Bc=Bc),
        out_shape=jax.ShapeDtypeStruct((H, Kp), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        scratch_shapes=[
            pltpu.VMEM((Bc, Hb, L), xp.dtype),
            pltpu.VMEM((Bc, Hb, L), dy.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(xp, dy)
    return out[:, :K]
