"""Chaos tests: seeded fault plans must produce *graceful* outcomes.

Every test injects a deterministic fault (``repro.resilience.faults``) into a
production path and asserts the documented degradation — never a crash:

  * kernel lowering failures fall down the dispatch chain to the
    conservative default and then the XLA reference, bit-for-bit matching a
    clean run of the surviving variant, with the failure memoized and the
    tuning-cache decision quarantined;
  * cache corruption (torn writes, unreadable files, broken entries) is
    preserved aside and salvaged per-entry, never silently destroyed;
  * checkpoint write failures retry once; a corrupt latest checkpoint falls
    back to the previous step on restore;
  * the supervisor ignores heartbeats older than the child it is watching
    (the stale-beat kill-loop regression) and still catches a stalled beat;
  * nonfinite train steps are skipped, and persistent nonfiniteness aborts
    with the documented exit code and no traceback.

Everything runs in interpret mode on CPU and is deterministic.
"""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.kernels import ops, ref
from repro.kernels.common import pad_widths
from repro.launch.supervisor import Heartbeat, Supervisor, SupervisorConfig
from repro.resilience import (
    CheckpointIOError,
    FaultPlan,
    FaultRule,
    NonFiniteOutputError,
    NumericsGuard,
    SITES,
    faults,
    guard,
)
from repro.resilience.report import build_report
from repro.tuning import cache as tcache
from repro.tuning import tuner
from repro.kernels.common import DWConvDims

REPO = Path(__file__).resolve().parent.parent

B, H, L, K = 2, 8, 200, 4
X = jnp.asarray(np.random.default_rng(0).normal(size=(B, H, L)), jnp.float32)
KW = jnp.asarray(np.random.default_rng(1).normal(size=(H, K)), jnp.float32)
DY = jnp.asarray(np.random.default_rng(2).normal(size=(B, H, L)), jnp.float32)


@pytest.fixture(autouse=True)
def _clean_resilience_state(tmp_path, monkeypatch):
    """Every test starts with no fault plan, no memoized failures, and a
    private tuning-cache file."""
    monkeypatch.delenv(faults.FAULTS_ENV_VAR, raising=False)
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, str(tmp_path / "cache.json"))
    faults.reset()
    guard.clear()
    tcache.reset_default_cache()
    yield
    faults.reset()
    guard.clear()
    tcache.reset_default_cache()


def _fwd_key(**over):
    kw = dict(path="fwd", B=B, H=H, L=L, K=K, dtype="float32",
              backend=jax.default_backend(), padding="same", epilogue="none")
    kw.update(over)
    return tcache.ShapeKey(**kw)


# ---------------------------------------------------------------------------
# fault plan harness
# ---------------------------------------------------------------------------


def test_fault_plan_grammar_roundtrip():
    plan = FaultPlan.parse("kernel/lower*2,cache/read@skip=1,ckpt/write")
    assert plan.rules["kernel/lower"].count == 2
    assert plan.rules["cache/read"].skip == 1
    assert plan.rules["ckpt/write"].count == 1
    # unlimited and probabilistic forms
    plan2 = FaultPlan.parse("kernel/nan*,heartbeat/stall@p=0.5@seed=7")
    assert plan2.rules["kernel/nan"].count == -1
    assert plan2.rules["heartbeat/stall"].p == 0.5
    # spec() round-trips through parse()
    for pl in (plan, plan2):
        assert FaultPlan.parse(pl.spec()).spec() == pl.spec()


def test_fault_plan_rejects_typos():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan.parse("kernel/lwoer")
    with pytest.raises(ValueError, match="bad fault modifier"):
        FaultPlan.parse("kernel/lower@when=later")
    with pytest.raises(ValueError, match="duplicate"):
        FaultPlan([FaultRule("ckpt/write"), FaultRule("ckpt/write")])


def test_fault_plan_counting_and_determinism():
    plan = FaultPlan.parse("kernel/lower*2@skip=1")
    seq = [plan.should_fire("kernel/lower") for _ in range(5)]
    assert seq == [False, True, True, False, False]  # skip 1, fire 2, done
    assert plan.hits("kernel/lower") == 5 and plan.fired("kernel/lower") == 2
    # seeded probabilistic rules replay identically
    a = FaultPlan.parse("kernel/nan*@p=0.4@seed=9")
    b = FaultPlan.parse("kernel/nan*@p=0.4@seed=9")
    assert ([a.should_fire("kernel/nan") for _ in range(32)]
            == [b.should_fire("kernel/nan") for _ in range(32)])


def test_env_plan_and_context_stacking(monkeypatch):
    monkeypatch.setenv(faults.FAULTS_ENV_VAR, "ckpt/write*")
    faults.reset()
    assert faults.should_fire("ckpt/write")
    with FaultPlan.parse("cache/read"):  # explicit plan shadows the env plan
        assert not faults.should_fire("ckpt/write")
        assert faults.should_fire("cache/read")
    assert faults.should_fire("ckpt/write")  # env plan restored on exit


# ---------------------------------------------------------------------------
# guarded kernel dispatch
# ---------------------------------------------------------------------------


def test_lowering_failure_degrades_to_default():
    p_left, _ = pad_widths(K, "same")
    want = ops._fwd_impl(X, KW, p_left, "row", ops.DEFAULT_OPTS)
    with FaultPlan.parse("kernel/lower"):
        got = ops.dwconv_fwd_op(X, KW, "same", "block")
    # one fault: the requested 'block' fails, the conservative 'row'
    # default runs — bit-identical to calling it directly
    assert np.array_equal(np.asarray(got), np.asarray(want))
    (ev,) = [e for e in guard.degradation_events()
             if e["site"] == "kernel/dispatch"]
    assert ev["from_variant"] == "block" and ev["to_variant"] == "row"


def test_chain_exhaustion_reaches_xla_reference():
    with FaultPlan.parse("kernel/lower*2"):
        got = ops.dwconv_fwd_op(X, KW, "same", "block")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.dwconv_fwd_ref(X, KW, "same")),
        rtol=1e-4, atol=1e-5)
    chain = [(e["from_variant"], e["to_variant"])
             for e in guard.degradation_events()
             if e["site"] == "kernel/dispatch"]
    assert chain == [("block", "row"), ("row", "xla")]


def test_failure_memoized_across_calls():
    with FaultPlan.parse("kernel/lower"):
        ops.dwconv_fwd_op(X, KW, "same", "block")
    assert guard.failed_configs()
    n_events = len(guard.degradation_events())
    # no fault now, but 'block' at this config is memoized broken: the
    # default runs without re-attempting (and without a new degradation)
    got = ops.dwconv_fwd_op(X, KW, "same", "block")
    p_left, _ = pad_widths(K, "same")
    want = ops._fwd_impl(X, KW, p_left, "row", ops.DEFAULT_OPTS)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert len(guard.degradation_events()) == n_events


def test_backward_paths_degrade_gracefully():
    want_dx = ops.dwconv_bwd_input_op(DY, KW, "same", "row")
    want_dk = ops.dwconv_bwd_kernel_op(X, DY, K, "same", "accum")
    guard.clear()
    with FaultPlan.parse("kernel/lower*2"):  # fused bwd fails -> split runs
        dx, dk = ops.dwconv_bwd_fused_op(X, DY, KW, "same", "fused")
    np.testing.assert_allclose(np.asarray(dx), np.asarray(want_dx),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(want_dk),
                               rtol=1e-4, atol=1e-4)
    sites = [(e.get("path"), e["to_variant"]) for e in
             guard.degradation_events() if e["site"] == "kernel/dispatch"]
    assert ("bwd_fused", "split") in sites


def test_split_fallback_reconstructs_x_from_residual():
    """Mid-VJP degradation: only the padded residual xp exists, and the
    split path must slice the raw input back out of it."""
    p_left, _ = pad_widths(K, "same")
    _, xp = ops.dwconv_fwd_op_res(X, KW, "same", "row")
    assert xp is not None and xp.shape != X.shape
    xs = ops._residual_input(None, xp, B, H, L, K, "same")
    assert np.array_equal(np.asarray(xs), np.asarray(X))
    with FaultPlan.parse("kernel/lower*2"):
        dx, dk = ops.dwconv_bwd_fused_op(None, DY, KW, "same", "fused", xp=xp)
    np.testing.assert_allclose(
        np.asarray(dk), np.asarray(ops.dwconv_bwd_kernel_op(X, DY, K, "same",
                                                            "accum")),
        rtol=1e-4, atol=1e-4)


def test_grad_through_guarded_vjp_matches_clean_run():
    from repro.core.dwconv import dwconv

    def loss_op(x, k):
        return jnp.sum(dwconv(x, k, variant="fused") ** 2)

    g_clean = jax.grad(loss_op, argnums=(0, 1))(X, KW)
    guard.clear()
    with FaultPlan.parse("kernel/lower@skip=1"):  # fwd survives, bwd degrades
        g_chaos = jax.grad(loss_op, argnums=(0, 1))(X, KW)
    for a, b in zip(g_clean, g_chaos):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    assert any(e["site"] == "kernel/dispatch"
               for e in guard.degradation_events())


def test_degradation_emitted_through_tracer(tmp_path):
    from repro.obs import trace as obs_trace

    tp = tmp_path / "trace.jsonl"
    obs_trace.configure(str(tp), meta={"test": "resilience"})
    try:
        with FaultPlan.parse("kernel/lower"):
            ops.dwconv_fwd_op(X, KW, "same", "block")
        obs_trace.get_tracer().close()
        recs = [json.loads(line) for line in tp.read_text().splitlines()]
        degr = [r for r in recs if r.get("kind") == "degradation"]
        assert degr and degr[0]["site"] == "kernel/dispatch"
        assert degr[0]["from_variant"] == "block"
    finally:
        obs_trace.configure(None)


# ---------------------------------------------------------------------------
# quarantine: broken cached decisions are skipped and re-tuned
# ---------------------------------------------------------------------------


def test_poisoned_auto_entry_is_quarantined_on_disk():
    key = _fwd_key()
    tcache.default_cache().put(key, tcache.TuneEntry(
        variant="no-such-kernel", block_h=8, block_t=512, batch_chunk=128))
    # auto dispatch runs the poisoned decision, which cannot execute;
    # the guard absorbs it and quarantines the entry
    got = ops.dwconv_fwd_op(X, KW, "same", "auto")
    p_left, _ = pad_widths(K, "same")
    want = ops._fwd_impl(X, KW, p_left, "row", ops.DEFAULT_OPTS)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    tcache.reset_default_cache()  # force a fresh read of the file
    e = tcache.default_cache().get(key)
    assert e is not None and e.quarantined and e.quarantine_reason
    # lookup() (the dispatch entry point) now skips it ...
    assert tcache.lookup(path="fwd", B=B, H=H, L=L, K=K, dtype="float32",
                         backend=jax.default_backend()) is None
    # ... so auto dispatch resolves to the fallback, not the broken entry
    v, _ = ops.resolve_variant("fwd", "auto", None, B=B, H=H, L=L, K=K,
                               dtype=jnp.float32, padding="same")
    assert v == ops.AUTO_FALLBACK["fwd"]
    assert any(e2["site"] == "cache/quarantine"
               for e2 in guard.degradation_events())


def test_quarantine_requires_matching_variant():
    key = _fwd_key()
    c = tcache.default_cache()
    c.put(key, tcache.TuneEntry(variant="lane", block_h=8, block_t=512,
                                batch_chunk=128))
    assert not c.quarantine(key, variant="row", reason="stale report")
    assert not c.get(key).quarantined
    assert c.quarantine(key, variant="lane", reason="real failure")
    assert c.get(key).quarantined
    assert not c.quarantine(key, variant="lane", reason="again")  # idempotent


def test_v5_migration_and_quarantine_roundtrip(tmp_path, monkeypatch):
    p = tmp_path / "v5.json"
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, str(p))
    tcache.reset_default_cache()
    key = _fwd_key()
    p.write_text(json.dumps({"version": 5, "entries": {key.encode(): {
        "variant": "lane", "block_h": 4, "block_t": 256, "batch_chunk": 64,
        "time_us": 10.0, "analytical_time_us": 9.0, "source": "measured"}}}))
    e = tcache.default_cache().get(key)
    assert e is not None and e.variant == "lane" and not e.quarantined
    assert tcache.default_cache().quarantine(key, reason="chaos")
    saved = json.loads(p.read_text())
    assert saved["version"] == tcache.CACHE_VERSION
    assert saved["entries"][key.encode()]["quarantined"] is True
    tcache.reset_default_cache()
    assert tcache.default_cache().get(key).quarantined


def test_retune_clears_quarantine_and_bans_broken_config(tmp_path):
    d = DWConvDims(B=2, H=4, L=48, K=5)
    key = tcache.ShapeKey(path="fwd", B=2, H=4, L=48, K=5, dtype="float32",
                          backend=jax.default_backend(), padding="same")
    c = tcache.default_cache()
    c.put(key, tcache.TuneEntry(variant="lane", block_h=4, block_t=128,
                                batch_chunk=2))
    assert c.quarantine(key, reason="failed to execute")

    metered = []

    def stub_measure(cand, dd):
        metered.append(cand)
        return 1e-6 * (1 + cand.block_h)

    res = tuner.tune_path(d, "fwd", budget=6, measure_fn=stub_measure, cache=c)
    fresh = c.get(key)
    assert fresh is not None and not fresh.quarantined  # re-tune overwrote it
    # the quarantined configuration was never even metered
    from repro.tuning import space as tspace

    banned = tspace.normalize(tspace.Candidate(
        path="fwd", variant="lane", block_h=4, block_t=128, batch_chunk=2), d)
    assert banned not in metered
    assert res.best.variant in ops.FWD_VARIANTS


# ---------------------------------------------------------------------------
# tuning-cache file corruption
# ---------------------------------------------------------------------------


def test_corrupt_cache_file_preserved_not_overwritten(tmp_path, monkeypatch):
    p = tmp_path / "c.json"
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, str(p))
    tcache.reset_default_cache()
    p.write_text('{"version": 6, "entries": {"truncated')
    c = tcache.default_cache()
    assert len(c) == 0  # unreadable -> treated as empty, with a warning
    c.put(_fwd_key(), tcache.TuneEntry(variant="row", block_h=8, block_t=512,
                                       batch_chunk=128))
    side = list(tmp_path.glob("c.json.corrupt-*"))
    assert len(side) == 1, "corrupt bytes were not preserved aside"
    assert side[0].read_text().startswith('{"version": 6')
    assert json.loads(p.read_text())["version"] == tcache.CACHE_VERSION


def test_broken_entries_salvaged_individually(tmp_path, monkeypatch, capsys):
    p = tmp_path / "c.json"
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, str(p))
    tcache.reset_default_cache()
    good = _fwd_key()
    p.write_text(json.dumps({"version": tcache.CACHE_VERSION, "entries": {
        good.encode(): {"variant": "row", "block_h": 8, "block_t": 512,
                        "batch_chunk": 128},
        "fwd/B1-H1-L1-K1/same/float32/cpu/none": {"nonsense": True},
    }}))
    c = tcache.default_cache()
    assert c.get(good) is not None  # the parseable entry survived
    assert len(c) == 1
    assert "salvaged" in capsys.readouterr().err


def test_torn_write_survives_next_load(tmp_path, monkeypatch):
    p = tmp_path / "c.json"
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, str(p))
    tcache.reset_default_cache()
    key = _fwd_key()
    with FaultPlan.parse("cache/torn-write"):
        tcache.default_cache().put(key, tcache.TuneEntry(
            variant="row", block_h=8, block_t=512, batch_chunk=128))
    with pytest.raises(json.JSONDecodeError):
        json.loads(p.read_text())  # the file really is torn
    tcache.reset_default_cache()  # new process arrives at the torn file
    c = tcache.default_cache()
    assert c.get(key) is None  # torn DB reads as empty, never crashes
    c.put(key, tcache.TuneEntry(variant="lane", block_h=8, block_t=512,
                                batch_chunk=128))
    assert list(tmp_path.glob("c.json.corrupt-*"))  # torn bytes preserved
    tcache.reset_default_cache()
    assert tcache.default_cache().get(key).variant == "lane"  # DB healthy


def test_cache_read_fault_degrades_to_empty_without_data_loss():
    key = _fwd_key()
    tcache.default_cache().put(key, tcache.TuneEntry(
        variant="row", block_h=8, block_t=512, batch_chunk=128))
    tcache.reset_default_cache()
    with FaultPlan.parse("cache/read"):
        # injected I/O failure: the DB reads as empty (dispatch falls back
        # to defaults) instead of crashing the process
        assert tcache.default_cache().get(key) is None
    tcache.reset_default_cache()
    assert tcache.default_cache().get(key).variant == "row"  # data intact


# ---------------------------------------------------------------------------
# tuner under chaos
# ---------------------------------------------------------------------------


def test_tuner_survives_measure_failures():
    d = DWConvDims(B=2, H=4, L=48, K=5)

    def flaky_measure(cand, dd):
        if cand.variant == "lane":
            raise faults.KernelLoweringError("lane always explodes today")
        return 1e-6 * cand.block_h

    res = tuner.tune_path(d, "fwd", budget=8, measure_fn=flaky_measure,
                          persist=False)
    assert res.best.variant != "lane"
    assert np.isfinite(res.best.time_us)
    assert any(e["site"] == "tuner/measure-failed"
               for e in guard.degradation_events())


def test_tuner_slow_candidate_fault_changes_loser():
    d = DWConvDims(B=2, H=4, L=48, K=5)

    def stub(cand, dd):
        return 1e-6

    with FaultPlan.parse("tuner/slow-candidate"):
        res = tuner.tune_path(d, "fwd", budget=4, measure_fn=stub,
                              persist=False)
    # the first metered candidate (the fallback baseline) was inflated
    # 1000x, so the winner is one of the others at the uninflated time
    assert res.best.time_us == pytest.approx(1.0)
    times = sorted(t for _, _, t in res.history)
    assert times[-1] == pytest.approx(1e-3)  # the straggler is in history


# ---------------------------------------------------------------------------
# numerics guard
# ---------------------------------------------------------------------------


def test_numerics_guard_skip_recover_abort():
    g = NumericsGuard(max_consecutive=3)
    assert g.check(0, loss=1.0, grad_norm=2.0)
    assert not g.check(1, loss=float("nan"), grad_norm=1.0)
    assert not g.check(2, loss=float("inf"), grad_norm=1.0)
    assert g.check(3, loss=0.9, grad_norm=1.0)  # recovery resets the streak
    assert g.consecutive == 0 and g.total_skipped == 2
    assert not g.check(4, loss=float("nan"), grad_norm=1.0)
    assert not g.check(5, loss=float("nan"), grad_norm=1.0)
    with pytest.raises(NonFiniteOutputError):
        g.check(6, loss=float("nan"), grad_norm=1.0)
    assert sum(1 for e in guard.degradation_events()
               if e["site"] == "train/nonfinite") == 5
    with pytest.raises(ValueError):
        NumericsGuard(max_consecutive=0)


# ---------------------------------------------------------------------------
# checkpoint chaos
# ---------------------------------------------------------------------------


def _params():
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}


def test_checkpoint_write_fault_retries_once(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    with FaultPlan.parse("ckpt/write"):
        m.save(1, params=_params())
    assert m.all_steps() == [1]
    assert any(e["site"] == "ckpt/write" and e["action"] == "retry once"
               for e in guard.degradation_events())
    with FaultPlan.parse("ckpt/write*2"):  # both attempts fail -> surfaces
        with pytest.raises(CheckpointIOError):
            m.save(2, params=_params())


def test_checkpoint_restore_falls_back_past_corruption(tmp_path):
    m = CheckpointManager(tmp_path, keep=5)
    m.save(1, params=_params())
    m.save(2, params={"w": _params()["w"] * 2})
    npz = Path(tmp_path) / "step_0000000002" / "params.npz"
    npz.write_bytes(npz.read_bytes()[:16])  # torn payload
    step, params, _, _ = m.restore(params_template=_params())
    assert step == 1
    np.testing.assert_array_equal(params["w"], _params()["w"])
    assert any(e["site"] == "ckpt/restore" for e in guard.degradation_events())
    with pytest.raises(CheckpointIOError):  # explicit intent still raises
        m.restore(2, params_template=_params())


def test_checkpoint_restore_missing_payload_detected(tmp_path):
    m = CheckpointManager(tmp_path, keep=5)
    m.save(1, params=_params())
    m.save(2, params=_params())
    (Path(tmp_path) / "step_0000000002" / "params.npz").unlink()
    step, _, _, _ = m.restore(params_template=_params())
    assert step == 1


# ---------------------------------------------------------------------------
# supervisor chaos
# ---------------------------------------------------------------------------


def test_stale_heartbeat_does_not_kill_fresh_child(tmp_path):
    """Regression: a hung child's final heartbeat used to out-live it and
    SIGKILL every restarted child before its first beat."""
    hb_path = tmp_path / "hb.json"
    hb_path.write_text(json.dumps(
        {"step": 7, "t": time.time() - 1000, "step_time_s": 1.0}))
    cfg = SupervisorConfig(
        cmd=[sys.executable, "-c", "import time; time.sleep(6)"],
        heartbeat_path=str(hb_path), max_restarts=0,
        heartbeat_timeout_s=30.0)
    sup = Supervisor(cfg)
    assert sup.run() == 0, "fresh child was killed off a stale heartbeat"


def test_silent_child_killed_from_launch_clock(tmp_path):
    """A child that never beats is judged from its *launch* time — the
    heartbeat/stall fault makes beats silently vanish."""
    hb_path = tmp_path / "hb.json"
    child = ("import time\n"
             "from repro.launch.supervisor import Heartbeat\n"
             f"hb = Heartbeat({str(hb_path)!r})\n"
             "for i in range(600):\n"
             "    hb.beat(i)\n"
             "    time.sleep(0.1)\n")
    cfg = SupervisorConfig(
        cmd=[sys.executable, "-c", child], heartbeat_path=str(hb_path),
        max_restarts=0, heartbeat_timeout_s=1.0)
    sup = Supervisor(cfg)
    rc = sup.run(extra_env={"PYTHONPATH": str(REPO / "src"),
                            "REPRO_FAULTS": "heartbeat/stall*"})
    assert rc != 0
    assert not hb_path.exists(), "stalled beat still reached the disk"
    assert any("stale" in e for e in sup.events)


def test_heartbeat_stall_fault_unit(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"))
    with FaultPlan.parse("heartbeat/stall"):
        hb.beat(0)
    assert not (tmp_path / "hb.json").exists()
    hb.beat(1)  # fault exhausted: the next beat lands
    assert Heartbeat.read(str(tmp_path / "hb.json"))["step"] == 1


# ---------------------------------------------------------------------------
# end-to-end: the training launcher under injected faults (subprocess)
# ---------------------------------------------------------------------------


def _run_train(tmp_path, fault_spec, *extra):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               REPRO_FAULTS=fault_spec,
               REPRO_TUNE_CACHE=str(tmp_path / "cache.json"))
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "mamba2-1.3b",
           "--smoke", "--steps", "3", "--batch", "2", "--seq", "32",
           "--log-every", "1", "--guard", "--conv-variant", "row", *extra]
    return subprocess.run(cmd, env=env, cwd=tmp_path, capture_output=True,
                          text=True, timeout=600)


def test_train_survives_lowering_faults(tmp_path):
    r = _run_train(tmp_path, "kernel/lower", "--trace",
                   str(tmp_path / "t.jsonl"))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "Traceback" not in r.stderr
    recs = [json.loads(line)
            for line in (tmp_path / "t.jsonl").read_text().splitlines()]
    assert any(rec.get("kind") == "degradation" and
               rec.get("site") == "kernel/dispatch" for rec in recs), \
        "degradation not recorded in the trace"
    rep = build_report([str(tmp_path / "t.jsonl")], None)
    assert rep["degradations_by_site"].get("kernel/dispatch", 0) >= 1


def test_train_nan_aborts_gracefully(tmp_path):
    from repro.launch.train import GUARD_ABORT_EXIT

    r = _run_train(tmp_path, "kernel/nan*1000")
    assert r.returncode == GUARD_ABORT_EXIT, (r.returncode, r.stderr[-2000:])
    assert "Traceback" not in r.stderr, r.stderr[-2000:]
    assert "numerics guard abort" in r.stdout


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def test_report_collects_traces_and_quarantine(tmp_path):
    tp = tmp_path / "t.jsonl"
    tp.write_text(
        json.dumps({"kind": "degradation", "site": "kernel/dispatch"}) + "\n"
        + json.dumps({"kind": "span", "name": "train/step"}) + "\n"
        + json.dumps({"kind": "degradation", "site": "ckpt/write"}) + "\n")
    c = tcache.default_cache()
    c.put(_fwd_key(), tcache.TuneEntry(variant="lane", block_h=8, block_t=512,
                                       batch_chunk=128))
    c.quarantine(_fwd_key(), reason="chaos")
    rep = build_report([str(tp)], str(c.path))
    assert rep["degradations_by_site"] == {"ckpt/write": 1,
                                           "kernel/dispatch": 1}
    assert len(rep["quarantined"]) == 1
    assert rep["quarantined"][0]["reason"] == "chaos"


def test_all_sites_documented():
    # the README fault-site table and SITES must cover the same names
    readme = (REPO / "README.md").read_text()
    for site in SITES:
        assert f"`{site}`" in readme, f"fault site {site} missing from README"
