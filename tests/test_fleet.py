"""Fleet tuning-cache distribution tests (repro.fleet): signed bundle
export/import round-trip, tamper/wrong-key rejection with byte-identical
local state, quarantine filtering across the fleet boundary (v6 fields
end-to-end through export→import→lookup), fingerprint-gated trust levels
(trusted merge vs advisory hints), measured-runtime-wins merge, schema
migration, ``REPRO_TUNE_BUNDLE`` warm start, and the guarded degradation
path.  No subprocesses here — the replica simulation lives in
``benchmarks/paper_fleet.py`` and the CI fleet job.
"""
import json

import jax
import pytest

from repro.fleet import bundle as fbundle
from repro.fleet import import_ as fimport
from repro.obs import trace as obs_trace
from repro.obs.calibrate import device_fingerprint
from repro.resilience import faults, guard
from repro.resilience.faults import BundleIntegrityError
from repro.tuning import cache as tcache
from repro.tuning import tuner
from repro.tuning.cache import ShapeKey, TuneEntry, TuningCache
from repro.kernels.common import DWConvDims

D = DWConvDims(B=2, H=4, L=48, K=5)
FOREIGN_FP = "tpu:TPU v5e:x8"


def _key(path="fwd", B=2, epilogue="none"):
    return ShapeKey(path=path, B=B, H=4, L=48, K=5, dtype="float32",
                    backend=jax.default_backend(), epilogue=epilogue)


def _entry(variant="row", time_us=10.0, **kw):
    return TuneEntry(variant=variant, block_h=8, block_t=512, batch_chunk=128,
                     time_us=time_us, **kw)


@pytest.fixture(autouse=True)
def fleet_env(tmp_path, monkeypatch):
    """Signing key installed, default cache redirected, all fleet/resilience
    process state reset around every test."""
    monkeypatch.setenv(fbundle.FLEET_KEY_ENV, "test-signing-key")
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, str(tmp_path / "local.json"))
    monkeypatch.delenv(tcache.BUNDLE_ENV_VAR, raising=False)
    tcache.reset_default_cache()
    fimport.clear_advisory()
    guard.clear()
    faults.reset()
    yield tmp_path
    tcache.reset_default_cache()
    fimport.clear_advisory()
    guard.clear()
    faults.reset()
    obs_trace.configure(enabled=False)


def _export(tmp_path, entries, name="a.bundle.json", **kw):
    src = TuningCache(tmp_path / f"src-{name}.json")
    for k, e in entries.items():
        src.put(k, e)
    return fbundle.export_bundle(src, tmp_path / name, **kw)


# ---------------------------------------------------------------------------
# bundle format + signing
# ---------------------------------------------------------------------------


def test_export_import_round_trip_trusted(tmp_path):
    p = _export(tmp_path, {_key(): _entry(time_us=12.5)})
    payload = fbundle.read_bundle(p)
    assert payload["cache_version"] == tcache.CACHE_VERSION
    man = payload["manifest"]
    assert man["fingerprint"] == device_fingerprint()
    assert man["entry_count"] == 1
    assert man["content_id"] == fbundle.content_id(
        payload["cache_version"], payload["entries"])

    res = fimport.import_bundle(p, tcache.default_cache())
    assert res.is_trusted and res.trusted == 1 and res.advisory == 0
    got = tcache.default_cache().get(_key())
    assert got is not None and got.variant == "row"
    assert got.time_us == pytest.approx(12.5)
    assert got.source.startswith("bundle:"), "provenance tag missing"
    # warm lookup serves it directly
    assert tcache.lookup("fwd", 2, 4, 48, 5, "float32",
                         jax.default_backend()) is not None


def test_export_to_directory_is_content_addressed(tmp_path):
    out = tmp_path / "store"
    out.mkdir()
    p = _export(tmp_path, {_key(): _entry()}, name=str(out))
    payload = json.loads(p.read_text())
    cid = payload["manifest"]["content_id"]
    assert p.name == f"{cid[:16]}{fbundle.BUNDLE_SUFFIX}"


def test_missing_or_wrong_key_rejected(tmp_path, monkeypatch):
    p = _export(tmp_path, {_key(): _entry()})
    with pytest.raises(BundleIntegrityError, match="signature mismatch"):
        fbundle.read_bundle(p, key="a-different-key")
    monkeypatch.delenv(fbundle.FLEET_KEY_ENV)
    with pytest.raises(BundleIntegrityError, match="signing key"):
        fbundle.read_bundle(p)
    with pytest.raises(BundleIntegrityError, match="signing key"):
        _export(tmp_path, {_key(): _entry()}, name="b.bundle.json")


def test_tampered_bundle_rejected_cache_untouched(tmp_path):
    """The acceptance property: flipped byte + re-used signature -> rejected
    with BundleIntegrityError, local cache byte-identical, no quarantine
    pollution, and the guarded path degrades instead of crashing."""
    local = tcache.default_cache()
    local.put(_key("bwd_in"), _entry("row", time_us=30.0))
    before = local.path.read_bytes()

    p = _export(tmp_path, {_key(): _entry(time_us=12.5)})
    text = p.read_text()
    bad = tmp_path / "bad.bundle.json"
    bad.write_text(text.replace('"time_us": 12.5', '"time_us": 1.5'))
    assert json.loads(bad.read_text()), "tamper must keep the JSON parseable"

    with pytest.raises(BundleIntegrityError, match="signature mismatch"):
        fbundle.read_bundle(bad)

    tracer = obs_trace.configure(enabled=True)
    assert fimport.import_bundle_guarded(bad, local) is None
    assert local.path.read_bytes() == before, "local cache mutated"
    assert not any(e.quarantined for e in local.items().values())
    events = [e for e in guard.degradation_events()
              if e["site"] == "bundle/import"]
    assert len(events) == 1 and "BundleIntegrityError" in events[0]["error"]
    assert any(r.get("kind") == "degradation" and r.get("site") == "bundle/import"
               for r in tracer.records)


def test_truncated_and_malformed_bundles_rejected(tmp_path):
    p = _export(tmp_path, {_key(): _entry()})
    torn = tmp_path / "torn.bundle.json"
    torn.write_text(p.read_text()[: len(p.read_text()) // 2])
    with pytest.raises(BundleIntegrityError, match="not valid JSON"):
        fbundle.read_bundle(torn)
    notabundle = tmp_path / "other.bundle.json"
    notabundle.write_text(json.dumps({"version": 6, "entries": {}}))
    with pytest.raises(BundleIntegrityError, match="format"):
        fbundle.read_bundle(notabundle)
    with pytest.raises(BundleIntegrityError, match="cannot read"):
        fbundle.read_bundle(tmp_path / "missing.bundle.json")
    # content-id forgery with a correctly re-signed payload still fails
    payload = fbundle.build_payload({_key().encode(): _entry().to_dict()},
                                    key="test-signing-key")
    payload["manifest"]["content_id"] = "0" * 64
    payload["signature"] = fbundle.sign_payload(payload, "test-signing-key")
    forged = tmp_path / "forged.bundle.json"
    fbundle.write_payload(payload, forged)
    with pytest.raises(BundleIntegrityError, match="content_id"):
        fbundle.read_bundle(forged)


# ---------------------------------------------------------------------------
# quarantine never crosses the fleet boundary
# ---------------------------------------------------------------------------


def test_quarantined_entries_dropped_at_export_and_strict_refuses(tmp_path):
    src = TuningCache(tmp_path / "src.json")
    src.put(_key(), _entry(time_us=12.0))
    src.put(_key("bwd_in"), _entry("lane", time_us=20.0))
    assert src.quarantine(_key("bwd_in"), reason="failed to lower")
    with pytest.raises(BundleIntegrityError, match="quarantined"):
        fbundle.export_bundle(src, tmp_path / "s.bundle.json", strict=True)
    p = fbundle.export_bundle(src, tmp_path / "ok.bundle.json")
    payload = fbundle.read_bundle(p)
    assert list(payload["entries"]) == [_key().encode()]


def test_quarantined_entries_filtered_at_import_end_to_end(tmp_path):
    """v6 quarantine fields through a crafted bundle: non-strict import
    drops them (lookup never sees them), strict import rejects the whole
    bundle."""
    qkey = _key("bwd_in")
    payload = fbundle.build_payload(
        {_key().encode(): _entry(time_us=9.0).to_dict(),
         qkey.encode(): _entry("lane", quarantined=True,
                               quarantine_reason="vmem blow-up").to_dict()},
        key="test-signing-key")
    p = fbundle.write_payload(payload, tmp_path / "q.bundle.json")

    with pytest.raises(BundleIntegrityError, match="strict"):
        fimport.import_bundle(p, tcache.default_cache(), strict=True)
    assert len(tcache.default_cache()) == 0, "strict rejection merged entries"

    res = fimport.import_bundle(p, tcache.default_cache())
    assert res.dropped_quarantined == 1 and res.trusted == 1
    assert tcache.default_cache().get(qkey) is None
    assert tcache.lookup("bwd_in", 2, 4, 48, 5, "float32",
                         jax.default_backend()) is None
    assert tcache.lookup("fwd", 2, 4, 48, 5, "float32",
                         jax.default_backend()) is not None


# ---------------------------------------------------------------------------
# fingerprint gate: trusted vs advisory
# ---------------------------------------------------------------------------


def test_foreign_fingerprint_imports_as_advisory_only(tmp_path):
    p = _export(tmp_path, {_key(): _entry("block", time_us=5.0)},
                fingerprint=FOREIGN_FP)
    cache = tcache.default_cache()
    res = fimport.import_bundle(p, cache)
    assert not res.is_trusted and res.advisory == 1 and res.trusted == 0
    assert len(cache) == 0, "advisory entries must never be persisted"
    adv = fimport.advisory_entry(_key().encode())
    assert adv is not None and adv.source == "advisory"
    # dispatch fall-through: local miss -> advisory hint
    hit = tcache.lookup("fwd", 2, 4, 48, 5, "float32", jax.default_backend())
    assert hit is not None and hit.source == "advisory"
    # a local measured decision beats the hint
    cache.put(_key(), _entry("row", time_us=50.0))
    hit = tcache.lookup("fwd", 2, 4, 48, 5, "float32", jax.default_backend())
    assert hit.variant == "row" and hit.source == "measured"


def test_advisory_seeds_tuner_stage2_but_never_bypasses_measurement(tmp_path):
    hint_entry = TuneEntry(variant="block", block_h=4, block_t=512,
                           batch_chunk=128, time_us=1.0)
    p = _export(tmp_path, {_key(B=2): hint_entry}, fingerprint=FOREIGN_FP)
    fimport.import_bundle(p, tcache.default_cache())

    def stub(c, d):  # the hint's config is NOT the stub's winner
        return 50.0 if c.variant == "row" else 80.0 + abs(c.block_h - 4)

    res = tuner.tune_path(D, "fwd", budget=2, measure_fn=stub,
                          backend=jax.default_backend(), persist=False)
    metered = [h[0] for h in res.history]
    assert any(c.variant == "block" and c.block_h == 4 for c in metered), (
        "advisory hint was not seeded into the measured set")
    # measurement won: the locally faster baseline beats the foreign hint
    assert res.best.variant == "row"
    assert res.candidates_measured <= 2


def test_stale_fingerprint_fault_downgrades_to_advisory(tmp_path):
    p = _export(tmp_path, {_key(): _entry()})
    with faults.FaultPlan.parse("bundle/stale-fingerprint"):
        res = fimport.import_bundle(p, tcache.default_cache())
    assert not res.is_trusted and res.advisory == 1
    assert len(tcache.default_cache()) == 0


def test_bundle_tamper_fault_site_is_caught_by_signature(tmp_path):
    p = _export(tmp_path, {_key(): _entry()})
    with faults.FaultPlan.parse("bundle/tamper"):
        with pytest.raises(BundleIntegrityError, match="signature mismatch"):
            fbundle.read_bundle(p)
    fbundle.read_bundle(p)  # plan exited: the same file verifies again


# ---------------------------------------------------------------------------
# three-way merge: measured-runtime-wins
# ---------------------------------------------------------------------------


def test_merge_measured_runtime_wins(tmp_path):
    cache = tcache.default_cache()
    cache.put(_key(B=2), _entry("row", time_us=30.0))    # slower local
    cache.put(_key(B=4), _entry("row", time_us=5.0))     # faster local
    cache.put(_key(B=8), _entry("row", time_us=0.0, source="manual"))
    p = _export(tmp_path, {
        _key(B=2): _entry("block", time_us=10.0),   # faster -> replaces
        _key(B=4): _entry("block", time_us=20.0),   # slower -> kept local
        _key(B=8): _entry("block", time_us=15.0),   # measured beats unmeasured
        _key(B=16): _entry("block", time_us=7.0),   # new -> inserted
    })
    res = fimport.import_bundle(p, cache)
    assert (res.inserted, res.replaced, res.kept_local) == (1, 2, 1)
    assert cache.get(_key(B=2)).variant == "block"
    assert cache.get(_key(B=4)).variant == "row"
    assert cache.get(_key(B=8)).variant == "block"
    assert cache.get(_key(B=16)).variant == "block"


def test_merge_never_launders_a_quarantined_decision(tmp_path):
    """The exact config this replica watched fail must stay quarantined even
    when a bundle re-delivers it; a *different* imported decision replaces
    the quarantined one (it measured elsewhere and will be re-verified by
    guarded dispatch here)."""
    cache = tcache.default_cache()
    cache.put(_key(B=2), _entry("lane"))
    cache.quarantine(_key(B=2), reason="failed here")
    cache.put(_key(B=4), _entry("lane"))
    cache.quarantine(_key(B=4), reason="failed here")
    p = _export(tmp_path, {
        _key(B=2): _entry("lane", time_us=3.0),     # same config re-arrives
        _key(B=4): _entry("block", time_us=3.0),    # different config
    })
    fimport.import_bundle(p, cache)
    still = cache.get(_key(B=2))
    assert still.quarantined and still.quarantine_reason == "failed here"
    swapped = cache.get(_key(B=4))
    assert not swapped.quarantined and swapped.variant == "block"


# ---------------------------------------------------------------------------
# schema migration
# ---------------------------------------------------------------------------


def test_v5_bundle_migrates_and_v1_is_rejected(tmp_path):
    old_key = "fwd/B2-H4-L48-K5/same/float32/cpu"  # pre-v5: no epilogue part
    entry5 = {k: v for k, v in _entry(time_us=8.0).to_dict().items()
              if k not in ("quarantined", "quarantine_reason")}
    payload = fbundle.build_payload({old_key: entry5}, key="test-signing-key",
                                    cache_version=5)
    p = fbundle.write_payload(payload, tmp_path / "v5.bundle.json")
    cache = TuningCache(tmp_path / "dst.json")
    res = fimport.import_bundle(p, cache)
    assert res.trusted == 1 and res.dropped_stale == 0
    normalized = ShapeKey.decode(old_key)
    got = cache.get(normalized)
    assert got is not None and not got.quarantined
    assert normalized.encode().endswith("/none"), "key not normalized to v6"

    p1 = fbundle.write_payload(
        fbundle.build_payload({old_key: entry5}, key="test-signing-key",
                              cache_version=1), tmp_path / "v1.bundle.json")
    with pytest.raises(BundleIntegrityError, match="schema v1"):
        fbundle.read_bundle(p1)


def test_garbage_keys_in_signed_bundle_are_dropped_not_fatal(tmp_path):
    payload = fbundle.build_payload(
        {"not/a/key": _entry().to_dict(),
         _key().encode(): _entry(time_us=4.0).to_dict()},
        key="test-signing-key")
    p = fbundle.write_payload(payload, tmp_path / "g.bundle.json")
    res = fimport.import_bundle(p, tcache.default_cache())
    assert res.trusted == 1 and res.dropped_stale == 1


# ---------------------------------------------------------------------------
# warm start: REPRO_TUNE_BUNDLE auto-import
# ---------------------------------------------------------------------------


def test_env_bundle_auto_imports_on_first_default_cache_touch(
        tmp_path, monkeypatch):
    p = _export(tmp_path, {_key(): _entry(time_us=6.0)})
    monkeypatch.setenv(tcache.BUNDLE_ENV_VAR, str(p))
    tcache.reset_default_cache()
    hit = tcache.lookup("fwd", 2, 4, 48, 5, "float32", jax.default_backend())
    assert hit is not None and hit.source.startswith("bundle:")


def test_env_bundle_corrupt_degrades_without_crashing(tmp_path, monkeypatch):
    bad = tmp_path / "bad.bundle.json"
    bad.write_text("{definitely not a bundle")
    monkeypatch.setenv(tcache.BUNDLE_ENV_VAR, str(bad))
    tcache.reset_default_cache()
    assert tcache.lookup("fwd", 2, 4, 48, 5, "float32",
                         jax.default_backend()) is None
    assert any(e["site"] == "bundle/import"
               for e in guard.degradation_events())


# ---------------------------------------------------------------------------
# sim helpers (no subprocesses)
# ---------------------------------------------------------------------------


def test_sim_tamper_keeps_json_parseable_but_breaks_signature(tmp_path):
    from repro.fleet import sim

    p = _export(tmp_path, {_key(): _entry()})
    bad = tmp_path / "t.bundle.json"
    sim.tamper_bundle(p, bad)
    assert json.loads(bad.read_text())
    with pytest.raises(BundleIntegrityError, match="signature mismatch"):
        fbundle.read_bundle(bad)
