"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--fast`` trims iteration
counts (used by CI); ``--only <prefix>`` filters benchmarks.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import paper_table2, paper_table3, paper_roofline, paper_validation
    from benchmarks import paper_autotune, roofline_table, s4convd_e2e

    modules = [
        ("paper_table2", paper_table2),
        ("paper_table3", paper_table3),
        ("paper_roofline", paper_roofline),
        ("paper_validation", paper_validation),
        ("paper_autotune", paper_autotune),
        ("s4convd_e2e", s4convd_e2e),
        ("roofline_table", roofline_table),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        if args.only and not name.startswith(args.only):
            continue
        try:
            for row in mod.run(fast=args.fast):
                print(f"{row.name},{row.us_per_call:.1f},{row.derived}")
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR", file=sys.stdout)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
