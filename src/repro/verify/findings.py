"""Finding records shared by the schedule verifier and the repo lint.

A finding is one violated invariant, identified by a stable rule code:

  * ``VERxxx`` — schedule↔kernel cross-check findings (see ``schedule_check``)
  * ``REPxxx`` — repo lint findings (see ``lint``)

Both tools emit the same record so the CLI / CI layer can merge, rank and
serialize them uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

SEVERITIES = ("error", "warning", "note")


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str            # "VER103", "REP001", ...
    severity: str        # "error" | "warning" | "note"
    where: str           # "fwd/row epilogue=none shape=..." or "path.py:32"
    message: str         # human sentence naming the violated invariant

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.where}: {self.code} [{self.severity}] {self.message}"


def max_severity(findings: Sequence[Finding]) -> Optional[str]:
    """Most severe level present, or None for an empty list."""
    present = [SEVERITIES.index(f.severity) for f in findings]
    return SEVERITIES[min(present)] if present else None


def should_fail(findings: Sequence[Finding], fail_on: str) -> bool:
    """True when ``findings`` crosses the ``--fail-on`` threshold."""
    if fail_on == "never":
        return False
    if fail_on not in SEVERITIES:
        raise ValueError(f"fail_on must be one of {SEVERITIES + ('never',)}, got {fail_on!r}")
    worst = max_severity(findings)
    return worst is not None and SEVERITIES.index(worst) <= SEVERITIES.index(fail_on)


def findings_payload(findings: Sequence[Finding]) -> List[Dict[str, str]]:
    return [f.to_dict() for f in findings]
