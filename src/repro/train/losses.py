"""Loss functions: RMSLE (paper §III-C) and cross-entropy for the LM pool."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsle(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    """Root-mean-squared log error; both inputs must be non-negative."""
    lp = jnp.log1p(jnp.maximum(pred, 0.0))
    lt = jnp.log1p(jnp.maximum(target, 0.0))
    return jnp.sqrt(jnp.mean((lp - lt) ** 2) + 1e-12)


def msle(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    """Squared-log error (RMSLE^2) — a smoother training objective whose
    gradients match RMSLE direction; benchmarks report true RMSLE."""
    lp = jnp.log1p(jnp.maximum(pred, 0.0))
    lt = jnp.log1p(jnp.maximum(target, 0.0))
    return jnp.mean((lp - lt) ** 2)


def softmax_cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Token-level CE.  logits (..., V) f32/bf16, labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
