"""Analytical memory-traffic models for the depthwise-conv kernel variants.

This is the paper's §III-G / §V-B3 machinery: with no hardware counters,
DRAM traffic is *modeled* from tensor sizes, access patterns, and kernel
structure.  Optimized variants account for reduced redundancy from on-chip
reuse; the naive baseline's realized traffic depends on caching behaviour
that is unobservable without counters, so — exactly as the paper does —
``naive`` reports its *redundant logical* traffic and is flagged
``reliable=False`` for effective-bandwidth purposes (paper Table III "N/A").

FLOP counts follow paper eqs. (2)-(3): every multiply-add pair is 2 FLOPs,
so all three paths count  B * H * L * 2K.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.kernels.common import LANE, DWConvDims, cdiv, round_up


@dataclasses.dataclass(frozen=True)
class TrafficEstimate:
    """Modeled HBM traffic for one (variant, path) execution."""

    flops: float
    bytes_read: float
    bytes_written: float
    transactions: float          # DMA count (structural, from the kernel)
    aligned: bool                # lane-aligned transactions?
    reliable: bool               # paper: naive redundant traffic is a proxy only

    @property
    def bytes_moved(self) -> float:
        return self.bytes_read + self.bytes_written

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_moved, 1.0)


def path_flops(d: DWConvDims) -> float:
    """Paper eqs. (2)-(3): identical op count on all three paths."""
    return 2.0 * d.B * d.H * d.L * d.K


def _tile_geometry(d: DWConvDims, block_h: int, block_t: int):
    Hb = min(block_h, d.H)
    Lout = round_up(d.L, LANE)
    Lt = min(block_t, Lout)
    nT = cdiv(Lout, Lt)
    n_tiles = d.B * cdiv(d.H, Hb) * nT
    return Hb, Lout, Lt, nT, n_tiles


def fwd_traffic(
    d: DWConvDims,
    variant: str,
    itemsize: int = 4,
    block_h: int = 8,
    block_t: int = 512,
) -> TrafficEstimate:
    """Forward path (and, by kernel symmetry, the input-gradient path)."""
    Hb, Lout, Lt, nT, n_tiles = _tile_geometry(d, block_h, block_t)
    flops = path_flops(d)
    y_bytes = d.B * d.H * d.L * itemsize
    k_bytes_once = d.H * d.K * itemsize

    if variant == "naive":
        # K unaligned per-tap DMAs of an (Hb, Lt) window per output tile.
        # Filter reads are charged uniformly across variants: one logical
        # pass over the (H, K) filter bank.
        read = n_tiles * d.K * (Hb * Lt) * itemsize + k_bytes_once
        tx = n_tiles * d.K
        return TrafficEstimate(flops, read, y_bytes, tx, aligned=False, reliable=False)
    if variant == "lane":
        # Same per-tap redundancy; windows widened to lane alignment.
        read = n_tiles * d.K * (Hb * (Lt + LANE)) * itemsize + k_bytes_once
        tx = n_tiles * d.K
        return TrafficEstimate(flops, read, y_bytes, tx, aligned=True, reliable=True)
    if variant == "block":
        # Current + neighbour halo tile staged in VMEM per output tile.
        read = n_tiles * 2 * (Hb * Lt) * itemsize + k_bytes_once
        tx = n_tiles * 2
        return TrafficEstimate(flops, read, y_bytes, tx, aligned=True, reliable=True)
    if variant == "row":
        # Full row staged once: every input element crosses HBM once.
        read = d.B * d.H * (Lout + d.K - 1) * itemsize + k_bytes_once
        tx = d.B * cdiv(d.H, Hb)
        return TrafficEstimate(flops, read, y_bytes, tx, aligned=True, reliable=True)
    if variant == "xla":
        # Fused elementwise loop: x once, y once (upper bound: XLA may fuse
        # the pad away; we model the logical minimum, like the paper's
        # PyTorch runtime context).
        read = d.B * d.H * (d.L + d.K - 1) * itemsize + k_bytes_once
        return TrafficEstimate(flops, read, y_bytes, 0, aligned=True, reliable=True)
    raise ValueError(variant)


def _bwd_tiles(d: DWConvDims, variant: str, block_t: int):
    """(nT, halo_elems_per_operand) for a staged bwd kernel.

    ``nT`` is the time-tile count the kernel actually runs (1 = untiled, the
    pre-``block_t`` behaviour); the halo term charges the K-1 columns every
    interior tile seam re-reads — the redundancy the tuner trades against
    per-cell footprint when it shrinks ``block_t``.

    This models the *design's* haloed ``(Bc, Hb, Lt + K - 1)`` slab (the
    traffic a manual halo DMA would move).  The current BlockSpec
    realization binds a full neighbour tile instead — an implementation
    ceiling that re-reads ~Lt columns per seam, like the fwd ``block``
    variant's 2x-tile charge — but on the tuner's axis the *ordering* of
    block_t candidates is set by the seam count either way, and the logical
    model is what the paper's counter-free methodology prescribes for
    redundancy a better realization (or a cache) absorbs.  The transaction
    term does count the physical per-cell block binds, so the DMA-issue
    cost of small tiles is not hidden.
    """
    from repro.kernels.ops import bwdk_time_tile

    Lt = bwdk_time_tile(d.L, d.K, block_t, variant)
    if Lt is None:
        return 1, 0
    nT = cdiv(round_up(d.L, LANE), Lt)
    halo = d.B * d.H * (nT - 1) * (d.K - 1)
    return nT, halo


def bwdk_traffic(
    d: DWConvDims,
    variant: str,
    itemsize: int = 4,
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Weight-gradient path: reduction over the (B x L) domain."""
    flops = path_flops(d)
    Hb = min(block_h, d.H)
    Bc = min(batch_chunk, d.B)
    nC = cdiv(d.B, Bc)
    nH = cdiv(d.H, Hb)
    Kp = round_up(d.K, LANE)
    slab = d.B * d.H * d.L * itemsize  # one full pass over x (or dy)
    dk_bytes = d.H * d.K * itemsize
    nT, halo = _bwd_tiles(d, variant, block_t)
    halo_bytes = halo * itemsize  # x halo re-read at every interior tile seam
    in_blocks = 3 if nT > 1 else 2  # tiled cells bind (x_cur, x_next, dy)

    if variant == "naive":
        # Both operands re-read per tap; no reuse across the K taps.
        read = 2 * d.K * slab
        tx = nH * nC * d.K * 2
        return TrafficEstimate(flops, read, dk_bytes, tx, aligned=False, reliable=False)
    if variant == "twostage":
        # One staged pass over both operands; partials round-trip HBM
        # (one partial block per (chunk, time-tile) in the tiled regime).
        partials = nC * nT * d.H * Kp * 4  # f32 partials
        read = 2 * slab + halo_bytes + partials
        tx = nH * nC * nT * in_blocks + nH * nC * nT
        return TrafficEstimate(flops, read, dk_bytes + partials, tx, aligned=True, reliable=True)
    if variant == "accum":
        # One staged pass; accumulator lives in VMEM across the sequential grid.
        read = 2 * slab + halo_bytes
        tx = nH * nC * nT * in_blocks
        return TrafficEstimate(flops, read, dk_bytes, tx, aligned=True, reliable=True)
    if variant == "xla":
        read = 2 * slab
        return TrafficEstimate(flops, read, dk_bytes, 0, aligned=True, reliable=True)
    raise ValueError(variant)


# ---------------------------------------------------------------------------
# Whole-backward accounting: fused single pass vs the split two-op path.
#
# Unlike the per-kernel models above, these charge the *padded-layout
# materialization* traffic (each ``jnp.pad`` reads its source and writes the
# padded buffer to HBM) — that is exactly the traffic the fusion removes, so
# a fused-vs-split comparison that ignored it would miss the point.  The
# split backward materializes three layouts (dy in the adjoint layout, x
# re-padded, dy again in the forward-aligned layout) and reads dy from HBM
# twice; the fused backward materializes one dy layout, reuses the forward's
# padded x residual verbatim, and reads each operand once.
# ---------------------------------------------------------------------------


def bwd_split_traffic(
    d: DWConvDims,
    itemsize: int = 4,
    bwd_in_variant: str = "row",
    bwd_k_variant: str = "accum",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Total modeled backward traffic for the split (bwd_in + bwd_k) path."""
    est_in = fwd_traffic(d, bwd_in_variant, itemsize,
                         block_h=block_h, block_t=block_t)
    est_k = bwdk_traffic(d, bwd_k_variant, itemsize,
                         block_h=block_h, block_t=block_t,
                         batch_chunk=batch_chunk)
    slab = d.B * d.H * d.L * itemsize
    pslab = d.B * d.H * (d.L + d.K - 1) * itemsize  # one padded layout
    # Three pad materializations: dy -> adjoint layout, x -> x_pad,
    # dy -> forward-aligned layout (each: read source, write padded buffer).
    pad_read = 3 * slab
    pad_written = 2 * pslab + slab
    return TrafficEstimate(
        flops=est_in.flops + est_k.flops,
        bytes_read=pad_read + est_in.bytes_read + est_k.bytes_read,
        bytes_written=pad_written + est_in.bytes_written + est_k.bytes_written,
        transactions=est_in.transactions + est_k.transactions + 3,
        aligned=est_in.aligned and est_k.aligned,
        reliable=est_in.reliable and est_k.reliable,
    )


def bwd_fused_traffic(
    d: DWConvDims,
    variant: str = "fused",
    itemsize: int = 4,
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Backward traffic for the fused single-pass kernels (``"split"`` maps
    to :func:`bwd_split_traffic` so the tuner compares like with like)."""
    if variant == "split":
        return bwd_split_traffic(d, itemsize, block_h=block_h,
                                 block_t=block_t, batch_chunk=batch_chunk)
    flops = 2.0 * path_flops(d)  # dx taps + dk reduction
    Hb = min(block_h, d.H)
    Bc = min(batch_chunk, d.B)
    nC = cdiv(d.B, Bc)
    nH = cdiv(d.H, Hb)
    slab = d.B * d.H * d.L * itemsize
    pslab = d.B * d.H * (d.L + d.K - 1) * itemsize
    k_bytes = d.H * d.K * itemsize
    dk_bytes = d.H * d.K * itemsize
    # Time tiling re-reads the K-1 halo columns of BOTH staged operands at
    # every interior tile seam (the fused slabs are haloed x *and* dy).
    nT, halo = _bwd_tiles(d, variant, block_t)
    halo_bytes = 2 * halo * itemsize
    in_blocks = 5 if nT > 1 else 3  # tiled: (x_cur, x_next, dy_cur, dy_next, k)
    # One pad materialization (dy, single unified layout); the forward's
    # x_pad residual is reused verbatim — zero backward pad cost for x.
    read = slab + 2 * pslab + k_bytes + halo_bytes  # pad src + x_pad + dy_pad + k
    written = pslab + slab + dk_bytes   # dy_pad + dx + dk
    tx = nH * nC * nT * in_blocks + 1
    if variant == "fused_partials":
        partials = nC * nT * d.H * round_up(d.K, LANE) * 4  # f32 HBM round-trip
        read += partials
        written += partials
        tx += nH * nC * nT
    elif variant != "fused":
        raise ValueError(variant)
    return TrafficEstimate(flops, read, written, tx, aligned=True, reliable=True)


# ---------------------------------------------------------------------------
# Epilogue accounting: fused bias/activation vs standalone elementwise ops.
#
# Every model-level call site composes the conv with a per-channel bias add
# and/or a pointwise activation.  Run standalone, each op is one full-tensor
# HBM read + write in the forward, and the activation backward costs a
# further read of dy, a read of the saved pre-activation residual, and a
# write of the effective gradient.  The fused epilogue moves *none* of
# those bytes: the forward applies the ops in-register before the single
# write, and the backward recomputes the pre-activation from the staged
# slab (K extra MACs per element — flops, not bytes) — so the modeled
# difference between the fused and unfused compositions is exactly the
# standalone elementwise traffic.
# ---------------------------------------------------------------------------

from repro.kernels.epilogue import parse_epilogue

# Pointwise-activation cost proxy (tanh/sigmoid polynomial, value or
# derivative) — a flop ordering term, not a calibrated count.
ACT_FLOPS_PER_ELEM = 10.0


def _epilogue_n_ops(bias: bool, act: str) -> int:
    """Standalone elementwise passes the unfused composition runs forward."""
    return (1 if bias else 0) + (1 if act != "none" else 0)


def _epilogue_flops(d: DWConvDims, bias: bool, act: str) -> float:
    elems = d.B * d.H * d.L
    return (elems if bias else 0.0) + (ACT_FLOPS_PER_ELEM * elems if act != "none" else 0.0)


def epilogue_fwd_traffic(
    d: DWConvDims,
    variant: str = "row",
    itemsize: int = 4,
    *,
    epilogue: str = "none",
    fused: bool = True,
    block_h: int = 8,
    block_t: int = 512,
) -> TrafficEstimate:
    """Forward traffic for ``act(conv(x, k) + bias)``.

    ``fused=True`` models the in-register epilogue (the conv variant's own
    traffic plus the bias-vector read); ``fused=False`` charges the unfused
    composition one extra full-tensor read + write per standalone op, so
    ``unfused - fused == n_ops * 2 * B*H*L * itemsize`` exactly.
    """
    bias, act = parse_epilogue(epilogue)
    base = fwd_traffic(d, variant, itemsize, block_h=block_h, block_t=block_t)
    bias_bytes = d.H * itemsize if bias else 0
    flops = base.flops + _epilogue_flops(d, bias, act)
    if fused:
        return dataclasses.replace(
            base, flops=flops, bytes_read=base.bytes_read + bias_bytes)
    n_ops = _epilogue_n_ops(bias, act)
    slab = d.B * d.H * d.L * itemsize
    return dataclasses.replace(
        base, flops=flops,
        bytes_read=base.bytes_read + bias_bytes + n_ops * slab,
        bytes_written=base.bytes_written + n_ops * slab)


def epilogue_bwd_traffic(
    d: DWConvDims,
    variant: str = "fused",
    itemsize: int = 4,
    *,
    epilogue: str = "none",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Whole-backward traffic for the epilogue-aware *fused* kernels.

    Mirrors :func:`bwd_fused_traffic` (pad materialization charged, the
    forward's x_pad residual reused verbatim) with the epilogue deltas: the
    pre-activation recompute adds one ``path_flops`` of MACs and — in the
    tiled regime — the extended x window binds a *third* (prev) tile, so
    three haloed operand reads cross every interior seam instead of two.
    No pre-activation residual is read and no standalone pass runs; the
    only new bytes are the bias vector in and the dbias vector out.

    ``variant="split"`` maps to the activation-*recompute* split
    composition that ``ops.dwconv_bwd_fused_act_op`` actually runs on that
    path (one standalone pre-activation pass + effective-gradient pass +
    the split two-op backward), so fused-vs-split stays like for like on
    the tuner's epilogue-aware ``bwd_fused`` axis.
    """
    bias, act = parse_epilogue(epilogue)
    if epilogue == "none":
        return bwd_fused_traffic(d, variant, itemsize, block_h=block_h,
                                 block_t=block_t, batch_chunk=batch_chunk)
    slab = d.B * d.H * d.L * itemsize
    if variant == "split":
        base = bwd_split_traffic(d, itemsize, block_h=block_h,
                                 block_t=block_t, batch_chunk=batch_chunk)
        # pre recompute (conv + bias, one pass) ...
        pre = fwd_traffic(d, "row", itemsize, block_h=block_h, block_t=block_t)
        # ... + effective-gradient pass (read dy + pre, write dy_eff) + the
        # dbias reduction over dy_eff.
        extra_read = pre.bytes_read + 2 * slab + (slab if bias else 0)
        extra_written = pre.bytes_written + slab + (d.H * itemsize if bias else 0)
        return dataclasses.replace(
            base,
            flops=base.flops + pre.flops + _epilogue_flops(d, bias, act),
            bytes_read=base.bytes_read + extra_read,
            bytes_written=base.bytes_written + extra_written,
            transactions=base.transactions + pre.transactions + 2)
    if variant not in ("fused", "fused_partials"):
        raise ValueError(variant)
    from repro.kernels.ops import epilogue_time_tile

    flops = 3.0 * path_flops(d) + _epilogue_flops(d, bias, act)  # dx + dk + recompute
    Hb = min(block_h, d.H)
    Bc = min(batch_chunk, d.B)
    nC = cdiv(d.B, Bc)
    nH = cdiv(d.H, Hb)
    pslab = d.B * d.H * (d.L + d.K - 1) * itemsize
    k_bytes = d.H * d.K * itemsize
    dk_bytes = d.H * d.K * itemsize
    bias_bytes = d.H * itemsize if bias else 0
    Lt = epilogue_time_tile(d.L, d.K, block_t, variant)
    if Lt is None:
        nT, halo = 1, 0
    else:
        nT = cdiv(round_up(d.L, LANE), Lt)
        halo = d.B * d.H * (nT - 1) * (d.K - 1)
    # Tiled: x binds prev+cur+next (two haloed seam re-reads) and dy
    # cur+next (one) — three halo charges vs the trivial kernels' two.
    halo_bytes = 3 * halo * itemsize
    in_blocks = (7 if bias else 6) if nT > 1 else (4 if bias else 3)
    read = slab + 2 * pslab + k_bytes + bias_bytes + halo_bytes
    written = pslab + slab + dk_bytes + bias_bytes  # dy_pad + dx + dk + dbias
    tx = nH * nC * nT * in_blocks + 1
    if variant == "fused_partials":
        partials = nC * nT * d.H * (round_up(d.K, LANE) + LANE) * 4  # dk + dbias blocks
        read += partials
        written += partials
        tx += nH * nC * nT
    return TrafficEstimate(flops, read, written, tx, aligned=True, reliable=True)


def epilogue_unfused_bwd_traffic(
    d: DWConvDims,
    itemsize: int = 4,
    *,
    epilogue: str = "none",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Backward traffic of the *unfused composition* under ordinary autodiff
    (``jax.vjp`` of conv -> bias add -> act): the activation backward reads
    dy and the saved pre-activation residual and writes the effective
    gradient, the dbias reduction re-reads it, and the split two-op
    backward consumes it.  This is the baseline the epilogue gate compares
    against (the residual's forward-side write is charged by
    ``epilogue_fwd_traffic(fused=False)``)."""
    bias, act = parse_epilogue(epilogue)
    base = bwd_split_traffic(d, itemsize, block_h=block_h, block_t=block_t,
                             batch_chunk=batch_chunk)
    slab = d.B * d.H * d.L * itemsize
    # act backward: read dy + read pre residual, write dy_eff (2R + 1W);
    # dbias reduction (bias only): re-read dy_eff, write the (H,) vector.
    extra_read = (2 * slab if act != "none" else 0) + (slab if bias else 0)
    extra_written = (slab if act != "none" else 0) + (d.H * itemsize if bias else 0)
    return dataclasses.replace(
        base,
        flops=base.flops + _epilogue_flops(d, bias, act),
        bytes_read=base.bytes_read + extra_read,
        bytes_written=base.bytes_written + extra_written,
        transactions=base.transactions + _epilogue_n_ops(bias, act))


def epilogue_block_traffic(
    d: DWConvDims,
    itemsize: int = 4,
    *,
    epilogue: str = "bias+silu",
    fused: bool = True,
    fwd_variant: str = "row",
    bwd_variant: str = "fused",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Whole-block (forward + backward) traffic for one conv + epilogue:
    the quantity the ``paper_epilogue`` gate compares fused vs unfused."""
    fwd = epilogue_fwd_traffic(d, fwd_variant, itemsize, epilogue=epilogue,
                               fused=fused, block_h=block_h, block_t=block_t)
    if fused:
        bwd = epilogue_bwd_traffic(d, bwd_variant, itemsize, epilogue=epilogue,
                                   block_h=block_h, block_t=block_t,
                                   batch_chunk=batch_chunk)
    else:
        bwd = epilogue_unfused_bwd_traffic(d, itemsize, epilogue=epilogue,
                                           block_h=block_h, block_t=block_t,
                                           batch_chunk=batch_chunk)
    return TrafficEstimate(
        flops=fwd.flops + bwd.flops,
        bytes_read=fwd.bytes_read + bwd.bytes_read,
        bytes_written=fwd.bytes_written + bwd.bytes_written,
        transactions=fwd.transactions + bwd.transactions,
        aligned=fwd.aligned and bwd.aligned,
        reliable=fwd.reliable and bwd.reliable,
    )


# ---------------------------------------------------------------------------
# Paper-mode accounting (P100 tables): the paper's §III-G model counts
# *cache-adjusted* traffic on the GPU — redundant in-flight loads within a
# warp/block are absorbed by L1/L2 and shared memory, so per-variant traffic
# differs by the surviving redundancy, not the full K x logical factor the
# explicit-DMA TPU variants move.  Variant names here are the paper's.
# ---------------------------------------------------------------------------

PAPER_VARIANTS = ("naive", "gmc", "shared", "warp")
_WARP_SIZE = 32
_SHARED_TPB = 128  # paper §IV-D temporal tile


def paper_fwd_traffic(d: DWConvDims, variant: str, itemsize: int = 4) -> TrafficEstimate:
    flops = path_flops(d)
    slab = d.B * d.H * d.L * itemsize
    k_bytes = d.H * d.K * itemsize
    if variant == "naive":
        # Realized traffic unobservable without counters: logical lower bound
        # as proxy, flagged unreliable (paper Table III "N/A").
        return TrafficEstimate(flops, slab + k_bytes, slab, 0, aligned=False, reliable=False)
    if variant == "gmc":
        # Warp-level reuse only: redundancy K / min(K, warp) survives caches.
        rho = d.K / min(d.K, _WARP_SIZE)
        return TrafficEstimate(flops, rho * slab + k_bytes, slab, 0, aligned=True, reliable=True)
    if variant == "shared":
        rho = (_SHARED_TPB + d.K - 1) / _SHARED_TPB  # halo per TPB tile
        return TrafficEstimate(flops, rho * slab + k_bytes, slab, 0, aligned=True, reliable=True)
    if variant == "warp":
        # Full row staged once; halo is zero padding (no HBM reads).
        return TrafficEstimate(flops, slab + k_bytes, slab, 0, aligned=True, reliable=True)
    raise ValueError(variant)


def paper_bwdk_traffic(d: DWConvDims, variant: str, itemsize: int = 4) -> TrafficEstimate:
    flops = path_flops(d)
    slab = d.B * d.H * d.L * itemsize
    dk = d.H * d.K * itemsize
    if variant == "naive":
        # Sequential accumulation over B x L per (h, j): K x redundant logical
        # traffic, realized value cache-dependent -> unreliable proxy.
        return TrafficEstimate(flops, 2 * slab, dk, 0, aligned=False, reliable=False)
    # gmc/shared/warp all restructure into chunked two-stage reductions:
    n_chunks = max(d.B // 128, 1)
    partials = n_chunks * d.H * d.K * 4 * 2  # write + re-read in stage 2
    return TrafficEstimate(flops, 2 * slab + partials / 2, dk + partials / 2, 0, aligned=True, reliable=True)


def paper_total_traffic(d: DWConvDims, variant: str, itemsize: int = 4) -> float:
    """Total modeled bytes across all three execution paths (Table III)."""
    fwd = paper_fwd_traffic(d, variant, itemsize)
    bwdk = paper_bwdk_traffic(d, variant, itemsize)
    return 2 * fwd.bytes_moved + bwdk.bytes_moved  # fwd + bwd_in (same) + bwd_k


def variant_traffic_table(
    d: DWConvDims, itemsize: int = 4, **tiling
) -> Dict[str, Dict[str, TrafficEstimate]]:
    """All (study variant x execution path) traffic estimates — the input to
    the paper's Table III / Fig. 10 analogues."""
    from repro.core.variant import REGISTRY

    out: Dict[str, Dict[str, TrafficEstimate]] = {}
    for name, spec in REGISTRY.items():
        if spec.fwd == "auto":  # cache-dependent dispatch: no static model
            continue
        fwd = fwd_traffic(d, spec.fwd, itemsize, **{k: v for k, v in tiling.items() if k in ("block_h", "block_t")})
        bwd_in = fwd_traffic(d, spec.bwd_in, itemsize, **{k: v for k, v in tiling.items() if k in ("block_h", "block_t")})
        bwd_k = bwdk_traffic(d, spec.bwd_k, itemsize, **{k: v for k, v in tiling.items() if k in ("block_h", "block_t", "batch_chunk")})
        out[name] = {"fwd": fwd, "bwd_in": bwd_in, "bwd_k": bwd_k}
        if spec.bwd == "fused":
            out[name]["bwd_fused"] = bwd_fused_traffic(
                d, spec.bwd_fused, itemsize,
                **{k: v for k, v in tiling.items() if k in ("block_h", "block_t", "batch_chunk")})
    return out
