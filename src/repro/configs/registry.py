"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

_MODULES: Dict[str, str] = {
    "whisper-base": "repro.configs.whisper_base",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "smollm-135m": "repro.configs.smollm_135m",
    "llama3-8b": "repro.configs.llama3_8b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE if smoke else mod.CONFIG


def shape_cells_for(cfg: ArchConfig) -> List[str]:
    """Assigned cells for an arch, with the mandated skips:
    long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells
