"""jit-ready wrappers around the Pallas depthwise-conv kernels.

These handle everything the kernels assume away: zero-padding to the
convolution window, rounding every tiled dimension up to TPU-friendly
multiples (lanes of 128, h-blocks, batch-chunks), variant dispatch, and
slicing the outputs back to logical shapes.  They are the only supported
entry points to ``dwconv_fwd.py`` / ``dwconv_bwdk.py``.

``interpret=None`` auto-selects: compiled on TPU, interpret mode elsewhere
(this container is CPU-only, so tests/benches run the kernel bodies in
interpret mode — the validation regime prescribed for this build).

``variant="auto"`` (or ``opts=None`` with it) consults the persistent tuning
cache written by ``repro.tuning`` (keyed on execution path + static shape +
padding + dtype + backend) and dispatches the cached winner — implementation variant
*and* tiling — falling back to the historical defaults (``row`` / ``accum``
with ``DEFAULT_OPTS``) when no entry exists.  Resolution happens at trace
time from static shapes, so jitted callers pay a dict lookup once per
compilation, never per step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dwconv_bwdk, dwconv_fwd, ref
from repro.kernels.common import (
    LANE,
    DWConvDims,
    Padding,
    adjoint_pad_widths,
    cdiv,
    pad_widths,
    round_up,
)

FWD_VARIANTS = ("naive", "lane", "block", "row", "xla")
BWDK_VARIANTS = ("naive", "twostage", "accum", "xla")

# Pre-autotuner hard-coded choices, kept as the no-cache-entry fallback.
AUTO_FALLBACK = {"fwd": "row", "bwd_in": "row", "bwd_k": "accum"}


@dataclasses.dataclass(frozen=True)
class KernelOptions:
    """Static tiling knobs (hashable: used as a custom_vjp nondiff arg)."""

    block_h: int = 8
    block_t: int = 512
    batch_chunk: int = 128
    interpret: Optional[bool] = None

    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"


DEFAULT_OPTS = KernelOptions()


def resolve_variant(
    path: str,
    variant: str,
    opts: Optional[KernelOptions],
    *,
    B: int,
    H: int,
    L: int,
    K: int,
    dtype,
    padding: Padding = "same",
) -> Tuple[str, KernelOptions]:
    """Resolve ``variant="auto"`` / ``opts=None`` through the tuning cache.

    Explicit ``opts`` always wins over cached tiling (the caller asked for
    it); a cached entry decides the variant and, absent explicit opts, the
    tiling; with no cache entry the pre-autotuner defaults apply.
    """
    if variant != "auto":
        return variant, (opts if opts is not None else DEFAULT_OPTS)
    from repro.tuning import cache as _tuning_cache  # deferred: tuning imports ops
    from repro.tuning import space as _tuning_space

    entry = _tuning_cache.lookup(
        path=path, B=B, H=H, L=L, K=K,
        dtype=jnp.dtype(dtype).name, backend=jax.default_backend(),
        padding=padding,
    )
    if entry is None:
        return AUTO_FALLBACK[path], (opts if opts is not None else DEFAULT_OPTS)
    if opts is None:
        return entry.variant, entry.options()
    # The cache tuned (variant, tiling) together; pairing its variant with
    # caller tiling can violate that variant's kernel asserts (e.g. a 'lane'
    # winner with an unaligned explicit block_t).  Keep the caller's opts —
    # they asked for them — and drop to the always-safe fallback variant
    # whenever the combination is illegal.
    cand = _tuning_space.Candidate(
        path=path, variant=entry.variant,
        block_h=opts.block_h, block_t=opts.block_t, batch_chunk=opts.batch_chunk)
    if _tuning_space.is_legal(cand, DWConvDims(B=B, H=H, L=L, K=K, padding=padding))[0]:
        return entry.variant, opts
    return AUTO_FALLBACK[path], opts


def _pad_channels(a: jnp.ndarray, H: int, Hb: int, axis: int) -> jnp.ndarray:
    Hp = round_up(H, Hb)
    if Hp == H:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, Hp - H)
    return jnp.pad(a, widths)


def _pad_kernel_lanes(k: jnp.ndarray, K: int) -> jnp.ndarray:
    Kp = round_up(K, LANE)
    return jnp.pad(k, ((0, 0), (0, Kp - K))) if Kp > K else k


def _fwd_impl(
    x: jnp.ndarray,
    k: jnp.ndarray,
    p_left: int,
    variant: str,
    opts: KernelOptions,
) -> jnp.ndarray:
    B, H, L = x.shape
    _, K = k.shape
    interpret = opts.resolved_interpret()
    Hb = min(opts.block_h, H)
    Lout = round_up(L, LANE)
    Lt = min(opts.block_t, Lout)
    nT = cdiv(Lout, Lt)
    # One padded buffer wide enough for every variant's window reads.
    Wpad = max(
        round_up(Lout + K - 1, LANE),
        (nT + 1) * Lt,                       # block: neighbour halo tile
        nT * Lt + K - 1 + LANE,              # lane: widened aligned windows
    )
    Wpad = round_up(Wpad, LANE)
    xp = jnp.pad(x, ((0, 0), (0, 0), (p_left, Wpad - L - p_left)))
    xp = _pad_channels(xp, H, Hb, axis=1)
    kp = _pad_channels(_pad_kernel_lanes(k, K), H, Hb, axis=0)

    kw = dict(K=K, Lout=Lout, block_h=Hb, interpret=interpret)
    if variant == "row":
        y = dwconv_fwd.dwconv_fwd_row(xp, kp, **kw)
    elif variant == "block":
        y = dwconv_fwd.dwconv_fwd_block(xp, kp, block_t=Lt, **kw)
    elif variant == "naive":
        y = dwconv_fwd.dwconv_fwd_naive(xp, kp, block_t=Lt, **kw)
    elif variant == "lane":
        y = dwconv_fwd.dwconv_fwd_lane(xp, kp, block_t=Lt, **kw)
    else:
        raise ValueError(f"unknown fwd variant {variant!r}")
    return y[:, :H, :L]


def dwconv_fwd_op(
    x: jnp.ndarray,
    k: jnp.ndarray,
    padding: Padding = "same",
    variant: str = "row",
    opts: Optional[KernelOptions] = None,
) -> jnp.ndarray:
    """y[b,h,t] = sum_j x_pad[b,h,t+j] k[h,j].  ``variant="auto"`` dispatches
    the tuned (variant, tiling) for this shape; ``"xla"`` runs the reference."""
    B, H, L = x.shape
    K = k.shape[-1]
    variant, opts = resolve_variant("fwd", variant, opts, B=B, H=H, L=L, K=K,
                                    dtype=x.dtype, padding=padding)
    if variant == "xla":
        return ref.dwconv_fwd_ref(x, k, padding)
    p_left, _ = pad_widths(K, padding)
    return _fwd_impl(x, k, p_left, variant, opts)


def dwconv_bwd_input_op(
    dy: jnp.ndarray,
    k: jnp.ndarray,
    padding: Padding = "same",
    variant: str = "row",
    opts: Optional[KernelOptions] = None,
) -> jnp.ndarray:
    """dx: flipped-filter correlation under adjoint padding (same kernels as
    the forward path — the structural symmetry the paper exploits)."""
    B, H, L = dy.shape
    K = k.shape[-1]
    variant, opts = resolve_variant("bwd_in", variant, opts, B=B, H=H, L=L, K=K,
                                    dtype=dy.dtype, padding=padding)
    if variant == "xla":
        return ref.dwconv_bwd_input_ref(dy, k, padding)
    p_left, _ = adjoint_pad_widths(K, padding)
    return _fwd_impl(dy, k[:, ::-1], p_left, variant, opts)


def _bwdk_impl(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    K: int,
    padding: Padding,
    variant: str,
    opts: KernelOptions,
) -> jnp.ndarray:
    B, H, L = x.shape
    interpret = opts.resolved_interpret()
    Hb = min(opts.block_h, H)
    Bc = min(opts.batch_chunk, B)
    p_left, _ = pad_widths(K, padding)
    Lout = round_up(L, LANE)
    Wpad = round_up(Lout + K - 1, LANE)
    Bp = round_up(B, Bc)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0), (p_left, Wpad - L - p_left)))
    dyp = jnp.pad(dy, ((0, Bp - B), (0, 0), (0, Lout - L)))
    xp = _pad_channels(xp, H, Hb, axis=1)
    dyp = _pad_channels(dyp, H, Hb, axis=1)

    kw = dict(K=K, block_h=Hb, batch_chunk=Bc, interpret=interpret)
    if variant == "accum":
        dk = dwconv_bwdk.dwconv_bwdk_accum(xp, dyp, **kw)
    elif variant == "twostage":
        dk = dwconv_bwdk.dwconv_bwdk_twostage(xp, dyp, **kw)
    elif variant == "naive":
        dk = dwconv_bwdk.dwconv_bwdk_naive(xp, dyp, **kw)
    else:
        raise ValueError(f"unknown bwdk variant {variant!r}")
    return dk[:H]


def dwconv_bwd_kernel_op(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    K: int,
    padding: Padding = "same",
    variant: str = "accum",
    opts: Optional[KernelOptions] = None,
) -> jnp.ndarray:
    """dk[h,j] = sum_{b,t} dy[b,h,t] x_pad[b,h,t+j].  Returns f32 (H, K)
    (the ``"xla"`` reference returns x.dtype; callers cast to the param dtype)."""
    B, H, L = x.shape
    variant, opts = resolve_variant("bwd_k", variant, opts, B=B, H=H, L=L, K=K,
                                    dtype=x.dtype, padding=padding)
    if variant == "xla":
        return ref.dwconv_bwd_kernel_ref(x, dy, K, padding)
    return _bwdk_impl(x, dy, K, padding, variant, opts)


@functools.partial(jax.jit, static_argnames=("padding", "variant", "opts"))
def dwconv_fwd_jit(x, k, padding="same", variant="row", opts=None):
    return dwconv_fwd_op(x, k, padding, variant, opts)


@functools.partial(jax.jit, static_argnames=("padding", "variant", "opts"))
def dwconv_bwd_input_jit(dy, k, padding="same", variant="row", opts=None):
    return dwconv_bwd_input_op(dy, k, padding, variant, opts)


@functools.partial(jax.jit, static_argnames=("K", "padding", "variant", "opts"))
def dwconv_bwd_kernel_jit(x, dy, K, padding="same", variant="accum", opts=None):
    return dwconv_bwd_kernel_op(x, dy, K, padding, variant, opts)
