"""Markdown/CSV emitters for the counter-free analysis workflow, plus the
schedule-derived full report (paper Tables II/III + Fig. 10 analysis).

The report half of this module is pure derivation: every number comes from
the registered :class:`~repro.perfmodel.KernelSchedule` specs through
``perfmodel.derive`` — no hardware counters, no measurement, no benchmark
scripts.  ``python -m repro.launch.report`` is the CLI;
``benchmarks/paper_roofline.py`` consumes :func:`paper_roofline_points`
so the benchmark's rows and the report's rows are one computation.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro import perfmodel
from repro.analysis.hw import P100, TPU_V5E, HardwareModel
from repro.analysis.paper_data import TABLE2_MS
from repro.analysis.roofline import RooflineReport
from repro.kernels.common import DWConvDims
from repro.kernels.epilogue import EPILOGUE_KEYS
from repro.perfmodel import RooflinePoint


def fmt_si(x: Optional[float], unit: str = "") -> str:
    if x is None:
        return "N/A"
    ax = abs(x)
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if ax >= div:
            return f"{x / div:.2f}{suf}{unit}"
    return f"{x:.3g}{unit}"


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    if x >= 1e-6:
        return f"{x * 1e6:.1f}us"
    return f"{x * 1e9:.1f}ns"


def markdown_table(headers: Sequence[str], rows: Iterable[Sequence[str]]) -> str:
    lines = ["| " + " | ".join(headers) + " |", "|" + "|".join(["---"] * len(headers)) + "|"]
    for row in rows:
        lines.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(lines)


def roofline_markdown(reports: List[RooflineReport]) -> str:
    headers = [
        "cell", "chips", "compute", "memory", "collective", "dominant",
        "bound step", "MODEL/HLO flops", "roofline frac", "peak mem/dev",
    ]
    rows = []
    for r in reports:
        rows.append(
            [
                r.label,
                r.chips,
                fmt_s(r.compute_s),
                fmt_s(r.memory_s),
                fmt_s(r.collective_s),
                r.dominant,
                fmt_s(r.step_time_overlap_s),
                f"{r.useful_flops_ratio:.3f}",
                f"{r.roofline_fraction:.3f}",
                fmt_si(r.peak_memory_per_device, "B"),
            ]
        )
    return markdown_table(headers, rows)


def csv_line(fields: Sequence) -> str:
    return ",".join(str(f) for f in fields)


def dump_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=str)


# ---------------------------------------------------------------------------
# The counter-free report: everything derived from registered schedules.
# ---------------------------------------------------------------------------

def study_schedules(
    d: DWConvDims,
    itemsize: int = 4,
    *,
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> List[tuple]:
    """Every ``(study variant, schedule)`` pair in registry order — the
    spec set behind the paper's Table III / Fig. 10 analogues (plus the
    whole-backward ``bwd_fused`` row for specs that fuse the backward)."""
    from repro.core.variant import REGISTRY

    kw = dict(block_h=block_h, block_t=block_t, batch_chunk=batch_chunk)
    out: List[tuple] = []
    for name, spec in REGISTRY.items():
        if spec.fwd == "auto":  # cache-dependent dispatch: no static model
            continue
        for path, variant in (("fwd", spec.fwd), ("bwd_in", spec.bwd_in),
                              ("bwd_k", spec.bwd_k)):
            out.append((name, perfmodel.schedule_for(path, variant, d,
                                                     itemsize, **kw)))
        if spec.bwd == "fused":
            out.append((name, perfmodel.schedule_for(
                "bwd_fused", spec.bwd_fused, d, itemsize, **kw)))
    return out


def decode_study_schedules(
    d: DWConvDims,
    itemsize: int = 4,
    *,
    block_t: int = 512,
    batch_chunk: int = 128,
    epilogue: str = "bias+silu",
) -> Tuple[DWConvDims, List[tuple], perfmodel.KernelSchedule]:
    """The streaming-decode rows at this shape's L=1 serving slice.

    Returns ``(decode_dims, [(variant, schedule)], baseline)`` where the
    schedules are the registered single-step decode variants (fused ring
    kernels + the XLA reference chain) and ``baseline`` is the full
    causal conv re-run over the length-``d.L`` cache to produce one new
    position — the serve loop the decode path replaces.  The modeled
    margin is structural: the step moves O(B*H*K) bytes against the
    baseline's O(B*H*L).  ``epilogue`` defaults to the serve path's
    actual fused epilogue (SSM convs decode under bias+silu).
    """
    from repro.perfmodel.schedules import decode_full_conv_schedule

    dd = dataclasses.replace(d, L=1, padding="causal")
    rows: List[tuple] = []
    for variant in ("rows", "chanblock", "xla"):
        rows.append((variant, perfmodel.schedule_for(
            "decode", variant, dd, itemsize, block_t=block_t,
            batch_chunk=batch_chunk, epilogue=epilogue)))
    baseline = decode_full_conv_schedule(
        dataclasses.replace(d, padding="causal"), itemsize,
        epilogue=epilogue)
    return dd, rows, baseline


def _schedule_record(study: str, s: perfmodel.KernelSchedule,
                     hw: HardwareModel,
                     verified: Optional[str] = None) -> Dict[str, Any]:
    """One execution-path decomposition row: the derived traffic plus the
    per-operand breakdown straight out of the spec."""
    est = perfmodel.derive_traffic(s)
    return {
        "study": study,
        "path": s.path,
        "variant": s.variant,
        "epilogue": s.epilogue,
        "schedule_verified": verified,
        "grid": {name: extent for name, extent in s.grid},
        "flops": est.flops,
        "bytes_read": est.bytes_read,
        "bytes_written": est.bytes_written,
        "bytes_moved": est.bytes_moved,
        "transactions": est.transactions,
        "aligned": est.aligned,
        "reliable": est.reliable,
        "arithmetic_intensity": est.arithmetic_intensity if est.reliable else None,
        "vmem_bytes_per_cell": perfmodel.vmem_bytes(s),
        "analytical_time_s": perfmodel.analytical_time_s(s, hw),
        "operands": [
            {"name": o.name, "role": o.role, "bytes": o.hbm_bytes,
             "transactions": o.transactions, "note": o.note}
            for o in s.operands
        ],
    }


def paper_roofline_points(
    d: Optional[DWConvDims] = None,
    itemsize: int = 4,
    *,
    hw: HardwareModel = P100,
) -> List[RooflinePoint]:
    """Paper Fig. 10 rows: the paper-mode schedules at the paper's study
    shape, placed on the P100 roofline against the paper's *published*
    Table II runtimes.  ``benchmarks/paper_roofline.py`` renders exactly
    these points, so the benchmark and the report cannot diverge."""
    from repro.analysis.paper_data import PAPER_DIMS

    d = d if d is not None else PAPER_DIMS
    points: List[RooflinePoint] = []
    for variant, (fwd_ms, bin_ms, bk_ms, _, _) in TABLE2_MS.items():
        for path, ms in (("fwd", fwd_ms), ("bwd_in", bin_ms), ("bwd_k", bk_ms)):
            sched_path = "paper_bwd_k" if path == "bwd_k" else "paper_fwd"
            s = perfmodel.schedule_for(sched_path, variant, d, itemsize)
            # Label with the study path (fwd / bwd_in share one schedule
            # family — the paper's structural symmetry).
            s = dataclasses.replace(s, path=path)
            points.append(perfmodel.roofline_point(s, hw, runtime_s=ms / 1e3))
    return points


def counter_free_report(
    d: DWConvDims,
    *,
    hw: HardwareModel = TPU_V5E,
    itemsize: int = 4,
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
    include_paper: bool = True,
    include_epilogue: bool = True,
    include_decode: bool = True,
    calibration=None,
    measured: Optional[Dict[str, Any]] = None,
    verify: bool = True,
) -> Dict[str, Any]:
    """The paper's full counter-free analysis as one JSON-able payload.

    Sections:
      * ``decomposition`` — execution-path traffic decomposition per
        (variant x path), with the per-operand byte breakdown and the
        static ``schedule_verified`` badge (``verify=False`` skips the
        model↔kernel cross-check);
      * ``roofline``      — roofline placement per (variant x path), with
        effective bandwidth at the modeled bound vs the ``hw`` peaks;
      * ``paper``         — the P100 paper-mode rows against the published
        Table II runtimes (Fig. 10 / Table III analogues);
      * ``epilogue``      — fused-vs-unfused whole-block bytes per epilogue;
      * ``decode``        — the streaming-decode rows: single-step fused
        ring kernels at the L=1 serving slice of ``d`` against the
        full-conv-over-cache baseline (modeled O(K)-vs-O(L) byte margin);
      * ``calibration`` / ``calibrated_roofline`` — when a
        :class:`~repro.obs.calibrate.CalibratedHardware` overlay is given,
        the measured achievable roofs and each kernel's placement against
        them (the denominator this runner can actually reach);
      * ``measured``      — per-kernel modeled-vs-measured rows (built by
        ``launch/report.py``, which owns the measurement), passed through.
    """
    kw = dict(block_h=block_h, block_t=block_t, batch_chunk=batch_chunk)
    schedules = study_schedules(d, itemsize, **kw)
    # Per-kernel static verification badge: each unique (path, variant) is
    # cross-checked against its abstractly traced pallas_call at these exact
    # dims/knobs (repro.verify.schedule_check — no execution).  "model-only"
    # marks variants with no Pallas kernel (xla, split, paper_*).
    verified_map: Dict[Tuple[str, str], str] = {}
    if verify:
        from repro.verify.schedule_check import verify_config

        vdtype = {2: "bfloat16", 4: "float32"}.get(itemsize, "float32")
        for _, s in schedules:
            key = (s.path, s.variant)
            if key in verified_map:
                continue
            status, fs = verify_config(s.path, s.variant, d,
                                       itemsize=itemsize, dtype=vdtype, **kw)
            verified_map[key] = (f"findings:{len(fs)}" if fs else status)
    payload: Dict[str, Any] = {
        "dims": {"B": d.B, "H": d.H, "L": d.L, "K": d.K, "padding": d.padding},
        "hw": hw.name,
        "itemsize": itemsize,
        "tiling": kw,
        "hbm_peak_bytes_per_s": hw.hbm_bw,
        "peak_flops_f32": hw.peak_flops_f32,
        "roofline_knee_flop_per_byte": hw.peak_flops_f32 / hw.hbm_bw,
        "decomposition": [
            _schedule_record(study, s, hw,
                             verified_map.get((s.path, s.variant)))
            for study, s in schedules],
        # Effective bandwidth against the DMA-inclusive stage-1 analytical
        # time (the tuner's ranking quantity): still fully derived, and it
        # separates the per-tap-DMA variants from the staged ones instead
        # of reporting a vacuous 100% at the pure roofline bound.
        "roofline": [
            dict(perfmodel.roofline_point(
                s, hw, runtime_s=perfmodel.analytical_time_s(s, hw)).to_dict(),
                 study=study, runtime_modeled=True)
            for study, s in schedules
        ],
    }
    if calibration is not None:
        cal_hw = calibration.hardware_model(hw)
        payload["calibration"] = {
            "fingerprint": calibration.fingerprint,
            "base": hw.name,
            "hbm_bw": calibration.hbm_bw,
            "copy_bw": calibration.copy_bw,
            "flops_f32": calibration.flops_f32,
            "dispatch_overhead_s": calibration.dispatch_overhead_s,
            "bw_overhead_s": calibration.bw_overhead_s,
            "bw_r2": calibration.bw_r2,
            "flops_r2": calibration.flops_r2,
            "created": calibration.created,
            "bw_fraction_of_peak": calibration.hbm_bw / hw.hbm_bw,
            "flops_fraction_of_peak": calibration.flops_f32 / hw.peak_flops_f32,
        }
        # The same kernels, re-placed against the *achievable* roofs: the
        # knee moves, regimes can flip, and bandwidth utilization is now
        # relative to what a microbenchmark proved this runner reaches.
        payload["calibrated_roofline"] = [
            dict(perfmodel.roofline_point(
                s, cal_hw,
                runtime_s=calibration.analytical_time_s(s, hw)).to_dict(),
                 study=study, runtime_modeled=True)
            for study, s in schedules
        ]
    if measured is not None:
        payload["measured"] = measured
    if include_paper:
        # Always float32 charging here: the section divides modeled bytes by
        # the paper's *published* Table II runtimes, which are f32 runs — a
        # --dtype bfloat16 report must not halve the paper's bandwidths.
        payload["paper"] = [p.to_dict() for p in paper_roofline_points(itemsize=4)]
    if include_decode:
        dd, drows, baseline = decode_study_schedules(
            d, itemsize, block_t=block_t, batch_chunk=batch_chunk)
        dver: Dict[str, str] = {}
        if verify:
            from repro.verify.schedule_check import verify_config

            vdtype = {2: "bfloat16", 4: "float32"}.get(itemsize, "float32")
            for variant, s in drows:
                status, fs = verify_config(
                    "decode", variant, dd, itemsize=itemsize, dtype=vdtype,
                    epilogue=s.epilogue, block_h=block_h, block_t=block_t,
                    batch_chunk=batch_chunk)
                dver[variant] = f"findings:{len(fs)}" if fs else status
        base_est = perfmodel.derive_traffic(baseline)
        drow_payload = []
        for variant, s in drows:
            est = perfmodel.derive_traffic(s)
            pt = perfmodel.roofline_point(
                s, hw, runtime_s=perfmodel.analytical_time_s(s, hw))
            drow_payload.append({
                "variant": variant,
                "schedule_verified": dver.get(variant),
                "flops": est.flops,
                "bytes_moved": est.bytes_moved,
                "arithmetic_intensity":
                    est.arithmetic_intensity if est.reliable else None,
                "regime": pt.regime,
                "analytical_time_s": pt.runtime_s,
                "vmem_bytes_per_cell": perfmodel.vmem_bytes(s),
                # The structural win: the per-step fused kernel's bytes
                # against re-running the conv over the whole cache.
                "byte_margin_vs_full_conv":
                    base_est.bytes_moved / est.bytes_moved
                    if est.bytes_moved else None,
            })
        payload["decode"] = {
            "dims": {"B": dd.B, "H": dd.H, "L": dd.L, "K": dd.K,
                     "padding": dd.padding},
            "cache_len": d.L,
            "epilogue": drows[0][1].epilogue,
            "baseline": {
                "path": baseline.path,
                "variant": baseline.variant,
                "flops": base_est.flops,
                "bytes_moved": base_est.bytes_moved,
                "analytical_time_s":
                    perfmodel.analytical_time_s(baseline, hw),
            },
            "rows": drow_payload,
        }
    if include_epilogue:
        epi_rows = []
        for epi in EPILOGUE_KEYS:
            if epi == "none":
                continue
            fused = perfmodel.derive_traffic(
                perfmodel.epilogue_block_schedule(d, itemsize, epilogue=epi,
                                                  fused=True, **kw))
            unfused = perfmodel.derive_traffic(
                perfmodel.epilogue_block_schedule(d, itemsize, epilogue=epi,
                                                  fused=False, **kw))
            epi_rows.append({
                "epilogue": epi,
                "fused_bytes": fused.bytes_moved,
                "unfused_bytes": unfused.bytes_moved,
                "ratio": fused.bytes_moved / unfused.bytes_moved,
            })
        payload["epilogue"] = epi_rows
    return payload


def _fmt_ai(x: Optional[float]) -> str:
    return "N/A" if x is None else f"{x:.2f}"


def counter_free_markdown(payload: Dict[str, Any]) -> str:
    """Render the :func:`counter_free_report` payload as markdown."""
    d = payload["dims"]
    lines = [
        "# Counter-free performance report",
        "",
        f"Shape (B, H, L, K) = ({d['B']}, {d['H']}, {d['L']}, {d['K']}), "
        f"padding={d['padding']}, itemsize={payload['itemsize']}B, "
        f"hardware={payload['hw']} "
        f"(HBM {fmt_si(payload['hbm_peak_bytes_per_s'], 'B/s')}, "
        f"f32 peak {fmt_si(payload['peak_flops_f32'], 'FLOP/s')}, "
        f"knee {payload['roofline_knee_flop_per_byte']:.1f} FLOP/B).",
        "",
        "Every number below is *derived* from the registered kernel",
        "schedules (`repro.perfmodel`) — no hardware counters, no",
        "measurement.  Unreliable rows (the naive baseline's cache-dependent",
        "redundancy) report `N/A`, exactly like the paper's Table III.",
        "The `verified` column is the static model↔kernel cross-check",
        "(`repro.verify.schedule_check`): `verified` means the schedule was",
        "proven against the kernel's abstractly traced launch geometry at",
        "these dims; `model-only` marks variants with no Pallas kernel.",
        "",
        "## Execution-path decomposition (modeled bytes)",
        "",
        markdown_table(
            ["study", "path", "kernel", "verified", "FLOPs", "read",
             "written", "moved", "DMAs", "AI (FLOP/B)", "VMEM/cell"],
            [[r["study"], r["path"], r["variant"],
              r.get("schedule_verified") or "—",
              fmt_si(r["flops"]),
              fmt_si(r["bytes_read"], "B"), fmt_si(r["bytes_written"], "B"),
              fmt_si(r["bytes_moved"], "B"), fmt_si(r["transactions"]),
              _fmt_ai(r["arithmetic_intensity"]),
              fmt_si(r["vmem_bytes_per_cell"], "B")]
             for r in payload["decomposition"]]),
        "",
        "## Roofline placement + effective bandwidth (modeled bound)",
        "",
        markdown_table(
            ["study", "path", "kernel", "AI (FLOP/B)", "regime",
             "roof GFLOP/s", "modeled time", "eff. BW", "BW util"],
            [[r["study"], r["path"], r["variant"],
              _fmt_ai(r["arithmetic_intensity"]),
              r["regime"] or "N/A",
              "N/A" if r["roof_gflops"] is None else f"{r['roof_gflops']:.0f}",
              fmt_s(r["runtime_s"]),
              "N/A" if r["effective_bandwidth"] is None
              else fmt_si(r["effective_bandwidth"], "B/s"),
              "N/A" if r["bandwidth_utilization"] is None
              else f"{100 * r['bandwidth_utilization']:.1f}%"]
             for r in payload["roofline"]]),
    ]
    if payload.get("calibration"):
        c = payload["calibration"]
        lines += [
            "",
            "## Hardware calibration (this runner)",
            "",
            f"Device `{c['fingerprint']}`, microbenchmarked "
            f"{c['created'] or 'previously'}: the *achievable* roofs below "
            "replace the datasheet peaks as the effective-bandwidth",
            "denominator (fit: `time = overhead + bytes/bandwidth` over the "
            "sweep; see `repro.obs.calibrate`).",
            "",
            markdown_table(
                ["quantity", "measured", "datasheet", "achieved"],
                [["triad bandwidth", fmt_si(c["hbm_bw"], "B/s"),
                  fmt_si(payload["hbm_peak_bytes_per_s"], "B/s"),
                  f"{100 * c['bw_fraction_of_peak']:.1f}%"],
                 ["copy bandwidth", fmt_si(c["copy_bw"], "B/s"), "—", "—"],
                 ["f32 FLOP/s", fmt_si(c["flops_f32"], "FLOP/s"),
                  fmt_si(payload["peak_flops_f32"], "FLOP/s"),
                  f"{100 * c['flops_fraction_of_peak']:.1f}%"],
                 ["dispatch floor", fmt_s(c["dispatch_overhead_s"]), "—", "—"],
                 ["launch overhead (bw fit)", fmt_s(c["bw_overhead_s"]),
                  "—", f"r²={c['bw_r2']:.3f}"]]),
        ]
    if payload.get("calibrated_roofline"):
        lines += [
            "",
            "## Roofline placement — calibrated (achievable) roofs",
            "",
            markdown_table(
                ["study", "path", "kernel", "AI (FLOP/B)", "regime",
                 "calibrated time", "eff. BW", "BW util (achievable)"],
                [[r["study"], r["path"], r["variant"],
                  _fmt_ai(r["arithmetic_intensity"]),
                  r["regime"] or "N/A",
                  fmt_s(r["runtime_s"]),
                  "N/A" if r["effective_bandwidth"] is None
                  else fmt_si(r["effective_bandwidth"], "B/s"),
                  "N/A" if r["bandwidth_utilization"] is None
                  else f"{100 * r['bandwidth_utilization']:.1f}%"]
                 for r in payload["calibrated_roofline"]]),
        ]
    if payload.get("measured"):
        m = payload["measured"]
        md = m["dims"]
        lines += [
            "",
            "## Modeled vs measured (per-kernel error bars)",
            "",
            f"Kernels metered at (B, H, L, K) = ({md['B']}, {md['H']}, "
            f"{md['L']}, {md['K']}), dtype={m['dtype']}, "
            f"{m['iters']} iterations; measured is the median ±1σ "
            "(paper §III-F protocol).  `x model` divides measured time by "
            "the calibrated analytical bound — the per-kernel error bar on "
            "the counter-free model itself.",
            "",
            markdown_table(
                ["path", "kernel", "modeled (datasheet)",
                 "modeled (calibrated)", "measured ±1σ", "x model"],
                [[r["path"], r["variant"], fmt_s(r["modeled_s"]),
                  "N/A" if r.get("modeled_calibrated_s") is None
                  else fmt_s(r["modeled_calibrated_s"]),
                  f"{fmt_s(r['measured_s'])} ±{fmt_s(r['measured_std_s'])}",
                  "N/A" if r.get("error_ratio") is None
                  else f"{r['error_ratio']:.2f}x"]
                 for r in m["rows"]]),
        ]
    if payload.get("paper"):
        lines += [
            "",
            "## Paper-mode rows (P100, published Table II runtimes)",
            "",
            markdown_table(
                ["variant", "path", "runtime", "achieved GFLOP/s",
                 "AI (FLOP/B)", "regime", "eff. BW"],
                [[r["variant"], r["path"], fmt_s(r["runtime_s"]),
                  f"{r['achieved_gflops']:.0f}",
                  _fmt_ai(r["arithmetic_intensity"]), r["regime"] or "N/A",
                  "N/A" if r["effective_bandwidth"] is None
                  else fmt_si(r["effective_bandwidth"], "B/s")]
                 for r in payload["paper"]]),
        ]
    if payload.get("decode"):
        dk = payload["decode"]
        dd = dk["dims"]
        base = dk["baseline"]
        lines += [
            "",
            "## Streaming decode (single-step ring kernels, L=1)",
            "",
            f"One serving step at (B, H, K) = ({dd['B']}, {dd['H']}, "
            f"{dd['K']}), epilogue `{dk['epilogue']}`: the fused kernels "
            "shift the carried ring, apply the K-tap dot, and write the new "
            "ring back — O(B·H·K) bytes per step.  The baseline re-runs the "
            f"full causal conv over the length-{dk['cache_len']} cache "
            f"({fmt_si(base['bytes_moved'], 'B')} moved, "
            f"{fmt_s(base['analytical_time_s'])} modeled) to produce the "
            "same one position; `x full-conv` is the modeled byte margin "
            "the decode path buys.",
            "",
            markdown_table(
                ["kernel", "verified", "FLOPs", "moved", "AI (FLOP/B)",
                 "regime", "modeled time", "VMEM/cell", "x full-conv"],
                [[r["variant"], r.get("schedule_verified") or "—",
                  fmt_si(r["flops"]), fmt_si(r["bytes_moved"], "B"),
                  _fmt_ai(r["arithmetic_intensity"]), r["regime"] or "N/A",
                  fmt_s(r["analytical_time_s"]),
                  fmt_si(r["vmem_bytes_per_cell"], "B"),
                  "N/A" if r["byte_margin_vs_full_conv"] is None
                  else f"{r['byte_margin_vs_full_conv']:.0f}x"]
                 for r in dk["rows"]]),
        ]
    if payload.get("epilogue"):
        lines += [
            "",
            "## Epilogue fusion (whole-block fused vs unfused bytes)",
            "",
            markdown_table(
                ["epilogue", "fused", "unfused", "fused/unfused"],
                [[r["epilogue"], fmt_si(r["fused_bytes"], "B"),
                  fmt_si(r["unfused_bytes"], "B"), f"{r['ratio']:.3f}"]
                 for r in payload["epilogue"]]),
        ]
    return "\n".join(lines) + "\n"
