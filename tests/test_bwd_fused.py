"""Fused-backward validation: exactness vs ``jax.vjp`` of the XLA reference
across K parity / padding / dtype / non-divisible shapes, bit-for-bit dk
agreement with the split ``accum`` variant, residual reuse through the
custom VJP, tuning-cache dispatch of the fused path, and the cache schema
bump (v2 databases migrate cleanly).

``hypothesis`` is optional, as in ``test_kernels_dwconv.py``: the property
test skips when it is absent; the deterministic sweeps always run.
"""
import json

try:  # optional dev dependency (see README "Optional dependencies")
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None
    st = None
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dwconv as dw
from repro.core.variant import get_variant
from repro.kernels import ops, ref
from repro.tuning import cache as tcache
from repro.tuning.cache import DEFAULT_CACHE_PATH, ShapeKey, TuneEntry, TuningCache

# (B, H, L, K, padding): odd/even K, same/causal, non-divisible B and H
# (forcing batch-chunk and channel padding), L both below and above LANE.
SHAPES = [
    (2, 8, 48, 48, "same"),      # the paper's L=K geometry (even K)
    (3, 16, 100, 7, "same"),     # odd K, B not divisible by batch_chunk
    (2, 4, 200, 4, "causal"),    # causal even K
    (1, 8, 130, 48, "same"),     # L > LANE
    (2, 3, 48, 5, "same"),       # H not divisible by block_h
    (1, 1, 7, 3, "same"),        # degenerate tiny dims
    (3, 5, 96, 48, "causal"),    # causal long filter, ragged B and H
]
FUSED_VARIANTS = ["fused", "fused_partials"]
SMALL_OPTS = ops.KernelOptions(batch_chunk=2, block_h=3, interpret=True)


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


def _vjp_ref(x, k, dy, pad):
    _, vjp = jax.vjp(lambda x, k: ref.dwconv_fwd_ref(x, k, pad), x, k)
    return vjp(dy)


# ---------------------------------------------------------------------------
# exactness vs jax.vjp of the reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", FUSED_VARIANTS)
@pytest.mark.parametrize("B,H,L,K,pad", SHAPES)
def test_fused_op_matches_vjp(variant, B, H, L, K, pad):
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    dy = _rand((B, H, L), jnp.float32, 2)
    dx_want, dk_want = _vjp_ref(x, k, dy, pad)
    dx, dk = ops.dwconv_bwd_fused_op(x, dy, k, pad, variant, SMALL_OPTS)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_want),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dk_want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)])
def test_fused_dtype_sweep(dtype, atol):
    B, H, L, K, pad = 2, 8, 96, 9, "same"
    x = _rand((B, H, L), dtype, 0)
    k = _rand((H, K), dtype, 1)
    dy = _rand((B, H, L), dtype, 2)
    dx_want, dk_want = _vjp_ref(x, k, dy, pad)
    dx, dk = ops.dwconv_bwd_fused_op(x, dy, k, pad, "fused", SMALL_OPTS)
    np.testing.assert_allclose(np.asarray(dx, np.float32),
                               np.asarray(dx_want, np.float32),
                               atol=atol, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(dk, np.float32),
                               np.asarray(dk_want, np.float32),
                               atol=atol * 10, rtol=1e-2)


@pytest.mark.parametrize("variant", ["fused", "xla", "auto"])
@pytest.mark.parametrize("pad", ["same", "causal"])
@pytest.mark.parametrize("K", [5, 48])
def test_custom_vjp_fused_matches_autodiff(variant, pad, K):
    """The differentiable operator under the fused spec (and its residual
    reuse: the forward's padded xp feeds the backward) matches XLA grads."""
    x = _rand((2, 8, 64), jnp.float32, 0)
    k = _rand((8, K), jnp.float32, 1)
    spec = "fused" if variant == "fused" else variant

    def loss_custom(x, k):
        return jnp.sum(jnp.sin(dw.dwconv(x, k, padding=pad, variant=spec)))

    def loss_ref(x, k):
        return jnp.sum(jnp.sin(ref.dwconv_fwd_ref(x, k, pad)))

    gx, gk = jax.grad(loss_custom, argnums=(0, 1))(x, k)
    rx, rk = jax.grad(loss_ref, argnums=(0, 1))(x, k)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(rk), atol=1e-3)


def test_fwd_op_res_residual_layout():
    """The saved residual is the forward's own unified-Wpad padded buffer:
    left pad p_left of zeros, then x verbatim, wide enough for the fused
    backward's staged window."""
    from repro.kernels.common import pad_widths

    B, H, L, K = 2, 8, 48, 48
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    y, xp = ops.dwconv_fwd_op_res(x, k, "same", "row",
                                  ops.KernelOptions(interpret=True))
    p_left, _ = pad_widths(K, "same")
    assert xp is not None and xp.shape[-1] >= ops.bwd_fused_wpad(L, K)
    np.testing.assert_array_equal(np.asarray(xp[:, :H, p_left:p_left + L]),
                                  np.asarray(x))
    assert not np.asarray(xp[:, :H, :p_left]).any()
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.dwconv_fwd_ref(x, k, "same")),
                               atol=1e-4)
    # the reference forward materializes no padded buffer
    _, none_xp = ops.dwconv_fwd_op_res(x, k, "same", "xla")
    assert none_xp is None


def test_fused_split_escape_hatch():
    """variant='split' delegates to the two independent ops — the
    controlled per-path study survives the fused redesign."""
    B, H, L, K, pad = 2, 4, 48, 5, "same"
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    dy = _rand((B, H, L), jnp.float32, 2)
    dx, dk = ops.dwconv_bwd_fused_op(x, dy, k, pad, "split", SMALL_OPTS)
    np.testing.assert_allclose(
        np.asarray(dx), np.asarray(ref.dwconv_bwd_input_ref(dy, k, pad)), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(dk), np.asarray(ref.dwconv_bwd_kernel_ref(x, dy, K, pad)), atol=2e-3)
    with pytest.raises(ValueError):
        ops.dwconv_bwd_fused_op(None, dy, k, pad, "split", SMALL_OPTS)


def test_variant_registry_has_fused_spec():
    spec = get_variant("fused")
    assert spec.bwd == "fused" and spec.bwd_fused in ops.BWD_FUSED_VARIANTS
    assert get_variant("row").bwd == "split"   # default: study preserved
    assert get_variant("auto").bwd == "auto"


# ---------------------------------------------------------------------------
# bit-for-bit dk agreement with the split accum variant (f32 accumulation)
# ---------------------------------------------------------------------------


def _assert_dk_bitwise(B, H, L, K, pad, seed, opts):
    x = _rand((B, H, L), jnp.float32, seed)
    k = _rand((H, K), jnp.float32, seed + 1)
    dy = _rand((B, H, L), jnp.float32, seed + 2)
    _, dk_fused = ops.dwconv_bwd_fused_op(x, dy, k, pad, "fused", opts)
    dk_accum = ops.dwconv_bwd_kernel_op(x, dy, K, pad, "accum", opts)
    np.testing.assert_array_equal(np.asarray(dk_fused), np.asarray(dk_accum))


@pytest.mark.parametrize("B,H,L,K,pad", SHAPES[:5])
def test_fused_dk_bitwise_equals_accum(B, H, L, K, pad):
    """Identical slab shapes + identical sequential-chunk accumulation order
    => identical f32 bit patterns (not just allclose)."""
    _assert_dk_bitwise(B, H, L, K, pad, 0, SMALL_OPTS)


if hypothesis is not None:

    @hypothesis.given(
        st.integers(1, 4), st.integers(1, 10), st.integers(4, 96),
        st.integers(1, 12), st.sampled_from(["same", "causal"]),
        st.integers(0, 2**31 - 4),
    )
    @hypothesis.settings(max_examples=15, deadline=None)
    def test_property_fused_dk_bitwise_equals_accum(B, H, L, K, pad, seed):
        _assert_dk_bitwise(B, H, L, K, pad, seed, SMALL_OPTS)

else:

    def test_property_fused_dk_bitwise_requires_hypothesis():
        pytest.skip("hypothesis not installed — property test skipped")


# ---------------------------------------------------------------------------
# tuning-cache dispatch + schema bump
# ---------------------------------------------------------------------------


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    p = tmp_path / "cache.json"
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, str(p))
    tcache.reset_default_cache()
    yield p
    tcache.reset_default_cache()


def test_auto_selects_fused_through_cache(tmp_cache):
    """variant='auto' + a 'bwd_fused' cache entry => the fused backward runs
    inside the custom VJP (and still matches XLA autodiff)."""
    B, H, L, K = 2, 4, 48, 5
    tcache.default_cache().put(
        ShapeKey(path="bwd_fused", B=B, H=H, L=L, K=K, dtype="float32",
                 backend=jax.default_backend()),
        TuneEntry(variant="fused", block_h=2, block_t=512, batch_chunk=2))
    v, o = ops.resolve_variant("bwd_fused", "auto", None, B=B, H=H, L=L, K=K,
                               dtype=jnp.float32)
    assert v == "fused" and (o.block_h, o.batch_chunk) == (2, 2)

    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    ga = jax.grad(lambda x, k: jnp.sum(dw.dwconv(x, k, variant="auto") ** 2),
                  argnums=(0, 1))(x, k)
    gx = jax.grad(lambda x, k: jnp.sum(dw.dwconv(x, k, variant="xla") ** 2),
                  argnums=(0, 1))(x, k)
    for a, b in zip(ga, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_auto_without_entry_stays_split(tmp_cache):
    v, _ = ops.resolve_variant("bwd_fused", "auto", None, B=2, H=4, L=48, K=5,
                               dtype=jnp.float32)
    assert v == "split", "untuned shapes must keep the historical split backward"


def test_cache_v2_database_migrates_cleanly(tmp_path):
    """The schema bump (v3: bwd_fused path) must not discard a pre-existing
    v2 database: v2 entries are path-compatible and migrate verbatim; a v1
    (or unknown) version is still ignored."""
    key = ShapeKey(path="fwd", B=64, H=128, L=48, K=48, dtype="float32",
                   backend="cpu")
    entry = TuneEntry(variant="row", block_h=8, block_t=512, batch_chunk=128)
    p = tmp_path / "db.json"
    p.write_text(json.dumps({
        "version": 2,
        "entries": {key.encode(): entry.to_dict()},
    }))
    c = TuningCache(p)
    assert c.get(key) == entry, "v2 entry was not migrated"
    # a save rewrites the file at the current version, entries intact
    c.save()
    raw = json.loads(p.read_text())
    assert raw["version"] == tcache.CACHE_VERSION
    assert TuningCache(p).get(key) == entry

    p.write_text(json.dumps({"version": 1, "entries": {"bogus": {}}}))
    assert TuningCache(p).get(key) is None


def test_cache_v3_migration_drops_stale_tiled_bwd_decisions(tmp_path):
    """v3 predates block_t time tiling, which changed the bwd candidate
    space for every shape that admits a tile: staged winners were measured
    under untiled semantics, and even an 'xla' winner beat runners-up that
    no longer exist as such.  All bwd_k/bwd_fused entries on tileable
    shapes must be dropped on migration; short shapes (whose space is
    unchanged) and all fwd/bwd_in entries migrate verbatim."""
    stale = ShapeKey(path="bwd_k", B=8, H=64, L=4096, K=4, dtype="float32",
                     backend="cpu")          # tileable: staged winner stale
    stale_xla = ShapeKey(path="bwd_k", B=8, H=64, L=16384, K=4,
                         dtype="float32", backend="cpu")  # tileable: xla
    fresh = ShapeKey(path="bwd_k", B=64, H=128, L=48, K=48, dtype="float32",
                     backend="cpu")          # Lout <= min tile: unchanged
    fwd = ShapeKey(path="fwd", B=8, H=64, L=4096, K=4, dtype="float32",
                   backend="cpu")
    entry = TuneEntry(variant="accum", block_h=8, block_t=512, batch_chunk=8)
    xla_entry = TuneEntry(variant="xla", block_h=8, block_t=512, batch_chunk=128)
    fwd_entry = TuneEntry(variant="row", block_h=8, block_t=512, batch_chunk=128)
    p = tmp_path / "db.json"
    p.write_text(json.dumps({
        "version": 3,
        "entries": {stale.encode(): entry.to_dict(),
                    stale_xla.encode(): xla_entry.to_dict(),
                    fresh.encode(): entry.to_dict(),
                    fwd.encode(): fwd_entry.to_dict()},
    }))
    c = TuningCache(p)
    assert c.get(stale) is None, "stale tiled-semantics decision migrated"
    assert c.get(stale_xla) is None, "xla winner pins a tileable shape"
    assert c.get(fresh) == entry
    assert c.get(fwd) == fwd_entry


def test_checked_in_cache_loads_without_crash():
    """The repository's persistent database must survive the schema bump."""
    if not DEFAULT_CACHE_PATH.exists():
        pytest.skip("no checked-in tuning database")
    cache = TuningCache(DEFAULT_CACHE_PATH)
    assert len(cache) >= 0  # loading must not raise
    for k in cache.items():
        assert k.path in ("fwd", "bwd_in", "bwd_k", "bwd_fused")
