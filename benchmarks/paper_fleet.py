"""Fleet warm-start gate: signed-bundle distribution across replicas.

Runs the replica simulation (``repro.fleet.sim``) end-to-end in subprocess
replicas and gates the three fleet-cache properties the robustness work
promises:

  * **seed** — a cold replica tunes the shape and exports a signed bundle;
  * **warm** — a replica with an empty local cache and
    ``REPRO_TUNE_BUNDLE`` pointing at the bundle serves the shape with
    **zero** metered tuning candidates (``tune/candidate`` span count);
  * **chaos** — a replica fed a bit-flipped copy (byte mutated, signature
    re-used) rejects it with ``BundleIntegrityError``, records a
    degradation instead of crashing, and still serves *correctly* via
    fresh tuning.

Every replica also verifies its served output against the XLA reference,
so a warm start can never silently mean a wrong answer.  The promoted
``fleet_warm_metered_candidates`` metric must stay 0 in the perf ledger.
"""
from __future__ import annotations

import dataclasses
import tempfile
from typing import Dict, List, Optional

# Tiny shape: the gate proves the distribution protocol, not kernel speed,
# and CPU-interpret replicas re-execute kernel bodies in Python.
SIM_SHAPE = "2x4x48x5"


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def run(fast: bool = False) -> List[Row]:
    from repro.fleet import sim

    budget = 2 if fast else 4
    warm_n = 1 if fast else 2
    rows: List[Row] = []
    with tempfile.TemporaryDirectory(prefix="paper-fleet-") as workdir:
        res = sim.run_sim(SIM_SHAPE, workdir, warm_replicas=warm_n,
                          chaos=True, tune_budget=budget)

        seed_ok = res.seed["served_ok"] and res.seed["returncode"] == 0
        rows.append(Row(
            name="fleet_seed",
            us_per_call=0.0,
            derived=(f"tuned+exported metered={res.seed['metered_candidates']}"
                     if seed_ok else "FAILED: seed replica did not serve")))

        for r in res.warm:
            warm_ok = (r["served_ok"] and r["returncode"] == 0
                       and r["metered_candidates"] == 0)
            rows.append(Row(
                name=f"fleet_{r['replica']}",
                us_per_call=0.0,
                derived=("metered=0 WARM_OK" if warm_ok else
                         f"FAILED: metered={r['metered_candidates']} "
                         f"served_ok={r['served_ok']} rc={r['returncode']}")))

        c = res.chaos
        chaos_ok = (c is not None and c["served_ok"] and c["returncode"] == 0
                    and c["bundle_rejections"] > 0
                    and c["metered_candidates"] > 0)
        rows.append(Row(
            name="fleet_chaos_replica",
            us_per_call=0.0,
            derived=(f"rejected tampered bundle, tuned fresh "
                     f"(metered={c['metered_candidates']})" if chaos_ok else
                     f"FAILED: tampered bundle not handled ({c})")))
    return rows


def top_level_metrics(rows: List[Row]) -> Dict[str, Optional[float]]:
    """Warm replicas' total metered candidates — the ledger gate pins it
    at 0 (any tuning on a warm replica is a fleet-cache regression)."""
    metered = 0.0
    for r in rows:
        if r.name.startswith("fleet_warm") and "FAILED" in r.derived:
            return {"fleet_warm_metered_candidates": None}
        if r.name.startswith("fleet_warm"):
            metered += 0.0 if "metered=0" in r.derived else 1.0
    return {"fleet_warm_metered_candidates": metered}
