"""Counter-free report smoke: the ``repro.launch.report`` derivation as
benchmark rows, with structural gates.

Runs the full schedule-derived report at the paper's study shape (pure
derivation — no kernels execute, so ``--fast`` changes nothing) and gates
the paper's qualitative claims on it:

  * every reliable (variant x path) point lands in the memory-bound regime
    (Fig. 10's headline observation);
  * the fused epilogue moves strictly fewer whole-block bytes than the
    unfused composition for every epilogue key;
  * the paper-mode effective bandwidths stay monotone gmc < shared < warp
    on every path (Table III's trend).

A ``FAILED`` verdict in any row makes ``benchmarks/run.py`` exit nonzero.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.analysis.hw import TPU_V5E
from repro.analysis.paper_data import PAPER_DIMS
from repro.analysis.report import counter_free_report


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def run(fast: bool = False) -> List[Row]:
    payload = counter_free_report(PAPER_DIMS, hw=TPU_V5E)
    rows: List[Row] = []

    reliable = [r for r in payload["roofline"] if r["regime"] is not None]
    n_mem = sum(r["regime"] == "memory-bound" for r in reliable)
    for r in payload["roofline"]:
        bw = "N/A" if r["effective_bandwidth"] is None \
            else f"{r['effective_bandwidth'] / 1e9:.1f}GB/s"
        rows.append(Row(
            f"paper_report/roofline/{r['study']}/{r['path']}",
            r["runtime_s"] * 1e6,
            f"bytes={r['bytes_moved'] / 1e9:.3f}GB regime={r['regime'] or 'N/A'} "
            f"eff_bw={bw} (schedule-derived)"))
    verdict = "REPRODUCED" if n_mem == len(reliable) else "GATE_FAILED"
    rows.append(Row("paper_report/regime", 0.0,
                    f"memory_bound={n_mem}/{len(reliable)} reliable points {verdict}"))

    for r in payload["epilogue"]:
        ok = "GATE_OK" if r["ratio"] < 1.0 else "GATE_FAILED"
        rows.append(Row(
            f"paper_report/epilogue/{r['epilogue']}", 0.0,
            f"fused_vs_unfused_bytes={r['ratio']:.3f} {ok}"))

    by_path: Dict[str, List[float]] = {}
    for r in payload["paper"]:
        if r["effective_bandwidth"] is not None:
            by_path.setdefault(r["path"], []).append(r["effective_bandwidth"])
    monotone = all(bws == sorted(bws) for bws in by_path.values())
    rows.append(Row(
        "paper_report/table3_trend", 0.0,
        "paper-mode eff_bw monotone gmc<shared<warp "
        + ("REPRODUCED" if monotone else "GATE_FAILED")))
    return rows


def top_level_metrics(rows: List[Row]) -> Dict[str, float]:
    """``benchmarks/run.py`` hook: promote the report's regime census to
    top-level ``--json`` keys."""
    for r in rows:
        if r.name == "paper_report/regime":
            n_mem, total = r.derived.split()[0].split("=")[1].split("/")
            return {"report_memory_bound_fraction": float(n_mem) / float(total)}
    return {}


if __name__ == "__main__":
    import sys

    rows = run()
    for r in rows:
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
    if any("FAILED" in r.derived for r in rows):
        sys.exit(1)
