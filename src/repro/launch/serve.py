"""Serving launcher: batched greedy decoding with a sharded KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \\
      --batch 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, list_archs
from repro.distributed import sharding as shd
from repro.distributed.stepfn import build_serve_step
from repro.launch.mesh import make_mesh
from repro.models.api import get_model, make_demo_batch
from repro.obs import trace as obs_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="write the span trace (JSONL) here; phase timings "
                         "are read from the spans either way")
    ap.add_argument("--bundle", default="",
                    help="signed fleet tuning bundle (*.bundle.json) to "
                         "import before serving (warm start; validated + "
                         "degradation-guarded — a bad bundle logs a "
                         "BundleIntegrityError degradation and serving "
                         "proceeds with the local cache)")
    args = ap.parse_args(argv)

    if args.bundle:
        from repro.fleet import import_ as fleet_import
        from repro.tuning.cache import default_cache

        res = fleet_import.import_bundle_guarded(args.bundle,
                                                 cache=default_cache())
        print(f"[serve] bundle {args.bundle}: "
              f"{res.summary() if res else 'rejected; tuning fresh'}",
              flush=True)

    cfg = get_config(args.arch, smoke=args.smoke)
    # The prefill/decode numbers below are the spans' own measurements
    # (event-style: block_until_ready before the span closes, perf_counter
    # clock) — with --trace they are additionally persisted as JSONL.
    if args.trace:
        tracer = obs_trace.configure(args.trace, meta={"launcher": "serve",
                                                       "arch": cfg.name})
    else:
        tracer = obs_trace.Tracer(enabled=True)
    model = get_model(cfg)
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
    else:
        mesh = make_mesh((1, jax.device_count()), ("data", "model"))

    with mesh, shd.use_sharding(mesh, "serve"):
        params = model.init(jax.random.PRNGKey(args.seed))
        batch = make_demo_batch(cfg, args.batch, args.prompt_len)
        cache = model.init_cache(args.batch, args.cache_len)
        # enc-dec / vlm: precompute cross caches from the stub modality input
        if cfg.family == "encdec":
            from repro.models import encdec
            enc = encdec.encode(params, cfg, jnp.asarray(
                np.random.default_rng(0).normal(
                    size=(args.batch, cfg.encdec.enc_frames, cfg.d_model)), jnp.float32))
            ck, cv = encdec.precompute_cross_cache(params, cfg, enc)
            cache["cross_k"], cache["cross_v"] = ck, cv
        if cfg.family == "vlm":
            from repro.models import vlm
            ik, iv = vlm.precompute_img_cache(params, cfg, batch["img"])
            cache["img_k"], cache["img_v"] = ik, iv

        serve_step = jax.jit(build_serve_step(model), donate_argnums=(1,))
        # Warm-up on a throwaway cache: the step is shape-stable across
        # prefill and decode, so one call compiles it and neither phase's
        # timing is billed for jit compilation.  (The real cache cannot be
        # used — it is donated.)
        warm = model.init_cache(args.batch, args.cache_len)
        for key in ("cross_k", "cross_v", "img_k", "img_v"):
            if key in cache:
                # Copy, don't alias: serve_step donates its cache argument,
                # and donating a buffer the real cache still references
                # would invalidate it before prefill runs.
                warm[key] = jnp.copy(cache[key])
        jax.block_until_ready(
            serve_step(params, warm, {"tokens": batch["tokens"][:, :1]}))

        # prefill by teacher-forcing the prompt token by token (robust across
        # families); production prefill path is exercised by the dry-run.
        with tracer.span("serve/prefill", tokens=args.prompt_len - 1) as sp_pre:
            for i in range(args.prompt_len - 1):
                # unsynced: per-token prefill spans time the *enqueue* (the
                # dispatch floor); the phase span syncs and owns execution.
                with tracer.span("serve/prefill/token", pos=i):
                    _, cache = serve_step(
                        params, cache, {"tokens": batch["tokens"][:, i : i + 1]})
            sp_pre.sync(cache)
        t_prefill = sp_pre.dur_s

        # Decode continues from the *last* prompt token (tokens 0..P-2 are
        # already in the cache; feeding token P-1 predicts position P).
        tok = batch["tokens"][:, -1:]
        generated = []
        with tracer.span("serve/decode", tokens=args.gen) as sp_dec:
            for pos in range(args.gen):
                with tracer.span("serve/decode/token", pos=pos) as sp_tok:
                    nxt, cache = serve_step(params, cache, {"tokens": tok})
                    tok = nxt[:, None]
                    # np.asarray devices-to-host copies, which blocks on the
                    # step — the per-token span time is the real step latency.
                    generated.append(np.asarray(tok))
                    sp_tok.sync(tok)
            sp_dec.sync(tok)
        t_decode = sp_dec.dur_s
    # --gen 0 is a legitimate prefill-only measurement: keep shapes valid.
    gen = (np.concatenate(generated, axis=1) if generated
           else np.zeros((args.batch, 0), np.int64))
    prefill_toks = args.batch * (args.prompt_len - 1)
    decode_toks = args.batch * gen.shape[1]
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill {args.prompt_len - 1} tok/seq in {t_prefill:.2f}s "
          f"({prefill_toks / max(t_prefill, 1e-9):.1f} tok/s)")
    print(f"[serve] decode {gen.shape[1]} tok/seq in {t_decode:.2f}s "
          f"({decode_toks / max(t_decode, 1e-9):.1f} tok/s)")
    print("[serve] sample token ids:", gen[0].tolist())
    if args.trace:
        tracer.close()
        print(f"[serve] trace written to {args.trace} "
              f"({len(tracer.records)} records)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
