"""Elastic supervising launcher — the 1000+-node fault-tolerance story.

On a real cluster each host runs this supervisor around the training
process.  It provides:

  * **crash-restart**: the train loop runs as a child process; non-zero
    exits trigger a restart from the latest checkpoint (bounded retries,
    exponential backoff);
  * **elasticity**: on restart the supervisor re-reads the healthy-host
    count and passes a (possibly smaller/larger) data-axis size; training
    resumes because checkpoints are mesh-independent (see
    ``repro.checkpoint``) and the batch is re-sharded by the rule table;
  * **straggler watchdog**: the child writes a heartbeat file every step;
    an EWMA of step times flags hosts slower than ``straggler_factor`` x
    the median — on a cluster, the supervisor would report the host for
    replacement (here: logged + surfaced in the exit report).

This module is fully functional on one host (tests exercise crash-restart
and heartbeat flagging with a toy child) and is the documented deployment
pattern for multi-pod runs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path
from typing import List, Optional, Sequence

from repro.resilience import faults


@dataclasses.dataclass
class SupervisorConfig:
    cmd: Sequence[str]
    heartbeat_path: str
    max_restarts: int = 5
    backoff_s: float = 1.0
    backoff_max_s: float = 60.0
    heartbeat_timeout_s: float = 600.0
    straggler_factor: float = 2.0


@dataclasses.dataclass
class StepBeat:
    step: int
    t: float
    step_time_s: float


class Heartbeat:
    """Written by the training loop; read by the supervisor."""

    def __init__(self, path: str):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._last_t: Optional[float] = None
        self._ewma: Optional[float] = None

    def beat(self, step: int) -> None:
        if faults.should_fire("heartbeat/stall"):
            # Injected stall: the loop *thinks* it beat but nothing reaches
            # the supervisor — exactly what a hung filesystem or a wedged
            # writer thread looks like from the watchdog's side.
            return
        now = time.time()
        dt = (now - self._last_t) if self._last_t is not None else 0.0
        self._last_t = now
        self._ewma = dt if self._ewma is None else 0.9 * self._ewma + 0.1 * dt
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {"step": step, "t": now, "step_time_s": dt, "ewma_s": self._ewma}))
        os.replace(tmp, self.path)

    @staticmethod
    def read(path: str) -> Optional[dict]:
        p = Path(path)
        if not p.exists():
            return None
        try:
            return json.loads(p.read_text())
        except (json.JSONDecodeError, OSError):
            return None


def detect_stragglers(beats: List[dict], factor: float = 2.0) -> List[int]:
    """Given per-host heartbeat dicts, return indices of straggler hosts
    (EWMA step time > factor x median)."""
    times = [b.get("ewma_s", 0.0) or 0.0 for b in beats]
    valid = sorted(t for t in times if t > 0)
    if not valid:
        return []
    median = valid[len(valid) // 2]
    if median <= 0:
        return []
    return [i for i, t in enumerate(times) if t > factor * median]


class Supervisor:
    def __init__(self, cfg: SupervisorConfig):
        self.cfg = cfg
        self.restarts = 0
        self.events: List[str] = []

    def _log(self, msg: str):
        self.events.append(msg)
        print(f"[supervisor] {msg}", flush=True)

    def run(self, extra_env: Optional[dict] = None) -> int:
        backoff = self.cfg.backoff_s
        while True:
            env = dict(os.environ)
            env.update(extra_env or {})
            env["REPRO_RESTART_COUNT"] = str(self.restarts)
            self._log(f"launching attempt {self.restarts + 1}: {' '.join(self.cfg.cmd)}")
            launched_at = time.time()
            proc = subprocess.Popen(list(self.cfg.cmd), env=env)
            rc = self._watch(proc, launched_at)
            if rc == 0:
                self._log("child exited cleanly")
                return 0
            self.restarts += 1
            if self.restarts > self.cfg.max_restarts:
                self._log(f"giving up after {self.restarts - 1} restarts (rc={rc})")
                return rc
            self._log(f"child failed rc={rc}; restarting from latest checkpoint "
                      f"in {backoff:.1f}s")
            time.sleep(backoff)
            backoff = min(backoff * 2, self.cfg.backoff_max_s)

    def _watch(self, proc: subprocess.Popen, launched_at: float) -> int:
        hb = self.cfg.heartbeat_path
        while True:
            try:
                return proc.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                pass
            beat = Heartbeat.read(hb)
            # A beat older than this child's launch belongs to a *previous*
            # incarnation: judging the fresh child by it would SIGKILL every
            # restart whose predecessor hung (the stale file just sits
            # there), turning one hang into an unrecoverable kill loop.  The
            # fresh child's own silence is covered by the same timeout,
            # measured from launch.
            if beat is not None and beat.get("t", 0) < launched_at:
                beat = None
            ref_t = beat.get("t", launched_at) if beat is not None else launched_at
            stale = time.time() - ref_t
            if stale > self.cfg.heartbeat_timeout_s:
                self._log(f"heartbeat stale {stale:.0f}s (hung step?) — killing child")
                proc.send_signal(signal.SIGKILL)
                return proc.wait() or 1
