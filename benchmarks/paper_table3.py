"""Paper Table III analogue: counter-free effective-bandwidth estimates.

Feeds the paper's *published* Table II runtimes through this framework's
analytical traffic model (paper-mode accounting) and reports the recovered
effective bandwidths next to the paper's published values — validating that
the counter-free pipeline reproduces the paper's Table III trend (naive N/A;
monotone increase gmc -> shared -> warp; all far below the 732 GB/s peak).
"""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.paper_constants import PAPER_DIMS, TABLE2_MS, TABLE3_GBPS
from repro.analysis.bandwidth import effective_bandwidth
from repro.analysis.hw import P100
from repro.analysis.traffic import paper_bwdk_traffic, paper_fwd_traffic, paper_total_traffic


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def run(fast: bool = False) -> List[Row]:
    rows: List[Row] = []
    prev_bw = 0.0
    for variant, (fwd_ms, bin_ms, bk_ms, total_ms, _) in TABLE2_MS.items():
        est_fwd = paper_fwd_traffic(PAPER_DIMS, variant)
        if not est_fwd.reliable:
            rows.append(Row(f"paper_table3/{variant}", total_ms * 1e3,
                            "eff_bw=N/A (redundant traffic unobservable, as in paper)"))
            continue
        total_bytes = paper_total_traffic(PAPER_DIMS, variant)
        runtime_s = total_ms / 1e3
        bw = total_bytes / runtime_s
        util = bw / P100.hbm_bw
        published = TABLE3_GBPS[variant]
        ratio = bw / (published * 1e9) if published else float("nan")
        assert bw > prev_bw, "effective bandwidth must increase down the table"
        prev_bw = bw
        rows.append(Row(
            f"paper_table3/{variant}", total_ms * 1e3,
            f"eff_bw={bw / 1e9:.1f}GB/s util={util * 100:.1f}% "
            f"paper={published:.0f}GB/s ratio={ratio:.2f}",
        ))
    # trend check: ordering must match the paper's (gmc < shared < warp)
    rows.append(Row("paper_table3/trend", 0.0,
                    "monotone gmc<shared<warp REPRODUCED; naive N/A REPRODUCED"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
