"""Pallas TPU kernels — the *fused* backward pass (dx and dk in one sweep).

The split backward runs two independent ops: the input-gradient path pads
``dy`` into an adjoint layout and re-runs the forward kernels with a flipped
filter, then the weight-gradient path pads ``dy`` *again* (into a different
layout) and re-reads the freshly re-padded ``x``.  Every operand therefore
crosses HBM twice and three distinct padded layouts are materialized.

These kernels stage ``x_pad`` and ``dy`` in VMEM **once** per
(h-block x batch-chunk) grid cell and compute *both* gradients from the
shared slab:

    dx[b,h,s] = sum_j dy_pad[b,h,s+j] * k[h,K-1-j]     (flipped-filter taps)
    dk[h,j]   = sum_{b,t} dy[b,h,t] * x_pad[b,h,t+j]   (tap partials)

A single ``dy`` layout serves both: ``dy`` is padded with ``p_right`` zeros
on the left (the adjoint layout), so the dx taps read it at offset ``j`` and
the dk reduction reads the un-shifted window at static offset
``off_dk = p_right``.  Two family members mirror the weight-gradient study:

  fused          : dk accumulates in-place into a revisited output block
                   across the sequential batch-chunk grid (the ``accum``
                   structure); dx blocks are written per cell.
  fused_partials : per-chunk dk partials round-trip HBM and a second jnp
                   reduction combines them (the ``twostage`` structure).

Inputs arrive pre-padded from ``ops.py``:
  xp  (B, H, >=Wk) with ``p_left`` forward padding — the *forward's own*
      padded residual is accepted verbatim (its unified Wpad is a superset
      of the ``Wk = round_up(round_up(L,LANE) + K - 1, LANE)`` window the
      BlockSpecs slice);
  dyp (B, H, Wk)   with ``p_right`` adjoint padding;
  kp  (H, Kp)      lane-padded filters.
Outputs: dx (B, H, Lout) in dy's dtype and dk (H, Kp) in f32; ``ops.py``
slices both back to logical shapes.  Accumulation is f32; the dk partials
are computed with the *same* slab shapes as ``dwconv_bwdk``'s staged
variants, so fused dk matches the ``accum`` variant bit-for-bit.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dwconv_bwdk import _taps_from_slabs


def _dx_from_slab(dy32: jnp.ndarray, kv: jnp.ndarray, K: int, Lout: int) -> jnp.ndarray:
    """(Bc, Hb, >=Lout+K-1) adjoint-padded dy slab -> dx taps (Bc, Hb, Lout)."""
    acc = jnp.zeros(dy32.shape[:2] + (Lout,), jnp.float32)
    for j in range(K):  # static unroll: flipped-filter multiply-adds from VMEM
        acc = acc + dy32[:, :, j : j + Lout] * kv[:, K - 1 - j][None, :, None]
    return acc


# ---------------------------------------------------------------------------
# fused (accum-style): sequential-grid in-place dk accumulation
# ---------------------------------------------------------------------------


def _fused_accum_kernel(
    x_ref, dy_ref, k_ref, dx_ref, dk_ref, *, K: int, Kp: int, Lout: int, off_dk: int
):
    c = pl.program_id(1)  # batch-chunk index — innermost, sequential

    @pl.when(c == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)

    # Both operand slabs staged once; every tap of BOTH gradients reads VMEM.
    x32 = x_ref[...].astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    kv = k_ref[...].astype(jnp.float32)
    dx_ref[...] = _dx_from_slab(dy32, kv, K, Lout).astype(dx_ref.dtype)
    dy_win = dy32[:, :, off_dk : off_dk + Lout]  # forward-aligned window
    dk_ref[...] += _taps_from_slabs(x32, dy_win, K, Kp).astype(dk_ref.dtype)


def dwconv_bwd_fused_accum(
    xp: jnp.ndarray,
    dyp: jnp.ndarray,
    kp: jnp.ndarray,
    *,
    K: int,
    Lout: int,
    off_dk: int,
    block_w: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One staged pass -> (dx (B, H, Lout), dk (H, Kp) f32)."""
    B, H, Wx = xp.shape
    _, Kp = kp.shape
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    assert B % Bc == 0 and H % Hb == 0, (B, Bc, H, Hb)
    assert Wx >= block_w and dyp.shape[-1] >= block_w, (Wx, dyp.shape, block_w)
    assert block_w >= Lout + K - 1 >= off_dk + Lout, (block_w, Lout, K, off_dk)
    grid = (H // Hb, B // Bc)
    return pl.pallas_call(
        functools.partial(_fused_accum_kernel, K=K, Kp=Kp, Lout=Lout, off_dk=off_dk),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Lout), dyp.dtype),
            jax.ShapeDtypeStruct((H, Kp), jnp.float32),
        ],
        grid=grid,
        in_specs=[
            # Width block_w slices the staged window out of a possibly wider
            # forward residual — the reuse is free, not a re-pad.
            pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Bc, Hb, Lout), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        ],
        interpret=interpret,
    )(xp, dyp, kp)


# ---------------------------------------------------------------------------
# fused_partials (twostage-style): HBM dk partials + second reduction stage
# ---------------------------------------------------------------------------


def _fused_partials_kernel(
    x_ref, dy_ref, k_ref, dx_ref, part_ref, *, K: int, Kp: int, Lout: int, off_dk: int
):
    x32 = x_ref[...].astype(jnp.float32)
    dy32 = dy_ref[...].astype(jnp.float32)
    kv = k_ref[...].astype(jnp.float32)
    dx_ref[...] = _dx_from_slab(dy32, kv, K, Lout).astype(dx_ref.dtype)
    dy_win = dy32[:, :, off_dk : off_dk + Lout]
    part_ref[0] = _taps_from_slabs(x32, dy_win, K, Kp)


def dwconv_bwd_fused_partials(
    xp: jnp.ndarray,
    dyp: jnp.ndarray,
    kp: jnp.ndarray,
    *,
    K: int,
    Lout: int,
    off_dk: int,
    block_w: int,
    block_h: int = 8,
    batch_chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Staged pass with explicit per-chunk dk partials -> (dx, dk)."""
    B, H, Wx = xp.shape
    _, Kp = kp.shape
    Hb = min(block_h, H)
    Bc = min(batch_chunk, B)
    assert B % Bc == 0 and H % Hb == 0, (B, Bc, H, Hb)
    assert Wx >= block_w and dyp.shape[-1] >= block_w, (Wx, dyp.shape, block_w)
    assert block_w >= Lout + K - 1 >= off_dk + Lout, (block_w, Lout, K, off_dk)
    nC = B // Bc
    grid = (H // Hb, nC)
    dx, partials = pl.pallas_call(
        functools.partial(_fused_partials_kernel, K=K, Kp=Kp, Lout=Lout, off_dk=off_dk),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Lout), dyp.dtype),
            jax.ShapeDtypeStruct((nC, H, Kp), jnp.float32),
        ],
        grid=grid,
        in_specs=[
            pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Bc, Hb, block_w), lambda h, c: (c, h, 0)),
            pl.BlockSpec((Hb, Kp), lambda h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Bc, Hb, Lout), lambda h, c: (c, h, 0)),
            pl.BlockSpec((1, Hb, Kp), lambda h, c: (c, h, 0)),
        ],
        interpret=interpret,
    )(xp, dyp, kp)
    return dx, jnp.sum(partials, axis=0)  # second reduction stage
