"""Shared transformer/SSM layer primitives for the architecture pool.

Everything is written scan-over-layers friendly (params stacked on a leading
``layers`` axis by the model builders) and annotated with logical sharding
axes via ``repro.distributed.sharding.shard``.

Attention supports, in one implementation: GQA/MQA (n_kv <= n_heads),
causal + sliding-window masks (window as a *traced* per-layer scalar so
heterogeneous local:global stacks scan), optional QKV bias (qwen2), optional
QK-norm (gemma3), per-layer RoPE theta (gemma3 local vs global), cross
attention (whisper decoder, VLM), KV-cache decode, and an online-softmax
*chunked* mode for long sequences (the paper's staging insight applied at
the attention level: bounded on-chip working set instead of an S x S score
matrix).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(rng: jax.Array, in_dim: int, *out_shape: int, scale: float = 1.0) -> jnp.ndarray:
    shape = (in_dim, *out_shape)
    std = scale / math.sqrt(in_dim)
    return (jax.random.normal(rng, shape, dtype=jnp.float32) * std)


def embed_init(rng: jax.Array, vocab: int, dim: int) -> jnp.ndarray:
    return jax.random.normal(rng, (vocab, dim), dtype=jnp.float32) * 0.01


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (theta may be a traced per-layer scalar)
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """x: (..., S, n, head_dim); positions: (..., S) int32."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq_exp = jnp.arange(half, dtype=jnp.float32) / half
    theta = jnp.asarray(theta, jnp.float32)
    inv_freq = 1.0 / (theta ** freq_exp)                       # (half,)
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., S, half)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(S: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((S, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    chunk_q: int = 0       # 0 -> unchunked
    chunk_kv: int = 2048
    causal: bool = True


def init_attention(rng, d_model: int, dims: AttnDims) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(rng, 6)
    p = {
        "wq": dense_init(ks[0], d_model, dims.n_heads, dims.head_dim),
        "wk": dense_init(ks[1], d_model, dims.n_kv, dims.head_dim),
        "wv": dense_init(ks[2], d_model, dims.n_kv, dims.head_dim),
        "wo": dense_init(ks[3], dims.n_heads * dims.head_dim, d_model),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((dims.n_heads, dims.head_dim))
        p["bk"] = jnp.zeros((dims.n_kv, dims.head_dim))
        p["bv"] = jnp.zeros((dims.n_kv, dims.head_dim))
    if dims.qk_norm:
        p["q_norm"] = jnp.zeros((dims.head_dim,))
        p["k_norm"] = jnp.zeros((dims.head_dim,))
    return p


def attention_param_axes(dims: AttnDims) -> Dict[str, tuple]:
    axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "embed"),
    }
    if dims.qkv_bias:
        axes.update(bq=("heads", "head_dim"), bk=("kv_heads", "head_dim"), bv=("kv_heads", "head_dim"))
    if dims.qk_norm:
        axes.update(q_norm=("head_dim",), k_norm=("head_dim",))
    return axes


def _project_qkv(p, x, kv_x, dims: AttnDims):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dnh->bsnh", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", kv_x, p["wv"].astype(x.dtype))
    if dims.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if dims.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _mask_bias(q_pos, kv_pos, window, causal: bool, valid_len=None):
    """Additive mask (..., Sq, Skv).  ``window``: traced scalar; <= 0 means
    unbounded.  ``valid_len``: mask out cache positions >= valid_len."""
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    w = jnp.asarray(window)
    ok &= (w <= 0) | (d < w)
    if valid_len is not None:
        ok &= kv_pos[..., None, :] < valid_len
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _expand_bias(bias: Optional[jnp.ndarray]) -> Optional[jnp.ndarray]:
    """Normalize a (Sq,Skv) or (B,Sq,Skv) mask to broadcast over
    scores of shape (B, N, Sq, Skv)."""
    if bias is None:
        return None
    if bias.ndim == 2:
        return bias[None, None, :, :]
    if bias.ndim == 3:
        return bias[:, None, :, :]
    return bias


def _repeat_kv(k, G: int):
    """Replicate KV heads to the full head count (TP-friendly GQA layout:
    merged-head scores shard over `model`; separated (kv, group) dims would
    each be smaller than the axis and fall back to replication)."""
    return jnp.repeat(k, G, axis=2) if G > 1 else k


def _sdpa(q, k, v, bias, dims: AttnDims, seq_sharded: bool = False):
    """Scaled-dot-product attention, merged-head GQA.
    q: (B,Sq,N,H); k,v: (B,Skv,Nkv,H).  ``seq_sharded``: decode-time KV cache
    sharded along sequence -> annotate scores so GSPMD derives the
    flash-decoding partial-softmax combine (psum over `model`)."""
    B, Sq, N, H = q.shape
    G = N // dims.n_kv
    k = _repeat_kv(k, G)
    v = _repeat_kv(v, G)
    # f32 accumulation directly out of the MXU: avoids materializing a bf16
    # scores tensor AND an f32 convert copy (§Perf iteration A2).
    scores = jnp.einsum("bqnh,bsnh->bnqs", q * (H ** -0.5), k,
                        preferred_element_type=jnp.float32)
    if seq_sharded:
        scores = shard(scores, "act_batch", None, None, "cache_seq")
    else:
        scores = shard(scores, "act_batch", "act_heads", "act_attn_q", None)
    bias = _expand_bias(bias)
    if bias is not None:
        scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    if seq_sharded:
        probs = shard(probs, "act_batch", None, None, "cache_seq")
    else:
        probs = shard(probs, "act_batch", "act_heads", "act_attn_q", None)
    out = jnp.einsum("bnqs,bsnh->bqnh", probs, v)
    return out


def _sdpa_chunked(q, k, v, q_pos, kv_pos, window, dims: AttnDims, valid_len=None):
    """Online-softmax over KV chunks: O(Sq x chunk) live scores."""
    B, Sq, N, H = q.shape
    Skv = k.shape[1]
    C = min(dims.chunk_kv, Skv)
    nC = (Skv + C - 1) // C
    assert Skv % C == 0, (Skv, C)
    G = N // dims.n_kv
    k = _repeat_kv(k, G)
    v = _repeat_kv(v, G)
    qs = q * (H ** -0.5)

    kc = k.reshape(B, nC, C, N, H).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nC, C, N, H).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(nC, C) if kv_pos.ndim == 1 else kv_pos.reshape(B, nC, C).transpose(1, 0, 2)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        s = jnp.einsum("bqnh,bsnh->bnqs", qs, kb).astype(jnp.float32)
        s = shard(s, "act_batch", "act_heads", "act_attn_q", None)
        bias = _expand_bias(_mask_bias(q_pos, pb, window, dims.causal, valid_len))
        s = s + bias
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bnqs,bsnh->bnqh", p.astype(q.dtype), vb).astype(jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), ()

    m0 = jnp.full((B, N, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, N, Sq), jnp.float32)
    a0 = jnp.zeros((B, N, Sq, H), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).transpose(0, 2, 1, 3)


def attention(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    dims: AttnDims,
    *,
    positions: Optional[jnp.ndarray] = None,
    rope_theta=None,
    window=0,
    kv_x: Optional[jnp.ndarray] = None,      # cross attention source
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    use_chunked: bool = False,
    return_kv: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Returns (output (B,S,D), updated cache / (k, v) / None)."""
    B, S, D = x.shape
    cross = kv_x is not None
    q, k, v = _project_qkv(p, x, kv_x if cross else x, dims)
    q = shard(q, "act_batch", "act_seq", "act_heads", None)
    k = shard(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = shard(v, "act_batch", "act_seq", "act_kv_heads", None)

    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    if rope_theta is not None and not cross:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)

    new_cache = None
    if cache is not None and not cross:
        # decode: write new kv at cache_pos, attend over the whole cache
        ck, cv = cache["k"], cache["v"]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
        ck = shard(ck, "cache_batch", "cache_seq", "cache_kv_heads", None)
        cv = shard(cv, "cache_batch", "cache_seq", "cache_kv_heads", None)
        new_cache = {"k": ck, "v": cv}
        kv_pos = jnp.arange(ck.shape[1], dtype=jnp.int32)
        bias = _mask_bias(positions, kv_pos, window, dims.causal, valid_len=cache_pos + S)
        out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), bias, dims,
                    seq_sharded=True)
    else:
        if cross:
            out = _sdpa(q, k, v, None, dims)
        elif use_chunked:
            out = _sdpa_chunked(q, k, v, positions, jnp.arange(k.shape[1], dtype=jnp.int32),
                                window, dims)
        else:
            bias = _mask_bias(positions[0], jnp.arange(k.shape[1], dtype=jnp.int32),
                              window, dims.causal)
            out = _sdpa(q, k, v, bias, dims)

    out = out.reshape(B, S, dims.n_heads * dims.head_dim)
    y = jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(x.dtype))
    y = shard(y, "act_batch", "act_seq", "act_embed")
    if return_kv:
        return y, (k, v)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP (dense + GLU variants)
# ---------------------------------------------------------------------------


def init_mlp(rng, d_model: int, d_ff: int, gated: bool = True) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(rng, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff), "w_down": dense_init(ks[1], d_ff, d_model)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff)
    return p


def mlp_param_axes(gated: bool = True) -> Dict[str, tuple]:
    axes = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    if gated:
        axes["w_gate"] = ("embed", "mlp")
    return axes


def mlp(p: Dict[str, jnp.ndarray], x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "gelu_tanh": lambda v: jax.nn.gelu(v, approximate=True)}[act]
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    up = shard(up, "act_batch", "act_seq", "act_mlp")
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        up = actf(gate) * up
    else:
        up = actf(up)
    y = jnp.einsum("bsf,fd->bsd", up, p["w_down"].astype(x.dtype))
    return shard(y, "act_batch", "act_seq", "act_embed")


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    out = jnp.take(table.astype(dtype), tokens, axis=0)
    return shard(out, "act_batch", "act_seq", "act_embed")


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    return shard(logits, "act_batch", "act_seq", "act_vocab")
