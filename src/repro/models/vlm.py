"""llama-3.2-vision-11b backbone — llama3-style text stack with gated
cross-attention layers interleaved every 5th layer (8 cross in 40).

Per the assignment the vision tower is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_img_tokens, d_model).  Cross layers use
tanh-gated residuals (zero-init, as in the released checkpoints) so the
backbone starts text-equivalent.

Scan structure: 8 stacked superblocks of (4 self-attn layers + 1 cross
layer); the inner 4 self layers are themselves a stacked scan.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.train.losses import softmax_cross_entropy


def _n_blocks(cfg: ArchConfig):
    per = cfg.vlm.cross_every
    assert cfg.n_layers % per == 0
    return cfg.n_layers // per, per - 1  # (superblocks, self layers per block)


def _init_cross_layer(rng, cfg: ArchConfig):
    k1, k2 = jax.random.split(rng)
    return {
        "attn": L.init_attention(k1, cfg.d_model, T.attn_dims(cfg)),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=True),
        "ln1": jnp.zeros((cfg.d_model,)),
        "ln2": jnp.zeros((cfg.d_model,)),
        "kv_norm": jnp.zeros((cfg.d_model,)),
        "gate_attn": jnp.zeros(()),
        "gate_mlp": jnp.zeros(()),
    }


def _init_superblock(rng, cfg: ArchConfig):
    nb, n_self = _n_blocks(cfg)
    k1, k2 = jax.random.split(rng)
    self_keys = jax.random.split(k1, n_self)
    return {
        "self": jax.vmap(lambda r: T._init_layer(r, cfg))(self_keys),
        "cross": _init_cross_layer(k2, cfg),
    }


def init(rng, cfg: ArchConfig):
    nb, _ = _n_blocks(cfg)
    ks = jax.random.split(rng, 3)
    keys = jax.random.split(ks[0], nb)
    params = {
        "embed": L.embed_init(ks[1], cfg.vocab, cfg.d_model),
        "blocks": jax.vmap(lambda r: _init_superblock(r, cfg))(keys),
        "ln_f": jnp.zeros((cfg.d_model,)),
        "unembed": L.embed_init(ks[2], cfg.vocab, cfg.d_model),
    }
    return jax.tree.map(lambda x: x.astype(cfg.param_dt), params)


def param_axes(cfg: ArchConfig):
    # inner self stack adds one more leading stacked axis (superblock, layer)
    inner = {
        "attn": {k: (None, None) + v for k, v in L.attention_param_axes(T.attn_dims(cfg)).items()},
        "mlp": {k: (None, None) + v for k, v in L.mlp_param_axes(True).items()},
        "ln1": (None, None, "embed"),
        "ln2": (None, None, "embed"),
    }
    cross = {
        "attn": {k: (None,) + v for k, v in L.attention_param_axes(T.attn_dims(cfg)).items()},
        "mlp": {k: (None,) + v for k, v in L.mlp_param_axes(True).items()},
        "ln1": (None, "embed"), "ln2": (None, "embed"), "kv_norm": (None, "embed"),
        "gate_attn": (None,), "gate_mlp": (None,),
    }
    return {
        "embed": ("vocab", "embed"),
        "blocks": {"self": inner, "cross": cross},
        "ln_f": ("embed",),
        "unembed": ("vocab", "embed"),
    }


def _cross_layer(lp, cfg: ArchConfig, x, img):
    dims = T.attn_dims(cfg)
    h = L.rms_norm(x, lp["ln1"])
    kv = L.rms_norm(img, lp["kv_norm"])
    a, _ = L.attention(lp["attn"], h, dims, kv_x=kv)
    x = x + jnp.tanh(lp["gate_attn"].astype(x.dtype)) * a
    h = L.rms_norm(x, lp["ln2"])
    x = x + jnp.tanh(lp["gate_mlp"].astype(x.dtype)) * L.mlp(lp["mlp"], h, cfg.act)
    return shard(x, "act_batch", "act_seq", "act_embed")


def forward(params, cfg: ArchConfig, tokens: jnp.ndarray, img: jnp.ndarray):
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    img = img.astype(cfg.compute_dt)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    use_chunked = S >= cfg.attn_chunk_threshold

    def inner_body(x, lp):
        return T._layer_body(cfg, x, lp, 0, cfg.rope_theta, positions, use_chunked), ()

    def body(x, sb):
        x, _ = jax.lax.scan(inner_body, x, sb["self"])
        x = _cross_layer(sb["cross"], cfg, x, img)
        return x, ()

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    return L.rms_norm(x, params["ln_f"])


def loss_fn(params, cfg: ArchConfig, batch) -> jnp.ndarray:
    hidden = forward(params, cfg, batch["tokens"], batch["img"])
    logits = L.unembed(hidden, params["unembed"])
    return softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or cfg.compute_dt
    nb, n_self = _n_blocks(cfg)
    shape = (nb, n_self, batch, cache_len, cfg.n_kv, cfg.head_dim)
    cross = (nb, batch, cfg.vlm.n_img_tokens, cfg.n_kv, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
        "img_k": jnp.zeros(cross, dtype), "img_v": jnp.zeros(cross, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg: ArchConfig):
    kv = ("layers", None, "cache_batch", "cache_seq", "cache_kv_heads", None)
    ckv = ("layers", "cache_batch", None, "cache_kv_heads", None)
    return {"k": kv, "v": kv, "img_k": ckv, "img_v": ckv, "pos": ()}


def precompute_img_cache(params, cfg: ArchConfig, img: jnp.ndarray):
    dims = T.attn_dims(cfg)

    def body(_, sb):
        lp = sb["cross"]
        kvx = L.rms_norm(img.astype(cfg.compute_dt), lp["kv_norm"])
        _, (k, v) = L.attention(lp["attn"], kvx[:, :1, :], dims, kv_x=kvx, return_kv=True)
        return (), (k.astype(cfg.compute_dt), v.astype(cfg.compute_dt))

    _, (ik, iv) = jax.lax.scan(body, (), params["blocks"])
    return ik, iv


def decode_step(params, cfg: ArchConfig, cache, tokens: jnp.ndarray):
    B, S = tokens.shape
    pos = cache["pos"]
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    positions = jnp.broadcast_to(pos[None, None] + jnp.arange(S, dtype=jnp.int32), (B, S))
    dims = T.attn_dims(cfg)

    def inner_body(x, inp):
        lp, ck, cv = inp
        h = L.rms_norm(x, lp["ln1"])
        a, nc = L.attention(lp["attn"], h, dims, positions=positions,
                            rope_theta=cfg.rope_theta,
                            cache={"k": ck, "v": cv}, cache_pos=pos)
        x = x + a
        x = x + L.mlp(lp["mlp"], L.rms_norm(x, lp["ln2"]), cfg.act)
        return x, (nc["k"], nc["v"])

    def body(x, inp):
        sb, ck, cv, ik, iv = inp
        x, (nk, nv) = jax.lax.scan(inner_body, x, (sb["self"], ck, cv))
        lp = sb["cross"]
        h = L.rms_norm(x, lp["ln1"])
        q = jnp.einsum("bsd,dnh->bsnh", h, lp["attn"]["wq"].astype(h.dtype))
        out = L._sdpa(q, ik.astype(q.dtype), iv.astype(q.dtype), None, dims)
        c = jnp.einsum("bsf,fd->bsd", out.reshape(B, S, cfg.n_heads * cfg.head_dim),
                       lp["attn"]["wo"].astype(h.dtype))
        x = x + jnp.tanh(lp["gate_attn"].astype(x.dtype)) * c
        h = L.rms_norm(x, lp["ln2"])
        x = x + jnp.tanh(lp["gate_mlp"].astype(x.dtype)) * L.mlp(lp["mlp"], h, cfg.act)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"], cache["img_k"], cache["img_v"]))
    hidden = L.rms_norm(x, params["ln_f"])
    logits = L.unembed(hidden, params["unembed"])
    return logits, dict(cache, k=nk, v=nv, pos=pos + S)


def prefill(params, cfg: ArchConfig, tokens: jnp.ndarray, img: jnp.ndarray = None):
    """Prefill with self-attn KV caches + precomputed image cross K/V."""
    B, S = tokens.shape
    if img is None:
        img = jnp.zeros((B, cfg.vlm.n_img_tokens, cfg.d_model), cfg.compute_dt)
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    use_chunked = S >= cfg.attn_chunk_threshold
    dims = T.attn_dims(cfg)

    def inner_body(x, lp):
        h = L.rms_norm(x, lp["ln1"])
        a, (k, v) = L.attention(lp["attn"], h, dims, positions=positions,
                                rope_theta=cfg.rope_theta, use_chunked=use_chunked,
                                return_kv=True)
        x = x + a
        x = x + L.mlp(lp["mlp"], L.rms_norm(x, lp["ln2"]), cfg.act)
        return x, (k.astype(cfg.compute_dt), v.astype(cfg.compute_dt))

    def body(x, sb):
        x, (k, v) = jax.lax.scan(inner_body, x, sb["self"])
        x = _cross_layer(sb["cross"], cfg, x, img)
        return x, (k, v)

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(body_fn, x, params["blocks"])
    ik, iv = precompute_img_cache(params, cfg, img)
    hidden = L.rms_norm(x, params["ln_f"])
    logits = L.unembed(hidden[:, -1:, :], params["unembed"])
    cache = {"k": ks, "v": vs, "img_k": ik, "img_v": iv,
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def n_params(cfg: ArchConfig) -> int:
    nb, n_self = _n_blocks(cfg)
    attn = cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * cfg.d_model
    mlp_p = 3 * cfg.d_model * cfg.d_ff
    self_layer = attn + mlp_p + 2 * cfg.d_model
    cross_layer = attn + mlp_p + 3 * cfg.d_model + 2
    return nb * (n_self * self_layer + cross_layer) + 2 * cfg.vocab * cfg.d_model + cfg.d_model


def n_active_params(cfg: ArchConfig) -> int:
    return n_params(cfg)
