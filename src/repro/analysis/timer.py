"""Event-style wall-clock timing (paper §III-F) — portable, counter-free.

Mirrors the paper's protocol: explicit synchronization (block_until_ready is
the CUDA-event analogue in JAX), warm-up iterations excluded, steady-state
statistics over repeated runs.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Sequence

import jax


@dataclasses.dataclass(frozen=True)
class Timing:
    mean_s: float
    median_s: float
    min_s: float
    std_s: float
    samples: Sequence[float]

    @property
    def us(self) -> float:
        return self.mean_s * 1e6

    @property
    def ms(self) -> float:
        return self.mean_s * 1e3


def _sync(x):
    return jax.block_until_ready(x)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10, **kwargs) -> Timing:
    """Steady-state timing of ``fn(*args, **kwargs)`` with explicit sync."""
    for _ in range(warmup):
        _sync(fn(*args, **kwargs))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _sync(fn(*args, **kwargs))
        samples.append(time.perf_counter() - t0)
    return Timing(
        mean_s=statistics.fmean(samples),
        median_s=statistics.median(samples),
        min_s=min(samples),
        std_s=statistics.pstdev(samples) if len(samples) > 1 else 0.0,
        samples=tuple(samples),
    )
