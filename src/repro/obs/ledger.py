"""Append-only perf-trajectory ledger + noise-aware regression gate.

Every ``benchmarks/run.py --json`` run promotes a handful of top-level
metrics (fused-vs-split speedup, epilogue fusion speedup, ...).  Before
this module they vanished into CI artifact storage; now each run appends
one :class:`LedgerEntry` — git SHA, device fingerprint, timestamp, metrics
— to a JSONL ledger (``results/perf/ledger.jsonl`` or
``$REPRO_PERF_LEDGER``), and ``python -m repro.launch.perf --check`` gates
on the trajectory.

The gate is deliberately *noise-aware*: shared cloud runners have no
hardware counters to disqualify a descheduled iteration (the counter-free
premise), so the baseline is the rolling **median** of the last ``window``
entries on the same device fingerprint, and the tolerance widens with the
trajectory's own robust spread (MAD).  A metric regresses only when it
falls outside ``max(rel_tol · |baseline|, noise_mult · MAD-sigma)`` in its
bad direction — a jittery-but-flat history never trips the gate, a clean
20% drop always does.
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import statistics
import subprocess
from typing import Dict, List, Optional, Sequence, Tuple

LEDGER_ENV = "REPRO_PERF_LEDGER"
DEFAULT_LEDGER = os.path.join("results", "perf", "ledger.jsonl")

# Direction conventions for gate-able metric names; anything unmatched is
# informational (tracked, never gated) — a gate must not guess.
_HIGHER_SUFFIXES = ("_speedup", "_per_s", "_throughput", "_bandwidth",
                    "_gflops", "_tok_s")
_LOWER_SUFFIXES = ("_us", "_ms", "_s", "_seconds", "_time", "_latency",
                   "_failures", "_bytes")
_LOWER_EXACT = ("failures",)


def ledger_path(path: Optional[str] = None) -> str:
    return path or os.environ.get(LEDGER_ENV) or DEFAULT_LEDGER


def git_sha(default: str = "unknown") -> str:
    """Short SHA of HEAD; CI env fallback; never raises."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    env = os.environ.get("GITHUB_SHA", "")
    return env[:12] if env else default


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    ts: str                      # ISO-8601 UTC
    sha: str                     # git revision the numbers describe
    fingerprint: str             # device identity (obs.calibrate convention)
    source: str                  # who appended (bench module, CLI, ...)
    metrics: Dict[str, float]

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, obj: Dict) -> "LedgerEntry":
        return cls(ts=obj.get("ts", ""), sha=obj.get("sha", "unknown"),
                   fingerprint=obj.get("fingerprint", "unknown"),
                   source=obj.get("source", ""),
                   metrics={k: float(v) for k, v in (obj.get("metrics") or {}).items()
                            if isinstance(v, (int, float))})


def numeric_metrics(payload: Dict) -> Dict[str, float]:
    """The gate-able projection of a ``benchmarks/run.py --json`` payload:
    finite top-level numbers only (rows, nulls, and strings stay behind)."""
    import math

    out = {}
    for k, v in payload.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        if isinstance(v, float) and not math.isfinite(v):
            continue
        out[k] = float(v)
    return out


def append_entry(metrics: Dict[str, float], *, source: str,
                 path: Optional[str] = None, sha: Optional[str] = None,
                 fingerprint: Optional[str] = None,
                 ts: Optional[str] = None) -> LedgerEntry:
    """Append one entry (creating the ledger and its directory on first use)."""
    if fingerprint is None:
        from repro.obs.calibrate import device_fingerprint

        fingerprint = device_fingerprint()
    entry = LedgerEntry(
        ts=ts or datetime.datetime.now(datetime.timezone.utc).isoformat(),
        sha=sha if sha is not None else git_sha(),
        fingerprint=fingerprint,
        source=source,
        metrics={k: float(v) for k, v in metrics.items()},
    )
    p = ledger_path(path)
    parent = os.path.dirname(p)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(p, "a") as f:
        f.write(json.dumps(entry.to_dict()) + "\n")
    return entry


def read_ledger(path: Optional[str] = None) -> List[LedgerEntry]:
    p = ledger_path(path)
    if not os.path.exists(p):
        return []
    out: List[LedgerEntry] = []
    with open(p) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(LedgerEntry.from_dict(json.loads(line)))
            except (json.JSONDecodeError, TypeError):
                continue  # a torn concurrent write must not sink the gate
    return out


def metric_direction(name: str) -> int:
    """+1 higher-is-better, -1 lower-is-better, 0 informational."""
    if name.endswith(_HIGHER_SUFFIXES):  # before _s/_bytes: "*_tok_s" is a rate
        return +1
    if name in _LOWER_EXACT or name.endswith(_LOWER_SUFFIXES):
        return -1
    return 0


@dataclasses.dataclass(frozen=True)
class MetricVerdict:
    metric: str
    status: str                  # ok | improved | regressed | no-baseline | informational
    current: float
    baseline: Optional[float]    # rolling median (None without history)
    tolerance: Optional[float]   # absolute band the gate applied
    n_history: int
    direction: int

    @property
    def gate_failed(self) -> bool:
        return self.status == "regressed"


def _mad_sigma(values: Sequence[float], center: float) -> float:
    """Robust sigma: 1.4826 x the median absolute deviation."""
    if len(values) < 2:
        return 0.0
    return 1.4826 * statistics.median(abs(v - center) for v in values)


def check_regression(
    entries: Sequence[LedgerEntry],
    *,
    window: int = 5,
    rel_tol: float = 0.05,
    noise_mult: float = 3.0,
    metrics: Optional[Sequence[str]] = None,
) -> Tuple[bool, List[MetricVerdict]]:
    """Gate the newest entry against the rolling baseline of its own device.

    Returns ``(ok, verdicts)``.  A fresh ledger (no prior entries for the
    current fingerprint + metric) passes: a gate with no baseline has
    nothing to defend yet.
    """
    if not entries:
        return True, []
    current = entries[-1]
    history = [e for e in entries[:-1] if e.fingerprint == current.fingerprint]
    verdicts: List[MetricVerdict] = []
    names = list(metrics) if metrics is not None else sorted(current.metrics)
    for name in names:
        if name not in current.metrics:
            continue
        cur = current.metrics[name]
        direction = metric_direction(name)
        if direction == 0:
            verdicts.append(MetricVerdict(name, "informational", cur, None,
                                          None, 0, 0))
            continue
        past = [e.metrics[name] for e in history if name in e.metrics][-window:]
        if not past:
            verdicts.append(MetricVerdict(name, "no-baseline", cur, None,
                                          None, 0, direction))
            continue
        baseline = statistics.median(past)
        tol = max(rel_tol * abs(baseline), noise_mult * _mad_sigma(past, baseline))
        delta = (cur - baseline) * direction   # >0 means better
        if delta < -tol:
            status = "regressed"
        elif delta > tol:
            status = "improved"
        else:
            status = "ok"
        verdicts.append(MetricVerdict(name, status, cur, baseline, tol,
                                      len(past), direction))
    ok = not any(v.gate_failed for v in verdicts)
    return ok, verdicts
