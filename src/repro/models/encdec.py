"""whisper-base backbone — encoder-decoder transformer.

Per the assignment, the audio conv frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d_model) directly; the
backbone (bidirectional encoder + causal decoder with cross attention) is
implemented in full.  LayerNorm + non-gated GELU MLPs + learned absolute
positions follow the Whisper architecture (arXiv:2212.04356).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.train.losses import softmax_cross_entropy


def attn_dims(cfg: ArchConfig) -> L.AttnDims:
    return L.AttnDims(n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                      causal=False)


def _init_ln(d):
    return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}


def _ln(p, x):
    return L.layer_norm(x, p["scale"], p["bias"])


def _init_enc_layer(rng, cfg: ArchConfig):
    k1, k2 = jax.random.split(rng)
    return {
        "attn": L.init_attention(k1, cfg.d_model, attn_dims(cfg)),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False),
        "ln1": _init_ln(cfg.d_model),
        "ln2": _init_ln(cfg.d_model),
    }


def _init_dec_layer(rng, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "self_attn": L.init_attention(k1, cfg.d_model, attn_dims(cfg)),
        "cross_attn": L.init_attention(k2, cfg.d_model, attn_dims(cfg)),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False),
        "ln1": _init_ln(cfg.d_model),
        "ln2": _init_ln(cfg.d_model),
        "ln3": _init_ln(cfg.d_model),
    }


def init(rng, cfg: ArchConfig):
    n_enc = cfg.encdec.n_enc_layers
    ks = jax.random.split(rng, 4)
    enc_keys = jax.random.split(ks[0], n_enc)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    params = {
        "embed": L.embed_init(ks[2], cfg.vocab, cfg.d_model),        # decoder tokens
        "dec_pos": jax.random.normal(ks[3], (cfg.encdec.max_positions, cfg.d_model)) * 0.01,
        "enc_layers": jax.vmap(lambda r: _init_enc_layer(r, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda r: _init_dec_layer(r, cfg))(dec_keys),
        "ln_enc": _init_ln(cfg.d_model),
        "ln_dec": _init_ln(cfg.d_model),
    }
    return jax.tree.map(lambda x: x.astype(cfg.param_dt), params)


def param_axes(cfg: ArchConfig):
    ln = {"scale": ("embed",), "bias": ("embed",)}
    attn_ax = L.attention_param_axes(attn_dims(cfg))
    mlp_ax = L.mlp_param_axes(gated=False)
    enc = {"attn": attn_ax, "mlp": mlp_ax, "ln1": ln, "ln2": ln}
    dec = {"self_attn": attn_ax, "cross_attn": attn_ax, "mlp": mlp_ax,
           "ln1": ln, "ln2": ln, "ln3": ln}
    stack = lambda tree: jax.tree.map(lambda t: ("layers",) + t, tree,
                                      is_leaf=lambda t: isinstance(t, tuple))
    return {
        "embed": ("vocab", "embed"),
        "dec_pos": (None, "embed"),
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "ln_enc": ln,
        "ln_dec": ln,
    }


def encode(params, cfg: ArchConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, D) precomputed embeddings (stub frontend) with
    sinusoidal positions added, -> encoder states (B, S_enc, D)."""
    B, S, D = frames.shape
    x = frames.astype(cfg.compute_dt)
    x = x + L.sinusoidal_positions(S, D).astype(x.dtype)[None]
    x = shard(x, "act_batch", "act_seq", "act_embed")
    dims = attn_dims(cfg)
    use_chunked = S >= cfg.attn_chunk_threshold

    def body(x, lp):
        h = _ln(lp["ln1"], x)
        a, _ = L.attention(lp["attn"], h, dims, use_chunked=use_chunked)
        x = x + a
        x = x + L.mlp(lp["mlp"], _ln(lp["ln2"], x), "gelu")
        return shard(x, "act_batch", "act_seq", "act_embed"), ()

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return _ln(params["ln_enc"], x)


def _dec_dims(cfg):
    return L.AttnDims(n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                      causal=True)


def decode_train(params, cfg: ArchConfig, tokens: jnp.ndarray, enc_states: jnp.ndarray):
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    x = x + params["dec_pos"][:S].astype(x.dtype)[None]
    dims = _dec_dims(cfg)
    cross_dims = attn_dims(cfg)
    use_chunked = S >= cfg.attn_chunk_threshold

    def body(x, lp):
        h = _ln(lp["ln1"], x)
        a, _ = L.attention(lp["self_attn"], h, dims, use_chunked=use_chunked)
        x = x + a
        h = _ln(lp["ln2"], x)
        c, _ = L.attention(lp["cross_attn"], h, cross_dims, kv_x=enc_states)
        x = x + c
        x = x + L.mlp(lp["mlp"], _ln(lp["ln3"], x), "gelu")
        return shard(x, "act_batch", "act_seq", "act_embed"), ()

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    return _ln(params["ln_dec"], x)


def loss_fn(params, cfg: ArchConfig, batch) -> jnp.ndarray:
    enc_states = encode(params, cfg, batch["frames"])
    hidden = decode_train(params, cfg, batch["tokens"], enc_states)
    logits = L.unembed(hidden, params["embed"])
    return softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or cfg.compute_dt
    e = cfg.encdec
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv, cfg.head_dim)
    cross = (cfg.n_layers, batch, e.enc_frames, cfg.n_kv, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
        "cross_k": jnp.zeros(cross, dtype), "cross_v": jnp.zeros(cross, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg: ArchConfig):
    kv = ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None)
    ckv = ("layers", "cache_batch", None, "cache_kv_heads", None)
    return {"k": kv, "v": kv, "cross_k": ckv, "cross_v": ckv, "pos": ()}


def precompute_cross_cache(params, cfg: ArchConfig, enc_states: jnp.ndarray):
    """Cross-attention K/V computed once per request (standard enc-dec serving)."""
    dims = attn_dims(cfg)

    def body(_, lp):
        _, (k, v) = L.attention(lp["cross_attn"], enc_states[:, :1, :], dims,
                                kv_x=enc_states, return_kv=True)
        return (), (k.astype(cfg.compute_dt), v.astype(cfg.compute_dt))

    _, (ck, cv) = jax.lax.scan(body, (), params["dec_layers"])
    return ck, cv


def decode_step(params, cfg: ArchConfig, cache, tokens: jnp.ndarray):
    B, S = tokens.shape
    pos = cache["pos"]
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    pos_emb = jax.lax.dynamic_slice(params["dec_pos"], (pos, 0), (S, cfg.d_model))
    x = x + pos_emb.astype(x.dtype)[None]
    dims = _dec_dims(cfg)
    positions = jnp.broadcast_to(pos[None, None] + jnp.arange(S, dtype=jnp.int32), (B, S))
    G = cfg.n_heads // cfg.n_kv

    def body(x, inp):
        lp, ck, cv, xk, xv = inp
        h = _ln(lp["ln1"], x)
        a, nc = L.attention(lp["self_attn"], h, dims, positions=positions,
                            cache={"k": ck, "v": cv}, cache_pos=pos)
        x = x + a
        # cross attention against the precomputed encoder K/V
        h = _ln(lp["ln2"], x)
        q = jnp.einsum("bsd,dnh->bsnh", h, lp["cross_attn"]["wq"].astype(h.dtype))
        out = L._sdpa(q, xk.astype(q.dtype), xv.astype(q.dtype), None,
                      attn_dims(cfg))
        c = jnp.einsum("bsf,fd->bsd",
                       out.reshape(B, S, cfg.n_heads * cfg.head_dim),
                       lp["cross_attn"]["wo"].astype(h.dtype))
        x = x + c
        x = x + L.mlp(lp["mlp"], _ln(lp["ln3"], x), "gelu")
        return x, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]))
    hidden = _ln(params["ln_dec"], x)
    logits = L.unembed(hidden, params["embed"])
    new_cache = dict(cache, k=nk, v=nv, pos=pos + S)
    return logits, new_cache


def prefill(params, cfg: ArchConfig, tokens: jnp.ndarray, frames: jnp.ndarray = None):
    """Enc-dec prefill: encode stub frames + teacher-forced decoder pass;
    cross K/V precomputed for decode."""
    B, S = tokens.shape
    if frames is None:
        frames = jnp.zeros((B, cfg.encdec.enc_frames, cfg.d_model), cfg.compute_dt)
    enc_states = encode(params, cfg, frames)
    hidden = decode_train(params, cfg, tokens, enc_states)
    logits = L.unembed(hidden[:, -1:, :], params["embed"])
    ck, cv = precompute_cross_cache(params, cfg, enc_states)
    cache = init_cache(cfg, B, S)
    cache["cross_k" ] = ck
    cache["cross_v"] = cv
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits, cache


def n_params(cfg: ArchConfig) -> int:
    D = cfg.d_model
    attn = D * (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim + cfg.n_heads * cfg.head_dim * D
    mlp_p = 2 * D * cfg.d_ff
    enc = cfg.encdec.n_enc_layers * (attn + mlp_p + 4 * D)
    dec = cfg.n_layers * (2 * attn + mlp_p + 6 * D)
    return enc + dec + cfg.vocab * D + cfg.encdec.max_positions * D + 4 * D


def n_active_params(cfg: ArchConfig) -> int:
    return n_params(cfg)
