"""Per-kernel validation: every Pallas variant x every execution path vs the
pure-jnp oracle, across a shape/dtype sweep (the role of the paper's App. A),
plus hypothesis property tests on the operator's invariants.

``hypothesis`` is an *optional* dev dependency: when it is absent the
property tests below are skipped, but the deterministic shape-sweep tests
still run (the tier-1 suite must degrade gracefully, not abort collection).
"""
try:  # optional dev dependency (see README "Optional dependencies")
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # property tests skip; deterministic sweeps still run
    hypothesis = None
    st = None
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dwconv as dw
from repro.kernels import ops, ref
from repro.kernels.common import pad_widths, adjoint_pad_widths

SHAPES = [
    # (B, H, L, K, padding) — includes the paper's config family (L=K=48),
    # even/odd K, causal short filters (mamba/RG-LRU), unaligned H and L.
    (2, 8, 48, 48, "same"),
    (3, 16, 100, 7, "same"),
    (2, 4, 200, 4, "causal"),
    (1, 8, 130, 48, "same"),
    (2, 3, 48, 5, "same"),
    (1, 1, 7, 3, "same"),
    (4, 8, 256, 48, "causal"),
]
FWD_VARIANTS = ["row", "block", "naive", "lane"]
BWDK_VARIANTS = ["accum", "twostage", "naive"]
SMALL_OPTS = ops.KernelOptions(batch_chunk=2)


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.mark.parametrize("B,H,L,K,pad", SHAPES)
def test_oracles_agree(B, H, L, K, pad):
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    np.testing.assert_allclose(
        ref.dwconv_fwd_ref(x, k, pad), ref.dwconv_lax_ref(x, k, pad), atol=1e-4
    )


@pytest.mark.parametrize("B,H,L,K,pad", SHAPES)
def test_ref_adjoints_match_autodiff(B, H, L, K, pad):
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    dy = _rand((B, H, L), jnp.float32, 2)
    _, vjp = jax.vjp(lambda x, k: ref.dwconv_fwd_ref(x, k, pad), x, k)
    dx_auto, dk_auto = vjp(dy)
    np.testing.assert_allclose(ref.dwconv_bwd_input_ref(dy, k, pad), dx_auto, atol=1e-4)
    np.testing.assert_allclose(ref.dwconv_bwd_kernel_ref(x, dy, K, pad), dk_auto, atol=2e-3)


@pytest.mark.parametrize("variant", FWD_VARIANTS)
@pytest.mark.parametrize("B,H,L,K,pad", SHAPES)
def test_fwd_variants_allclose(variant, B, H, L, K, pad):
    x = _rand((B, H, L), jnp.float32, 0)
    k = _rand((H, K), jnp.float32, 1)
    got = dw.run_fwd(x, k, pad, variant=variant)
    want = ref.dwconv_fwd_ref(x, k, pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("variant", FWD_VARIANTS)
@pytest.mark.parametrize("B,H,L,K,pad", SHAPES[:4])
def test_bwd_input_variants_allclose(variant, B, H, L, K, pad):
    dy = _rand((B, H, L), jnp.float32, 2)
    k = _rand((H, K), jnp.float32, 1)
    got = dw.run_bwd_input(dy, k, pad, variant=variant)
    want = ref.dwconv_bwd_input_ref(dy, k, pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("variant", BWDK_VARIANTS)
@pytest.mark.parametrize("B,H,L,K,pad", SHAPES[:5])
def test_bwd_kernel_variants_allclose(variant, B, H, L, K, pad):
    x = _rand((B, H, L), jnp.float32, 0)
    dy = _rand((B, H, L), jnp.float32, 2)
    got = ops.dwconv_bwd_kernel_op(x, dy, K, pad, variant, SMALL_OPTS)
    want = ref.dwconv_bwd_kernel_ref(x, dy, K, pad)
    # Parallel-reduction accumulation-order tolerance (paper §V-A).
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("variant", ["row", "block"])
def test_dtype_sweep(variant, dtype, atol):
    B, H, L, K = 2, 8, 96, 9
    x = _rand((B, H, L), dtype, 0)
    k = _rand((H, K), dtype, 1)
    got = np.asarray(dw.run_fwd(x, k, "same", variant=variant), np.float32)
    want = np.asarray(ref.dwconv_fwd_ref(x, k, "same"), np.float32)
    np.testing.assert_allclose(got, want, atol=atol, rtol=1e-2)


@pytest.mark.parametrize("variant", ["xla", "row", "block"])
def test_custom_vjp_matches_autodiff(variant):
    x = _rand((2, 8, 64), jnp.float32, 0)
    k = _rand((8, 5), jnp.float32, 1)

    def loss_custom(x, k):
        return jnp.sum(jnp.sin(dw.dwconv(x, k, variant=variant)))

    def loss_ref(x, k):
        return jnp.sum(jnp.sin(ref.dwconv_fwd_ref(x, k)))

    gx, gk = jax.grad(loss_custom, argnums=(0, 1))(x, k)
    rx, rk = jax.grad(loss_ref, argnums=(0, 1))(x, k)
    np.testing.assert_allclose(gx, rx, atol=1e-4)
    np.testing.assert_allclose(gk, rk, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("variant", BWDK_VARIANTS + ["xla"])
def test_bwdk_dtype_consistent_across_variants(variant, dtype):
    """Every bwdk variant — including the ``"xla"`` reference — accumulates
    and returns f32, so an ``auto`` cache winner flipping variants can never
    silently change the gradient dtype under bf16 training."""
    B, H, L, K = 2, 4, 96, 5
    x = _rand((B, H, L), dtype, 0)
    dy = _rand((B, H, L), dtype, 2)
    dk = ops.dwconv_bwd_kernel_op(x, dy, K, "same", variant, SMALL_OPTS)
    assert dk.dtype == jnp.float32, (variant, dtype, dk.dtype)
    want = np.asarray(ref.dwconv_bwd_kernel_ref(x, dy, K, "same"), np.float32)
    atol = 1e-3 if dtype == jnp.float32 else 5e-1
    np.testing.assert_allclose(np.asarray(dk, np.float32), want, atol=atol, rtol=1e-2)


def test_shape_legality_errors_name_dims_and_knob():
    """Illegal geometries raise ValueError (not a bare assert stripped under
    ``python -O``) naming the offending dims and the knob to change."""
    from repro.kernels import dwconv_bwd_fused, dwconv_bwdk, dwconv_fwd

    xp = jnp.zeros((3, 4, 256), jnp.float32)
    dyp = jnp.zeros((3, 4, 256), jnp.float32)
    with pytest.raises(ValueError, match="batch_chunk"):
        dwconv_bwdk.dwconv_bwdk_accum(xp, dyp, K=3, batch_chunk=2)
    with pytest.raises(ValueError, match="block_t"):
        dwconv_bwdk.dwconv_bwdk_twostage(
            jnp.zeros((2, 4, 512)), jnp.zeros((2, 4, 384)), K=5,
            batch_chunk=2, block_t=2)
    with pytest.raises(ValueError, match="block_h"):
        dwconv_fwd.dwconv_fwd_row(
            jnp.zeros((2, 5, 256)), jnp.zeros((5, 128)), K=3, Lout=128,
            block_h=3)
    with pytest.raises(ValueError, match="block_t"):
        dwconv_fwd.dwconv_fwd_block(
            jnp.zeros((2, 4, 512)), jnp.zeros((4, 128)), K=48, Lout=128,
            block_t=16)
    with pytest.raises(ValueError, match="block_t"):
        dwconv_fwd.dwconv_fwd_lane(
            jnp.zeros((2, 4, 512)), jnp.zeros((4, 128)), K=3, Lout=256,
            block_t=100)
    with pytest.raises(ValueError, match="block_w"):
        dwconv_bwd_fused.dwconv_bwd_fused_accum(
            xp, dyp, jnp.zeros((4, 128)), K=3, Lout=256, off_dk=1,
            block_w=512, batch_chunk=3)


def test_block_tiling_configs():
    """Sweep tile shapes: results must be tiling-invariant."""
    x = _rand((2, 16, 300, ), jnp.float32, 0)
    k = _rand((16, 11), jnp.float32, 1)
    want = ref.dwconv_fwd_ref(x, k, "same")
    for bh in (4, 8, 16):
        for bt in (128, 256, 512):
            got = dw.run_fwd(x, k, "same", variant="block",
                             opts=ops.KernelOptions(block_h=bh, block_t=bt))
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                                       err_msg=f"bh={bh} bt={bt}")


# ---------------------------------------------------------------------------
# Property tests (hypothesis) on operator invariants — skipped when the
# optional ``hypothesis`` package is not installed.
# ---------------------------------------------------------------------------

if hypothesis is None:

    def test_property_suite_requires_hypothesis():
        pytest.skip("hypothesis not installed — property tests skipped")

else:
    dims = st.tuples(
        st.integers(1, 3),        # B
        st.integers(1, 12),       # H
        st.integers(4, 96),       # L
        st.integers(1, 16),       # K
        st.sampled_from(["same", "causal"]),
    )

    @hypothesis.given(dims, st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_property_linearity(d, seed):
        """conv(a*x1 + x2, k) == a*conv(x1,k) + conv(x2,k)."""
        B, H, L, K, pad = d
        x1 = _rand((B, H, L), jnp.float32, seed)
        x2 = _rand((B, H, L), jnp.float32, seed + 1)
        k = _rand((H, K), jnp.float32, seed + 2)
        a = 0.7
        lhs = ref.dwconv_fwd_ref(a * x1 + x2, k, pad)
        rhs = a * ref.dwconv_fwd_ref(x1, k, pad) + ref.dwconv_fwd_ref(x2, k, pad)
        np.testing.assert_allclose(lhs, rhs, atol=1e-3)

    @hypothesis.given(dims, st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_property_adjoint_identity(d, seed):
        """<dy, conv(x,k)> == <x, bwd_input(dy,k)> == <k, bwd_kernel(x,dy)>."""
        B, H, L, K, pad = d
        x = _rand((B, H, L), jnp.float32, seed)
        k = _rand((H, K), jnp.float32, seed + 1)
        dy = _rand((B, H, L), jnp.float32, seed + 2)
        a = float(jnp.vdot(dy, ref.dwconv_fwd_ref(x, k, pad)))
        b = float(jnp.vdot(x, ref.dwconv_bwd_input_ref(dy, k, pad)))
        c = float(jnp.vdot(k, ref.dwconv_bwd_kernel_ref(x, dy, K, pad)))
        scale = max(1.0, abs(a))
        assert abs(a - b) / scale < 1e-3
        assert abs(a - c) / scale < 1e-3

    @hypothesis.given(dims, st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_property_pallas_row_matches_ref(d, seed):
        B, H, L, K, pad = d
        x = _rand((B, H, L), jnp.float32, seed)
        k = _rand((H, K), jnp.float32, seed + 1)
        got = dw.run_fwd(x, k, pad, variant="row")
        want = ref.dwconv_fwd_ref(x, k, pad)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)

    @hypothesis.given(
        st.integers(1, 2), st.integers(1, 8), st.integers(8, 64), st.integers(1, 8),
        st.integers(1, 16), st.integers(0, 2**31 - 1),
    )
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_property_causal_shift_equivariance(B, H, L, K, shift, seed):
        """Causal conv commutes with right-shift (zero-fill), away from the edge."""
        hypothesis.assume(shift < L)
        x = _rand((B, H, L), jnp.float32, seed)
        k = _rand((H, K), jnp.float32, seed + 1)
        shifted = jnp.pad(x, ((0, 0), (0, 0), (shift, 0)))[:, :, :L]
        y = ref.dwconv_fwd_ref(x, k, "causal")
        ys = ref.dwconv_fwd_ref(shifted, k, "causal")
        y_shift = jnp.pad(y, ((0, 0), (0, 0), (shift, 0)))[:, :, :L]
        # Positions < shift + K - 1 see the zero boundary; compare beyond it.
        lo = min(L, shift + K - 1)
        np.testing.assert_allclose(ys[:, :, lo:], y_shift[:, :, lo:], atol=1e-4)


def test_padding_width_math():
    assert pad_widths(48, "same") == (24, 23)
    assert pad_widths(47, "same") == (23, 23)
    assert pad_widths(4, "causal") == (3, 0)
    assert adjoint_pad_widths(48, "same") == (23, 24)
