"""Event-style wall-clock timing (paper §III-F) — portable, counter-free.

Mirrors the paper's protocol: explicit synchronization (block_until_ready is
the CUDA-event analogue in JAX), warm-up iterations excluded, steady-state
statistics over repeated runs.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Sequence

import jax


@dataclasses.dataclass(frozen=True)
class Timing:
    mean_s: float
    median_s: float
    min_s: float
    std_s: float
    samples: Sequence[float]

    @property
    def us(self) -> float:
        return self.mean_s * 1e6

    @property
    def ms(self) -> float:
        return self.mean_s * 1e3

    @property
    def median_us(self) -> float:
        """Preferred single-number summary on shared cloud runners: the
        median is insensitive to the occasional descheduled iteration that
        would drag the mean (the counter-free protocol has no hardware
        counters to cross-check an outlier against)."""
        return self.median_s * 1e6

    @property
    def median_ms(self) -> float:
        return self.median_s * 1e3


def _sync(x):
    return jax.block_until_ready(x)


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10,
            trim: float = 0.0, **kwargs) -> Timing:
    """Steady-state timing of ``fn(*args, **kwargs)`` with explicit sync.

    ``trim`` (fraction in [0, 0.5)) drops that share of samples from *each*
    tail before computing ``mean_s``/``std_s`` — an outlier-robust mean for
    jittery shared-tenancy runners.  ``median_s`` / ``min_s`` / ``samples``
    always describe the full untrimmed sample set.
    """
    if iters < 1:
        raise ValueError(
            f"time_fn needs iters >= 1 to produce a sample, got iters={iters}")
    if not 0.0 <= trim < 0.5:
        raise ValueError(
            f"trim must be a per-tail fraction in [0, 0.5), got {trim}")
    for _ in range(warmup):
        _sync(fn(*args, **kwargs))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _sync(fn(*args, **kwargs))
        samples.append(time.perf_counter() - t0)
    cut = int(len(samples) * trim)
    kept = sorted(samples)[cut : len(samples) - cut] if cut else samples
    return Timing(
        mean_s=statistics.fmean(kept),
        median_s=statistics.median(samples),
        min_s=min(samples),
        std_s=statistics.pstdev(kept) if len(kept) > 1 else 0.0,
        samples=tuple(samples),
    )
