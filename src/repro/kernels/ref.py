"""Pure-jnp oracle for every depthwise-convolution execution path.

This is the numerical ground truth the Pallas kernels are validated against
(the role the PyTorch grouped-conv1d reference plays in the paper, App. A).
It is also the ``variant='xla'`` production implementation: it is written
with plain jnp ops that XLA's SPMD partitioner shards cleanly, so the
distributed model code paths use it by default.

All functions operate on
  x : (B, H, L) float32/bfloat16
  k : (H, K)
and return arrays of the matching path shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import Padding, adjoint_pad_widths, pad_widths


def _padded(x: jnp.ndarray, K: int, padding: Padding) -> jnp.ndarray:
    left, right = pad_widths(K, padding)
    return jnp.pad(x, ((0, 0), (0, 0), (left, right)))


def _fwd_acc(x: jnp.ndarray, k: jnp.ndarray, padding: Padding) -> jnp.ndarray:
    """The forward tap sum in the f32 accumulator, *before* the output cast
    (shared by the plain reference and the fused-epilogue reference)."""
    B, H, L = x.shape
    Hk, K = k.shape
    if Hk != H:
        raise ValueError(
            f"filter bank has Hk={Hk} channels but the input has H={H}; "
            f"depthwise conv needs one (K,) filter per input channel")
    xp = _padded(x, K, padding)
    # Unrolled tap sum: K static slices, each fused by XLA into a single
    # elementwise loop; lowers without gathers and shards over (B, H).
    acc = jnp.zeros((B, H, L), dtype=jnp.promote_types(x.dtype, jnp.float32))
    for j in range(K):
        acc = acc + xp[:, :, j : j + L].astype(acc.dtype) * k[:, j][None, :, None].astype(acc.dtype)
    return acc


def dwconv_fwd_ref(x: jnp.ndarray, k: jnp.ndarray, padding: Padding = "same") -> jnp.ndarray:
    """y[b,h,t] = sum_j x_pad[b,h,t+j] * k[h,j]  (paper eq. (8))."""
    return _fwd_acc(x, k, padding).astype(x.dtype)


def dwconv_act_ref(
    x: jnp.ndarray,
    k: jnp.ndarray,
    bias: jnp.ndarray = None,
    act: str = "none",
    padding: Padding = "same",
) -> jnp.ndarray:
    """Fused-epilogue reference: ``act(conv(x, k) + bias)`` with the bias add
    and activation applied to the f32 accumulator *before* the single cast —
    the same rounding semantics as the Pallas epilogue kernels (one rounding
    step, vs one per op in the unfused composition).  This is also the SPMD
    production path: XLA fuses the whole chain into one elementwise loop."""
    from repro.kernels.epilogue import apply_act

    acc = _fwd_acc(x, k, padding)
    if bias is not None:
        acc = acc + bias.astype(acc.dtype)[None, :, None]
    return apply_act(acc, act).astype(x.dtype)


def dwconv_decode_ref(
    ring: jnp.ndarray,
    x: jnp.ndarray,
    k: jnp.ndarray,
    bias: jnp.ndarray = None,
    act: str = "none",
):
    """Single-step streaming-decode reference: one causal conv output at the
    newest position plus the shifted ring.

      ring : (B, H, K-1) — the last K-1 pre-conv inputs, oldest tap first
      x    : (B, H)      — the new step's input
      k    : (H, K)
      -> (y (B, H), new_ring (B, H, K-1))

    y[b,h] = act(sum_{j<K-1} ring[b,h,j]*k[h,j] + x[b,h]*k[h,K-1] + bias[h])

    Accumulates in f32 with ascending taps — the *same operation order* as
    ``_fwd_acc``, so N successive steps from a zero ring are bit-identical
    to one causal ``dwconv_act_ref`` over the stream for f32 ``act='none'``.
    Also the ``variant='xla'`` production decode path (plain jnp, shards
    over (B, H)); handles K=1 (empty ring) where the Pallas kernels refuse.
    """
    from repro.kernels.epilogue import apply_act

    B, H = x.shape
    Hk, K = k.shape
    if Hk != H:
        raise ValueError(
            f"filter bank has Hk={Hk} channels but the input has H={H}; "
            f"depthwise conv needs one (K,) filter per input channel")
    if ring.shape != (B, H, K - 1):
        raise ValueError(
            f"ring shape {ring.shape} does not match (B={B}, H={H}, K-1={K - 1}); "
            f"the ring must hold exactly the last K-1 inputs")
    acc = jnp.zeros((B, H), dtype=jnp.promote_types(x.dtype, jnp.float32))
    for j in range(K - 1):
        acc = acc + ring[:, :, j].astype(acc.dtype) * k[:, j][None, :].astype(acc.dtype)
    acc = acc + x.astype(acc.dtype) * k[:, K - 1][None, :].astype(acc.dtype)
    if bias is not None:
        acc = acc + bias.astype(acc.dtype)[None, :]
    y = apply_act(acc, act).astype(x.dtype)
    # append the new tap, drop the oldest: stays (B, H, K-1) even at K=1,
    # where the ring is empty and the "new ring" must stay empty too
    buf = jnp.concatenate([ring, x[:, :, None].astype(ring.dtype)], axis=-1)
    return y, buf[:, :, 1:]


def dwconv_bwd_input_ref(dy: jnp.ndarray, k: jnp.ndarray, padding: Padding = "same") -> jnp.ndarray:
    """dx = correlation of dy with the flipped kernel under adjoint padding."""
    B, H, L = dy.shape
    Hk, K = k.shape
    left, right = adjoint_pad_widths(K, padding)
    dyp = jnp.pad(dy, ((0, 0), (0, 0), (left, right)))
    kf = k[:, ::-1]
    acc = jnp.zeros((B, H, L), dtype=jnp.promote_types(dy.dtype, jnp.float32))
    for j in range(K):
        acc = acc + dyp[:, :, j : j + L].astype(acc.dtype) * kf[:, j][None, :, None].astype(acc.dtype)
    return acc.astype(dy.dtype)


def dwconv_bwd_kernel_ref(
    x: jnp.ndarray, dy: jnp.ndarray, K: int, padding: Padding = "same"
) -> jnp.ndarray:
    """dk[h,j] = sum_{b,t} dy[b,h,t] * x_pad[b,h,t+j]  (paper eq. (10)).

    Accumulates *and returns* f32 like the Pallas bwdk kernels, so a
    ``variant="auto"`` cache winner flipping between ``"xla"`` and a Pallas
    variant never silently changes the gradient dtype under bf16 training
    (callers cast to the parameter dtype, as ``core/dwconv.py`` does).
    """
    B, H, L = x.shape
    xp = _padded(x, K, padding)
    dy32 = dy.astype(jnp.float32)
    taps = [
        jnp.sum(dy32 * xp[:, :, j : j + L].astype(jnp.float32), axis=(0, 2)) for j in range(K)
    ]
    return jnp.stack(taps, axis=-1)


def dwconv_ref(x: jnp.ndarray, k: jnp.ndarray, padding: Padding = "same") -> jnp.ndarray:
    """Differentiable reference (autodiff gives the adjoints for free)."""
    return dwconv_fwd_ref(x, k, padding)


def dwconv_lax_ref(x: jnp.ndarray, k: jnp.ndarray, padding: Padding = "same") -> jnp.ndarray:
    """Independent second oracle via lax.conv_general_dilated with
    feature_group_count=H (the cuDNN-style grouped convolution the paper's
    PyTorch reference uses).  Used in tests to cross-check ``dwconv_fwd_ref``.
    """
    B, H, L = x.shape
    _, K = k.shape
    left, right = pad_widths(K, padding)
    # conv_general_dilated computes cross-correlation (XLA convention) — no flip.
    rhs = k.astype(x.dtype)[:, None, :]  # (H, 1, K)  O I W
    out = jax.lax.conv_general_dilated(
        x,
        rhs,
        window_strides=(1,),
        padding=[(left, right)],
        dimension_numbers=("NCH", "OIH", "NCH"),
        feature_group_count=H,
    )
    return out
