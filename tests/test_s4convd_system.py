"""System tests: the paper's fixed S4ConvD workload end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import s4convd
from repro.data.gep3 import BatchIterator, GEP3Config, generate_corpus, make_splits
from repro.train.losses import rmsle, softmax_cross_entropy
from repro.train.optim import adamw, clip_by_global_norm, global_norm, sgd_momentum
from repro.train.s4_trainer import train

SMALL = s4convd.S4ConvDConfig(H=16, N=4, n_blocks=2, L=48, K=12)


def test_kernel_materialization_finite_and_decaying():
    p = s4convd.init(jax.random.PRNGKey(0), SMALL)
    k = s4convd.materialize_kernel(p["blocks"][0], SMALL.K)
    assert k.shape == (SMALL.H, SMALL.K)
    assert bool(jnp.all(jnp.isfinite(k)))
    # diagonal SSM kernels decay: late-tap mass below early-tap mass
    early = jnp.mean(jnp.abs(k[:, : SMALL.K // 4]))
    late = jnp.mean(jnp.abs(k[:, -SMALL.K // 4 :]))
    assert float(late) < float(early)


def test_apply_shapes_and_positivity():
    p = s4convd.init(jax.random.PRNGKey(0), SMALL)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 48, 4)), jnp.float32)
    y = s4convd.apply(p, SMALL, x)
    assert y.shape == (3, 48)
    assert bool(jnp.all(y >= 0))  # softplus head for RMSLE
    assert bool(jnp.all(jnp.isfinite(y)))


def test_variant_equivalence_in_model():
    """The controlled-study invariant: changing only the kernel variant does
    not change the model function."""
    p = s4convd.init(jax.random.PRNGKey(0), SMALL)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 48, 4)), jnp.float32)
    base = s4convd.apply(p, SMALL, x)
    import dataclasses

    for v in ("row", "block"):
        cfg = dataclasses.replace(SMALL, conv_variant=v)
        got = s4convd.apply(p, cfg, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base), atol=1e-4)


def test_training_converges():
    res = train(SMALL, GEP3Config(n_buildings=8, n_hours=256),
                batch_size=128, epochs=3, max_steps_per_epoch=8)
    assert res.epoch_losses[-1] < res.epoch_losses[0]
    assert np.isfinite(res.dev_rmsle)


def test_corpus_statistics():
    c = generate_corpus(GEP3Config(n_buildings=4, n_hours=500))
    assert c.shape == (4, 500, 4)
    r = c[..., 0]
    assert np.all(r > 0)  # energy is positive
    cc = c[..., 2]
    assert np.all((cc >= 0) & (cc <= 1))  # cloud coverage in [0, 1]


def test_iterator_checkpoint_resume():
    """Fault-tolerance requirement: data iterator resumes deterministically."""
    x = np.arange(100, dtype=np.float32)[:, None, None].repeat(4, 2).repeat(2, 1)
    y = np.arange(100, dtype=np.float32)[:, None].repeat(2, 1)
    it1 = BatchIterator(x, y, 10, seed=7)
    seen1 = []
    for i, (xb, _) in enumerate(it1):
        seen1.append(xb[0, 0, 0])
        if i == 3:
            state = it1.state_dict()
            break
    it2 = BatchIterator(x, y, 10, seed=0)
    it2.load_state_dict(state)
    nxt1 = next(iter(it1))[0][0, 0, 0]
    nxt2 = next(iter(it2))[0][0, 0, 0]
    assert nxt1 == nxt2


def test_losses():
    p = jnp.asarray([1.0, 2.0, 3.0])
    assert float(rmsle(p, p)) < 1e-5
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0]])
    labels = jnp.asarray([0, 1])
    assert float(softmax_cross_entropy(logits, labels)) < 1e-3
    mask = jnp.asarray([1.0, 0.0])
    assert float(softmax_cross_entropy(logits, jnp.asarray([0, 0]), mask)) < 1e-3


def test_optimizers_descend_quadratic():
    for opt in (sgd_momentum(lr=0.1, clip_norm=None), adamw(lr=0.1, weight_decay=0.0)):
        params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
        state = opt.init(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(100):
            grads = jax.grad(loss)(params)
            params, state = opt.update(grads, params, state)
        assert float(loss(params)) < 1e-2, opt.name


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
