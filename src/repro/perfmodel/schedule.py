"""The declarative kernel-schedule IR for counter-free analysis.

A :class:`KernelSchedule` is a *pure-data* description of how one kernel
configuration — (execution path x implementation variant x epilogue) at one
static problem shape and tiling — maps onto the machine: the launch grid,
every operand's per-grid-cell staged block shape (halos included), the total
elements each operand moves across HBM (revisit counts folded in), the HBM
partials arrays, and the epilogue op counts.  It asserts nothing about
*when* things run; it only records *what* the kernel touches.

Everything the paper's counter-free methodology needs is then **derived**
(``perfmodel/derive.py``) instead of hand-maintained per call site:

  * HBM byte traffic            — sum of the operands' HBM crossings;
  * per-grid-cell VMEM footprint — sum of the staged block shapes;
  * structural legality          — the schedule's own verdict fields;
  * stage-1 analytical time      — traffic + flops through the roofline;
  * arithmetic intensity / roofline placement — the same two numbers.

Schedules are built by the registered builders in
``perfmodel/schedules.py`` from the *same* geometry functions
(``perfmodel/geometry.py``) that ``kernels/ops.py`` uses to pad and tile
the real buffers, so the model and the runtime cannot drift.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.kernels.common import DWConvDims

#: Operand roles a schedule distinguishes.  ``read`` / ``write`` charge HBM
#: traffic; ``scratch`` is VMEM-only state (accumulators, recompute
#: temporaries) that never crosses HBM but occupies the per-cell footprint.
ROLES = ("read", "write", "scratch")


@dataclasses.dataclass(frozen=True)
class OperandTraffic:
    """One array the kernel touches: its HBM crossings and VMEM staging.

    ``elems`` is the *total* element count crossing HBM over the whole
    launch — output revisit counts, halo re-reads, and partials round-trips
    are already folded in by the builder (from the shared geometry, so the
    sum is exact, not an estimate).  ``block`` is the per-grid-cell staged
    VMEM shape (``()`` for operands the kernel streams without staging, or
    whose staging the footprint model deliberately does not charge — the
    convention the tuner's legality predicates have always used).
    """

    name: str                             # "x", "dy", "k", "dk_partials", ...
    role: str                             # "read" | "write" | "scratch"
    # Integral for the explicit-DMA TPU family; paper-mode *cache-adjusted*
    # charges (surviving-redundancy fractions rho) may be fractional.
    elems: float                          # total elements crossing HBM
    itemsize: int                         # bytes/elem charged for HBM traffic
    transactions: int = 0                 # structural DMA count (whole launch)
    block: Tuple[int, ...] = ()           # per-grid-cell staged VMEM shape
    block_itemsize: Optional[int] = None  # VMEM width (defaults to itemsize)
    note: str = ""                        # derivation note, surfaced in reports

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(f"unknown operand role {self.role!r}; known: {ROLES}")

    @property
    def hbm_bytes(self) -> int:
        return 0 if self.role == "scratch" else self.elems * self.itemsize

    @property
    def block_elems(self) -> int:
        n = 1
        for s in self.block:
            n *= s
        return n if self.block else 0

    @property
    def vmem_bytes(self) -> int:
        w = self.block_itemsize if self.block_itemsize is not None else self.itemsize
        return self.block_elems * w


@dataclasses.dataclass(frozen=True)
class KernelSchedule:
    """Pure-data execution mapping of one kernel configuration."""

    path: str                              # "fwd" | "bwd_in" | "bwd_k" | "bwd_fused" | composites
    variant: str                           # implementation variant (or composite label)
    dims: DWConvDims
    grid: Tuple[Tuple[str, int], ...]      # named launch-grid extents
    operands: Tuple[OperandTraffic, ...]
    flops: float                           # paper eqs. (2)-(3) + epilogue ops
    epilogue: str = "none"                 # canonical epilogue key
    epilogue_ops: int = 0                  # standalone elementwise passes (unfused)
    aligned: bool = True                   # lane-aligned transactions?
    reliable: bool = True                  # False: redundant-traffic proxy (paper "N/A")
    legal: bool = True                     # structural kernel asserts satisfied?
    illegal_reason: str = "ok"

    @property
    def grid_cells(self) -> int:
        n = 1
        for _, extent in self.grid:
            n *= extent
        return n

    def reads(self) -> Tuple[OperandTraffic, ...]:
        return tuple(o for o in self.operands if o.role == "read")

    def writes(self) -> Tuple[OperandTraffic, ...]:
        return tuple(o for o in self.operands if o.role == "write")


@dataclasses.dataclass(frozen=True)
class TrafficEstimate:
    """Modeled HBM traffic for one (variant, path) execution.

    The typed contract every traffic/report/roofline consumer shares (no
    ad-hoc dicts): derived from a :class:`KernelSchedule` by
    ``perfmodel.derive.derive_traffic`` and re-exported by
    ``repro.analysis.traffic`` under its historical name.
    """

    flops: float
    bytes_read: float
    bytes_written: float
    transactions: float          # DMA count (structural, from the kernel)
    aligned: bool                # lane-aligned transactions?
    reliable: bool               # paper: naive redundant traffic is a proxy only

    @property
    def bytes_moved(self) -> float:
        return self.bytes_read + self.bytes_written

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_moved, 1.0)


def path_flops(d: DWConvDims) -> float:
    """Paper eqs. (2)-(3): identical op count on all three paths."""
    return 2.0 * d.B * d.H * d.L * d.K


def merge_schedules(
    path: str,
    variant: str,
    d: DWConvDims,
    parts: Tuple[KernelSchedule, ...],
    *,
    extra_operands: Tuple[OperandTraffic, ...] = (),
    extra_flops: float = 0.0,
    epilogue: str = "none",
    epilogue_ops: int = 0,
) -> KernelSchedule:
    """Concatenate component schedules into one composite (e.g. the split
    backward = pad materializations + bwd_in + bwd_k).  Traffic and flops
    sum; alignment/reliability/legality AND together; the grid is the
    disjoint union (components launch sequentially)."""
    operands = tuple(extra_operands)
    grid: Tuple[Tuple[str, int], ...] = ()
    flops = extra_flops
    aligned = reliable = legal = True
    reason = "ok"
    for i, p in enumerate(parts):
        operands += tuple(
            dataclasses.replace(o, name=f"{p.path}/{p.variant}:{o.name}")
            for o in p.operands)
        grid += tuple((f"{p.path}[{i}].{name}", ext) for name, ext in p.grid)
        flops += p.flops
        aligned &= p.aligned
        reliable &= p.reliable
        if legal and not p.legal:
            legal, reason = False, p.illegal_reason
    return KernelSchedule(
        path=path, variant=variant, dims=d, grid=grid, operands=operands,
        flops=flops, epilogue=epilogue, epilogue_ops=epilogue_ops,
        aligned=aligned, reliable=reliable, legal=legal, illegal_reason=reason)
