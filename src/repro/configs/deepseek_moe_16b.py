"""deepseek-moe-16b [moe]: 28L, d=2048, 16H, ff=1408/expert, 2 shared + 64
routed top-6 (fine-grained), dense first layer (ff=10944), vocab=102400.
[arXiv:2401.06066]"""
import dataclasses

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, capacity_factor=1.25,
                  group_size=512, dense_first_layer=True, dense_ff=10944),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=32, vocab=256,
    head_dim=16,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, capacity_factor=1.5,
                  group_size=16, dense_first_layer=True, dense_ff=128),
    compute_dtype="float32",
)
