"""Unit tests for the counter-free analysis subsystem."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    TPU_V5E,
    analyze_hlo,
    bwd_fused_traffic,
    bwd_split_traffic,
    bwdk_traffic,
    effective_bandwidth,
    fwd_traffic,
    path_flops,
    roofline_from_compiled,
    shape_bytes,
    time_fn,
)
from repro.analysis.hlo import CollectiveOp
from repro.launch.mesh import make_mesh
from repro.kernels.common import DWConvDims

PAPER_DIMS = DWConvDims(B=16384, H=128, L=48, K=48)


# ---------------------------------------------------------------------------
# shape / HLO parsing
# ---------------------------------------------------------------------------


def test_shape_bytes():
    assert shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4
    assert shape_bytes("bf16[2,3,4]") == 48
    assert shape_bytes("f32[]") == 4
    assert shape_bytes("(f32[8], bf16[8])") == 32 + 16
    assert shape_bytes("pred[16]") == 16


GOLDEN_HLO = """
HloModule jit_step

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[16,256])) -> (s32[], f32[16,256]) {
  %p = (s32[], f32[16,256]) parameter(0)
  %g = f32[16,256]{1,0} get-tuple-element(%p), index=1
  %ar = f32[16,256]{1,0} all-reduce(%g), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %i = s32[] constant(0)
  ROOT %t = (s32[], f32[16,256]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[16,256])) -> pred[] {
  %p = (s32[], f32[16,256]) parameter(0)
  %c = s32[] constant(5)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (x: f32[16,256]) -> f32[64,256] {
  %x = f32[16,256]{1,0} parameter(0)
  %i0 = s32[] constant(0)
  %t0 = (s32[], f32[16,256]) tuple(%i0, %x)
  %w = (s32[], f32[16,256]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %gw = f32[16,256]{1,0} get-tuple-element(%w), index=1
  ROOT %ag = f32[64,256]{1,0} all-gather(%gw), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
}
"""


def test_analyze_golden_hlo():
    a = analyze_hlo(GOLDEN_HLO, num_partitions=8)
    kinds = a.counts_by_kind()
    # all-reduce inside the while body runs 5 times; all-gather once.
    assert kinds["all-reduce"] == 5
    assert kinds["all-gather"] == 1
    ar_bytes = 16 * 256 * 4
    ag_result = 64 * 256 * 4
    by_kind = a.bytes_by_kind()
    assert by_kind["all-reduce"] == pytest.approx(5 * ar_bytes)
    # all-gather operand = result / group size (4)
    assert by_kind["all-gather"] == pytest.approx(ag_result / 4)
    assert a.while_trip_counts.get("body") == 5


def test_collective_wire_model():
    op = CollectiveOp("all-reduce", result_bytes=1024, group_size=4, trip_mult=1, computation="e")
    assert op.operand_bytes == 1024
    assert op.wire_bytes == pytest.approx(2 * 1024 * 3 / 4)
    ag = CollectiveOp("all-gather", result_bytes=4096, group_size=4, trip_mult=1, computation="e")
    assert ag.operand_bytes == 1024
    rs = CollectiveOp("reduce-scatter", result_bytes=1024, group_size=4, trip_mult=1, computation="e")
    assert rs.operand_bytes == 4096


def test_analyze_real_compiled_hlo():
    """End-to-end: SPMD-compile a sharded program on this process's devices
    and confirm the parser finds its collectives."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jnp.sum(x * 2.0)

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    with mesh:
        compiled = jax.jit(f, in_shardings=NamedSharding(mesh, P("data"))).lower(xs).compile()
    rep = roofline_from_compiled(compiled, label="t", chips=1, model_flops=64 * 128)
    assert rep.flops_per_device > 0
    assert rep.memory_s > 0
    assert rep.dominant in ("compute", "memory", "collective")


# ---------------------------------------------------------------------------
# traffic models
# ---------------------------------------------------------------------------


def test_paper_flop_count():
    # Paper eq. (2): B*H*L*2K = 16384*128*48*96
    assert path_flops(PAPER_DIMS) == 16384 * 128 * 48 * 2 * 48


def test_traffic_ordering_fwd():
    """The study's central claim: redundant traffic strictly decreases
    naive/lane -> block -> row."""
    d = DWConvDims(B=64, H=128, L=512, K=48)
    naive = fwd_traffic(d, "naive")
    lane = fwd_traffic(d, "lane")
    block = fwd_traffic(d, "block")
    row = fwd_traffic(d, "row")
    assert naive.bytes_moved > block.bytes_moved > row.bytes_moved
    assert lane.bytes_moved >= naive.bytes_moved  # alignment adds overfetch
    assert lane.aligned and not naive.aligned
    # row reads each input element approximately once
    logical = d.B * d.H * d.L * 4
    assert row.bytes_read < 2.2 * logical


def test_traffic_ordering_bwdk():
    d = DWConvDims(B=256, H=128, L=48, K=48)
    naive = bwdk_traffic(d, "naive")
    two = bwdk_traffic(d, "twostage")
    acc = bwdk_traffic(d, "accum")
    assert naive.bytes_moved > two.bytes_moved > acc.bytes_moved
    assert not naive.reliable  # paper Table III: naive is N/A


def test_fwd_traffic_charges_filter_reads_uniformly():
    """Every variant charges one logical pass over the (H, K) filter bank —
    the naive/lane branches must not disagree on kernel-operand accounting."""
    from repro.kernels.common import LANE, cdiv, round_up

    d = DWConvDims(B=4, H=16, L=256, K=9)
    itemsize, Hb, bt = 4, 8, 128
    kb = d.H * d.K * itemsize
    Lout = round_up(d.L, LANE)
    Lt = min(bt, Lout)
    n_tiles = d.B * cdiv(d.H, Hb) * cdiv(Lout, Lt)
    naive = fwd_traffic(d, "naive", itemsize, block_h=Hb, block_t=bt)
    lane = fwd_traffic(d, "lane", itemsize, block_h=Hb, block_t=bt)
    assert naive.bytes_read == n_tiles * d.K * Hb * Lt * itemsize + kb
    assert lane.bytes_read == n_tiles * d.K * Hb * (Lt + LANE) * itemsize + kb
    # lane differs from naive only by the alignment overfetch
    assert lane.bytes_read - naive.bytes_read == n_tiles * d.K * Hb * LANE * itemsize
    for v in ("block", "row", "xla"):
        assert fwd_traffic(d, v, itemsize, block_h=Hb, block_t=bt).bytes_read >= kb


def test_bwd_fused_traffic_model():
    """Whole-backward accounting: fused < fused_partials < split, and the
    paper-shape gate the fused-backward benchmark enforces."""
    d = PAPER_DIMS
    fused = bwd_fused_traffic(d, "fused")
    partials = bwd_fused_traffic(d, "fused_partials")
    split = bwd_fused_traffic(d, "split")
    assert split.bytes_moved == bwd_split_traffic(d).bytes_moved
    assert fused.bytes_moved < partials.bytes_moved < split.bytes_moved
    assert fused.bytes_moved <= 0.6 * split.bytes_moved
    # both gradients' multiply-adds are counted once each
    assert fused.flops == 2 * path_flops(d) == split.flops
    assert fused.reliable and fused.aligned


def test_effective_bandwidth_na_for_naive():
    d = DWConvDims(B=8, H=16, L=48, K=8)
    est = fwd_traffic(d, "naive")
    bw = effective_bandwidth("naive", "fwd", est, runtime_s=1e-3, hw=TPU_V5E)
    assert bw.eff_bw is None and bw.peak_util is None
    est2 = fwd_traffic(d, "row")
    bw2 = effective_bandwidth("row", "fwd", est2, runtime_s=1e-3, hw=TPU_V5E)
    assert bw2.eff_bw is not None and bw2.peak_util > 0


def test_timer_smoke():
    f = jax.jit(lambda x: x * 2 + 1)
    x = jnp.ones((128, 128))
    t = time_fn(f, x, warmup=1, iters=3)
    assert t.mean_s > 0 and len(t.samples) == 3


def test_roofline_fraction_bounds():
    from repro.analysis.roofline import RooflineReport

    r = RooflineReport(
        label="x", chips=256,
        flops_per_device=1e12, bytes_per_device=1e9,
        collective_bytes_per_device=1e8, collective_wire_bytes_per_device=1e8,
        compute_s=1e12 / TPU_V5E.peak_flops,
        memory_s=1e9 / TPU_V5E.hbm_bw,
        collective_s=1e8 / TPU_V5E.ici_bw,
        model_flops=0.9e12 * 256,
    )
    assert r.dominant == "compute"
    assert 0 < r.roofline_fraction <= 1.0
    assert r.useful_flops_ratio == pytest.approx(0.9)
