"""qwen2-0.5b [dense]: 24L, d=896, 14H (GQA kv=2), ff=4864, vocab=151936,
QKV bias, tied embeddings.  [arXiv:2407.10671]"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=56, n_heads=7, n_kv=1, d_ff=128, vocab=256,
    head_dim=8, compute_dtype="float32",
)
