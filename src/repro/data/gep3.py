"""Synthetic ASHRAE GEPIII-like data pipeline (paper §III-A).

The real GEPIII dataset (hourly building energy + weather) is not available
offline, so the pipeline *generates* a statistically GEPIII-like corpus:
per-building hourly energy consumption driven by daily/weekly usage
patterns, a weather response (air temperature, cloud coverage, dew point),
building-specific base loads, and heteroscedastic noise.  Everything is
deterministic in the seed.

Matching the paper:
  * features per timestep:  u = [R, T_a, CC, T_d]     (eq. (1))
  * window length L = 48, F = 4
  * a reproducible 10% development subset with preserved temporal ordering
    (paper §III-H) and a held-out test split
  * multi-worker-style prefetching is modeled with a background thread so
    measured step time reflects compute, not input loading (§III-C).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class GEP3Config:
    n_buildings: int = 64
    n_hours: int = 2048          # hourly series length per building
    L: int = 48                  # window length (paper)
    seed: int = 0
    dev_fraction: float = 0.10   # paper §III-H development subset
    test_fraction: float = 0.15


def generate_corpus(cfg: GEP3Config) -> np.ndarray:
    """Returns (n_buildings, n_hours, 4) float32: [R, T_a, CC, T_d]."""
    rng = np.random.default_rng(cfg.seed)
    t = np.arange(cfg.n_hours, dtype=np.float32)
    hour = t % 24.0
    dow = (t // 24.0) % 7.0

    base = rng.lognormal(mean=4.0, sigma=0.6, size=(cfg.n_buildings, 1)).astype(np.float32)
    daily_phase = rng.uniform(0, 2 * np.pi, size=(cfg.n_buildings, 1)).astype(np.float32)
    daily = 1.0 + 0.45 * np.sin(2 * np.pi * hour / 24.0 + daily_phase)
    weekly = 1.0 - 0.25 * (dow >= 5).astype(np.float32)  # weekend dip

    season = 10.0 * np.sin(2 * np.pi * t / (24 * 365) * 8)  # fast "seasons"
    ta = 15.0 + season + 6.0 * np.sin(2 * np.pi * hour / 24.0 - 0.8)
    ta = ta + rng.normal(0, 1.2, size=(cfg.n_buildings, cfg.n_hours)).astype(np.float32)
    cc = np.clip(
        0.5 + 0.3 * np.sin(2 * np.pi * t / 96.0)
        + rng.normal(0, 0.18, size=(cfg.n_buildings, cfg.n_hours)),
        0.0, 1.0,
    ).astype(np.float32)
    td = ta - rng.uniform(1.0, 6.0, size=(cfg.n_buildings, 1)).astype(np.float32)

    # Energy responds to deviation from a comfort band (heating/cooling load).
    hvac = 1.0 + 0.02 * np.abs(ta - 18.0) + 0.05 * cc
    noise = rng.lognormal(0.0, 0.08, size=(cfg.n_buildings, cfg.n_hours)).astype(np.float32)
    r = (base * daily * weekly[None, :] * hvac * noise).astype(np.float32)

    feats = np.stack([r, ta.astype(np.float32), cc, td.astype(np.float32)], axis=-1)
    return feats.astype(np.float32)


def make_windows(corpus: np.ndarray, L: int) -> Tuple[np.ndarray, np.ndarray]:
    """Sliding windows: inputs (N, L, 4) and next-step energy targets (N, L).

    Target for position t is R at t+1 (sequence-to-sequence forecasting).
    """
    nb, nh, F = corpus.shape
    # Memory-friendly strided views:
    from numpy.lib.stride_tricks import sliding_window_view

    win = sliding_window_view(corpus, (L, F), axis=(1, 2))[:, :-1, 0]  # (nb, n_win, L, F)
    tgt = sliding_window_view(corpus[:, 1:, 0], L, axis=1)             # (nb, n_win', L)
    n = min(win.shape[1], tgt.shape[1])
    x = win[:, :n].reshape(-1, L, F)
    y = tgt[:, :n].reshape(-1, L)
    return np.ascontiguousarray(x), np.ascontiguousarray(y)


@dataclasses.dataclass
class Splits:
    train_x: np.ndarray
    train_y: np.ndarray
    dev_x: np.ndarray
    dev_y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray


def make_splits(cfg: GEP3Config) -> Splits:
    """Temporal split: train | dev (10%, ordered) | test — per §III-H."""
    corpus = generate_corpus(cfg)
    x, y = make_windows(corpus, cfg.L)
    n = x.shape[0]
    n_test = int(n * cfg.test_fraction)
    n_dev = int(n * cfg.dev_fraction)
    n_train = n - n_dev - n_test
    return Splits(
        train_x=x[:n_train], train_y=y[:n_train],
        dev_x=x[n_train : n_train + n_dev], dev_y=y[n_train : n_train + n_dev],
        test_x=x[n_train + n_dev :], test_y=y[n_train + n_dev :],
    )


class BatchIterator:
    """Sharded, shuffled, prefetching batch iterator.

    ``shard_index/shard_count`` give multi-host data parallelism (each host
    reads its slice).  The iterator's RNG state is checkpointable via
    ``state_dict`` / ``load_state_dict`` so restarts resume mid-epoch
    (fault-tolerance requirement).
    """

    def __init__(
        self,
        x: np.ndarray,
        y: np.ndarray,
        batch_size: int,
        *,
        seed: int = 0,
        shard_index: int = 0,
        shard_count: int = 1,
        drop_remainder: bool = True,
        prefetch: int = 2,
    ):
        self.x = x[shard_index::shard_count]
        self.y = y[shard_index::shard_count]
        self.batch = batch_size
        self.seed = seed
        self.epoch = 0
        self.step_in_epoch = 0
        self.drop_remainder = drop_remainder
        self.prefetch = prefetch

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> dict:
        return {"seed": self.seed, "epoch": self.epoch, "step_in_epoch": self.step_in_epoch}

    def load_state_dict(self, d: dict) -> None:
        self.seed = int(d["seed"])
        self.epoch = int(d["epoch"])
        self.step_in_epoch = int(d["step_in_epoch"])

    def end_epoch(self) -> None:
        """Mark the current epoch finished (used when a consumer stops early,
        e.g. a step-capped epoch); the next ``__iter__`` starts fresh."""
        self.epoch += 1
        self.step_in_epoch = 0

    # -- iteration -------------------------------------------------------------
    def _epoch_order(self) -> np.ndarray:
        rng = np.random.default_rng((self.seed, self.epoch))
        return rng.permutation(self.x.shape[0])

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def producer():
            order = self._epoch_order()
            n = order.shape[0]
            start = self.step_in_epoch * self.batch
            for lo in range(start, n - (self.batch - 1 if self.drop_remainder else 0), self.batch):
                sel = order[lo : lo + self.batch]
                q.put((self.x[sel], self.y[sel]))
            q.put(stop)

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        while True:
            item = q.get()
            if item is stop:
                break
            self.step_in_epoch += 1
            yield item
        self.epoch += 1
        self.step_in_epoch = 0
