"""Architecture configuration schema for the assigned model pool.

One dataclass covers all families; family-specific blocks are optional
sub-configs.  Every ``src/repro/configs/<id>.py`` exports ``CONFIG`` (the
exact public configuration) and ``SMOKE`` (a reduced same-family config for
CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 8
    n_shared: int = 0              # always-on shared experts (DeepSeekMoE)
    capacity_factor: float = 1.25
    group_size: int = 512          # dispatch group (tokens)
    dense_first_layer: bool = False  # DeepSeekMoE: layer 0 is a dense MLP
    dense_ff: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64             # SSD head size P
    chunk: int = 256               # SSD chunk length
    conv_variant: str = "xla"      # the paper's kernel in mamba's conv1d!
    split_conv: bool = False       # conv x/B/C separately: keeps the x-conv
                                   # shard-aligned (concat slices a model-
                                   # sharded dim at non-boundary offsets)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 2560
    d_conv: int = 4
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    attn_window: int = 2048
    conv_variant: str = "xla"


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 6
    enc_frames: int = 1500          # stub frontend output length for serving
    max_positions: int = 32768      # learned decoder position table size


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    cross_every: int = 5            # 1 cross-attn layer per 5-layer superblock
    n_img_tokens: int = 1024        # stub vision-tower output length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0   # gemma3: different theta on global layers
    window: int = 0                  # 0 = full attention
    local_global_pattern: int = 0    # gemma3: N local layers per 1 global
    tie_embeddings: bool = False
    act: str = "silu"
    norm: str = "rms"                # rms | layer
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # family sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # training-step shape knobs (overridden per input-shape cell)
    microbatches: int = 1
    remat: bool = True
    attn_chunk_threshold: int = 8192  # use chunked attention at/above this seq

    # -- capability flags used by the dry-run matrix ------------------------
    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec included)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM/hybrid/mostly-local attention)."""
        return self.family in ("ssm", "hybrid") or self.local_global_pattern > 0

    @property
    def compute_dt(self):
        import jax.numpy as jnp

        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.compute_dtype]

    @property
    def param_dt(self):
        import jax.numpy as jnp

        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.param_dtype]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "long_decode"),
}
