"""Abstract capture of every ``pl.pallas_call`` launch — nothing executes.

The kernel wrappers in ``repro.kernels.ops`` are run under ``jax.eval_shape``
with ``pl.pallas_call`` replaced by a recorder: the wrapper's padding /
tiling / validation logic all runs for real (it is plain Python on static
shapes), but at the launch point we capture the grid, the per-operand
``BlockSpec``s (block shape + index map), the operand binding structure
(which traced array feeds which spec — halo kernels bind the same array
twice), the declared out shapes and the scratch allocations, then return
abstract zeros of the declared out shapes.  No Mosaic lowering, no
accelerator, no numerics — this is what lets the verifier sweep hundreds of
(path × variant × epilogue × shape) configurations in seconds on any host.

``repro.resilience.guard.run_guarded`` is replaced by a direct call of the
first attempt for the duration of the trace, so a kernel wrapper's
``ValueError`` (an illegal layout) propagates to the verifier instead of
being absorbed by the degradation chain.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ops
from repro.kernels.common import DWConvDims
from repro.kernels.epilogue import parse_epilogue
from repro.resilience import guard as _guard

# The (path, variant) pairs that lower through pl.pallas_call and are
# therefore cross-checkable.  Everything else in the registry ("xla",
# "split", the paper_* GPU models) is analytical-only.
PALLAS_VARIANTS = {
    "fwd": ("naive", "lane", "block", "row"),
    "bwd_in": ("naive", "lane", "block", "row"),
    "bwd_k": ("naive", "twostage", "accum"),
    "bwd_fused": ("fused", "fused_partials"),
    "decode": ("rows", "chanblock"),
}


@dataclasses.dataclass(frozen=True)
class SpecInfo:
    """One BlockSpec as captured at the launch site."""
    block_shape: Optional[Tuple[int, ...]]   # None: unblocked (pl.ANY / HBM ref)
    index_map: Optional[Callable]            # None: no map (unblocked)


@dataclasses.dataclass(frozen=True)
class ScratchInfo:
    kind: str                                # "vmem" | "sem" | "other"
    shape: Tuple[int, ...]
    dtype: str


@dataclasses.dataclass(frozen=True)
class PallasRecord:
    """Everything the verifier needs about one pallas_call launch."""
    kernel_name: str
    grid: Tuple[int, ...]
    in_specs: Tuple[SpecInfo, ...]
    out_specs: Tuple[SpecInfo, ...]
    operand_shapes: Tuple[Tuple[int, ...], ...]
    operand_dtypes: Tuple[str, ...]
    operand_groups: Tuple[int, ...]          # same id => same source array
    out_shapes: Tuple[Tuple[int, ...], ...]
    out_dtypes: Tuple[str, ...]
    scratch: Tuple[ScratchInfo, ...]


def _spec_info(spec: Any) -> SpecInfo:
    block = getattr(spec, "block_shape", None)
    if block is not None:
        block = tuple(1 if b is None else int(b) for b in block)
    return SpecInfo(block_shape=block, index_map=getattr(spec, "index_map", None))


def _scratch_info(s: Any) -> ScratchInfo:
    cls = type(s).__name__
    if "Semaphore" in cls or "semaphore" in str(getattr(s, "dtype", "")):
        return ScratchInfo("sem", (), "sem")
    shape = getattr(s, "shape", None)
    dtype = getattr(s, "dtype", None)
    if shape is not None and dtype is not None:
        return ScratchInfo("vmem", tuple(int(x) for x in shape), jnp.dtype(dtype).name)
    return ScratchInfo("other", (), cls)


@contextlib.contextmanager
def record_pallas_calls(records: List[PallasRecord]):
    """Patch pallas_call (recorder) and run_guarded (first attempt, no net)."""
    real_call = pl.pallas_call
    real_guard = _guard.run_guarded

    def fake_pallas_call(kernel, *args, **kwargs):
        out_shape = kwargs.get("out_shape", args[0] if args else None)
        grid = kwargs.get("grid", ())
        if isinstance(grid, int):
            grid = (grid,)
        in_specs = kwargs.get("in_specs") or ()
        out_specs = kwargs.get("out_specs")
        scratch_shapes = kwargs.get("scratch_shapes") or ()
        multi_out = isinstance(out_shape, (list, tuple))
        out_list = list(out_shape) if multi_out else [out_shape]
        specs_out = list(out_specs) if isinstance(out_specs, (list, tuple)) else [out_specs]
        fn = getattr(kernel, "func", kernel)     # unwrap functools.partial
        name = getattr(fn, "__name__", str(kernel))

        def runner(*operands):
            groups: dict = {}
            gids = tuple(groups.setdefault(id(a), len(groups)) for a in operands)
            records.append(PallasRecord(
                kernel_name=name,
                grid=tuple(int(g) for g in grid),
                in_specs=tuple(_spec_info(s) for s in in_specs),
                out_specs=tuple(_spec_info(s) for s in specs_out if s is not None),
                operand_shapes=tuple(tuple(a.shape) for a in operands),
                operand_dtypes=tuple(jnp.dtype(a.dtype).name for a in operands),
                operand_groups=gids,
                out_shapes=tuple(tuple(s.shape) for s in out_list),
                out_dtypes=tuple(jnp.dtype(s.dtype).name for s in out_list),
                scratch=tuple(_scratch_info(s) for s in scratch_shapes),
            ))
            outs = [jnp.zeros(s.shape, s.dtype) for s in out_list]
            return outs if multi_out else outs[0]

        return runner

    def direct_guard(path, **kw):
        variant, opts = kw["attempts"][0]
        return kw["run"](variant, opts)

    pl.pallas_call = fake_pallas_call
    _guard.run_guarded = direct_guard
    try:
        yield
    finally:
        pl.pallas_call = real_call
        _guard.run_guarded = real_guard


def trace_config(
    path: str,
    variant: str,
    d: DWConvDims,
    *,
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
    epilogue: str = "none",
    dtype: str = "float32",
) -> Tuple[List[PallasRecord], Optional[str]]:
    """Run one (path, variant, epilogue, shape) config abstractly.

    Returns ``(records, error)`` where ``error`` is the wrapper's
    ``ValueError`` text when the config is rejected as an illegal layout
    (the verifier cross-checks that verdict against ``check_legality``).
    """
    if variant not in PALLAS_VARIANTS.get(path, ()):
        raise ValueError(f"{path}/{variant} is not a traceable Pallas config")
    opts = ops.KernelOptions(block_h=block_h, block_t=block_t,
                             batch_chunk=batch_chunk, interpret=True)
    has_bias, act = parse_epilogue(epilogue)
    dt = jnp.dtype(dtype)
    x = jax.ShapeDtypeStruct((d.B, d.H, d.L), dt)
    k = jax.ShapeDtypeStruct((d.H, d.K), dt)
    bias = jax.ShapeDtypeStruct((d.H,), dt) if has_bias else None

    if path == "fwd":
        fn = lambda x_, k_, b_: ops.dwconv_fwd_op(
            x_, k_, d.padding, variant, opts, bias=b_, act=act)
        fargs = (x, k, bias)
    elif path == "bwd_in":
        fn = lambda dy_, k_: ops.dwconv_bwd_input_op(dy_, k_, d.padding, variant, opts)
        fargs = (x, k)
    elif path == "bwd_k":
        fn = lambda x_, dy_: ops.dwconv_bwd_kernel_op(x_, dy_, d.K, d.padding, variant, opts)
        fargs = (x, x)
    elif path == "bwd_fused":
        if epilogue == "none":
            fn = lambda x_, dy_, k_: ops.dwconv_bwd_fused_op(
                x_, dy_, k_, d.padding, variant, opts)
            fargs = (x, x, k)
        else:
            fn = lambda x_, dy_, k_, b_: ops.dwconv_bwd_fused_act_op(
                x_, dy_, k_, b_, d.padding, variant, opts, act=act)
            fargs = (x, x, k, bias)
    elif path == "decode":
        ring = jax.ShapeDtypeStruct((d.B, d.H, max(d.K - 1, 0)), dt)
        xstep = jax.ShapeDtypeStruct((d.B, d.H), dt)
        fn = lambda r_, x_, k_, b_: ops.dwconv_decode_op(
            r_, x_, k_, variant, opts, bias=b_, act=act)
        fargs = (ring, xstep, k, bias)
    else:
        raise ValueError(f"unknown path {path!r}")

    records: List[PallasRecord] = []
    with record_pallas_calls(records):
        try:
            jax.eval_shape(fn, *fargs)
        except ValueError as e:
            return records, str(e)
    return records, None
