"""Resilience report CLI — what degraded, and what is quarantined.

  PYTHONPATH=src python -m repro.resilience.report \\
      --trace CHAOS_train.jsonl --cache results/tuning/cache.json \\
      --out CHAOS_report.json

Collects (1) every ``kind="degradation"`` record from one or more span
traces (``repro.obs.trace`` JSONL), (2) the quarantined entries of a tuning
cache (schema v6), and (3) the current process's in-memory ledger when run
programmatically, into a single JSON artifact.  The chaos CI job uploads it
next to the degradation-event JSONL so a failed run is diagnosable from
artifacts alone.  ``--fail-on-quarantine`` exits nonzero when quarantined
entries exist (for gating a cache artifact before fleet export).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional


def build_report(trace_paths: List[str], cache_path: Optional[str],
                 include_process_ledger: bool = False) -> Dict[str, Any]:
    from repro.obs.trace import read_trace

    degradations: List[Dict[str, Any]] = []
    for tp in trace_paths:
        try:
            records = read_trace(tp)
        except OSError as e:
            print(f"[resilience.report] cannot read trace {tp}: {e}",
                  file=sys.stderr, flush=True)
            continue
        for rec in records:
            if rec.get("kind") == "degradation":
                degradations.append({"trace": tp, **rec})

    if include_process_ledger:
        from repro.resilience import guard

        degradations.extend({"trace": "<in-process>", **e}
                            for e in guard.degradation_events())

    quarantined: List[Dict[str, Any]] = []
    cache_entries = 0
    if cache_path:
        from repro.tuning.cache import TuningCache

        cache = TuningCache(cache_path)
        for key, entry in cache.items().items():
            cache_entries += 1
            if entry.quarantined:
                quarantined.append({"key": key.encode(),
                                    "variant": entry.variant,
                                    "reason": entry.quarantine_reason})

    by_site: Dict[str, int] = {}
    for d in degradations:
        by_site[d.get("site", "?")] = by_site.get(d.get("site", "?"), 0) + 1
    return {
        "degradations": degradations,
        "degradations_by_site": dict(sorted(by_site.items())),
        "quarantined": quarantined,
        "cache_entries": cache_entries,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", action="append", default=[],
                    help="span-trace JSONL to scan for degradation records "
                         "(repeatable)")
    ap.add_argument("--cache", default="",
                    help="tuning-cache JSON to scan for quarantined entries")
    ap.add_argument("--out", default="",
                    help="write the full report JSON here")
    ap.add_argument("--fail-on-quarantine", action="store_true",
                    help="exit 1 when any cache entry is quarantined")
    args = ap.parse_args(argv)

    rep = build_report(args.trace, args.cache or None)
    print(f"[resilience.report] {len(rep['degradations'])} degradation "
          f"event(s) across {len(args.trace)} trace(s); "
          f"{len(rep['quarantined'])}/{rep['cache_entries']} cache entries "
          f"quarantined", flush=True)
    for site, n in rep["degradations_by_site"].items():
        print(f"  {site}: {n}", flush=True)
    for q in rep["quarantined"]:
        print(f"  quarantined: {q['key']} ({q['variant']}): {q['reason']}",
              flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=1)
        print(f"[resilience.report] wrote {args.out}", flush=True)
    if args.fail_on_quarantine and rep["quarantined"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
