"""Unit tests for the logical-axis sharding rule engine, including the
divisibility-aware fallback that drives §Perf pair D."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    LONG_SERVE_RULES,
    SERVE_RULES,
    TRAIN_RULES,
    logical_to_spec,
    spec_for_axes,
)
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_basic_mapping(mesh):
    spec = logical_to_spec(("act_batch", "act_seq", "act_embed"), TRAIN_RULES,
                           mesh, (8, 16, 32))
    # pod missing from this mesh -> only data survives for act_batch
    assert spec == P("data", None, None)


def test_no_duplicate_mesh_axes(mesh):
    # act_heads takes `model`; act_attn_q must NOT reuse it
    spec = logical_to_spec(("act_batch", "act_heads", "act_attn_q", None),
                           TRAIN_RULES, mesh, (8, 16, 4096, 4096))
    assert spec == P("data", "model", None, None)


def test_divisibility_fallback_to_seq(mesh):
    # 14 heads on a 16-wide model axis: heads cannot shard -> the
    # query-sequence dim claims `model` instead (pair D mechanism).
    big = make_mesh((1, 16), ("data", "model")) if jax.device_count() >= 16 else None
    if big is None:
        # emulate with shape math on the 1x1 mesh by checking the rule order
        spec = logical_to_spec(("act_batch", "act_heads", "act_attn_q", None),
                               TRAIN_RULES, mesh, (8, 14, 4096, 4096))
        # on a 1-wide axis everything divides; heads keep it
        assert spec == P("data", "model", None, None)
        return
    spec = logical_to_spec(("act_batch", "act_heads", "act_attn_q", None),
                           TRAIN_RULES, big, (8, 14, 4096, 4096))
    assert spec == P("data", None, "model", None)


def test_non_divisible_dim_left_unsharded(mesh):
    spec = spec_for_axes(("vocab", "embed"), mesh, "train", (50280, 64))
    assert spec.spec[1] == "data" or spec.spec[1] is None


def test_serve_rules_shard_cache_seq():
    assert SERVE_RULES["cache_seq"] == "model"
    assert LONG_SERVE_RULES["cache_seq"] == ("data", "model")
    assert SERVE_RULES["embed"] == "data"  # 2D weight sharding at serve


def test_missing_rule_is_replicated(mesh):
    spec = logical_to_spec(("nonexistent_axis", None), TRAIN_RULES, mesh, (4, 4))
    assert spec == P(None, None)
