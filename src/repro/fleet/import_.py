"""Validated bundle import: the fleet's hostile-input consumption side.

The chain a bundle runs before any of its decisions can serve dispatch::

    signature check            (fleet/bundle.read_bundle — HMAC over
      |                         canonical JSON; any flipped byte fails)
    schema migration           (the cache's own v2–v6 path: per-entry
      |                         _migration_drops + key re-encoding)
    fingerprint gate           (exact obs.calibrate.device_fingerprint()
      |                         match -> *trusted*; mismatch -> *advisory*)
    quarantine filter          (quarantined entries are dropped, or the
      |                         whole bundle rejected under strict=True)
    three-way merge            (TuningCache.merge_entries: flock-guarded,
                                measured-runtime-wins)

Trust levels:

  * **trusted** — the bundle was measured on hardware with the same device
    fingerprint; its entries merge into the local flock-guarded cache and
    serve ``variant="auto"`` dispatch directly (warm start: zero metered
    candidates for covered shapes);
  * **advisory** — a foreign fingerprint.  Entries land in an in-process
    side table only: dispatch may use them as a *hint* when the local cache
    has nothing, and the tuner seeds its stage-2 candidate order with them,
    but they are never persisted as measured decisions and never bypass
    measurement.

Failure posture: :func:`import_bundle_guarded` absorbs every
:class:`~repro.resilience.faults.BundleIntegrityError` (and plain I/O
errors) into a ``kind="degradation"`` trace record and returns ``None`` —
the replica's local cache stays byte-identical and it simply tunes fresh.
A bad bundle must never crash a serving replica.
"""
from __future__ import annotations

import dataclasses
import sys
import threading
from pathlib import Path
from typing import Dict, Optional

from repro.fleet import bundle as bundle_mod
from repro.resilience import faults, guard
from repro.resilience.faults import BundleIntegrityError
from repro.tuning.cache import (
    CACHE_VERSION,
    ShapeKey,
    TuneEntry,
    TuningCache,
    _migration_drops,
    default_cache,
)

__all__ = [
    "ImportResult",
    "advisory_entry",
    "advisory_entries",
    "clear_advisory",
    "import_bundle",
    "import_bundle_guarded",
    "register_advisory",
]


def _warn(msg: str) -> None:
    print(f"[fleet.import] {msg}", file=sys.stderr, flush=True)


# ---------------------------------------------------------------------------
# advisory side table (in-process only — advisory decisions are hints, so
# they must never survive into the persisted cache as measured entries)
# ---------------------------------------------------------------------------

_ADVISORY: Dict[str, TuneEntry] = {}
_ADVISORY_LOCK = threading.Lock()


def register_advisory(key_str: str, entry: TuneEntry) -> None:
    with _ADVISORY_LOCK:
        _ADVISORY[key_str] = dataclasses.replace(entry, source="advisory")


def advisory_entry(key_str: str) -> Optional[TuneEntry]:
    """The advisory hint for an encoded :class:`ShapeKey`, if any."""
    with _ADVISORY_LOCK:
        return _ADVISORY.get(key_str)


def advisory_entries() -> Dict[str, TuneEntry]:
    with _ADVISORY_LOCK:
        return dict(_ADVISORY)


def clear_advisory() -> None:
    """Drop every advisory hint (tests; or after re-tuning a fleet)."""
    with _ADVISORY_LOCK:
        _ADVISORY.clear()


# ---------------------------------------------------------------------------
# import chain
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ImportResult:
    """What one validated import did, for logs/tests/CLI output."""

    bundle: str
    bundle_id: str
    fingerprint: str        # the bundle's manifest fingerprint
    local_fingerprint: str  # this replica's device fingerprint
    trusted: int = 0        # entries merged into the local cache
    advisory: int = 0       # entries registered as hints only
    dropped_quarantined: int = 0
    dropped_stale: int = 0  # lost to schema migration / unparseable entries
    inserted: int = 0       # merge stats (trusted path only)
    replaced: int = 0
    kept_local: int = 0

    @property
    def is_trusted(self) -> bool:
        return self.fingerprint == self.local_fingerprint

    def summary(self) -> str:
        mode = "trusted" if self.is_trusted else "advisory"
        return (f"bundle {self.bundle_id[:16]} [{mode}] "
                f"trusted={self.trusted} advisory={self.advisory} "
                f"dropped_quarantined={self.dropped_quarantined} "
                f"dropped_stale={self.dropped_stale} "
                f"merge(ins={self.inserted} repl={self.replaced} "
                f"kept={self.kept_local})")


def _local_fingerprint() -> str:
    from repro.obs.calibrate import device_fingerprint

    fp = device_fingerprint()
    if faults.should_fire("bundle/stale-fingerprint"):
        # Injected hardware drift: this replica now reports a fingerprint no
        # exported bundle carries, so every import must downgrade to
        # advisory — warm start off, measurement still mandatory.
        fp = f"{fp}+stale-fault"
    return fp


def import_bundle(path, cache: Optional[TuningCache] = None, *,
                  key: Optional[str] = None,
                  strict: bool = False) -> ImportResult:
    """Run one bundle through the full validated import chain.

    Raises :class:`BundleIntegrityError` on any integrity defect — and,
    under ``strict``, on the *presence* of quarantined entries (the import
    twin of ``resilience.report --fail-on-quarantine`` and of strict
    export).  On the non-strict path quarantined entries are dropped here,
    so a quarantine can never cross the fleet boundary into a replica that
    never observed the failure.
    """
    payload = bundle_mod.read_bundle(path, key=key)
    manifest = payload["manifest"]
    bundle_id = str(manifest.get("content_id", ""))
    version = payload["cache_version"]

    # --- quarantine filter + per-entry parse + schema migration ----------
    entries: Dict[str, TuneEntry] = {}
    dropped_q = 0
    dropped_stale = 0
    quarantined_keys = []
    for key_str, ed in payload["entries"].items():
        try:
            entry = bundle_mod.parse_entry(ed)
        except (TypeError, KeyError, ValueError):
            dropped_stale += 1
            continue
        if entry.quarantined:
            quarantined_keys.append(key_str)
            continue
        if version != CACHE_VERSION:
            if _migration_drops(key_str, entry, version):
                dropped_stale += 1
                continue
            try:
                key_str = ShapeKey.decode(key_str).encode()
            except (KeyError, ValueError):
                dropped_stale += 1
                continue
        else:
            try:  # a signed bundle can still carry a garbage key string
                ShapeKey.decode(key_str)
            except (KeyError, ValueError):
                dropped_stale += 1
                continue
        entries[key_str] = entry
    if quarantined_keys:
        if strict:
            raise BundleIntegrityError(
                f"bundle {path} carries {len(quarantined_keys)} quarantined "
                f"entr{'y' if len(quarantined_keys) == 1 else 'ies'} "
                f"({', '.join(quarantined_keys)}); rejected under strict "
                f"import")
        dropped_q = len(quarantined_keys)
        _warn(f"dropped {dropped_q} quarantined entr"
              f"{'y' if dropped_q == 1 else 'ies'} at import: "
              f"{', '.join(quarantined_keys)}")

    # --- fingerprint gate -------------------------------------------------
    local_fp = _local_fingerprint()
    bundle_fp = str(manifest.get("fingerprint", ""))
    result = ImportResult(bundle=str(path), bundle_id=bundle_id,
                          fingerprint=bundle_fp, local_fingerprint=local_fp,
                          dropped_quarantined=dropped_q,
                          dropped_stale=dropped_stale)

    if bundle_fp == local_fp:
        # Trusted: same hardware measured these decisions.  Merge into the
        # local flock-guarded cache (measured-runtime-wins) and let them
        # serve dispatch directly.
        the_cache = cache if cache is not None else default_cache()
        tagged = {
            k: dataclasses.replace(e, source=f"bundle:{bundle_id[:12]}")
            for k, e in entries.items()}
        stats = the_cache.merge_entries(tagged)
        result.trusted = len(tagged)
        result.inserted = stats["inserted"]
        result.replaced = stats["replaced"]
        result.kept_local = stats["kept_local"]
    else:
        # Advisory: foreign hardware.  Hints only — dispatch may borrow
        # them when the local cache is empty, the tuner seeds stage 2 with
        # them, but nothing is persisted and nothing bypasses measurement.
        _warn(f"bundle {path} fingerprint {bundle_fp!r} != local "
              f"{local_fp!r}: importing {len(entries)} entries as advisory "
              f"(tuner hints; measurement still required)")
        for k, e in entries.items():
            register_advisory(k, e)
        result.advisory = len(entries)
    _warn(result.summary())
    return result


def import_bundle_guarded(path, cache: Optional[TuningCache] = None, *,
                          key: Optional[str] = None,
                          strict: bool = False) -> Optional[ImportResult]:
    """:func:`import_bundle`, degraded instead of raised.

    Any integrity or I/O failure becomes a ``kind="degradation"`` trace
    record at site ``bundle/import`` and a ``None`` return: the local cache
    is untouched and the caller tunes fresh.  This is the entry point every
    serving surface (``default_cache`` auto-import, ``launch/serve.py
    --bundle``, the replica sim) uses — a hostile bundle must never crash a
    replica.
    """
    try:
        return import_bundle(path, cache, key=key, strict=strict)
    except (BundleIntegrityError, OSError) as e:
        guard.record_degradation(
            "bundle/import", bundle=str(path),
            error=f"{type(e).__name__}: {e}",
            action="bundle dropped; local cache untouched; tuning fresh")
        return None
