"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--fast`` trims iteration
counts (used by CI); ``--only <prefix>`` filters benchmarks; ``--json
<path>`` additionally writes machine-readable results (conventionally
``BENCH_kernels.json``) so the perf trajectory is recorded per run.

Modules are imported *lazily, per module*: an ``--only paper_epilogue``
run never pays for (or dies on) importing unrelated benchmark modules —
an import failure is charged to the module that failed, not the harness.

A module may export ``top_level_metrics(rows) -> dict`` to promote derived
quantities (e.g. the fused-vs-split backward speedup, the epilogue fusion
speedup) to top-level keys of the ``--json`` payload; the harness itself
no longer hard-codes any row-parsing regex.

A module may signal a soft failure by emitting a row whose ``derived``
contains ``FAILED`` (e.g. the e2e convergence check): the remaining rows
still print, but the harness exits nonzero.

Every ``--json`` run also appends its numeric top-level metrics to the
perf-trajectory ledger (``repro.obs.ledger``; opt out with ``--no-ledger``),
so ``python -m repro.launch.perf --check`` can gate regressions across runs.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import traceback

# Declaration order is execution order; names only — nothing imports until
# the module is actually selected.
MODULE_NAMES = [
    "paper_table2",
    "paper_table3",
    "paper_roofline",
    "paper_report",
    "paper_validation",
    "paper_autotune",
    "paper_fused_bwd",
    "paper_longseq",
    "paper_epilogue",
    "paper_decode",
    "s4convd_e2e",
    "roofline_table",
    "paper_fleet",
]

# --json keys that must exist (as null) even when their module didn't run,
# so downstream dashboards never key-error on an --only subset.
_STABLE_METRIC_KEYS = (
    "fused_vs_split_backward_speedup",
    "epilogue_fused_speedup",
    "report_memory_bound_fraction",
    "fleet_warm_metered_candidates",
    "decode_tokens_per_s",
    "decode_p99_step_s",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write machine-readable results (BENCH_kernels.json)")
    ap.add_argument("--no-ledger", action="store_true",
                    help="do not append --json metrics to the perf ledger")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    results = []
    metrics = {k: None for k in _STABLE_METRIC_KEYS}
    for name in MODULE_NAMES:
        if args.only and not name.startswith(args.only):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = list(mod.run(fast=args.fast))
            for row in rows:
                print(f"{row.name},{row.us_per_call:.1f},{row.derived}")
                results.append({"name": row.name, "us_per_call": row.us_per_call,
                                "derived": row.derived})
                if "FAILED" in row.derived:
                    failures += 1
            hook = getattr(mod, "top_level_metrics", None)
            if hook is not None:
                metrics.update(hook(rows))
            else:
                print(f"# note: benchmarks.{name} exports no top_level_metrics "
                      f"hook — its rows are not promoted to the --json payload "
                      f"or the perf ledger", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR", file=sys.stdout)
            results.append({"name": name, "us_per_call": 0.0, "derived": "ERROR"})
            traceback.print_exc()
    if args.json:
        payload = dict(metrics)
        payload.update({"failures": failures, "results": results})
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(results)} rows to {args.json}", file=sys.stderr)
        if not args.no_ledger:
            # best-effort: a broken ledger must never fail the benchmark run
            try:
                from repro.obs.ledger import append_entry, numeric_metrics

                nums = numeric_metrics(payload)
                if nums:
                    entry = append_entry(nums, source=f"benchmarks/run.py"
                                         f"{' --only ' + args.only if args.only else ''}")
                    print(f"# ledger: appended {len(nums)} metrics @ {entry.sha}",
                          file=sys.stderr)
            except Exception as e:
                print(f"# ledger: append skipped ({e})", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
