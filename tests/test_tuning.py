"""Autotuner subsystem tests (repro.tuning): search-space legality (every
emitted candidate actually executes and matches the XLA oracle), cache
round-trip + versioning + env override, deterministic tuning under a stubbed
timer, and ``variant="auto"`` dispatch equivalence in ``kernels/ops.py``.

All execution happens on tiny shapes in interpret mode; no timing assertions
are made here (that is ``benchmarks/paper_autotune.py``'s job).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.common import DWConvDims
from repro.tuning import cache as tcache
from repro.tuning import cost, space, tuner
from repro.tuning.cache import ShapeKey, TuneEntry, TuningCache
from repro.tuning.space import Candidate

# Small enough to execute every candidate in interpret mode, but with the
# paper's L=K geometry represented.
SMALL_DIMS = DWConvDims(B=2, H=4, L=48, K=5)
PAPERISH_DIMS = DWConvDims(B=2, H=4, L=48, K=48)


def _rand(shape, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Point the process-wide default cache at a fresh tmp file."""
    p = tmp_path / "cache.json"
    monkeypatch.setenv(tcache.CACHE_ENV_VAR, str(p))
    tcache.reset_default_cache()
    yield p
    tcache.reset_default_cache()


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [SMALL_DIMS, PAPERISH_DIMS], ids=["K5", "K48"])
@pytest.mark.parametrize("path", space.PATHS)
def test_search_space_nonempty_normalized_legal(d, path):
    cands = space.search_space(d, path)
    assert cands, f"empty search space for {path}"
    seen = set()
    for c in cands:
        assert c.path == path
        ok, reason = space.is_legal(c, d)
        assert ok, reason
        assert space.normalize(c, d) == c, "emitted candidate not normalized"
        assert c not in seen, "duplicate candidate emitted"
        seen.add(c)
    # the hard-coded defaults and the reference/split escape hatches are
    # always in-space
    variants = {c.variant for c in cands}
    if path == "bwd_fused":
        assert "split" in variants and "fused" in variants
    else:
        assert "xla" in variants
        default = {"bwd_k": "accum", "decode": "rows"}.get(path, "row")
        assert default in variants


@pytest.mark.parametrize("path", space.PATHS)
def test_every_emitted_candidate_executes_and_matches_oracle(path):
    """Legality predicates really mirror the kernel asserts: run everything."""
    d = SMALL_DIMS
    x = _rand((d.B, d.H, d.L), 0)
    k = _rand((d.H, d.K), 1)
    dy = _rand((d.B, d.H, d.L), 2)
    if path == "fwd":
        want = ref.dwconv_fwd_ref(x, k, d.padding)
    elif path == "bwd_in":
        want = ref.dwconv_bwd_input_ref(dy, k, d.padding)
    elif path == "bwd_fused":
        want = (ref.dwconv_bwd_input_ref(dy, k, d.padding),
                ref.dwconv_bwd_kernel_ref(x, dy, d.K, d.padding))
    else:
        want = ref.dwconv_bwd_kernel_ref(x, dy, d.K, d.padding)
    for c in space.search_space(d, path):
        opts = c.options(interpret=True)
        if path == "fwd":
            got = (ref.dwconv_fwd_ref(x, k, d.padding) if c.variant == "xla"
                   else ops.dwconv_fwd_op(x, k, d.padding, c.variant, opts))
        elif path == "bwd_in":
            got = (ref.dwconv_bwd_input_ref(dy, k, d.padding) if c.variant == "xla"
                   else ops.dwconv_bwd_input_op(dy, k, d.padding, c.variant, opts))
        elif path == "bwd_fused":
            dx, dk = ops.dwconv_bwd_fused_op(x, dy, k, d.padding, c.variant, opts)
            np.testing.assert_allclose(np.asarray(dx), np.asarray(want[0]),
                                       atol=1e-4,
                                       err_msg=f"candidate {c} dx diverges")
            np.testing.assert_allclose(np.asarray(dk), np.asarray(want[1]),
                                       atol=2e-3,
                                       err_msg=f"candidate {c} dk diverges")
            continue
        else:
            got = (ref.dwconv_bwd_kernel_ref(x, dy, d.K, d.padding) if c.variant == "xla"
                   else ops.dwconv_bwd_kernel_op(x, dy, d.K, d.padding, c.variant, opts))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                                   err_msg=f"candidate {c} diverges from oracle")


def test_illegal_candidates_are_rejected_with_reason():
    d = SMALL_DIMS
    ok, reason = space.is_legal(Candidate("fwd", "naive", block_t=100), d)
    # naive requires lane alignment; 100 < Lout so it is NOT clamped away
    assert not ok and "lane" in reason
    ok, reason = space.is_legal(Candidate("bwd_k", "row"), d)
    assert not ok and "not applicable" in reason
    ok, reason = space.is_legal(Candidate("fwd", "block", block_t=0), d)
    assert not ok
    with pytest.raises(ValueError):
        space.search_space(d, "sideways")


def test_neighbors_reach_both_straddling_lattice_points():
    """A clamped off-lattice knob (block_h=12 with H=12) must offer BOTH
    adjacent lattice values (8 and 16->clamped) as single hillclimb moves."""
    d = DWConvDims(B=2, H=12, L=48, K=5)
    c = space.normalize(Candidate("fwd", "block", block_h=12), d)
    assert c.block_h == 12
    hs = {m.block_h for m in space.neighbors(c, d) if m.variant == "block"}
    assert 8 in hs, "lower straddling lattice point unreachable in one move"


def test_neighbors_are_legal_single_moves():
    d = PAPERISH_DIMS
    c = space.normalize(Candidate("fwd", "row"), d)
    moves = space.neighbors(c, d)
    assert moves, "hillclimb move set empty"
    for m in moves:
        assert m != c
        assert space.is_legal(m, d)[0]
        assert space.normalize(m, d) == m


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

KEY = ShapeKey(path="fwd", B=64, H=128, L=48, K=48, dtype="float32", backend="cpu")
ENTRY = TuneEntry(variant="block", block_h=4, block_t=512, batch_chunk=128,
                  time_us=12.5, analytical_time_us=10.0)


def test_cache_round_trip(tmp_path):
    p = tmp_path / "db.json"
    TuningCache(p).put(KEY, ENTRY)
    assert p.exists()
    reloaded = TuningCache(p)  # fresh instance: forces disk read
    got = reloaded.get(KEY)
    assert got == ENTRY
    assert len(reloaded) == 1
    assert reloaded.items() == {KEY: ENTRY}
    # key codec is its own inverse
    assert ShapeKey.decode(KEY.encode()) == KEY


def test_cache_version_mismatch_ignored(tmp_path):
    p = tmp_path / "db.json"
    c = TuningCache(p)
    c.put(KEY, ENTRY)
    raw = json.loads(p.read_text())
    raw["version"] = tcache.CACHE_VERSION + 1
    p.write_text(json.dumps(raw))
    assert TuningCache(p).get(KEY) is None, "stale-schema entry was applied"


def test_cache_corrupt_file_starts_empty(tmp_path):
    p = tmp_path / "db.json"
    p.write_text("{not json")
    c = TuningCache(p)
    assert c.get(KEY) is None
    c.put(KEY, ENTRY)  # save must rewrite the corrupt file
    assert TuningCache(p).get(KEY) == ENTRY


def test_padding_is_part_of_the_shape_key(tmp_cache):
    """'same' and 'causal' tunings of equal dims must not collide, and auto
    dispatch must only see the entry for its own padding."""
    same = ShapeKey(path="fwd", B=2, H=4, L=48, K=5, dtype="float32",
                    backend=jax.default_backend(), padding="same")
    causal = ShapeKey(path="fwd", B=2, H=4, L=48, K=5, dtype="float32",
                      backend=jax.default_backend(), padding="causal")
    assert same.encode() != causal.encode()
    tcache.default_cache().put(causal, TuneEntry(
        variant="lane", block_h=2, block_t=256, batch_chunk=2))
    # dispatch under 'same' padding misses the causal entry -> fallback
    v, _ = ops.resolve_variant("fwd", "auto", None, B=2, H=4, L=48, K=5,
                               dtype=jnp.float32, padding="same")
    assert v == ops.AUTO_FALLBACK["fwd"]
    v, _ = ops.resolve_variant("fwd", "auto", None, B=2, H=4, L=48, K=5,
                               dtype=jnp.float32, padding="causal")
    assert v == "lane"
    # tuner keys carry the problem's padding
    dd = DWConvDims(B=2, H=4, L=48, K=5, padding="causal")
    res = tuner.tune_path(dd, "fwd", budget=2, measure_fn=_stub_measure,
                          persist=False)
    assert res.key.padding == "causal"


def test_cache_env_override_and_memoization(tmp_cache):
    c1 = tcache.default_cache()
    assert str(c1.path) == str(tmp_cache)
    assert tcache.default_cache() is c1, "default cache not memoized"
    c1.put(KEY, ENTRY)
    assert tcache.lookup("fwd", 64, 128, 48, 48, "float32", "cpu") == ENTRY
    assert tcache.lookup("fwd", 64, 128, 48, 47, "float32", "cpu") is None


# ---------------------------------------------------------------------------
# tuner (stubbed timer: deterministic, no real measurement)
# ---------------------------------------------------------------------------


def _stub_measure(c, d):
    """Deterministic fake clock: 'block' with block_h=4 is the planted winner."""
    t = 100.0
    if c.variant == "block":
        t -= 50.0
    t += abs(c.block_h - 4)
    return t + 1e-3 * (c.block_t / 512) + 1e-4 * (c.batch_chunk / 128)


@pytest.mark.parametrize("search", ["grid", "hillclimb"])
def test_tuner_is_deterministic_and_respects_budget(search, tmp_path):
    d = PAPERISH_DIMS
    cache = TuningCache(tmp_path / "db.json")
    res1 = tuner.tune_path(d, "fwd", budget=6, search=search,
                           measure_fn=_stub_measure, cache=cache)
    res2 = tuner.tune_path(d, "fwd", budget=6, search=search,
                           measure_fn=_stub_measure, cache=cache)
    assert res1.best == res2.best, "tuning not deterministic under a fixed timer"
    assert res1.candidates_measured <= 6
    assert res1.candidates_considered >= res1.candidates_measured
    # winner == argmin of the stub over everything actually measured
    best_measured = min(res1.history, key=lambda h: h[2])
    assert res1.best.variant == best_measured[0].variant
    # the decision was persisted under the right key
    got = cache.get(res1.key)
    assert got is not None and got.variant == res1.best.variant
    assert res1.key.path == "fwd" and res1.key.B == d.B and res1.key.K == d.K


def test_grid_finds_planted_winner_with_full_budget(tmp_path):
    d = PAPERISH_DIMS
    cache = TuningCache(tmp_path / "db.json")
    res = tuner.tune_path(d, "fwd", budget=10_000, search="grid",
                          measure_fn=_stub_measure, cache=cache)
    assert res.best.variant == "block"
    assert res.best.block_h == 4
    assert res.best.time_us == pytest.approx(min(h[2] for h in res.history) * 1e6)


def test_tune_shape_covers_all_paths(tmp_path):
    cache = TuningCache(tmp_path / "db.json")
    out = tuner.tune_shape(SMALL_DIMS, budget=6, measure_fn=_stub_measure,
                           cache=cache)
    assert set(out) == set(space.PATHS)
    assert len(cache) == len(space.PATHS)


def test_tuner_rejects_bad_inputs():
    with pytest.raises(ValueError):
        tuner.tune_path(SMALL_DIMS, "fwd", budget=0, measure_fn=_stub_measure)
    with pytest.raises(ValueError):
        tuner.tune_path(SMALL_DIMS, "fwd", search="anneal", measure_fn=_stub_measure)


def test_analytical_rank_is_total_and_positive():
    d = PAPERISH_DIMS
    cands = space.search_space(d, "fwd")
    ranked = cost.rank_candidates(cands, d)
    assert [c for c, _ in ranked[:3]] == [c for c, _ in cost.rank_candidates(cands, d, top_n=3)]
    assert all(t > 0 for _, t in ranked)
    times = [t for _, t in ranked]
    assert times == sorted(times)


# ---------------------------------------------------------------------------
# variant="auto" dispatch through ops.py
# ---------------------------------------------------------------------------


def test_auto_falls_back_to_row_without_cache_entry(tmp_cache):
    d = SMALL_DIMS
    x, k = _rand((d.B, d.H, d.L), 0), _rand((d.H, d.K), 1)
    auto = ops.dwconv_fwd_op(x, k, d.padding, "auto", ops.KernelOptions(interpret=True))
    row = ops.dwconv_fwd_op(x, k, d.padding, "row", ops.KernelOptions(interpret=True))
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(row))


def test_auto_resolves_cached_entry_and_matches_reference(tmp_cache):
    d = SMALL_DIMS
    backend = jax.default_backend()
    for path, variant in (("fwd", "block"), ("bwd_in", "lane"), ("bwd_k", "twostage")):
        tcache.default_cache().put(
            ShapeKey(path=path, B=d.B, H=d.H, L=d.L, K=d.K,
                     dtype="float32", backend=backend),
            TuneEntry(variant=variant, block_h=2, block_t=256, batch_chunk=2),
        )
    x, k, dy = _rand((d.B, d.H, d.L), 0), _rand((d.H, d.K), 1), _rand((d.B, d.H, d.L), 2)
    opts = ops.KernelOptions(block_h=2, block_t=256, batch_chunk=2, interpret=True)

    v, o = ops.resolve_variant("fwd", "auto", None, B=d.B, H=d.H, L=d.L, K=d.K,
                               dtype=jnp.float32)
    assert v == "block" and (o.block_h, o.block_t, o.batch_chunk) == (2, 256, 2)
    # explicit opts win over cached tiling
    _, o2 = ops.resolve_variant("fwd", "auto", opts, B=d.B, H=d.H, L=d.L, K=d.K,
                                dtype=jnp.float32)
    assert o2 is opts

    # opts=None: the cached tiling itself is exercised (interpret auto-resolves)
    np.testing.assert_allclose(
        np.asarray(ops.dwconv_fwd_op(x, k, d.padding, "auto")),
        np.asarray(ref.dwconv_fwd_ref(x, k, d.padding)), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ops.dwconv_bwd_input_op(dy, k, d.padding, "auto")),
        np.asarray(ref.dwconv_bwd_input_ref(dy, k, d.padding)), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ops.dwconv_bwd_kernel_op(x, dy, d.K, d.padding, "auto")),
        np.asarray(ref.dwconv_bwd_kernel_ref(x, dy, d.K, d.padding)), atol=1e-4)


def test_auto_with_illegal_explicit_opts_falls_back_safely(tmp_cache):
    """Cached variant + caller tiling that violates its kernel asserts must
    drop to the fallback variant, not crash inside Pallas."""
    d = SMALL_DIMS
    tcache.default_cache().put(
        ShapeKey(path="fwd", B=d.B, H=d.H, L=d.L, K=d.K,
                 dtype="float32", backend=jax.default_backend()),
        TuneEntry(variant="lane", block_h=8, block_t=512, batch_chunk=128),
    )
    bad = ops.KernelOptions(block_t=100, interpret=True)  # Lt=100: not lane-aligned
    v, o = ops.resolve_variant("fwd", "auto", bad, B=d.B, H=d.H, L=d.L, K=d.K,
                               dtype=jnp.float32)
    assert v == ops.AUTO_FALLBACK["fwd"] and o is bad
    x, k = _rand((d.B, d.H, d.L), 0), _rand((d.H, d.K), 1)
    got = ops.dwconv_fwd_op(x, k, d.padding, "auto", bad)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.dwconv_fwd_ref(x, k, d.padding)),
                               atol=1e-5)
    # a *legal* explicit tiling still gets the cached variant
    good = ops.KernelOptions(block_t=128, interpret=True)
    v, o = ops.resolve_variant("fwd", "auto", good, B=d.B, H=d.H, L=d.L, K=d.K,
                               dtype=jnp.float32)
    assert v == "lane" and o is good


def test_concurrent_cache_writers_merge_disjoint_keys(tmp_path):
    """Two cache instances sharing one file must not clobber each other's
    disjoint entries on save (the shared-artifact cluster workflow)."""
    p = tmp_path / "shared.json"
    a, b = TuningCache(p), TuningCache(p)
    key_b = ShapeKey(path="bwd_k", B=8, H=4, L=48, K=5, dtype="float32", backend="cpu")
    a.get(KEY)  # both load the (empty) file before either writes
    b.get(KEY)
    a.put(KEY, ENTRY)
    b.put(key_b, TuneEntry(variant="accum", block_h=2, block_t=512, batch_chunk=8))
    fresh = TuningCache(p)
    assert fresh.get(KEY) == ENTRY
    assert fresh.get(key_b) is not None


def test_interleaved_concurrent_saves_drop_no_entries(tmp_path):
    """Simulated cross-process interleaving: many writers, each with its own
    cache instance (distinct in-process locks, exactly like separate tuner
    processes sharing ``REPRO_TUNE_CACHE``), save disjoint keys
    concurrently.  The inter-process file lock makes read-merge-replace
    atomic, so no last-writer-wins lost update may drop an entry."""
    import threading

    p = tmp_path / "shared.json"
    n_threads, per_thread = 6, 4
    barrier = threading.Barrier(n_threads)
    errors = []

    def writer(tid):
        try:
            cache = TuningCache(p)  # own instance: no shared threading.Lock
            barrier.wait()
            for i in range(per_thread):
                key = ShapeKey(path="bwd_k", B=2 ** tid, H=4, L=48 + i,
                               K=5, dtype="float32", backend="cpu")
                cache.put(key, TuneEntry(variant="accum", block_h=2,
                                         block_t=128, batch_chunk=8))
        except Exception as e:  # pragma: no cover - surfaced via assert
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    fresh = TuningCache(p)
    assert len(fresh) == n_threads * per_thread, (
        "interleaved saves dropped entries (lost update)")


def test_auto_equivalent_to_row_through_differentiable_dwconv(tmp_cache):
    """End-to-end: core.dwconv with variant='auto' (tuned to 'row') matches
    both the explicit 'row' path and XLA autodiff, grads included."""
    from repro.core.dwconv import dwconv

    d = SMALL_DIMS
    backend = jax.default_backend()
    tuned = {"fwd": "row", "bwd_in": "row", "bwd_k": "accum",
             "bwd_fused": "split", "decode": "rows"}
    for path in space.PATHS:
        tcache.default_cache().put(
            ShapeKey(path=path, B=d.B, H=d.H, L=d.L, K=d.K,
                     dtype="float32", backend=backend),
            TuneEntry(variant=tuned[path],
                      block_h=8, block_t=512, batch_chunk=128),
        )
    x, k = _rand((d.B, d.H, d.L), 0), _rand((d.H, d.K), 1)

    def loss(variant):
        def f(x, k):
            return jnp.sum(dwconv(x, k, padding=d.padding, variant=variant) ** 2)
        return f

    y_auto, grads_auto = jax.value_and_grad(loss("auto"), argnums=(0, 1))(x, k)
    y_xla, grads_xla = jax.value_and_grad(loss("xla"), argnums=(0, 1))(x, k)
    np.testing.assert_allclose(float(y_auto), float(y_xla), rtol=1e-5)
    for ga, gx in zip(grads_auto, grads_xla):
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gx), atol=2e-3)


def test_tune_then_auto_dispatch_round_trip(tmp_cache):
    """The acceptance flow in miniature: tune (stubbed clock) -> cache file
    on disk -> fresh process-level lookup -> auto runs the tuned config."""
    d = SMALL_DIMS
    tuner.tune_path(d, "fwd", budget=4, measure_fn=_stub_measure,
                    backend=jax.default_backend())
    assert tmp_cache.exists(), "tuner did not persist the cache file"
    tcache.reset_default_cache()  # simulate a new process reading the file
    v, _ = ops.resolve_variant("fwd", "auto", None, B=d.B, H=d.H, L=d.L, K=d.K,
                               dtype=jnp.float32)
    entry = tcache.lookup("fwd", d.B, d.H, d.L, d.K, "float32", jax.default_backend())
    assert entry is not None and v == entry.variant

    x, k = _rand((d.B, d.H, d.L), 0), _rand((d.H, d.K), 1)
    np.testing.assert_allclose(
        np.asarray(ops.dwconv_fwd_op(x, k, d.padding, "auto",
                                     ops.KernelOptions(interpret=True))),
        np.asarray(ref.dwconv_fwd_ref(x, k, d.padding)), atol=1e-5)


def test_concurrent_bundle_imports_union_under_file_lock(tmp_path, monkeypatch):
    """Two importers (own cache instances, exactly like separate serving
    replicas sharing ``REPRO_TUNE_CACHE``) merge different signed bundles
    into one cache file concurrently: the flock-guarded read-merge-replace
    in ``merge_entries`` -> ``save`` must union the entry sets, never
    last-writer-wins away either bundle."""
    import threading

    from repro.fleet import bundle as fbundle
    from repro.fleet import import_ as fimport

    monkeypatch.setenv(fbundle.FLEET_KEY_ENV, "union-test-key")
    shared = tmp_path / "shared.json"

    def make_bundle(tag, b_values):
        src = TuningCache(tmp_path / f"src-{tag}.json")
        for b in b_values:
            src.put(ShapeKey(path="fwd", B=b, H=4, L=48, K=5,
                             dtype="float32", backend="cpu"),
                    TuneEntry(variant="row", block_h=4, block_t=512,
                              batch_chunk=128, time_us=float(b)))
        return fbundle.export_bundle(src, tmp_path / f"{tag}.bundle.json",
                                     fingerprint="cpu:cpu:x1")

    bundles = [make_bundle("a", (1, 2, 3, 4)), make_bundle("b", (5, 6, 7, 8))]
    # pin the fingerprint so both imports take the trusted (merging) path
    monkeypatch.setattr("repro.fleet.import_._local_fingerprint",
                        lambda: "cpu:cpu:x1")
    barrier = threading.Barrier(2)
    errors = []

    def importer(path):
        try:
            cache = TuningCache(shared)  # own instance: no shared in-process lock
            barrier.wait()
            fimport.import_bundle(path, cache)
        except Exception as e:  # pragma: no cover - surfaced via assert
            errors.append(e)

    threads = [threading.Thread(target=importer, args=(b,)) for b in bundles]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    fresh = TuningCache(shared)
    got = {k.B for k in fresh.items()}
    assert got == set(range(1, 9)), (
        f"concurrent bundle imports lost entries: {sorted(got)}")


def test_corrupt_corpses_are_capped(tmp_path, capsys):
    """A crash-looping replica preserving its corrupt cache every restart
    must not fill the artifact dir: only the newest ``_MAX_CORRUPT_KEPT``
    ``.corrupt-<pid>`` corpses survive a new preservation."""
    import os

    p = tmp_path / "cache.json"
    for i in range(5):  # five prior crashes, oldest first by mtime
        side = p.with_name(p.name + f".corrupt-{9000000 + i}")
        side.write_text("{old corpse")
        os.utime(side, (i, i))
    p.write_text("{not json")
    c = TuningCache(p)
    assert c.get(ShapeKey(path="fwd", B=2, H=4, L=48, K=5, dtype="float32",
                          backend="cpu")) is None  # marks _disk_corrupt
    c.put(ShapeKey(path="fwd", B=2, H=4, L=48, K=5, dtype="float32",
                   backend="cpu"),
          TuneEntry(variant="row", block_h=4, block_t=512, batch_chunk=128))
    corpses = sorted(q.name for q in tmp_path.glob("cache.json.corrupt-*"))
    assert len(corpses) == tcache._MAX_CORRUPT_KEPT
    assert f"cache.json.corrupt-{os.getpid()}" in corpses, (
        "the newest corpse (this preservation) must survive the prune")
    err = capsys.readouterr().err
    assert "pruned 3 old corrupt-cache corpses" in err
