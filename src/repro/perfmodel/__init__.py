"""Declarative kernel-schedule performance model (counter-free).

One :class:`~repro.perfmodel.schedule.KernelSchedule` spec per
(execution path x kernel variant x epilogue), registered alongside each
Pallas kernel in :mod:`repro.perfmodel.schedules`, from which the system
*derives* everything the paper's counter-free methodology needs — HBM byte
traffic, per-grid-cell VMEM footprint and legality, stage-1 analytical
time for the tuner, and arithmetic intensity / roofline placement.

Runtime padding/tiling (``kernels/ops.py``) and the model both read the
same geometry functions (:mod:`repro.perfmodel.geometry`), so they cannot
drift.
"""
from repro.perfmodel.derive import (
    DMA_OVERHEAD_S,
    RooflinePoint,
    analytical_time_s,
    check_legality,
    derive_traffic,
    roofline_point,
    vmem_bytes,
)
from repro.perfmodel.geometry import (
    bwd_fused_wpad,
    bwd_time_tiles,
    bwdk_time_tile,
    dtype_itemsize,
    effective_tiles,
    epilogue_time_tile,
    fwd_tile_grid,
    time_tile,
    unified_wpad,
)
from repro.perfmodel.schedule import (
    KernelSchedule,
    OperandTraffic,
    TrafficEstimate,
    merge_schedules,
    path_flops,
)
from repro.perfmodel.schedules import (
    ACT_FLOPS_PER_ELEM,
    PAPER_VARIANTS,
    SCHEDULE_BUILDERS,
    epilogue_block_schedule,
    epilogue_elementwise_ops,
    epilogue_flops,
    register_schedule,
    registered_variants,
    schedule_for,
    unfused_epilogue_bwd_schedule,
)

__all__ = [
    "ACT_FLOPS_PER_ELEM",
    "DMA_OVERHEAD_S",
    "KernelSchedule",
    "OperandTraffic",
    "PAPER_VARIANTS",
    "RooflinePoint",
    "SCHEDULE_BUILDERS",
    "TrafficEstimate",
    "analytical_time_s",
    "bwd_fused_wpad",
    "bwd_time_tiles",
    "bwdk_time_tile",
    "check_legality",
    "derive_traffic",
    "dtype_itemsize",
    "effective_tiles",
    "epilogue_block_schedule",
    "epilogue_elementwise_ops",
    "epilogue_flops",
    "epilogue_time_tile",
    "fwd_tile_grid",
    "merge_schedules",
    "path_flops",
    "register_schedule",
    "registered_variants",
    "roofline_point",
    "schedule_for",
    "time_tile",
    "unfused_epilogue_bwd_schedule",
    "unified_wpad",
    "vmem_bytes",
]
