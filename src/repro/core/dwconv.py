"""Public depthwise-convolution operator with execution-path-aware dispatch.

``dwconv(x, k, padding=..., variant=...)`` is differentiable; its custom VJP
routes each execution path to the selected kernel implementation so that the
paper's controlled study — same operator, same model, different kernels — is
a one-argument switch anywhere in the framework.

  variant='xla'   : pure-jnp (SPMD-friendly; the default inside models)
  variant='row' / 'block' / 'lane' / 'naive' : Pallas TPU kernels
  variant='auto'  : per-shape dispatch through the persistent tuning cache
                    (see ``repro.tuning``); untuned shapes fall back to the
                    'row'/'accum' defaults
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.variant import get_variant
from repro.kernels import ops, ref
from repro.kernels.common import Padding


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _dwconv(x, k, padding: Padding, variant: str, opts: ops.KernelOptions):
    spec = get_variant(variant)
    if spec.fwd == "xla":
        return ref.dwconv_fwd_ref(x, k, padding)
    return ops.dwconv_fwd_op(x, k, padding, spec.fwd, opts)


def _dwconv_fwd_rule(x, k, padding, variant, opts):
    return _dwconv(x, k, padding, variant, opts), (x, k)


def _dwconv_bwd_rule(padding, variant, opts, res, dy):
    x, k = res
    spec = get_variant(variant)
    K = k.shape[-1]
    if spec.bwd_in == "xla":
        dx = ref.dwconv_bwd_input_ref(dy, k, padding)
    else:
        dx = ops.dwconv_bwd_input_op(dy, k, padding, spec.bwd_in, opts)
    if spec.bwd_k == "xla":
        dk = ref.dwconv_bwd_kernel_ref(x, dy, K, padding)
    else:
        dk = ops.dwconv_bwd_kernel_op(x, dy, K, padding, spec.bwd_k, opts)
    return dx.astype(x.dtype), dk.astype(k.dtype)


_dwconv.defvjp(_dwconv_fwd_rule, _dwconv_bwd_rule)


def dwconv(
    x: jnp.ndarray,
    k: jnp.ndarray,
    *,
    padding: Padding = "same",
    variant: str = "xla",
    opts: Optional[ops.KernelOptions] = None,
) -> jnp.ndarray:
    """Depthwise 1-D convolution, y[b,h,t] = sum_j x_pad[b,h,t+j] k[h,j].

    x: (B, H, L); k: (H, K).  ``padding='same'`` is the paper's convention;
    ``padding='causal'`` is the Mamba/RG-LRU short-filter convention.
    """
    if x.ndim != 3 or k.ndim != 2 or x.shape[1] != k.shape[0]:
        raise ValueError(f"bad shapes x={x.shape} k={k.shape}")
    # opts=None flows through so variant='auto' can apply cached tiling.
    return _dwconv(x, k, padding, variant, opts)


# Convenience aliases used by the operator-study benchmarks: run a single
# execution path under a named variant without autodiff plumbing.
def run_fwd(x, k, padding="same", variant="row", opts=None):
    spec = get_variant(variant)
    if spec.fwd == "xla":
        return ref.dwconv_fwd_ref(x, k, padding)
    return ops.dwconv_fwd_op(x, k, padding, spec.fwd, opts)


def run_bwd_input(dy, k, padding="same", variant="row", opts=None):
    spec = get_variant(variant)
    if spec.bwd_in == "xla":
        return ref.dwconv_bwd_input_ref(dy, k, padding)
    return ops.dwconv_bwd_input_op(dy, k, padding, spec.bwd_in, opts)


def run_bwd_kernel(x, dy, K, padding="same", variant="row", opts=None):
    spec = get_variant(variant)
    if spec.bwd_k == "xla":
        return ref.dwconv_bwd_kernel_ref(x, dy, K, padding)
    return ops.dwconv_bwd_kernel_op(x, dy, K, padding, spec.bwd_k, opts)
