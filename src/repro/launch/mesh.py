"""Production mesh construction (assignment §Multi-pod dry-run).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading pod axis (2 pods =
    512 chips).  The ``pod`` axis carries only gradient all-reduces (DCN);
    ``data``/``model`` collectives stay on ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/elastic restarts (e.g. (2,4) on 8 devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def describe(mesh) -> str:
    return f"mesh{dict(mesh.shape)} on {mesh.devices.size} devices"
