"""Analytical memory-traffic models for the depthwise-conv kernel variants.

This is the paper's §III-G / §V-B3 machinery: with no hardware counters,
DRAM traffic is *modeled* from tensor sizes, access patterns, and kernel
structure.  Optimized variants account for reduced redundancy from on-chip
reuse; the naive baseline's realized traffic depends on caching behaviour
that is unobservable without counters, so — exactly as the paper does —
``naive`` reports its *redundant logical* traffic and is flagged
``reliable=False`` for effective-bandwidth purposes (paper Table III "N/A").

FLOP counts follow paper eqs. (2)-(3): every multiply-add pair is 2 FLOPs,
so all three paths count  B * H * L * 2K.

Since the ``perfmodel`` refactor, every function here is a thin wrapper:
the byte/transaction accounting lives in the declarative
:class:`~repro.perfmodel.schedule.KernelSchedule` registered per kernel
variant (``repro/perfmodel/schedules.py``), and this module just derives
the :class:`TrafficEstimate` from it.  The historical signatures are kept
because the benchmarks, tests, and tuner all call them; the golden
equivalence suite (``tests/test_perfmodel_golden.py``) pins the derived
numbers to integer-byte equality with the pre-refactor formulas.
"""
from __future__ import annotations

from typing import Dict

from repro.kernels.common import DWConvDims
from repro.kernels.epilogue import parse_epilogue  # noqa: F401  (re-export)
from repro.perfmodel import (
    ACT_FLOPS_PER_ELEM,  # noqa: F401  (re-export: historical home)
    PAPER_VARIANTS,  # noqa: F401  (re-export)
    TrafficEstimate,  # noqa: F401  (re-export: historical home)
    derive_traffic,
    epilogue_block_schedule,
    path_flops,  # noqa: F401  (re-export)
    schedule_for,
    unfused_epilogue_bwd_schedule,
)


def fwd_traffic(
    d: DWConvDims,
    variant: str,
    itemsize: int = 4,
    block_h: int = 8,
    block_t: int = 512,
) -> TrafficEstimate:
    """Forward path (and, by kernel symmetry, the input-gradient path)."""
    return derive_traffic(schedule_for(
        "fwd", variant, d, itemsize, block_h=block_h, block_t=block_t))


def bwdk_traffic(
    d: DWConvDims,
    variant: str,
    itemsize: int = 4,
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Weight-gradient path: reduction over the (B x L) domain."""
    return derive_traffic(schedule_for(
        "bwd_k", variant, d, itemsize,
        block_h=block_h, block_t=block_t, batch_chunk=batch_chunk))


def bwd_split_traffic(
    d: DWConvDims,
    itemsize: int = 4,
    bwd_in_variant: str = "row",
    bwd_k_variant: str = "accum",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Total modeled backward traffic for the split (bwd_in + bwd_k) path,
    with the three padded-layout materializations charged."""
    return derive_traffic(schedule_for(
        "bwd_fused", "split", d, itemsize,
        bwd_in_variant=bwd_in_variant, bwd_k_variant=bwd_k_variant,
        block_h=block_h, block_t=block_t, batch_chunk=batch_chunk))


def bwd_fused_traffic(
    d: DWConvDims,
    variant: str = "fused",
    itemsize: int = 4,
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Backward traffic for the fused single-pass kernels (``"split"`` maps
    to :func:`bwd_split_traffic` so the tuner compares like with like)."""
    return derive_traffic(schedule_for(
        "bwd_fused", variant, d, itemsize,
        block_h=block_h, block_t=block_t, batch_chunk=batch_chunk))


def epilogue_fwd_traffic(
    d: DWConvDims,
    variant: str = "row",
    itemsize: int = 4,
    *,
    epilogue: str = "none",
    fused: bool = True,
    block_h: int = 8,
    block_t: int = 512,
) -> TrafficEstimate:
    """Forward traffic for ``act(conv(x, k) + bias)``.

    ``fused=True`` models the in-register epilogue (the conv variant's own
    traffic plus the bias-vector read); ``fused=False`` charges the unfused
    composition one extra full-tensor read + write per standalone op, so
    ``unfused - fused == n_ops * 2 * B*H*L * itemsize`` exactly.
    """
    return derive_traffic(schedule_for(
        "fwd", variant, d, itemsize, epilogue=epilogue, fused=fused,
        block_h=block_h, block_t=block_t))


def epilogue_bwd_traffic(
    d: DWConvDims,
    variant: str = "fused",
    itemsize: int = 4,
    *,
    epilogue: str = "none",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Whole-backward traffic for the epilogue-aware *fused* kernels.

    Mirrors :func:`bwd_fused_traffic` (pad materialization charged, the
    forward's x_pad residual reused verbatim) with the epilogue deltas: the
    pre-activation recompute adds one ``path_flops`` of MACs and — in the
    tiled regime — the extended x window binds a *third* (prev) tile, so
    three haloed operand reads cross every interior seam instead of two.
    ``variant="split"`` maps to the activation-*recompute* split
    composition that ``ops.dwconv_bwd_fused_act_op`` actually runs on that
    path, so fused-vs-split stays like for like on the tuner's
    epilogue-aware ``bwd_fused`` axis.
    """
    return derive_traffic(schedule_for(
        "bwd_fused", variant, d, itemsize, epilogue=epilogue,
        block_h=block_h, block_t=block_t, batch_chunk=batch_chunk))


def epilogue_unfused_bwd_traffic(
    d: DWConvDims,
    itemsize: int = 4,
    *,
    epilogue: str = "none",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Backward traffic of the *unfused composition* under ordinary autodiff
    (``jax.vjp`` of conv -> bias add -> act) — the baseline the epilogue
    gate compares against."""
    return derive_traffic(unfused_epilogue_bwd_schedule(
        d, itemsize, epilogue=epilogue,
        block_h=block_h, block_t=block_t, batch_chunk=batch_chunk))


def epilogue_block_traffic(
    d: DWConvDims,
    itemsize: int = 4,
    *,
    epilogue: str = "bias+silu",
    fused: bool = True,
    fwd_variant: str = "row",
    bwd_variant: str = "fused",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> TrafficEstimate:
    """Whole-block (forward + backward) traffic for one conv + epilogue:
    the quantity the ``paper_epilogue`` gate compares fused vs unfused."""
    return derive_traffic(epilogue_block_schedule(
        d, itemsize, epilogue=epilogue, fused=fused,
        fwd_variant=fwd_variant, bwd_variant=bwd_variant,
        block_h=block_h, block_t=block_t, batch_chunk=batch_chunk))


# ---------------------------------------------------------------------------
# Paper-mode accounting (P100 tables): the paper's §III-G model counts
# *cache-adjusted* traffic on the GPU — redundant in-flight loads within a
# warp/block are absorbed by L1/L2 and shared memory, so per-variant traffic
# differs by the surviving redundancy, not the full K x logical factor the
# explicit-DMA TPU variants move.  Variant names here are the paper's.
# ---------------------------------------------------------------------------


def paper_fwd_traffic(d: DWConvDims, variant: str, itemsize: int = 4) -> TrafficEstimate:
    return derive_traffic(schedule_for("paper_fwd", variant, d, itemsize))


def paper_bwdk_traffic(d: DWConvDims, variant: str, itemsize: int = 4) -> TrafficEstimate:
    return derive_traffic(schedule_for("paper_bwd_k", variant, d, itemsize))


def paper_total_traffic(d: DWConvDims, variant: str, itemsize: int = 4) -> float:
    """Total modeled bytes across all three execution paths (Table III)."""
    fwd = paper_fwd_traffic(d, variant, itemsize)
    bwdk = paper_bwdk_traffic(d, variant, itemsize)
    return 2 * fwd.bytes_moved + bwdk.bytes_moved  # fwd + bwd_in (same) + bwd_k


def variant_traffic_table(
    d: DWConvDims, itemsize: int = 4, **tiling
) -> Dict[str, Dict[str, TrafficEstimate]]:
    """All (study variant x execution path) traffic estimates — the input to
    the paper's Table III / Fig. 10 analogues."""
    from repro.core.variant import REGISTRY

    fwd_kw = {k: v for k, v in tiling.items() if k in ("block_h", "block_t")}
    bwd_kw = {k: v for k, v in tiling.items()
              if k in ("block_h", "block_t", "batch_chunk")}
    out: Dict[str, Dict[str, TrafficEstimate]] = {}
    for name, spec in REGISTRY.items():
        if spec.fwd == "auto":  # cache-dependent dispatch: no static model
            continue
        out[name] = {
            "fwd": fwd_traffic(d, spec.fwd, itemsize, **fwd_kw),
            "bwd_in": fwd_traffic(d, spec.bwd_in, itemsize, **fwd_kw),
            "bwd_k": bwdk_traffic(d, spec.bwd_k, itemsize, **bwd_kw),
        }
        if spec.bwd == "fused":
            out[name]["bwd_fused"] = bwd_fused_traffic(
                d, spec.bwd_fused, itemsize, **bwd_kw)
    return out
