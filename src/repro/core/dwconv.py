"""Public depthwise-convolution operator with execution-path-aware dispatch.

``dwconv(x, k, padding=..., variant=...)`` is differentiable; its custom VJP
routes each execution path to the selected kernel implementation so that the
paper's controlled study — same operator, same model, different kernels — is
a one-argument switch anywhere in the framework.

  variant='xla'   : pure-jnp (SPMD-friendly; the default inside models)
  variant='row' / 'block' / 'lane' / 'naive' : Pallas TPU kernels
  variant='fused' : row forward + single-pass fused backward (dx and dk
                    from one staged sweep; the forward's padded input is
                    the VJP residual, so it is never re-padded)
  variant='auto'  : per-shape dispatch through the persistent tuning cache
                    (see ``repro.tuning``); untuned shapes fall back to the
                    'row'/'accum' defaults with a split backward

Backward structure is governed by ``VariantSpec.bwd``: ``"split"`` keeps
the two independent backward ops (the paper's controlled per-path study),
``"fused"`` runs the fused kernel, ``"auto"`` resolves through the tuning
cache's ``bwd_fused`` path.  The fwd and bwd VJP rules make this decision
from identical static arguments, so the saved residual always matches what
the backward expects.

``dwconv_act(x, k, bias=..., act=...)`` is the fused-epilogue sibling:
the bias add + activation execute in-register on the forward accumulator,
and its custom VJP saves only the padded input — the backward *recomputes*
the pre-activation (K MACs per element) instead of storing it, emitting
dbias alongside dx/dk.  With the trivial epilogue it IS ``dwconv``,
bit for bit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.variant import get_variant
from repro.kernels import ops, ref
from repro.kernels.common import Padding
from repro.kernels.epilogue import ACTS, act_grad, epilogue_key


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _dwconv(x, k, padding: Padding, variant: str, opts: ops.KernelOptions):
    spec = get_variant(variant)
    if spec.fwd == "xla":
        return ref.dwconv_fwd_ref(x, k, padding)
    return ops.dwconv_fwd_op(x, k, padding, spec.fwd, opts)


def _resolve_bwd_fused(spec, opts, *, B, H, L, K, dtype, padding,
                       epilogue: str = "none"):
    """(fused_variant, resolved_opts) or (None, None) for a split backward.

    Pure function of static (trace-time) arguments — called identically by
    the fwd and bwd VJP rules so residual layout and consumer agree.
    """
    if spec.bwd == "fused":
        return spec.bwd_fused, (opts if opts is not None else ops.DEFAULT_OPTS)
    if spec.bwd == "auto":
        v, o = ops.resolve_variant("bwd_fused", "auto", opts, B=B, H=H, L=L,
                                   K=K, dtype=dtype, padding=padding,
                                   epilogue=epilogue)
        # A stale/foreign cache entry naming an unknown fused kernel must
        # degrade to the split backward, never crash the VJP.
        if v in ops.BWD_FUSED_VARIANTS and v != "split":
            return v, o
    return None, None


def _dwconv_fwd_rule(x, k, padding, variant, opts):
    spec = get_variant(variant)
    B, H, L = x.shape
    K = k.shape[-1]
    fused_v, _ = _resolve_bwd_fused(spec, opts, B=B, H=H, L=L, K=K,
                                    dtype=x.dtype, padding=padding)
    if fused_v is None:
        return _dwconv(x, k, padding, variant, opts), (x, k)
    # Fused backward: save the forward's unified-Wpad padded input as the
    # residual (x itself when the reference forward materializes none).
    y, xp = ops.dwconv_fwd_op_res(x, k, padding, spec.fwd, opts)
    return y, (xp if xp is not None else x, k)


def _dwconv_bwd_rule(padding, variant, opts, res, dy):
    xr, k = res
    spec = get_variant(variant)
    K = k.shape[-1]
    B, H, L = dy.shape
    fused_v, fused_opts = _resolve_bwd_fused(spec, opts, B=B, H=H, L=L, K=K,
                                             dtype=xr.dtype, padding=padding)
    if fused_v is not None:
        # The fwd rule saved either the raw x (shape == dy.shape) or the
        # padded unified-Wpad buffer (strictly wider).  Detect which by
        # SHAPE, not by re-resolving the forward variant: guarded dispatch
        # (repro.resilience.guard) may have degraded the forward mid-trace,
        # so a re-resolution can disagree with what the fwd rule actually
        # saved.  The residual's own geometry cannot lie.
        xp_saved = xr.shape != dy.shape
        dx, dk = ops.dwconv_bwd_fused_op(
            None if xp_saved else xr, dy, k, padding, fused_v, fused_opts,
            xp=xr if xp_saved else None)
        return dx.astype(xr.dtype), dk.astype(k.dtype)
    x = xr
    if spec.bwd_in == "xla":
        dx = ref.dwconv_bwd_input_ref(dy, k, padding)
    else:
        dx = ops.dwconv_bwd_input_op(dy, k, padding, spec.bwd_in, opts)
    if spec.bwd_k == "xla":
        dk = ref.dwconv_bwd_kernel_ref(x, dy, K, padding)
    else:
        dk = ops.dwconv_bwd_kernel_op(x, dy, K, padding, spec.bwd_k, opts)
    return dx.astype(x.dtype), dk.astype(k.dtype)


_dwconv.defvjp(_dwconv_fwd_rule, _dwconv_bwd_rule)


def dwconv(
    x: jnp.ndarray,
    k: jnp.ndarray,
    *,
    padding: Padding = "same",
    variant: str = "xla",
    opts: Optional[ops.KernelOptions] = None,
) -> jnp.ndarray:
    """Depthwise 1-D convolution, y[b,h,t] = sum_j x_pad[b,h,t+j] k[h,j].

    x: (B, H, L); k: (H, K).  ``padding='same'`` is the paper's convention;
    ``padding='causal'`` is the Mamba/RG-LRU short-filter convention.
    """
    if x.ndim != 3 or k.ndim != 2 or x.shape[1] != k.shape[0]:
        raise ValueError(f"bad shapes x={x.shape} k={k.shape}")
    # opts=None flows through so variant='auto' can apply cached tiling.
    return _dwconv(x, k, padding, variant, opts)


# ---------------------------------------------------------------------------
# Fused-epilogue operator: y = act(dwconv(x, k) + bias)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _dwconv_act(x, k, bias, padding: Padding, act: str,
                variant: str, opts: Optional[ops.KernelOptions]):
    spec = get_variant(variant)
    if spec.fwd == "xla":
        return ref.dwconv_act_ref(x, k, bias=bias, act=act, padding=padding)
    return ops.dwconv_fwd_op(x, k, padding, spec.fwd, opts, bias=bias, act=act)


def _dwconv_act_fwd_rule(x, k, bias, padding, act, variant, opts):
    spec = get_variant(variant)
    B, H, L = x.shape
    K = k.shape[-1]
    epi = epilogue_key(bias is not None, act)
    fused_v, _ = _resolve_bwd_fused(spec, opts, B=B, H=H, L=L, K=K,
                                    dtype=x.dtype, padding=padding, epilogue=epi)
    if fused_v is None:
        return _dwconv_act(x, k, bias, padding, act, variant, opts), (x, k, bias)
    # Fused epilogue backward: the residual is the forward's unified-Wpad
    # padded *input* — never the pre-activation, which the backward kernels
    # recompute in-register (K MACs vs a full-tensor residual round-trip).
    y, xp = ops.dwconv_fwd_op_res(x, k, padding, spec.fwd, opts,
                                  bias=bias, act=act)
    return y, (xp if xp is not None else x, k, bias)


def _dwconv_act_bwd_rule(padding, act, variant, opts, res, dy):
    xr, k, bias = res
    spec = get_variant(variant)
    K = k.shape[-1]
    B, H, L = dy.shape
    epi = epilogue_key(bias is not None, act)
    fused_v, fused_opts = _resolve_bwd_fused(spec, opts, B=B, H=H, L=L, K=K,
                                             dtype=xr.dtype, padding=padding,
                                             epilogue=epi)
    if fused_v is not None:
        # Shape-based residual detection — see _dwconv_bwd_rule: re-resolving
        # the forward variant can disagree with what the fwd rule saved when
        # guarded dispatch degraded the forward mid-trace.
        xp_saved = xr.shape != dy.shape
        dx, dk, dbias = ops.dwconv_bwd_fused_act_op(
            None if xp_saved else xr, dy, k, bias, padding, fused_v,
            fused_opts, act=act, xp=xr if xp_saved else None)
        return (dx.astype(xr.dtype), dk.astype(k.dtype),
                None if bias is None else dbias.astype(bias.dtype))
    # Split / reference backward: recompute the pre-activation (one
    # standalone conv + bias pass — still no stored residual), form the
    # effective gradient, and feed the ordinary per-path backward ops.
    x = xr
    if spec.fwd == "xla":
        pre = ref.dwconv_act_ref(x, k, bias=bias, act="none", padding=padding)
    else:
        pre = ops.dwconv_fwd_op(x, k, padding, spec.fwd, opts, bias=bias)
    dy_eff32 = dy.astype(jnp.float32) * act_grad(pre.astype(jnp.float32), act)
    dy_eff = dy_eff32.astype(dy.dtype)
    if spec.bwd_in == "xla":
        dx = ref.dwconv_bwd_input_ref(dy_eff, k, padding)
    else:
        dx = ops.dwconv_bwd_input_op(dy_eff, k, padding, spec.bwd_in, opts)
    if spec.bwd_k == "xla":
        dk = ref.dwconv_bwd_kernel_ref(x, dy_eff, K, padding)
    else:
        dk = ops.dwconv_bwd_kernel_op(x, dy_eff, K, padding, spec.bwd_k, opts)
    dbias = None if bias is None else jnp.sum(dy_eff32, axis=(0, 2)).astype(bias.dtype)
    return dx.astype(x.dtype), dk.astype(k.dtype), dbias


_dwconv_act.defvjp(_dwconv_act_fwd_rule, _dwconv_act_bwd_rule)


def dwconv_act(
    x: jnp.ndarray,
    k: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    *,
    act: str = "none",
    padding: Padding = "same",
    variant: str = "xla",
    opts: Optional[ops.KernelOptions] = None,
) -> jnp.ndarray:
    """Depthwise conv with a fused epilogue: ``act(dwconv(x, k) + bias)``.

    x: (B, H, L); k: (H, K); bias: per-channel (H,) or ``None``;
    ``act`` in ``("none", "gelu", "silu")``.  The epilogue executes on the
    f32 accumulator inside the forward kernel (one HBM write, one rounding
    step); the custom VJP saves only the padded input and *recomputes* the
    pre-activation in the backward, emitting dbias alongside dx/dk.  With
    the trivial epilogue (no bias, ``act="none"``) this is exactly
    :func:`dwconv` — bit for bit, preserving the paper's controlled study.
    """
    if x.ndim != 3 or k.ndim != 2 or x.shape[1] != k.shape[0]:
        raise ValueError(f"bad shapes x={x.shape} k={k.shape}")
    if act not in ACTS:
        raise ValueError(f"unknown act {act!r}; known: {ACTS}")
    if bias is not None and bias.shape != (x.shape[1],):
        raise ValueError(
            f"bias must be per-channel ({x.shape[1]},), got {bias.shape}")
    if bias is None and act == "none":
        return _dwconv(x, k, padding, variant, opts)  # bit-identical fast path
    return _dwconv_act(x, k, bias, padding, act, variant, opts)


# ---------------------------------------------------------------------------
# Streaming decode: fused single-step ring-buffer conv (inference only)
# ---------------------------------------------------------------------------


def decode_variant_for(variant: str) -> str:
    """Map an operator variant name onto the decode path's variant axis.

    Decode-native names ("rows", "chanblock", "xla", "auto") pass through.
    A model-level variant spec (the one-argument switch models thread
    through ``conv_variant``) maps by its forward family: a pure-XLA spec
    runs the fused-elementwise reference step, any Pallas spec resolves
    through the decode tuning cache ("auto" — the fwd tile names mean
    nothing at L=1, where channels ride the lane axis instead of time).
    """
    if variant in ops.DECODE_VARIANTS or variant == "auto":
        return variant
    spec = get_variant(variant)  # validates the name
    return "xla" if spec.fwd == "xla" else "auto"


def train_variant_for(variant: str) -> str:
    """Inverse companion of :func:`decode_variant_for`: map a decode-native
    variant name onto the full-sequence (train/prefill) conv switch, so one
    ``conv_variant`` setting drives both phases.  Decode tile names mean
    nothing at full L, so they resolve through the fwd tuning cache."""
    if variant in ("rows", "chanblock"):
        return "auto"
    return variant


def dwconv_decode(
    ring: jnp.ndarray,
    x: jnp.ndarray,
    k: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    *,
    act: str = "none",
    variant: str = "xla",
    opts: Optional[ops.KernelOptions] = None,
):
    """One fused streaming-decode conv step -> ``(y, new_ring)``.

    ring: (B, H, K-1) — the last K-1 pre-conv inputs, oldest tap first (the
    Mamba ``conv_state`` idiom); x: (B, H) the new step's input; k: (H, K);
    bias: (H,) or None.  Computes ``y = act(sum_j taps[j] * k[:, j] + bias)``
    with the new input as tap K-1, and returns the shifted ring alongside —
    O(B*H*K) bytes per step against O(B*H*L) for re-running the full conv
    over a sequence cache.  Inference-only (no VJP): decode never
    differentiates.  ``variant`` accepts both decode-native names and the
    model-level variant switch (see :func:`decode_variant_for`).
    """
    if ring.ndim != 3 or x.ndim != 2 or k.ndim != 2:
        raise ValueError(
            f"bad shapes ring={ring.shape} x={x.shape} k={k.shape}; want "
            f"(B, H, K-1), (B, H), (H, K)")
    if act not in ACTS:
        raise ValueError(f"unknown act {act!r}; known: {ACTS}")
    if bias is not None and bias.shape != (x.shape[1],):
        raise ValueError(
            f"bias must be per-channel ({x.shape[1]},), got {bias.shape}")
    return ops.dwconv_decode_op(ring, x, k, decode_variant_for(variant),
                                opts, bias=bias, act=act)


# Convenience aliases used by the operator-study benchmarks: run a single
# execution path under a named variant without autodiff plumbing.
def run_fwd(x, k, padding="same", variant="row", opts=None):
    spec = get_variant(variant)
    if spec.fwd == "xla":
        return ref.dwconv_fwd_ref(x, k, padding)
    return ops.dwconv_fwd_op(x, k, padding, spec.fwd, opts)


def run_bwd_input(dy, k, padding="same", variant="row", opts=None):
    spec = get_variant(variant)
    if spec.bwd_in == "xla":
        return ref.dwconv_bwd_input_ref(dy, k, padding)
    return ops.dwconv_bwd_input_op(dy, k, padding, spec.bwd_in, opts)


def run_bwd_kernel(x, dy, K, padding="same", variant="row", opts=None):
    spec = get_variant(variant)
    if spec.bwd_k == "xla":
        return ref.dwconv_bwd_kernel_ref(x, dy, K, padding)
    return ops.dwconv_bwd_kernel_op(x, dy, K, padding, spec.bwd_k, opts)


def run_bwd_fused(x, dy, k, padding="same", variant="fused", opts=None):
    """Run the fused backward path standalone -> (dx, dk).  ``variant`` is a
    ``BWD_FUSED_VARIANTS`` name ("split" runs the two independent ops)."""
    return ops.dwconv_bwd_fused_op(x, dy, k, padding, variant, opts)
