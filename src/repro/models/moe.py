"""Mixture-of-Experts LM family: olmoe-1b-7b (64e top-8) and
deepseek-moe-16b (2 shared + 64 routed top-6, dense first layer).

Token-choice top-k routing with capacity-factor einsum dispatch (GShard
style): tokens are blocked into groups, each group dispatches into
(experts x capacity) slots via one-hot position-in-expert tensors.  Under
the production mesh the dispatch/return einsums lower to all-to-alls
(groups sharded over data, experts over model) — expert parallelism without
manual collectives.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.train.losses import softmax_cross_entropy


# ---------------------------------------------------------------------------
# expert MLP + router
# ---------------------------------------------------------------------------


def _init_moe_block(rng, cfg: ArchConfig) -> Dict[str, Any]:
    m = cfg.moe
    ks = jax.random.split(rng, 5)
    D, F, E = cfg.d_model, cfg.d_ff, m.n_experts
    p = {
        "router": L.dense_init(ks[0], D, E),
        "w_gate": jax.random.normal(ks[1], (E, D, F)) / jnp.sqrt(D),
        "w_up": jax.random.normal(ks[2], (E, D, F)) / jnp.sqrt(D),
        "w_down": jax.random.normal(ks[3], (E, F, D)) / jnp.sqrt(F),
    }
    if m.n_shared > 0:
        p["shared"] = L.init_mlp(ks[4], D, m.n_shared * F, gated=True)
    return p


def _moe_block_axes(cfg: ArchConfig) -> Dict[str, Any]:
    axes = {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
    }
    if cfg.moe.n_shared > 0:
        axes["shared"] = L.mlp_param_axes(gated=True)
    return axes


def moe_mlp(p: Dict[str, Any], x: jnp.ndarray, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).  Group-blocked top-k dispatch."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.n_experts, m.top_k
    tokens = B * S
    g = min(m.group_size, tokens)
    G = tokens // g
    assert G * g == tokens, (tokens, g)
    xt = x.reshape(G, g, D)
    xt = shard(xt, "act_groups", None, "act_embed")

    logits = jnp.einsum("Ggd,de->Gge", xt, p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (G,g,E)
    gate_vals, idx = jax.lax.top_k(probs, k)                    # (G,g,k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    C = int(max(4, round(g * k / E * m.capacity_factor)))
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # (G,g,k,E)
    # position of each (token, choice) within its expert queue, in slot order
    flat = onehot.reshape(G, g * k, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, g, k, E)  # (G,g,k,E)
    keep = (pos < C).astype(jnp.float32) * onehot
    # dispatch/combine (G,g,E,C) accumulated per choice to bound peak memory
    dispatch = jnp.zeros((G, g, E, C), jnp.float32)
    combine = jnp.zeros((G, g, E, C), jnp.float32)
    for j in range(k):
        slot = jax.nn.one_hot(pos[:, :, j, :].astype(jnp.int32), C, dtype=jnp.float32)  # (G,g,E,C)
        dj = keep[:, :, j, :, None] * slot
        dispatch = dispatch + dj
        combine = combine + dj * gate_vals[:, :, j, None, None]
    dispatch = shard(dispatch.astype(x.dtype), "act_groups", None, "act_experts", None)
    combine = shard(combine.astype(x.dtype), "act_groups", None, "act_experts", None)

    # dispatch -> (E, G, C, D): all-to-all under (G: data, E: model) sharding
    expert_in = jnp.einsum("GgEC,Ggd->EGCd", dispatch, xt)
    expert_in = shard(expert_in, "act_experts", "act_groups", None, "act_embed")
    gate = jnp.einsum("EGCd,Edf->EGCf", expert_in, p["w_gate"].astype(x.dtype))
    up = jnp.einsum("EGCd,Edf->EGCf", expert_in, p["w_up"].astype(x.dtype))
    act = jax.nn.silu(gate) * up
    expert_out = jnp.einsum("EGCf,Efd->EGCd", act, p["w_down"].astype(x.dtype))
    expert_out = shard(expert_out, "act_experts", "act_groups", None, "act_embed")
    out = jnp.einsum("GgEC,EGCd->Ggd", combine, expert_out)

    if m.n_shared > 0:
        out = out + L.mlp(p["shared"], xt, cfg.act)

    # Switch-style load-balance aux loss
    density = jnp.mean(onehot.sum(2), axis=(0, 1))              # fraction per expert
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * router_prob)
    return out.reshape(B, S, D), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# model assembly (attention layers from the dense family)
# ---------------------------------------------------------------------------


def _init_layer(rng, cfg: ArchConfig, dense_mlp: bool) -> Dict[str, Any]:
    k1, k2 = jax.random.split(rng)
    p = {
        "attn": L.init_attention(k1, cfg.d_model, T.attn_dims(cfg)),
        "ln1": jnp.zeros((cfg.d_model,)),
        "ln2": jnp.zeros((cfg.d_model,)),
    }
    if dense_mlp:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.moe.dense_ff or cfg.d_ff, gated=True)
    else:
        p["moe"] = _init_moe_block(k2, cfg)
    return p


def init(rng, cfg: ArchConfig) -> Dict[str, Any]:
    m = cfg.moe
    k_embed, k_layers, k_first, k_out = jax.random.split(rng, 4)
    n_scan = cfg.n_layers - (1 if m.dense_first_layer else 0)
    layer_keys = jax.random.split(k_layers, n_scan)
    params = {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model),
        "layers": jax.vmap(lambda r: _init_layer(r, cfg, dense_mlp=False))(layer_keys),
        "ln_f": jnp.zeros((cfg.d_model,)),
    }
    if m.dense_first_layer:
        params["first_layer"] = _init_layer(k_first, cfg, dense_mlp=True)
    if not cfg.tie_embeddings:
        params["unembed"] = L.embed_init(k_out, cfg.vocab, cfg.d_model)
    return jax.tree.map(lambda x: x.astype(cfg.param_dt), params)


def param_axes(cfg: ArchConfig) -> Dict[str, Any]:
    attn_axes = L.attention_param_axes(T.attn_dims(cfg))
    lp = {
        "attn": {k: ("layers",) + v for k, v in attn_axes.items()},
        "moe": {k: ("layers",) + v for k, v in _moe_block_axes(cfg).items()
                if k != "shared"},
        "ln1": ("layers", "embed"),
        "ln2": ("layers", "embed"),
    }
    if cfg.moe.n_shared > 0:
        lp["moe"]["shared"] = {k: ("layers",) + v for k, v in L.mlp_param_axes(True).items()}
    axes = {"embed": ("vocab", "embed"), "layers": lp, "ln_f": ("embed",)}
    if cfg.moe.dense_first_layer:
        axes["first_layer"] = {
            "attn": attn_axes,
            "mlp": L.mlp_param_axes(True),
            "ln1": ("embed",),
            "ln2": ("embed",),
        }
    if not cfg.tie_embeddings:
        axes["unembed"] = ("vocab", "embed")
    return axes


def forward(params, cfg: ArchConfig, tokens: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    use_chunked = S >= cfg.attn_chunk_threshold
    dims = T.attn_dims(cfg)

    def attn_part(lp, x):
        h = L.rms_norm(x, lp["ln1"])
        a, _ = L.attention(lp["attn"], h, dims, positions=positions,
                           rope_theta=cfg.rope_theta, use_chunked=use_chunked)
        return x + a

    if "first_layer" in params:
        fl = params["first_layer"]
        x = attn_part(fl, x)
        x = x + L.mlp(fl["mlp"], L.rms_norm(x, fl["ln2"]), cfg.act)

    def body(carry, lp):
        x, aux = carry
        x = attn_part(lp, x)
        h = L.rms_norm(x, lp["ln2"])
        mo, a = moe_mlp(lp["moe"], h, cfg)
        x = shard(x + mo, "act_batch", "act_seq", "act_embed")
        return (x, aux + a), ()

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    n_moe = cfg.n_layers - (1 if cfg.moe.dense_first_layer else 0)
    return L.rms_norm(x, params["ln_f"]), aux / n_moe


def loss_fn(params, cfg: ArchConfig, batch) -> jnp.ndarray:
    hidden, aux = forward(params, cfg, batch["tokens"])
    logits = T.logits_fn(params, cfg, hidden)
    ce = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or cfg.compute_dt
    n_scan = cfg.n_layers - (1 if cfg.moe.dense_first_layer else 0)
    shape = (n_scan, batch, cache_len, cfg.n_kv, cfg.head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
             "pos": jnp.zeros((), jnp.int32)}
    if cfg.moe.dense_first_layer:
        fshape = (batch, cache_len, cfg.n_kv, cfg.head_dim)
        cache["first_k"] = jnp.zeros(fshape, dtype)
        cache["first_v"] = jnp.zeros(fshape, dtype)
    return cache


def cache_axes(cfg: ArchConfig):
    kv = ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None)
    axes = {"k": kv, "v": kv, "pos": ()}
    if cfg.moe.dense_first_layer:
        axes["first_k"] = kv[1:]
        axes["first_v"] = kv[1:]
    return axes


def decode_step(params, cfg: ArchConfig, cache, tokens: jnp.ndarray):
    B, S = tokens.shape
    pos = cache["pos"]
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    positions = jnp.broadcast_to(pos[None, None] + jnp.arange(S, dtype=jnp.int32), (B, S))
    dims = T.attn_dims(cfg)
    new_cache = dict(cache)

    def attn_decode(lp, x, ck, cv):
        h = L.rms_norm(x, lp["ln1"])
        a, nc = L.attention(lp["attn"], h, dims, positions=positions,
                            rope_theta=cfg.rope_theta,
                            cache={"k": ck, "v": cv}, cache_pos=pos)
        return x + a, nc

    if "first_layer" in params:
        fl = params["first_layer"]
        x, nc = attn_decode(fl, x, cache["first_k"], cache["first_v"])
        x = x + L.mlp(fl["mlp"], L.rms_norm(x, fl["ln2"]), cfg.act)
        new_cache["first_k"], new_cache["first_v"] = nc["k"], nc["v"]

    def body(x, inp):
        lp, ck, cv = inp
        x, nc = attn_decode(lp, x, ck, cv)
        h = L.rms_norm(x, lp["ln2"])
        mo, _ = moe_mlp(lp["moe"], h, cfg)
        return x + mo, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    hidden = L.rms_norm(x, params["ln_f"])
    logits = T.logits_fn(params, cfg, hidden)
    new_cache.update(k=nk, v=nv, pos=pos + S)
    return logits, new_cache


def prefill(params, cfg: ArchConfig, tokens: jnp.ndarray):
    """Full-sequence prefill with KV-cache materialization."""
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    use_chunked = S >= cfg.attn_chunk_threshold
    dims = T.attn_dims(cfg)
    cache = {}

    def attn_prefill(lp, x):
        h = L.rms_norm(x, lp["ln1"])
        a, (k, v) = L.attention(lp["attn"], h, dims, positions=positions,
                                rope_theta=cfg.rope_theta, use_chunked=use_chunked,
                                return_kv=True)
        return x + a, k.astype(cfg.compute_dt), v.astype(cfg.compute_dt)

    if "first_layer" in params:
        fl = params["first_layer"]
        x, fk, fv = attn_prefill(fl, x)
        x = x + L.mlp(fl["mlp"], L.rms_norm(x, fl["ln2"]), cfg.act)
        cache["first_k"], cache["first_v"] = fk, fv

    def body(x, lp):
        x, k, v = attn_prefill(lp, x)
        h = L.rms_norm(x, lp["ln2"])
        mo, _ = moe_mlp(lp["moe"], h, cfg)
        x = shard(x + mo, "act_batch", "act_seq", "act_embed")
        return x, (k, v)

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(body_fn, x, params["layers"])
    hidden = L.rms_norm(x, params["ln_f"])
    logits = T.logits_fn(params, cfg, hidden[:, -1:, :])
    cache.update(k=ks, v=vs, pos=jnp.asarray(S, jnp.int32))
    return logits, cache


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def _attn_params(cfg: ArchConfig) -> int:
    return cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * cfg.d_model


def n_params(cfg: ArchConfig) -> int:
    m = cfg.moe
    expert = 3 * cfg.d_model * cfg.d_ff
    shared = 3 * cfg.d_model * (m.n_shared * cfg.d_ff) if m.n_shared else 0
    router = cfg.d_model * m.n_experts
    n_moe = cfg.n_layers - (1 if m.dense_first_layer else 0)
    per_moe_layer = _attn_params(cfg) + m.n_experts * expert + shared + router + 2 * cfg.d_model
    total = n_moe * per_moe_layer
    if m.dense_first_layer:
        total += _attn_params(cfg) + 3 * cfg.d_model * (m.dense_ff or cfg.d_ff) + 2 * cfg.d_model
    total += cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2) + cfg.d_model
    return total


def n_active_params(cfg: ArchConfig) -> int:
    m = cfg.moe
    expert = 3 * cfg.d_model * cfg.d_ff
    shared = 3 * cfg.d_model * (m.n_shared * cfg.d_ff) if m.n_shared else 0
    router = cfg.d_model * m.n_experts
    n_moe = cfg.n_layers - (1 if m.dense_first_layer else 0)
    per_layer = _attn_params(cfg) + m.top_k * expert + shared + router + 2 * cfg.d_model
    total = n_moe * per_layer
    if m.dense_first_layer:
        total += _attn_params(cfg) + 3 * cfg.d_model * (m.dense_ff or cfg.d_ff) + 2 * cfg.d_model
    total += cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2) + cfg.d_model
    return total
