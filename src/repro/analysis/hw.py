"""Hardware models for counter-free analysis.

The paper's counter-free methodology replaces hardware counters with
published peak numbers + analytical models.  We carry two targets:

  * TPU_V5E — the deployment target of this framework (roofline terms for
    the multi-pod dry-run use these constants, per the assignment brief).
  * P100    — the paper's platform (used by the paper-faithful benchmark
    tables so the reproduction is apples-to-apples with the paper's Fig. 10).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    peak_flops: float          # FLOP/s at the relevant precision
    peak_flops_f32: float      # FLOP/s for f32 (VPU path on TPU)
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per ICI link (0 for single-device GPU)
    hbm_bytes: float           # capacity per chip
    vmem_bytes: float = 0.0    # on-chip staging memory (VMEM / smem per SM)

    def roofline_knee(self, precision: str = "default") -> float:
        """Arithmetic intensity (FLOP/byte) where compute roof meets memory roof."""
        peak = self.peak_flops if precision == "default" else self.peak_flops_f32
        return peak / self.hbm_bw


# TPU v5e constants from the assignment brief: 197 TFLOP/s bf16 per chip,
# 819 GB/s HBM, ~50 GB/s/link ICI, 16 GiB HBM, ~128 MiB VMEM.
TPU_V5E = HardwareModel(
    name="tpu-v5e",
    peak_flops=197e12,
    peak_flops_f32=197e12 / 2,  # MXU fp32 path is ~half of bf16
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)

# NVIDIA Tesla P100-PCIE-16GB (paper Table I + §III-G): 10.6 TFLOP/s fp32,
# 732 GB/s HBM2, 16 GB; 64 KiB shared memory per SM.
P100 = HardwareModel(
    name="p100",
    peak_flops=10.6e12,
    peak_flops_f32=10.6e12,
    hbm_bw=732e9,
    ici_bw=0.0,
    hbm_bytes=16 * 2**30,
    vmem_bytes=64 * 2**10,
)

HARDWARE = {m.name: m for m in (TPU_V5E, P100)}
