"""Hierarchical span tracer — event-style timing as a persistent trace.

The paper times kernels with CUDA events: enqueue, synchronize, read the
elapsed wall time (§III-F).  The JAX analogue is ``block_until_ready``, and
``analysis/timer.py`` already uses it for one-shot benchmarks.  This module
turns the same protocol into a *structured* trace: nested spans (context
manager or decorator), each closed by an explicit sync on the values it
produced, emitted as JSONL records.

The counter-free twist: a span may *attach* one or more
:class:`~repro.perfmodel.KernelSchedule` specs.  Each attachment is emitted
as a child ``kind="kernel"`` record carrying the schedule's derived modeled
bytes/flops next to the span's measured wall time — so every kernel span
reports an effective bandwidth (modeled bytes / measured seconds) and its
roofline placement, exactly the paper's Tables II/III quantity, with no
hardware counters.  When the enclosing span measured more than the kernel
alone (e.g. a whole jitted train step), the record says so
(``time_scope="enclosing-span"``) and the effective bandwidth is the
*attributable* lower bound.

Disabled tracing is near-free: ``span()`` returns a shared no-op context
manager without allocating, and no file is ever touched.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import IO, Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Span",
    "Tracer",
    "configure",
    "dwconv_step_schedules",
    "get_tracer",
    "read_trace",
]


def _block_until_ready(value) -> None:
    import jax

    jax.block_until_ready(value)


@dataclasses.dataclass
class _Attachment:
    name: str
    schedule: Any                      # perfmodel.KernelSchedule
    hw: Any = None                     # analysis.hw.HardwareModel | None
    count: int = 1                     # e.g. layers running this kernel
    runtime_s: Optional[float] = None  # per-kernel measured time override


class Span:
    """One open span.  Created by :meth:`Tracer.span`; closes on ``__exit__``
    by syncing every value registered with :meth:`sync` *before* reading the
    end timestamp (the CUDA-event protocol)."""

    __slots__ = ("_tracer", "name", "id", "parent_id", "path", "tags",
                 "_sync_values", "_attachments", "t_start", "dur_s")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: Optional[int], path: str, tags: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.id = span_id
        self.parent_id = parent_id
        self.path = path
        self.tags = tags
        self._sync_values: List[Any] = []
        self._attachments: List[_Attachment] = []
        self.t_start = 0.0
        self.dur_s = 0.0

    def tag(self, **kw) -> "Span":
        """Add/overwrite tags on the open span."""
        self.tags.update(kw)
        return self

    def sync(self, value) -> "Span":
        """Register a value to ``block_until_ready`` at span close, so the
        span's wall time covers the async work that produced it."""
        self._sync_values.append(value)
        return self

    def attach(self, name: str, schedule, *, hw=None, count: int = 1,
               runtime_s: Optional[float] = None) -> "Span":
        """Attach a kernel schedule: emitted at close as a ``kind="kernel"``
        child record with modeled bytes/flops and effective bandwidth.

        ``count`` multiplies the schedule's traffic (e.g. ``n_layers``
        identical convs per step); ``runtime_s`` supplies a per-kernel
        measured time when one exists (otherwise the enclosing span's wall
        time is used and the record is marked ``time_scope="enclosing-span"``).
        """
        self._attachments.append(_Attachment(name, schedule, hw, count, runtime_s))
        return self

    # -- context manager ----------------------------------------------------
    def __enter__(self) -> "Span":
        self._tracer._open(self)
        self.t_start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._sync_values:
            _block_until_ready(self._sync_values)
        self.dur_s = time.perf_counter() - self.t_start
        self._tracer._close(self, error=exc_type is not None)


class _NullSpan:
    """Shared no-op span: the disabled-tracer fast path allocates nothing."""

    __slots__ = ()
    id = None
    dur_s = 0.0
    tags: Dict[str, Any] = {}

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return None

    def tag(self, **kw):
        return self

    def sync(self, value):
        return self

    def attach(self, *a, **kw):
        return self


_NULL_SPAN = _NullSpan()


class Tracer:
    """Span tracer writing JSONL records (and keeping them in ``records``).

    ``Tracer(path)`` writes to ``path``; ``Tracer(enabled=True)`` traces
    in-memory only (``records``); the default ``Tracer()`` is disabled and
    near-free.  Single-threaded by design — the launchers, the tuner, and
    the benchmark harness all trace from one thread.
    """

    def __init__(self, path: Optional[str] = None, *,
                 enabled: Optional[bool] = None, meta: Optional[Dict] = None):
        self.path = path or None
        self.enabled = bool(path) if enabled is None else bool(enabled)
        self.records: List[Dict[str, Any]] = []
        self._stack: List[Span] = []
        self._next_id = 1
        self._fh: Optional[IO[str]] = None
        self._epoch = time.perf_counter()
        self.meta = dict(meta or {})

    # -- public API ---------------------------------------------------------
    def span(self, name: str, *, sync=None, **tags):
        """Open a span.  Usage::

            with tracer.span("train/step", step=i) as sp:
                out = jit_step(...)
                sp.sync(out)
        """
        if not self.enabled:
            return _NULL_SPAN
        parent = self._stack[-1] if self._stack else None
        sp = Span(self, name, self._next_id,
                  parent.id if parent is not None else None,
                  f"{parent.path}/{name}" if parent is not None else name,
                  dict(tags))
        self._next_id += 1
        if sync is not None:
            sp.sync(sync)
        return sp

    def traced(self, name: Optional[str] = None, **tags):
        """Decorator form: spans the call and syncs on its return value."""
        def deco(fn):
            import functools

            span_name = name or getattr(fn, "__name__", "fn")

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                if not self.enabled:
                    return fn(*a, **kw)
                with self.span(span_name, **tags) as sp:
                    out = fn(*a, **kw)
                    sp.sync(out)
                    return out
            return wrapper
        return deco

    def event(self, kind: str, **fields) -> None:
        """Emit a standalone (span-less) record of ``kind`` — e.g. the
        resilience layer's ``kind="degradation"`` records.  Parented to the
        innermost open span so a degradation lands inside the step that
        absorbed it; a no-op when tracing is disabled."""
        if not self.enabled:
            return
        rec: Dict[str, Any] = {
            "kind": kind, "id": self._next_id,
            "parent": self._stack[-1].id if self._stack else None,
            "t_s": time.perf_counter() - self._epoch,
        }
        self._next_id += 1
        rec.update(_jsonable(fields))
        self._emit(rec)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- span plumbing ------------------------------------------------------
    def _open(self, sp: Span) -> None:
        self._stack.append(sp)

    def _close(self, sp: Span, *, error: bool = False) -> None:
        # tolerate out-of-order exits (exceptions unwinding several spans)
        while self._stack and self._stack[-1] is not sp:
            self._stack.pop()
        if self._stack:
            self._stack.pop()
        rec: Dict[str, Any] = {
            "kind": "span", "id": sp.id, "parent": sp.parent_id,
            "name": sp.name, "path": sp.path,
            "t_start_s": sp.t_start - self._epoch, "dur_s": sp.dur_s,
        }
        if error:
            rec["error"] = True
        if sp.tags:
            rec["tags"] = _jsonable(sp.tags)
        self._emit(rec)
        for att in sp._attachments:
            self._emit(self._kernel_record(sp, att))

    def _kernel_record(self, sp: Span, att: _Attachment) -> Dict[str, Any]:
        from repro import perfmodel

        est = perfmodel.derive_traffic(att.schedule)
        n = max(int(att.count), 1)
        bytes_moved = est.bytes_moved * n
        flops = est.flops * n
        own_time = att.runtime_s is not None
        runtime = att.runtime_s if own_time else sp.dur_s
        rec: Dict[str, Any] = {
            "kind": "kernel", "id": self._next_id, "parent": sp.id,
            "name": att.name, "path": f"{sp.path}/{att.name}",
            "dur_s": runtime,
            "time_scope": "kernel" if own_time else "enclosing-span",
            "count": n,
            "schedule": {"path": att.schedule.path,
                         "variant": att.schedule.variant,
                         "epilogue": att.schedule.epilogue},
            "modeled_bytes": bytes_moved,
            "modeled_flops": flops,
            "reliable": est.reliable,
        }
        self._next_id += 1
        if runtime and runtime > 0:
            # modeled bytes / measured time: the paper's effective bandwidth.
            # Under time_scope="enclosing-span" this is the *attributable*
            # lower bound (the span measured more than this kernel alone).
            rec["effective_bandwidth"] = bytes_moved / runtime
            rec["achieved_gflops"] = flops / runtime / 1e9
        if est.reliable and bytes_moved > 0:
            rec["arithmetic_intensity"] = flops / bytes_moved
        if att.hw is not None:
            rec["hw"] = att.hw.name
            knee = att.hw.peak_flops_f32 / att.hw.hbm_bw
            rec["roofline_knee"] = knee
            ai = rec.get("arithmetic_intensity")
            if ai is not None:
                rec["regime"] = "memory-bound" if ai < knee else "compute-bound"
            bw = rec.get("effective_bandwidth")
            if bw is not None:
                rec["bandwidth_utilization"] = bw / att.hw.hbm_bw
        return rec

    def _emit(self, rec: Dict[str, Any]) -> None:
        self.records.append(rec)
        if self.path is not None:
            if self._fh is None:
                import os

                parent = os.path.dirname(self.path)
                if parent:
                    os.makedirs(parent, exist_ok=True)
                self._fh = open(self.path, "a")
                if self.meta:
                    header = {"kind": "meta", **_jsonable(self.meta)}
                    self._fh.write(json.dumps(header) + "\n")
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()


def _jsonable(obj):
    """Best-effort plain-JSON projection of tag values."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    try:
        return float(obj)
    except (TypeError, ValueError):
        return str(obj)


# ---------------------------------------------------------------------------
# global tracer (launchers and the tuner share one)
# ---------------------------------------------------------------------------

_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    return _GLOBAL


def configure(path: Optional[str] = None, *, enabled: bool = True,
              meta: Optional[Dict] = None) -> Tracer:
    """Install (and return) the process-global tracer."""
    global _GLOBAL
    _GLOBAL.close()
    _GLOBAL = Tracer(path, enabled=enabled, meta=meta)
    return _GLOBAL


def read_trace(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL trace back into a list of records."""
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ---------------------------------------------------------------------------
# arch introspection: which paper-operator kernels run inside one train step?
# ---------------------------------------------------------------------------

def dwconv_step_schedules(cfg, batch: int, seq: int, *, itemsize: int = 4,
                          training: bool = True) -> List[Tuple[str, Any, int]]:
    """``(name, schedule, count)`` attachments for the depthwise-conv kernels
    one jitted train/serve step of ``cfg`` executes.

    SSM archs run one causal conv over ``(x, B, C)`` (width
    ``expand*d_model + 2*d_state``) per layer; RG-LRU/hybrid archs run one
    over ``lru_width`` per recurrent block.  Attention-only archs return
    ``[]`` — their steps carry no paper-operator span.  Training steps
    attach the fused backward alongside the forward.
    """
    from repro.kernels.common import DWConvDims
    from repro.perfmodel import registered_variants, schedule_for
    from repro.tuning.space import Candidate, normalize

    specs: List[Tuple[int, int, str, int]] = []  # (channels, K, variant, count)
    ssm = getattr(cfg, "ssm", None)
    if ssm is not None:
        conv_dim = ssm.expand * cfg.d_model + 2 * ssm.d_state
        specs.append((conv_dim, ssm.d_conv, ssm.conv_variant, cfg.n_layers))
    rglru = getattr(cfg, "rglru", None)
    if rglru is not None:
        pattern = rglru.block_pattern
        n_blocks = (cfg.n_layers // len(pattern)) * pattern.count("rec") \
            + pattern[: cfg.n_layers % len(pattern)].count("rec")
        specs.append((rglru.lru_width, rglru.d_conv, rglru.conv_variant,
                      max(n_blocks, 1)))

    out: List[Tuple[str, Any, int]] = []
    for conv_dim, K, variant, count in specs:
        d = DWConvDims(B=batch, H=conv_dim, L=seq, K=K, padding="causal")
        fwd_variant = variant if variant in registered_variants("fwd") else "row"
        c = normalize(Candidate("fwd", fwd_variant, 8, 512, 128), d)
        out.append(("dwconv_fwd", schedule_for(
            "fwd", fwd_variant, d, itemsize, block_h=c.block_h,
            block_t=c.block_t, batch_chunk=c.batch_chunk,
            epilogue="bias+silu"), count))
        if training:
            cb = normalize(Candidate("bwd_fused", "fused", 8, 512, 128), d,
                           epilogue="bias+silu")
            out.append(("dwconv_bwd_fused", schedule_for(
                "bwd_fused", "fused", d, itemsize, block_h=cb.block_h,
                block_t=cb.block_t, batch_chunk=cb.batch_chunk,
                epilogue="bias+silu"), count))
    return out
