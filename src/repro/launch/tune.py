"""Autotuning CLI — counter-free per-shape kernel selection.

  PYTHONPATH=src python -m repro.launch.tune --shapes paper --budget 50
  PYTHONPATH=src python -m repro.launch.tune --shapes paper --budget 20 --fast
  PYTHONPATH=src python -m repro.launch.tune --shapes 64x128x48x48 --search hillclimb

Workflow (see ``repro.tuning``): enumerate the legal candidate space, rank
it with the analytical traffic/roofline model, measure only the top
survivors with the paper's §III-F event-style timing, and persist winners
into the tuning cache (``REPRO_TUNE_CACHE`` or ``results/tuning/cache.json``)
that ``variant="auto"`` dispatch reads.

``--shapes`` accepts comma-separated presets and/or explicit ``BxHxLxK``
quads.  Preset ``paper`` is the paper's (16384, 128, 48, 48) study shape;
``--fast`` (CI / CPU-interpret regime) swaps it for the benchmark harness's
reduced-batch geometry (64, 128, 48, 48) and trims measurement iterations —
interpret mode executes kernel bodies in Python, so full-batch metering on
CPU is not meaningful, exactly as in ``benchmarks/paper_table2.py``.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List

from repro.kernels.common import DWConvDims
from repro.kernels.epilogue import EPILOGUE_KEYS
from repro.tuning.cache import ShapeKey, TuningCache
from repro.tuning.space import PAPER_DIMS_CPU, PAPER_DIMS_FULL, PATHS
from repro.tuning.tuner import tune_path

PRESETS = {
    "paper": PAPER_DIMS_FULL,
    "paper-cpu": PAPER_DIMS_CPU,
}


def parse_shapes(spec: str, fast: bool) -> List[DWConvDims]:
    out: List[DWConvDims] = []
    for tok in spec.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if tok in PRESETS:
            d = PRESETS[tok]
            if fast and tok == "paper":
                d = PAPER_DIMS_CPU
            out.append(d)
        else:
            try:
                b, h, l, k = (int(v) for v in tok.lower().split("x"))
            except ValueError:
                raise SystemExit(
                    f"bad shape {tok!r}: expected a preset {sorted(PRESETS)} or BxHxLxK")
            out.append(DWConvDims(B=b, H=h, L=l, K=k))
    if not out:
        raise SystemExit("no shapes given")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--shapes", default="paper",
                    help="comma-separated presets (paper, paper-cpu) and/or BxHxLxK")
    ap.add_argument("--budget", type=int, default=50,
                    help="total measured candidates per shape (split across paths)")
    ap.add_argument("--paths", default=",".join(PATHS),
                    help=f"execution paths to tune (default {','.join(PATHS)})")
    ap.add_argument("--search", default="grid", choices=["grid", "hillclimb"])
    ap.add_argument("--dtype", default="float32", choices=["float32", "bfloat16"])
    ap.add_argument("--epilogue", default="none", choices=list(EPILOGUE_KEYS),
                    help="fused bias/activation epilogue to tune the 'fwd' and "
                         "'bwd_fused' paths under (other paths tune epilogue-less)")
    ap.add_argument("--cache", default="",
                    help="cache file (default: $REPRO_TUNE_CACHE or results/tuning/cache.json)")
    ap.add_argument("--iters", type=int, default=3, help="timing iterations per candidate")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--fast", action="store_true",
                    help="CI mode: reduced paper batch, 1 timing iteration")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--bundle", default="",
                    help="signed fleet bundle to import first (warm start: "
                         "keys it covers as trusted entries skip tuning)")
    ap.add_argument("--export-bundle", default="",
                    help="after tuning, export the cache as a signed bundle "
                         "here (a file, or a directory for the "
                         "content-addressed default name); requires "
                         "REPRO_FLEET_KEY")
    ap.add_argument("--strict", action="store_true",
                    help="with --export-bundle: refuse to export while any "
                         "entry is quarantined (otherwise they are dropped); "
                         "with --bundle: reject a bundle carrying "
                         "quarantined entries outright")
    args = ap.parse_args(argv)

    shapes = parse_shapes(args.shapes, args.fast)
    paths = [p.strip() for p in args.paths.split(",") if p.strip()]
    for p in paths:
        if p not in PATHS:
            raise SystemExit(f"unknown path {p!r}; known: {PATHS}")
    iters = 1 if args.fast else args.iters
    cache = TuningCache(args.cache) if args.cache else TuningCache()
    per_path = max(1, args.budget // len(paths))

    if args.bundle:
        from repro.fleet import import_ as fleet_import

        res = fleet_import.import_bundle_guarded(args.bundle, cache=cache,
                                                 strict=args.strict)
        print(f"[tune] bundle {args.bundle}: "
              f"{res.summary() if res else 'rejected; tuning fresh'}",
              flush=True)

    import jax  # deferred: key construction needs the active backend

    backend = jax.default_backend()
    print(f"[tune] cache={cache.path} search={args.search} "
          f"budget={args.budget} ({per_path}/path) dtype={args.dtype}", flush=True)
    for d in shapes:
        for path in paths:
            epi = args.epilogue if path in ("fwd", "bwd_fused") else "none"
            prev = cache.get(ShapeKey(
                path=path, B=d.B, H=d.H, L=d.L, K=d.K, dtype=args.dtype,
                backend=backend, padding=d.padding, epilogue=epi))
            if args.bundle and prev is not None and not prev.quarantined:
                print(f"[tune] warm: {path}/B{d.B}-H{d.H}-L{d.L}-K{d.K} "
                      f"covered by cache/bundle ({prev.variant} "
                      f"{prev.time_us:.1f}us, source={prev.source}); skipping",
                      flush=True)
                continue
            # wall clock here only reports elapsed tuning time; candidate
            # measurements sync inside cost.measure_candidate's timer
            t0 = time.perf_counter()  # repro: noqa(REP002)
            res = tune_path(
                d, path,
                dtype=args.dtype, budget=per_path, search=args.search,
                warmup=args.warmup, iters=iters, cache=cache,
                verbose=args.verbose,
                epilogue=epi,
            )
            e = res.best
            print(
                f"[tune] {res.key.encode()}: {e.variant} "
                f"bh={e.block_h} bt={e.block_t} bc={e.batch_chunk} "
                f"{e.time_us:.1f}us  (space={res.candidates_considered} "
                f"measured={res.candidates_measured} in {time.perf_counter() - t0:.1f}s)",
                flush=True,
            )
    print(f"[tune] wrote {len(cache)} entries to {cache.path}", flush=True)
    if args.export_bundle:
        from repro.fleet import bundle as fleet_bundle

        out = fleet_bundle.export_bundle(cache, args.export_bundle,
                                         strict=args.strict)
        print(f"[tune] exported signed bundle {out}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
