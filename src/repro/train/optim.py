"""Optimizers as pure pytree transforms (no external deps).

The paper's training configuration (§III-C) is SGD with momentum 0.9,
lr 1e-3, and global-norm gradient clipping at 1.0; the LM architecture pool
uses AdamW.  Both are implemented as (init, update) pairs over arbitrary
parameter pytrees, sharding-transparent (states inherit parameter
shardings under pjit), with optional f32 master state for bf16 params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    update: Callable[[Params, Params, OptState], Tuple[Params, OptState]]
    name: str = "opt"


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def sgd_momentum(lr: float = 1e-3, momentum: float = 0.9, clip_norm: Optional[float] = 1.0) -> Optimizer:
    """Paper §III-C configuration."""

    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, params, state):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
        )
        new_params = jax.tree.map(lambda p, m: (p - lr * m.astype(p.dtype)).astype(p.dtype), params, mu)
        return new_params, {"mu": mu, "step": state["step"] + 1}

    return Optimizer(init, update, name="sgd_momentum")


def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
    warmup_steps: int = 0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, params, state):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        sched = jnp.minimum(1.0, step.astype(jnp.float32) / max(warmup_steps, 1)) if warmup_steps else 1.0
        lr_t = lr * sched
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}

    return Optimizer(init, update, name="adamw")


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd_momentum":
        return sgd_momentum(**kw)
    if name == "adamw":
        return adamw(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
