"""Derivations from a :class:`KernelSchedule` — the counter-free toolkit.

One spec in, every §III-G quantity out:

  * :func:`derive_traffic`     — HBM byte traffic (``TrafficEstimate``);
  * :func:`vmem_bytes`         — per-grid-cell VMEM staging footprint;
  * :func:`check_legality`     — structural + VMEM legality verdict;
  * :func:`analytical_time_s`  — stage-1 roofline-bounded time estimate;
  * :func:`roofline_point`     — arithmetic intensity, regime, effective
    bandwidth — the paper's Table III / Fig. 10 row for this schedule.

These replace the four hand-maintained copies that previously lived in
``analysis/traffic.py`` (byte models), ``tuning/space.py`` (VMEM/legality),
``tuning/cost.py`` (analytical time), and the benchmark scripts (roofline
rows).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, Optional, Tuple

from repro.perfmodel.schedule import KernelSchedule, TrafficEstimate

if TYPE_CHECKING:  # duck-typed at runtime: keeps perfmodel import-cycle-free
    from repro.analysis.hw import HardwareModel

# Fixed per-DMA issue overhead for the analytical model.  The value is a
# structural tie-breaker (it orders high-transaction-count candidates behind
# equal-traffic low-transaction ones), not a calibrated latency.
DMA_OVERHEAD_S = 1e-7


def derive_traffic(s: KernelSchedule) -> TrafficEstimate:
    """Sum the schedule's operand HBM crossings into the typed estimate."""
    return TrafficEstimate(
        flops=s.flops,
        bytes_read=sum(o.hbm_bytes for o in s.reads()),
        bytes_written=sum(o.hbm_bytes for o in s.writes()),
        transactions=sum(o.transactions for o in s.operands),
        aligned=s.aligned,
        reliable=s.reliable,
    )


def vmem_bytes(s: KernelSchedule) -> int:
    """Per-grid-cell VMEM staging footprint: the staged operand blocks plus
    scratch (accumulators, recompute temporaries).  Operands with no
    ``block`` are streamed/unstaged and charge nothing — the same
    convention the tuner's legality predicate has always used."""
    return sum(o.vmem_bytes for o in s.operands)


def check_legality(
    s: KernelSchedule,
    *,
    hw: Optional["HardwareModel"] = None,
) -> Tuple[bool, str]:
    """Structural kernel asserts + (when ``hw`` models it) the VMEM bound.

    Returns ``(ok, reason)`` — the reason names the violated constraint so
    tuner logs stay self-explanatory.
    """
    if not s.legal:
        return False, s.illegal_reason
    if hw is not None and hw.vmem_bytes:
        need = vmem_bytes(s)
        if need > hw.vmem_bytes:
            return False, f"VMEM working set {need}B > {int(hw.vmem_bytes)}B"
    return True, "ok"


def analytical_time_s(
    s: KernelSchedule,
    hw: "HardwareModel",
    *,
    dma_overhead_s: float = DMA_OVERHEAD_S,
) -> float:
    """Roofline-bounded execution-time estimate (seconds).

    ``max(compute, memory)`` is the perfect-overlap roofline bound; the DMA
    term models serialization of transaction issue, which is what actually
    separates the per-tap-DMA variants from the staged ones on equal-FLOP
    problems.  ``reliable=False`` traffic (the naive baseline's
    cache-dependent redundancy) is still ranked by its logical traffic —
    pessimistic, exactly like the paper's Table III treatment.
    """
    est = derive_traffic(s)
    compute_s = est.flops / hw.peak_flops_f32
    memory_s = est.bytes_moved / hw.hbm_bw
    return max(compute_s, memory_s) + est.transactions * dma_overhead_s


@dataclasses.dataclass(frozen=True)
class RooflinePoint:
    """One (variant x path) point of the paper's Fig. 10 / Table III row,
    derived from a schedule with no hardware counters."""

    path: str
    variant: str
    epilogue: str
    flops: float
    bytes_read: float
    bytes_written: float
    transactions: float
    reliable: bool
    # roofline placement (None when the traffic is an unreliable proxy)
    arithmetic_intensity: Optional[float]
    knee: float                      # FLOP/byte where the roofs meet
    regime: Optional[str]            # "memory-bound" | "compute-bound"
    roof_gflops: Optional[float]     # attainable GFLOP/s at this AI
    # time + bandwidth accounting
    runtime_s: float                 # measured if given, else modeled bound
    runtime_modeled: bool            # True when runtime_s is the model's bound
    achieved_gflops: Optional[float]
    effective_bandwidth: Optional[float]   # bytes_moved / runtime_s
    bandwidth_utilization: Optional[float]  # effective / hw peak

    @property
    def bytes_moved(self) -> float:
        return self.bytes_read + self.bytes_written

    def to_dict(self) -> Dict[str, Any]:
        out = dataclasses.asdict(self)
        out["bytes_moved"] = self.bytes_moved
        return out


def roofline_point(
    s: KernelSchedule,
    hw: "HardwareModel",
    *,
    runtime_s: Optional[float] = None,
    precision: str = "f32",
) -> RooflinePoint:
    """Place one schedule on the roofline.

    ``runtime_s`` is a *measured* steady-state runtime when available (the
    paper's Tables II/III workflow: modeled bytes / measured time =
    effective bandwidth); when omitted, the analytical roofline bound
    stands in, so the report stays fully counter-free and measurement-free.
    Unreliable traffic (the naive proxy) reports achieved GFLOP/s but
    ``N/A`` intensity/bandwidth, exactly like the paper's Table III.
    """
    est = derive_traffic(s)
    peak = hw.peak_flops_f32 if precision == "f32" else hw.peak_flops
    knee = peak / hw.hbm_bw
    modeled = runtime_s is None
    if modeled:
        runtime_s = max(est.flops / peak, est.bytes_moved / hw.hbm_bw)
    achieved = est.flops / runtime_s / 1e9 if runtime_s > 0 else None
    if not est.reliable:
        return RooflinePoint(
            path=s.path, variant=s.variant, epilogue=s.epilogue,
            flops=est.flops, bytes_read=est.bytes_read,
            bytes_written=est.bytes_written, transactions=est.transactions,
            reliable=False, arithmetic_intensity=None, knee=knee,
            regime=None, roof_gflops=None, runtime_s=runtime_s,
            runtime_modeled=modeled, achieved_gflops=achieved,
            effective_bandwidth=None, bandwidth_utilization=None)
    ai = est.arithmetic_intensity
    eff_bw = est.bytes_moved / runtime_s if runtime_s > 0 else None
    return RooflinePoint(
        path=s.path, variant=s.variant, epilogue=s.epilogue,
        flops=est.flops, bytes_read=est.bytes_read,
        bytes_written=est.bytes_written, transactions=est.transactions,
        reliable=True, arithmetic_intensity=ai, knee=knee,
        regime="memory-bound" if ai < knee else "compute-bound",
        roof_gflops=min(ai * hw.hbm_bw, peak) / 1e9,
        runtime_s=runtime_s, runtime_modeled=modeled,
        achieved_gflops=achieved, effective_bandwidth=eff_bw,
        bandwidth_utilization=(eff_bw / hw.hbm_bw) if eff_bw is not None else None)
