"""Fused-epilogue gate (the epilogue PR's tentpole benchmark).

The model-level call sites compose the depthwise conv with a per-channel
bias add and/or a pointwise activation (GELU in S4ConvD, bias+SiLU in the
Mamba-2 block).  This benchmark gates the fused-epilogue kernel family
against the unfused composition in three regimes:

  *modeled*   — whole-block (fwd + bwd) HBM bytes at the paper geometry
                (B=32, H=128, L=48, K=48) for the in-register epilogue +
                activation-recompute backward vs the unfused chain under
                ordinary autodiff (standalone elementwise passes + saved
                pre-activation residual).  **Gate**: fused bytes <= 0.75x
                unfused bytes, for both call-site epilogues.

  *exactness* — dx/dk/dbias from the fused epilogue backward vs ``jax.vjp``
                of the unfused reference composition, and the ``act=none``
                path bitwise-identical to the pre-epilogue kernels (the
                controlled per-variant study is untouched).  Violations are
                FAILED rows (nonzero harness exit), not exceptions.

  *measured*  — interpret-mode wall-clock of the fused fwd+bwd vs the
                unfused composition at reduced batch (structure on the CPU
                validation regime, not TPU prediction); medians, exported
                as the ``epilogue_fused_speedup`` top-level metric.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import perfmodel
from repro.analysis.hw import TPU_V5E
from repro.analysis.timer import time_fn
from repro.core import dwconv as dw
from repro.kernels import ops, ref
from repro.kernels.common import DWConvDims

# Acceptance gate: the fused-epilogue whole block must move at most this
# fraction of the unfused composition's modeled HBM bytes on the paper shape.
GATE_RATIO = 0.75
EPI_DIMS = DWConvDims(B=32, H=128, L=48, K=48)
# The two call-site epilogues: S4ConvD (GELU, no bias), Mamba-2 (bias+SiLU).
CALL_SITE_EPILOGUES = ("gelu", "bias+silu")


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def modeled_rows() -> List[Row]:
    hw = TPU_V5E
    rows: List[Row] = []
    worst = 0.0
    for epi in CALL_SITE_EPILOGUES:
        points = {
            name: perfmodel.roofline_point(
                perfmodel.epilogue_block_schedule(EPI_DIMS, epilogue=epi,
                                                  fused=fused), hw)
            for name, fused in (("fused", True), ("unfused", False))
        }
        for name, p in points.items():
            rows.append(Row(
                f"paper_epilogue/modeled/{epi}/{name}",
                p.runtime_s * 1e6,
                f"bytes={p.bytes_moved / 1e6:.3f}MB "
                f"AI={p.arithmetic_intensity:.2f} "
                f"roofline={p.regime}",
            ))
        ratio = points["fused"].bytes_moved / points["unfused"].bytes_moved
        worst = max(worst, ratio)
        rows.append(Row(
            f"paper_epilogue/modeled/{epi}/ratio", 0.0,
            f"fused_vs_unfused_bytes={ratio:.3f}"))
    verdict = "GATE_OK" if worst <= GATE_RATIO else "GATE_FAILED"
    rows.append(Row(
        "paper_epilogue/modeled/gate", 0.0,
        f"worst_ratio={worst:.3f} (gate <= {GATE_RATIO}) {verdict}"))
    return rows


def _unfused_ref(x, k, b, act, pad):
    """The unfused composition the call sites used to run (and the autodiff
    oracle the fused gradients must match)."""
    y = ref.dwconv_fwd_ref(x, k, pad)
    if b is not None:
        y = y + b[None, :, None]
    return jax.nn.gelu(y) if act == "gelu" else jax.nn.silu(y)


def exactness_rows() -> List[Row]:
    rows: List[Row] = []
    B, H, L, K = 4, 8, 96, 9
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, H, L)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(B, H, L)), jnp.float32)
    opts = ops.KernelOptions(batch_chunk=2, interpret=True)

    for epi, bias, act, pad in (("gelu", None, "gelu", "same"),
                                ("bias+silu", b, "silu", "causal")):
        db_want = None
        if bias is None:
            _, vjp = jax.vjp(lambda x, k: _unfused_ref(x, k, None, act, pad), x, k)
            dx_want, dk_want = vjp(dy)
        else:
            _, vjp3 = jax.vjp(lambda x, k, b: _unfused_ref(x, k, b, act, pad), x, k, b)
            dx_want, dk_want, db_want = vjp3(dy)
        dx, dk, db = ops.dwconv_bwd_fused_act_op(
            x, dy, k, bias, pad, "fused", opts, act=act)
        errs = [float(jnp.max(jnp.abs(dx - dx_want))),
                float(jnp.max(jnp.abs(dk - dk_want)))]
        if db_want is not None:
            errs.append(float(jnp.max(jnp.abs(db - db_want))))
        ok = max(errs) < 1e-3
        rows.append(Row(
            f"paper_epilogue/grads/{epi}", 0.0,
            f"max_err={max(errs):.2e} vs jax.vjp(unfused) "
            + ("GRADS_OK" if ok else "GRADS_FAILED")))

    # act=none must be bitwise-identical to the pre-epilogue kernels.
    plain = ops.dwconv_fwd_op(x, k, "same", "row", opts)
    via_epi = dw.dwconv_act(x, k, act="none", padding="same", variant="row", opts=opts)
    bitwise = bool(jnp.all(plain == via_epi))
    rows.append(Row(
        "paper_epilogue/act_none_bitwise", 0.0,
        "act=none bit-identical to pre-epilogue kernels: "
        + ("BITWISE_OK" if bitwise else "BITWISE_FAILED")))
    return rows


def measured_rows(iters: int = 3) -> List[Row]:
    """Interpret-mode fwd+bwd wall-clock: fused epilogue vs unfused chain."""
    B, H, L, K = 16, 64, 48, 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, H, L)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    opts = ops.KernelOptions(batch_chunk=8, interpret=True)

    def fused_loss(x, k, b):
        return jnp.sum(dw.dwconv_act(x, k, b, act="silu", padding="causal",
                                     variant="fused", opts=opts))

    def unfused_loss(x, k, b):
        y = dw.dwconv(x, k, padding="causal", variant="fused", opts=opts)
        return jnp.sum(jax.nn.silu(y + b[None, :, None]))

    f_fused = jax.jit(jax.grad(fused_loss, argnums=(0, 1, 2)))
    f_unfused = jax.jit(jax.grad(unfused_loss, argnums=(0, 1, 2)))
    t_fused = time_fn(f_fused, x, k, b, warmup=1, iters=iters)
    t_unfused = time_fn(f_unfused, x, k, b, warmup=1, iters=iters)
    speedup = t_unfused.median_s / max(t_fused.median_s, 1e-12)
    return [
        Row("paper_epilogue/measured/fused", t_fused.median_us,
            "fwd+bwd, bias+silu in-kernel, interpret mode"),
        Row("paper_epilogue/measured/unfused", t_unfused.median_us,
            "fwd+bwd, conv then standalone bias/silu, interpret mode"),
        Row("paper_epilogue/measured/speedup", 0.0,
            f"epilogue_fused={speedup:.2f}x (interpret-mode wall-clock)"),
    ]


_SPEEDUP_RE = re.compile(r"epilogue_fused=([0-9.]+)x")


def top_level_metrics(rows: List[Row]) -> Dict[str, float]:
    """``benchmarks/run.py`` hook: promote the measured epilogue-fusion
    speedup to a top-level ``--json`` key (``BENCH_kernels.json``)."""
    for r in rows:
        m = _SPEEDUP_RE.search(r.derived)
        if m:
            return {"epilogue_fused_speedup": float(m.group(1))}
    return {}


def run(fast: bool = False) -> List[Row]:
    rows = modeled_rows()
    rows += exactness_rows()
    rows += measured_rows(iters=2 if fast else 3)
    return rows


if __name__ == "__main__":
    import sys

    rows = run()
    for r in rows:
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
    if any("FAILED" in r.derived for r in rows):
        sys.exit(1)
