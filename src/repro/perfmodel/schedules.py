"""Registered :class:`KernelSchedule` builders — one per kernel variant.

Every Pallas kernel variant in this repo registers a builder here, keyed by
``(execution path, variant)``.  A builder reads the problem shape, the
tiling knobs, and the epilogue key, pulls the *executed* geometry from
``perfmodel/geometry.py`` (the same functions ``kernels/ops.py`` pads and
tiles with), and emits the pure-data schedule: grid extents, per-operand
HBM crossings and staged block shapes, partials arrays, flop counts, and
structural-legality verdicts.

All downstream numbers — ``analysis/traffic.py``'s byte models,
``tuning/space.py``'s VMEM/legality predicates, ``tuning/cost.py``'s
stage-1 analytical time, and the ``launch.report`` roofline tables — are
derived from these schedules (``perfmodel/derive.py``).  The golden
equivalence suite (``tests/test_perfmodel_golden.py``) pins every derived
quantity to integer-byte equality with the pre-refactor hand-written
formulas.

Two model families coexist, exactly as before the refactor:

  * the **TPU explicit-DMA** family (paths ``fwd`` / ``bwd_in`` / ``bwd_k``
    / ``bwd_fused``): traffic is what the BlockSpecs physically move;
  * the **paper-mode** family (paths ``paper_fwd`` / ``paper_bwd_k``,
    paper variant names): §III-G cache-adjusted traffic on the P100, where
    only the redundancy surviving L1/L2/shared memory is charged.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from repro.kernels.common import LANE, DWConvDims, cdiv, round_up
from repro.kernels.epilogue import parse_epilogue
from repro.perfmodel.geometry import (
    bwd_time_tiles,
    decode_tiles,
    effective_tiles,
    fwd_tile_grid,
    time_tile,
)
from repro.perfmodel.schedule import (
    KernelSchedule,
    OperandTraffic,
    merge_schedules,
    path_flops,
)

# Pointwise-activation cost proxy (tanh/sigmoid polynomial, value or
# derivative) — a flop ordering term, not a calibrated count.
ACT_FLOPS_PER_ELEM = 10.0

SCHEDULE_BUILDERS: Dict[Tuple[str, str], Callable[..., KernelSchedule]] = {}


def register_schedule(*keys: Tuple[str, str]):
    """Register a builder for one or more ``(path, variant)`` pairs."""
    def deco(fn):
        for key in keys:
            if key in SCHEDULE_BUILDERS:
                raise ValueError(f"duplicate schedule registration {key}")
            SCHEDULE_BUILDERS[key] = fn
        return fn
    return deco


def registered_variants(path: str) -> Tuple[str, ...]:
    return tuple(v for (p, v) in SCHEDULE_BUILDERS if p == path)


def schedule_for(
    path: str,
    variant: str,
    d: DWConvDims,
    itemsize: int = 4,
    *,
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
    epilogue: str = "none",
    fused: bool = True,
    bwd_in_variant: str = "row",
    bwd_k_variant: str = "accum",
) -> KernelSchedule:
    """Build the registered schedule for one kernel configuration."""
    try:
        builder = SCHEDULE_BUILDERS[(path, variant)]
    except KeyError:
        known = sorted(registered_variants(path))
        raise ValueError(
            f"no schedule registered for path={path!r} variant={variant!r}"
            + (f"; known variants: {known}" if known else f"; unknown path {path!r}")
        ) from None
    return builder(
        path, variant, d, itemsize,
        block_h=block_h, block_t=block_t, batch_chunk=batch_chunk,
        epilogue=epilogue, fused=fused,
        bwd_in_variant=bwd_in_variant, bwd_k_variant=bwd_k_variant)


def epilogue_elementwise_ops(bias: bool, act: str) -> int:
    """Standalone elementwise passes the unfused composition runs forward."""
    return (1 if bias else 0) + (1 if act != "none" else 0)


def epilogue_flops(d: DWConvDims, bias: bool, act: str) -> float:
    elems = d.B * d.H * d.L
    return (elems if bias else 0.0) + (ACT_FLOPS_PER_ELEM * elems if act != "none" else 0.0)


# ---------------------------------------------------------------------------
# forward family (paths "fwd" and "bwd_in": same kernels, flipped filter)
# ---------------------------------------------------------------------------


def _fwd_epilogue_extras(d, itemsize, bias, act, fused):
    """Bias-vector read + (unfused only) the standalone elementwise passes."""
    ops = []
    if bias:
        ops.append(OperandTraffic("bias", "read", d.H, itemsize,
                                  note="per-channel bias vector"))
    n_ops = 0
    if not fused:
        n_ops = epilogue_elementwise_ops(bias, act)
        slab = d.B * d.H * d.L
        for i in range(n_ops):
            ops.append(OperandTraffic(f"epilogue_pass{i}:in", "read", slab, itemsize,
                                      note="standalone elementwise op, full-tensor read"))
            ops.append(OperandTraffic(f"epilogue_pass{i}:out", "write", slab, itemsize,
                                      note="standalone elementwise op, full-tensor write"))
    return tuple(ops), n_ops


def _fwd_schedule(path, variant, d, itemsize, *, block_h, block_t,
                  epilogue="none", fused=True, **_):
    bias, act = parse_epilogue(epilogue)
    Hb, Lout, Lt, nT, n_tiles = fwd_tile_grid(d, block_h, block_t)
    Wpad = round_up(Lout + d.K - 1, LANE)
    flops = path_flops(d) + epilogue_flops(d, bias, act)
    y = OperandTraffic("y", "write", d.B * d.H * d.L, itemsize,
                       block=(Hb, Lout) if variant == "row" else (Hb, Lt),
                       note="output, written once")
    k = OperandTraffic("k", "read", d.H * d.K, itemsize,
                       note="filter bank, charged once uniformly across variants")
    epi_ops, n_ops = _fwd_epilogue_extras(d, itemsize, bias, act, fused)
    grid = (("b", d.B), ("h", cdiv(d.H, Hb)), ("t", nT))
    aligned = reliable = True
    legal, reason = True, "ok"

    if variant == "naive":
        # K unaligned per-tap DMAs of an (Hb, Lt) window per output tile.
        x = OperandTraffic("x", "read", n_tiles * d.K * (Hb * Lt), itemsize,
                           transactions=n_tiles * d.K, block=(Hb, Lt + LANE),
                           note=f"{d.K} per-tap window DMAs per output tile")
        aligned = reliable = False
        if Lt % LANE != 0:
            legal, reason = False, f"Lt={Lt} not lane-aligned (Lt % {LANE} != 0)"
    elif variant == "lane":
        # Same per-tap redundancy; windows widened to lane alignment.
        x = OperandTraffic("x", "read", n_tiles * d.K * (Hb * (Lt + LANE)), itemsize,
                           transactions=n_tiles * d.K, block=(Hb, Lt + LANE),
                           note=f"{d.K} lane-aligned per-tap DMAs per output tile")
        if Lt % LANE != 0:
            legal, reason = False, f"Lt={Lt} not lane-aligned (Lt % {LANE} != 0)"
    elif variant == "block":
        # Current + neighbour halo tile staged in VMEM per output tile.
        x = OperandTraffic("x", "read", n_tiles * 2 * (Hb * Lt), itemsize,
                           transactions=n_tiles * 2, block=(2, Hb, Lt),
                           note="current + neighbour halo tile per output tile")
        if Lt < d.K - 1:
            legal, reason = False, f"halo K-1={d.K - 1} does not fit tile Lt={Lt}"
    elif variant == "row":
        # Full row staged once: every input element crosses HBM once.
        x = OperandTraffic("x", "read", d.B * d.H * (Lout + d.K - 1), itemsize,
                           transactions=d.B * cdiv(d.H, Hb), block=(Hb, Wpad),
                           note="whole padded row staged once per (b, h-block)")
        grid = (("b", d.B), ("h", cdiv(d.H, Hb)))
    elif variant == "xla":
        # Fused elementwise loop: x once, y once (logical minimum).
        x = OperandTraffic("x", "read", d.B * d.H * (d.L + d.K - 1), itemsize,
                           note="XLA-fused logical minimum: padded input once")
        y = OperandTraffic("y", "write", d.B * d.H * d.L, itemsize)
        grid = ()
    else:
        raise ValueError(variant)
    return KernelSchedule(
        path=path, variant=variant, dims=d, grid=grid,
        operands=(x, k, y) + epi_ops, flops=flops,
        epilogue=epilogue, epilogue_ops=n_ops,
        aligned=aligned, reliable=reliable, legal=legal, illegal_reason=reason)


for _v in ("naive", "lane", "block", "row", "xla"):
    register_schedule(("fwd", _v), ("bwd_in", _v))(_fwd_schedule)


# ---------------------------------------------------------------------------
# weight-gradient family (path "bwd_k": reduction over the B x L domain)
# ---------------------------------------------------------------------------


def _bwdk_schedule(path, variant, d, itemsize, *, block_h, block_t,
                   batch_chunk, **_):
    Hb, Lt_eff, Bc, Lout = effective_tiles(d, block_h, block_t, batch_chunk)
    nC = cdiv(d.B, Bc)
    nH = cdiv(d.H, Hb)
    Kp = round_up(d.K, LANE)
    Wpad = round_up(Lout + d.K - 1, LANE)
    slab = d.B * d.H * d.L
    flops = path_flops(d)
    nT, halo = bwd_time_tiles(d, variant, block_t)
    tiled = nT > 1
    dk = OperandTraffic("dk", "write", d.H * d.K, itemsize)
    # f32 accumulator / partials block, staged per grid cell (not charged
    # for the untiled regime — the historical footprint convention).
    dk_acc = OperandTraffic("dk_acc", "scratch", 0, 4, block=(Hb, Kp),
                            block_itemsize=4,
                            note="f32 dk accumulator, staged per (h-block, chunk)")
    grid = (("chunk", nC), ("h", nH), ("t", nT))

    if variant == "naive":
        # Both operands re-read per tap; no reuse across the K taps.
        x = OperandTraffic("x", "read", d.K * slab, itemsize,
                           transactions=nH * nC * d.K, block=(Bc, Hb, Wpad),
                           note=f"{d.K}x redundant: one pass per tap")
        dy = OperandTraffic("dy", "read", d.K * slab, itemsize,
                            transactions=nH * nC * d.K, block=(Bc, Hb, d.L),
                            note=f"{d.K}x redundant: one pass per tap")
        return KernelSchedule(path, variant, d, (("chunk", nC), ("h", nH)),
                              (x, dy, dk), flops,
                              aligned=False, reliable=False)
    if variant in ("accum", "twostage"):
        per_op_binds = 2 if tiled else 1  # tiled cells bind (cur, next) x
        x = OperandTraffic(
            "x", "read", slab + halo, itemsize,
            transactions=nH * nC * nT * per_op_binds,
            block=(2, Bc, Hb, Lt_eff) if tiled else (Bc, Hb, Wpad),
            note="staged slab; tiled: + K-1 halo columns per interior seam")
        dy = OperandTraffic(
            "dy", "read", slab, itemsize, transactions=nH * nC * nT,
            block=(Bc, Hb, Lt_eff) if tiled else (Bc, Hb, d.L),
            note="staged slab, one pass")
        ops = [x, dy, dk]
        if tiled:
            ops.append(dk_acc)
        if variant == "twostage":
            # Partials round-trip HBM: one f32 block per (chunk, time-tile).
            partials = nC * nT * d.H * Kp
            ops.append(OperandTraffic("dk_partials", "write", partials, 4,
                                      transactions=nH * nC * nT,
                                      note="stage-1 f32 partials -> HBM"))
            ops.append(OperandTraffic("dk_partials", "read", partials, 4,
                                      note="stage-2 re-read of the partials"))
        return KernelSchedule(path, variant, d, grid, tuple(ops), flops)
    if variant == "xla":
        x = OperandTraffic("x", "read", slab, itemsize)
        dy = OperandTraffic("dy", "read", slab, itemsize)
        return KernelSchedule(path, variant, d, (), (x, dy, dk), flops)
    raise ValueError(variant)


for _v in ("naive", "twostage", "accum", "xla"):
    register_schedule(("bwd_k", _v))(_bwdk_schedule)


# ---------------------------------------------------------------------------
# whole-backward family (path "bwd_fused"): fused single pass vs split.
#
# Unlike the per-kernel schedules above, these charge the *padded-layout
# materialization* traffic (each ``jnp.pad`` reads its source and writes the
# padded buffer to HBM) — that is exactly the traffic the fusion removes, so
# a fused-vs-split comparison that ignored it would miss the point.
# ---------------------------------------------------------------------------


def _bwd_split_schedule(path, variant, d, itemsize, *, block_h, block_t,
                        batch_chunk, bwd_in_variant="row",
                        bwd_k_variant="accum", **_):
    """Split (bwd_in + bwd_k) composite with the three pad materializations
    the two-op path runs (dy -> adjoint layout, x -> x_pad, dy -> forward-
    aligned layout; each: read source, write padded buffer)."""
    part_in = schedule_for("bwd_in", bwd_in_variant, d, itemsize,
                           block_h=block_h, block_t=block_t)
    part_k = schedule_for("bwd_k", bwd_k_variant, d, itemsize,
                          block_h=block_h, block_t=block_t,
                          batch_chunk=batch_chunk)
    slab = d.B * d.H * d.L
    pslab = d.B * d.H * (d.L + d.K - 1)
    pads = (
        OperandTraffic("pad:dy_src", "read", slab, itemsize),
        OperandTraffic("pad:dy_adjoint", "write", pslab, itemsize, transactions=1,
                       note="dy materialized in the adjoint layout"),
        OperandTraffic("pad:x_src", "read", slab, itemsize),
        OperandTraffic("pad:x_pad", "write", pslab, itemsize, transactions=1,
                       note="x re-padded for the dk reduction"),
        OperandTraffic("pad:dy_src2", "read", slab, itemsize),
        OperandTraffic("pad:dy_fwd", "write", slab, itemsize, transactions=1,
                       note="dy materialized in the forward-aligned layout"),
    )
    return merge_schedules(path, variant, d, (part_in, part_k),
                           extra_operands=pads)


register_schedule(("bwd_fused", "split"))(
    lambda path, variant, d, itemsize, *, epilogue="none", **kw:
        _bwd_split_schedule(path, variant, d, itemsize, **kw)
        if epilogue == "none"
        else _split_epilogue_schedule(path, variant, d, itemsize,
                                      epilogue=epilogue, **kw))


def _split_epilogue_schedule(path, variant, d, itemsize, *, epilogue,
                             block_h, block_t, batch_chunk, **_):
    """Activation-*recompute* split composition (what
    ``ops.dwconv_bwd_fused_act_op`` actually runs on the split path): one
    standalone pre-activation pass (conv + bias, no act), an effective-
    gradient pass, the dbias reduction, then the ordinary split backward."""
    bias, act = parse_epilogue(epilogue)
    base = _bwd_split_schedule(path, variant, d, itemsize, block_h=block_h,
                               block_t=block_t, batch_chunk=batch_chunk)
    pre = schedule_for("fwd", "row", d, itemsize,
                       block_h=block_h, block_t=block_t)
    slab = d.B * d.H * d.L
    extras = [
        OperandTraffic("dy_eff:dy", "read", slab, itemsize, transactions=1),
        OperandTraffic("dy_eff:pre", "read", slab, itemsize,
                       note="recomputed pre-activation, read back once"),
        OperandTraffic("dy_eff", "write", slab, itemsize, transactions=1),
    ]
    if bias:
        extras.append(OperandTraffic("dbias:dy_eff", "read", slab, itemsize,
                                     note="dbias reduction re-reads dy_eff"))
        extras.append(OperandTraffic("dbias", "write", d.H, itemsize))
    return merge_schedules(
        path, variant, d, (base, pre), extra_operands=tuple(extras),
        extra_flops=epilogue_flops(d, bias, act),
        epilogue=epilogue)


def _bwd_fused_schedule(path, variant, d, itemsize, *, block_h, block_t,
                        batch_chunk, epilogue="none", **_):
    bias, act = parse_epilogue(epilogue)
    epi = epilogue != "none"
    Hb, _, Bc, Lout = effective_tiles(d, block_h, block_t, batch_chunk)
    nC = cdiv(d.B, Bc)
    nH = cdiv(d.H, Hb)
    Kp = round_up(d.K, LANE)
    Wpad = round_up(Lout + d.K - 1, LANE)
    slab = d.B * d.H * d.L
    pslab = d.B * d.H * (d.L + d.K - 1)
    Lt = time_tile(d.L, d.K, block_t, variant, epilogue)
    nT, halo = bwd_time_tiles(d, variant, block_t, epilogue)
    tiled = nT > 1
    # Per-operand seam re-reads: the staged x slab needs prev+cur+next tiles
    # under the epilogue recompute window (two halo charges), cur+next
    # otherwise (one); dy always cur+next (one).
    x_halo, dy_halo = (2 * halo, halo) if epi else (halo, halo)
    x_binds = (3 if epi else 2) if tiled else 1
    dy_binds = 2 if tiled else 1
    # dx taps + dk reduction (+ the in-register pre-activation recompute).
    flops = (3.0 if epi else 2.0) * path_flops(d) + epilogue_flops(d, bias, act)
    x_block = ((x_binds, Bc, Hb, Lt) if tiled else (Bc, Hb, Wpad))
    dy_block = ((dy_binds, Bc, Hb, Lt) if tiled else (Bc, Hb, Wpad))
    operands = [
        # One pad materialization (dy, single unified layout); the forward's
        # x_pad residual is reused verbatim — zero backward pad cost for x.
        OperandTraffic("pad:dy_src", "read", slab, itemsize),
        OperandTraffic("pad:dy_unified", "write", pslab, itemsize, transactions=1,
                       note="single unified dy layout (dx taps + off_dk reduction)"),
        OperandTraffic("x_pad", "read", pslab + x_halo, itemsize,
                       transactions=nH * nC * nT * x_binds, block=x_block,
                       note="forward residual reused; tiled: haloed seam re-reads"),
        OperandTraffic("dy_pad", "read", pslab + dy_halo, itemsize,
                       transactions=nH * nC * nT * dy_binds, block=dy_block,
                       note="unified dy layout; tiled: haloed seam re-reads"),
        OperandTraffic("k", "read", d.H * d.K, itemsize,
                       transactions=nH * nC * nT,
                       note="filter block per grid cell (VMEM resident)"),
        OperandTraffic("dx", "write", slab, itemsize,
                       block=(Bc, Hb, Lt) if tiled else (Bc, Hb, Lout)),
        OperandTraffic("dk", "write", d.H * d.K, itemsize),
        OperandTraffic("dk_acc", "scratch", 0, 4, block=(Hb, Kp), block_itemsize=4,
                       note="f32 dk accumulator per (h-block, chunk) cell"),
    ]
    if epi:
        operands.append(OperandTraffic(
            "bias", "read", d.H if bias else 0, itemsize,
            transactions=nH * nC * nT if bias else 0))
        operands.append(OperandTraffic("dbias", "write", d.H if bias else 0, itemsize))
        # Recompute temporaries: the pre-activation and effective-gradient
        # windows held in f32 alongside the staged slabs.
        tmp = (Bc, Hb, Lt + d.K - 1) if tiled else (Bc, Hb, Lout)
        operands.append(OperandTraffic("pre", "scratch", 0, 4, block=tmp,
                                       block_itemsize=4,
                                       note="recomputed pre-activation (f32)"))
        operands.append(OperandTraffic("dy_eff", "scratch", 0, 4, block=tmp,
                                       block_itemsize=4,
                                       note="effective gradient dy * act'(pre) (f32)"))
    if variant == "fused_partials":
        # f32 HBM round-trip; the epilogue kernels append a dbias column
        # block (LANE wide) to every partials row.
        partials = nC * nT * d.H * ((Kp + LANE) if epi else Kp)
        operands.append(OperandTraffic("partials", "write", partials, 4,
                                       transactions=nH * nC * nT,
                                       note="stage-1 f32 partials -> HBM"))
        operands.append(OperandTraffic("partials", "read", partials, 4))
    elif variant != "fused":
        raise ValueError(variant)
    return KernelSchedule(
        path=path, variant=variant, dims=d,
        grid=(("chunk", nC), ("h", nH), ("t", nT)),
        operands=tuple(operands), flops=flops, epilogue=epilogue)


for _v in ("fused", "fused_partials"):
    register_schedule(("bwd_fused", _v))(_bwd_fused_schedule)


def unfused_epilogue_bwd_schedule(
    d: DWConvDims,
    itemsize: int = 4,
    *,
    epilogue: str = "none",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> KernelSchedule:
    """Backward of the *unfused composition* under ordinary autodiff
    (``jax.vjp`` of conv -> bias add -> act): the activation backward reads
    dy and the saved pre-activation residual and writes the effective
    gradient, the dbias reduction re-reads it, and the split two-op
    backward consumes it."""
    bias, act = parse_epilogue(epilogue)
    base = _bwd_split_schedule("bwd_fused", "split", d, itemsize,
                               block_h=block_h, block_t=block_t,
                               batch_chunk=batch_chunk)
    slab = d.B * d.H * d.L
    extras = []
    if act != "none":
        extras += [
            OperandTraffic("act_bwd:dy", "read", slab, itemsize, transactions=1),
            OperandTraffic("act_bwd:pre_residual", "read", slab, itemsize,
                           note="saved pre-activation residual (forward-side write "
                                "charged by the unfused forward model)"),
            OperandTraffic("dy_eff", "write", slab, itemsize),
        ]
    if bias:
        extras += [
            OperandTraffic("dbias:dy_eff", "read", slab, itemsize, transactions=1),
            OperandTraffic("dbias", "write", d.H, itemsize),
        ]
    return merge_schedules(
        "bwd_unfused", "autodiff", d, (base,), extra_operands=tuple(extras),
        extra_flops=epilogue_flops(d, bias, act), epilogue=epilogue,
        epilogue_ops=epilogue_elementwise_ops(bias, act))


def epilogue_block_schedule(
    d: DWConvDims,
    itemsize: int = 4,
    *,
    epilogue: str = "bias+silu",
    fused: bool = True,
    fwd_variant: str = "row",
    bwd_variant: str = "fused",
    block_h: int = 8,
    block_t: int = 512,
    batch_chunk: int = 128,
) -> KernelSchedule:
    """Whole-block (forward + backward) schedule for one conv + epilogue:
    the quantity the ``paper_epilogue`` gate compares fused vs unfused."""
    fwd = schedule_for("fwd", fwd_variant, d, itemsize, epilogue=epilogue,
                       fused=fused, block_h=block_h, block_t=block_t)
    if fused:
        bwd = schedule_for("bwd_fused", bwd_variant, d, itemsize,
                           epilogue=epilogue, block_h=block_h,
                           block_t=block_t, batch_chunk=batch_chunk)
    else:
        bwd = unfused_epilogue_bwd_schedule(d, itemsize, epilogue=epilogue,
                                            block_h=block_h, block_t=block_t,
                                            batch_chunk=batch_chunk)
    return merge_schedules("block", "fused" if fused else "unfused", d,
                           (fwd, bwd), epilogue=epilogue)


# ---------------------------------------------------------------------------
# streaming-decode family (path "decode"): fused single-step ring-buffer conv
# at L=1 — the most extreme memory-bound regime in the repo (arithmetic
# intensity ~K flops per ring byte round-trip).  Channels ride the lane axis
# (the temporal axis degenerates at L=1), so ``block_t`` is reused as the
# channel-lane tile; honest per-step traffic is ring read+write, the x tap,
# and the weights — O(B*H*K) bytes vs O(B*H*L) for re-running the full conv
# over the cache.
# ---------------------------------------------------------------------------


def _decode_schedule(path, variant, d, itemsize, *, block_t, batch_chunk,
                     epilogue="none", **_):
    bias, act = parse_epilogue(epilogue)
    Km1 = d.K - 1
    flops = path_flops(d) + epilogue_flops(d, bias, act)  # L=1: ~2*B*H*K
    if variant == "xla":
        # Fused elementwise loop: every operand crosses HBM once, unpadded.
        ops = [
            OperandTraffic("ring", "read", d.B * d.H * Km1, itemsize),
            OperandTraffic("x", "read", d.B * d.H, itemsize),
            OperandTraffic("k", "read", d.H * d.K, itemsize),
            OperandTraffic("y", "write", d.B * d.H, itemsize),
            OperandTraffic("new_ring", "write", d.B * d.H * Km1, itemsize),
        ]
        if bias:
            ops.insert(3, OperandTraffic("bias", "read", d.H, itemsize))
        return KernelSchedule(path, variant, d, (), tuple(ops), flops,
                              epilogue=epilogue)
    Hl, nH, Hp, Bc, nB, Bp = decode_tiles(d, block_t, batch_chunk)
    legal, reason = True, "ok"
    if d.K < 2:
        legal, reason = False, (
            f"decode kernels need K >= 2 (K-1 >= 1 ring taps); K={d.K} has "
            f"an empty ring — the XLA reference runs instead")
    elif Hl % LANE != 0:
        legal, reason = False, (
            f"channel tile Hl={Hl} is not lane-aligned (Hl % {LANE} != 0)")
    if variant == "rows":
        grid = (("h", nH),)
        cells, Bb = nH, Bp
    elif variant == "chanblock":
        grid = (("b", nB), ("h", nH))
        cells, Bb = nB * nH, Bc
    else:
        raise ValueError(variant)
    # Elems charge the lane-padded channel extent Hp: the channel axis *is*
    # the lane axis here, so its padding physically crosses HBM (unlike the
    # fwd family, where channel padding rides the untiled sublane axis).
    ops = [
        OperandTraffic("ring", "read", d.B * Km1 * Hp, itemsize,
                       transactions=cells, block=(Bb, Km1, Hl),
                       note="carried ring state (oldest K-1 taps), channel-last"),
        OperandTraffic("x", "read", d.B * Hp, itemsize,
                       transactions=cells, block=(Bb, 1, Hl),
                       note="the new step's input row"),
        OperandTraffic("k", "read", d.K * Hp, itemsize,
                       transactions=cells, block=(d.K, Hl),
                       note="tap-major filter block, channels on lanes"),
        OperandTraffic("y", "write", d.B * Hp, itemsize,
                       transactions=cells, block=(Bb, 1, Hl)),
        OperandTraffic("new_ring", "write", d.B * Km1 * Hp, itemsize,
                       transactions=cells, block=(Bb, Km1, Hl),
                       note="shifted ring written back every step"),
    ]
    if bias:
        ops.insert(3, OperandTraffic("bias", "read", Hp, itemsize,
                                     transactions=cells, block=(1, Hl),
                                     note="per-channel bias row (channels on lanes)"))
    return KernelSchedule(path, variant, d, grid, tuple(ops), flops,
                          epilogue=epilogue, legal=legal, illegal_reason=reason)


for _v in ("rows", "chanblock", "xla"):
    register_schedule(("decode", _v))(_decode_schedule)


def decode_full_conv_schedule(d: DWConvDims, itemsize: int = 4, *,
                              variant: str = "xla",
                              epilogue: str = "none") -> KernelSchedule:
    """The serve-loop baseline the decode path replaces: re-running the full
    causal conv over the (B, H, L) cache to produce one new position.  Used
    by the decode benchmark/report to state the modeled O(B*H*L) vs
    O(B*H*K) margin."""
    return schedule_for("fwd", variant, d, itemsize, epilogue=epilogue)


# ---------------------------------------------------------------------------
# paper-mode family (P100 tables): §III-G cache-adjusted accounting — only
# the redundancy surviving L1/L2/shared memory is charged.  Variant names
# are the paper's.
# ---------------------------------------------------------------------------

PAPER_VARIANTS = ("naive", "gmc", "shared", "warp")
_WARP_SIZE = 32
_SHARED_TPB = 128  # paper §IV-D temporal tile


def _paper_fwd_schedule(path, variant, d, itemsize, **_):
    flops = path_flops(d)
    slab = d.B * d.H * d.L
    k = OperandTraffic("k", "read", d.H * d.K, itemsize)
    y = OperandTraffic("y", "write", slab, itemsize)
    if variant == "naive":
        # Realized traffic unobservable without counters: logical lower bound
        # as proxy, flagged unreliable (paper Table III "N/A").
        x = OperandTraffic("x", "read", slab, itemsize,
                           note="logical lower bound; realized value cache-dependent")
        return KernelSchedule(path, variant, d, (), (x, k, y), flops,
                              aligned=False, reliable=False)
    if variant == "gmc":
        # Warp-level reuse only: redundancy K / min(K, warp) survives caches.
        rho = d.K / min(d.K, _WARP_SIZE)
        x = OperandTraffic("x", "read", rho * slab, itemsize,
                           note=f"surviving redundancy rho={rho:.3f} (warp reuse only)")
    elif variant == "shared":
        rho = (_SHARED_TPB + d.K - 1) / _SHARED_TPB  # halo per TPB tile
        x = OperandTraffic("x", "read", rho * slab, itemsize,
                           note=f"halo per {_SHARED_TPB}-thread tile: rho={rho:.4f}")
    elif variant == "warp":
        # Full row staged once; halo is zero padding (no HBM reads).
        x = OperandTraffic("x", "read", slab, itemsize,
                           note="row staged once; halo is zero padding")
    else:
        raise ValueError(variant)
    return KernelSchedule(path, variant, d, (), (x, k, y), flops)


for _v in PAPER_VARIANTS:
    register_schedule(("paper_fwd", _v))(_paper_fwd_schedule)


def _paper_bwdk_schedule(path, variant, d, itemsize, **_):
    if variant not in PAPER_VARIANTS:
        raise ValueError(variant)
    flops = path_flops(d)
    slab = d.B * d.H * d.L
    x = OperandTraffic("x", "read", slab, itemsize)
    dy = OperandTraffic("dy", "read", slab, itemsize)
    dk = OperandTraffic("dk", "write", d.H * d.K, itemsize)
    if variant == "naive":
        # Sequential accumulation over B x L per (h, j): K x redundant logical
        # traffic, realized value cache-dependent -> unreliable proxy.
        return KernelSchedule(path, variant, d, (), (x, dy, dk), flops,
                              aligned=False, reliable=False)
    # gmc/shared/warp all restructure into chunked two-stage reductions:
    n_chunks = max(d.B // 128, 1)
    partials = n_chunks * d.H * d.K
    ops = (x, dy, dk,
           OperandTraffic("dk_partials", "write", partials, 4,
                          note="stage-1 f32 partials -> HBM"),
           OperandTraffic("dk_partials", "read", partials, 4,
                          note="stage-2 re-read of the partials"))
    return KernelSchedule(path, variant, d, (("chunk", n_chunks),), ops, flops)


for _v in PAPER_VARIANTS:
    register_schedule(("paper_bwd_k", _v))(_paper_bwdk_schedule)
