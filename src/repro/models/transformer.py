"""Dense decoder-only LM family: llama3-8b, qwen2-0.5b, smollm-135m,
gemma3-27b (5:1 local:global interleave via per-layer scanned window/theta).

Scan-over-layers with stacked parameters keeps the HLO compact for the
62-layer dry-run cells; heterogeneous local/global layers share one scan
body because the window size and RoPE theta are *traced per-layer scalars*
feeding the mask arithmetic, not Python control flow.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.train.losses import softmax_cross_entropy


def attn_dims(cfg: ArchConfig) -> L.AttnDims:
    return L.AttnDims(
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv,
        head_dim=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm,
    )


def layer_schedule(cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-layer (window, rope_theta) arrays — the local:global interleave."""
    windows, thetas = [], []
    for i in range(cfg.n_layers):
        if cfg.local_global_pattern > 0:
            is_global = (i + 1) % (cfg.local_global_pattern + 1) == 0
        else:
            is_global = cfg.window == 0
        if is_global:
            windows.append(0)  # full attention
            thetas.append(cfg.rope_theta_global or cfg.rope_theta)
        else:
            windows.append(cfg.window or 1024)
            thetas.append(cfg.rope_theta)
    return jnp.asarray(windows, jnp.int32), jnp.asarray(thetas, jnp.float32)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(rng: jax.Array, cfg: ArchConfig) -> Dict[str, Any]:
    k1, k2 = jax.random.split(rng)
    p = {
        "attn": L.init_attention(k1, cfg.d_model, attn_dims(cfg)),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=True),
        "ln1": jnp.zeros((cfg.d_model,)),
        "ln2": jnp.zeros((cfg.d_model,)),
    }
    return p


def init(rng: jax.Array, cfg: ArchConfig) -> Dict[str, Any]:
    k_embed, k_layers, k_out = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda r: _init_layer(r, cfg))(layer_keys)
    params = {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model),
        "layers": stacked,
        "ln_f": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.embed_init(k_out, cfg.vocab, cfg.d_model)
    return jax.tree.map(lambda x: x.astype(cfg.param_dt), params)


def param_axes(cfg: ArchConfig) -> Dict[str, Any]:
    lp = {
        "attn": {k: ("layers",) + v for k, v in L.attention_param_axes(attn_dims(cfg)).items()},
        "mlp": {k: ("layers",) + v for k, v in L.mlp_param_axes(gated=True).items()},
        "ln1": ("layers", "embed"),
        "ln2": ("layers", "embed"),
    }
    axes = {"embed": ("vocab", "embed"), "layers": lp, "ln_f": ("embed",)}
    if not cfg.tie_embeddings:
        axes["unembed"] = ("vocab", "embed")
    return axes


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _layer_body(cfg: ArchConfig, x, lp, window, theta, positions, use_chunked):
    h = L.rms_norm(x, lp["ln1"])
    a, _ = L.attention(
        lp["attn"], h, attn_dims(cfg),
        positions=positions, rope_theta=theta, window=window, use_chunked=use_chunked,
    )
    x = x + a
    h = L.rms_norm(x, lp["ln2"])
    x = x + L.mlp(lp["mlp"], h, cfg.act)
    return shard(x, "act_batch", "act_seq", "act_embed")


def forward(params, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (B, S) -> final hidden states (B, S, D)."""
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    windows, thetas = layer_schedule(cfg)
    use_chunked = S >= cfg.attn_chunk_threshold

    def body(x, inp):
        lp, w, th = inp
        return _layer_body(cfg, x, lp, w, th, positions, use_chunked), ()

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["layers"], windows, thetas))
    return L.rms_norm(x, params["ln_f"])


def logits_fn(params, cfg: ArchConfig, hidden: jnp.ndarray) -> jnp.ndarray:
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(hidden, table)


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    hidden = forward(params, cfg, batch["tokens"])
    logits = logits_fn(params, cfg, hidden)
    return softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def _lg_structure(cfg: ArchConfig):
    """(n_superblocks, block_len, n_tail) for a local:global interleave.
    gemma3-27b: 62 = 10 x (5 local + 1 global) + 2 local tail."""
    per = cfg.local_global_pattern + 1
    return cfg.n_layers // per, per, cfg.n_layers % per


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None) -> Dict[str, Any]:
    dtype = dtype or cfg.compute_dt
    if cfg.local_global_pattern > 0:
        # Window-capped caches: local layers only ever attend within the
        # sliding window, so their cache is a ring of `window` slots —
        # 52/62 gemma3 layers drop from seq_len to 1024 slots.
        nb, per, tail = _lg_structure(cfg)
        W = min(cfg.window or 1024, cache_len)
        kvshape = lambda n, s: (n, batch, s, cfg.n_kv, cfg.head_dim)
        cache = {
            "local_k": jnp.zeros((nb, per - 1) + kvshape(1, W)[1:], dtype),
            "local_v": jnp.zeros((nb, per - 1) + kvshape(1, W)[1:], dtype),
            "global_k": jnp.zeros(kvshape(nb, cache_len), dtype),
            "global_v": jnp.zeros(kvshape(nb, cache_len), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
        if tail:
            cache["tail_k"] = jnp.zeros(kvshape(tail, W), dtype)
            cache["tail_v"] = jnp.zeros(kvshape(tail, W), dtype)
        return cache
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg: ArchConfig) -> Dict[str, Any]:
    kv = ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None)
    if cfg.local_global_pattern > 0:
        lkv = ("layers", None, "cache_batch", None, "cache_kv_heads", None)
        axes = {"local_k": lkv, "local_v": lkv,
                "global_k": kv, "global_v": kv, "pos": ()}
        if _lg_structure(cfg)[2]:
            tkv = ("layers", "cache_batch", None, "cache_kv_heads", None)
            axes["tail_k"] = tkv
            axes["tail_v"] = tkv
        return axes
    return {"k": kv, "v": kv, "pos": ()}


def _decode_layer_ring(cfg, lp, x, ck, cv, pos, theta, window):
    """Windowed decode with a ring-buffer cache (slot = pos % W)."""
    dims = attn_dims(cfg)
    B = x.shape[0]
    h = L.rms_norm(x, lp["ln1"])
    q, k, v = L._project_qkv(lp["attn"], h, h, dims)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q = L.rope(q, positions, theta)
    k = L.rope(k, positions, theta)
    W = ck.shape[1]
    slot = pos % W
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
    s = jnp.arange(W, dtype=jnp.int32)
    kv_pos = pos - ((pos - s) % W)
    valid = (kv_pos >= 0) & (kv_pos <= pos) & (pos - kv_pos < window)
    bias = jnp.where(valid, 0.0, -1e30)[None, :]
    out = L._sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), bias, dims)
    y = jnp.einsum("bsf,fd->bsd", out.reshape(B, 1, -1),
                   lp["attn"]["wo"].astype(x.dtype))
    x = x + y
    x = x + L.mlp(lp["mlp"], L.rms_norm(x, lp["ln2"]), cfg.act)
    return x, ck, cv


def _decode_layer_full(cfg, lp, x, ck, cv, pos, theta, window):
    """Full-length decode against a sequence-sharded cache."""
    B = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    h = L.rms_norm(x, lp["ln1"])
    a, nc = L.attention(
        lp["attn"], h, attn_dims(cfg), positions=positions, rope_theta=theta,
        window=window, cache={"k": ck, "v": cv}, cache_pos=pos,
    )
    x = x + a
    x = x + L.mlp(lp["mlp"], L.rms_norm(x, lp["ln2"]), cfg.act)
    return x, nc["k"], nc["v"]


def _decode_step_lg(params, cfg: ArchConfig, cache, tokens: jnp.ndarray):
    """Decode for local:global interleaves with window-capped local caches."""
    B, S = tokens.shape
    assert S == 1
    nb, per, tail = _lg_structure(cfg)
    n_local = per - 1
    pos = cache["pos"]
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    local_theta = cfg.rope_theta
    global_theta = cfg.rope_theta_global or cfg.rope_theta
    W = cfg.window or 1024

    main = jax.tree.map(lambda t: t[: nb * per].reshape(nb, per, *t.shape[1:]),
                        params["layers"])

    def inner(x, lin):
        lpp, ck, cv = lin
        x, nk, nv = _decode_layer_ring(cfg, lpp, x, ck, cv, pos, local_theta, W)
        return x, (nk, nv)

    def body(x, inp):
        sbp, lk, lv, gk, gv = inp
        local_p = jax.tree.map(lambda t: t[:n_local], sbp)
        x, (nlk, nlv) = jax.lax.scan(inner, x, (local_p, lk, lv))
        global_p = jax.tree.map(lambda t: t[n_local], sbp)
        x, ngk, ngv = _decode_layer_full(cfg, global_p, x, gk, gv, pos,
                                         global_theta, 0)
        return x, (nlk, nlv, ngk, ngv)

    x, (nlk, nlv, ngk, ngv) = jax.lax.scan(
        body, x,
        (main, cache["local_k"], cache["local_v"],
         cache["global_k"], cache["global_v"]))
    new_cache = {"local_k": nlk, "local_v": nlv, "global_k": ngk,
                 "global_v": ngv, "pos": pos + 1}
    if tail:
        ntk, ntv = [], []
        for i in range(tail):
            lpp = jax.tree.map(lambda t: t[nb * per + i], params["layers"])
            x, nk, nv = _decode_layer_ring(
                cfg, lpp, x, cache["tail_k"][i], cache["tail_v"][i], pos,
                local_theta, W)
            ntk.append(nk)
            ntv.append(nv)
        new_cache["tail_k"] = jnp.stack(ntk)
        new_cache["tail_v"] = jnp.stack(ntv)
    hidden = L.rms_norm(x, params["ln_f"])
    logits = logits_fn(params, cfg, hidden)
    return logits, new_cache


def decode_step(params, cfg: ArchConfig, cache, tokens: jnp.ndarray):
    """One decode step.  tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    if cfg.local_global_pattern > 0:
        return _decode_step_lg(params, cfg, cache, tokens)
    B, S = tokens.shape
    pos = cache["pos"]
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.broadcast_to(pos[None, None] + jnp.arange(S, dtype=jnp.int32), (B, S)) \
        if pos.ndim == 0 else pos
    windows, thetas = layer_schedule(cfg)

    def body(x, inp):
        lp, w, th, ck, cv = inp
        h = L.rms_norm(x, lp["ln1"])
        a, new_c = L.attention(
            lp["attn"], h, attn_dims(cfg),
            positions=positions, rope_theta=th, window=w,
            cache={"k": ck, "v": cv}, cache_pos=cache["pos"],
        )
        x = x + a
        h = L.rms_norm(x, lp["ln2"])
        x = x + L.mlp(lp["mlp"], h, cfg.act)
        return x, (new_c["k"], new_c["v"])

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], windows, thetas, cache["k"], cache["v"]))
    hidden = L.rms_norm(x, params["ln_f"])
    logits = logits_fn(params, cfg, hidden)
    new_cache = {"k": nk, "v": nv, "pos": cache["pos"] + S}
    return logits, new_cache


def _ring_gather_idx(S: int, W: int):
    """Static gather indices mapping ring slot s -> the position in the last
    W tokens whose ring slot is s (slot = pos % W)."""
    import numpy as np

    s = np.arange(W)
    return (S - W) + ((s - (S % W)) % W)


def _prefill_lg(params, cfg: ArchConfig, tokens: jnp.ndarray):
    """Prefill for local:global interleaves; local caches capped at the
    window (ring layout matching _decode_layer_ring)."""
    B, S = tokens.shape
    nb, per, tail = _lg_structure(cfg)
    n_local = per - 1
    W = cfg.window or 1024
    assert S >= W, (S, W)
    ring_idx = jnp.asarray(_ring_gather_idx(S, W))
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    use_chunked = S >= cfg.attn_chunk_threshold
    dims = attn_dims(cfg)
    local_theta = cfg.rope_theta
    global_theta = cfg.rope_theta_global or cfg.rope_theta

    def layer(x, lp, w, th):
        h = L.rms_norm(x, lp["ln1"])
        a, (k, v) = L.attention(lp["attn"], h, dims, positions=positions,
                                rope_theta=th, window=w, use_chunked=use_chunked,
                                return_kv=True)
        x = x + a
        x = x + L.mlp(lp["mlp"], L.rms_norm(x, lp["ln2"]), cfg.act)
        return shard(x, "act_batch", "act_seq", "act_embed"), k, v

    def inner(x, lpp):
        x, k, v = layer(x, lpp, W, local_theta)
        # keep only the live window, in ring layout
        rk = jnp.take(k, ring_idx, axis=1).astype(cfg.compute_dt)
        rv = jnp.take(v, ring_idx, axis=1).astype(cfg.compute_dt)
        return x, (rk, rv)

    def body(x, sbp):
        local_p = jax.tree.map(lambda t: t[:n_local], sbp)
        x, (lk, lv) = jax.lax.scan(inner, x, local_p)
        global_p = jax.tree.map(lambda t: t[n_local], sbp)
        x, gk, gv = layer(x, global_p, 0, global_theta)
        return x, (lk, lv, gk.astype(cfg.compute_dt), gv.astype(cfg.compute_dt))

    main = jax.tree.map(lambda t: t[: nb * per].reshape(nb, per, *t.shape[1:]),
                        params["layers"])
    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, (lk, lv, gk, gv) = jax.lax.scan(body_fn, x, main)
    cache = {"local_k": lk, "local_v": lv, "global_k": gk, "global_v": gv,
             "pos": jnp.asarray(S, jnp.int32)}
    if tail:
        tks, tvs = [], []
        for i in range(tail):
            lpp = jax.tree.map(lambda t: t[nb * per + i], params["layers"])
            x, (rk, rv) = inner(x, lpp)
            tks.append(rk)
            tvs.append(rv)
        cache["tail_k"] = jnp.stack(tks)
        cache["tail_v"] = jnp.stack(tvs)
    hidden = L.rms_norm(x, params["ln_f"])
    logits = logits_fn(params, cfg, hidden[:, -1:, :])
    return logits, cache


def prefill(params, cfg: ArchConfig, tokens: jnp.ndarray):
    """Full-sequence prefill: returns (last-token logits, filled cache)."""
    if cfg.local_global_pattern > 0:
        return _prefill_lg(params, cfg, tokens)
    B, S = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg.compute_dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    windows, thetas = layer_schedule(cfg)
    use_chunked = S >= cfg.attn_chunk_threshold
    dims = attn_dims(cfg)

    def body(x, inp):
        lp, w, th = inp
        h = L.rms_norm(x, lp["ln1"])
        a, (k, v) = L.attention(
            lp["attn"], h, dims,
            positions=positions, rope_theta=th, window=w, use_chunked=use_chunked,
            return_kv=True,
        )
        x = x + a
        h2 = L.rms_norm(x, lp["ln2"])
        x = x + L.mlp(lp["mlp"], h2, cfg.act)
        x = shard(x, "act_batch", "act_seq", "act_embed")
        return x, (k.astype(cfg.compute_dt), v.astype(cfg.compute_dt))

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows, thetas))
    hidden = L.rms_norm(x, params["ln_f"])
    logits = logits_fn(params, cfg, hidden[:, -1:, :])
    cache = {"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def n_params(cfg: ArchConfig) -> int:
    attn = cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * cfg.d_model
    mlp_p = 3 * cfg.d_model * cfg.d_ff
    per_layer = attn + mlp_p + 2 * cfg.d_model
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * per_layer + emb + cfg.d_model


def n_active_params(cfg: ArchConfig) -> int:
    return n_params(cfg)
