"""Counter-free kernel autotuner (paper §III-F methodology as a tuner).

The paper's central result — a 3.26x kernel speedup from re-mapping the same
operator — is a *per-shape* selection problem: which implementation variant
and which tile shape win depends on (B, H, L, K, dtype, backend).  This
package turns the reproduction's fixed choices into a shape-general
optimization engine with four layers:

  space.py : declarative search space over (variant, block_h, block_t,
             batch_chunk) per execution path, with the legality constraints
             of the Pallas kernels lifted into predicates.
  cost.py  : two-stage cost model — analytical traffic/roofline pre-ranking
             (``analysis/traffic.py`` + ``analysis/hw.py``) followed by
             counter-free steady-state measurement of the top survivors
             (``analysis/timer.time_fn``, the paper's CUDA-event analogue).
  cache.py : persistent JSON tuning database keyed by
             (path, B, H, L, K, padding, dtype, backend), versioned,
             memoized in-process, overridable via ``REPRO_TUNE_CACHE``.
  tuner.py : grid and greedy-hillclimb search drivers; writes winners into
             the cache that ``kernels/ops.py`` consults for
             ``variant="auto"`` dispatch.

CLI: ``python -m repro.launch.tune --shapes paper --budget 50``.
"""
from repro.tuning.cache import (  # noqa: F401
    CACHE_ENV_VAR,
    CACHE_VERSION,
    ShapeKey,
    TuneEntry,
    TuningCache,
    default_cache,
    lookup,
    reset_default_cache,
)
from repro.tuning.cost import analytical_time_s, measure_candidate, rank_candidates  # noqa: F401
from repro.tuning.space import (  # noqa: F401
    PATHS,
    Candidate,
    is_legal,
    search_space,
)
from repro.tuning.tuner import TuneResult, tune_path, tune_shape  # noqa: F401
