"""Hardware calibration: fit *achievable* roofs from microbenchmarks.

``analysis/hw.py`` carries datasheet peaks.  The counter-free methodology's
headline quantity — effective bandwidth = modeled bytes / measured time —
is only credible against a roof this runner can actually reach ("Fast
convolution kernels on Pascal GPU with high memory efficiency", arXiv
2212.00404, makes the same move: achievable copy bandwidth, not the spec
sheet, is the denominator).  This module measures three floors:

  * **HBM sweep** — jitted copy and triad kernels across a size ladder;
    each point is ``(bytes_moved, median seconds)``.  A least-squares fit of
    ``time = overhead + bytes / bandwidth`` recovers the *asymptotic
    achievable bandwidth* (the slope) and the per-launch overhead (the
    intercept) — noise-aware, because one descheduled iteration moves a
    point, not the slope.
  * **MXU/VPU matmul sweep** — f32 ``n x n`` matmuls; the same linear fit
    in FLOPs recovers achievable FLOP/s.
  * **dispatch floor** — a jitted identity on a scalar: the fixed cost of
    one device round-trip, charged by the calibrated analytical model as a
    per-call constant.

The result is a :class:`CalibratedHardware` overlay keyed by a device
fingerprint and persisted as JSON (``results/calibration/`` by default, or
``$REPRO_CALIBRATION``).  ``CalibratedHardware.hardware_model()`` projects
it back onto :class:`~repro.analysis.hw.HardwareModel`, so every existing
derivation (`analytical_time_s`, `roofline_point`) runs unchanged against
calibrated roofs.
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import re
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.hw import HARDWARE, TPU_V5E, HardwareModel

CALIBRATION_ENV = "REPRO_CALIBRATION"
DEFAULT_CALIBRATION_DIR = os.path.join("results", "calibration")

# size ladders (bytes of the swept operand / matmul edge length)
BW_SIZES_FULL = (1 << 20, 4 << 20, 16 << 20, 64 << 20)
BW_SIZES_FAST = (1 << 18, 1 << 20, 4 << 20)
MM_SIZES_FULL = (128, 256, 512, 1024)
MM_SIZES_FAST = (64, 128, 256)


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One microbenchmark point: ``work`` units (bytes or FLOPs) done in
    ``time_s`` median seconds."""
    work: float
    time_s: float


@dataclasses.dataclass(frozen=True)
class LinearFit:
    """``time = overhead_s + work / rate`` least-squares fit."""
    rate: float          # bytes/s or FLOP/s (1 / slope)
    overhead_s: float    # fixed per-launch cost (intercept, clamped >= 0)
    r2: float

    def time_s(self, work: float) -> float:
        return self.overhead_s + work / self.rate


def fit_linear_time(points: Sequence[SweepPoint]) -> LinearFit:
    """Fit ``time = a + work/rate`` by least squares over the sweep.

    Falls back to the best single-point rate (overhead 0) when the sweep is
    degenerate — fewer than two distinct sizes, or a non-positive slope
    (pure noise): the calibration must never report a negative or infinite
    roof.
    """
    import numpy as np

    if not points:
        raise ValueError("fit_linear_time needs at least one sweep point")
    w = np.asarray([p.work for p in points], dtype=np.float64)
    t = np.asarray([p.time_s for p in points], dtype=np.float64)
    best_rate = float(np.max(w / np.maximum(t, 1e-12)))
    if len(set(w.tolist())) < 2:
        return LinearFit(rate=best_rate, overhead_s=0.0, r2=0.0)
    slope, intercept = np.polyfit(w, t, 1)
    if slope <= 0:
        return LinearFit(rate=best_rate, overhead_s=0.0, r2=0.0)
    pred = intercept + slope * w
    ss_res = float(np.sum((t - pred) ** 2))
    ss_tot = float(np.sum((t - np.mean(t)) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    return LinearFit(rate=float(1.0 / slope),
                     overhead_s=float(max(intercept, 0.0)), r2=r2)


# ---------------------------------------------------------------------------
# microbenchmarks
# ---------------------------------------------------------------------------

def device_fingerprint() -> str:
    """Stable identity of the runner this calibration describes."""
    import jax

    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "unknown") or "unknown"
    return f"{jax.default_backend()}:{kind}:x{jax.device_count()}"


def _timer(fn, *args, iters: int, warmup: int) -> float:
    from repro.analysis.timer import time_fn

    return time_fn(fn, *args, warmup=warmup, iters=iters).median_s


def measure_bandwidth_sweep(sizes_bytes: Sequence[int], *, op: str = "triad",
                            iters: int = 5, warmup: int = 2) -> List[SweepPoint]:
    """Copy (2 crossings/element) or triad (3 crossings/element) ladder."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    if op == "copy":
        fn = jax.jit(lambda x: x * np.float32(1.0000001))
        n_arrays, crossings = 1, 2
    elif op == "triad":
        fn = jax.jit(lambda b, c: b + np.float32(0.5) * c)
        n_arrays, crossings = 2, 3
    else:
        raise ValueError(f"unknown bandwidth op {op!r}; use 'copy' or 'triad'")
    points = []
    for nbytes in sizes_bytes:
        n = max(int(nbytes) // 4, 128)
        args = tuple(jnp.asarray(np.random.default_rng(i).standard_normal(n),
                                 jnp.float32) for i in range(n_arrays))
        t = _timer(fn, *args, iters=iters, warmup=warmup)
        points.append(SweepPoint(work=float(crossings * n * 4), time_s=t))
    return points


def measure_matmul_sweep(sizes: Sequence[int], *, iters: int = 5,
                         warmup: int = 2) -> List[SweepPoint]:
    """f32 ``n x n`` matmul ladder; work is 2·n³ FLOPs."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    fn = jax.jit(lambda a, b: a @ b)
    points = []
    for n in sizes:
        rng = np.random.default_rng(n)
        a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        t = _timer(fn, a, b, iters=iters, warmup=warmup)
        points.append(SweepPoint(work=float(2 * n ** 3), time_s=t))
    return points


def measure_dispatch_floor(*, iters: int = 30, warmup: int = 5) -> float:
    """Median seconds for one jitted no-op round-trip: the floor under
    every per-call time this runner can report."""
    import jax
    import jax.numpy as jnp

    fn = jax.jit(lambda x: x + 1)
    return _timer(fn, jnp.float32(0.0), iters=iters, warmup=warmup)


# ---------------------------------------------------------------------------
# the calibrated overlay
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CalibratedHardware:
    """Measured achievable roofs overlaying one ``hw.py`` base model."""

    base: str                    # HardwareModel name the overlay applies to
    fingerprint: str             # device identity the numbers describe
    hbm_bw: float                # achievable bytes/s (triad fit slope)
    copy_bw: float               # achievable bytes/s (copy fit slope)
    flops_f32: float             # achievable f32 FLOP/s (matmul fit slope)
    dispatch_overhead_s: float   # jitted no-op round-trip floor
    bw_overhead_s: float         # per-launch overhead from the triad fit
    bw_r2: float
    flops_r2: float
    created: str = ""
    sweeps: Dict[str, List[Tuple[float, float]]] = dataclasses.field(
        default_factory=dict)   # raw (work, time_s) points per microbenchmark

    def hardware_model(self, base: Optional[HardwareModel] = None) -> HardwareModel:
        """The base model with its roofs replaced by the measured ones —
        a drop-in for every ``perfmodel.derive`` entry point."""
        hw = base if base is not None else HARDWARE[self.base]
        return dataclasses.replace(
            hw, name=f"{hw.name}+calibrated", hbm_bw=self.hbm_bw,
            peak_flops_f32=self.flops_f32,
            peak_flops=min(hw.peak_flops, self.flops_f32 * (
                hw.peak_flops / max(hw.peak_flops_f32, 1.0))))

    def analytical_time_s(self, schedule, base: Optional[HardwareModel] = None) -> float:
        """Calibrated roofline bound + the measured dispatch floor."""
        from repro import perfmodel

        est = perfmodel.derive_traffic(schedule)
        hw = self.hardware_model(base)
        return max(est.flops / hw.peak_flops_f32,
                   est.bytes_moved / hw.hbm_bw) + self.dispatch_overhead_s

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, obj: Dict) -> "CalibratedHardware":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in obj.items() if k in fields}
        kw["sweeps"] = {k: [tuple(p) for p in v]
                        for k, v in (kw.get("sweeps") or {}).items()}
        return cls(**kw)


def run_calibration(*, base: HardwareModel = TPU_V5E, fast: bool = False,
                    iters: Optional[int] = None,
                    bw_sizes: Optional[Sequence[int]] = None,
                    mm_sizes: Optional[Sequence[int]] = None) -> CalibratedHardware:
    """Run the full microbenchmark suite and fit the overlay."""
    iters = iters if iters is not None else (3 if fast else 7)
    bw_sizes = tuple(bw_sizes if bw_sizes is not None
                     else (BW_SIZES_FAST if fast else BW_SIZES_FULL))
    mm_sizes = tuple(mm_sizes if mm_sizes is not None
                     else (MM_SIZES_FAST if fast else MM_SIZES_FULL))
    triad = measure_bandwidth_sweep(bw_sizes, op="triad", iters=iters)
    copy = measure_bandwidth_sweep(bw_sizes, op="copy", iters=iters)
    mm = measure_matmul_sweep(mm_sizes, iters=iters)
    triad_fit = fit_linear_time(triad)
    copy_fit = fit_linear_time(copy)
    mm_fit = fit_linear_time(mm)
    return CalibratedHardware(
        base=base.name,
        fingerprint=device_fingerprint(),
        hbm_bw=triad_fit.rate,
        copy_bw=copy_fit.rate,
        flops_f32=mm_fit.rate,
        dispatch_overhead_s=measure_dispatch_floor(),
        bw_overhead_s=triad_fit.overhead_s,
        bw_r2=triad_fit.r2,
        flops_r2=mm_fit.r2,
        created=datetime.datetime.now(datetime.timezone.utc).isoformat(),
        sweeps={
            "triad": [(p.work, p.time_s) for p in triad],
            "copy": [(p.work, p.time_s) for p in copy],
            "matmul": [(p.work, p.time_s) for p in mm],
        },
    )


# ---------------------------------------------------------------------------
# persistence (JSON keyed by device fingerprint)
# ---------------------------------------------------------------------------

def default_calibration_path(fingerprint: Optional[str] = None) -> str:
    env = os.environ.get(CALIBRATION_ENV)
    if env:
        return env
    fp = fingerprint if fingerprint is not None else device_fingerprint()
    safe = re.sub(r"[^A-Za-z0-9_.-]+", "-", fp)
    return os.path.join(DEFAULT_CALIBRATION_DIR, f"{safe}.json")


def save_calibration(cal: CalibratedHardware, path: Optional[str] = None) -> str:
    path = path or default_calibration_path(cal.fingerprint)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(cal.to_dict(), f, indent=1)
    return path


def load_calibration(path: str) -> CalibratedHardware:
    with open(path) as f:
        return CalibratedHardware.from_dict(json.load(f))


# fingerprint pairs already warned about: the mismatch is per-file identity,
# not per-call, and a consumer probing the calibration every report must not
# spam stderr.
_MISMATCH_WARNED: set = set()


def load_for_device(path: Optional[str] = None) -> Optional[CalibratedHardware]:
    """The persisted calibration for *this* runner, or ``None`` when missing
    or recorded on different hardware (a stale file must not lend its roofs
    to a machine it never measured).  A fingerprint mismatch warns once,
    naming both identities — a replica migrated to new hardware should know
    *why* its calibrated roofs vanished, not silently fall back to
    datasheet peaks."""
    path = path or default_calibration_path()
    if not os.path.exists(path):
        return None
    try:
        cal = load_calibration(path)
    except (json.JSONDecodeError, TypeError, KeyError, ValueError):
        return None
    current = device_fingerprint()
    if cal.fingerprint != current:
        pair = (path, cal.fingerprint, current)
        if pair not in _MISMATCH_WARNED:
            _MISMATCH_WARNED.add(pair)
            print(f"[obs.calibrate] calibration {path} was measured on "
                  f"{cal.fingerprint!r} but this runner is {current!r}; "
                  f"ignoring it (datasheet roofs apply) — re-run "
                  f"`python -m repro.obs.calibrate` on this hardware",
                  file=sys.stderr, flush=True)
        return None
    return cal
