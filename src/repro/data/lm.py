"""Synthetic LM token pipeline (sharded, stateful, checkpointable).

Generates deterministic pseudo-text: a per-shard Markov-ish process with
Zipfian unigram marginals and short-range structure, so cross-entropy
meaningfully decreases during smoke training.  The iterator state (epoch,
step) is checkpointable like the GEPIII iterator, and ``shard_index /
shard_count`` slice the stream for multi-host data parallelism.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class LMStreamConfig:
    vocab: int
    batch_size: int
    seq_len: int
    seed: int = 0
    shard_index: int = 0
    shard_count: int = 1


class LMTokenStream:
    def __init__(self, cfg: LMStreamConfig):
        self.cfg = cfg
        self.step = 0
        # Zipfian unigram table (shared across shards for stationarity)
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._shift = rng.integers(1, max(cfg.vocab - 1, 2))

    # checkpointable state ---------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step}

    def load_state_dict(self, d: Dict) -> None:
        self.step = int(d["step"])

    # iteration ----------------------------------------------------------------
    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, c.shard_index, self.step))  # deterministic per (shard, step)
        base = rng.choice(c.vocab, size=(c.batch_size, c.seq_len + 1), p=self._probs)
        # inject predictable structure: every other token repeats shifted
        base[:, 1::2] = (base[:, 0:-1:2] + self._shift) % c.vocab
        self.step += 1
        return {
            "tokens": base[:, :-1].astype(np.int32),
            "labels": base[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
