"""Serving launcher: batched greedy decoding with a sharded KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \\
      --batch 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, list_archs
from repro.distributed import sharding as shd
from repro.distributed.stepfn import build_serve_step
from repro.launch.mesh import make_mesh
from repro.models.api import get_model, make_demo_batch


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
    else:
        mesh = make_mesh((1, jax.device_count()), ("data", "model"))

    with mesh, shd.use_sharding(mesh, "serve"):
        params = model.init(jax.random.PRNGKey(args.seed))
        batch = make_demo_batch(cfg, args.batch, args.prompt_len)
        cache = model.init_cache(args.batch, args.cache_len)
        # enc-dec / vlm: precompute cross caches from the stub modality input
        if cfg.family == "encdec":
            from repro.models import encdec
            enc = encdec.encode(params, cfg, jnp.asarray(
                np.random.default_rng(0).normal(
                    size=(args.batch, cfg.encdec.enc_frames, cfg.d_model)), jnp.float32))
            ck, cv = encdec.precompute_cross_cache(params, cfg, enc)
            cache["cross_k"], cache["cross_v"] = ck, cv
        if cfg.family == "vlm":
            from repro.models import vlm
            ik, iv = vlm.precompute_img_cache(params, cfg, batch["img"])
            cache["img_k"], cache["img_v"] = ik, iv

        serve_step = jax.jit(build_serve_step(model), donate_argnums=(1,))
        # prefill by teacher-forcing the prompt token by token (robust across
        # families); production prefill path is exercised by the dry-run.
        tok = batch["tokens"][:, :1]
        t0 = time.time()
        generated = []
        for i in range(args.prompt_len - 1):
            _, cache = serve_step(params, cache, {"tokens": batch["tokens"][:, i : i + 1]})
        for _ in range(args.gen):
            nxt, cache = serve_step(params, cache, {"tokens": tok})
            tok = nxt[:, None]
            generated.append(np.asarray(tok))
        jax.block_until_ready(tok)
        dt = time.time() - t0
    gen = np.concatenate(generated, axis=1)
    print(f"[serve] arch={cfg.name} batch={args.batch} generated {gen.shape[1]} tokens "
          f"in {dt:.2f}s ({args.batch * gen.shape[1] / dt:.1f} tok/s)")
    print("[serve] sample token ids:", gen[0].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
