"""recurrentgemma-2b [hybrid]: 26L (8 x (rec,rec,attn) + 2 rec tail),
d=2560, 10H MQA (kv=1, head_dim=256), ff=7680 GeGLU, RG-LRU width 2560,
local attention window 2048, vocab=256000.  [arXiv:2402.19427]

The temporal conv1d in each recurrent block routes through the paper's
kernel (``rglru.conv_variant``)."""
import dataclasses

from repro.models.config import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    rope_theta=10_000.0,
    tie_embeddings=True,
    act="gelu",
    rglru=RGLRUConfig(lru_width=2560, d_conv=4, attn_window=2048,
                      conv_variant="xla"),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv=1, d_ff=128, vocab=256,
    head_dim=16,
    rglru=RGLRUConfig(lru_width=64, d_conv=4, attn_window=16, conv_variant="xla"),
    compute_dtype="float32",
)
