"""Signed, content-addressed tuning-cache bundles (the fleet export side).

A *bundle* is one JSON file (``<id>.bundle.json``) that carries a
:class:`~repro.tuning.cache.TuningCache`'s entries across the fleet
boundary::

    {
      "format": "repro-tuning-bundle",
      "bundle_version": 1,
      "cache_version": 6,                  # the tuning-cache schema exported
      "manifest": {
        "content_id":  sha256(canonical {cache_version, entries}),
        "fingerprint": device that measured the entries (obs.calibrate),
        "git_sha":     revision the decisions describe,
        "created":     ISO-8601 UTC,
        "entry_count": N,
        "source_cache": exporting cache path (diagnostic only)
      },
      "entries":   { shape-key: TuneEntry dict },   # incl. time_us,
      "signature": HMAC-SHA256 over the canonical    # quarantine fields
                   JSON of everything above, keyed by REPRO_FLEET_KEY
    }

Design points:

  * **canonical JSON** — signing and content addressing both hash
    ``json.dumps(..., sort_keys=True, separators=(",", ":"))``, so the
    signature is stable under re-serialization but breaks under *any*
    entry/manifest mutation (a flipped byte cannot re-use the signature);
  * **content-addressed** — ``content_id`` names the decision set itself;
    exporting the same entries twice yields the same id, and the default
    filename is ``<content_id[:16]>.bundle.json``;
  * **quarantine never crosses the fleet boundary** — quarantined entries
    (schema v6: a decision that failed to execute) are dropped at export
    with a warning, or the export is refused outright under ``strict=True``
    (the programmatic twin of ``repro.resilience.report
    --fail-on-quarantine``);
  * **hostile-input reads** — :func:`read_bundle` maps every defect
    (unreadable file, wrong format, bad signature, content-id mismatch,
    unmigratable schema) onto
    :class:`~repro.resilience.faults.BundleIntegrityError` so the import
    chain can degrade to "tune fresh" instead of crashing a replica.

This module and ``tuning/cache.py`` are the *only* places allowed to read
or write bundle/cache JSON directly (lint rule REP006).
"""
from __future__ import annotations

import datetime
import hashlib
import hmac
import json
import os
import sys
from pathlib import Path
from typing import Dict, Optional

from repro.resilience import faults
from repro.resilience.faults import BundleIntegrityError
from repro.tuning.cache import (
    CACHE_VERSION,
    MIGRATABLE_VERSIONS,
    TuneEntry,
    TuningCache,
)

FLEET_KEY_ENV = "REPRO_FLEET_KEY"
BUNDLE_FORMAT = "repro-tuning-bundle"
BUNDLE_VERSION = 1
BUNDLE_SUFFIX = ".bundle.json"


def _warn(msg: str) -> None:
    print(f"[fleet.bundle] {msg}", file=sys.stderr, flush=True)


def resolve_key(key: Optional[str] = None) -> str:
    """Explicit key argument > ``REPRO_FLEET_KEY`` env.  No key is an
    integrity failure: an unsigned bundle can neither be produced nor
    trusted, so both sides fail the same way."""
    if key:
        return key
    env = os.environ.get(FLEET_KEY_ENV, "").strip()
    if env:
        return env
    raise BundleIntegrityError(
        f"no fleet signing key: set {FLEET_KEY_ENV} (or pass key=) — bundles "
        f"are only exchanged signed")


def canonical_bytes(obj) -> bytes:
    """The byte string signing and content addressing agree on."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


def content_id(cache_version: int, entries: Dict[str, Dict]) -> str:
    """sha256 naming the decision set (schema + entries, nothing else)."""
    return hashlib.sha256(
        canonical_bytes({"cache_version": cache_version,
                         "entries": entries})).hexdigest()


def sign_payload(payload: Dict, key: str) -> str:
    """HMAC-SHA256 over the canonical JSON of ``payload`` (sans signature)."""
    unsigned = {k: v for k, v in payload.items() if k != "signature"}
    return hmac.new(key.encode(), canonical_bytes(unsigned),
                    hashlib.sha256).hexdigest()


def _default_fingerprint() -> str:
    from repro.obs.calibrate import device_fingerprint

    return device_fingerprint()


def _default_git_sha() -> str:
    from repro.obs.ledger import git_sha

    return git_sha()


def build_payload(entries: Dict[str, Dict], *, key: str,
                  cache_version: int = CACHE_VERSION,
                  fingerprint: Optional[str] = None,
                  git_sha: Optional[str] = None,
                  source_cache: str = "") -> Dict:
    """Assemble + sign a bundle payload from raw entry dicts (the export
    path below; tests use it to craft adversarial bundles)."""
    cid = content_id(cache_version, entries)
    payload = {
        "format": BUNDLE_FORMAT,
        "bundle_version": BUNDLE_VERSION,
        "cache_version": cache_version,
        "manifest": {
            "content_id": cid,
            "fingerprint": (fingerprint if fingerprint is not None
                            else _default_fingerprint()),
            "git_sha": git_sha if git_sha is not None else _default_git_sha(),
            "created": datetime.datetime.now(
                datetime.timezone.utc).isoformat(),
            "entry_count": len(entries),
            "source_cache": source_cache,
        },
        "entries": entries,
    }
    payload["signature"] = sign_payload(payload, key)
    return payload


def write_payload(payload: Dict, out: os.PathLike) -> Path:
    """Write a signed payload atomically.  ``out`` names the file, or a
    directory that gets the content-addressed default name."""
    out = Path(out)
    if out.is_dir():
        out = out / f"{payload['manifest']['content_id'][:16]}{BUNDLE_SUFFIX}"
    out.parent.mkdir(parents=True, exist_ok=True)
    tmp = out.with_suffix(out.suffix + ".tmp")
    tmp.write_text(json.dumps(payload, indent=1))
    os.replace(tmp, out)
    return out


def export_bundle(cache: TuningCache, out: os.PathLike, *,
                  key: Optional[str] = None, strict: bool = False,
                  fingerprint: Optional[str] = None,
                  git_sha: Optional[str] = None) -> Path:
    """Export ``cache`` as a signed bundle at ``out`` (file or directory).

    Quarantined entries never cross the fleet boundary: dropped with a
    warning, or — under ``strict`` — the export is refused with
    :class:`BundleIntegrityError` naming them, mirroring
    ``resilience.report --fail-on-quarantine``.
    """
    key = resolve_key(key)
    entries: Dict[str, Dict] = {}
    quarantined = []
    for k, e in sorted(cache.items().items(), key=lambda kv: kv[0].encode()):
        if e.quarantined:
            quarantined.append(k.encode())
            continue
        entries[k.encode()] = e.to_dict()
    if quarantined:
        if strict:
            raise BundleIntegrityError(
                f"refusing strict export of {cache.path}: "
                f"{len(quarantined)} quarantined entr"
                f"{'y' if len(quarantined) == 1 else 'ies'} "
                f"({', '.join(quarantined)}) — re-tune them first "
                f"(resilience.report --fail-on-quarantine semantics)")
        _warn(f"dropping {len(quarantined)} quarantined entr"
              f"{'y' if len(quarantined) == 1 else 'ies'} from the export: "
              f"{', '.join(quarantined)}")
    payload = build_payload(entries, key=key, fingerprint=fingerprint,
                            git_sha=git_sha, source_cache=str(cache.path))
    path = write_payload(payload, out)
    _warn(f"exported {len(entries)} entries as {path} "
          f"(id {payload['manifest']['content_id'][:16]})")
    return path


def read_bundle(path: os.PathLike, *, key: Optional[str] = None) -> Dict:
    """Read + validate one bundle file, returning the verified payload.

    Every defect raises :class:`BundleIntegrityError`: unreadable JSON,
    unknown format/version, signature mismatch (any mutated byte — a
    re-used signature cannot cover altered content), content-id mismatch,
    or a cache schema the v2–v6 migration path cannot carry forward.
    """
    key = resolve_key(key)
    try:
        text = Path(path).read_text()
    except OSError as e:
        raise BundleIntegrityError(f"cannot read bundle {path}: {e}") from e
    try:
        payload = json.loads(text)
    except json.JSONDecodeError as e:
        raise BundleIntegrityError(
            f"bundle {path} is not valid JSON ({e}) — truncated or "
            f"bit-flipped in transit") from e
    if not isinstance(payload, dict) or payload.get("format") != BUNDLE_FORMAT:
        raise BundleIntegrityError(
            f"bundle {path} has format {payload.get('format') if isinstance(payload, dict) else type(payload).__name__!r}, "
            f"expected {BUNDLE_FORMAT!r}")
    if payload.get("bundle_version") != BUNDLE_VERSION:
        raise BundleIntegrityError(
            f"bundle {path} has bundle_version "
            f"{payload.get('bundle_version')!r}, this importer speaks "
            f"{BUNDLE_VERSION}")
    if faults.should_fire("bundle/tamper"):
        # Injected in-flight mutation: skew one manifest field *after* the
        # producer signed, exactly what a hostile artifact store could do.
        # Verification below must catch it.
        man = dict(payload.get("manifest") or {})
        man["entry_count"] = int(man.get("entry_count") or 0) + 1
        payload["manifest"] = man
    sig = payload.get("signature")
    expect = sign_payload(payload, key)
    if not (isinstance(sig, str) and hmac.compare_digest(sig, expect)):
        raise BundleIntegrityError(
            f"bundle {path} signature mismatch — content was altered after "
            f"signing, or it was signed with a different {FLEET_KEY_ENV}")
    entries = payload.get("entries")
    if not isinstance(entries, dict):
        raise BundleIntegrityError(f"bundle {path} carries no entries object")
    version = payload.get("cache_version")
    cid = (payload.get("manifest") or {}).get("content_id")
    if cid != content_id(version, entries):
        raise BundleIntegrityError(
            f"bundle {path} content_id does not name its own entries")
    if version != CACHE_VERSION and version not in MIGRATABLE_VERSIONS:
        raise BundleIntegrityError(
            f"bundle {path} carries cache schema v{version}; this importer "
            f"migrates {MIGRATABLE_VERSIONS} -> v{CACHE_VERSION} only")
    return payload


def parse_entry(entry_dict: Dict) -> TuneEntry:
    """One bundle entry as a :class:`TuneEntry` (unknown fields ignored,
    missing required fields raise — the import chain drops such entries)."""
    return TuneEntry.from_dict(entry_dict)
