"""Shared tile geometry for the depthwise-conv kernel family.

This module is the *single* source of truth for every derived geometric
quantity the kernels and the performance model agree on: padded-buffer
widths, effective tile sizes, time-tile fallbacks, and grid extents.
``kernels/ops.py`` imports (and re-exports) these functions to lay out the
runtime padding/tiling, and ``perfmodel/schedules.py`` reads the *same*
functions to build the declarative :class:`~repro.perfmodel.schedule.
KernelSchedule` specs — so the analytical model and the executed kernels
cannot drift (the divergence PRs 2-4 had to maintain by hand across
``ops.py`` / ``analysis/traffic.py`` / ``tuning/space.py``).

Nothing here imports jax or any kernel module: pure integer arithmetic on
static shapes, usable from the tuner's host-side ranking loop.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.kernels.common import LANE, DWConvDims, cdiv, round_up


def dtype_itemsize(dtype) -> int:
    """Bytes per element for the dtypes the kernels support.

    The one consistent charging convention for the whole model: operand
    traffic is charged at the tensor dtype's width; f32 accumulators /
    HBM partials are always charged at 4 (they are materialized in f32
    regardless of the operand dtype).
    """
    name = getattr(dtype, "name", None) or str(dtype)
    sizes = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}
    try:
        return sizes[name]
    except KeyError:
        raise ValueError(f"no itemsize convention for dtype {name!r}") from None


def bwd_fused_wpad(L: int, K: int) -> int:
    """Staged-window width the fused backward kernels read: one padded
    layout covering both the dx taps and the dk reduction."""
    return round_up(round_up(L, LANE) + K - 1, LANE)


def unified_wpad(L: int, K: int, block_t: int) -> int:
    """One padded-buffer width serving every forward variant's window reads
    *and* the fused backward's staged window (``bwd_fused_wpad`` is its
    first max term), so the forward's ``xp`` is reusable as the fused VJP
    residual verbatim — no re-pad in backward."""
    Lout = round_up(L, LANE)
    Lt = min(block_t, Lout)
    nT = cdiv(Lout, Lt)
    Wpad = max(
        bwd_fused_wpad(L, K),                # row + fused-backward window
        (nT + 1) * Lt,                       # block: neighbour halo tile
        nT * Lt + K - 1 + LANE,              # lane: widened aligned windows
    )
    return round_up(Wpad, LANE)


def bwdk_time_tile(L: int, K: int, block_t: int, variant: str) -> Optional[int]:
    """Effective time tile ``Lt`` for a staged weight-gradient kernel, or
    ``None`` when it executes untiled (single staged slab).

    Tiling requires more than one tile to be worth a third grid dimension
    and ``Lt >= K - 1`` so the halo fits one neighbour tile; shapes failing
    that quietly run the untiled path (tiling is a perf knob, not
    semantics).  ``naive`` has no staged slab to tile.
    """
    if variant not in ("accum", "twostage", "fused", "fused_partials"):
        return None
    Lout = round_up(L, LANE)
    Lt = min(block_t, Lout)
    if Lt >= Lout or Lt < K - 1:
        return None
    return Lt


def epilogue_time_tile(L: int, K: int, block_t: int, variant: str) -> Optional[int]:
    """Time tile for the *epilogue* fused backward, or ``None`` (untiled).

    The activation-recompute needs the extended pre-activation window
    (prev + cur + next x tiles), so the tile must additionally satisfy
    ``Lt >= 2 * (K - 1)``; shapes failing that quietly run untiled, exactly
    like ``bwdk_time_tile``'s own fallbacks."""
    Lt = bwdk_time_tile(L, K, block_t, variant)
    if Lt is None or Lt < 2 * (K - 1):
        return None
    return Lt


def time_tile(L: int, K: int, block_t: int, variant: str,
              epilogue: str = "none") -> Optional[int]:
    """The time tile the kernel actually runs for this (variant, epilogue):
    the epilogue-aware fused backward needs the stricter recompute window."""
    if epilogue != "none":
        return epilogue_time_tile(L, K, block_t, variant)
    return bwdk_time_tile(L, K, block_t, variant)


def decode_lane_tile(H: int, block_t: int) -> int:
    """Channel-lane tile ``Hl`` for the streaming-decode kernels.

    At L=1 the temporal axis degenerates, so channels ride the lane axis and
    the ``block_t`` knob is reused as the channel tile: ``Hl = min(block_t,
    round_up(H, LANE))``.  The result must be a LANE multiple to be legal
    (the kernels raise, the schedules mark illegal) — an unaligned
    ``block_t`` smaller than the padded channel extent fails that.
    """
    return min(block_t, round_up(max(H, 1), LANE))


def decode_tiles(
    d: DWConvDims, block_t: int, batch_chunk: int
) -> Tuple[int, int, int, int, int, int]:
    """``(Hl, nH, Hp, Bc, nB, Bp)`` exactly as ``ops._decode_impl`` pads and
    the decode kernels tile: channel axis padded to ``Hl`` tiles, slot pool
    padded to ``batch_chunk`` rows (the ``rows`` variant stages the whole
    padded pool per cell; ``chanblock`` walks it in ``Bc``-row chunks)."""
    Hl = decode_lane_tile(d.H, block_t)
    Hp = round_up(d.H, Hl)
    nH = Hp // Hl
    Bc = max(1, min(batch_chunk, d.B))
    Bp = round_up(d.B, Bc)
    nB = Bp // Bc
    return Hl, nH, Hp, Bc, nB, Bp


def effective_tiles(
    d: DWConvDims, block_h: int, block_t: int, batch_chunk: int
) -> Tuple[int, int, int, int]:
    """``(Hb, Lt, Bc, Lout)`` exactly as ``ops.py`` and the kernels clamp
    the tiling knobs to the problem dimensions."""
    Hb = max(1, min(block_h, d.H))
    Lout = round_up(d.L, LANE)
    Lt = max(1, min(block_t, Lout))
    Bc = max(1, min(batch_chunk, d.B))
    return Hb, Lt, Bc, Lout


def fwd_tile_grid(d: DWConvDims, block_h: int, block_t: int
                  ) -> Tuple[int, int, int, int, int]:
    """``(Hb, Lout, Lt, nT, n_tiles)`` for the tiled forward-family kernels
    (naive/lane/block): the output-tile grid the per-tap DMA charges walk."""
    Hb, Lt, _, Lout = effective_tiles(d, block_h, block_t, d.B)
    nT = cdiv(Lout, Lt)
    n_tiles = d.B * cdiv(d.H, Hb) * nT
    return Hb, Lout, Lt, nT, n_tiles


def bwd_time_tiles(d: DWConvDims, variant: str, block_t: int,
                   epilogue: str = "none") -> Tuple[int, int]:
    """``(nT, halo_elems_per_operand)`` for a staged bwd kernel.

    ``nT`` is the time-tile count the kernel actually runs (1 = untiled, the
    pre-``block_t`` behaviour); the halo term counts the K-1 columns every
    interior tile seam re-reads — the redundancy the tuner trades against
    per-cell footprint when it shrinks ``block_t``.
    """
    Lt = time_tile(d.L, d.K, block_t, variant, epilogue)
    if Lt is None:
        return 1, 0
    nT = cdiv(round_up(d.L, LANE), Lt)
    halo = d.B * d.H * (nT - 1) * (d.K - 1)
    return nT, halo
