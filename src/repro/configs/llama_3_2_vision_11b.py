"""llama-3.2-vision-11b [vlm]: 40L (32 self + 8 gated cross-attn, one per
5-layer superblock), d=4096, 32H (GQA kv=8), ff=14336, vocab=128256; stub
vision tower provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision]"""
import dataclasses

from repro.models.config import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=128256,
    head_dim=128,
    rope_theta=500_000.0,
    vlm=VLMConfig(cross_every=5, n_img_tokens=1600),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
    head_dim=16, vlm=VLMConfig(cross_every=2, n_img_tokens=8),
    compute_dtype="float32",
)
