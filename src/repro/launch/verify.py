"""Static schedule verification sweep: ``python -m repro.launch.verify``.

Cross-checks every registered (path × variant × epilogue) schedule against
the kernels' actual launch geometry (abstractly traced — no accelerator, no
execution; see ``repro.verify.schedule_check``) over a shape grid covering
the paper study shape, a long-sequence tiled regime, ragged extents that
divide nothing cleanly, a causal decoder conv, and an uneven time tiling,
each under two knob settings (the defaults and a small-tile/chunked setting
that activates the time-tiled and batch-chunked kernels).

Exit status follows ``--fail-on``; ``--json`` writes the findings report
(the CI ``static-analysis`` job uploads it as VERIFY.json).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.kernels.common import DWConvDims
from repro.kernels.epilogue import EPILOGUE_KEYS
from repro.perfmodel.schedules import SCHEDULE_BUILDERS
from repro.verify.findings import (Finding, findings_payload, max_severity,
                                   should_fail)
from repro.verify.schedule_check import verify_config

# Shape grid: name -> dims.  L=600 forces nT*Lt > Lout (uneven time tiles).
SHAPE_GRID: Tuple[Tuple[str, DWConvDims], ...] = (
    ("paper", DWConvDims(B=64, H=128, L=48, K=48)),
    ("longseq", DWConvDims(B=8, H=64, L=16384, K=4)),
    ("ragged", DWConvDims(B=4, H=24, L=100, K=5)),
    ("uneven-tile", DWConvDims(B=2, H=16, L=600, K=7)),
    ("causal", DWConvDims(B=8, H=32, L=256, K=4, padding="causal")),
)

KNOB_GRID: Tuple[Dict[str, int], ...] = (
    dict(block_h=8, block_t=512, batch_chunk=128),
    dict(block_h=8, block_t=128, batch_chunk=4),
)

# Decode is a single-step path — the SHAPE_GRID L values are meaningless for
# it, so it sweeps its own serving shapes (L=1 always): a typical per-layer
# conv state, a large-model slot pool, ragged extents, and the K=2 floor.
DECODE_SHAPE_GRID: Tuple[Tuple[str, DWConvDims], ...] = (
    ("serve", DWConvDims(B=8, H=192, L=1, K=4, padding="causal")),
    ("serve-wide", DWConvDims(B=64, H=1536, L=1, K=4, padding="causal")),
    ("serve-ragged", DWConvDims(B=5, H=100, L=1, K=7, padding="causal")),
    ("serve-min", DWConvDims(B=1, H=128, L=1, K=2, padding="causal")),
)


def sweep_registry(
    shapes: Sequence[Tuple[str, DWConvDims]] = SHAPE_GRID,
    knob_grid: Sequence[Dict[str, int]] = KNOB_GRID,
    decode_shapes: Sequence[Tuple[str, DWConvDims]] = DECODE_SHAPE_GRID,
) -> Tuple[List[Dict], List[Finding]]:
    """Run the full registry sweep.  Returns (per-config rows, findings)."""
    rows: List[Dict] = []
    findings: List[Finding] = []

    def _check(shape_name, d, knobs, path, variant):
        epilogues = (EPILOGUE_KEYS if path in ("fwd", "bwd_fused", "decode")
                     else ("none",))
        for epi in epilogues:
            status, fs = verify_config(path, variant, d,
                                       epilogue=epi, **knobs)
            rows.append({
                "shape": shape_name,
                "dims": f"{d.B}x{d.H}x{d.L}x{d.K}/{d.padding}",
                "knobs": dict(knobs),
                "path": path, "variant": variant, "epilogue": epi,
                "status": status, "findings": len(fs),
            })
            findings.extend(fs)

    for shape_name, d in shapes:
        for knobs in knob_grid:
            for path, variant in sorted(SCHEDULE_BUILDERS):
                if path == "decode":
                    continue  # swept below at its own L=1 serving shapes
                _check(shape_name, d, knobs, path, variant)
    for shape_name, d in decode_shapes:
        for knobs in knob_grid:
            for path, variant in sorted(SCHEDULE_BUILDERS):
                if path != "decode":
                    continue
                _check(shape_name, d, knobs, path, variant)
    return rows, findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.verify",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the findings report as JSON (VERIFY.json)")
    ap.add_argument("--fail-on", choices=("error", "warning", "never"),
                    default="error",
                    help="exit 1 when findings at/above this level exist")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    rows, findings = sweep_registry()
    dt = time.perf_counter() - t0

    by_status: Dict[str, int] = {}
    for r in rows:
        by_status[r["status"]] = by_status.get(r["status"], 0) + 1
    for f in findings:
        print(f.render())
    checked = by_status.get("verified", 0) + by_status.get("failed", 0)
    print(f"[verify] {len(rows)} configs in {dt:.1f}s — "
          + ", ".join(f"{k}={v}" for k, v in sorted(by_status.items()))
          + f" ({checked} cross-checked against a traced pallas_call)",
          file=sys.stderr)

    if args.json:
        payload = {
            "tool": "repro.launch.verify",
            "status_counts": by_status,
            "configs": rows,
            "findings": findings_payload(findings),
        }
        Path(args.json).write_text(json.dumps(payload, indent=1))
        print(f"[verify] wrote {args.json}", file=sys.stderr)
    return 1 if should_fail(findings, args.fail_on) else 0


if __name__ == "__main__":
    sys.exit(main())
