"""Paper Fig. 10 analogue: roofline placement of every (variant x path) point.

Counter-free construction (paper §III-G): FLOPs from eqs. (2)-(3), bytes
derived from the registered kernel schedules (``repro.perfmodel``),
runtimes from the paper's Table II, roofs from the P100 datasheet
(732 GB/s, 10.6 TFLOP/s fp32).  The rows are rendered from
``analysis/report.paper_roofline_points`` — the same derivation the
``python -m repro.launch.report`` CLI emits, so the benchmark and the
report cannot diverge.  The reproduction target is the paper's qualitative
result: *every* variant/path stays in the memory-bound regime, with
shared/warp shifted up and slightly right.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.analysis.hw import P100
from repro.analysis.report import paper_roofline_points


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def run(fast: bool = False) -> List[Row]:
    rows: List[Row] = []
    knee = P100.roofline_knee()
    for p in paper_roofline_points():
        if p.reliable:
            assert p.regime == "memory-bound", (p.variant, p.path, p.arithmetic_intensity)
            assert p.achieved_gflops < P100.peak_flops / 1e9, "must stay below compute roof"
            rows.append(Row(
                f"paper_roofline/{p.variant}/{p.path}", p.runtime_s * 1e6,
                f"AI={p.arithmetic_intensity:.2f}FLOP/B "
                f"achieved={p.achieved_gflops:.0f}GFLOP/s "
                f"roof@AI={p.roof_gflops:.0f}GFLOP/s {p.regime}",
            ))
        else:
            rows.append(Row(
                f"paper_roofline/{p.variant}/{p.path}", p.runtime_s * 1e6,
                f"achieved={p.achieved_gflops:.0f}GFLOP/s AI=N/A (naive proxy) memory-bound",
            ))
    rows.append(Row("paper_roofline/summary", 0.0,
                    f"knee={knee:.1f}FLOP/B all points memory-bound REPRODUCED"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
