"""gemma3-27b [dense]: 62L, d=5376, 32H (GQA kv=16), ff=21504,
vocab=262144, 5:1 local:global interleave (window 1024), dual RoPE theta,
QK-norm, tied embeddings.  [hf:google/gemma-3-*]"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    qk_norm=True,
    rope_theta=10_000.0,          # local layers
    rope_theta_global=1_000_000.0,
    window=1024,
    local_global_pattern=5,       # 5 local : 1 global
    tie_embeddings=True,
    act="gelu_tanh",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
    head_dim=16, window=8, local_global_pattern=2, compute_dtype="float32",
)
