"""Tests for the counter-free observability stack (repro.obs).

Three legs: the span tracer (trace.py), the hardware-calibration
microbenchmark fits (calibrate.py), and the perf-trajectory ledger with
its noise-aware regression gate (ledger.py).
"""
import json
import math
import os

import jax.numpy as jnp
import pytest

from repro.analysis.hw import TPU_V5E
from repro.kernels.common import DWConvDims
from repro.obs import ledger as L
from repro.obs import trace as T
from repro.obs.calibrate import (
    CalibratedHardware,
    SweepPoint,
    device_fingerprint,
    fit_linear_time,
    load_calibration,
    load_for_device,
    run_calibration,
    save_calibration,
)
from repro.perfmodel import derive_traffic, schedule_for


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_parents(tmp_path):
    p = str(tmp_path / "t.jsonl")
    tr = T.Tracer(p, meta={"launcher": "test"})
    with tr.span("outer", step=0) as outer:
        with tr.span("inner") as inner:
            pass
    tr.close()
    assert inner.parent_id == outer.id
    recs = T.read_trace(p)
    kinds = [r["kind"] for r in recs]
    assert kinds[0] == "meta" and kinds.count("span") == 2
    by_name = {r["name"]: r for r in recs if r["kind"] == "span"}
    assert by_name["inner"]["parent"] == by_name["outer"]["id"]
    assert by_name["inner"]["path"] == "outer/inner"
    assert by_name["outer"]["tags"] == {"step": 0}
    # inner closes first: JSONL order is close order
    assert recs[1]["name"] == "inner"


def test_disabled_tracer_is_nullspan_and_touches_no_file(tmp_path):
    tr = T.Tracer()  # default: disabled
    assert not tr.enabled
    s1 = tr.span("a")
    s2 = tr.span("b", step=1)
    assert s1 is s2  # shared singleton — no per-span allocation
    with s1 as sp:
        sp.tag(x=1).sync(object()).attach("k", None)
    assert tr.records == []
    assert list(tmp_path.iterdir()) == []


def test_span_sync_blocks_on_jax_values():
    tr = T.Tracer(enabled=True)
    with tr.span("compute") as sp:
        out = jnp.ones((64, 64)) @ jnp.ones((64, 64))
        sp.sync(out)
    assert sp.dur_s > 0
    assert tr.records[0]["dur_s"] == sp.dur_s


def test_attach_emits_kernel_record_with_model_and_roofline():
    d = DWConvDims(B=4, H=8, L=64, K=4, padding="causal")
    s = schedule_for("fwd", "row", d, 4)
    est = derive_traffic(s)
    tr = T.Tracer(enabled=True)
    with tr.span("step") as sp:
        sp.attach("dwconv_fwd", s, hw=TPU_V5E, count=3)
    span_rec, k = tr.records
    assert k["kind"] == "kernel"
    assert k["parent"] == span_rec["id"]
    assert k["modeled_bytes"] == est.bytes_moved * 3
    assert k["time_scope"] == "enclosing-span"
    assert k["dur_s"] == span_rec["dur_s"]
    assert k["effective_bandwidth"] == pytest.approx(
        k["modeled_bytes"] / span_rec["dur_s"])
    assert k["regime"] in ("memory-bound", "compute-bound")
    assert 0 < k["bandwidth_utilization"]


def test_attach_runtime_override_is_kernel_scoped():
    d = DWConvDims(B=4, H=8, L=64, K=4)
    s = schedule_for("fwd", "row", d, 4)
    tr = T.Tracer(enabled=True)
    with tr.span("measure") as sp:
        sp.attach("kernel", s, hw=TPU_V5E, runtime_s=1e-3)
    k = tr.records[1]
    assert k["time_scope"] == "kernel"
    assert k["dur_s"] == 1e-3
    assert k["effective_bandwidth"] == pytest.approx(
        derive_traffic(s).bytes_moved / 1e-3)


def test_traced_decorator():
    tr = T.Tracer(enabled=True)

    @tr.traced("fn/add", kind="unit")
    def add(a, b):
        return a + b

    assert add(2, 3) == 5
    rec = tr.records[0]
    assert rec["name"] == "fn/add" and rec["tags"]["kind"] == "unit"


def test_configure_installs_global(tmp_path):
    old = T.get_tracer()
    try:
        tr = T.configure(str(tmp_path / "g.jsonl"), meta={"m": 1})
        assert T.get_tracer() is tr and tr.enabled
    finally:
        T.configure(None, enabled=False)
        assert not T.get_tracer().enabled


def test_dwconv_step_schedules_ssm_and_attention():
    from repro.configs.registry import get_config

    cfg = get_config("mamba2-1.3b", smoke=True)
    atts = T.dwconv_step_schedules(cfg, batch=2, seq=32)
    assert [a[0] for a in atts] == ["dwconv_fwd", "dwconv_bwd_fused"]
    for _, sched, count in atts:
        assert count == cfg.n_layers
        assert derive_traffic(sched).bytes_moved > 0
        assert sched.epilogue == "bias+silu"
    # serving: forward only
    assert [a[0] for a in T.dwconv_step_schedules(cfg, 2, 32, training=False)] \
        == ["dwconv_fwd"]
    # attention-only archs carry no paper-operator kernel
    qwen = get_config("qwen2-0.5b", smoke=True)
    assert T.dwconv_step_schedules(qwen, 2, 32) == []


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_fit_linear_time_exact():
    rate, overhead = 2e9, 5e-6
    pts = [SweepPoint(w, overhead + w / rate) for w in (1e6, 4e6, 16e6, 64e6)]
    fit = fit_linear_time(pts)
    assert fit.rate == pytest.approx(rate, rel=1e-6)
    assert fit.overhead_s == pytest.approx(overhead, rel=1e-4)
    assert fit.r2 > 0.999999


def test_fit_linear_time_noisy():
    import numpy as np

    rng = np.random.default_rng(0)
    rate, overhead = 8e11, 2e-5
    pts = [SweepPoint(w, (overhead + w / rate) * float(rng.uniform(0.97, 1.03)))
           for w in np.geomspace(1e6, 256e6, 12)]
    fit = fit_linear_time(pts)
    assert fit.rate == pytest.approx(rate, rel=0.15)
    assert fit.r2 > 0.99


def test_fit_linear_time_degenerate_single_point():
    fit = fit_linear_time([SweepPoint(1e6, 1e-3)])
    assert fit.rate == pytest.approx(1e9)


def test_run_calibration_and_roundtrip(tmp_path):
    cal = run_calibration(base=TPU_V5E, fast=True, iters=1)
    assert cal.fingerprint == device_fingerprint()
    assert cal.hbm_bw > 0 and cal.flops_f32 > 0
    assert cal.dispatch_overhead_s >= 0
    p = str(tmp_path / "cal.json")
    save_calibration(cal, p)
    back = load_calibration(p)
    assert back.fingerprint == cal.fingerprint
    assert back.hbm_bw == pytest.approx(cal.hbm_bw)
    # overlayed hardware model keeps datasheet identity but measured roofs
    hwm = back.hardware_model(TPU_V5E)
    assert hwm.hbm_bw == pytest.approx(back.hbm_bw)
    assert hwm.peak_flops_f32 == pytest.approx(back.flops_f32)
    assert hwm.name.endswith("+calibrated")


def test_calibrated_analytical_time_adds_dispatch_floor(tmp_path):
    cal = run_calibration(base=TPU_V5E, fast=True, iters=1)
    d = DWConvDims(B=8, H=16, L=256, K=4)
    s = schedule_for("fwd", "row", d, 4)
    t = cal.analytical_time_s(s, TPU_V5E)
    assert t >= cal.dispatch_overhead_s
    est = derive_traffic(s)
    assert t >= est.bytes_moved / cal.hbm_bw


def test_load_for_device_fingerprint_mismatch(tmp_path, monkeypatch):
    cal = run_calibration(base=TPU_V5E, fast=True, iters=1)
    other = CalibratedHardware(**{**cal.__dict__, "fingerprint": "gpu:h100:x8"})
    p = str(tmp_path / "cal.json")
    save_calibration(other, p)
    monkeypatch.setenv("REPRO_CALIBRATION", p)
    assert load_for_device() is None          # wrong device
    save_calibration(cal, p)
    assert load_for_device() is not None      # right device
    with open(p, "w") as f:
        f.write("{corrupt")
    assert load_for_device() is None          # corrupt file -> None, no raise


def test_load_for_device_mismatch_warns_once_naming_both(
        tmp_path, monkeypatch, capsys):
    from repro.obs import calibrate as cal_mod

    cal = run_calibration(base=TPU_V5E, fast=True, iters=1)
    other = CalibratedHardware(**{**cal.__dict__, "fingerprint": "gpu:h100:x8"})
    p = str(tmp_path / "cal.json")
    save_calibration(other, p)
    monkeypatch.setenv("REPRO_CALIBRATION", p)
    monkeypatch.setattr(cal_mod, "_MISMATCH_WARNED", set())
    assert load_for_device() is None
    err = capsys.readouterr().err
    assert "gpu:h100:x8" in err and cal_mod.device_fingerprint() in err, (
        "the warning must name both fingerprints")
    assert "re-run" in err
    assert load_for_device() is None          # second probe: same pair,
    assert capsys.readouterr().err == ""      # one warning only


# ---------------------------------------------------------------------------
# ledger + regression gate
# ---------------------------------------------------------------------------

def _entry(metrics, i=0, fp="cpu:cpu:x1"):
    return L.LedgerEntry(ts=f"2026-08-0{i % 9 + 1}T00:00:00+00:00",
                         sha=f"sha{i}", fingerprint=fp, source="test",
                         metrics=metrics)


def test_ledger_append_read_roundtrip(tmp_path):
    p = str(tmp_path / "ledger.jsonl")
    e1 = L.append_entry({"a_speedup": 2.0, "failures": 0}, source="t", path=p)
    L.append_entry({"a_speedup": 2.1, "failures": 0}, source="t", path=p)
    entries = L.read_ledger(p)
    assert len(entries) == 2
    assert entries[0].metrics == e1.metrics
    assert entries[0].fingerprint == device_fingerprint()
    # torn trailing line is skipped, not fatal
    with open(p, "a") as f:
        f.write('{"truncat')
    assert len(L.read_ledger(p)) == 2


def test_ledger_env_var_path(tmp_path, monkeypatch):
    p = str(tmp_path / "env.jsonl")
    monkeypatch.setenv(L.LEDGER_ENV, p)
    assert L.ledger_path() == p
    L.append_entry({"x_per_s": 1.0}, source="t")
    assert os.path.exists(p)


def test_numeric_metrics_filters():
    payload = {"a_speedup": 2.0, "failures": 0, "results": [1, 2],
               "name": "x", "flag": True, "bad": float("nan"), "none": None}
    nums = L.numeric_metrics(payload)
    assert nums == {"a_speedup": 2.0, "failures": 0.0}


def test_metric_direction_suffix_priority():
    # rates ending in _s must classify higher-better, not time-like
    assert L.metric_direction("decode_tok_s") == +1
    assert L.metric_direction("prefill_per_s") == +1
    assert L.metric_direction("fused_vs_split_backward_speedup") == +1
    assert L.metric_direction("kernel_time_us") == -1
    assert L.metric_direction("step_ms") == -1
    assert L.metric_direction("failures") == -1
    assert L.metric_direction("report_memory_bound_fraction") == 0


def test_check_regression_fresh_ledger_passes():
    ok, verdicts = L.check_regression([])
    assert ok and verdicts == []
    ok, verdicts = L.check_regression([_entry({"a_speedup": 2.0})])
    assert ok
    assert verdicts[0].status == "no-baseline"


def test_check_regression_improving_passes():
    entries = [_entry({"a_speedup": 2.0 + 0.05 * i}, i) for i in range(6)]
    ok, verdicts = L.check_regression(entries)
    assert ok
    v = {x.metric: x for x in verdicts}["a_speedup"]
    assert v.status in ("ok", "improved")


def test_check_regression_twenty_percent_drop_fails():
    entries = [_entry({"a_speedup": 2.0}, i) for i in range(5)]
    entries.append(_entry({"a_speedup": 1.6}, 5))  # -20%
    ok, verdicts = L.check_regression(entries)
    assert not ok
    v = {x.metric: x for x in verdicts}["a_speedup"]
    assert v.status == "regressed" and v.gate_failed


def test_check_regression_noisy_flat_passes():
    import numpy as np

    rng = np.random.default_rng(1)
    entries = [_entry({"t_us": 100.0 * float(rng.uniform(0.9, 1.1))}, i)
               for i in range(8)]
    ok, _ = L.check_regression(entries, noise_mult=3.0)
    assert ok


def test_check_regression_ignores_other_fingerprints():
    entries = [_entry({"a_speedup": 9.0}, i, fp="gpu:p100:x1") for i in range(5)]
    entries.append(_entry({"a_speedup": 2.0}, 6, fp="cpu:cpu:x1"))
    ok, verdicts = L.check_regression(entries)
    assert ok  # no same-fingerprint history -> no-baseline, not regressed
    assert verdicts[0].status == "no-baseline"


def test_check_regression_lower_better_metric():
    entries = [_entry({"step_ms": 10.0}, i) for i in range(5)]
    entries.append(_entry({"step_ms": 14.0}, 5))  # +40% time
    ok, verdicts = L.check_regression(entries)
    assert not ok
    entries[-1] = _entry({"step_ms": 8.0}, 5)
    ok, verdicts = L.check_regression(entries)
    assert ok
    assert verdicts[0].status == "improved"


# ---------------------------------------------------------------------------
# CLIs
# ---------------------------------------------------------------------------

def test_perf_cli_gate_exit_codes(tmp_path, monkeypatch, capsys):
    from repro.launch.perf import main as perf_main

    p = str(tmp_path / "ledger.jsonl")
    monkeypatch.setenv(L.LEDGER_ENV, p)
    assert perf_main(["--check"]) == 0  # empty ledger: pass
    for v in (2.0, 2.02, 1.98, 2.01):
        L.append_entry({"a_speedup": v, "failures": 0}, source="t", path=p)
    assert perf_main(["--check"]) == 0
    L.append_entry({"a_speedup": 1.4, "failures": 0}, source="t", path=p)
    assert perf_main(["--check"]) == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "a_speedup" in out


def test_perf_cli_append_and_show(tmp_path, capsys):
    from repro.launch.perf import main as perf_main

    bench = tmp_path / "BENCH.json"
    bench.write_text(json.dumps({"epilogue_fused_speedup": 2.5, "failures": 0,
                                 "results": []}))
    p = str(tmp_path / "ledger.jsonl")
    assert perf_main(["--append", str(bench), "--ledger", p]) == 0
    entries = L.read_ledger(p)
    assert entries[0].metrics["epilogue_fused_speedup"] == 2.5
    assert perf_main(["--show", "--ledger", p]) == 0
    assert "epilogue_fused_speedup" in capsys.readouterr().out


def test_calibrate_cli(tmp_path, capsys):
    from repro.launch.calibrate import main as cal_main

    out = str(tmp_path / "cal.json")
    assert cal_main(["--fast", "--iters", "1", "--out", out]) == 0
    assert load_calibration(out).fingerprint == device_fingerprint()
    text = capsys.readouterr().out
    assert device_fingerprint() in text and "triad" in text
