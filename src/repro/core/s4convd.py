"""S4ConvD — the paper's fixed model (diagonal state-space conv blocks).

Follows the S4ConvD construction (Schaller & Rosenhahn, arXiv:2502.21035,
built on S4D, arXiv:2206.11893): the depthwise convolution filter of each
channel is *materialized* from diagonal state-space parameters with
per-channel adaptive timescale scaling (learned Delta) and frequency
adjustment (learned imaginary parts), then applied with the framework's
depthwise-conv operator — the operator under study.  Everything except the
kernel implementation variant is fixed (paper §III-B):

  input (B, L=48, F=4) -> Linear(F -> H=128) -> n x S4ConvDBlock -> head

  S4ConvDBlock(x): u = dwconv_act(x, k_ssm(theta), act="gelu")
                   # the studied operator with its GELU fused in-register
                   # (one HBM write; activation recomputed in backward)
                   u = channelwise Linear(H -> H) + dropout(0.01)
                   x = x + u                      # residual

The regression head emits softplus-positive next-step energy predictions
for the RMSLE loss.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.dwconv import dwconv_act
from repro.kernels.ops import KernelOptions


@dataclasses.dataclass(frozen=True)
class S4ConvDConfig:
    F: int = 4            # input features (R, T_a, CC, T_d)
    H: int = 128          # latent channels (paper §III-B)
    L: int = 48           # sequence length (paper §III-A1)
    K: int = 48           # conv kernel length (paper App. A)
    N: int = 16           # diagonal state size per channel
    n_blocks: int = 4
    dropout: float = 0.01
    padding: str = "same"          # paper eq. (7)-(8) convention
    conv_variant: str = "xla"      # study axis: naive/lane/block/row/xla/auto
    # None lets variant="auto" apply cached tiling (explicit opts override it)
    kernel_opts: Optional[KernelOptions] = None

    @property
    def param_count_estimate(self) -> int:
        per_block = self.H * self.N * 4 + self.H + self.H * self.H + self.H
        return self.F * self.H + self.H + self.n_blocks * per_block + self.H + 1


def _init_block(rng: jax.Array, cfg: S4ConvDConfig) -> Dict[str, jnp.ndarray]:
    """S4D-Lin diagonal initialization + adaptive-scale Delta."""
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    H, N = cfg.H, cfg.N
    # A = -exp(log_a_real) + i * a_imag ; S4D-Lin: imag parts at pi * n
    log_a_real = jnp.log(0.5 * jnp.ones((H, N)))
    a_imag = math.pi * jnp.broadcast_to(jnp.arange(N, dtype=jnp.float32), (H, N)).copy()
    # frequency adjustment (S4ConvD): learnable multiplicative detuning
    freq_scale = jnp.ones((H, N)) + 0.01 * jax.random.normal(k1, (H, N))
    c = jax.random.normal(k2, (H, N, 2)) / math.sqrt(N)  # complex C as (re, im)
    # Adaptive timescale (S4ConvD): log-uniform Delta per channel, with the
    # range tied to the kernel support K so even the slowest channel's modes
    # decay across the materialized filter (|A_re| * dt_min * K ~ 0.5).  The
    # classic S4D range [1e-3, 1e-1] assumes L ~ 1e3; for the paper's short
    # K = 48 filters it leaves kernels effectively non-decaying.
    dt_min, dt_max = 1.0 / cfg.K, 10.0 / cfg.K
    u = jax.random.uniform(k3, (H,))
    log_dt = u * (math.log(dt_max) - math.log(dt_min)) + math.log(dt_min)
    w_out = jax.random.normal(k4, (H, H)) / math.sqrt(H)
    return {
        "log_a_real": log_a_real,
        "a_imag": a_imag,
        "freq_scale": freq_scale,
        "c": c,
        "log_dt": log_dt,
        "w_out": w_out,
        "b_out": jnp.zeros((H,)),
    }


def materialize_kernel(block_params: Dict[str, jnp.ndarray], K: int) -> jnp.ndarray:
    """k[h, j] = Re( sum_n C[h,n] * dt[h] * exp(A[h,n] * dt[h] * j) ).

    The ZOH-ish dt prefactor keeps filter energy stable across timescales
    (S4D eq. (5) family); freq_scale implements S4ConvD's frequency
    adjustment.  Returns (H, K) float32.
    """
    a_real = -jnp.exp(block_params["log_a_real"])          # (H, N) < 0
    a_imag = block_params["a_imag"] * block_params["freq_scale"]
    dt = jnp.exp(block_params["log_dt"])[:, None]          # (H, 1)
    t = jnp.arange(K, dtype=jnp.float32)                   # (K,)
    # exponent: (H, N, K)
    phase = (a_real * dt)[..., None] * t + 1j * (a_imag * dt)[..., None] * t
    c = block_params["c"][..., 0] + 1j * block_params["c"][..., 1]  # (H, N)
    k = jnp.einsum("hn,hnk->hk", c * dt.astype(c.dtype), jnp.exp(phase))
    return k.real.astype(jnp.float32)


def init(rng: jax.Array, cfg: S4ConvDConfig) -> Dict[str, Any]:
    keys = jax.random.split(rng, cfg.n_blocks + 2)
    params: Dict[str, Any] = {
        "w_in": jax.random.normal(keys[0], (cfg.F, cfg.H)) / math.sqrt(cfg.F),
        "b_in": jnp.zeros((cfg.H,)),
        "blocks": [_init_block(keys[i + 1], cfg) for i in range(cfg.n_blocks)],
        "w_head": jax.random.normal(keys[-1], (cfg.H, 1)) / math.sqrt(cfg.H),
        "b_head": jnp.zeros((1,)),
    }
    return params


def apply(
    params: Dict[str, Any],
    cfg: S4ConvDConfig,
    x: jnp.ndarray,
    *,
    rng: Optional[jax.Array] = None,
    train: bool = False,
) -> jnp.ndarray:
    """x: (B, L, F) -> positive next-step predictions (B, L)."""
    B, L, F = x.shape
    h = x @ params["w_in"] + params["b_in"]               # (B, L, H)
    h = jnp.transpose(h, (0, 2, 1))                       # (B, H, L) — operator layout
    for i, bp in enumerate(params["blocks"]):
        k = materialize_kernel(bp, cfg.K)
        # Fused GELU epilogue: applied in-register on the conv accumulator
        # (one HBM write); the backward recomputes the pre-activation.
        u = dwconv_act(
            h, k.astype(h.dtype), act="gelu",
            padding=cfg.padding, variant=cfg.conv_variant, opts=cfg.kernel_opts,
        )
        u = jnp.einsum("bhl,hg->bgl", u, bp["w_out"]) + bp["b_out"][None, :, None]
        if train and cfg.dropout > 0 and rng is not None:
            keep = 1.0 - cfg.dropout
            mask = jax.random.bernoulli(jax.random.fold_in(rng, i), keep, u.shape)
            u = jnp.where(mask, u / keep, 0.0)
        h = h + u                                          # residual
    h = jnp.transpose(h, (0, 2, 1))                        # (B, L, H)
    out = h @ params["w_head"] + params["b_head"]          # (B, L, 1)
    return jax.nn.softplus(out[..., 0])                    # positive for RMSLE


def param_count(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
