"""Published measurements from the paper (Tables II/III, Fig. 10).

Canonical home: ``repro.analysis.paper_data`` (importable by the
``repro.launch.report`` CLI without depending on this benchmarks tree);
re-exported here because every ``benchmarks/paper_*`` module historically
imports them from this module.
"""
from repro.analysis.paper_data import (  # noqa: F401
    CLAIM_BWDK_SPEEDUP,
    CLAIM_EPOCH_SPEEDUP,
    CLAIM_FWD_SPEEDUP,
    CLAIM_KERNEL_SPEEDUP,
    PAPER_DIMS,
    PAPER_TO_TPU,
    PYTORCH_MS,
    TABLE2_MS,
    TABLE3_GBPS,
)
