"""Persistent tuning database for the counter-free autotuner.

A flat JSON file maps shape keys ``(path, B, H, L, K, padding, dtype,
backend)`` to
the winning kernel configuration plus the counter-free measurement that
selected it.  Design points:

  * **versioned**: the file carries ``CACHE_VERSION``; entries written by an
    incompatible tuner are ignored (never mis-applied) and overwritten on
    the next save, while ``MIGRATABLE_VERSIONS`` whose entries remain valid
    (e.g. v2, which merely predates the ``bwd_fused`` path) migrate verbatim;
  * **memoized**: one in-process :class:`TuningCache` per resolved file path
    — ``variant="auto"`` dispatch in ``kernels/ops.py`` costs a dict lookup
    after the first miss, not file I/O per call;
  * **overridable**: ``REPRO_TUNE_CACHE=/path/to/cache.json`` redirects both
    the tuner's writes and auto-dispatch reads (cluster jobs point it at a
    shared artifact; tests point it at a tmpdir);
  * **atomic**: writes go to ``<path>.tmp`` then ``os.replace`` so a crashed
    tuning run never corrupts the database.

The cache stores *decisions*, not timings-as-truth: measured microseconds
are kept for reporting (``benchmarks/paper_autotune.py``) but dispatch only
reads the configuration fields.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from pathlib import Path
from typing import Dict, Optional

from repro.kernels.ops import KernelOptions

CACHE_VERSION = 3  # v3: the 'bwd_fused' execution path joined the key space
# Older schemas whose entries are still valid per-path decisions and are
# carried forward on load (and re-written as CACHE_VERSION on next save).
# v2 == v3 minus the bwd_fused path: its keys can never collide with or
# mis-apply to the new path, so entries migrate verbatim.  v1 lacked the
# padding key component and is never migrated.
MIGRATABLE_VERSIONS = (2,)
CACHE_ENV_VAR = "REPRO_TUNE_CACHE"
# Anchored to the source tree (src/repro/tuning/ -> repo root), not the CWD:
# a tuner run from the repo root and a training job launched from a scratch
# directory must resolve the same database.
DEFAULT_CACHE_PATH = Path(__file__).resolve().parents[3] / "results/tuning/cache.json"


def resolve_cache_path(path: Optional[os.PathLike] = None) -> Path:
    """Explicit argument > ``REPRO_TUNE_CACHE`` env > repo-local default."""
    if path is not None:
        return Path(path)
    env = os.environ.get(CACHE_ENV_VAR)
    return Path(env) if env else DEFAULT_CACHE_PATH


@dataclasses.dataclass(frozen=True)
class ShapeKey:
    """Identity of one tuned problem: execution path + static shape + regime.

    ``padding`` is part of the identity: 'same' and 'causal' problems with
    equal dims are measured under different windows and must not share a
    tuning decision.
    """

    path: str        # "fwd" | "bwd_in" | "bwd_k"
    B: int
    H: int
    L: int
    K: int
    dtype: str       # e.g. "float32", "bfloat16"
    backend: str     # jax.default_backend(): "cpu" | "tpu" | "gpu"
    padding: str = "same"

    def encode(self) -> str:
        return (f"{self.path}/B{self.B}-H{self.H}-L{self.L}-K{self.K}/"
                f"{self.padding}/{self.dtype}/{self.backend}")

    @classmethod
    def decode(cls, s: str) -> "ShapeKey":
        path, dims, padding, dtype, backend = s.split("/")
        vals = {p[0]: int(p[1:]) for p in dims.split("-")}
        return cls(path=path, B=vals["B"], H=vals["H"], L=vals["L"], K=vals["K"],
                   dtype=dtype, backend=backend, padding=padding)


@dataclasses.dataclass(frozen=True)
class TuneEntry:
    """The tuner's decision for one :class:`ShapeKey`."""

    variant: str
    block_h: int
    block_t: int
    batch_chunk: int
    time_us: float = 0.0          # counter-free steady-state measurement
    analytical_time_us: float = 0.0
    source: str = "measured"      # "measured" | "analytical" | "manual"

    def options(self, interpret: Optional[bool] = None) -> KernelOptions:
        return KernelOptions(
            block_h=self.block_h,
            block_t=self.block_t,
            batch_chunk=self.batch_chunk,
            interpret=interpret,
        )

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "TuneEntry":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


class TuningCache:
    """One JSON tuning database (thread-safe; load-once, save-on-put)."""

    def __init__(self, path: Optional[os.PathLike] = None):
        self.path = resolve_cache_path(path)
        self._lock = threading.Lock()
        self._entries: Dict[str, TuneEntry] = {}
        self._loaded = False

    # ------------------------------------------------------------------- I/O
    def _read_disk(self) -> Dict[str, TuneEntry]:
        """Current on-disk entries (empty on missing/corrupt/stale-version)."""
        if not self.path.exists():
            return {}
        try:
            raw = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return {}  # unreadable/corrupt: treat as empty, next save rewrites
        version = raw.get("version")
        if version != CACHE_VERSION and version not in MIGRATABLE_VERSIONS:
            return {}  # incompatible schema: never mis-apply stale decisions
        out: Dict[str, TuneEntry] = {}
        for key, ed in raw.get("entries", {}).items():
            try:
                out[key] = TuneEntry.from_dict(ed)
            except TypeError:
                continue
        return out

    def _load_locked(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        self._entries.update(self._read_disk())

    def save(self) -> None:
        with self._lock:
            self._load_locked()
            # Re-read and overlay so concurrent tuners sharing one file only
            # lose on *colliding* keys (last decision wins), never on
            # disjoint shapes tuned in parallel.
            merged = self._read_disk()
            merged.update(self._entries)
            self._entries = merged
            payload = {
                "version": CACHE_VERSION,
                "entries": {k: e.to_dict() for k, e in sorted(merged.items())},
            }
            self.path.parent.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(self.path.suffix + ".tmp")
            tmp.write_text(json.dumps(payload, indent=1))
            os.replace(tmp, self.path)

    # ------------------------------------------------------------- accessors
    def get(self, key: ShapeKey) -> Optional[TuneEntry]:
        with self._lock:
            self._load_locked()
            return self._entries.get(key.encode())

    def put(self, key: ShapeKey, entry: TuneEntry, *, persist: bool = True) -> None:
        with self._lock:
            self._load_locked()
            self._entries[key.encode()] = entry
        if persist:
            self.save()

    def items(self) -> Dict[ShapeKey, TuneEntry]:
        with self._lock:
            self._load_locked()
            return {ShapeKey.decode(k): e for k, e in self._entries.items()}

    def __len__(self) -> int:
        with self._lock:
            self._load_locked()
            return len(self._entries)

    def __bool__(self) -> bool:
        # An *empty* cache is still a cache — never let `cache or default`
        # style code silently swap in a different instance.
        return True


# ---------------------------------------------------------------------------
# process-wide memoized caches (one per resolved file path)
# ---------------------------------------------------------------------------

_CACHES: Dict[str, TuningCache] = {}
_CACHES_LOCK = threading.Lock()


def default_cache(path: Optional[os.PathLike] = None) -> TuningCache:
    """The memoized cache for ``path`` (or the env/default location)."""
    p = str(resolve_cache_path(path))
    with _CACHES_LOCK:
        c = _CACHES.get(p)
        if c is None:
            c = _CACHES[p] = TuningCache(p)
        return c


def reset_default_cache() -> None:
    """Drop all memoized caches (tests; or after external file edits)."""
    with _CACHES_LOCK:
        _CACHES.clear()


def lookup(path: str, B: int, H: int, L: int, K: int, dtype: str,
           backend: str, padding: str = "same") -> Optional[TuneEntry]:
    """The single entry point ``kernels/ops.py`` uses for auto dispatch."""
    return default_cache().get(
        ShapeKey(path=path, B=B, H=H, L=L, K=K, dtype=dtype, backend=backend,
                 padding=padding))
