"""Counter-free performance report CLI — the paper's full analysis from specs.

  PYTHONPATH=src python -m repro.launch.report
  PYTHONPATH=src python -m repro.launch.report --shapes paper --out REPORT.md \\
      --json BENCH_report.json
  PYTHONPATH=src python -m repro.launch.report --shapes 8x64x16384x4 --hw p100

One command reproduces the paper's Tables II/III / Fig. 10 analysis for
every (study variant x execution path): the execution-path traffic
decomposition, modeled HBM bytes with the per-operand breakdown, effective
bandwidth against the ``analysis/hw.py`` peaks, and the roofline table —
all *derived* from the declarative kernel schedules (``repro.perfmodel``),
with no hardware counters, no measurement, and no benchmark scripts.

The P100 paper-mode section places the paper's published Table II runtimes
on the roofline through the same derivation ``benchmarks/paper_roofline.py``
renders, so the report and the benchmark cannot diverge.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Sequence

from repro.analysis.hw import HARDWARE, TPU_V5E, HardwareModel
from repro.analysis.report import (
    counter_free_markdown,
    counter_free_report,
    dump_json,
)
from repro.kernels.common import DWConvDims
from repro.obs.calibrate import (
    CalibratedHardware,
    load_calibration,
    load_for_device,
    run_calibration,
    save_calibration,
)
from repro.perfmodel import dtype_itemsize


def parse_shapes(spec: str) -> List[DWConvDims]:
    from repro.tuning.space import PAPER_DIMS_CPU, PAPER_DIMS_FULL

    presets = {"paper": PAPER_DIMS_FULL, "paper-cpu": PAPER_DIMS_CPU}
    out: List[DWConvDims] = []
    for tok in spec.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if tok in presets:
            out.append(presets[tok])
            continue
        try:
            b, h, l, k = (int(v) for v in tok.lower().split("x"))
        except ValueError:
            raise SystemExit(
                f"bad shape {tok!r}: expected a preset {sorted(presets)} or BxHxLxK")
        out.append(DWConvDims(B=b, H=h, L=l, K=k))
    if not out:
        raise SystemExit("no shapes given")
    return out


def measured_error_rows(
    d: DWConvDims,
    *,
    hw: HardwareModel,
    calibration: Optional[CalibratedHardware] = None,
    itemsize: int = 4,
    dtype: str = "float32",
    iters: int = 3,
    warmup: int = 1,
    paths: Sequence[str] = ("fwd", "bwd_fused"),
) -> List[dict]:
    """Per-kernel modeled-vs-measured rows at a *metered* shape.

    For each unique (path x study variant) the study table carries, run the
    candidate through the tuner's measurable (paper §III-F protocol:
    explicit sync, warm-up excluded, median + σ over repeats) and put the
    measured time next to the analytical bound — datasheet and calibrated.
    ``error_ratio`` (measured / calibrated bound) is the per-kernel error
    bar the counter-free claims inherit.
    """
    from repro.analysis.timer import time_fn
    from repro.core.variant import REGISTRY
    from repro.obs import trace as obs_trace
    from repro.tuning import cost, space

    wanted = []
    for spec in REGISTRY.values():
        if spec.fwd == "auto":
            continue
        pairs = [("fwd", spec.fwd), ("bwd_in", spec.bwd_in),
                 ("bwd_k", spec.bwd_k)]
        if spec.bwd == "fused":
            pairs.append(("bwd_fused", spec.bwd_fused))
        for path, variant in pairs:
            if path in paths and (path, variant) not in wanted:
                wanted.append((path, variant))
    if "bwd_fused" in paths and ("bwd_fused", "split") not in wanted:
        wanted.append(("bwd_fused", "split"))

    tracer = obs_trace.get_tracer()
    rows = []
    for path, variant in wanted:
        c = space.normalize(space.Candidate(path, variant, 8, 512, 128), d)
        s = space._schedule(c, d, itemsize, "none")
        fn, args = cost.build_measurable(c, d, dtype=dtype)
        with tracer.span("report/measure", path=path, variant=variant) as sp:
            t = time_fn(fn, *args, warmup=warmup, iters=iters)
            sp.tag(measured_s=t.median_s)
            sp.attach("kernel", s, hw=hw, runtime_s=t.median_s)
        from repro import perfmodel

        modeled = perfmodel.analytical_time_s(s, hw)
        modeled_cal = (calibration.analytical_time_s(s, hw)
                       if calibration is not None else None)
        denom = modeled_cal if modeled_cal else modeled
        est = perfmodel.derive_traffic(s)
        rows.append({
            "path": path,
            "variant": variant,
            "modeled_s": modeled,
            "modeled_calibrated_s": modeled_cal,
            "measured_s": t.median_s,
            "measured_std_s": t.std_s,
            "error_ratio": (t.median_s / denom) if denom else None,
            "modeled_bytes": est.bytes_moved,
            "effective_bandwidth": (est.bytes_moved / t.median_s
                                    if est.reliable and t.median_s > 0 else None),
        })
    return rows


def resolve_calibration(spec: str, hw: HardwareModel) -> Optional[CalibratedHardware]:
    """``none`` | ``auto`` (load for this device, else run fast + persist)
    | an explicit JSON path."""
    if spec == "none":
        return None
    if spec != "auto":
        return load_calibration(spec)
    cal = load_for_device()
    if cal is None:
        print("[report] no calibration for this device — running the fast "
              "microbenchmark suite (persisting for reuse)", file=sys.stderr)
        cal = run_calibration(base=hw, fast=True)
        path = save_calibration(cal)
        print(f"[report] calibration written to {path}", file=sys.stderr)
    return cal


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--shapes", default="paper",
                    help="comma-separated presets (paper, paper-cpu) and/or BxHxLxK")
    ap.add_argument("--hw", default=TPU_V5E.name, choices=sorted(HARDWARE),
                    help="hardware model for the roofline terms")
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="operand dtype: sets the one itemsize convention "
                         "charged end to end (f32 partials always charge 4)")
    ap.add_argument("--block-h", type=int, default=8)
    ap.add_argument("--block-t", type=int, default=512)
    ap.add_argument("--batch-chunk", type=int, default=128)
    ap.add_argument("--no-paper", action="store_true",
                    help="omit the P100 paper-mode section")
    ap.add_argument("--no-epilogue", action="store_true",
                    help="omit the epilogue fused-vs-unfused section")
    ap.add_argument("--no-decode", action="store_true",
                    help="omit the streaming-decode (single-step) section")
    ap.add_argument("--out", default="",
                    help="write the markdown report here (default: stdout)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the machine-readable payload (BENCH_report.json)")
    ap.add_argument("--calibration", default="auto", metavar="PATH|auto|none",
                    help="calibrated-roof overlay: 'auto' loads (or runs + "
                         "persists) this device's microbenchmark fit; 'none' "
                         "keeps datasheet peaks only")
    ap.add_argument("--no-measure", dest="measure", action="store_false",
                    default=True,
                    help="skip the per-kernel modeled-vs-measured section")
    ap.add_argument("--no-verify", dest="verify", action="store_false",
                    default=True,
                    help="skip the static schedule↔kernel cross-check "
                         "('verified' column in the decomposition table)")
    ap.add_argument("--measure-shape", default="8x32x48x48",
                    help="BxHxLxK the error-bar kernels are metered at "
                         "(small: interpret mode runs kernel bodies in Python)")
    ap.add_argument("--measure-iters", type=int, default=3)
    ap.add_argument("--measure-paths", default="fwd,bwd_fused",
                    help="comma-separated execution paths to meter")
    args = ap.parse_args(argv)

    hw = HARDWARE[args.hw]
    itemsize = dtype_itemsize(args.dtype)
    calibration = resolve_calibration(args.calibration, hw)
    measured = None
    if args.measure:
        dm = parse_shapes(args.measure_shape)[0]
        rows = measured_error_rows(
            dm, hw=hw, calibration=calibration, itemsize=itemsize,
            dtype=args.dtype, iters=args.measure_iters,
            paths=tuple(p for p in args.measure_paths.split(",") if p))
        measured = {"dims": {"B": dm.B, "H": dm.H, "L": dm.L, "K": dm.K},
                    "dtype": args.dtype, "iters": args.measure_iters,
                    "rows": rows}
    payloads = []
    chunks = []
    for d in parse_shapes(args.shapes):
        payload = counter_free_report(
            d, hw=hw, itemsize=itemsize,
            block_h=args.block_h, block_t=args.block_t,
            batch_chunk=args.batch_chunk,
            include_paper=not args.no_paper,
            include_epilogue=not args.no_epilogue,
            include_decode=not args.no_decode,
            calibration=calibration,
            measured=measured,
            verify=args.verify,
        )
        payloads.append(payload)
        chunks.append(counter_free_markdown(payload))
    md = "\n".join(chunks)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"[report] wrote {args.out}", file=sys.stderr)
    else:
        print(md, end="")
    if args.json:
        dump_json(args.json, payloads[0] if len(payloads) == 1 else payloads)
        print(f"[report] wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
