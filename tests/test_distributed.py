"""Distribution-layer integration tests on a real (fake-)multi-device mesh.

Runs in a subprocess with 8 host devices so the main test process keeps its
single-device jax config.  Exercises: sharding rules -> NamedShardings,
microbatched+compressed train step executing under pjit with FSDP+TP, and
the seq-sharded decode step.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import get_config
    from repro.models.api import get_model, make_demo_batch
    from repro.distributed import sharding as shd
    from repro.distributed.stepfn import (build_train_step, build_serve_step,
        params_shardings, opt_state_shardings, cache_shardings)
    from repro.launch.mesh import make_mesh
    from repro.train.optim import adamw

    cfg = get_config("qwen2-0.5b", smoke=True)
    model = get_model(cfg)
    mesh = make_mesh((2, 4), ("data", "model"))
    opt = adamw(lr=1e-3)

    with mesh, shd.use_sharding(mesh, "train"):
        p_shard = params_shardings(model, mesh, "train")
        o_shard = opt_state_shardings(model, opt, mesh, "train")
        params = jax.jit(model.init, out_shardings=p_shard)(jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init, out_shardings=o_shard)(params)
        step = jax.jit(build_train_step(model, opt, microbatches=2,
                                        grad_dtype="bfloat16"),
                       donate_argnums=(0, 1))
        batch = make_demo_batch(cfg, 8, 32)
        losses = []
        for i in range(4):
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        assert losses[-1] < losses[0], losses  # same batch -> must descend
        # params must actually be sharded over the mesh
        leaf = params["layers"]["mlp"]["w_up"]
        assert len(leaf.sharding.device_set) == 8
        print("TRAIN_OK", losses[0], losses[-1])

    with mesh, shd.use_sharding(mesh, "serve"):
        cache = model.init_cache(8, 32)
        c_shapes = jax.eval_shape(lambda: model.init_cache(8, 32))
        c_shard = cache_shardings(model, mesh, "serve", c_shapes)
        cache = jax.tree.map(lambda x, s: jax.device_put(x, s), cache, c_shard)
        serve = jax.jit(build_serve_step(model), donate_argnums=(1,))
        tok = jnp.zeros((8, 1), jnp.int32)
        for _ in range(3):
            nxt, cache = serve(params, cache, {"tokens": tok})
            tok = nxt[:, None]
        assert int(cache["pos"]) == 3
        # KV cache sequence axis must be sharded over `model`
        spec = cache["k"].sharding.spec
        assert "model" in str(spec), spec
        print("SERVE_OK", str(spec))
""")


def test_multidevice_train_and_serve():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", CHILD], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    assert "TRAIN_OK" in r.stdout and "SERVE_OK" in r.stdout
