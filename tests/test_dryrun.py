"""Dry-run machinery test: subprocess with a scaled 8-device mesh compiles a
train cell and a decode cell end-to-end and emits well-formed roofline
records.  (The full 512-device matrix runs via ``python -m
repro.launch.dryrun --all``; its results live in results/dryrun/.)"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(tmp_path, arch, shape, mesh):
    env = dict(
        os.environ,
        PYTHONPATH=str(REPO / "src"),
        REPRO_DRYRUN_DEVICES="8",
        REPRO_RESULTS_DIR=str(tmp_path),
    )
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--force"]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    rec = json.loads((tmp_path / f"{arch}__{shape}__{mesh}.json").read_text())
    return rec


@pytest.mark.parametrize("shape,mesh", [
    ("train_4k", "pod1x16x16"),
    ("decode_32k", "pod2x16x16"),
])
def test_dryrun_cell_smollm(tmp_path, shape, mesh):
    rec = _run(tmp_path, "smollm-135m", shape, mesh)
    assert rec["compute_s"] > 0 and rec["memory_s"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")
    assert rec["flops_per_device"] > 0
    assert rec["collective_bytes_per_device"] > 0  # sharded program must communicate
    assert rec["arch"] == "smollm-135m" and rec["mesh"] == mesh
    assert rec["peak_memory_per_device"] > 0


def test_production_results_complete():
    """The committed 512-device matrix must cover every assigned cell
    (40 cells; long_500k runs only for sub-quadratic archs per DESIGN §5,
    so 33 runnable cells x 2 meshes)."""
    d = REPO / "results" / "dryrun"
    if not d.exists():
        pytest.skip("production dry-run results not present")
    from repro.configs.registry import get_config, list_archs, shape_cells_for

    missing = []
    for arch in list_archs():
        for cell in shape_cells_for(get_config(arch)):
            for mesh in ("pod1x16x16", "pod2x16x16"):
                p = d / f"{arch}__{cell}__{mesh}.json"
                if not p.exists():
                    missing.append(p.name)
    assert not missing, f"missing dry-run cells: {missing}"


def test_production_results_fit_memory():
    d = REPO / "results" / "dryrun"
    if not d.exists():
        pytest.skip("production dry-run results not present")
    bad = []
    for p in d.glob("*.json"):
        rec = json.loads(p.read_text())
        if rec.get("chips", 0) < 256:
            continue  # scaled test meshes
        if not rec.get("fits_16gb", False):
            bad.append((p.name, rec["bytes_per_device_estimate"] / 2**30))
    assert not bad, f"cells exceeding 16 GiB/chip: {bad}"
