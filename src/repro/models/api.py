"""Uniform model API over the architecture pool.

``get_model(cfg)`` returns a ``Model`` whose members close over the family
module.  ``input_specs(cfg, shape)`` builds the ShapeDtypeStruct stand-ins
for every model input of an assigned (arch x shape) cell — the dry-run
contract (weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, moe, ssm, transformer, vlm
from repro.models.config import ArchConfig, ShapeCell

_FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
    "vlm": vlm,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    module: Any

    def init(self, rng):
        return self.module.init(rng, self.cfg)

    def init_shapes(self):
        """Param ShapeDtypeStructs without allocation (dry-run)."""
        return jax.eval_shape(lambda r: self.module.init(r, self.cfg),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))

    def param_axes(self):
        return self.module.param_axes(self.cfg)

    def loss(self, params, batch):
        return self.module.loss_fn(params, self.cfg, batch)

    def decode_step(self, params, cache, batch):
        return self.module.decode_step(params, self.cfg, cache, batch["tokens"])

    def init_cache(self, batch: int, cache_len: int):
        return self.module.init_cache(self.cfg, batch, cache_len)

    def cache_axes(self):
        return self.module.cache_axes(self.cfg)

    def n_params(self) -> int:
        return self.module.n_params(self.cfg)

    def n_active_params(self) -> int:
        return self.module.n_active_params(self.cfg)

    @property
    def has_prefill(self) -> bool:
        return hasattr(self.module, "prefill") or self.cfg.family in (
            "dense", "moe", "ssm", "hybrid", "encdec", "vlm")


def get_model(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg, module=_FAMILIES[cfg.family])


# ---------------------------------------------------------------------------
# input specs per (arch x shape) cell
# ---------------------------------------------------------------------------


def batch_spec(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """Train/prefill batch ShapeDtypeStructs for one cell."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    spec: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "labels": jax.ShapeDtypeStruct((B, S), i32),
    }
    if cfg.family == "encdec":
        # stub conv frontend: precomputed frame embeddings
        spec["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        spec["img"] = jax.ShapeDtypeStruct((B, cfg.vlm.n_img_tokens, cfg.d_model), jnp.float32)
    return spec


def decode_batch_spec(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    B = cell.global_batch
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def batch_axes(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, tuple]:
    axes = {"tokens": ("act_batch", None), "labels": ("act_batch", None)}
    if cfg.family == "encdec":
        axes["frames"] = ("act_batch", None, None)
    if cfg.family == "vlm":
        axes["img"] = ("act_batch", None, None)
    return axes


def make_demo_batch(cfg: ArchConfig, batch: int, seq: int, rng: Optional[jax.Array] = None):
    """Concrete random batch for smoke tests/examples (small shapes only)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(k2, (batch, seq), 0, cfg.vocab, jnp.int32),
    }
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(k3, (batch, seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["img"] = jax.random.normal(k3, (batch, cfg.vlm.n_img_tokens, cfg.d_model), jnp.float32)
    return out
