"""Long-sequence weight-gradient gate (the time-tiling PR's tentpole bench).

The paper's named bottleneck — the reduction-dominated weight-gradient path
— matters most exactly where sequences are long (the S4 regime), yet the
untiled staged kernels grow their per-cell VMEM working set with L.  This
benchmark demonstrates the ``block_t`` time-tiled kernels opening that
regime on ``B=8, H=64, L=16384, K=4``:

  *legality*  — every staged Pallas bwdk / fused-backward variant has a
                time-tiled configuration whose per-cell VMEM working set is
                bounded by ``block_t`` (checked via the tuner's own
                legality predicates, and shown to be independent of L).
                **Gate**: tiled working set fits VMEM and does not grow
                when L doubles.

  *modeled*   — tiled-accum traffic vs the untiled model: the only extra
                bytes are the K-1 halo columns per tile seam.
                **Gate**: tiled bytes <= 1.10x untiled bytes.

  *runs*      — every Pallas bwdk variant (accum, twostage, naive) and
                fused-backward variant (fused, fused_partials) executes the
                long-sequence shape in interpret mode and matches
                ``jax.vjp`` of the reference.

  *tunes*     — ``tune_path`` runs on the long shape for both ``bwd_k`` and
                ``bwd_fused`` (a search space that the VMEM predicates used
                to prune to nothing) and persists a winner.

``--fast`` (CI smoke) shrinks the shape to ``B=2, H=16, L=2048, K=4`` so
the interpret-mode sweep stays cheap; the structure is identical.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import traffic
from repro.analysis.hw import TPU_V5E
from repro.kernels import ops, ref
from repro.kernels.common import DWConvDims, round_up
from repro.tuning import space
from repro.tuning.cache import TuningCache
from repro.tuning.space import Candidate, _vmem_working_set_bytes, is_legal
from repro.tuning.tuner import tune_path

# The long-sequence study shape: small batch, long time axis — the regime
# where the untiled staged slabs are the binding constraint.
LONGSEQ_DIMS = DWConvDims(B=8, H=64, L=16384, K=4)
LONGSEQ_DIMS_FAST = DWConvDims(B=2, H=16, L=2048, K=4)

BWDK_PALLAS = ("accum", "twostage", "naive")
FUSED_PALLAS = ("fused", "fused_partials")

# Modeled-traffic gate: tiling may only add the per-seam halo re-read.
TRAFFIC_GATE = 1.10


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def _tiled_candidate(d: DWConvDims, path: str, variant: str, block_t: int) -> Candidate:
    return space.normalize(
        Candidate(path=path, variant=variant, block_h=8, block_t=block_t,
                  batch_chunk=8), d)


def legality_rows(d: DWConvDims, block_t: int) -> List[Row]:
    """Tiled candidates are VMEM-legal and their footprint is L-independent."""
    rows: List[Row] = []
    d2 = dataclasses.replace(d, L=2 * d.L)
    for path, variants in (("bwd_k", ("accum", "twostage")),
                           ("bwd_fused", FUSED_PALLAS)):
        for v in variants:
            c = _tiled_candidate(d, path, v, block_t)
            ok, reason = is_legal(c, d, hw=TPU_V5E)
            need = _vmem_working_set_bytes(c, d, itemsize=4)
            need2 = _vmem_working_set_bytes(_tiled_candidate(d2, path, v, block_t),
                                            d2, itemsize=4)
            bounded = ok and need2 == need
            verdict = "GATE_OK" if bounded else "GATE_FAILED"
            rows.append(Row(
                f"paper_longseq/legality/{path}/{v}", 0.0,
                f"block_t={c.block_t} vmem={need}B vmem@2L={need2}B "
                f"legal={ok}({reason}) {verdict}"))
    return rows


def modeled_rows(d: DWConvDims, block_t: int) -> List[Row]:
    """Tiled-accum traffic within TRAFFIC_GATE of the untiled model."""
    tiled = traffic.bwdk_traffic(d, "accum", block_t=block_t)
    untiled = traffic.bwdk_traffic(d, "accum", block_t=d.L)
    ratio = tiled.bytes_moved / untiled.bytes_moved
    verdict = "GATE_OK" if ratio <= TRAFFIC_GATE else "GATE_FAILED"
    return [
        Row("paper_longseq/modeled/accum_tiled", 0.0,
            f"bytes={tiled.bytes_moved / 1e9:.4f}GB block_t={block_t}"),
        Row("paper_longseq/modeled/accum_untiled", 0.0,
            f"bytes={untiled.bytes_moved / 1e9:.4f}GB"),
        Row("paper_longseq/modeled/ratio", 0.0,
            f"tiled_vs_untiled_bytes={ratio:.4f} (gate <= {TRAFFIC_GATE}) {verdict}"),
    ]


def run_rows(d: DWConvDims, block_t: int) -> List[Row]:
    """Every Pallas bwdk/fused variant executes the shape and matches vjp."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(d.B, d.H, d.L)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(d.B, d.H, d.L)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(d.H, d.K)), jnp.float32)
    _, vjp = jax.vjp(lambda x, k: ref.dwconv_fwd_ref(x, k, d.padding), x, k)
    dx_want, dk_want = vjp(dy)
    opts = ops.KernelOptions(block_h=8, block_t=block_t, batch_chunk=8)

    rows: List[Row] = []
    for v in BWDK_PALLAS:
        dk = ops.dwconv_bwd_kernel_op(x, dy, d.K, d.padding, v, opts)
        err = float(jnp.max(jnp.abs(dk - dk_want)) / jnp.max(jnp.abs(dk_want)))
        verdict = "GATE_OK" if err < 1e-5 else "GATE_FAILED"
        rows.append(Row(f"paper_longseq/runs/bwd_k/{v}", 0.0,
                        f"rel_err={err:.2e} {verdict}"))
    for v in FUSED_PALLAS:
        dx, dk = ops.dwconv_bwd_fused_op(x, dy, k, d.padding, v, opts)
        err_k = float(jnp.max(jnp.abs(dk - dk_want)) / jnp.max(jnp.abs(dk_want)))
        err_x = float(jnp.max(jnp.abs(dx - dx_want)) / jnp.max(jnp.abs(dx_want)))
        verdict = "GATE_OK" if max(err_k, err_x) < 1e-5 else "GATE_FAILED"
        rows.append(Row(f"paper_longseq/runs/bwd_fused/{v}", 0.0,
                        f"rel_err_dk={err_k:.2e} rel_err_dx={err_x:.2e} {verdict}"))
    return rows


def tune_rows(d: DWConvDims, tmp_cache_path: str, budget: int) -> List[Row]:
    """The long shape tunes end-to-end through both backward paths.

    The gate is that the tuner's *legal candidate space* contains time-tiled
    staged configurations (the exact regression this benchmark exists to
    catch is those being VMEM-mispruned back to the xla/naive escape
    hatches) and that tuning persists a winner.  Which candidates get
    metered within the budget — and who wins under interpret-mode timing —
    is reported but not gated.
    """
    cache = TuningCache(tmp_cache_path)
    staged = {"accum", "twostage", "fused", "fused_partials"}
    Lout = round_up(d.L, 128)
    rows: List[Row] = []
    for path in ("bwd_k", "bwd_fused"):
        tiled_in_space = any(
            c.variant in staged and c.block_t < Lout
            for c in space.search_space(d, path))
        res = tune_path(d, path, budget=budget, iters=1, warmup=0,
                        cache=cache, persist=True)
        e = res.best
        tiled_metered = any(
            c.variant in staged and c.block_t < Lout for c, _, _ in res.history)
        ok = tiled_in_space and len(TuningCache(tmp_cache_path)) > 0
        verdict = "GATE_OK" if ok else "GATE_FAILED"
        rows.append(Row(
            f"paper_longseq/tunes/{path}", e.time_us,
            f"winner={e.variant} bh={e.block_h} bt={e.block_t} bc={e.batch_chunk} "
            f"measured {res.candidates_measured}/{res.candidates_considered} "
            f"tiled_in_space={tiled_in_space} tiled_metered={tiled_metered} "
            f"{verdict}"))
    return rows


def run(fast: bool = False) -> List[Row]:
    import tempfile

    d = LONGSEQ_DIMS_FAST if fast else LONGSEQ_DIMS
    block_t = 512
    rows = legality_rows(d, block_t)
    rows += modeled_rows(d, block_t)
    rows += run_rows(d, block_t)
    with tempfile.TemporaryDirectory() as td:
        rows += tune_rows(d, f"{td}/longseq-cache.json", budget=3 if fast else 4)
    return rows


if __name__ == "__main__":
    import sys

    rows = run(fast="--fast" in sys.argv)
    for r in rows:
        print(f"{r.name},{r.us_per_call:.1f},{r.derived}")
    if any("FAILED" in r.derived for r in rows):
        sys.exit(1)
