"""Streaming-decode gate (this PR's tentpole benchmark).

Serving a conv-bearing model token by token used to re-run the full causal
conv over the length-L activation cache to produce one new position —
O(B·H·L) bytes per step.  The fused single-step decode kernels
(``repro.kernels.dwconv_decode``) shift the K-1-tap ring, apply the K-tap
dot with the bias/act epilogue, and write the ring back: O(B·H·K) bytes.
Three regimes gate the claim:

  *modeled*    — per-step HBM bytes of the fused decode schedules vs the
                 full-conv-over-cache baseline
                 (``perfmodel.decode_full_conv_schedule``) at a serving
                 shape.  **Gate**: the modeled byte margin must be at least
                 ``GATE_MIN_MARGIN`` x (the structural L/K win, less
                 padding).

  *measured*   — wall-clock of one production (XLA) fused step vs the
                 full-conv baseline step at the same shape: the margin must
                 materialize as real latency, not just modeled bytes.
                 **Gate**: fused median <= baseline median.  The Pallas
                 variants are reported unguarded (interpret mode runs their
                 bodies in Python on CPU — structure, not TPU prediction).

  *continuous* — the serve loop's continuous-batching path
                 (``repro.launch.serve.run_continuous``) over >= 3 ragged
                 slot-pool widths on the smoke Mamba-2: tokens/sec and
                 p50/p99 per-step latency from the span tracer, exported as
                 the ``decode_tokens_per_s`` / ``decode_p99_step_s``
                 top-level metrics (perf-ledger gated across runs).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import perfmodel
from repro.analysis.timer import time_fn
from repro.core import dwconv as dw
from repro.kernels import ops, ref
from repro.kernels.common import DWConvDims
from repro.perfmodel.schedules import decode_full_conv_schedule

# Serving shape: the smoke Mamba-2 conv_dim at a realistic slot pool, with
# the cache length the baseline must re-read every step.
SERVE = DWConvDims(B=8, H=192, L=1, K=4, padding="causal")
CACHE_LEN = 64
# The structural margin is ~L/K bytes; lane padding and the double ring
# write erode it, so gate at a quarter of the ideal.
GATE_MIN_MARGIN = CACHE_LEN / SERVE.K / 4


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str


def modeled_rows() -> List[Row]:
    rows: List[Row] = []
    base = dataclasses.replace(SERVE, L=CACHE_LEN)
    baseline = decode_full_conv_schedule(base, epilogue="bias+silu")
    best = perfmodel.derive_traffic(baseline)
    rows.append(Row(
        "paper_decode/modeled/full_conv_baseline", 0.0,
        f"bytes={best.bytes_moved / 1e6:.3f}MB cache_len={CACHE_LEN}"))
    worst = float("inf")
    for variant in ("rows", "chanblock", "xla"):
        s = perfmodel.schedule_for("decode", variant, SERVE, 4,
                                   epilogue="bias+silu")
        est = perfmodel.derive_traffic(s)
        margin = best.bytes_moved / est.bytes_moved
        worst = min(worst, margin)
        rows.append(Row(
            f"paper_decode/modeled/{variant}", 0.0,
            f"bytes={est.bytes_moved / 1e3:.2f}kB "
            f"AI={est.arithmetic_intensity:.2f} "
            f"margin_vs_full_conv={margin:.1f}x"))
    verdict = "GATE_OK" if worst >= GATE_MIN_MARGIN else "GATE_FAILED"
    rows.append(Row(
        "paper_decode/modeled/gate", 0.0,
        f"worst_margin={worst:.1f}x (gate >= {GATE_MIN_MARGIN:.1f}x) {verdict}"))
    return rows


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def measured_rows(iters: int = 5) -> List[Row]:
    B, H, K, L = SERVE.B, SERVE.H, SERVE.K, CACHE_LEN
    cache = _rand((B, H, L), 0)
    ring = _rand((B, H, K - 1), 1)
    x = _rand((B, H), 2)
    k = _rand((H, K), 3)
    bias = _rand((H,), 4)

    @jax.jit
    def baseline_step(cache, x, k, bias):
        # the pre-decode serve loop: roll the new input into the cache and
        # re-run the whole causal conv for one output position
        cache = jnp.concatenate([cache[:, :, 1:], x[:, :, None]], axis=-1)
        y = dw.dwconv_act(cache, k, bias, act="silu", padding="causal",
                          variant="xla")
        return y[:, :, -1], cache

    def fused_step(variant):
        def fn(ring, x, k, bias):
            return ops.dwconv_decode_jit(ring, x, k, variant,
                                         bias=bias, act="silu")
        return fn

    t_base = time_fn(baseline_step, cache, x, k, bias, warmup=2, iters=iters)
    rows = [Row("paper_decode/measured/full_conv_baseline",
                t_base.median_s * 1e6, f"cache_len={L}")]
    t_fused = time_fn(fused_step("xla"), ring, x, k, bias,
                      warmup=2, iters=iters)
    speedup = t_base.median_s / max(t_fused.median_s, 1e-12)
    verdict = "GATE_OK" if t_fused.median_s <= t_base.median_s else "GATE_FAILED"
    rows.append(Row("paper_decode/measured/fused_xla",
                    t_fused.median_s * 1e6,
                    f"speedup_vs_full_conv={speedup:.2f}x {verdict}"))
    for variant in ("rows", "chanblock"):
        t = time_fn(fused_step(variant), ring, x, k, bias,
                    warmup=1, iters=max(2, iters // 2))
        rows.append(Row(f"paper_decode/measured/fused_{variant}",
                        t.median_s * 1e6,
                        "interpret-mode (structure only, ungated)"))
    return rows


def continuous_rows(fast: bool = False) -> List[Row]:
    from repro.configs.mamba2_1_3b import SMOKE
    from repro.launch.serve import run_continuous
    from repro.models.api import get_model
    from repro.obs.trace import Tracer

    cfg = SMOKE
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt_len, gen = 8, (3 if fast else 6)
    rows: List[Row] = []
    for slots in (1, 2, 4):
        n_req = slots + 2
        reqs = [rng.integers(0, cfg.vocab, size=(1, prompt_len))
                .astype(np.int32) for _ in range(n_req)]
        gens = [max(1, gen - (i % 3)) for i in range(n_req)]  # ragged
        tracer = Tracer(enabled=True)
        stats = run_continuous(
            model, params, slots=slots, request_tokens=reqs,
            gen_lengths=gens, cache_len=32, tracer=tracer,
            label=f"bench/continuous{slots}")
        rows.append(Row(
            f"paper_decode/continuous/slots{slots}",
            stats["p50_step_s"] * 1e6,
            f"requests={n_req} steps={stats['steps']} "
            f"tokens_per_s={stats['tokens_per_s']:.2f} "
            f"p50_step_s={stats['p50_step_s']:.5f} "
            f"p99_step_s={stats['p99_step_s']:.5f}"))
    return rows


_TPS_RE = re.compile(r"tokens_per_s=([0-9.]+)")
_P99_RE = re.compile(r"p99_step_s=([0-9.]+)")


def top_level_metrics(rows: List[Row]) -> Dict[str, float]:
    """Promote the widest-pool continuous-batching throughput and p99 step
    latency to top-level ``--json`` keys (perf-ledger gated)."""
    out: Dict[str, float] = {}
    for r in rows:  # last continuous row wins: the widest slot pool
        tps, p99 = _TPS_RE.search(r.derived), _P99_RE.search(r.derived)
        if tps:
            out["decode_tokens_per_s"] = float(tps.group(1))
        if p99:
            out["decode_p99_step_s"] = float(p99.group(1))
    return out


def run(fast: bool = False) -> List[Row]:
    rows = modeled_rows()
    rows += measured_rows(iters=3 if fast else 5)
    rows += continuous_rows(fast=fast)
    return rows
